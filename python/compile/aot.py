"""AOT lowering: JAX → HLO **text** artifacts for the rust runtime.

Run once by ``make artifacts``; rust loads the text via
``HloModuleProto::from_text_file`` (HLO text, NOT ``.serialize()`` — the
image's xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit-id protos; the text
parser reassigns ids. See /opt/xla-example/README.md).

Artifacts (demo dims: 8 experts, d_model 64, d_ff 256, capacity 64):

* ``gate.hlo.txt``          — x[cap, d] → (idx i32[cap], weight f32[cap])
* ``expert_ffn_<e>.hlo.txt`` — x[cap, d] → y[cap, d], weights baked in
* ``moe_layer.hlo.txt``     — x[cap, d] → y[cap, d], fused layer, all baked
* ``meta.json``             — dims + seed, consumed by the rust engine

Weights are baked into the HLO as constants (closed over at trace time), so
the rust request path only moves activations.
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

N_EXPERTS = 8
D_MODEL = 64
D_FF = 256
CAPACITY = 64
# Expert-FFN capacity buckets: the rust engine routes each expert's token
# group to the smallest compiled capacity that fits, instead of always paying
# the full-capacity FFN (EXPERIMENTS.md §Perf: ~3x serving throughput).
FFN_CAPACITIES = [8, 16, 64]
SEED = 0


def to_hlo_text(fn, *example_args):
    """Lower a jittable function to XLA HLO text (return_tuple=True).

    ``print_large_constants=True`` is essential: the default printer elides
    big constants as ``{...}``, and the xla text parser then reads the baked
    weights back as zeros — silently corrupting the model.
    """
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # the xla_extension 0.5.1 text parser predates newer metadata attributes
    # (source_end_line etc.), so strip metadata entirely
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--seed", type=int, default=SEED)
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    params = model.init_params(jax.random.PRNGKey(args.seed), N_EXPERTS, D_MODEL, D_FF)
    x_spec = jax.ShapeDtypeStruct((CAPACITY, D_MODEL), jnp.float32)

    written = []

    def emit_with_spec(name, fn, spec):
        text = to_hlo_text(fn, spec)
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        written.append(name)
        print(f"wrote {path} ({len(text)} chars)")

    def emit(name, fn):
        emit_with_spec(name, fn, x_spec)

    emit("gate.hlo.txt", lambda x: model.gate_fn(params, x))
    for e in range(N_EXPERTS):
        for cap in FFN_CAPACITIES:
            spec = jax.ShapeDtypeStruct((cap, D_MODEL), jnp.float32)
            emit_with_spec(
                f"expert_ffn_{e}_c{cap}.hlo.txt",
                lambda x, e=e: (model.expert_ffn_padded(params, e, x),),
                spec,
            )
    emit("moe_layer.hlo.txt", lambda x: (model.moe_layer(params, x),))

    meta = {
        "n_experts": N_EXPERTS,
        "d_model": D_MODEL,
        "d_ff": D_FF,
        "capacity": CAPACITY,
        "ffn_capacities": FFN_CAPACITIES,
        "seed": args.seed,
        "artifacts": written,
    }
    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
