"""Pure-jnp oracles for the Pallas kernels (the L1 correctness ground truth).

Every Pallas kernel in this package has an exact reference here; pytest
(``python/tests/test_kernel.py``) asserts allclose between the two across a
hypothesis sweep of shapes and dtypes.
"""

import jax.numpy as jnp


def gelu(x):
    """tanh-approximated GELU (matches the kernel's in-VMEM activation)."""
    return (
        0.5
        * x
        * (1.0 + jnp.tanh(jnp.sqrt(2.0 / jnp.pi) * (x + 0.044715 * jnp.power(x, 3))))
    )


def expert_ffn_ref(x, w1, b1, w2, b2):
    """Reference expert FFN: ``gelu(x @ w1 + b1) @ w2 + b2``.

    Args:
      x: [tokens, d_model]
      w1: [d_model, d_ff]; b1: [d_ff]
      w2: [d_ff, d_model]; b2: [d_model]
    Returns:
      [tokens, d_model]
    """
    h = gelu(x @ w1 + b1)
    return h @ w2 + b2


def gate_ref(x, wg):
    """Reference top-1 gate.

    Args:
      x: [tokens, d_model]; wg: [d_model, n_experts]
    Returns:
      (expert_idx int32 [tokens], gate_weight f32 [tokens]) where the weight
      is the softmax probability of the selected expert.
    """
    logits = x @ wg
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    idx = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    weight = jnp.take_along_axis(probs, idx[:, None], axis=-1)[:, 0]
    return idx, weight


def moe_layer_ref(x, wg, w1, b1, w2, b2):
    """Reference dense-masked MoE layer (top-1 routing).

    Args:
      x: [tokens, d_model]
      wg: [d_model, n_experts]
      w1: [n_experts, d_model, d_ff]; b1: [n_experts, d_ff]
      w2: [n_experts, d_ff, d_model]; b2: [n_experts, d_model]
    Returns:
      [tokens, d_model] — each token processed by its top-1 expert, scaled by
      the gate weight.
    """
    idx, weight = gate_ref(x, wg)
    n_experts = wg.shape[-1]
    out = jnp.zeros_like(x)
    for e in range(n_experts):
        y = expert_ffn_ref(x, w1[e], b1[e], w2[e], b2[e])
        mask = (idx == e).astype(x.dtype)[:, None]
        out = out + y * mask
    return out * weight[:, None].astype(x.dtype)


def gate_top2_ref(x, wg):
    """Reference top-2 gate: two experts per token, renormalized weights."""
    logits = x @ wg
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    order = jnp.argsort(-logits, axis=-1)
    i1, i2 = order[:, 0].astype(jnp.int32), order[:, 1].astype(jnp.int32)
    p1 = jnp.take_along_axis(probs, i1[:, None], axis=-1)[:, 0]
    p2 = jnp.take_along_axis(probs, i2[:, None], axis=-1)[:, 0]
    denom = p1 + p2
    return i1, i2, p1 / denom, p2 / denom


def moe_layer_top2_ref(x, wg, w1, b1, w2, b2):
    """Reference dense-masked top-2 MoE layer."""
    i1, i2, g1, g2 = gate_top2_ref(x, wg)
    n_experts = wg.shape[-1]
    out = jnp.zeros_like(x)
    for e in range(n_experts):
        y = expert_ffn_ref(x, w1[e], b1[e], w2[e], b2[e])
        m1 = ((i1 == e).astype(x.dtype) * g1.astype(x.dtype))[:, None]
        m2 = ((i2 == e).astype(x.dtype) * g2.astype(x.dtype))[:, None]
        out = out + y * (m1 + m2)
    return out
