"""L1: the expert-FFN Pallas kernel — the MoE compute hot spot.

TPU mapping of the paper's per-expert FFN (DESIGN.md §Hardware-Adaptation):
the GPU implementation the paper assumes tiles the two GEMMs across
threadblocks with shared-memory staging; on TPU we express the same schedule
with a Pallas grid and BlockSpecs:

* grid axis 0 tiles the **token** dimension (``block_t`` rows per step);
* grid axis 1 tiles the **d_ff** dimension (``block_f`` columns per step),
  so neither weight matrix has to fit in VMEM at once;
* each grid step computes a partial ``gelu(x·W1[:, j])·W2[j, :]`` product on
  the MXU and accumulates into the output block, which stays resident in
  VMEM across the ``d_ff`` sweep (revisited-output accumulation);
* block sizes default to MXU-friendly 128 multiples, clamped to the layer's
  actual dims.

VMEM per step ≈ ``block_t·d_model + d_model·block_f + block_f·d_model +
block_t·block_f + block_t·d_model`` floats — bounded regardless of ``d_ff``.

``interpret=True`` always: the CPU PJRT runtime cannot execute Mosaic
custom-calls; correctness is validated against ``ref.expert_ffn_ref`` and
real-TPU efficiency is estimated analytically in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One (token-block, ff-block) grid step.

    Computes ``h = gelu(x·W1_j + b1_j)`` for this d_ff tile and accumulates
    ``h·W2_j`` into the output tile; the bias ``b2`` is added on the first
    ff-step only.
    """
    j = pl.program_id(1)

    x = x_ref[...]
    h = ref.gelu(
        jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...]
    )
    partial = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = (partial + b2_ref[...]).astype(o_ref.dtype)

    @pl.when(j != 0)
    def _accum():
        o_ref[...] = (o_ref[...] + partial.astype(o_ref.dtype)).astype(o_ref.dtype)


def _pick_block(dim, preferred):
    """Largest divisor of ``dim`` that is ≤ preferred (MXU-aligned when the
    dim allows it)."""
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_t", "block_f"))
def expert_ffn(x, w1, b1, w2, b2, *, block_t=128, block_f=128):
    """Pallas expert FFN: ``gelu(x @ w1 + b1) @ w2 + b2``.

    Args:
      x: [tokens, d_model] activations.
      w1: [d_model, d_ff]; b1: [d_ff]; w2: [d_ff, d_model]; b2: [d_model].
      block_t / block_f: preferred token / d_ff tile sizes (clamped to
        divisors of the actual dims).
    Returns:
      [tokens, d_model], same dtype as ``x``.
    """
    t, d_model = x.shape
    d_ff = w1.shape[1]
    bt = _pick_block(t, block_t)
    bf = _pick_block(d_ff, block_f)
    grid = (t // bt, d_ff // bf)

    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d_model), lambda i, j: (i, 0)),  # x tile
            pl.BlockSpec((d_model, bf), lambda i, j: (0, j)),  # W1 column tile
            pl.BlockSpec((bf,), lambda i, j: (j,)),  # b1 tile
            pl.BlockSpec((bf, d_model), lambda i, j: (j, 0)),  # W2 row tile
            pl.BlockSpec((d_model,), lambda i, j: (0,)),  # b2
        ],
        out_specs=pl.BlockSpec((bt, d_model), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d_model), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w1, b1, w2, b2)


def vmem_bytes_per_step(block_t, block_f, d_model, dtype_bytes=4):
    """Analytic VMEM footprint of one grid step (see module docstring).

    Used by EXPERIMENTS.md §Perf to check the schedule against the ~16 MiB
    per-core VMEM budget of a TPU.
    """
    x_tile = block_t * d_model
    w1_tile = d_model * block_f
    b1_tile = block_f
    w2_tile = block_f * d_model
    b2_tile = d_model
    h_tile = block_t * block_f
    out_tile = block_t * d_model
    return dtype_bytes * (x_tile + w1_tile + b1_tile + w2_tile + b2_tile + h_tile + out_tile)
