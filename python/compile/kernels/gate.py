"""L1: the top-1 gate Pallas kernel.

A thin matmul + row-softmax + argmax. Tokens are tiled on grid axis 0; the
gate weight matrix ``wg`` ([d_model, n_experts], a few KB) stays fully
resident in VMEM — the expert count is small (8 in the paper) so the reduction
dimension never needs tiling.

``interpret=True`` as everywhere (see ``moe_ffn``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gate_kernel(x_ref, wg_ref, idx_ref, weight_ref):
    logits = jnp.dot(x_ref[...], wg_ref[...], preferred_element_type=jnp.float32)
    m = logits.max(axis=-1, keepdims=True)
    probs = jnp.exp(logits - m)
    probs = probs / probs.sum(axis=-1, keepdims=True)
    idx = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    idx_ref[...] = idx
    weight_ref[...] = jnp.max(probs, axis=-1).astype(weight_ref.dtype)


def _pick_block(dim, preferred):
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_t",))
def gate_top1(x, wg, *, block_t=128):
    """Pallas top-1 gate.

    Args:
      x: [tokens, d_model]; wg: [d_model, n_experts].
    Returns:
      (expert_idx int32 [tokens], gate_weight f32 [tokens]).
    """
    t, _ = x.shape
    n_experts = wg.shape[1]
    bt = _pick_block(t, block_t)
    grid = (t // bt,)
    d_model = x.shape[1]

    return pl.pallas_call(
        _gate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d_model), lambda i: (i, 0)),
            pl.BlockSpec((d_model, n_experts), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.int32),
            jax.ShapeDtypeStruct((t,), jnp.float32),
        ],
        interpret=True,
    )(x, wg)


def _gate_top2_kernel(x_ref, wg_ref, idx1_ref, idx2_ref, w1_ref, w2_ref):
    logits = jnp.dot(x_ref[...], wg_ref[...], preferred_element_type=jnp.float32)
    m = logits.max(axis=-1, keepdims=True)
    probs = jnp.exp(logits - m)
    probs = probs / probs.sum(axis=-1, keepdims=True)
    i1 = jnp.argmax(logits, axis=-1)
    p1 = jnp.max(probs, axis=-1)
    # mask the winner, take the runner-up
    masked = jnp.where(
        jax.nn.one_hot(i1, logits.shape[-1], dtype=jnp.bool_), -jnp.inf, logits
    )
    i2 = jnp.argmax(masked, axis=-1)
    p2 = jnp.take_along_axis(probs, i2[:, None], axis=-1)[:, 0]
    # renormalize the pair (GShard-style top-2 combine weights)
    denom = p1 + p2
    idx1_ref[...] = i1.astype(jnp.int32)
    idx2_ref[...] = i2.astype(jnp.int32)
    w1_ref[...] = (p1 / denom).astype(w1_ref.dtype)
    w2_ref[...] = (p2 / denom).astype(w2_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t",))
def gate_top2(x, wg, *, block_t=128):
    """Pallas top-2 gate (the paper's "one or two experts" routing).

    Returns ``(idx1, idx2, w1, w2)``: the two selected experts per token and
    their renormalized combine weights (``w1 + w2 == 1``).
    """
    t, d_model = x.shape
    n_experts = wg.shape[1]
    bt = _pick_block(t, block_t)
    grid = (t // bt,)

    return pl.pallas_call(
        _gate_top2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d_model), lambda i: (i, 0)),
            pl.BlockSpec((d_model, n_experts), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.int32),
            jax.ShapeDtypeStruct((t,), jnp.int32),
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.float32),
        ],
        interpret=True,
    )(x, wg)
