"""L2: the JAX MoE layer (build-time only; never on the request path).

Composes the L1 Pallas kernels into the paper's MoE layer (Fig. 1):
gate → dispatch → expert FFN → combine. Two variants are exported:

* :func:`moe_layer` — a fully fused, AOT-compilable layer with dense-masked
  dispatch: every expert runs over the full token block, masked by the gate's
  top-1 selection. Static shapes make it trivially AOT-exportable; the
  compute redundancy is irrelevant on the tiny demo dims (the paper's
  *performance* story lives in the L3 simulator, not in this functional
  model — see DESIGN.md).
* :func:`expert_ffn_padded` / :func:`gate_fn` — the *split* artifacts used by
  the rust serving engine, which performs real sparse dispatch itself: it
  runs the gate, groups tokens by expert (ordering transmissions with
  Aurora's schedule), and invokes each expert's FFN on a padded
  fixed-capacity batch.

Weight initialization is seeded and reproduced exactly by the rust side's
expectations (weights are baked into the HLO as constants at AOT time).
"""

import jax
import jax.numpy as jnp

from compile.kernels import gate as gate_kernel
from compile.kernels import moe_ffn


def init_params(key, n_experts, d_model, d_ff):
    """Seeded MoE-layer parameters.

    Returns a dict with ``wg [d, E]``, ``w1 [E, d, f]``, ``b1 [E, f]``,
    ``w2 [E, f, d]``, ``b2 [E, d]``.
    """
    kg, k1, k2 = jax.random.split(key, 3)
    scale1 = 1.0 / jnp.sqrt(d_model)
    scale2 = 1.0 / jnp.sqrt(d_ff)
    return {
        "wg": jax.random.normal(kg, (d_model, n_experts), jnp.float32) * scale1,
        "w1": jax.random.normal(k1, (n_experts, d_model, d_ff), jnp.float32) * scale1,
        "b1": jnp.zeros((n_experts, d_ff), jnp.float32),
        "w2": jax.random.normal(k2, (n_experts, d_ff, d_model), jnp.float32) * scale2,
        "b2": jnp.zeros((n_experts, d_model), jnp.float32),
    }


def gate_fn(params, x):
    """Gate sub-graph: top-1 expert index and gate weight per token."""
    return gate_kernel.gate_top1(x, params["wg"])


def expert_ffn_padded(params, e, x):
    """Single expert's FFN over a padded fixed-capacity token block.

    The rust engine pads each expert's token group to the compiled capacity;
    padding rows are garbage-in/garbage-out and dropped by the engine.

    Block sizes: the demo artifact dims (d_model 64, d_ff 256) fit a single
    tile comfortably, so the whole layer is one grid step — the interpret-mode
    lowering then emits straight-line HLO instead of a grid while-loop
    (EXPERIMENTS.md §Perf: ~2x serving throughput). The multi-tile schedule
    (128x128 blocks) is what a real ViT-B deployment on TPU would compile.
    """
    d_ff = params["w1"].shape[-1]
    return moe_ffn.expert_ffn(
        x,
        params["w1"][e],
        params["b1"][e],
        params["w2"][e],
        params["b2"][e],
        block_t=x.shape[0],
        block_f=d_ff,
    )


def moe_layer(params, x):
    """The fused dense-masked MoE layer (top-1 routing).

    Args:
      params: from :func:`init_params`.
      x: [tokens, d_model].
    Returns:
      [tokens, d_model].
    """
    idx, weight = gate_fn(params, x)
    n_experts = params["wg"].shape[1]
    out = jnp.zeros_like(x)
    for e in range(n_experts):
        y = expert_ffn_padded(params, e, x)
        mask = (idx == e).astype(x.dtype)[:, None]
        out = out + y * mask
    return out * weight[:, None].astype(x.dtype)


def moe_stack(params_list, x):
    """A stack of MoE layers (the model the e2e serving demo loads)."""
    for p in params_list:
        x = moe_layer(p, x)
    return x


def gate_top2_fn(params, x):
    """Top-2 gate sub-graph (paper §2.1: "each token will be sent to one or
    two experts")."""
    return gate_kernel.gate_top2(x, params["wg"])


def moe_layer_top2(params, x):
    """Dense-masked top-2 MoE layer: each token combines its two selected
    experts' outputs with renormalized gate weights."""
    i1, i2, g1, g2 = gate_top2_fn(params, x)
    n_experts = params["wg"].shape[1]
    out = jnp.zeros_like(x)
    for e in range(n_experts):
        y = expert_ffn_padded(params, e, x)
        m1 = ((i1 == e).astype(x.dtype) * g1.astype(x.dtype))[:, None]
        m2 = ((i2 == e).astype(x.dtype) * g2.astype(x.dtype))[:, None]
        out = out + y * (m1 + m2)
    return out
