"""L2 correctness: the fused MoE layer vs the pure-jnp reference model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0), 8, 64, 256)


def test_moe_layer_matches_ref(params):
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 64), jnp.float32)
    got = model.moe_layer(params, x)
    want = ref.moe_layer_ref(
        x, params["wg"], params["w1"], params["b1"], params["w2"], params["b2"]
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_moe_layer_shapes(params):
    for t in [1, 16, 64]:
        x = jnp.ones((t, 64), jnp.float32)
        y = model.moe_layer(params, x)
        assert y.shape == (t, 64)
        assert y.dtype == x.dtype


def test_gate_and_split_experts_compose_to_layer(params):
    """The split artifacts (gate + per-expert FFN), recombined the way the
    rust engine does, must equal the fused layer."""
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 64), jnp.float32)
    idx, weight = model.gate_fn(params, x)
    idx = np.asarray(idx)
    weight = np.asarray(weight)
    out = np.zeros_like(np.asarray(x))
    for e in range(8):
        rows = np.nonzero(idx == e)[0]
        if len(rows) == 0:
            continue
        # pad the expert's token group to capacity, as the engine does
        group = np.zeros((64, 64), np.float32)
        group[: len(rows)] = np.asarray(x)[rows]
        y = np.asarray(model.expert_ffn_padded(params, e, jnp.asarray(group)))
        out[rows] = y[: len(rows)] * weight[rows, None]
    fused = np.asarray(model.moe_layer(params, x))
    np.testing.assert_allclose(out, fused, rtol=1e-4, atol=1e-5)


def test_moe_stack_composes(params):
    p2 = model.init_params(jax.random.PRNGKey(9), 8, 64, 256)
    x = jax.random.normal(jax.random.PRNGKey(3), (16, 64), jnp.float32)
    y = model.moe_stack([params, p2], x)
    z = model.moe_layer(p2, model.moe_layer(params, x))
    np.testing.assert_allclose(y, z, rtol=1e-6, atol=1e-6)


def test_init_params_deterministic():
    a = model.init_params(jax.random.PRNGKey(5), 4, 8, 16)
    b = model.init_params(jax.random.PRNGKey(5), 4, 8, 16)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_routing_actually_uses_multiple_experts(params):
    x = jax.random.normal(jax.random.PRNGKey(4), (256, 64), jnp.float32)
    idx, _ = model.gate_fn(params, x)
    used = len(np.unique(np.asarray(idx)))
    assert used >= 3, f"degenerate routing: only {used} experts used"
