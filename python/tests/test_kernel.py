"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and dtypes; every case asserts allclose — this is
the core correctness signal for the kernel layer.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import gate, moe_ffn, ref

hypothesis.settings.register_profile(
    "kernels", max_examples=25, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@hypothesis.given(
    t=st.sampled_from([1, 4, 16, 48, 128]),
    d_model=st.sampled_from([8, 32, 64]),
    d_ff=st.sampled_from([16, 64, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_expert_ffn_matches_ref(t, d_model, d_ff, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = rand(keys[0], (t, d_model), jnp.float32)
    w1 = rand(keys[1], (d_model, d_ff), jnp.float32) * 0.1
    b1 = rand(keys[2], (d_ff,), jnp.float32) * 0.1
    w2 = rand(keys[3], (d_ff, d_model), jnp.float32) * 0.1
    b2 = rand(keys[4], (d_model,), jnp.float32) * 0.1
    got = moe_ffn.expert_ffn(x, w1, b1, w2, b2)
    want = ref.expert_ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@hypothesis.given(
    block_t=st.sampled_from([1, 8, 32, 128]),
    block_f=st.sampled_from([8, 32, 128]),
)
def test_expert_ffn_block_size_invariance(block_t, block_f):
    """The tiling schedule must not change the numerics."""
    keys = jax.random.split(jax.random.PRNGKey(7), 5)
    t, d_model, d_ff = 32, 16, 64
    x = rand(keys[0], (t, d_model), jnp.float32)
    w1 = rand(keys[1], (d_model, d_ff), jnp.float32) * 0.1
    b1 = rand(keys[2], (d_ff,), jnp.float32) * 0.1
    w2 = rand(keys[3], (d_ff, d_model), jnp.float32) * 0.1
    b2 = rand(keys[4], (d_model,), jnp.float32) * 0.1
    got = moe_ffn.expert_ffn(x, w1, b1, w2, b2, block_t=block_t, block_f=block_f)
    want = ref.expert_ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_ffn_dtypes(dtype):
    keys = jax.random.split(jax.random.PRNGKey(3), 5)
    t, d_model, d_ff = 16, 32, 64
    x = rand(keys[0], (t, d_model), dtype)
    w1 = rand(keys[1], (d_model, d_ff), dtype) * 0.1
    b1 = rand(keys[2], (d_ff,), dtype) * 0.1
    w2 = rand(keys[3], (d_ff, d_model), dtype) * 0.1
    b2 = rand(keys[4], (d_model,), dtype) * 0.1
    got = moe_ffn.expert_ffn(x, w1, b1, w2, b2)
    want = ref.expert_ffn_ref(
        x.astype(jnp.float32),
        w1.astype(jnp.float32),
        b1.astype(jnp.float32),
        w2.astype(jnp.float32),
        b2.astype(jnp.float32),
    )
    assert got.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        got.astype(jnp.float32), want, rtol=tol, atol=tol
    )


@hypothesis.given(
    t=st.sampled_from([1, 8, 64, 96]),
    d_model=st.sampled_from([8, 64]),
    n_experts=st.sampled_from([2, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gate_matches_ref(t, d_model, n_experts, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = rand(k1, (t, d_model), jnp.float32)
    wg = rand(k2, (d_model, n_experts), jnp.float32)
    idx, weight = gate.gate_top1(x, wg)
    ridx, rweight = ref.gate_ref(x, wg)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(weight, rweight, rtol=1e-5, atol=1e-6)
    assert idx.dtype == jnp.int32
    assert (np.asarray(idx) >= 0).all() and (np.asarray(idx) < n_experts).all()
    # top-1 softmax weight is at least 1/E and at most 1
    assert (np.asarray(weight) >= 1.0 / n_experts - 1e-6).all()
    assert (np.asarray(weight) <= 1.0 + 1e-6).all()


def test_vmem_estimate_within_budget_for_vit_b():
    """The schedule's analytic VMEM footprint at ViT-B dims fits a TPU core's
    ~16 MiB VMEM with the default 128x128 blocks (see EXPERIMENTS.md §Perf)."""
    budget = 16 * 1024 * 1024
    fp32 = moe_ffn.vmem_bytes_per_step(128, 128, 768, dtype_bytes=4)
    assert fp32 < budget, f"fp32 footprint {fp32} exceeds VMEM budget"
    bf16 = moe_ffn.vmem_bytes_per_step(128, 128, 768, dtype_bytes=2)
    assert bf16 < budget / 2
