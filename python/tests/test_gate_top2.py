"""Top-2 gating: Pallas kernel vs oracle, and layer-level composition."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import gate, ref

hypothesis.settings.register_profile(
    "top2", max_examples=15, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("top2")


@hypothesis.given(
    t=st.sampled_from([1, 8, 64]),
    d_model=st.sampled_from([8, 32]),
    n_experts=st.sampled_from([2, 8]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gate_top2_matches_ref(t, d_model, n_experts, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (t, d_model), jnp.float32)
    wg = jax.random.normal(k2, (d_model, n_experts), jnp.float32)
    i1, i2, w1, w2 = gate.gate_top2(x, wg)
    r1, r2, rw1, rw2 = ref.gate_top2_ref(x, wg)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(r1))
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(r2))
    np.testing.assert_allclose(w1, rw1, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(w2, rw2, rtol=1e-5, atol=1e-6)


def test_top2_weights_normalized_and_distinct():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 32), jnp.float32)
    wg = jax.random.normal(jax.random.PRNGKey(1), (32, 8), jnp.float32)
    i1, i2, w1, w2 = gate.gate_top2(x, wg)
    np.testing.assert_allclose(np.asarray(w1) + np.asarray(w2), 1.0, rtol=1e-5)
    assert (np.asarray(i1) != np.asarray(i2)).all()
    assert (np.asarray(w1) >= np.asarray(w2) - 1e-6).all()


def test_moe_layer_top2_matches_ref():
    params = model.init_params(jax.random.PRNGKey(0), 8, 32, 64)
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 32), jnp.float32)
    got = model.moe_layer_top2(params, x)
    want = ref.moe_layer_top2_ref(
        x, params["wg"], params["w1"], params["b1"], params["w2"], params["b2"]
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_top2_reduces_to_top1_weighting_when_one_expert_dominates():
    # a gate matrix that makes expert 0 dominate: top-2 weight w1 -> 1
    params = model.init_params(jax.random.PRNGKey(0), 4, 8, 16)
    wg = jnp.zeros((8, 4)).at[:, 0].set(100.0)
    x = jnp.ones((8, 8), jnp.float32)
    i1, _, w1, w2 = gate.gate_top2(x, wg)
    assert (np.asarray(i1) == 0).all()
    assert (np.asarray(w1) > 0.99).all()
    assert (np.asarray(w2) < 0.01).all()
