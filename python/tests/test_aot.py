"""AOT path: lowering produces loadable HLO text with the expected interface."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile import aot, model


def test_to_hlo_text_produces_hlo_module():
    params = model.init_params(jax.random.PRNGKey(0), 2, 8, 16)
    spec = jax.ShapeDtypeStruct((4, 8), jnp.float32)
    text = aot.to_hlo_text(lambda x: (model.moe_layer(params, x),), spec)
    assert "HloModule" in text
    assert "ENTRY" in text
    # weights are baked: the ENTRY computation takes only the activation
    entry_body = text[text.index("ENTRY") :]
    n_params = entry_body.count("parameter(")
    assert n_params == 1, f"expected a single activation parameter, found {n_params}"


def test_gate_lowering_has_two_outputs():
    params = model.init_params(jax.random.PRNGKey(0), 4, 8, 16)
    spec = jax.ShapeDtypeStruct((4, 8), jnp.float32)
    text = aot.to_hlo_text(lambda x: model.gate_fn(params, x), spec)
    assert "HloModule" in text
    # tuple of (s32 idx, f32 weight)
    assert "s32[4]" in text and "f32[4]" in text


@pytest.mark.slow
def test_full_artifact_build(tmp_path):
    """Run the real artifact build into a temp dir and check the manifest."""
    env = dict(os.environ)
    repo_py = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path)],
        cwd=repo_py,
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr
    meta = json.loads((tmp_path / "meta.json").read_text())
    assert meta["n_experts"] == 8
    for name in meta["artifacts"]:
        text = (tmp_path / name).read_text()
        assert "HloModule" in text, name
