//! Gray-failure recovery: straggler detection and effective-rate replanning.
//!
//! ```bash
//! cargo run --release --example straggler_recovery
//! ```
//!
//! A 16-GPU cluster where GPU 2 silently drops to 0.4x compute mid-trace —
//! no failure event fires; the only symptom is every barrier stretching to
//! the straggler's pace. Three acts: the serving race (blind static plan vs
//! the detector-driven coordinator vs an oracle told the truth), the
//! detection math by hand (observed-vs-predicted busy ratios, EWMA,
//! confirmation), and the sync-wait collapse once the plan is re-solved on
//! the inferred effective rates.

use aurora::cluster::{Cluster, GpuScales, Topology};
use aurora::coordinator::{run_online, ClusterEvent, DegradeState, OnlineConfig, OnlineStrategy};
use aurora::obs::timeline::{TimelineRecorder, Timelines};
use aurora::obs::{DegradationDetector, DegradeConfig, DetectorEvent, WindowObservation};
use aurora::planner::{Planner, ReplicationConfig};
use aurora::schedule::SchedulePolicy;
use aurora::sim::{simulate_window_topology_recorded, MoeLayerStats};
use aurora::trace::ModelTrace;
use aurora::traffic::drifting_zipf_traffic;

const N_GPUS: usize = 16;
const STRAGGLER: usize = 2;
const TRUE_SCALE: f64 = 0.4;

/// One recorded window of `layer` on `cluster`, optionally throttled by the
/// true effective-rate scales.
fn record_window(layer: &MoeLayerStats, cluster: &Cluster, scales: Option<&GpuScales>) -> Timelines {
    let mut rec = TimelineRecorder::new(cluster.len());
    simulate_window_topology_recorded(
        &[layer],
        None,
        cluster,
        scales,
        &Topology::BigSwitch,
        SchedulePolicy::Aurora,
        &mut rec,
    );
    rec.take().expect("recorder was enabled")
}

fn main() {
    // 1. The serving race: 32 experts on 16 GPUs, drifting Zipf(1.2)
    //    routing, GPU 2 degraded to 0.4x compute from window 5 on. The
    //    coordinator is never told — it must infer the throttle from its
    //    own recorded timelines.
    let cfg = OnlineConfig {
        n_gpus: N_GPUS,
        n_experts: 2 * N_GPUS,
        windows: 16,
        rotate_every: 8,
        events: vec![(
            5,
            ClusterEvent::GpuDegraded {
                gpu: STRAGGLER,
                compute_scale: TRUE_SCALE,
                bandwidth_scale: 1.0,
            },
        )],
        degrade_detection: true,
        ..OnlineConfig::default()
    };
    let cluster = Cluster::homogeneous(cfg.n_gpus, 814.0);
    println!(
        "straggler race: {} experts on {} GPUs, GPU {} at {:.1}x compute from window 5\n",
        cfg.n_experts, cfg.n_gpus, STRAGGLER, TRUE_SCALE
    );
    for strategy in [
        OnlineStrategy::Static,
        OnlineStrategy::Coordinator,
        OnlineStrategy::Oracle,
    ] {
        let out = run_online(&cfg, &cluster, strategy);
        println!(
            "{:<12} total {:>8.2} ms | p99 window {:>6.2} ms | {} replan(s)",
            out.strategy, out.total_ms, out.p99_ms, out.replans
        );
    }

    // 2. The detection math, by hand. Serve one window's projected traffic
    //    on the degraded truth with the recorder on, re-simulate the same
    //    traffic at nominal rates, and ratio the per-GPU busy totals. Busy
    //    time is barrier-independent, so the straggler's peers read ~1.0
    //    while its own compute ratio reads the true scale.
    let stats = MoeLayerStats {
        traffic: drifting_zipf_traffic(cfg.n_experts, cfg.tokens_per_sender, 1.2, cfg.seed, 0),
        gate_ms: 0.02,
        ffn_ms_per_token: 0.001,
        agg_ms: 0.015,
    };
    let trace = ModelTrace {
        name: "demo".to_string(),
        layers: vec![stats.clone()],
    };
    let planner = Planner::default();
    let (rep, splits) = planner
        .plan_replicated(&[&trace], &cluster, &ReplicationConfig::default())
        .expect("plans");
    let gpu_stats = rep.project_layer_split(0, &stats, &splits);

    let mut truth = DegradeState::new(N_GPUS);
    truth.apply(&ClusterEvent::GpuDegraded {
        gpu: STRAGGLER,
        compute_scale: TRUE_SCALE,
        bandwidth_scale: 1.0,
    });

    let observed = record_window(&gpu_stats, &cluster, Some(truth.scales()));
    let predicted = record_window(&gpu_stats, &cluster, None);

    let dcfg = DegradeConfig::default();
    let obs = WindowObservation::from_timelines(&observed, &predicted, dcfg.min_ms);
    println!(
        "\nper-window ratios (predicted/observed busy ms): GPU {} compute {:.3}, \
         peers GPU {} compute {:.3}, GPU {} link {:.3}",
        STRAGGLER,
        obs.compute_ratio[STRAGGLER],
        (STRAGGLER + 1) % N_GPUS,
        obs.compute_ratio[(STRAGGLER + 1) % N_GPUS],
        STRAGGLER,
        obs.link_ratio[STRAGGLER]
    );

    let confirm = dcfg.confirm_windows;
    let mut detector = DegradationDetector::new(N_GPUS, dcfg);
    println!("feeding the same window to the detector (confirm after {confirm}):");
    for window in 1..=4 {
        let events = detector.observe(&obs);
        let inferred = detector.scales();
        print!(
            "  window {window}: inferred compute[{}] = {:.3} (truth {:.2})",
            STRAGGLER, inferred.compute[STRAGGLER], TRUE_SCALE
        );
        for ev in &events {
            if let DetectorEvent::Degraded {
                gpu,
                compute_scale,
                bandwidth_scale,
            } = ev
            {
                print!(
                    "  -> CONFIRMED gpu {gpu} at {compute_scale:.3}x compute, \
                     {bandwidth_scale:.3}x bandwidth"
                );
            }
        }
        println!();
        if detector.is_degraded(STRAGGLER) {
            break;
        }
    }

    // 3. The repair: re-solve the plan on the *inferred* effective cluster
    //    (the truth stays hidden) and serve it on the real degraded rates.
    //    The barriers stop waiting on GPU 2 and sync-wait collapses.
    let effective = detector.scales().scaled(&cluster);
    let (rep2, splits2) = planner
        .plan_replicated(&[&trace], &effective, &ReplicationConfig::default())
        .expect("plans");
    let repaired_stats = rep2.project_layer_split(0, &stats, &splits2);
    let repaired = record_window(&repaired_stats, &cluster, Some(truth.scales()));

    let before = observed.breakdown();
    let after = repaired.breakdown();
    println!("\nwindow breakdown on the degraded truth (fraction of makespan):");
    println!(
        "  blind plan:    makespan {:>7.2} ms | compute {:>5.1}% | sync-wait {:>5.1}% | idle {:>5.1}%",
        before.makespan_ms,
        100.0 * before.cluster.compute,
        100.0 * before.cluster.sync_wait,
        100.0 * before.cluster.idle
    );
    println!(
        "  repaired plan: makespan {:>7.2} ms | compute {:>5.1}% | sync-wait {:>5.1}% | idle {:>5.1}%",
        after.makespan_ms,
        100.0 * after.cluster.compute,
        100.0 * after.cluster.sync_wait,
        100.0 * after.cluster.idle
    );
    assert!(
        after.makespan_ms < before.makespan_ms,
        "the effective-rate replan must beat the blind plan on the degraded truth"
    );
    println!(
        "\nreplanning on the inferred rates recovered {:.1}% of the degraded window",
        100.0 * (1.0 - after.makespan_ms / before.makespan_ms)
    );
}
