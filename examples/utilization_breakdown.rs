//! Where do the GPU-milliseconds go? Attribute every one on a 16-GPU
//! cluster, exclusive vs colocated, and echo the paper's ≈1.5× utilization
//! claim (§7) with the idle time itemized instead of asserted.
//!
//! ```bash
//! cargo run --release --example utilization_breakdown
//! ```

use aurora::cluster::Cluster;
use aurora::config::gbps_to_tokens_per_ms;
use aurora::obs::timeline::TimelineRecorder;
use aurora::schedule::SchedulePolicy;
use aurora::sim::{simulate_colocated_recorded, simulate_exclusive_recorded, MoeLayerStats};
use aurora::traffic::zipf_traffic;

fn main() {
    // 16 experts on 16 GPUs over 100 Gbps effective links. The FFN constant
    // keeps per-GPU compute comparable to one all-to-all (K ≈ C) — the
    // regime where exclusive deployments stall on their collective barriers
    // and colocation has something to fill them with.
    let n = 16;
    let bw = gbps_to_tokens_per_ms(100.0, 3072.0, 0.2);
    let cluster = Cluster::homogeneous(n, bw);
    let layer = |seed: u64| MoeLayerStats {
        traffic: zipf_traffic(n, 1024, 1.2, seed),
        gate_ms: 0.02,
        ffn_ms_per_token: 1.0 / bw,
        agg_ms: 0.015,
    };
    let a = layer(1);
    let b = layer(2);
    println!(
        "two Zipf(1.2) MoE layers, {n} experts on {n} GPUs, {bw:.0} tokens/ms links\n"
    );

    // Exclusive: model A alone on its own GPUs. Every all-to-all is a
    // barrier — the engines wait, and the timeline says so.
    let mut rec = TimelineRecorder::new(n);
    let (excl, _) = simulate_exclusive_recorded(&a, &cluster, SchedulePolicy::Aurora, &mut rec);
    let excl_tl = rec.take().expect("recorder was enabled");
    println!("=== exclusive (model A alone) ===");
    println!("{}", excl_tl.render_table());

    // Colocated: models A and B interleave on the same GPUs (Table 2
    // recurrences) — B's experts compute through A's barriers.
    let mut rec = TimelineRecorder::new(n);
    let (coloc, _) =
        simulate_colocated_recorded(&a, &b, &cluster, SchedulePolicy::Aurora, &mut rec);
    let coloc_tl = rec.take().expect("recorder was enabled");
    println!("=== colocated (A + B interleaved) ===");
    println!("{}", coloc_tl.render_table());

    let excl_bd = excl_tl.breakdown();
    let coloc_bd = coloc_tl.breakdown();
    println!(
        "exclusive:  {:.3} ms/layer, util {:.1}% (sync-wait {:.1}%, trailing idle {:.1}%)",
        excl.inference_ms,
        100.0 * excl.utilization,
        100.0 * excl_bd.cluster.sync_wait,
        100.0 * excl_bd.cluster.idle,
    );
    println!(
        "colocated:  {:.3} ms/layer for both models, util {:.1}% (sync-wait {:.1}%)",
        coloc.inference_ms,
        100.0 * coloc.utilization,
        100.0 * coloc_bd.cluster.sync_wait,
    );
    println!(
        "\ncolocation lifts utilization {:.2}x (paper reports ~1.5x at K ~= C)",
        coloc.utilization / excl.utilization
    );
}
