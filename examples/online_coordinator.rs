//! Online coordination under drifting routing.
//!
//! ```bash
//! cargo run --release --example online_coordinator
//! ```
//!
//! Serves a drifting-Zipf workload (the hot expert rotates every 8 windows)
//! four ways — static initial plan, naive replan-every-window, the
//! cost-aware coordinator, and a zero-cost oracle — then walks one replan
//! decision by hand: drift score, candidate plan, migration flows, staging
//! makespan, and the hitless swap.

use aurora::cluster::Cluster;
use aurora::coordinator::{
    plan_migration, run_online, Coordinator, CoordinatorConfig, CoordinatorDecision,
    OnlineConfig, OnlineStrategy,
};
use aurora::planner::{Planner, ReplicationConfig};
use aurora::sim::MoeLayerStats;
use aurora::trace::ModelTrace;
use aurora::traffic::drifting_zipf_traffic;

fn main() {
    // 1. The serving race: 16 experts on 8 GPUs, Zipf(1.2) popularity with
    //    the hot expert rotating every 8 of 32 windows.
    let cfg = OnlineConfig::default();
    let cluster = Cluster::homogeneous(cfg.n_gpus, 814.0);
    println!(
        "drifting-Zipf serving: {} experts on {} GPUs, {} windows, rotate every {}\n",
        cfg.n_experts, cfg.n_gpus, cfg.windows, cfg.rotate_every
    );
    for strategy in [
        OnlineStrategy::Static,
        OnlineStrategy::EveryWindow,
        OnlineStrategy::Coordinator,
        OnlineStrategy::Oracle,
    ] {
        let out = run_online(&cfg, &cluster, strategy);
        println!(
            "{:<12} total {:>8.2} ms | p95 window {:>6.2} ms | {} replan(s), migration {:.2} ms",
            out.strategy, out.total_ms, out.p95_ms, out.replans, out.migration_ms
        );
    }

    // 2. One replan decision, by hand. Plan for phase 0, then feed the
    //    rotated regime and watch the pipeline commit.
    let stats = |phase: usize| MoeLayerStats {
        traffic: drifting_zipf_traffic(cfg.n_experts, cfg.tokens_per_sender, 1.2, cfg.seed, phase),
        gate_ms: 0.02,
        ffn_ms_per_token: 0.001,
        agg_ms: 0.015,
    };
    let plan_layer = stats(0);
    let trace = ModelTrace {
        name: "phase-0".to_string(),
        layers: vec![plan_layer.clone()],
    };
    let planner = Planner::default();
    let (rep, splits) = planner
        .plan_replicated(&[&trace], &cluster, &ReplicationConfig::default())
        .expect("plans");
    let mut coord = Coordinator::new(
        planner,
        rep,
        splits,
        &plan_layer,
        CoordinatorConfig::default(),
    );

    println!("\nfeeding the rotated regime (phase 2):");
    let rotated = stats(2).traffic;
    for window in 1.. {
        let decision = coord.observe_window(&rotated, &cluster);
        match decision {
            CoordinatorDecision::Keep { drift } => {
                println!("  window {window}: keep (drift {drift:.3})");
            }
            CoordinatorDecision::Replan(outcome) => {
                println!(
                    "  window {window}: REPLAN — drift {:.3}, predicted gain {:.2} ms over the horizon, migration {:.2} ms ({} flow(s), {} freed)",
                    outcome.drift,
                    outcome.predicted_gain_ms,
                    outcome.migration_ms,
                    outcome.migration.flows.len(),
                    outcome.migration.dropped.len()
                );
                break;
            }
        }
        coord.advance(5.0);
        if window > 16 {
            println!("  (no replan within 16 windows)");
            break;
        }
    }
    println!("staged weight traffic shares the serving links: {:?} phase", coord.swap_phase());
    coord.advance(1e9); // serve long enough to finish staging
    println!(
        "after staging: {:?} phase, {} swap(s) completed\n",
        coord.swap_phase(),
        coord.stats.swaps
    );

    // 3. Migrations are ordinary traffic: diff two plans and inspect.
    let tgt_trace = ModelTrace {
        name: "phase-2".to_string(),
        layers: vec![stats(2)],
    };
    let planner = Planner::default();
    let (tgt, _) = planner
        .plan_replicated(&[&tgt_trace], &cluster, &ReplicationConfig::default())
        .expect("plans");
    let (cur, _) = coord.active();
    let migration = plan_migration(cur, &tgt, 4096);
    println!(
        "diff active -> phase-2 plan: {} weight flow(s), b_max {} tokens, {:.2} ms on this cluster",
        migration.flows.len(),
        migration.makespan_tokens(),
        migration.migration_ms(&cluster)
    );
}
