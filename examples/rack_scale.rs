//! Rack scale: hierarchical scheduling + placement on a two-tier fabric.
//!
//! ```bash
//! cargo run --release --example rack_scale
//! ```
//!
//! Walks a 16-GPU, 4-group, 4x-oversubscribed deployment end to end: build
//! the topology, plan with and without topology awareness, schedule the
//! all-to-all hierarchically, and compare against flat Aurora priced
//! honestly on the oversubscribed uplinks.

use aurora::cluster::{uplink_bound, Cluster, Topology};
use aurora::eval::skewed_workload;
use aurora::planner::Planner;
use aurora::schedule::{
    comm_time_on, flat_aurora_on_topology, hierarchical_schedule, SchedulePolicy,
};
use aurora::trace::ModelTrace;

fn main() {
    // 1. A 16-GPU cluster in 4 racks; each rack's uplink into the spine is
    //    4x oversubscribed (uplink rate = 4 ports / 4 = one port rate).
    let cluster = Cluster::homogeneous(16, 814.0);
    let topo = Topology::even_two_tier(16, 4, 4.0).expect("16 GPUs tile into 4 groups");
    println!(
        "fabric: 16 GPUs, 4 groups, 4x oversubscription (uplink {} tokens/ms)",
        topo.uplink_rates(&cluster)[0]
    );

    // 2. One 32-expert model (two experts per GPU slot) with Zipf(1.2)
    //    routing — the skewed regime where rack placement matters.
    let trace = skewed_workload(32, 4, 1024, 1.2, 2024);
    let refs: Vec<&ModelTrace> = vec![&trace];
    let planner = Planner::default();

    // 3. Plan twice: topology-blind vs topology-aware.
    let blind = planner.plan_multi(&refs, &cluster).expect("plans");
    let placed = planner.plan_topology(&refs, &cluster, &topo).expect("plans");
    let layer = &trace.layers[0];
    let blind_agg = blind.aggregated_traffic(&[layer]);
    let placed_agg = placed.aggregated_traffic(&[layer]);
    println!(
        "cross-uplink drain: blind {:.3} ms -> placed {:.3} ms",
        uplink_bound(&blind_agg, &cluster, &topo),
        uplink_bound(&placed_agg, &cluster, &topo)
    );

    // 4. Schedule the placed all-to-all hierarchically: per-rack Aurora
    //    phases plus a group-level BvN uplink phase with gateway senders.
    let sched = hierarchical_schedule(&placed_agg, &cluster, &topo).expect("two-tier fabric");
    println!(
        "two-phase schedule: intra {:.3} ms | inter {:.3} ms ({} group rounds) | pipelined {:.3} ms",
        sched.intra_ms,
        sched.inter_ms,
        sched.inter.len(),
        sched.pipelined_ms
    );

    // 5. The comparison that motivates the subsystem: flat Aurora's rounds
    //    are contention-free at the ports but not at the uplinks.
    let hier_ms = comm_time_on(&placed_agg, &cluster, &topo, SchedulePolicy::Aurora).makespan;
    let flat_ms = flat_aurora_on_topology(&blind_agg, &cluster, &topo);
    let sjf_ms = comm_time_on(&blind_agg, &cluster, &topo, SchedulePolicy::Sjf).makespan;
    println!("\n{:<28} {:>12}", "stack", "all-to-all");
    println!("{:<28} {:>9.3} ms", "hierarchical (plan+sched)", hier_ms);
    println!("{:<28} {:>9.3} ms", "flat aurora (blind plan)", flat_ms);
    println!("{:<28} {:>9.3} ms", "sjf (blind plan)", sjf_ms);
    println!("\nhierarchical speedup over flat aurora: {:.2}x", flat_ms / hier_ms);

    // 6. Oversubscription sweep: the win opens as the uplinks tighten.
    println!("\n{:<10} {:>14} {:>14} {:>9}", "oversub", "hier (ms)", "flat (ms)", "speedup");
    for os in [1.0, 2.0, 4.0, 8.0] {
        let t = Topology::even_two_tier(16, 4, os).expect("tiles");
        let p = planner.plan_topology(&refs, &cluster, &t).expect("plans");
        let agg = p.aggregated_traffic(&[layer]);
        let h = comm_time_on(&agg, &cluster, &t, SchedulePolicy::Aurora).makespan;
        let f = flat_aurora_on_topology(&blind_agg, &cluster, &t);
        println!("{:<10} {:>11.3} ms {:>11.3} ms {:>8.2}x", format!("{os}x"), h, f, f / h);
    }
}
