//! Tracing a plan and an online serving run.
//!
//! ```bash
//! cargo run --release --example trace_profile
//! ```
//!
//! Plans a skewed 64-GPU workload under a wall-clock tracer and prints the
//! five hottest planner phases, then serves a drifting-Zipf stream with the
//! cost-aware coordinator under a *sim-time* tracer and prints the replan
//! gate's decision log — every window's drift score, candidate gain, and
//! verdict. Both traces export to Chrome trace-event JSON; the sim-time one
//! is byte-identical across runs with the same seed.

use aurora::cluster::{Cluster, Topology};
use aurora::coordinator::{run_online_traced, OnlineConfig, OnlineStrategy};
use aurora::eval::skewed_workload;
use aurora::obs::profile::aggregate_phases;
use aurora::obs::{MetricsRegistry, Tracer};
use aurora::planner::{Planner, ReplicationConfig};

fn main() {
    // 1. Plan under a wall-clock tracer: 64 experts on 64 GPUs in 8 racks,
    //    Zipf(1.2) routing, up to 2 replicas per hot expert.
    let n = 64;
    let cluster = Cluster::homogeneous(n, 814.0);
    let topo = Topology::even_two_tier(n, 8, 4.0).expect("topology");
    let trace = skewed_workload(n, 2, 512, 1.2, 7);
    let tr = Tracer::wall();
    let planner = Planner::default();
    let cfg = ReplicationConfig {
        max_replicas: 2,
        ..ReplicationConfig::default()
    };
    let (rep, _splits) = planner
        .plan_replicated_topology_traced(&[&trace], &cluster, &topo, &cfg, &tr)
        .expect("plans");
    println!(
        "planned {} experts on {} GPUs ({} replica(s) added)\n",
        n,
        cluster.len(),
        rep.added_replicas()
    );

    println!("top 5 hottest planner phases:");
    for p in aggregate_phases(&tr.spans()).iter().take(5) {
        println!(
            "  {:<32} {:>4}x  total {:>8} µs  max {:>7} µs",
            p.name, p.count, p.total_us, p.max_us
        );
    }
    println!(
        "\nchrome trace: {} spans, {} decision records (open in chrome://tracing)\n",
        tr.spans().len(),
        tr.decisions().len()
    );

    // 2. Serve a drifting-Zipf stream under a sim-time tracer. The tracer's
    //    clock is the simulator's, so this trace is deterministic: rerunning
    //    with the same seed produces a byte-identical file.
    let ocfg = OnlineConfig::default();
    let serve_cluster = Cluster::homogeneous(ocfg.n_gpus, 814.0);
    let sim_tr = Tracer::sim();
    let metrics = MetricsRegistry::new();
    let out = run_online_traced(
        &ocfg,
        &serve_cluster,
        OnlineStrategy::Coordinator,
        &sim_tr,
        &metrics,
    );
    println!(
        "coordinator strategy: total {:.2} ms over {} windows, {} replan(s), {} swap(s)\n",
        out.total_ms,
        out.per_window_ms.len(),
        out.replans,
        out.swaps
    );

    println!("replan gate decision log:");
    for d in sim_tr.decisions() {
        if d.kind == "coordinator.replan_gate" {
            println!("  {}", d.render());
        }
    }

    if let Some(h) = metrics.histogram("serve.window_ms") {
        println!(
            "\nwindow latency: {} windows, mean {:.2} ms, p99 {:.2} ms",
            h.count(),
            h.mean(),
            h.quantile(0.99).unwrap_or(0.0)
        );
    }
}
