//! Pod scale: planning and scheduling a 1024-GPU, three-tier fabric.
//!
//! ```bash
//! cargo run --release --example pod_scale
//! ```
//!
//! Walks the thousand-GPU regime the recursive planner targets: 16 pods of
//! 8 racks of 8 GPUs each (`even_tiered(1024, &[128, 16], ..)`), rack
//! uplinks 2x oversubscribed into the pod switch and pod uplinks 4x
//! oversubscribed into the core. A Zipf(1.2)-routed 1024-expert model is
//! planned tier-locally and its all-to-all scheduled recursively; both
//! steps are timed so the sub-second win condition is visible end to end.

use std::time::Instant;

use aurora::cluster::{uplink_bound, Cluster, Topology};
use aurora::eval::skewed_workload;
use aurora::planner::Planner;
use aurora::schedule::hierarchical_schedule;
use aurora::trace::ModelTrace;

fn main() {
    // 1. 1024 GPUs in 16 pods x 8 racks x 8 GPUs. Level 0 groups the GPUs
    //    into 128 racks (2x oversubscribed uplinks); level 1 groups the
    //    racks into 16 pods (4x oversubscribed into the core).
    let cluster = Cluster::homogeneous(1024, 814.0);
    let topo = Topology::even_tiered(1024, &[128, 16], &[2.0, 4.0])
        .expect("1024 GPUs tile into 128 racks and 16 pods");
    println!(
        "fabric: 1024 GPUs = 16 pods x 8 racks x 8 GPUs \
         (rack uplink {:.0} tokens/ms, pod uplink {:.0} tokens/ms)",
        topo.uplink_rates_at(&cluster, 0)[0],
        topo.uplink_rates_at(&cluster, 1)[0],
    );

    // 2. A 1024-expert model with Zipf(1.2) routing: one expert per GPU
    //    slot, heavy-tailed token counts, so cross-pod locality is the
    //    dominant term in the drain.
    let trace = skewed_workload(1024, 1, 4096, 1.2, 2026);
    let refs: Vec<&ModelTrace> = vec![&trace];
    let layer = &trace.layers[0];
    let planner = Planner::default();

    // 3. Plan twice: topology-blind vs tier-local refinement.
    let t0 = Instant::now();
    let blind = planner.plan_multi(&refs, &cluster).expect("plans");
    let blind_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let placed = planner.plan_topology(&refs, &cluster, &topo).expect("plans");
    let plan_s = t1.elapsed().as_secs_f64();
    let blind_agg = blind.aggregated_traffic(&[layer]);
    let placed_agg = placed.aggregated_traffic(&[layer]);
    println!(
        "cross-tier drain: blind {:.3} ms -> placed {:.3} ms  \
         (blind plan {:.2} s, tiered plan {:.2} s)",
        uplink_bound(&blind_agg, &cluster, &topo),
        uplink_bound(&placed_agg, &cluster, &topo),
        blind_s,
        plan_s,
    );

    // 4. Schedule the placed all-to-all recursively: per-rack Aurora
    //    phases, then a rack-level phase inside each pod, then a pod-level
    //    phase over the core.
    let t2 = Instant::now();
    let sched = hierarchical_schedule(&placed_agg, &cluster, &topo).expect("tiered fabric");
    let sched_s = t2.elapsed().as_secs_f64();
    println!(
        "recursive schedule: intra {:.3} ms | inter {:.3} ms | pipelined {:.3} ms  \
         (scheduled in {:.2} s)",
        sched.intra_ms, sched.inter_ms, sched.pipelined_ms, sched_s,
    );
    for (p, rounds) in sched.tiers.iter().enumerate() {
        println!("  phase {}: {} rounds over level-{} units", p + 1, rounds.len(), p);
    }
    println!(
        "plan_topology + hierarchical_schedule: {:.2} s total \
         (win condition: < 1 s each at 1024 GPUs)",
        plan_s + sched_s,
    );
}
