//! Large cluster: the incremental planning engine at 128 GPUs.
//!
//! ```bash
//! cargo run --release --example large_cluster
//! # or, with the parallel candidate sweep:
//! cargo run --release --features rayon --example large_cluster
//! ```
//!
//! Plans a 128-expert Zipf(1.2) workload on a 128-GPU, 8-group, 4x-
//! oversubscribed fabric, replicates the hot experts with the lazy-greedy
//! (CELF-style) loop, then replans for a drifted distribution (the hot
//! expert rotated) — printing wall-clock time for every step. This is the
//! regime the delta estimators exist for: the historical exhaustive greedy
//! re-ran full water-filling splits and per-GPU/uplink rescans for every
//! `(expert, gpu)` candidate and was minutes-slow at this scale.

use std::time::Instant;

use aurora::cluster::{Cluster, Topology};
use aurora::eval::skewed_workload;
use aurora::planner::{Planner, ReplicationConfig};
use aurora::trace::ModelTrace;
use aurora::traffic::drifting_zipf_traffic;

const N_GPUS: usize = 128;
const SEED: u64 = 2026;

fn main() {
    let cluster = Cluster::homogeneous(N_GPUS, 814.0);
    let topo = Topology::even_two_tier(N_GPUS, 8, 4.0).expect("128 GPUs tile into 8 groups");
    println!(
        "fabric: {N_GPUS} GPUs, 8 groups, 4x oversubscription (uplink {} tokens/ms)",
        topo.uplink_rates(&cluster)[0]
    );

    // One expert per GPU, Zipf(1.2) routing: a handful of hot experts carry
    // most of the batch, so replication is what buys the win.
    let trace = skewed_workload(N_GPUS, 2, 512, 1.2, SEED);
    let refs: Vec<&ModelTrace> = vec![&trace];
    let planner = Planner::default();
    let cfg = ReplicationConfig::default();

    let t0 = Instant::now();
    let placed = planner.plan_topology(&refs, &cluster, &topo).expect("plans");
    println!(
        "plan_topology:        {:>8.1} ms (max group size {})",
        t0.elapsed().as_secs_f64() * 1e3,
        placed.max_group_size()
    );

    let t1 = Instant::now();
    let (rep, splits) = planner
        .plan_replicated_topology(&refs, &cluster, &topo, &cfg)
        .expect("plans");
    println!(
        "plan_replicated:      {:>8.1} ms ({} added replicas)",
        t1.elapsed().as_secs_f64() * 1e3,
        rep.added_replicas()
    );
    let t_before = rep.total_inference_ms(&refs, &cluster, &splits);

    // The online regime: the hot expert rotates (phase 3 of the drifting
    // generator), and the coordinator wants a fresh plan on the live
    // estimate. Replan latency is what gates how often that is affordable.
    let mut drifted = trace.clone();
    for layer in &mut drifted.layers {
        layer.traffic = drifting_zipf_traffic(N_GPUS, 512, 1.2, SEED, 3);
    }
    let drifted_refs: Vec<&ModelTrace> = vec![&drifted];
    let t2 = Instant::now();
    let (rep2, splits2) = planner
        .plan_replicated_topology(&drifted_refs, &cluster, &topo, &cfg)
        .expect("plans");
    println!(
        "replan (drifted):     {:>8.1} ms ({} added replicas)",
        t2.elapsed().as_secs_f64() * 1e3,
        rep2.added_replicas()
    );

    // Sanity: the replicated plan beats the stale one on the drifted load.
    let stale = rep.total_inference_ms(&drifted_refs, &cluster, &splits);
    let fresh = rep2.total_inference_ms(&drifted_refs, &cluster, &splits2);
    println!(
        "simulated serving:    original load {t_before:.3} ms | drifted load stale {stale:.3} ms -> replanned {fresh:.3} ms ({:.2}x)",
        stale / fresh
    );
}
