//! Heterogeneous planning: Theorem 5.1's sorted GPU assignment in action.
//!
//! ```bash
//! cargo run --release --example plan_heterogeneous
//! ```
//!
//! Builds the paper's §8.1 mixed cluster (100/80/50/40 Gbps GPU types),
//! plans a LIMoE-like model onto it, and contrasts the planned assignment
//! with random GPU assignment (RGA) and the exhaustive optimum.

use aurora::assignment::{brute_force_assignment, random_assignment};
use aurora::config::EvalConfig;
use aurora::planner::Planner;
use aurora::schedule::SchedulePolicy;
use aurora::sim::simulate_exclusive;
use aurora::trace::{limoe_trace, Dataset, LimoeVariant};
use aurora::util::Rng;

fn main() {
    let cfg = EvalConfig::default();
    let cluster = cfg.heterogeneous_cluster();
    let trace = limoe_trace(LimoeVariant::B16, Dataset::Imagenet, 8, 4, 64, 7);

    println!("cluster: {} GPUs, bandwidths {:?} tokens/ms", cluster.len(), cluster.bandwidths());
    let loads = trace.total_expert_loads();
    println!("expert loads (tokens over 4 layers): {loads:?}");

    // Aurora's plan: heavy experts onto fast GPUs (Theorem 5.1).
    let plan = Planner::default().plan_exclusive(&trace, &cluster);
    println!("planned assignment (expert -> GPU): {:?}", plan.assignment_a);

    let eval = |perm: &[usize]| -> f64 {
        trace
            .layers
            .iter()
            .map(|l| {
                simulate_exclusive(&l.placed(perm), &cluster, SchedulePolicy::Aurora)
                    .0
                    .inference_ms
            })
            .sum()
    };

    let t_plan = eval(&plan.assignment_a);
    println!("\nplanned (Theorem 5.1): {t_plan:.4} ms over 4 layers");

    // RGA baseline: average of 20 random assignments.
    let mut rng = Rng::new(99);
    let rga: Vec<f64> = (0..20).map(|_| eval(&random_assignment(8, &mut rng))).collect();
    let rga_mean = rga.iter().sum::<f64>() / rga.len() as f64;
    println!("RGA (mean of 20):      {rga_mean:.4} ms  ({:.2}x slower)", rga_mean / t_plan);

    // Exhaustive optimum over all 8! assignments (feasible at this scale).
    let (t_opt, _) = brute_force_assignment(8, |perm| eval(perm));
    println!("exhaustive optimum:    {t_opt:.4} ms  (plan gap: {:.4}x)", t_plan / t_opt);
}
