//! Quickstart: plan and simulate one MoE model on a homogeneous cluster.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the Exclusive + Homogeneous scenario end to end: generate a
//! LIMoE-like trace, schedule the all-to-alls with Aurora / SJF / RCS, and
//! compare the per-layer inference times (Theorem 4.1/4.2).

use aurora::cluster::Cluster;
use aurora::schedule::{aurora_schedule, comm_time, validate_slot_schedule, SchedulePolicy};
use aurora::sim::simulate_exclusive;
use aurora::trace::{limoe_trace, Dataset, LimoeVariant};

fn main() {
    // 1. A LIMoE-B/16-like model: 8 experts, 4 MoE layers, 64 images/batch.
    let trace = limoe_trace(LimoeVariant::B16, Dataset::Coco, 8, 4, 64, 42);
    println!(
        "trace: {} ({} layers, {} experts)",
        trace.name,
        trace.layers.len(),
        trace.n_experts()
    );

    // 2. An 8-GPU homogeneous cluster, ~814 tokens/ms per port
    //    (100 Gbps line rate, f32 ViT-B tokens, 20% all-to-all efficiency).
    let cluster = Cluster::homogeneous(8, 814.0);

    // 3. Aurora's optimal transmission order for layer 1, validated against
    //    the Theorem 4.2 bound.
    let layer0 = &trace.layers[0];
    let schedule = aurora_schedule(&layer0.traffic);
    validate_slot_schedule(&layer0.traffic, &schedule).expect("schedule is optimal by theorem");
    println!(
        "layer 1 all-to-all: {} tokens at the bottleneck, {} contention-free rounds",
        schedule.makespan_tokens(),
        schedule.rounds.len()
    );

    // 4. Per-layer inference time under the three schedulers.
    println!(
        "\n{:<8} {:>12} {:>12} {:>12} {:>9}",
        "layer", "aurora (ms)", "sjf (ms)", "rcs (ms)", "speedup"
    );
    for (k, layer) in trace.layers.iter().enumerate() {
        let a = simulate_exclusive(layer, &cluster, SchedulePolicy::Aurora).0;
        let s = simulate_exclusive(layer, &cluster, SchedulePolicy::Sjf).0;
        let r = simulate_exclusive(layer, &cluster, SchedulePolicy::Rcs { seed: 1 }).0;
        println!(
            "{:<8} {:>12.4} {:>12.4} {:>12.4} {:>8.2}x",
            k + 1,
            a.inference_ms,
            s.inference_ms,
            r.inference_ms,
            s.inference_ms.min(r.inference_ms) / a.inference_ms
        );
    }

    // 5. The Theorem 4.2 bound is what Aurora achieves.
    let bw = cluster.bandwidths();
    let comm = comm_time(&layer0.traffic, &bw, SchedulePolicy::Aurora);
    println!(
        "\nTheorem 4.2: minimal comm time = b_max / B = {:.4} ms (achieved: {:.4} ms)",
        layer0.traffic.b_max_tokens() as f64 / bw[0],
        comm.makespan
    );
}
