//! Expert replication under Zipf-skewed routing.
//!
//! ```bash
//! cargo run --release --example replicate_skew
//! ```
//!
//! Demonstrates the replication subsystem end to end: generate a skewed
//! workload where one expert absorbs ~36% of the batch, plan it with and
//! without replication, inspect the water-filled token splits, and compare
//! the simulated completion times. At α = 0 (uniform routing) the replicated
//! planner returns the plain plan bit-for-bit.

use aurora::cluster::Cluster;
use aurora::eval::skewed_workload;
use aurora::planner::{Planner, ReplicationConfig};
use aurora::replication::estimate_per_gpu_replicated;
use aurora::serve::ReplicaRouter;

fn main() {
    // 1. A 16-expert model on 8 GPUs (two experts per GPU slot), routing
    //    1024 tokens per sender with Zipf(1.2) expert popularity.
    let trace = skewed_workload(16, 4, 1024, 1.2, 2024);
    let refs = [&trace];
    let cluster = Cluster::homogeneous(8, 814.0);
    let loads = trace.layers[0].expert_loads();
    let hot = (0..16).max_by_key(|&e| loads[e]).unwrap();
    let total: u64 = loads.iter().sum();
    println!(
        "workload: {} — hot expert {} takes {:.1}% of {} tokens/layer",
        trace.name,
        hot,
        100.0 * loads[hot] as f64 / total as f64,
        total
    );

    // 2. The best non-replicated plan: the hot expert still pins one GPU.
    let planner = Planner::default();
    let plain = planner.plan_multi(&refs, &cluster).expect("plans");
    let t_plain = plain.total_inference_ms(&refs, &cluster);

    // 3. The replicated plan: up to 4 copies per expert, splits chosen by
    //    water-filling.
    let (rep, splits) = planner
        .plan_replicated(&refs, &cluster, &ReplicationConfig::default())
        .expect("plans");
    let t_rep = rep.total_inference_ms(&refs, &cluster, &splits);
    println!(
        "\nreplication: {} added replica(s); hot expert now on GPUs {:?}",
        rep.added_replicas(),
        rep.replicas[0][hot]
    );
    let w: Vec<String> = splits.weights_for(0, hot).iter().map(|x| format!("{x:.2}")).collect();
    println!("hot expert split weights: [{}]", w.join(", "));

    // 4. Per-GPU completion estimates and end-to-end times.
    let totals = aurora::trace::aggregate_totals(&refs);
    let layer_refs: Vec<&aurora::sim::MoeLayerStats> = totals.iter().collect();
    let per_gpu = estimate_per_gpu_replicated(&rep, &layer_refs, &cluster, &splits);
    let bottleneck = per_gpu.iter().cloned().fold(0.0, f64::max);
    println!("replicated bottleneck estimate: {bottleneck:.3} ms");
    println!(
        "\nsimulated total: plain {t_plain:.3} ms, replicated {t_rep:.3} ms ({:.2}x faster)",
        t_plain / t_rep
    );

    // 5. Serving-side: the replica router apportions live batches by the
    //    same weights, amortizing rounding across batches.
    let mut router = ReplicaRouter::new(&rep, &splits);
    for _ in 0..10 {
        router.route_tokens(0, hot, 100);
    }
    println!(
        "after 10 batches of 100 tokens, hot expert replicas carry {:?}",
        router.routed_per_replica(0, hot)
    );

    // 6. Uniform routing (α = 0) falls back to the plain plan bit-for-bit.
    let uniform = skewed_workload(16, 4, 1024, 0.0, 2024);
    let uref = [&uniform];
    let (urep, _) = planner
        .plan_replicated(&uref, &cluster, &ReplicationConfig::default())
        .expect("plans");
    println!(
        "\nuniform routing: {} added replicas (plan == plan_multi: {})",
        urep.added_replicas(),
        urep.base == planner.plan_multi(&uref, &cluster).unwrap()
    );
}
