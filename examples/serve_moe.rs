//! END-TO-END serving driver: load the real AOT-compiled JAX/Pallas MoE
//! model via PJRT, serve batched requests through router + dynamic batcher +
//! engine, and report latency/throughput — proving all three layers compose
//! (L1 Pallas kernels → L2 JAX layer → HLO artifacts → L3 rust coordinator).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_moe
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use aurora::schedule::SchedulePolicy;
use aurora::serve::demo::run_serving_demo;

fn main() {
    let requests = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(128usize);
    if let Err(e) = run_serving_demo("artifacts", requests, 64, SchedulePolicy::Aurora) {
        eprintln!("serving demo failed: {e:#}");
        eprintln!("hint: run `make artifacts` first");
        std::process::exit(1);
    }
}
