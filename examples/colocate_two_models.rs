//! Cross-model expert colocation (§6): interleave two MoE models on one
//! cluster and watch utilization rise without hurting latency.
//!
//! ```bash
//! cargo run --release --example colocate_two_models
//! ```

use aurora::config::EvalConfig;
use aurora::eval::{lina_colocated_times, lina_utilization};
use aurora::planner::Planner;
use aurora::schedule::SchedulePolicy;
use aurora::sim::{simulate_colocated, simulate_exclusive};
use aurora::trace::{limoe_trace, Dataset, LimoeVariant};

fn main() {
    let cfg = EvalConfig::default();
    let cluster = cfg.homogeneous_cluster();
    // Equal-sized pair (same variant, two datasets) — the regime where
    // cross-model interleaving shines; see eval::workloads for the rationale.
    let a = limoe_trace(LimoeVariant::B16, Dataset::Coco, 8, 4, 64, 1);
    let b = limoe_trace(LimoeVariant::B16, Dataset::Imagenet, 8, 4, 64, 2);
    println!("colocating {} with {} on {} GPUs\n", a.name, b.name, cluster.len());

    // Aurora's colocation: Case II bottleneck matching on the traffic.
    let plan = Planner::default().plan_colocated(&a, &b, &cluster);
    let pairing = plan.pairing().unwrap();
    println!("expert pairing (a-expert i shares its GPU with b-expert pairing[i]):");
    println!("  {pairing:?}");

    // The same plan as a generalized Deployment — the placement core's view
    // (any model count, any experts-per-GPU) that serving and the group
    // simulator consume.
    let deployment = plan.to_deployment();
    println!(
        "as generalized deployment: {}",
        deployment.to_json().to_string_compact()
    );

    let pa = plan.place_a(&a);
    let pb = plan.place_b(&b);
    let (lina_a, lina_b) = lina_colocated_times(&a, &b, &cluster, SchedulePolicy::Aurora);
    let lina_util = lina_utilization(&a, &b, &cluster, SchedulePolicy::Aurora);

    println!(
        "\n{:<7} {:>14} {:>13} {:>13} {:>11} {:>10}",
        "layer", "aurora (ms)", "lina-a (ms)", "lina-b (ms)", "util", "lina util"
    );
    for k in 0..a.layers.len() {
        let (coloc, _) = simulate_colocated(&pa[k], &pb[k], &cluster, plan.policy);
        println!(
            "{:<7} {:>14.4} {:>13.4} {:>13.4} {:>10.1}% {:>9.1}%",
            k + 1,
            coloc.inference_ms,
            lina_a[k],
            lina_b[k],
            coloc.utilization * 100.0,
            lina_util[k] * 100.0
        );
    }

    // Utilization vs running each model alone (Fig. 12's comparison).
    let (excl_a, _) = simulate_exclusive(&a.layers[0], &cluster, SchedulePolicy::Aurora);
    let (coloc0, _) = simulate_colocated(&pa[0], &pb[0], &cluster, plan.policy);
    println!(
        "\nlayer-1 GPU utilization: exclusive {:.1}% -> colocated {:.1}% ({:.2}x)",
        excl_a.utilization * 100.0,
        coloc0.utilization * 100.0,
        coloc0.utilization / excl_a.utilization
    );
}
