//! Generalized placement: colocate N models with multiple experts per GPU.
//!
//! ```bash
//! cargo run --release --example colocate_many_models
//! ```
//!
//! Goes beyond the paper's two-model / one-expert-per-GPU analysis: three
//! LIMoE-like models with 16 experts each are packed onto 8 GPUs (6 experts
//! per GPU), planned by the generalized core (`Planner::plan_multi`), and
//! compared against random placement on both cluster kinds.

use aurora::config::EvalConfig;
use aurora::eval::{multi_workload, random_deployment};
use aurora::planner::Planner;
use aurora::trace::ModelTrace;
use aurora::util::Rng;

fn main() {
    let cfg = EvalConfig::default();
    let n_models = 3;
    let n_experts = 16;
    let traces = multi_workload(&cfg, n_models, n_experts);
    let refs: Vec<&ModelTrace> = traces.iter().collect();

    for (label, cluster) in [
        ("homogeneous", cfg.homogeneous_cluster()),
        ("heterogeneous", cfg.heterogeneous_cluster()),
    ] {
        let dep = Planner::default()
            .plan_multi(&refs, &cluster)
            .expect("plan_multi handles N >= 3");
        println!(
            "\n== {label}: {n_models} models x {n_experts} experts on {} GPUs ==",
            cluster.len()
        );
        println!(
            "scenario {}, experts per GPU {:?}",
            dep.scenario.name(),
            dep.experts_per_gpu()
        );

        let t_plan = dep.total_inference_ms(&refs, &cluster);
        println!("planned placement:  {t_plan:.4} ms over {} layers", cfg.n_layers);

        let mut rng = Rng::new(0xBEEF);
        let rand_mean = (0..20)
            .map(|_| {
                random_deployment(&refs, cluster.len(), dep.scenario, &mut rng)
                    .total_inference_ms(&refs, &cluster)
            })
            .sum::<f64>()
            / 20.0;
        println!(
            "random placement:   {rand_mean:.4} ms (mean of 20)  -> {:.2}x slower",
            rand_mean / t_plan
        );

        let sims = dep.simulate(&refs, &cluster);
        let util =
            sims.iter().map(|r| r.utilization).sum::<f64>() / sims.len() as f64 * 100.0;
        println!("mean GPU utilization with 3-way colocation: {util:.1}%");
    }
}
