//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate wraps libxla_extension; this build environment has neither
//! the shared library nor crates.io access, so this vendored crate mirrors
//! the API surface aurora's [`runtime`] layer uses. Client construction and
//! HLO-text loading succeed (so code paths and tests that only need the
//! plumbing stay green); anything that would actually *execute* an HLO
//! program returns a descriptive error. The artifact-backed integration
//! tests already skip when `make artifacts` has not run, which is always the
//! case wherever this stub is in use.

use std::fmt;

/// Stub error type.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: XLA runtime unavailable (offline stub build; link the real xla crate to execute artifacts)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Parsed HLO module (the stub retains the text only).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read an HLO-text file. Parsing is deferred to compile time in the
    /// real crate; the stub just checks the file is readable.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path).map_err(|e| Error(format!("{path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    #[allow(dead_code)]
    proto: HloModuleProto,
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            proto: HloModuleProto {
                text: proto.text.clone(),
            },
        }
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    /// CPU client. Succeeds in the stub so plumbing-only tests pass.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient {
            platform: "cpu-stub",
        })
    }

    /// Backend platform name.
    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    /// Compile a computation. The stub cannot lower HLO, so this errors.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compile"))
    }
}

/// A compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments. Unreachable in the stub (compile
    /// already fails), but present so callers typecheck.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute"))
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("to_literal_sync"))
    }
}

/// A host-side literal value.
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("to_tuple"))
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_and_platform() {
        let c = PjRtClient::cpu().unwrap();
        assert!(!c.platform_name().is_empty());
    }

    #[test]
    fn execution_paths_error_cleanly() {
        let exe = PjRtLoadedExecutable { _private: () };
        let lit = Literal::vec1(&[1.0, 2.0]).reshape(&[1, 2]).unwrap();
        let err = exe.execute::<Literal>(&[lit]).unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }
}
