//! Offline stub of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides the (small) subset of anyhow's API that aurora uses: the opaque
//! [`Error`] type, the [`Result`] alias, the [`Context`] extension trait for
//! `Result` and `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Context frames are flattened into one message chain joined with `": "`,
//! which matches how aurora renders errors (`{e}` / `{e:#}`).

use std::fmt;

/// Opaque error: a message chain, newest context first.
pub struct Error {
    msg: String,
}

impl Error {
    /// Wrap a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context frame.
    fn wrap<C: fmt::Display>(self, ctx: C) -> Error {
        Error {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like real anyhow: any std error converts into `Error` (and `Error` itself
// deliberately does NOT implement `std::error::Error`, which is what makes
// this blanket impl coherent).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E>: Sized {
    /// Attach a context message to the error/none case.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn context_chains_messages() {
        let e = io_err().context("reading meta.json").unwrap_err();
        let s = e.to_string();
        assert!(s.contains("reading meta.json"), "{s}");
        assert!(s.contains("gone"), "{s}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "k")).unwrap_err();
        assert_eq!(e.to_string(), "missing k");
    }

    #[test]
    fn macros_compose() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(5).unwrap_err().to_string().contains("five"));
    }
}
