//! `cargo bench --bench fig12` — regenerates Fig. 12 (GPU utilization) and
//! times the utilization accounting path.

use aurora::config::EvalConfig;
use aurora::eval::{fig12a, fig12b, lina_utilization, Workloads};
use aurora::schedule::SchedulePolicy;
use aurora::util::bench::Bench;

fn main() {
    let cfg = EvalConfig::default();
    let w = Workloads::generate(&cfg);

    for report in [fig12a(&cfg, &w), fig12b(&cfg, &w)] {
        println!("{}", report.render());
    }

    let homo = cfg.homogeneous_cluster();
    let mut b = Bench::new();
    Bench::header();
    b.run("lina merged-model utilization (4 layers)", || {
        lina_utilization(
            &w.b16_coco,
            &w.b16_imagenet,
            &homo,
            SchedulePolicy::Rcs { seed: 7 },
        )
    });
    b.run("fig12a full panel", || fig12a(&cfg, &w).rows.len());
}
