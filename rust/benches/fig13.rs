//! `cargo bench --bench fig13` — the optimality-gap experiment at reduced
//! scale (n = 6 pairings exhaustive; the full n = 8 run is
//! `aurora eval --figure 13`, ~15 s/instance) plus hot-path timings for the
//! matching machinery it leans on.

use aurora::config::EvalConfig;
use aurora::eval::{fig13, Workloads};
use aurora::matching::{bottleneck_matching, hungarian_min_sum};
use aurora::util::bench::Bench;
use aurora::util::Rng;

fn main() {
    // Reduced-scale figure (exhaustive search over 6! pairings).
    let cfg = EvalConfig {
        n_experts: 6,
        n_layers: 2,
        batch_images: 32,
        hetero_gbps: vec![100.0, 50.0],
        ..EvalConfig::default()
    };
    let w = Workloads::generate(&cfg);
    println!("{}", fig13(&cfg, &w).render());
    println!("(full n=8 run: `aurora eval --figure 13`)\n");

    // Matching hot paths at paper scale.
    let mut rng = Rng::new(0xF13);
    let n = 8;
    let weights: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..n).map(|_| rng.gen_f64() * 100.0).collect())
        .collect();
    let mut b = Bench::new();
    Bench::header();
    b.run("bottleneck_matching 8x8", || {
        bottleneck_matching(n, |i, j| weights[i][j]).0
    });
    b.run("hungarian_min_sum 8x8", || hungarian_min_sum(&weights).0);
    let big: Vec<Vec<f64>> = (0..64)
        .map(|_| (0..64).map(|_| rng.gen_f64()).collect())
        .collect();
    b.run("bottleneck_matching 64x64", || {
        bottleneck_matching(64, |i, j| big[i][j]).0
    });
}
