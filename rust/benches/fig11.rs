//! `cargo bench --bench fig11` — regenerates Fig. 11 (inference time across
//! the four scenarios) and times the planning/simulation hot paths behind it.
//!
//! The offline build has no criterion; `aurora::util::bench` provides the
//! warmup + median/mean/min harness.

use aurora::config::EvalConfig;
use aurora::eval::{fig11a, fig11b, fig11c, fig11d, Workloads};
use aurora::planner::Planner;
use aurora::schedule::{comm_time, SchedulePolicy};
use aurora::sim::{simulate_colocated, simulate_exclusive};
use aurora::util::bench::Bench;

fn main() {
    let cfg = EvalConfig::default();
    let w = Workloads::generate(&cfg);

    // --- regenerate the figure tables ---
    for report in [
        fig11a(&cfg, &w),
        fig11b(&cfg, &w),
        fig11c(&cfg, &w),
        fig11d(&cfg, &w),
    ] {
        println!("{}", report.render());
    }

    // --- time the hot paths the figure exercises ---
    let homo = cfg.homogeneous_cluster();
    let het = cfg.heterogeneous_cluster();
    let layer = &w.b16_coco.layers[0];
    let bw = homo.bandwidths();

    let mut b = Bench::new();
    Bench::header();
    b.run("comm_time/aurora (8x8)", || {
        comm_time(&layer.traffic, &bw, SchedulePolicy::Aurora).makespan
    });
    b.run("comm_time/sjf head-of-line sim (8x8)", || {
        comm_time(&layer.traffic, &bw, SchedulePolicy::Sjf).makespan
    });
    b.run("simulate_exclusive (8 GPUs)", || {
        simulate_exclusive(layer, &homo, SchedulePolicy::Aurora)
            .0
            .inference_ms
    });
    let planner = Planner::default();
    b.run("plan_exclusive hetero (Thm 5.1)", || {
        planner.plan_exclusive(&w.b16_coco, &het).assignment_a[0]
    });
    b.run("plan_colocated homo (Case II matching)", || {
        planner
            .plan_colocated(&w.b16_coco, &w.b16_imagenet, &homo)
            .assignment_a[0]
    });
    b.run("plan_colocated hetero (decoupled 3D)", || {
        planner
            .plan_colocated(&w.b16_coco, &w.b16_imagenet, &het)
            .assignment_a[0]
    });
    let plan = planner.plan_colocated(&w.b16_coco, &w.b16_imagenet, &homo);
    let pa = plan.place_a(&w.b16_coco);
    let pb = plan.place_b(&w.b16_imagenet);
    b.run("simulate_colocated (Table 2 timeline)", || {
        simulate_colocated(&pa[0], &pb[0], &homo, plan.policy)
            .0
            .inference_ms
    });
}
