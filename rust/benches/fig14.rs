//! `cargo bench --bench fig14` — regenerates Fig. 14 (robustness to traffic
//! imprecision) and times the noise-injection path.

use aurora::config::EvalConfig;
use aurora::eval::{fig14a, fig14b, Workloads};
use aurora::trace::noisy_traffic;
use aurora::util::bench::Bench;

fn main() {
    let cfg = EvalConfig::default();
    let w = Workloads::generate(&cfg);

    for report in [fig14a(&cfg, &w), fig14b(&cfg, &w)] {
        println!("{}", report.render());
    }

    let layers = &w.b16_coco.layers;
    let noise: Vec<&aurora::traffic::TrafficMatrix> =
        layers.iter().skip(1).map(|l| &l.traffic).collect();
    let mut b = Bench::new();
    Bench::header();
    b.run("noisy_traffic blend (8x8, 3 noise layers)", || {
        noisy_traffic(&layers[0].traffic, &noise, 0.5).total()
    });
    b.run("fig14a full panel", || fig14a(&cfg, &w).rows.len());
}
