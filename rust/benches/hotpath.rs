//! `cargo bench --bench hotpath` — L3 coordinator hot paths, tracked for the
//! §Perf targets in DESIGN.md:
//!
//! * plan for n = 64 GPUs in < 50 ms,
//! * schedule a 10k-token 8x8 matrix in < 100 ms (BvN decomposition),
//! * router overhead < 10 µs/request (excluding model execution),
//! * batcher push < 1 µs/request.
//!
//! Plus ablations: min-sum (Hungarian) vs bottleneck colocation on the
//! aggregated-b_max objective, and BvN schedule construction vs the analytic
//! bound.

use aurora::cluster::Cluster;
use aurora::colocation::{aggregated_b_max, case2_pairing};
use aurora::matching::hungarian_min_sum;
use aurora::planner::Planner;
use aurora::schedule::{aurora_schedule, comm_time, SchedulePolicy};
use aurora::serve::{BatcherConfig, DynamicBatcher, Request, Router};
use aurora::trace::{limoe_trace, Dataset, LimoeVariant};
use aurora::util::bench::Bench;
use aurora::util::Rng;

fn main() {
    let mut b = Bench::new();
    Bench::header();

    // --- scheduling ---
    let trace8 = limoe_trace(LimoeVariant::B16, Dataset::Coco, 8, 1, 64, 5);
    let d8 = &trace8.layers[0].traffic; // ~12.5k tokens
    b.run("bvn schedule 8x8 (~12.5k tokens)", || {
        aurora_schedule(d8).makespan_tokens()
    });
    b.run("analytic b_max 8x8", || d8.b_max_tokens());
    let trace64 = limoe_trace(LimoeVariant::B16, Dataset::Coco, 64, 1, 512, 6);
    let d64 = &trace64.layers[0].traffic;
    b.run("bvn schedule 64x64 (~100k tokens)", || {
        aurora_schedule(d64).makespan_tokens()
    });
    let bw64 = vec![800.0; 64];
    b.run("head-of-line sim 64x64 (sjf)", || {
        comm_time(d64, &bw64, SchedulePolicy::Sjf).makespan
    });

    // --- planning ---
    let planner = Planner::default();
    let cluster64 = Cluster::paper_heterogeneous(64, 800.0);
    let a64 = limoe_trace(LimoeVariant::B16, Dataset::Coco, 64, 4, 512, 7);
    let b64 = limoe_trace(LimoeVariant::B16, Dataset::Imagenet, 64, 4, 512, 8);
    b.run("plan_exclusive n=64 hetero", || {
        planner.plan_exclusive(&a64, &cluster64).assignment_a[0]
    });
    b.run("plan_colocated n=64 hetero (decoupled)", || {
        planner.plan_colocated(&a64, &b64, &cluster64).assignment_a[0]
    });

    // --- ablation: bottleneck vs min-sum colocation objective ---
    let da = &a64.layers[0].traffic;
    let db = &b64.layers[0].traffic;
    let (a_s, a_r) = aurora::colocation::send_recv_volumes(da);
    let (b_s, b_r) = aurora::colocation::send_recv_volumes(db);
    let (_, pi_bottleneck) = case2_pairing(da, db);
    let cost: Vec<Vec<f64>> = (0..64)
        .map(|i| {
            (0..64)
                .map(|j| ((a_s[i] + b_s[j]).max(a_r[i] + b_r[j])) as f64)
                .collect()
        })
        .collect();
    let (_, pi_minsum) = hungarian_min_sum(&cost);
    println!(
        "\nablation: aggregated b_max — bottleneck pairing {} vs min-sum pairing {} ({}x worse)\n",
        aggregated_b_max(da, db, &pi_bottleneck),
        aggregated_b_max(da, db, &pi_minsum),
        aggregated_b_max(da, db, &pi_minsum) as f64
            / aggregated_b_max(da, db, &pi_bottleneck) as f64
    );

    // --- serving-side hot paths ---
    let mut router = Router::new(4, aurora::serve::router::RoutePolicy::LeastLoaded);
    let mut rng = Rng::new(1);
    let reqs: Vec<Request> = (0..1024)
        .map(|id| Request::new(id, vec![0.1; (rng.gen_range(8) as usize + 1) * 64], 64))
        .collect();
    let mut i = 0;
    b.run("router.route (least-loaded, 4 workers)", || {
        let w = router.route(&reqs[i % reqs.len()]);
        router.complete(w, reqs[i % reqs.len()].n_tokens);
        i += 1;
        w
    });
    let mut batcher = DynamicBatcher::new(BatcherConfig::default());
    let now = std::time::Instant::now();
    let mut j = 0;
    b.run("batcher.push", || {
        let r = reqs[j % reqs.len()].clone();
        j += 1;
        if let Ok(Some(batch)) = batcher.push(r, now) {
            batch.requests.len()
        } else {
            0
        }
    });

    // --- §Perf target checks (hard numbers recorded in EXPERIMENTS.md) ---
    let samples = b.samples();
    let find = |name: &str| {
        samples
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("missing sample {name}"))
    };
    let plan64 = find("plan_colocated n=64 hetero (decoupled)");
    println!(
        "\nperf targets: plan n=64 {} (< 50 ms: {}), bvn 8x8 {} (< 100 ms: {}), route {} (< 10 us: {})",
        format_ms(plan64.median.as_secs_f64() * 1e3),
        plan64.median.as_millis() < 50,
        format_ms(find("bvn schedule 8x8 (~12.5k tokens)").median.as_secs_f64() * 1e3),
        find("bvn schedule 8x8 (~12.5k tokens)").median.as_millis() < 100,
        format_ms(find("router.route (least-loaded, 4 workers)").median.as_secs_f64() * 1e3),
        find("router.route (least-loaded, 4 workers)").median.as_micros() < 10,
    );
}

fn format_ms(ms: f64) -> String {
    if ms < 0.001 {
        format!("{:.1} ns", ms * 1e6)
    } else if ms < 1.0 {
        format!("{:.1} µs", ms * 1e3)
    } else {
        format!("{ms:.2} ms")
    }
}
