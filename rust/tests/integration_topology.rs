//! Integration: hierarchical two-phase scheduling + topology-aware placement.
//!
//! Three contracts anchor the subsystem:
//!
//! 1. **Rack-scale win** — on the canonical 16-GPU / 4-group / 4x-oversubscribed
//!    fabric serving a Zipf(1.2)-skewed 32-expert model, the hierarchical
//!    plan+schedule is ≥ 1.3x faster than topology-blind placement with the
//!    flat Aurora order priced honestly on the uplinks. Deterministic: fixed
//!    seeds, analytic schedules, no sampling.
//! 2. **Big-switch fallback** — `plan_topology` / `plan_replicated_topology`
//!    on `Topology::BigSwitch` are `plan_multi` / `plan_replicated`, bit for
//!    bit, and the topology-aware simulator collapses to the flat one.
//! 3. **Schedule validity** — the two-phase schedule conserves every
//!    (src, dst) token count and its uplink phase meets the group-level
//!    Theorem-4.2 budget exactly.

use aurora::cluster::{uplink_bound, Cluster, Topology};
use aurora::config::EvalConfig;
use aurora::eval::{run_figure, skewed_workload};
use aurora::planner::{Planner, ReplicationConfig};
use aurora::schedule::{
    comm_time_on, flat_aurora_on_topology, hierarchical_schedule, SchedulePolicy,
};
use aurora::trace::ModelTrace;

const N_GPUS: usize = 16;
const N_GROUPS: usize = 4;
const OVERSUB: f64 = 4.0;

fn rack() -> (Cluster, Topology, ModelTrace) {
    let cluster = Cluster::homogeneous(N_GPUS, 814.0);
    let topo = Topology::even_two_tier(N_GPUS, N_GROUPS, OVERSUB).unwrap();
    // 32 experts (two per GPU slot), Zipf(1.2) routing, fixed seed.
    let trace = skewed_workload(N_GPUS * 2, 4, 1024, 1.2, 2024);
    (cluster, topo, trace)
}

/// The acceptance pin: hierarchical plan+schedule ≥ 1.3x faster than flat
/// Aurora on the rack-scale Zipf workload.
#[test]
fn hierarchical_beats_flat_aurora_by_1_3x_at_rack_scale() {
    let (cluster, topo, trace) = rack();
    let refs: Vec<&ModelTrace> = vec![&trace];
    let planner = Planner::default();
    let layer = &trace.layers[0];

    // Topology-blind stack: plan_multi placement, flat Aurora rounds priced
    // with uplink contention.
    let flat_dep = planner.plan_multi(&refs, &cluster).unwrap();
    let flat_agg = flat_dep.aggregated_traffic(&[layer]);
    let flat_ms = flat_aurora_on_topology(&flat_agg, &cluster, &topo);

    // Hierarchical stack: topology-aware placement, two-phase schedule.
    let placed_dep = planner.plan_topology(&refs, &cluster, &topo).unwrap();
    let placed_agg = placed_dep.aggregated_traffic(&[layer]);
    let hier_ms = comm_time_on(&placed_agg, &cluster, &topo, SchedulePolicy::Aurora).makespan;

    assert!(hier_ms > 0.0 && flat_ms > 0.0);
    assert!(
        flat_ms >= hier_ms * 1.3,
        "hierarchical {hier_ms:.3} ms vs flat aurora {flat_ms:.3} ms \
         ({:.2}x < 1.3x)",
        flat_ms / hier_ms
    );

    // Determinism: the whole pipeline replays identically.
    let again = planner.plan_topology(&refs, &cluster, &topo).unwrap();
    assert_eq!(placed_dep, again);
    let hier_again =
        comm_time_on(&again.aggregated_traffic(&[layer]), &cluster, &topo, SchedulePolicy::Aurora)
            .makespan;
    assert_eq!(hier_ms, hier_again);
}

/// The hierarchical estimate never beats physics: it is at least the flat
/// port bound and at least the uplink drain bound.
#[test]
fn hierarchical_estimate_respects_lower_bounds() {
    let (cluster, topo, trace) = rack();
    let refs: Vec<&ModelTrace> = vec![&trace];
    let placed = Planner::default().plan_topology(&refs, &cluster, &topo).unwrap();
    let agg = placed.aggregated_traffic(&[&trace.layers[0]]);
    let hier = comm_time_on(&agg, &cluster, &topo, SchedulePolicy::Aurora).makespan;
    let port = agg.b_max_hetero(&cluster.bandwidths());
    let uplink = uplink_bound(&agg, &cluster, &topo);
    assert!(hier >= port - 1e-9, "hier {hier} vs port bound {port}");
    assert!(hier >= uplink - 1e-9, "hier {hier} vs uplink bound {uplink}");
}

/// Big-switch fallbacks are bit-for-bit, end to end.
#[test]
fn big_switch_fallback_is_bit_for_bit() {
    let (cluster, _, trace) = rack();
    let refs: Vec<&ModelTrace> = vec![&trace];
    let planner = Planner::default();

    let flat = planner.plan_multi(&refs, &cluster).unwrap();
    let topo = planner
        .plan_topology(&refs, &cluster, &Topology::BigSwitch)
        .unwrap();
    assert_eq!(flat, topo);

    let cfg = ReplicationConfig::default();
    let (rep_flat, splits_flat) = planner.plan_replicated(&refs, &cluster, &cfg).unwrap();
    let (rep_topo, splits_topo) = planner
        .plan_replicated_topology(&refs, &cluster, &Topology::BigSwitch, &cfg)
        .unwrap();
    assert_eq!(rep_flat, rep_topo);
    assert_eq!(splits_flat, splits_topo);

    // simulation collapses too
    let sims_flat = flat.simulate(&refs, &cluster);
    let sims_topo = topo.simulate_topology(&refs, &cluster, &Topology::BigSwitch);
    assert_eq!(sims_flat, sims_topo);
}

/// End-to-end simulated inference slows monotonically with oversubscription
/// for a fixed placement, and the topology-aware plan never loses materially
/// to the blind plan on the fabric it was placed for.
#[test]
fn simulated_inference_monotone_in_oversubscription() {
    let (cluster, _, trace) = rack();
    let refs: Vec<&ModelTrace> = vec![&trace];
    let planner = Planner::default();
    let blind = planner.plan_multi(&refs, &cluster).unwrap();
    let mut last = 0.0f64;
    for os in [1.0, 2.0, 4.0] {
        let topo = Topology::even_two_tier(N_GPUS, N_GROUPS, os).unwrap();
        let blind_total = blind.total_inference_ms_topology(&refs, &cluster, &topo);
        assert!(blind_total > 0.0);
        assert!(
            blind_total >= last - 1e-6,
            "os={os}: {blind_total} vs previous {last}"
        );
        last = blind_total;

        let placed = planner.plan_topology(&refs, &cluster, &topo).unwrap();
        let placed_total = placed.total_inference_ms_topology(&refs, &cluster, &topo);
        assert!(
            placed_total <= blind_total * 1.10 + 1e-6,
            "os={os}: placed {placed_total} vs blind {blind_total}"
        );
    }
}

/// Schedule validity on the rack shape: conservation per (src, dst) pair and
/// the group-level Theorem-4.2 budget.
#[test]
fn rack_scale_schedule_conserves_and_meets_the_budget() {
    let (cluster, topo, trace) = rack();
    let refs: Vec<&ModelTrace> = vec![&trace];
    let placed = Planner::default().plan_topology(&refs, &cluster, &topo).unwrap();
    let agg = placed.aggregated_traffic(&[&trace.layers[0]]);
    let sched = hierarchical_schedule(&agg, &cluster, &topo).unwrap();
    let delivered = sched.delivered();
    for i in 0..N_GPUS {
        for j in 0..N_GPUS {
            if i != j {
                assert_eq!(delivered.get(i, j), agg.get(i, j), "({i},{j})");
            }
        }
    }
    // uplink phase budget = b_max of the group-level matrix
    let owner = topo.group_of(N_GPUS).unwrap();
    let mut group = aurora::traffic::TrafficMatrix::zeros(N_GROUPS);
    for i in 0..N_GPUS {
        for j in 0..N_GPUS {
            if i != j && owner[i] != owner[j] {
                group.add(owner[i], owner[j], agg.get(i, j));
            }
        }
    }
    assert_eq!(sched.inter_budget_tokens(), group.b_max_tokens());
}

/// The `topology` eval figure runs and reports a hierarchical win at 4x.
#[test]
fn topology_figure_reports_the_win() {
    let cfg = EvalConfig {
        n_layers: 2,
        batch_images: 32,
        ..EvalConfig::default()
    };
    let reports = run_figure("topology", &cfg).unwrap();
    assert_eq!(reports.len(), 1);
    let speedups = reports[0].column("speedup").unwrap();
    assert_eq!(speedups.len(), 3);
    assert!(
        speedups[2] > 1.0,
        "no hierarchical win at 4x: {speedups:?}"
    );
}
