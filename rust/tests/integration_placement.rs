//! Integration: the generalized placement core.
//!
//! Two contracts anchor the refactor:
//!
//! 1. **Parity** — `plan_multi` + the generalized group simulator reproduce
//!    the seed two-model `DeploymentPlan` pipeline *bit-for-bit* on all four
//!    Fig. 2 scenarios (the old path used `MoeLayerStats::placed` +
//!    `simulate_exclusive`/`simulate_colocated` directly).
//! 2. **Generalization wins** — a 3-model / 16-experts-each deployment onto
//!    8 GPUs (6 experts per GPU) planned by the generalized core beats 20
//!    random placements on simulated inference time.

use aurora::cluster::Cluster;
use aurora::config::EvalConfig;
use aurora::eval::{multi_workload, random_deployment, run_figure};
use aurora::placement::{Deployment, PlacementError, Scenario};
use aurora::planner::Planner;
use aurora::schedule::SchedulePolicy;
use aurora::sim::{simulate_colocated, simulate_exclusive, simulate_group, SimResult};
use aurora::trace::{limoe_trace, Dataset, LimoeVariant, ModelTrace};
use aurora::util::Rng;

fn traces() -> (ModelTrace, ModelTrace) {
    (
        limoe_trace(LimoeVariant::B16, Dataset::Coco, 8, 4, 48, 31),
        limoe_trace(LimoeVariant::B16, Dataset::Imagenet, 8, 4, 48, 32),
    )
}

/// Parity on the two exclusive scenarios: the seed path (permute + Eqn. 3
/// closed form) and the generalized path (plan_multi → project → group sim)
/// must agree exactly, not approximately.
#[test]
fn parity_exclusive_scenarios_bit_for_bit() {
    let (a, _) = traces();
    for cluster in [
        Cluster::homogeneous(8, 10.0), // Fig. 2 leaf 1
        Cluster::paper_heterogeneous(8, 10.0), // Fig. 2 leaf 2
    ] {
        let planner = Planner::default();
        // seed path
        let plan = planner.plan_exclusive(&a, &cluster);
        let old: Vec<SimResult> = a
            .layers
            .iter()
            .map(|l| {
                simulate_exclusive(&l.placed(&plan.assignment_a), &cluster, plan.policy).0
            })
            .collect();
        // generalized path
        let dep = planner.plan_multi(&[&a], &cluster).unwrap();
        let new = dep.simulate(&[&a], &cluster);
        assert_eq!(old, new, "exclusive parity broke on {cluster:?}");
    }
}

/// Parity on the two colocated scenarios, same bit-for-bit contract.
#[test]
fn parity_colocated_scenarios_bit_for_bit() {
    let (a, b) = traces();
    for cluster in [
        Cluster::homogeneous(8, 10.0), // Fig. 2 leaf 3
        Cluster::paper_heterogeneous(8, 10.0), // Fig. 2 leaf 4
    ] {
        let planner = Planner::default();
        // seed path
        let plan = planner.plan_colocated(&a, &b, &cluster);
        let pb = plan.assignment_b.clone().unwrap();
        let old: Vec<SimResult> = a
            .layers
            .iter()
            .zip(&b.layers)
            .map(|(la, lb)| {
                simulate_colocated(
                    &la.placed(&plan.assignment_a),
                    &lb.placed(&pb),
                    &cluster,
                    plan.policy,
                )
                .0
            })
            .collect();
        // generalized path
        let dep = planner.plan_multi(&[&a, &b], &cluster).unwrap();
        assert_eq!(dep.assignments[0], plan.assignment_a);
        assert_eq!(dep.assignments[1], pb);
        let new = dep.simulate(&[&a, &b], &cluster);
        assert_eq!(old, new, "colocated parity broke on {cluster:?}");
    }
}

/// The DeploymentPlan wrapper itself routes through the generalized
/// projection and stays bit-identical to the seed's permute-based placement.
#[test]
fn wrapper_projection_matches_permutation_exactly() {
    let (a, b) = traces();
    let cluster = Cluster::paper_heterogeneous(8, 10.0);
    let plan = Planner::default().plan_colocated(&a, &b, &cluster);
    let pb = plan.assignment_b.clone().unwrap();
    for (placed, layer) in plan.place_a(&a).iter().zip(&a.layers) {
        assert_eq!(placed.traffic, layer.traffic.permute(&plan.assignment_a));
    }
    for (placed, layer) in plan.place_b(&b).iter().zip(&b.layers) {
        assert_eq!(placed.traffic, layer.traffic.permute(&pb));
    }
}

/// Acceptance: 3 models x 16 experts on 8 GPUs (6 experts per GPU), planned
/// end to end, beats 20 random placements on total simulated inference time.
#[test]
fn three_models_sixteen_experts_beat_twenty_random_placements() {
    let cfg = EvalConfig {
        n_layers: 2,
        batch_images: 32,
        ..EvalConfig::default()
    };
    let traces = multi_workload(&cfg, 3, 16);
    let refs: Vec<&ModelTrace> = traces.iter().collect();
    // paper-scale bandwidth (~100 Gbps -> ~800 tokens/ms): compute and comm
    // are comparable, the regime the placement heuristic targets
    for cluster in [
        Cluster::homogeneous(8, 800.0),
        Cluster::paper_heterogeneous(8, 800.0),
    ] {
        let dep = Planner::default().plan_multi(&refs, &cluster).unwrap();
        assert_eq!(dep.scenario, Scenario::MultiColocated);
        assert_eq!(dep.n_models(), 3);
        assert_eq!(dep.experts_per_gpu().iter().sum::<usize>(), 48);
        let t_plan = dep.total_inference_ms(&refs, &cluster);
        assert!(t_plan > 0.0);

        let mut rng = Rng::new(0xACCE97);
        for trial in 0..20 {
            let r = random_deployment(&refs, cluster.len(), dep.scenario, &mut rng);
            let t_rand = r.total_inference_ms(&refs, &cluster);
            assert!(
                t_plan <= t_rand + 1e-9,
                "trial {trial}: planned {t_plan} lost to random {t_rand}"
            );
        }
    }
}

/// Experts-per-GPU packing with a single model: 2x the cluster's experts,
/// exclusive scenario, still planned and simulated through the same core.
#[test]
fn single_model_multi_expert_packing() {
    let a = limoe_trace(LimoeVariant::B16, Dataset::Coco, 16, 3, 32, 77);
    let cluster = Cluster::paper_heterogeneous(8, 20.0);
    let dep = Planner::default().plan_multi(&[&a], &cluster).unwrap();
    assert_eq!(dep.scenario, Scenario::ExclusiveHeterogeneous);
    assert_eq!(dep.n_experts(0), 16);
    assert_eq!(dep.experts_per_gpu().iter().sum::<usize>(), 16);
    // token load is conserved through projection
    let proj = dep.project_layer(0, &a.layers[0]);
    assert_eq!(
        proj.expert_loads().iter().sum::<u64>(),
        a.layers[0].expert_loads().iter().sum::<u64>()
    );
    let sims = dep.simulate(&[&a], &cluster);
    assert_eq!(sims.len(), 3);
    for r in &sims {
        assert!(r.inference_ms > 0.0 && r.utilization > 0.0 && r.utilization <= 1.0);
    }
}

/// The group simulator refuses shape mismatches and the deployment validator
/// reports structured errors.
#[test]
fn validation_and_error_paths() {
    assert_eq!(
        Scenario::detect(0, &Cluster::homogeneous(4, 1.0)),
        Err(PlacementError::NoModels)
    );
    let err = Deployment::new(
        4,
        vec![vec![0, 1, 2, 9]],
        SchedulePolicy::Aurora,
        Scenario::ExclusiveHomogeneous,
    )
    .unwrap_err();
    assert!(matches!(
        err,
        PlacementError::GpuOutOfRange { gpu: 9, n_gpus: 4, .. }
    ));

    let empty = Planner::default().plan_multi(&[], &Cluster::homogeneous(4, 1.0));
    assert_eq!(empty.unwrap_err(), PlacementError::NoModels);
}

/// `Scenario::detect` decision-tree coverage beyond the happy paths: N ≥ 3
/// always lands on the generalized leaf (both cluster kinds), the error
/// variant renders a usable message, and mismatched cluster sizes surface as
/// structured `GpuOutOfRange` errors rather than index panics.
#[test]
fn scenario_detect_and_cluster_size_mismatches() {
    for cluster in [
        Cluster::homogeneous(8, 1.0),
        Cluster::paper_heterogeneous(8, 1.0),
        Cluster::homogeneous(2, 1.0),
    ] {
        for n in 3..6 {
            assert_eq!(Scenario::detect(n, &cluster), Ok(Scenario::MultiColocated));
        }
        let err = Scenario::detect(0, &cluster).unwrap_err();
        assert_eq!(err, PlacementError::NoModels);
        assert!(err.to_string().contains("at least one model"));
    }

    // A deployment built for one cluster size rejects a smaller cluster:
    // every out-of-range expert is reported with its coordinates.
    let err = Deployment::new(
        2,
        vec![vec![0, 1], vec![1, 2]],
        SchedulePolicy::Aurora,
        Scenario::ColocatedHomogeneous,
    )
    .unwrap_err();
    match err {
        PlacementError::GpuOutOfRange { model, expert, gpu, n_gpus } => {
            assert_eq!((model, expert, gpu, n_gpus), (1, 1, 2, 2));
        }
        other => panic!("expected GpuOutOfRange, got {other:?}"),
    }

    // MultiColocated deployments validate like any other scenario — the
    // leaf is a planned path, not a crash.
    let ok = Deployment::new(
        2,
        vec![vec![0, 1], vec![1, 0], vec![0, 0]],
        SchedulePolicy::Aurora,
        Scenario::MultiColocated,
    );
    assert!(ok.is_ok());
}

/// Aggregation before scheduling: the group simulator's shared-phase floor
/// equals the comm time of the summed projected matrices (Theorem 6.1
/// generalized), which a hand aggregation reproduces.
#[test]
fn group_sim_uses_aggregated_traffic() {
    let a = limoe_trace(LimoeVariant::B16, Dataset::Coco, 6, 1, 24, 5);
    let b = limoe_trace(LimoeVariant::B32, Dataset::Imagenet, 6, 1, 24, 6);
    let c = limoe_trace(LimoeVariant::B32, Dataset::Coco, 6, 1, 24, 7);
    let cluster = Cluster::homogeneous(6, 1.0);
    let dep = Deployment::new(
        6,
        vec![
            (0..6).collect(),
            (0..6).rev().collect(),
            (0..6).map(|i| (i + 2) % 6).collect(),
        ],
        SchedulePolicy::Aurora,
        Scenario::MultiColocated,
    )
    .unwrap();
    let layers = [&a.layers[0], &b.layers[0], &c.layers[0]];
    let projected: Vec<_> = (0..3).map(|m| dep.project_layer(m, layers[m])).collect();
    let refs: Vec<&_> = projected.iter().collect();
    let (_, breakdown) = simulate_group(&refs, &cluster, SchedulePolicy::Aurora);
    let agg = dep.aggregated_traffic(&layers);
    // homogeneous bandwidth 1.0 token/ms: aggregated makespan == b_max tokens
    assert_eq!(breakdown.agg_comm1_ms, agg.b_max_tokens() as f64);
    assert_eq!(
        breakdown.agg_comm2_ms,
        agg.transpose().b_max_tokens() as f64
    );
}

/// The multi-model eval figure is wired into the harness and well-formed.
#[test]
fn multi_figure_runs_and_wins() {
    let cfg = EvalConfig {
        n_layers: 2,
        batch_images: 16,
        baseline_samples: 3,
        ..EvalConfig::default()
    };
    let reports = run_figure("multi", &cfg).unwrap();
    assert_eq!(reports.len(), 1);
    let r = &reports[0];
    assert_eq!(r.rows.len(), 2);
    for (label, vals) in &r.rows {
        assert!(vals.iter().all(|v| v.is_finite() && *v >= 0.0), "{label}");
    }
    assert!(!r.notes.is_empty());
}
