//! Integration: the expert replication subsystem.
//!
//! Two contracts anchor the new subsystem (the PR's acceptance criteria):
//!
//! 1. **Skew win** — under Zipf(1.2)-skewed routing (8 GPUs, 16 experts),
//!    the replicated plan's simulated completion time beats the best
//!    non-replicated plan by ≥ 1.2×, deterministically.
//! 2. **Uniform fallback** — at α = 0 the replicated planner returns the
//!    plain `plan_multi` deployment *bit-for-bit* (no replicas, identical
//!    assignments, identical simulated times).
//!
//! Plus end-to-end checks that split matrices stay schedulable and that the
//! serving-side split converges to the planned weights.

use aurora::cluster::Cluster;
use aurora::config::EvalConfig;
use aurora::eval::{random_deployment, run_figure, skewed_workload};
use aurora::planner::{Planner, ReplicationConfig};
use aurora::replication::{optimize_splits, ReplicatedDeployment, SplitPlan};
use aurora::schedule::{aurora_schedule, validate_slot_schedule};
use aurora::serve::ReplicaRouter;
use aurora::sim::MoeLayerStats;
use aurora::trace::ModelTrace;
use aurora::util::Rng;

const N_GPUS: usize = 8;
const N_EXPERTS: usize = 16;
const TOKENS_PER_SENDER: u64 = 1024;
const SEED: u64 = 2024;

fn workload(alpha: f64) -> ModelTrace {
    skewed_workload(N_EXPERTS, 4, TOKENS_PER_SENDER, alpha, SEED)
}

fn cluster() -> Cluster {
    Cluster::homogeneous(N_GPUS, 814.0)
}

/// Acceptance: replicated vs best non-replicated ≥ 1.2× at α = 1.2,
/// deterministic (fixed seeds, no sampling anywhere in the pipeline).
#[test]
fn replicated_plan_beats_plain_by_1_2x_under_skew() {
    let trace = workload(1.2);
    let refs = [&trace];
    let cluster = cluster();
    let planner = Planner::default();

    let plain = planner.plan_multi(&refs, &cluster).unwrap();
    let t_plain = plain.total_inference_ms(&refs, &cluster);

    let (rep, splits) = planner
        .plan_replicated(&refs, &cluster, &ReplicationConfig::default())
        .unwrap();
    assert!(rep.is_replicated(), "skewed plan must add replicas");
    // the returned split plan is exactly what plan_splits reproduces
    assert_eq!(splits, rep.plan_splits(&refs, &cluster));
    let t_rep = rep.total_inference_ms(&refs, &cluster, &splits);

    let speedup = t_plain / t_rep;
    assert!(
        speedup >= 1.2,
        "replication speedup {speedup:.3} (plain {t_plain:.3} ms, replicated {t_rep:.3} ms)"
    );

    // determinism: the whole pipeline reproduces bit-for-bit
    let (rep2, splits2) = planner
        .plan_replicated(&refs, &cluster, &ReplicationConfig::default())
        .unwrap();
    assert_eq!(rep, rep2);
    assert_eq!(splits, splits2);
}

/// Acceptance: uniform routing falls back to the plain plan bit-for-bit.
#[test]
fn uniform_routing_is_bit_for_bit_unreplicated() {
    let trace = workload(0.0);
    let refs = [&trace];
    let cluster = cluster();
    let planner = Planner::default();

    let (rep, splits) = planner
        .plan_replicated(&refs, &cluster, &ReplicationConfig::default())
        .unwrap();
    let plain = planner.plan_multi(&refs, &cluster).unwrap();
    assert!(!rep.is_replicated());
    assert_eq!(rep.base, plain);
    assert_eq!(rep, ReplicatedDeployment::from_deployment(plain.clone()));

    // the simulated path is the same computation, to the last bit
    assert_eq!(splits, SplitPlan::trivial(&rep));
    let per_layer_rep = rep.simulate(&refs, &cluster, &splits);
    let per_layer_plain = plain.simulate(&refs, &cluster);
    assert_eq!(per_layer_rep, per_layer_plain);
}

/// Replication also beats random placement under skew (sanity floor), and
/// intermediate skew sits between the two regimes.
#[test]
fn skew_sweep_is_monotone_and_beats_random() {
    let cluster = cluster();
    let planner = Planner::default();
    let mut speedups = Vec::new();
    for alpha in [0.0, 0.6, 1.2] {
        let trace = workload(alpha);
        let refs = [&trace];
        let plain = planner.plan_multi(&refs, &cluster).unwrap();
        let (rep, splits) = planner
            .plan_replicated(&refs, &cluster, &ReplicationConfig::default())
            .unwrap();
        let t_rep = rep.total_inference_ms(&refs, &cluster, &splits);
        speedups.push(plain.total_inference_ms(&refs, &cluster) / t_rep);

        let mut rng = Rng::new(0xC0FFEE);
        for _ in 0..5 {
            let rand = random_deployment(&refs, cluster.len(), plain.scenario, &mut rng);
            let t_rand = rand.total_inference_ms(&refs, &cluster);
            assert!(
                t_rep <= t_rand + 1e-9,
                "alpha {alpha}: replicated {t_rep} lost to random {t_rand}"
            );
        }
    }
    assert!(speedups[2] >= speedups[0], "{speedups:?}");
    assert!((speedups[0] - 1.0).abs() < 1e-12, "{speedups:?}");
}

/// The schedule layer accepts replica-split matrices end to end: project the
/// replicated plan's layers, schedule each model's split matrix and the
/// aggregate, and machine-check every schedule.
#[test]
fn replicated_split_matrices_schedule_and_validate() {
    let trace = workload(1.2);
    let refs = [&trace];
    let cluster = cluster();
    let (rep, splits) = Planner::default()
        .plan_replicated(&refs, &cluster, &ReplicationConfig::default())
        .unwrap();
    for (k, layer) in trace.layers.iter().enumerate() {
        let proj = rep.project_layer_split(0, layer, &splits);
        // conservation through the split projection
        assert_eq!(
            proj.traffic.expert_loads().iter().sum::<u64>(),
            layer.traffic.expert_loads().iter().sum::<u64>(),
            "layer {k}"
        );
        let s = aurora_schedule(&proj.traffic);
        validate_slot_schedule(&proj.traffic, &s)
            .unwrap_or_else(|e| panic!("layer {k}: {e}"));
        // the reverse collective is schedulable too
        let rev = aurora_schedule(&proj.traffic.transpose());
        validate_slot_schedule(&proj.traffic.transpose(), &rev)
            .unwrap_or_else(|e| panic!("layer {k} reverse: {e}"));
    }
}

/// Serving-side split: the replica router's cumulative distribution
/// converges to the optimizer's weights.
#[test]
fn replica_router_converges_to_planned_split() {
    let trace = workload(1.2);
    let refs = [&trace];
    let cluster = cluster();
    let (rep, splits) = Planner::default()
        .plan_replicated(&refs, &cluster, &ReplicationConfig::default())
        .unwrap();
    let totals: Vec<u64> = {
        let layers: Vec<&MoeLayerStats> = trace.layers.iter().collect();
        let mut t = vec![0u64; N_EXPERTS];
        for l in &layers {
            for (e, v) in l.expert_loads().into_iter().enumerate() {
                t[e] += v;
            }
        }
        t
    };
    let hot = (0..N_EXPERTS).max_by_key(|&e| totals[e]).unwrap();
    assert!(rep.replica_count(0, hot) > 1, "hot expert must be replicated");

    let mut router = ReplicaRouter::new(&rep, &splits);
    for _ in 0..200 {
        router.route_tokens(0, hot, 37);
    }
    let routed = router.routed_per_replica(0, hot);
    let total: u64 = routed.iter().sum();
    assert_eq!(total, 200 * 37);
    for (r, &w) in splits.weights_for(0, hot).iter().enumerate() {
        let frac = routed[r] as f64 / total as f64;
        assert!(
            (frac - w).abs() < 0.01,
            "replica {r}: routed fraction {frac:.3} vs planned {w:.3}"
        );
    }
}

/// The `replication` eval figure runs end to end and reports the fallback
/// row exactly at 1.0x.
#[test]
fn replication_figure_runs() {
    let cfg = EvalConfig {
        n_layers: 2,
        baseline_samples: 2,
        ..EvalConfig::default()
    };
    let reports = run_figure("replication", &cfg).unwrap();
    assert_eq!(reports.len(), 1);
    let r = &reports[0];
    assert_eq!(r.rows.len(), 3);
    let vs_placed = r.column("vs placed").unwrap();
    assert!((vs_placed[0] - 1.0).abs() < 1e-12, "{vs_placed:?}");
    assert!(vs_placed[2] >= 1.2, "{vs_placed:?}");
}

/// Split-aware estimates agree with the placement-core estimator whenever
/// nothing is replicated — the structural guarantee behind the fallback.
#[test]
fn trivial_split_estimates_match_placement_core() {
    let trace = workload(0.7);
    let refs = [&trace];
    let cluster = cluster();
    let plain = Planner::default().plan_multi(&refs, &cluster).unwrap();
    let rep = ReplicatedDeployment::from_deployment(plain.clone());
    let totals = aurora::trace::aggregate_totals(&refs);
    let layers: Vec<&MoeLayerStats> = totals.iter().collect();
    let plan = optimize_splits(&rep, &layers, &cluster);
    assert_eq!(plan, SplitPlan::trivial(&rep));
    let a = aurora::replication::estimate_per_gpu_replicated(&rep, &layers, &cluster, &plan);
    let b = aurora::placement::estimate_per_gpu(&plain, &layers, &cluster);
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-12, "{a:?} vs {b:?}");
    }
}
