//! Integration: the PJRT runtime loads the AOT artifacts and produces
//! numerics consistent with the JAX/Pallas build path.
//!
//! These tests need `make artifacts`; they skip (pass trivially with a
//! notice) when the artifacts directory is absent so `cargo test` stays
//! green on a fresh checkout.

use aurora::runtime::{MoeModel, PjrtRuntime};
use aurora::schedule::SchedulePolicy;
use aurora::serve::{expert_execution_order, MoeEngine};
use aurora::util::Rng;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("meta.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn random_tokens(n: usize, d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * d).map(|_| rng.gen_f64() as f32 - 0.5).collect()
}

#[test]
fn gate_routes_to_multiple_experts() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let model = MoeModel::load(&rt, dir).unwrap();
    let cap = model.meta.capacity;
    let d = model.meta.d_model;
    let tokens = random_tokens(cap, d, 3);
    let (idx, weight) = model.run_gate(&tokens, cap).unwrap();
    let hist = model.expert_histogram(&idx);
    let used = hist.iter().filter(|&&c| c > 0).count();
    assert!(
        used >= 3,
        "expected varied routing, got histogram {hist:?}"
    );
    let n_experts = model.meta.n_experts as f32;
    for &w in &weight {
        assert!(w >= 1.0 / n_experts - 1e-5 && w <= 1.0 + 1e-5, "weight {w}");
    }
}

#[test]
fn split_dispatch_matches_fused_layer() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let model = MoeModel::load(&rt, dir).unwrap();
    let d = model.meta.d_model;
    for (n_tokens, seed) in [(1usize, 1u64), (8, 2), (64, 3)] {
        let tokens = random_tokens(n_tokens, d, seed);
        let order: Vec<usize> = (0..model.meta.n_experts).collect();
        let split = model.forward_layer(&tokens, n_tokens, &order).unwrap();
        let fused = model.forward_fused(&tokens, n_tokens).unwrap();
        let max_diff = split
            .iter()
            .zip(&fused)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-4, "n_tokens={n_tokens}: diff {max_diff}");
        // outputs must not be trivially zero (the layer actually computed)
        let norm: f32 = fused.iter().map(|v| v * v).sum();
        assert!(norm > 1e-6, "output is suspiciously zero");
    }
}

#[test]
fn dispatch_order_does_not_change_numerics() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let model = MoeModel::load(&rt, dir).unwrap();
    let d = model.meta.d_model;
    let tokens = random_tokens(32, d, 11);
    let fwd: Vec<usize> = (0..model.meta.n_experts).collect();
    let rev: Vec<usize> = (0..model.meta.n_experts).rev().collect();
    let a = model.forward_layer(&tokens, 32, &fwd).unwrap();
    let b = model.forward_layer(&tokens, 32, &rev).unwrap();
    assert_eq!(a, b, "expert visit order must be numerics-neutral");
}

#[test]
fn engine_accumulates_statistics_and_reorders() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let model = MoeModel::load(&rt, dir).unwrap();
    let d = model.meta.d_model;
    let mut engine = MoeEngine::new(model, SchedulePolicy::Aurora);
    let batch = aurora::serve::Batch {
        requests: vec![aurora::serve::Request::new(
            0,
            random_tokens(16, d, 21),
            d,
        )],
        total_tokens: 16,
        oldest_arrival: std::time::Instant::now(),
    };
    let responses = engine.run_batch(&batch).unwrap();
    assert_eq!(responses.len(), 1);
    assert_eq!(responses[0].output.len(), 16 * d);
    assert_eq!(engine.expert_stats.iter().sum::<u64>(), 16);
    // order puts the heaviest observed expert first
    let heaviest = (0..engine.expert_stats.len())
        .max_by_key(|&e| engine.expert_stats[e])
        .unwrap();
    assert_eq!(engine.expert_order[0], heaviest);
    let _ = expert_execution_order(&engine.expert_stats, SchedulePolicy::Sjf);
}
