//! Integration: the figure harness end to end at reduced scale — every
//! figure runs, produces well-formed reports, and reproduces the paper's
//! *shape* (who wins).

use aurora::config::EvalConfig;
use aurora::eval::run_figure;

fn small_cfg() -> EvalConfig {
    EvalConfig {
        batch_images: 16,
        baseline_samples: 3,
        ..EvalConfig::default()
    }
}

#[test]
fn all_fast_figures_run_and_are_well_formed() {
    let cfg = small_cfg();
    for fig in [
        "11a",
        "11b",
        "11c",
        "11d",
        "12",
        "14",
        "a1",
        "a2",
        "multi",
        "replication",
        "topology",
    ] {
        let reports = run_figure(fig, &cfg).unwrap();
        assert!(!reports.is_empty(), "{fig}: no reports");
        for r in &reports {
            assert!(!r.rows.is_empty(), "{fig}: empty table");
            for (label, values) in &r.rows {
                assert_eq!(values.len(), r.columns.len(), "{fig}/{label}");
                for &v in values {
                    assert!(v.is_finite() && v >= 0.0, "{fig}/{label}: bad value {v}");
                }
            }
            // every report carries a paper-comparison note
            assert!(!r.notes.is_empty(), "{fig}: missing summary note");
        }
    }
}

#[test]
fn fig13_runs_at_reduced_scale() {
    let cfg = EvalConfig {
        n_experts: 4,
        n_layers: 1,
        batch_images: 8,
        ..EvalConfig::default()
    };
    let reports = run_figure("13", &cfg).unwrap();
    for ratio in reports[0].column("ratio").unwrap() {
        assert!((1.0 - 1e-9..2.0).contains(&ratio), "ratio {ratio}");
    }
}

#[test]
fn unknown_figure_is_an_error() {
    assert!(run_figure("99", &small_cfg()).is_err());
}

#[test]
fn reports_serialize_to_json() {
    let cfg = small_cfg();
    let reports = run_figure("11a", &cfg).unwrap();
    let j = reports[0].to_json();
    let text = j.to_string_compact();
    let back = aurora::util::Json::parse(&text).unwrap();
    assert!(back.get("rows").unwrap().as_arr().unwrap().len() >= 4);
}

/// The headline shape of the paper: Aurora wins every scenario.
#[test]
fn aurora_wins_every_scenario_at_reduced_scale() {
    let cfg = small_cfg();
    let r11a = &run_figure("11a", &cfg).unwrap()[0];
    for v in r11a.column("sjf/aurora").unwrap() {
        assert!(v >= 1.0 - 1e-9);
    }
    let r11b = &run_figure("11b", &cfg).unwrap()[0];
    for v in r11b.column("rga/aurora").unwrap() {
        assert!(v >= 1.0 - 1e-9);
    }
    let r11c = &run_figure("11c", &cfg).unwrap()[0];
    for v in r11c.column("rec/aurora").unwrap() {
        assert!(v >= 1.0 - 1e-9, "rec/aurora {v}");
    }
    let r11d = &run_figure("11d", &cfg).unwrap()[0];
    for v in r11d.column("rga+rec/aurora").unwrap() {
        assert!(v >= 1.0 - 1e-9, "rga+rec/aurora {v}");
    }
}
