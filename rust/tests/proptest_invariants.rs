//! Seeded randomized property checks over the DESIGN.md invariants.
//!
//! The offline build has no `proptest`, so this file implements the same
//! discipline by hand: a deterministic generator ([`aurora::util::Rng`])
//! drives many random instances per property; every failure prints the seed
//! so the case replays exactly.

use aurora::assignment::{brute_force_assignment, sorted_assignment};
use aurora::cluster::{Cluster, GpuSpec};
use aurora::colocation::hetero::{brute_force_exact, decoupled_solution};
use aurora::colocation::{case1_pairing, case2_pairing, send_recv_volumes};
use aurora::matching::{bottleneck_matching, exhaustive_bottleneck, hungarian_min_sum};
use aurora::schedule::{
    aurora_schedule, comm_time, simulate_priority_order, validate_slot_schedule, SchedulePolicy,
};
use aurora::sim::{simulate_colocated, simulate_exclusive, MoeLayerStats};
use aurora::traffic::TrafficMatrix;
use aurora::util::Rng;

/// Random traffic matrix with off-diagonal entries in `[0, hi)`.
fn rand_matrix(rng: &mut Rng, n: usize, hi: u64) -> TrafficMatrix {
    let mut d = TrafficMatrix::zeros(n);
    for i in 0..n {
        for j in 0..n {
            if i != j {
                d.set(i, j, rng.gen_range(hi));
            }
        }
    }
    d
}

/// Random traffic matrix with controllable density: each off-diagonal cell
/// is nonzero (in `[1, hi)`) with probability `density` — sparse enough to
/// exercise the CSR representation's empty rows and columns.
fn rand_sparse_matrix(rng: &mut Rng, n: usize, hi: u64, density: f64) -> TrafficMatrix {
    let mut d = TrafficMatrix::zeros(n);
    for i in 0..n {
        for j in 0..n {
            if i != j && rng.gen_f64() < density {
                d.set(i, j, 1 + rng.gen_range(hi - 1));
            }
        }
    }
    d
}

/// MoE-shaped stats (uniform row sums) used where theorems assume them.
fn moe_stats(rng: &mut Rng, n: usize, per_source: u64) -> MoeLayerStats {
    let pop: Vec<f64> = (0..n).map(|_| rng.gen_f64() + 0.05).collect();
    let mut d = TrafficMatrix::zeros(n);
    for i in 0..n {
        for _ in 0..per_source {
            let mut j = rng.weighted_index(&pop);
            if j == i {
                j = (j + 1) % n;
            }
            d.add(i, j, 1);
        }
    }
    MoeLayerStats {
        traffic: d,
        gate_ms: 0.1,
        ffn_ms_per_token: 0.01,
        agg_ms: 0.05,
    }
}

/// PROPERTY: Aurora's slot schedule is contention-free, conserving, and
/// achieves exactly `b_max` for arbitrary traffic matrices.
#[test]
fn prop_aurora_schedule_valid_and_optimal() {
    for seed in 0..150u64 {
        let mut rng = Rng::new(seed + 1);
        let n = 2 + (rng.gen_range(11) as usize);
        let d = rand_matrix(&mut rng, n, 60);
        let s = aurora_schedule(&d);
        validate_slot_schedule(&d, &s).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

/// PROPERTY: no priority order beats the Theorem 4.2 lower bound, and the
/// bound is tight for Aurora.
#[test]
fn prop_lower_bound_dominates_all_orders() {
    for seed in 0..80u64 {
        let mut rng = Rng::new(seed ^ 0xB0);
        let n = 2 + (rng.gen_range(7) as usize);
        let d = rand_matrix(&mut rng, n, 40);
        let bound = d.b_max_tokens() as f64;
        let mut flows = d.flows();
        rng.shuffle(&mut flows);
        let order: Vec<(usize, usize)> = flows.iter().map(|&(i, j, _)| (i, j)).collect();
        let res = simulate_priority_order(&d, &order, &vec![1.0; n]);
        assert!(res.makespan >= bound - 1e-9, "seed {seed}");
        let aurora = comm_time(&d, &vec![1.0; n], SchedulePolicy::Aurora);
        assert!(aurora.makespan <= res.makespan + 1e-9, "seed {seed}");
    }
}

/// PROPERTY: reversed all-to-all (transpose) has identical Aurora time.
#[test]
fn prop_reversed_all_to_all_symmetric() {
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed ^ 0x1E);
        let n = 2 + (rng.gen_range(9) as usize);
        let d = rand_matrix(&mut rng, n, 50);
        assert_eq!(d.b_max_tokens(), d.transpose().b_max_tokens(), "seed {seed}");
    }
}

/// PROPERTY: bottleneck matching equals the exhaustive optimum (n ≤ 6) and
/// never exceeds any sampled matching (n = 12).
#[test]
fn prop_bottleneck_matching_optimal() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0xB077);
        let n = 2 + (rng.gen_range(5) as usize);
        let w: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..n).map(|_| rng.gen_range(100) as f64).collect())
            .collect();
        let (b, _) = bottleneck_matching(n, |i, j| w[i][j]);
        let (opt, _) = exhaustive_bottleneck(n, |i, j| w[i][j]);
        assert_eq!(b, opt, "seed {seed}");
    }
    let mut rng = Rng::new(0x51);
    let n = 12;
    let w: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..n).map(|_| rng.gen_f64()).collect())
        .collect();
    let (b, _) = bottleneck_matching(n, |i, j| w[i][j]);
    for _ in 0..500 {
        let p = rng.permutation(n);
        let m = (0..n).map(|i| w[i][p[i]]).fold(0.0, f64::max);
        assert!(b <= m + 1e-12);
    }
}

/// PROPERTY: Hungarian min-sum matches the exhaustive min-sum at small n.
#[test]
fn prop_hungarian_matches_exhaustive() {
    use aurora::matching::for_each_permutation;
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed ^ 0x40);
        let n = 2 + (rng.gen_range(4) as usize);
        let w: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..n).map(|_| rng.gen_range(50) as f64).collect())
            .collect();
        let (total, _) = hungarian_min_sum(&w);
        let mut best = f64::INFINITY;
        for_each_permutation(n, |p| {
            let s: f64 = (0..n).map(|i| w[i][p[i]]).sum();
            best = best.min(s);
        });
        assert!((total - best).abs() < 1e-9, "seed {seed}");
    }
}

/// PROPERTY (Theorem 6.2): the alternating pairing minimizes the max pair
/// sum versus every permutation (n ≤ 6).
#[test]
fn prop_case1_pairing_optimal() {
    use aurora::matching::for_each_permutation;
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0xC1C1);
        let n = 1 + (rng.gen_range(5) as usize);
        let a: Vec<u64> = (0..n).map(|_| rng.gen_range(100)).collect();
        let b: Vec<u64> = (0..n).map(|_| rng.gen_range(100)).collect();
        let pi = case1_pairing(&a, &b);
        let ours = (0..n).map(|i| a[i] + b[pi[i]]).max().unwrap();
        let mut best = u64::MAX;
        for_each_permutation(n, |p| {
            best = best.min((0..n).map(|i| a[i] + b[p[i]]).max().unwrap());
        });
        assert_eq!(ours, best, "seed {seed}");
    }
}

/// PROPERTY (§6.2 Case II): the bottleneck colocation minimizes aggregated
/// `b_max` over all sampled pairings.
#[test]
fn prop_case2_minimizes_aggregated_bmax() {
    use aurora::colocation::aggregated_b_max;
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed ^ 0xC2);
        let n = 4 + (rng.gen_range(5) as usize);
        let da = rand_matrix(&mut rng, n, 40);
        let db = rand_matrix(&mut rng, n, 40);
        let (_, pi) = case2_pairing(&da, &db);
        let ours = aggregated_b_max(&da, &db, &pi);
        for _ in 0..100 {
            let p = rng.permutation(n);
            assert!(ours <= aggregated_b_max(&da, &db, &p), "seed {seed}");
        }
    }
}

/// PROPERTY (Theorem 5.1): sorted assignment is end-to-end optimal among all
/// assignments on MoE-shaped traffic with aligned GPU perf (n = 5 exhaustive).
#[test]
fn prop_sorted_assignment_beats_exhaustive_search() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed ^ 0x51A);
        let stats = moe_stats(&mut rng, 5, 40);
        let cluster = Cluster::new(vec![
            GpuSpec {
                flops_scale: 1.0,
                bandwidth: 1.0,
            },
            GpuSpec {
                flops_scale: 0.9,
                bandwidth: 0.9,
            },
            GpuSpec {
                flops_scale: 0.7,
                bandwidth: 0.7,
            },
            GpuSpec {
                flops_scale: 0.5,
                bandwidth: 0.5,
            },
            GpuSpec {
                flops_scale: 0.4,
                bandwidth: 0.4,
            },
        ]);
        let eval = |perm: &[usize]| {
            simulate_exclusive(&stats.placed(perm), &cluster, SchedulePolicy::Aurora)
                .0
                .inference_ms
        };
        let sorted = sorted_assignment(&stats.expert_loads(), &cluster);
        let (best, _) = brute_force_assignment(5, eval);
        assert!(eval(&sorted) <= best + 1e-9, "seed {seed}");
    }
}

/// PROPERTY: the colocated timeline is monotone in the workload — adding
/// traffic or compute never shortens the layer.
#[test]
fn prop_colocated_timeline_monotone_in_load() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed ^ 0x7D);
        let n = 4;
        let a = moe_stats(&mut rng, n, 30);
        let b = moe_stats(&mut rng, n, 30);
        let cluster = Cluster::homogeneous(n, 1.0);
        let (base, _) = simulate_colocated(&a, &b, &cluster, SchedulePolicy::Aurora);
        // inflate model b's traffic
        let mut heavier = b.clone();
        let mut t = heavier.traffic.clone();
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    t.set(i, j, t.get(i, j) + 5);
                }
            }
        }
        heavier.traffic = t;
        let (more, _) = simulate_colocated(&a, &heavier, &cluster, SchedulePolicy::Aurora);
        assert!(more.inference_ms >= base.inference_ms - 1e-9, "seed {seed}");
        // inflate ffn cost
        let slower = MoeLayerStats {
            ffn_ms_per_token: a.ffn_ms_per_token * 2.0,
            ..a.clone()
        };
        let (comp, _) = simulate_colocated(&slower, &b, &cluster, SchedulePolicy::Aurora);
        assert!(comp.inference_ms >= base.inference_ms - 1e-9, "seed {seed}");
    }
}

/// PROPERTY: the decoupled heterogeneous heuristic never beats the exact
/// optimum, and stays within 2x of it at n = 4 (paper: 1.07x at n = 8).
#[test]
fn prop_decoupled_vs_exact_bounded_gap() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed ^ 0xDEC);
        let n = 4;
        let da = rand_matrix(&mut rng, n, 30);
        let db = rand_matrix(&mut rng, n, 30);
        let speeds = [1.0, 0.8, 0.5, 0.4];
        let (a_s, a_r) = send_recv_volumes(&da);
        let (b_s, b_r) = send_recv_volumes(&db);
        let cost = move |i: usize, j: usize, g: usize| {
            ((a_s[i] + b_s[j]).max(a_r[i] + b_r[j])) as f64 / speeds[g]
        };
        let sol = decoupled_solution(&da, &db, n, &cost);
        let (opt, _, _) = brute_force_exact(n, |pi, sg| {
            (0..n).map(|i| cost(i, pi[i], sg[i])).fold(0.0, f64::max)
        });
        assert!(
            sol.bottleneck >= opt - 1e-9,
            "seed {seed}: heuristic beat the optimum?"
        );
        assert!(
            sol.bottleneck <= opt * 2.0 + 1e-9,
            "seed {seed}: gap too large ({} vs {})",
            sol.bottleneck,
            opt
        );
    }
}

/// PROPERTY: traffic matrix algebra — permutation preserves totals and
/// `b_max`; merging conserves expert load totals.
#[test]
fn prop_matrix_algebra_invariants() {
    for seed in 0..50u64 {
        let mut rng = Rng::new(seed ^ 0xA1);
        let n = 2 + (rng.gen_range(7) as usize) * 2; // even for merging
        let d = rand_matrix(&mut rng, n, 30);
        let p = rng.permutation(n);
        let dp = d.permute(&p);
        assert_eq!(d.total(), dp.total(), "seed {seed}");
        assert_eq!(d.b_max_tokens(), dp.b_max_tokens(), "seed {seed}");
        let groups: Vec<Vec<usize>> = (0..n / 2).map(|g| vec![2 * g, 2 * g + 1]).collect();
        let merged = d.merge_groups(&groups);
        assert_eq!(
            merged.expert_loads().iter().sum::<u64>(),
            d.expert_loads().iter().sum::<u64>(),
            "seed {seed}"
        );
        assert!(merged.b_max_tokens() <= d.b_max_tokens() * 2, "seed {seed}");
    }
}

/// PROPERTY: the hierarchical two-phase schedule conserves tokens per
/// (src, dst) pair, splits flows cleanly into intra- and inter-group phases,
/// and its uplink phase never exceeds the Theorem-4.2-style budget: the
/// group-level round durations sum to exactly `b_max` of the group matrix,
/// so the uplink phase's fluid drain time equals the uplink drain bound on
/// homogeneous fabrics.
#[test]
fn prop_hierarchical_schedule_conserves_and_meets_uplink_budget() {
    use aurora::cluster::{uplink_bound, Topology};
    use aurora::schedule::hierarchical_schedule;

    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0x70B0);
        // 2..4 groups of 2..4 GPUs each
        let n_groups = 2 + rng.gen_range(3) as usize;
        let per = 2 + rng.gen_range(3) as usize;
        let n = n_groups * per;
        let oversub = 1.0 + rng.gen_range(4) as f64;
        let d = rand_matrix(&mut rng, n, 40);
        let cluster = Cluster::homogeneous(n, 1.0);
        let topo = Topology::even_two_tier(n, n_groups, oversub).unwrap();
        let owner = topo.group_of(n).unwrap();

        let sched = hierarchical_schedule(&d, &cluster, &topo).unwrap();

        // conservation per (src, dst)
        let delivered = sched.delivered();
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    assert_eq!(delivered.get(i, j), d.get(i, j), "seed {seed} ({i},{j})");
                }
            }
        }
        // phase separation
        for s in &sched.intra {
            for r in &s.rounds {
                for &(src, dst, _) in &r.transfers {
                    assert_eq!(owner[src], owner[dst], "seed {seed}: cross flow in intra");
                }
            }
        }
        for r in &sched.inter {
            for &(src, dst, _) in &r.transfers {
                assert_ne!(owner[src], owner[dst], "seed {seed}: local flow in inter");
            }
        }
        // group-level rounds are partial permutations whose budgets sum to
        // the group matrix's b_max — the uplink drain bound, exactly
        let mut group = TrafficMatrix::zeros(n_groups);
        for i in 0..n {
            for j in 0..n {
                if i != j && owner[i] != owner[j] {
                    group.add(owner[i], owner[j], d.get(i, j));
                }
            }
        }
        for r in &sched.inter {
            let mut send = vec![false; n_groups];
            let mut recv = vec![false; n_groups];
            for &(ga, gb, t) in &r.pairs {
                assert!(!send[ga] && !recv[gb], "seed {seed}: group round contention");
                send[ga] = true;
                recv[gb] = true;
                assert!(t <= r.budget, "seed {seed}: pair overruns round budget");
            }
        }
        assert_eq!(
            sched.inter_budget_tokens(),
            group.b_max_tokens(),
            "seed {seed}: uplink budget must equal the group-level b_max"
        );
        // fluid drain of the budget at the uplink rate equals the bound
        let rates = topo.uplink_rates(&cluster);
        let budget_drain = sched.inter_budget_tokens() as f64 / rates[0];
        let bound = uplink_bound(&d, &cluster, &topo);
        assert!(
            (budget_drain - bound).abs() < 1e-9,
            "seed {seed}: budget drain {budget_drain} vs bound {bound}"
        );
        // and the reported pipelined estimate respects both lower bounds
        assert!(sched.pipelined_ms >= bound - 1e-9, "seed {seed}");
        assert!(
            sched.pipelined_ms >= d.b_max_hetero(&cluster.bandwidths()) - 1e-9,
            "seed {seed}"
        );
        assert!(sched.sequential_ms >= sched.pipelined_ms - 1e-9, "seed {seed}");
    }
}

/// PROPERTY: the placement [`aurora::placement::DeltaEstimator`]'s per-GPU
/// estimates and uplink counters match the from-scratch
/// `estimate_per_gpu` / `uplink_bound` rescans after arbitrary randomized
/// move/swap sequences — the exactness contract that lets the planner's
/// refinement passes run on deltas without changing a single decision.
#[test]
fn prop_delta_estimator_matches_full_rescan() {
    use aurora::cluster::{uplink_bound, Topology};
    use aurora::placement::{estimate_per_gpu, DeltaEstimator, Deployment, Scenario};

    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0xDE17A);
        let n_gpus = 2 + rng.gen_range(7) as usize;
        let n_models = 1 + rng.gen_range(3) as usize;
        let cluster = if n_gpus % 4 == 0 && rng.gen_range(2) == 0 {
            Cluster::paper_heterogeneous(n_gpus, 60.0)
        } else {
            Cluster::homogeneous(n_gpus, 60.0)
        };
        let topo = if n_gpus % 4 == 0 && rng.gen_range(2) == 0 {
            // recursive fabric: n/2 leaf pairs under 2 pods
            Topology::even_tiered(
                n_gpus,
                &[n_gpus / 2, 2],
                &[1.0 + rng.gen_f64() * 2.0, 1.0 + rng.gen_f64() * 4.0],
            )
            .unwrap()
        } else if n_gpus % 2 == 0 && rng.gen_range(2) == 0 {
            Topology::even_two_tier(n_gpus, 2, 1.0 + rng.gen_f64() * 4.0).unwrap()
        } else {
            Topology::BigSwitch
        };
        let mut layers_owned: Vec<MoeLayerStats> = Vec::new();
        let mut assignments: Vec<Vec<usize>> = Vec::new();
        for _ in 0..n_models {
            let n_exp = n_gpus + rng.gen_range(9) as usize;
            layers_owned.push(MoeLayerStats {
                traffic: rand_matrix(&mut rng, n_exp, 30),
                gate_ms: 0.05,
                ffn_ms_per_token: 0.002,
                agg_ms: 0.03,
            });
            let mut a = Vec::with_capacity(n_exp);
            for _ in 0..n_exp {
                a.push(rng.gen_range(n_gpus as u64) as usize);
            }
            assignments.push(a);
        }
        let mut dep = Deployment::new(
            n_gpus,
            assignments,
            SchedulePolicy::Aurora,
            Scenario::MultiColocated,
        )
        .unwrap();
        let layers: Vec<&MoeLayerStats> = layers_owned.iter().collect();
        let mut est = DeltaEstimator::new(&dep, &layers, &cluster, &topo);
        for step in 0..30 {
            if rng.gen_range(2) == 0 {
                let m = rng.gen_range(n_models as u64) as usize;
                let e = rng.gen_range(dep.assignments[m].len() as u64) as usize;
                let g = rng.gen_range(n_gpus as u64) as usize;
                est.apply_move(m, e, g);
                dep.assignments[m][e] = g;
            } else {
                let m1 = rng.gen_range(n_models as u64) as usize;
                let e1 = rng.gen_range(dep.assignments[m1].len() as u64) as usize;
                let m2 = rng.gen_range(n_models as u64) as usize;
                let e2 = rng.gen_range(dep.assignments[m2].len() as u64) as usize;
                if m1 == m2 && e1 == e2 {
                    continue;
                }
                let (g1, g2) = (dep.assignments[m1][e1], dep.assignments[m2][e2]);
                est.apply_swap(m1, e1, m2, e2);
                dep.assignments[m1][e1] = g2;
                dep.assignments[m2][e2] = g1;
            }
            let full = estimate_per_gpu(&dep, &layers, &cluster);
            for (g, &c) in full.iter().enumerate() {
                assert!(
                    (est.cost(g) - c).abs() < 1e-9,
                    "seed {seed} step {step} gpu {g}: {} vs {c}",
                    est.cost(g)
                );
            }
            let drain = uplink_bound(&dep.aggregated_traffic(&layers), &cluster, &topo);
            assert!(
                (est.uplink_drain_ms() - drain).abs() < 1e-9,
                "seed {seed} step {step}: {} vs {drain}",
                est.uplink_drain_ms()
            );
        }
    }
}

/// PROPERTY: the replication-side [`aurora::replication::ReplicaDeltaEstimator`]'s
/// committed split plan, per-GPU estimates, and uplink drain match the
/// from-scratch `optimize_splits` / `estimate_per_gpu_replicated` /
/// `uplink_bound` pipeline after randomized replica additions — and every
/// candidate price (`eval_add`) equals a full re-evaluation of the mutated
/// deployment.
#[test]
fn prop_replica_delta_matches_full() {
    use aurora::cluster::{uplink_bound, Topology};
    use aurora::placement::{Deployment, Scenario};
    use aurora::replication::{
        estimate_per_gpu_replicated, optimize_splits, ReplicaDeltaEstimator, ReplicatedDeployment,
    };

    for seed in 0..25u64 {
        let mut rng = Rng::new(seed ^ 0x5137);
        let n_gpus = 2 + rng.gen_range(7) as usize;
        let n_exp = n_gpus + rng.gen_range(2 * n_gpus as u64) as usize;
        let cluster = Cluster::homogeneous(n_gpus, 80.0);
        let topo = if n_gpus % 4 == 0 && rng.gen_range(2) == 0 {
            Topology::even_tiered(n_gpus, &[n_gpus / 2, 2], &[2.0, 4.0]).unwrap()
        } else if n_gpus % 2 == 0 && rng.gen_range(2) == 0 {
            Topology::even_two_tier(n_gpus, 2, 2.0).unwrap()
        } else {
            Topology::BigSwitch
        };
        let layer = MoeLayerStats {
            traffic: rand_matrix(&mut rng, n_exp, 40),
            gate_ms: 0.02,
            ffn_ms_per_token: 0.001,
            agg_ms: 0.015,
        };
        let layers = [&layer];
        let base = Deployment::new(
            n_gpus,
            vec![(0..n_exp).map(|e| e % n_gpus).collect()],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        let mut rep = ReplicatedDeployment::from_deployment(base);
        let mut est = ReplicaDeltaEstimator::new(&rep, &layers, &cluster, &topo);
        for _step in 0..12 {
            let e = rng.gen_range(n_exp as u64) as usize;
            let g = rng.gen_range(n_gpus as u64) as usize;
            if rep.replicas[0][e].contains(&g) {
                continue;
            }
            let predicted = est.eval_add(0, e, g);
            est.commit_add(0, e, g);
            rep.replicas[0][e].push(g);
            let plan = optimize_splits(&rep, &layers, &cluster);
            let costs = estimate_per_gpu_replicated(&rep, &layers, &cluster, &plan);
            let agg = rep.aggregated_traffic_split(&layers, &plan);
            let mut full = costs.iter().cloned().fold(0.0, f64::max);
            full = full.max(uplink_bound(&agg, &cluster, &topo));
            assert!(
                (predicted - full).abs() < 1e-9,
                "seed {seed}: predicted {predicted} vs full {full}"
            );
            assert_eq!(est.plan(), &plan, "seed {seed}: split plans diverged");
            for (gpu, &c) in costs.iter().enumerate() {
                assert!(
                    (est.costs()[gpu] - c).abs() < 1e-9,
                    "seed {seed} gpu {gpu}: {} vs {c}",
                    est.costs()[gpu]
                );
            }
            assert!((est.objective() - full).abs() < 1e-9, "seed {seed}");
        }
    }
}

/// PROPERTY: the sparse (CSR) and dense traffic representations are
/// bit-for-bit interchangeable across the whole read surface — scalars,
/// projections, split projections, topology bounds, and the full Aurora/BvN
/// slot schedule — on randomized shapes and densities. This is the contract
/// that lets every hot path pick its representation by density without
/// changing a single planning or scheduling decision.
#[test]
fn prop_sparse_dense_bitwise_agreement() {
    use aurora::cluster::{uplink_bound, Topology};

    for seed in 0..60u64 {
        let mut rng = Rng::new(seed ^ 0x5DBB);
        let n = 2 + rng.gen_range(13) as usize;
        let density = 0.05 + rng.gen_f64() * 0.9;
        let d = rand_sparse_matrix(&mut rng, n, 40, density);
        let sp = d.to_sparse();
        let dn = sp.to_dense();

        // scalar surface
        assert_eq!(d.total(), sp.total(), "seed {seed}");
        assert_eq!(d.nnz(), sp.nnz(), "seed {seed}");
        assert_eq!(d.b_max_tokens(), sp.b_max_tokens(), "seed {seed}");
        let bws: Vec<f64> = (0..n).map(|_| 0.5 + rng.gen_f64() * 2.0).collect();
        assert!(
            d.b_max_hetero(&bws) == sp.b_max_hetero(&bws),
            "seed {seed}: hetero b_max diverged"
        );
        for i in 0..n {
            assert_eq!(d.row_sum(i), sp.row_sum(i), "seed {seed} row {i}");
            assert_eq!(d.col_sum(i), sp.col_sum(i), "seed {seed} col {i}");
            for j in 0..n {
                assert_eq!(d.get(i, j), sp.get(i, j), "seed {seed} ({i},{j})");
            }
        }
        assert_eq!(d.dense_vec(), dn.dense_vec(), "seed {seed}: round trip");
        assert_eq!(d.expert_loads(), sp.expert_loads(), "seed {seed}");
        assert_eq!(d.flows(), sp.flows(), "seed {seed}");
        assert_eq!(
            d.transpose().dense_vec(),
            sp.transpose().dense_vec(),
            "seed {seed}"
        );
        let p = rng.permutation(n);
        assert_eq!(d.permute(&p).dense_vec(), sp.permute(&p).dense_vec(), "seed {seed}");

        // projection surface: arbitrary many-to-one owner maps
        let m = 1 + rng.gen_range(n as u64) as usize;
        let owner: Vec<usize> = (0..n).map(|_| rng.gen_range(m as u64) as usize).collect();
        assert_eq!(
            d.project(&owner, m).dense_vec(),
            sp.project(&owner, m).dense_vec(),
            "seed {seed}: project"
        );
        // split projection: replicated destinations with fractional weights
        let mut replicas = Vec::with_capacity(n);
        let mut weights = Vec::with_capacity(n);
        for &o in &owner {
            if m >= 2 && rng.gen_range(2) == 0 {
                let other = (o + 1 + rng.gen_range(m as u64 - 1) as usize) % m;
                replicas.push(vec![o, other]);
                weights.push(vec![0.7, 0.3]);
            } else {
                replicas.push(vec![o]);
                weights.push(vec![1.0]);
            }
        }
        assert_eq!(
            d.project_split(&owner, &replicas, &weights, m).dense_vec(),
            sp.project_split(&owner, &replicas, &weights, m).dense_vec(),
            "seed {seed}: project_split"
        );

        // the full BvN slot schedule — identical rounds, not just makespan
        assert_eq!(aurora_schedule(&d), aurora_schedule(&sp), "seed {seed}");

        // topology bounds, two-tier and recursive
        let cluster = Cluster::homogeneous(n, 1.0 + rng.gen_f64());
        if n % 2 == 0 {
            let topo = Topology::even_two_tier(n, 2, 1.0 + rng.gen_f64() * 4.0).unwrap();
            assert!(
                uplink_bound(&d, &cluster, &topo) == uplink_bound(&sp, &cluster, &topo),
                "seed {seed}: two-tier uplink bound diverged"
            );
        }
        if n % 4 == 0 {
            let topo = Topology::even_tiered(
                n,
                &[n / 2, 2],
                &[1.0 + rng.gen_f64() * 2.0, 1.0 + rng.gen_f64() * 4.0],
            )
            .unwrap();
            assert!(
                uplink_bound(&d, &cluster, &topo) == uplink_bound(&sp, &cluster, &topo),
                "seed {seed}: tiered uplink bound diverged"
            );
        }
    }
}

/// PROPERTY: the recursive tiered schedule conserves tokens per (src, dst)
/// pair, separates flows by span (intra-rack / cross-rack-intra-pod /
/// cross-pod), and each phase's round budgets sum to exactly the `b_max` of
/// its own span matrix (Theorem 4.2 applied per tier) — on randomized pod /
/// rack / GPU shapes and oversubscriptions, with sparse input producing the
/// identical schedule.
#[test]
fn prop_tiered_schedule_conserves_and_meets_tier_budgets() {
    use aurora::cluster::{uplink_bound, Topology};
    use aurora::schedule::hierarchical_schedule;

    for seed in 0..30u64 {
        let mut rng = Rng::new(seed ^ 0x71E2);
        let pods = 2 + rng.gen_range(2) as usize; // 2..3 pods
        let racks_per = 2 + rng.gen_range(2) as usize; // 2..3 racks per pod
        let per = 2 + rng.gen_range(2) as usize; // 2..3 GPUs per rack
        let n_racks = pods * racks_per;
        let n = n_racks * per;
        let os0 = 1.0 + rng.gen_range(4) as f64;
        let os1 = 1.0 + rng.gen_range(4) as f64;
        let topo = Topology::even_tiered(n, &[n_racks, pods], &[os0, os1]).unwrap();
        let d = rand_sparse_matrix(&mut rng, n, 40, 0.3 + rng.gen_f64() * 0.6);
        let cluster = Cluster::homogeneous(n, 1.0);
        let rack = topo.owners_at(n, 0).unwrap();
        let pod = topo.owners_at(n, 1).unwrap();

        let sched = hierarchical_schedule(&d, &cluster, &topo).unwrap();

        // conservation per (src, dst) across intra + every tier phase
        let delivered = sched.delivered();
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    assert_eq!(delivered.get(i, j), d.get(i, j), "seed {seed} ({i},{j})");
                }
            }
        }
        // span separation
        for s in &sched.intra {
            for r in &s.rounds {
                for &(src, dst, _) in &r.transfers {
                    assert_eq!(rack[src], rack[dst], "seed {seed}: cross flow in intra");
                }
            }
        }
        assert_eq!(sched.tiers.len(), 2, "seed {seed}");
        for round in &sched.tiers[0] {
            for &(src, dst, _) in &round.transfers {
                assert_ne!(rack[src], rack[dst], "seed {seed}: intra flow in phase 1");
                assert_eq!(pod[src], pod[dst], "seed {seed}: cross-pod flow in phase 1");
            }
        }
        for round in &sched.tiers[1] {
            for &(src, dst, _) in &round.transfers {
                assert_ne!(pod[src], pod[dst], "seed {seed}: local flow in phase 2");
            }
        }
        // per-tier Theorem 4.2: budgets sum to each span matrix's b_max
        let mut g_rack = TrafficMatrix::zeros(n_racks);
        let mut g_pod = TrafficMatrix::zeros(pods);
        for i in 0..n {
            for (j, t) in d.row_iter(i) {
                if i == j || rack[i] == rack[j] {
                    continue;
                }
                if pod[i] == pod[j] {
                    g_rack.add(rack[i], rack[j], t);
                } else {
                    g_pod.add(pod[i], pod[j], t);
                }
            }
        }
        let budget = |rounds: &[aurora::schedule::InterRound]| {
            rounds.iter().map(|r| r.budget).sum::<u64>()
        };
        assert_eq!(budget(&sched.tiers[0]), g_rack.b_max_tokens(), "seed {seed}");
        assert_eq!(budget(&sched.tiers[1]), g_pod.b_max_tokens(), "seed {seed}");
        // rounds are partial permutations of their tier's units, and phase-1
        // pairs stay inside one pod (block-diagonal concurrency)
        let Topology::Tiered { levels } = &topo else {
            unreachable!("even_tiered builds a tiered topology")
        };
        let mut rack_pod = vec![0usize; n_racks];
        for (pg, members) in levels[1].groups.iter().enumerate() {
            for &r in members {
                rack_pod[r] = pg;
            }
        }
        for (t, (rounds, n_units)) in
            [(&sched.tiers[0], n_racks), (&sched.tiers[1], pods)].into_iter().enumerate()
        {
            for round in rounds.iter() {
                let mut send = vec![false; n_units];
                let mut recv = vec![false; n_units];
                for &(ua, ub, tok) in &round.pairs {
                    assert!(!send[ua] && !recv[ub], "seed {seed}: unit reused in a round");
                    send[ua] = true;
                    recv[ub] = true;
                    assert!(tok <= round.budget, "seed {seed}: pair overruns budget");
                    if t == 0 {
                        assert_eq!(rack_pod[ua], rack_pod[ub], "seed {seed}: phase-1 pair crosses pods");
                    }
                }
            }
        }
        // fluid bounds
        let lb = uplink_bound(&d, &cluster, &topo)
            .max(d.b_max_hetero(&cluster.bandwidths()));
        assert!(sched.pipelined_ms >= lb - 1e-9, "seed {seed}");
        assert!(sched.sequential_ms >= sched.pipelined_ms - 1e-9, "seed {seed}");
        // sparse input produces the identical schedule
        let ss = hierarchical_schedule(&d.to_sparse(), &cluster, &topo).unwrap();
        assert_eq!(ss.inter, sched.inter, "seed {seed}");
        assert_eq!(ss.tiers, sched.tiers, "seed {seed}");
        assert!(ss.pipelined_ms == sched.pipelined_ms, "seed {seed}");
    }
}

/// PROPERTY: every recorded engine timeline is sorted, non-overlapping, and
/// exactly partitions `[0, makespan]`; link timelines are sorted busy
/// intervals inside the makespan; the timeline-derived utilization equals
/// the simulator's scalar; and recording is observational — results with
/// the recorder on are bit-for-bit the results with it off — across random
/// 1..=3-model groups, both policies, two-tier fabrics, and background
/// (swap-drain) windows.
#[test]
fn prop_timeline_partitions_makespan_and_recording_is_observational() {
    use aurora::cluster::Topology;
    use aurora::obs::timeline::{SegmentKind, TimelineRecorder};
    use aurora::sim::{
        simulate_group, simulate_group_recorded, simulate_group_topology,
        simulate_group_topology_recorded, simulate_window, simulate_window_recorded,
    };

    let check_engine_partition = |tl: &aurora::obs::timeline::Timelines, seed: u64| {
        for g in &tl.gpus {
            assert!(!g.segments.is_empty(), "seed {seed} gpu {}: empty timeline", g.gpu);
            assert!(
                g.segments[0].start_ms.abs() < 1e-9,
                "seed {seed} gpu {}: first segment starts at {}",
                g.gpu,
                g.segments[0].start_ms
            );
            for w in g.segments.windows(2) {
                assert!(
                    (w[1].start_ms - w[0].end_ms).abs() < 1e-9,
                    "seed {seed} gpu {}: gap/overlap at {} -> {}",
                    g.gpu,
                    w[0].end_ms,
                    w[1].start_ms
                );
            }
            let last = g.segments.last().unwrap();
            assert!(
                (last.end_ms - tl.makespan_ms).abs() < 1e-9,
                "seed {seed} gpu {}: ends at {} of {}",
                g.gpu,
                last.end_ms,
                tl.makespan_ms
            );
            let total: f64 = g.segments.iter().map(|s| s.dur_ms()).sum();
            assert!(
                (total - tl.makespan_ms).abs() < 1e-6,
                "seed {seed} gpu {}: durations sum to {total} of {}",
                g.gpu,
                tl.makespan_ms
            );
        }
        for link in tl.uplinks.iter().chain(&tl.downlinks) {
            for w in link.segments.windows(2) {
                assert!(
                    w[1].start_ms >= w[0].end_ms - 1e-9,
                    "seed {seed} link {}: overlapping busy intervals",
                    link.gpu
                );
            }
            for s in &link.segments {
                assert!(s.end_ms > s.start_ms, "seed {seed}: empty link segment");
                assert!(
                    s.start_ms >= -1e-9 && s.end_ms <= tl.makespan_ms + 1e-9,
                    "seed {seed} link {}: segment [{}, {}] outside [0, {}]",
                    link.gpu,
                    s.start_ms,
                    s.end_ms,
                    tl.makespan_ms
                );
            }
        }
    };

    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0x717E);
        let n = 4 + (rng.gen_range(5) as usize);
        let m = 1 + (rng.gen_range(3) as usize);
        let models: Vec<MoeLayerStats> = (0..m).map(|_| moe_stats(&mut rng, n, 40)).collect();
        let refs: Vec<&MoeLayerStats> = models.iter().collect();
        let cluster = Cluster::homogeneous(n, 1.0 + rng.gen_f64() * 3.0);
        let policy = if seed % 2 == 0 {
            SchedulePolicy::Aurora
        } else {
            SchedulePolicy::Sjf
        };

        // recorder off vs on: bit-for-bit
        let (plain, _) = simulate_group(&refs, &cluster, policy);
        let mut rec = TimelineRecorder::new(n);
        let (recorded, _) = simulate_group_recorded(&refs, &cluster, policy, &mut rec);
        assert_eq!(plain, recorded, "seed {seed}: recording changed the result");
        let tl = rec.take().unwrap();
        assert!(
            tl.makespan_ms == plain.inference_ms,
            "seed {seed}: makespan {} vs inference {}",
            tl.makespan_ms,
            plain.inference_ms
        );
        check_engine_partition(&tl, seed);
        assert!(
            (tl.utilization() - plain.utilization).abs() < 1e-9,
            "seed {seed}: timeline util {} vs scalar {}",
            tl.utilization(),
            plain.utilization
        );

        // two-tier topology path
        if n % 2 == 0 {
            let topo = Topology::even_two_tier(n, 2, 1.0 + rng.gen_f64() * 3.0).unwrap();
            let (tp, _) = simulate_group_topology(&refs, &cluster, &topo, policy);
            let mut rec = TimelineRecorder::new(n);
            let (tr, _) =
                simulate_group_topology_recorded(&refs, &cluster, &topo, policy, &mut rec);
            assert_eq!(tp, tr, "seed {seed}: topology recording changed the result");
            check_engine_partition(&rec.take().unwrap(), seed);
        }

        // serving window with background staging traffic -> SwapDrain
        let bg = rand_matrix(&mut rng, n, 20);
        let wp = simulate_window(&refs, Some(&bg), &cluster, None, policy);
        let mut rec = TimelineRecorder::new(n);
        let wr = simulate_window_recorded(&refs, Some(&bg), &cluster, None, policy, &mut rec);
        assert_eq!(wp, wr, "seed {seed}: window recording changed the result");
        let tl = rec.take().unwrap();
        check_engine_partition(&tl, seed);
        if bg.total() > 0 {
            let has_swap = tl
                .uplinks
                .iter()
                .chain(&tl.downlinks)
                .flat_map(|l| &l.segments)
                .any(|s| matches!(s.kind, SegmentKind::SwapDrain));
            assert!(has_swap, "seed {seed}: background traffic left no SwapDrain segment");
        }
    }
}

/// PROPERTY: cluster utilization derived from the recorded timelines equals
/// the simulators' legacy scalar formula across all entry points (exclusive,
/// colocated, group) — the one shared `mean_busy_fraction` helper really is
/// the single source of truth.
#[test]
fn prop_timeline_utilization_matches_legacy_scalar() {
    use aurora::obs::timeline::TimelineRecorder;
    use aurora::sim::{simulate_colocated_recorded, simulate_exclusive_recorded};

    for seed in 0..30u64 {
        let mut rng = Rng::new(seed ^ 0x07B5);
        let n = 3 + (rng.gen_range(6) as usize);
        let a = moe_stats(&mut rng, n, 50);
        let b = moe_stats(&mut rng, n, 50);
        let cluster = Cluster::homogeneous(n, 1.0 + rng.gen_f64() * 2.0);

        let mut rec = TimelineRecorder::new(n);
        let (res, _) = simulate_exclusive_recorded(&a, &cluster, SchedulePolicy::Aurora, &mut rec);
        let tl = rec.take().unwrap();
        assert!(
            (tl.utilization() - res.utilization).abs() < 1e-9,
            "seed {seed}: exclusive {} vs {}",
            tl.utilization(),
            res.utilization
        );

        let mut rec = TimelineRecorder::new(n);
        let (res, _) =
            simulate_colocated_recorded(&a, &b, &cluster, SchedulePolicy::Aurora, &mut rec);
        let tl = rec.take().unwrap();
        assert!(
            (tl.utilization() - res.utilization).abs() < 1e-9,
            "seed {seed}: colocated {} vs {}",
            tl.utilization(),
            res.utilization
        );
    }
}

/// PROPERTY: [`aurora::obs::SloMonitor`] fires exactly when the nearest-rank
/// p99 of its rolling window exceeds the target — verified against an
/// independently maintained reference window on adversarial streams mixing
/// bursts, calm stretches, NaN, and infinities (non-finite and negative
/// samples are dropped, never poisoning the window).
#[test]
fn prop_slo_monitor_fires_iff_rolling_p99_exceeds_target() {
    use aurora::obs::SloMonitor;
    use std::collections::VecDeque;

    for seed in 0..30u64 {
        let mut rng = Rng::new(seed ^ 0x5105);
        let window = 1 + rng.gen_range(16) as usize;
        let target = 0.5 + rng.gen_f64() * 2.0;
        let mut mon = SloMonitor::new(target, window);
        let mut reference: VecDeque<f64> = VecDeque::new();

        for step in 0..200 {
            let x = match rng.gen_range(10) {
                0 => f64::NAN,
                1 => f64::INFINITY,
                2 => f64::NEG_INFINITY,
                3 => -rng.gen_f64(),
                // bursty tail: occasionally far past the target
                4 => target * (2.0 + rng.gen_f64() * 8.0),
                _ => rng.gen_f64() * target,
            };
            let st = mon.observe(x);
            if x.is_finite() && x >= 0.0 {
                if reference.len() == window {
                    reference.pop_front();
                }
                reference.push_back(x);
            }
            if reference.is_empty() {
                assert!(!st.violating, "seed {seed} step {step}: fired on empty window");
                continue;
            }
            // nearest-rank p99 of the reference window (matches obs::metrics)
            let mut xs: Vec<f64> = reference.iter().copied().collect();
            xs.sort_by(f64::total_cmp);
            let idx = ((xs.len() as f64 - 1.0) * 0.99).round() as usize;
            let p99 = xs[idx.min(xs.len() - 1)];
            assert!(
                (st.p99_ms - p99).abs() < 1e-12,
                "seed {seed} step {step}: p99 {} vs reference {p99}",
                st.p99_ms
            );
            assert_eq!(
                st.violating,
                p99 > target,
                "seed {seed} step {step}: violating={} but p99={p99} target={target}",
                st.violating
            );
            assert_eq!(mon.is_violating(), st.violating, "seed {seed} step {step}");
        }
    }
}

/// PROPERTY: under randomized cluster-membership churn
/// ([`aurora::coordinator::failure_schedule`]: fail/drain/join sequences
/// that always leave ≥ 2 placeable GPUs), the coordinator's fault path
/// holds its safety contract at every step:
///
/// 1. the active plan never routes a token through a dead GPU — checked by
///    projecting the plan's expert traffic onto GPUs and summing dead rows
///    and columns ([`aurora::sim::dead_gpu_tokens`]);
/// 2. dead GPUs host **zero** replicas (promotion evacuates them in the
///    event window, before the window serves);
/// 3. every split plan stays conservation-exact: one weight per replica,
///    each `(model, expert)` vector summing to 1;
/// 4. every committed migration sources only from live GPUs, lands only on
///    placeable GPUs, and its weight schedule validates contention-free.
#[test]
fn prop_membership_churn_never_touches_dead_gpus() {
    use aurora::coordinator::{
        failure_schedule, Coordinator, CoordinatorConfig, CoordinatorDecision,
    };
    use aurora::planner::{Planner, ReplicationConfig};
    use aurora::replication::{ReplicatedDeployment, SplitPlan};
    use aurora::sim::dead_gpu_tokens;
    use aurora::trace::ModelTrace;
    use aurora::traffic::zipf_traffic;

    fn check_active(
        coord: &Coordinator,
        layer: &MoeLayerStats,
        seed: u64,
        window: usize,
    ) {
        let (rep, splits): (&ReplicatedDeployment, &SplitPlan) = coord.active();
        let health = coord.health();
        for m in 0..rep.n_models() {
            for (e, replica_gpus) in rep.replicas[m].iter().enumerate() {
                let w = &splits.weights[m][e];
                assert_eq!(
                    w.len(),
                    replica_gpus.len(),
                    "seed {seed} window {window}: one split weight per replica"
                );
                let sum: f64 = w.iter().sum();
                assert!(
                    (sum - 1.0).abs() < 1e-9,
                    "seed {seed} window {window}: splits of ({m},{e}) sum to {sum}"
                );
                for &g in replica_gpus {
                    assert!(
                        health.is_alive(g),
                        "seed {seed} window {window}: replica of ({m},{e}) on dead GPU {g}"
                    );
                }
            }
        }
        let projected = rep.project_layer_split(0, layer, splits);
        assert_eq!(
            dead_gpu_tokens(&projected.traffic, health.alive()),
            0,
            "seed {seed} window {window}: tokens routed through a dead GPU"
        );
    }

    for seed in 0..10u64 {
        let mut rng = Rng::new(seed ^ 0xFA17);
        let n_gpus = 6 + rng.gen_range(3) as usize;
        let n_experts = n_gpus * 2;
        let windows = 12;
        let cluster = Cluster::homogeneous(n_gpus, 800.0);
        let alpha = 0.8 + rng.gen_f64();
        let traffic = zipf_traffic(n_experts, 512, alpha, seed);
        let layer = MoeLayerStats {
            traffic: traffic.clone(),
            gate_ms: 0.02,
            ffn_ms_per_token: 0.001,
            agg_ms: 0.015,
        };
        let trace = ModelTrace {
            name: format!("churn-{seed}"),
            layers: vec![layer.clone()],
        };
        let planner = Planner::default();
        let (rep, splits) = planner
            .plan_replicated(&[&trace], &cluster, &ReplicationConfig::default())
            .unwrap();
        let cfg = CoordinatorConfig {
            cooldown_windows: 0,
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(planner, rep, splits, &trace.layers[0], cfg);
        let events = failure_schedule(n_gpus, windows, 1 + rng.gen_range(3) as usize, seed);

        for w in 0..windows {
            for (_, ev) in events.iter().filter(|(ew, _)| *ew == w) {
                coord.inject_event(ev, &cluster);
                // the promoted stopgap must already be safe, pre-observe
                check_active(&coord, &layer, seed, w);
            }
            check_active(&coord, &layer, seed, w);
            let decision = coord.observe_window(&traffic, &cluster);
            if let CoordinatorDecision::Replan(out) = decision {
                let health = coord.health();
                for f in &out.migration.flows {
                    assert!(
                        health.is_alive(f.src),
                        "seed {seed} window {w}: migration sourced from dead GPU {}",
                        f.src
                    );
                    assert!(
                        health.is_placeable(f.dst),
                        "seed {seed} window {w}: migration lands on unplaceable GPU {}",
                        f.dst
                    );
                }
                if !out.migration.is_empty() {
                    // dead rows and columns of the weight traffic are empty,
                    // and the weight schedule is contention-free and exact
                    assert_eq!(dead_gpu_tokens(&out.migration.traffic, health.alive()), 0);
                    validate_slot_schedule(&out.migration.traffic, &out.migration.schedule)
                        .unwrap();
                }
            }
            // let any staging swap land, then the installed plan must be
            // safe for the *current* membership too
            coord.advance(1e9);
            check_active(&coord, &layer, seed, w);
        }
        assert_eq!(coord.stats.windows, windows as u64);
        assert!(coord.health().n_placeable() >= 2, "schedule guarantees survivability");
    }
}

/// Randomized gray-failure churn: seeded interleavings of degradations,
/// recoveries, and hard failures drive the coordinator through its detector
/// loop with ±5% synthetic observation noise. Invariants, every window:
///
/// 1. the detector's inferred scales always sit in `(0, 1]`;
/// 2. the active plan stays conservation-exact (one split weight per
///    replica, each `(model, expert)` vector summing to 1) and routes zero
///    tokens through dead GPUs — which covers escalated stragglers, since
///    escalation runs the failure path;
/// 3. flap damping holds: committed degrade replans are spaced at least
///    `degrade_cooldown_windows + 1` windows apart, so their total is
///    bounded by the horizon.
#[test]
fn prop_gray_failure_churn_invariants() {
    use aurora::coordinator::{
        degradation_schedule, failure_schedule, ClusterEvent, Coordinator, CoordinatorConfig,
        DegradeState,
    };
    use aurora::obs::degrade::{DegradationDetector, DegradeConfig, WindowObservation};
    use aurora::planner::{Planner, ReplicationConfig};
    use aurora::sim::dead_gpu_tokens;
    use aurora::trace::ModelTrace;
    use aurora::traffic::{multiplicative_noise, zipf_traffic};

    for seed in 0..8u64 {
        let mut rng = Rng::new(seed ^ 0x6EA7);
        let n_gpus = 6 + rng.gen_range(3) as usize;
        let n_experts = n_gpus * 2;
        let windows = 16usize;
        let cluster = Cluster::homogeneous(n_gpus, 800.0);
        let alpha = 0.8 + rng.gen_f64();
        let traffic = zipf_traffic(n_experts, 512, alpha, seed);
        let layer = MoeLayerStats {
            traffic: traffic.clone(),
            gate_ms: 0.02,
            ffn_ms_per_token: 0.001,
            agg_ms: 0.015,
        };
        let trace = ModelTrace {
            name: format!("gray-{seed}"),
            layers: vec![layer.clone()],
        };
        let planner = Planner::default();
        let (rep, splits) = planner
            .plan_replicated(&[&trace], &cluster, &ReplicationConfig::default())
            .unwrap();
        let cooldown = rng.gen_range(4);
        let cfg = CoordinatorConfig {
            cooldown_windows: 0,
            degrade_cooldown_windows: cooldown,
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(planner, rep, splits, &trace.layers[0], cfg);

        // Merged event stream: a couple of hard failures, a handful of gray
        // transitions, and one guaranteed-severe straggler to exercise the
        // escalation floor.
        let mut events = failure_schedule(n_gpus, windows, 1 + rng.gen_range(2) as usize, seed);
        events.extend(degradation_schedule(
            n_gpus,
            windows,
            2 + rng.gen_range(3) as usize,
            seed,
        ));
        events.push((
            2,
            ClusterEvent::GpuDegraded {
                gpu: n_gpus - 1,
                compute_scale: 0.1,
                bandwidth_scale: 1.0,
            },
        ));
        events.sort_by_key(|(w, _)| *w);

        let mut truth = DegradeState::new(n_gpus);
        let mut detector = DegradationDetector::new(n_gpus, DegradeConfig::default());
        let mut last_degrade_replans = 0u64;
        let mut last_commit_window: Option<usize> = None;

        for w in 0..windows {
            for (_, ev) in events.iter().filter(|(ew, _)| *ew == w) {
                truth.apply(ev);
                if ev.is_degradation() {
                    continue; // the coordinator must infer these
                }
                if matches!(ev, ClusterEvent::GpuFailed(g) if !coord.health().is_alive(*g)) {
                    continue; // escalation may have beaten the schedule to it
                }
                coord.inject_event(ev, &cluster);
            }

            // Synthetic detector input: truth × ±5% multiplicative noise.
            // Dead GPUs produce no timeline, so their ratios read 1.0 — the
            // same contract as WindowObservation::from_timelines' min_ms rule.
            let ts = truth.scales();
            let obs = WindowObservation {
                compute_ratio: (0..n_gpus)
                    .map(|g| {
                        if coord.health().is_alive(g) {
                            ts.compute[g] * multiplicative_noise(seed, w, g, 0.05)
                        } else {
                            1.0
                        }
                    })
                    .collect(),
                link_ratio: (0..n_gpus)
                    .map(|g| {
                        if coord.health().is_alive(g) {
                            ts.bandwidth[g] * multiplicative_noise(seed, w, n_gpus + g, 0.05)
                        } else {
                            1.0
                        }
                    })
                    .collect(),
            };
            let dev = detector.observe(&obs);
            let inferred = detector.scales();
            for g in 0..n_gpus {
                assert!(
                    inferred.compute[g] > 0.0 && inferred.compute[g] <= 1.0,
                    "seed {seed} window {w}: inferred compute scale {} of GPU {g}",
                    inferred.compute[g]
                );
                assert!(
                    inferred.bandwidth[g] > 0.0 && inferred.bandwidth[g] <= 1.0,
                    "seed {seed} window {w}: inferred bandwidth scale {} of GPU {g}",
                    inferred.bandwidth[g]
                );
            }
            coord.observe_degradation(&dev, &inferred, &cluster);
            coord.observe_window(&traffic, &cluster);

            // Flap damping: commits are at least cooldown+1 windows apart.
            if coord.stats.degrade_replans > last_degrade_replans {
                assert_eq!(
                    coord.stats.degrade_replans,
                    last_degrade_replans + 1,
                    "seed {seed} window {w}: one degrade commit per window"
                );
                if let Some(prev) = last_commit_window {
                    assert!(
                        w - prev > cooldown as usize,
                        "seed {seed}: degrade replans at windows {prev} and {w} inside the {cooldown}-window cooldown"
                    );
                }
                last_commit_window = Some(w);
                last_degrade_replans = coord.stats.degrade_replans;
            }

            coord.advance(1e9);

            // The installed plan is conservation-exact and never touches a
            // dead (failed or escalated) GPU.
            let (rep, splits) = coord.active();
            let health = coord.health();
            for m in 0..rep.n_models() {
                for (e, replica_gpus) in rep.replicas[m].iter().enumerate() {
                    let wts = &splits.weights[m][e];
                    assert_eq!(wts.len(), replica_gpus.len());
                    let sum: f64 = wts.iter().sum();
                    assert!(
                        (sum - 1.0).abs() < 1e-9,
                        "seed {seed} window {w}: splits of ({m},{e}) sum to {sum}"
                    );
                    for &g in replica_gpus {
                        assert!(
                            health.is_alive(g),
                            "seed {seed} window {w}: replica of ({m},{e}) on dead GPU {g}"
                        );
                    }
                }
            }
            let projected = rep.project_layer_split(0, &layer, splits);
            assert_eq!(
                dead_gpu_tokens(&projected.traffic, health.alive()),
                0,
                "seed {seed} window {w}: tokens routed through a dead GPU"
            );
        }

        // Bounded replans under flapping: the cooldown spacing caps the total.
        let max_commits = 1 + (windows as u64 - 1) / (cooldown + 1);
        assert!(
            coord.stats.degrade_replans <= max_commits,
            "seed {seed}: {} degrade replans exceed the cooldown bound {max_commits}",
            coord.stats.degrade_replans
        );
        // Escalations, when they fire, run the failure path end to end.
        assert!(coord.stats.failures >= coord.stats.escalations);
        assert_eq!(coord.stats.windows, windows as u64);
    }
}
