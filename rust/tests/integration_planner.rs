//! Integration: planner → simulator across all four scenarios, plus plan
//! serialization and the serving-side batching/routing pipeline (no PJRT).

use aurora::cluster::Cluster;
use aurora::config::EvalConfig;
use aurora::planner::{Planner, Scenario};
use aurora::schedule::SchedulePolicy;
use aurora::serve::{BatcherConfig, DynamicBatcher, Request, Router};
use aurora::sim::{simulate_colocated, simulate_exclusive};
use aurora::trace::{limoe_trace, trace_from_json, trace_to_json, Dataset, LimoeVariant};
use aurora::util::{Json, Rng};

fn traces() -> (aurora::trace::ModelTrace, aurora::trace::ModelTrace) {
    (
        limoe_trace(LimoeVariant::B16, Dataset::Coco, 8, 4, 48, 11),
        limoe_trace(LimoeVariant::B16, Dataset::Imagenet, 8, 4, 48, 12),
    )
}

#[test]
fn all_four_scenarios_plan_and_simulate() {
    let (a, b) = traces();
    let cfg = EvalConfig::default();
    let planner = Planner::default();

    for (cluster, expect_excl, expect_coloc) in [
        (
            cfg.homogeneous_cluster(),
            Scenario::ExclusiveHomogeneous,
            Scenario::ColocatedHomogeneous,
        ),
        (
            cfg.heterogeneous_cluster(),
            Scenario::ExclusiveHeterogeneous,
            Scenario::ColocatedHeterogeneous,
        ),
    ] {
        let excl = planner.plan_exclusive(&a, &cluster);
        assert_eq!(excl.scenario, expect_excl);
        for layer in excl.place_a(&a) {
            let (res, _) = simulate_exclusive(&layer, &cluster, excl.policy);
            assert!(res.inference_ms > 0.0);
            assert!(res.utilization > 0.0 && res.utilization <= 1.0);
        }

        let coloc = planner.plan_colocated(&a, &b, &cluster);
        assert_eq!(coloc.scenario, expect_coloc);
        let pa = coloc.place_a(&a);
        let pb = coloc.place_b(&b);
        for (la, lb) in pa.iter().zip(&pb) {
            let (res, t) = simulate_colocated(la, lb, &cluster, coloc.policy);
            assert!(res.inference_ms > 0.0);
            assert!(t.end >= t.e_a_b);
        }
    }
}

#[test]
fn plan_policy_flows_into_simulation() {
    let (a, _) = traces();
    let cluster = Cluster::homogeneous(8, 100.0);
    for policy in [
        SchedulePolicy::Aurora,
        SchedulePolicy::Sjf,
        SchedulePolicy::Rcs { seed: 5 },
    ] {
        let planner = Planner {
            policy,
            planning_layer: 0,
        };
        let plan = planner.plan_exclusive(&a, &cluster);
        assert_eq!(plan.policy, policy);
    }
}

#[test]
fn plan_json_contains_full_assignments() {
    let (a, b) = traces();
    let cluster = Cluster::paper_heterogeneous(8, 100.0);
    let plan = Planner::default().plan_colocated(&a, &b, &cluster);
    let j = plan.to_json();
    let text = j.to_string_compact();
    let back = Json::parse(&text).unwrap();
    assert_eq!(
        back.get("scenario").unwrap().as_str(),
        Some("colocating+heterogeneous")
    );
    assert_eq!(back.get("assignment_a").unwrap().as_arr().unwrap().len(), 8);
    assert_eq!(back.get("assignment_b").unwrap().as_arr().unwrap().len(), 8);
}

#[test]
fn trace_roundtrip_through_files() {
    let (a, _) = traces();
    let dir = std::env::temp_dir().join(format!("aurora-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    std::fs::write(&path, trace_to_json(&a).to_string_compact()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let back = trace_from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(a, back);
    std::fs::remove_dir_all(&dir).ok();
}

/// The serving pipeline's pure components compose: route → batch → conserve.
#[test]
fn serving_pipeline_conserves_requests_under_load() {
    let mut router = Router::new(3, aurora::serve::router::RoutePolicy::LeastLoaded);
    let mut batchers: Vec<DynamicBatcher> = (0..3)
        .map(|_| {
            DynamicBatcher::new(BatcherConfig {
                max_batch_tokens: 32,
                max_batch_requests: 8,
                max_wait: std::time::Duration::from_millis(1),
            })
        })
        .collect();
    let mut rng = Rng::new(123);
    let now = std::time::Instant::now();
    let mut delivered: Vec<u64> = Vec::new();
    for id in 0..500u64 {
        let n_tokens = rng.gen_range(8) as usize + 1;
        let req = Request::new(id, vec![0.1; n_tokens * 4], 4);
        let w = router.route(&req);
        if let Ok(Some(batch)) = batchers[w].push(req, now) {
            for r in &batch.requests {
                delivered.push(r.id);
                router.complete(w, r.n_tokens);
            }
        }
    }
    for (w, b) in batchers.iter_mut().enumerate() {
        if let Some(batch) = b.flush_all() {
            for r in &batch.requests {
                delivered.push(r.id);
                router.complete(w, r.n_tokens);
            }
        }
    }
    delivered.sort();
    assert_eq!(delivered, (0..500u64).collect::<Vec<_>>());
    assert!(router.load().iter().all(|&t| t == 0));
}

/// Scenario-specific sanity: heterogeneous plans use fast GPUs for heavy
/// experts even at reduced cluster scale (n = 4).
#[test]
fn small_cluster_plans_work() {
    let a = limoe_trace(LimoeVariant::B32, Dataset::Coco, 4, 2, 32, 3);
    let b = limoe_trace(LimoeVariant::B32, Dataset::Imagenet, 4, 2, 32, 4);
    let cluster = Cluster::paper_heterogeneous(4, 100.0);
    let plan = Planner::default().plan_colocated(&a, &b, &cluster);
    let pairing = plan.pairing().unwrap();
    assert_eq!(pairing.len(), 4);
    let (res, _) = simulate_colocated(
        &a.layers[0].placed(&plan.assignment_a),
        &b.layers[0].placed(plan.assignment_b.as_ref().unwrap()),
        &cluster,
        plan.policy,
    );
    assert!(res.inference_ms > 0.0);
}
