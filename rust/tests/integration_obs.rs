//! Observability integration tests: export round-trips, sim-time trace
//! determinism, and the tracing-on/off bit-for-bit property.

use aurora::cluster::{Cluster, Topology};
use aurora::coordinator::{run_online_traced, OnlineConfig, OnlineStrategy};
use aurora::eval::skewed_workload;
use aurora::obs::{parse_chrome_trace, run_profile, MetricsRegistry, ProfileConfig, Tracer};
use aurora::planner::{Planner, ReplicationConfig};
use aurora::schedule::{
    aurora_schedule, aurora_schedule_traced, hierarchical_schedule, hierarchical_schedule_traced,
};

const BW: f64 = 800.0;

/// A real planner run's trace survives the Chrome export → parse round trip
/// with the span tree, labels, and counters intact.
#[test]
fn chrome_export_round_trips_a_planner_trace() {
    let n = 32;
    let cluster = Cluster::homogeneous(n, BW);
    let topo = Topology::even_two_tier(n, 4, 4.0).expect("topology");
    let trace = skewed_workload(n, 2, 256, 1.2, 3);
    let tr = Tracer::wall();
    let planner = Planner::default();
    planner
        .plan_replicated_topology_traced(
            &[&trace],
            &cluster,
            &topo,
            &ReplicationConfig::default(),
            &tr,
        )
        .expect("plans");
    let spans = tr.spans();
    assert!(!spans.is_empty(), "planner run recorded no spans");
    assert!(
        spans.iter().any(|s| s.parent.is_some()),
        "expected nested phase spans"
    );
    let parsed = parse_chrome_trace(&tr.to_chrome_string()).expect("parses");
    assert_eq!(parsed, spans);
    // The JSONL export carries one record per span + decision.
    let lines = tr.to_jsonl().lines().count();
    assert_eq!(lines, spans.len() + tr.decisions().len());
}

/// Two seeded serve-sim runs under fresh sim-time tracers export
/// byte-identical trace files — the clock is the simulator's, not the wall's.
#[test]
fn seeded_serve_sim_traces_are_byte_identical() {
    let cfg = OnlineConfig::default();
    let cluster = Cluster::homogeneous(cfg.n_gpus, BW);
    let run = || {
        let tr = Tracer::sim();
        let metrics = MetricsRegistry::new();
        run_online_traced(&cfg, &cluster, OnlineStrategy::Coordinator, &tr, &metrics);
        (tr.to_chrome_string(), tr.to_jsonl(), metrics.snapshot().to_string_compact())
    };
    let (chrome_a, jsonl_a, metrics_a) = run();
    let (chrome_b, jsonl_b, metrics_b) = run();
    assert_eq!(chrome_a, chrome_b, "chrome traces differ between seeded runs");
    assert_eq!(jsonl_a, jsonl_b, "jsonl traces differ between seeded runs");
    assert_eq!(metrics_a, metrics_b, "metrics snapshots differ between seeded runs");
    // And the trace actually recorded the replan gate's reasoning.
    let parsed = parse_chrome_trace(&chrome_a).expect("parses");
    assert!(parsed.iter().any(|s| s.name == "serve.window"));
    let tr = Tracer::sim();
    let metrics = MetricsRegistry::new();
    run_online_traced(&cfg, &cluster, OnlineStrategy::Coordinator, &tr, &metrics);
    assert!(
        tr.decisions().iter().any(|d| d.kind == "coordinator.replan_gate"),
        "coordinator run emitted no replan-gate decisions"
    );
}

/// Tracing is purely observational: planning and scheduling with a live
/// tracer produce bit-for-bit the same outputs as with tracing off.
#[test]
fn tracing_on_or_off_is_bit_for_bit_identical() {
    let n = 64;
    let cluster = Cluster::homogeneous(n, BW);
    let topo = Topology::even_two_tier(n, 8, 4.0).expect("topology");
    let trace = skewed_workload(n, 2, 512, 1.2, 11);
    let planner = Planner::default();

    let plain = planner
        .plan_topology(&[&trace], &cluster, &topo)
        .expect("plans");
    let tr = Tracer::wall();
    let traced = planner
        .plan_topology_traced(&[&trace], &cluster, &topo, &tr)
        .expect("plans");
    assert_eq!(plain, traced);
    assert!(tr.is_enabled() && !tr.spans().is_empty());

    let cfg = ReplicationConfig::default();
    let (rep_plain, splits_plain) = planner
        .plan_replicated_topology(&[&trace], &cluster, &topo, &cfg)
        .expect("plans");
    let tr = Tracer::wall();
    let (rep_traced, splits_traced) = planner
        .plan_replicated_topology_traced(&[&trace], &cluster, &topo, &cfg, &tr)
        .expect("plans");
    assert_eq!(rep_plain, rep_traced);
    assert_eq!(splits_plain, splits_traced);

    let agg = rep_plain.aggregated_traffic_split(&[&trace.layers[0]], &splits_plain);
    let tr = Tracer::wall();
    assert_eq!(aurora_schedule(&agg), aurora_schedule_traced(&agg, &tr));
    let tr = Tracer::wall();
    assert_eq!(
        hierarchical_schedule(&agg, &cluster, &topo).expect("schedules"),
        hierarchical_schedule_traced(&agg, &cluster, &topo, &tr).expect("schedules")
    );
}

/// The profile driver emits a parsable Chrome trace and a non-empty phase
/// table for a plan + schedule run.
#[test]
fn profile_run_emits_a_valid_chrome_trace() {
    let cfg = ProfileConfig {
        gpus: 32,
        skew: 1.2,
        replicas: 2,
        seed: 42,
    };
    let report = run_profile(&cfg).expect("profiles");
    assert!(!report.phases.is_empty());
    assert!(report.schedule_ms > 0.0);
    assert!(
        report.phases.iter().any(|p| p.name.starts_with("planner.")),
        "no planner phases in {:?}",
        report.phases.iter().map(|p| &p.name).collect::<Vec<_>>()
    );
    let parsed = parse_chrome_trace(&report.tracer.to_chrome_string()).expect("parses");
    assert_eq!(parsed, report.tracer.spans());
    let table = report.render_table();
    assert!(table.contains("total"), "table header missing: {table}");
}
