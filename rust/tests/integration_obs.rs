//! Observability integration tests: export round-trips, sim-time trace
//! determinism, and the tracing-on/off bit-for-bit property.

use aurora::cluster::{Cluster, Topology};
use aurora::coordinator::{run_online_traced, OnlineConfig, OnlineStrategy};
use aurora::eval::skewed_workload;
use aurora::obs::{parse_chrome_trace, run_profile, MetricsRegistry, ProfileConfig, Tracer};
use aurora::planner::{Planner, ReplicationConfig};
use aurora::schedule::{
    aurora_schedule, aurora_schedule_traced, hierarchical_schedule, hierarchical_schedule_traced,
};

const BW: f64 = 800.0;

/// A real planner run's trace survives the Chrome export → parse round trip
/// with the span tree, labels, and counters intact.
#[test]
fn chrome_export_round_trips_a_planner_trace() {
    let n = 32;
    let cluster = Cluster::homogeneous(n, BW);
    let topo = Topology::even_two_tier(n, 4, 4.0).expect("topology");
    let trace = skewed_workload(n, 2, 256, 1.2, 3);
    let tr = Tracer::wall();
    let planner = Planner::default();
    planner
        .plan_replicated_topology_traced(
            &[&trace],
            &cluster,
            &topo,
            &ReplicationConfig::default(),
            &tr,
        )
        .expect("plans");
    let spans = tr.spans();
    assert!(!spans.is_empty(), "planner run recorded no spans");
    assert!(
        spans.iter().any(|s| s.parent.is_some()),
        "expected nested phase spans"
    );
    let parsed = parse_chrome_trace(&tr.to_chrome_string()).expect("parses");
    assert_eq!(parsed, spans);
    // The JSONL export carries one record per span + decision.
    let lines = tr.to_jsonl().lines().count();
    assert_eq!(lines, spans.len() + tr.decisions().len());
}

/// Two seeded serve-sim runs under fresh sim-time tracers export
/// byte-identical trace files — the clock is the simulator's, not the wall's.
#[test]
fn seeded_serve_sim_traces_are_byte_identical() {
    let cfg = OnlineConfig::default();
    let cluster = Cluster::homogeneous(cfg.n_gpus, BW);
    let run = || {
        let tr = Tracer::sim();
        let metrics = MetricsRegistry::new();
        run_online_traced(&cfg, &cluster, OnlineStrategy::Coordinator, &tr, &metrics);
        (tr.to_chrome_string(), tr.to_jsonl(), metrics.snapshot().to_string_compact())
    };
    let (chrome_a, jsonl_a, metrics_a) = run();
    let (chrome_b, jsonl_b, metrics_b) = run();
    assert_eq!(chrome_a, chrome_b, "chrome traces differ between seeded runs");
    assert_eq!(jsonl_a, jsonl_b, "jsonl traces differ between seeded runs");
    assert_eq!(metrics_a, metrics_b, "metrics snapshots differ between seeded runs");
    // And the trace actually recorded the replan gate's reasoning.
    let parsed = parse_chrome_trace(&chrome_a).expect("parses");
    assert!(parsed.iter().any(|s| s.name == "serve.window"));
    let tr = Tracer::sim();
    let metrics = MetricsRegistry::new();
    run_online_traced(&cfg, &cluster, OnlineStrategy::Coordinator, &tr, &metrics);
    assert!(
        tr.decisions().iter().any(|d| d.kind == "coordinator.replan_gate"),
        "coordinator run emitted no replan-gate decisions"
    );
}

/// Tracing is purely observational: planning and scheduling with a live
/// tracer produce bit-for-bit the same outputs as with tracing off.
#[test]
fn tracing_on_or_off_is_bit_for_bit_identical() {
    let n = 64;
    let cluster = Cluster::homogeneous(n, BW);
    let topo = Topology::even_two_tier(n, 8, 4.0).expect("topology");
    let trace = skewed_workload(n, 2, 512, 1.2, 11);
    let planner = Planner::default();

    let plain = planner
        .plan_topology(&[&trace], &cluster, &topo)
        .expect("plans");
    let tr = Tracer::wall();
    let traced = planner
        .plan_topology_traced(&[&trace], &cluster, &topo, &tr)
        .expect("plans");
    assert_eq!(plain, traced);
    assert!(tr.is_enabled() && !tr.spans().is_empty());

    let cfg = ReplicationConfig::default();
    let (rep_plain, splits_plain) = planner
        .plan_replicated_topology(&[&trace], &cluster, &topo, &cfg)
        .expect("plans");
    let tr = Tracer::wall();
    let (rep_traced, splits_traced) = planner
        .plan_replicated_topology_traced(&[&trace], &cluster, &topo, &cfg, &tr)
        .expect("plans");
    assert_eq!(rep_plain, rep_traced);
    assert_eq!(splits_plain, splits_traced);

    let agg = rep_plain.aggregated_traffic_split(&[&trace.layers[0]], &splits_plain);
    let tr = Tracer::wall();
    assert_eq!(aurora_schedule(&agg), aurora_schedule_traced(&agg, &tr));
    let tr = Tracer::wall();
    assert_eq!(
        hierarchical_schedule(&agg, &cluster, &topo).expect("schedules"),
        hierarchical_schedule_traced(&agg, &cluster, &topo, &tr).expect("schedules")
    );
}

/// The profile driver emits a parsable Chrome trace and a non-empty phase
/// table for a plan + schedule run.
#[test]
fn profile_run_emits_a_valid_chrome_trace() {
    let cfg = ProfileConfig {
        gpus: 32,
        skew: 1.2,
        replicas: 2,
        seed: 42,
    };
    let report = run_profile(&cfg).expect("profiles");
    assert!(!report.phases.is_empty());
    assert!(report.schedule_ms > 0.0);
    assert!(
        report.phases.iter().any(|p| p.name.starts_with("planner.")),
        "no planner phases in {:?}",
        report.phases.iter().map(|p| &p.name).collect::<Vec<_>>()
    );
    let parsed = parse_chrome_trace(&report.tracer.to_chrome_string()).expect("parses");
    assert_eq!(parsed, report.tracer.spans());
    let table = report.render_table();
    assert!(table.contains("total"), "table header missing: {table}");
}

/// An injected p99 violation drives the full SLO-watchdog path end to end:
/// serve-sim feeds window latencies into the coordinator's monitor, the
/// emergency override forces a replan, the decision log pins the verdict
/// with its SLO evidence, and the metrics registry counts the trigger.
#[test]
fn slo_violation_forces_replan_with_decision_evidence() {
    let mut cfg = OnlineConfig::default();
    // Unreachable target: every window latency violates the rolling p99.
    cfg.coordinator.slo_p99_ms = Some(1e-6);
    cfg.coordinator.cooldown_windows = 0;
    let cluster = Cluster::homogeneous(cfg.n_gpus, BW);
    let tr = Tracer::sim();
    let metrics = MetricsRegistry::new();
    let out = run_online_traced(&cfg, &cluster, OnlineStrategy::Coordinator, &tr, &metrics);
    assert!(out.replans >= 1, "SLO watchdog never forced a replan");
    let triggered: Vec<_> = tr
        .decisions()
        .iter()
        .filter(|d| {
            d.kind == "coordinator.replan_gate"
                && d.get("verdict").and_then(aurora::util::Json::as_str) == Some("slo_triggered")
        })
        .cloned()
        .collect();
    assert!(!triggered.is_empty(), "no slo_triggered decision was recorded");
    for d in &triggered {
        for field in ["slo_p99_ms", "slo_target_ms", "slo_burn_rate"] {
            assert!(
                d.get(field).is_some(),
                "slo_triggered decision lacks evidence field {field}"
            );
        }
        let p99 = d.get("slo_p99_ms").and_then(aurora::util::Json::as_f64).unwrap();
        let target = d.get("slo_target_ms").and_then(aurora::util::Json::as_f64).unwrap();
        assert!(p99 > target, "recorded p99 {p99} does not exceed target {target}");
    }
    let snapshot = metrics.snapshot().to_string_compact();
    assert!(
        snapshot.contains("serve.slo_triggered"),
        "metrics snapshot lacks the slo counter: {snapshot}"
    );
}

/// The same violating stream under an uncleared cooldown is suppressed, not
/// acted on: zero replans, and the log says why on every window.
#[test]
fn slo_violation_inside_cooldown_is_suppressed_not_replanned() {
    let mut cfg = OnlineConfig::default();
    cfg.coordinator.slo_p99_ms = Some(1e-6);
    cfg.coordinator.cooldown_windows = 10_000;
    let cluster = Cluster::homogeneous(cfg.n_gpus, BW);
    let tr = Tracer::sim();
    let out = run_online_traced(
        &cfg,
        &cluster,
        OnlineStrategy::Coordinator,
        &tr,
        &MetricsRegistry::disabled(),
    );
    assert_eq!(out.replans, 0, "cooldown must hold even under SLO pressure");
    assert!(
        tr.decisions().iter().any(|d| {
            d.get("verdict").and_then(aurora::util::Json::as_str)
                == Some("slo_suppressed_cooldown")
        }),
        "suppression left no slo_suppressed_cooldown decision"
    );
}

/// Timeline Chrome export round-trips through the trace parser with one
/// span per visible segment, and every track's spans are non-overlapping
/// and time-ordered (engines, uplinks, and downlinks each get a lane).
#[test]
fn timeline_chrome_export_round_trips_with_disjoint_tracks() {
    use aurora::obs::timeline::TimelineRecorder;
    use aurora::sim::simulate_colocated_recorded;
    use aurora::sim::MoeLayerStats;
    use aurora::traffic::zipf_traffic;

    let n = 8;
    let cluster = Cluster::homogeneous(n, BW);
    let layer = |seed| MoeLayerStats {
        traffic: zipf_traffic(n, 1024, 1.2, seed),
        gate_ms: 0.02,
        ffn_ms_per_token: 0.002,
        agg_ms: 0.015,
    };
    let mut rec = TimelineRecorder::new(n);
    simulate_colocated_recorded(
        &layer(1),
        &layer(2),
        &cluster,
        aurora::schedule::SchedulePolicy::Aurora,
        &mut rec,
    );
    let tl = rec.take().expect("recorder was enabled");

    let spans = tl.to_tracer().spans();
    assert!(!spans.is_empty(), "timeline export produced no spans");
    let parsed = parse_chrome_trace(&tl.to_chrome_string()).expect("parses");
    assert_eq!(parsed, spans, "chrome round trip changed the spans");

    // per-track ordering: spans on one lane never overlap
    let mut by_track: std::collections::BTreeMap<u32, Vec<(u64, u64)>> =
        std::collections::BTreeMap::new();
    for s in &spans {
        assert!(s.name.starts_with("timeline."), "unexpected span {}", s.name);
        by_track.entry(s.track).or_default().push((s.start_us, s.start_us + s.dur_us));
    }
    // 3 lanes per GPU: engine, uplink, downlink (links may be empty lanes)
    assert!(by_track.keys().all(|&t| (t as usize) < 3 * n));
    assert!(by_track.keys().any(|&t| (t as usize) < n), "no engine lane");
    assert!(by_track.keys().any(|&t| (t as usize) >= n), "no link lane");
    for (track, mut spans) in by_track {
        spans.sort();
        for w in spans.windows(2) {
            assert!(
                w[1].0 >= w[0].1,
                "track {track}: spans [{}, {}] and [{}, {}] overlap",
                w[0].0,
                w[0].1,
                w[1].0,
                w[1].1
            );
        }
    }
}
