//! Integration: the online coordinator.
//!
//! Acceptance contracts of the subsystem (all deterministic — fixed seeds,
//! exact or seeded-sampled workloads):
//!
//! 1. **Drift win** — under a drifting Zipf workload (skew rotating across
//!    experts every 8 windows), the coordinator's simulated end-to-end
//!    serving time beats the static initial plan by ≥ 1.15×.
//! 2. **Hysteresis** — under stationary uniform routing the coordinator
//!    never replans, and its serving times equal the static plan's exactly.
//! 3. **Migration conservation** — diffing two replicated plans yields
//!    weight flows that host every `(model, expert)` exactly per the target
//!    deployment after the swap, and the flow schedule passes
//!    `validate_slot_schedule`.

use aurora::cluster::Cluster;
use aurora::coordinator::{
    migration_preserves_target, plan_migration, run_online, run_online_traced, ClusterEvent,
    OnlineConfig, OnlineStrategy,
};
use aurora::obs::MetricsRegistry;
use aurora::Tracer;
use aurora::planner::{Planner, ReplicationConfig};
use aurora::schedule::validate_slot_schedule;
use aurora::sim::MoeLayerStats;
use aurora::trace::ModelTrace;
use aurora::traffic::drifting_zipf_traffic;

const N_GPUS: usize = 8;
const N_EXPERTS: usize = 16;
const TOKENS_PER_SENDER: u64 = 1024;
const SEED: u64 = 2024;

fn cluster() -> Cluster {
    Cluster::homogeneous(N_GPUS, 814.0)
}

fn online_cfg(alpha: f64, sampled: bool) -> OnlineConfig {
    OnlineConfig {
        n_gpus: N_GPUS,
        n_experts: N_EXPERTS,
        tokens_per_sender: TOKENS_PER_SENDER,
        alpha,
        windows: 32,
        rotate_every: 8,
        seed: SEED,
        sampled,
        ..OnlineConfig::default()
    }
}

fn phase_trace(alpha: f64, phase: usize) -> ModelTrace {
    ModelTrace {
        name: format!("phase-{phase}"),
        layers: vec![MoeLayerStats {
            traffic: drifting_zipf_traffic(N_EXPERTS, TOKENS_PER_SENDER, alpha, SEED, phase),
            gate_ms: 0.02,
            ffn_ms_per_token: 0.001,
            agg_ms: 0.015,
        }],
    }
}

/// Acceptance 1: the coordinator beats the static plan by ≥ 1.15× under a
/// rotating-hot-expert Zipf workload, deterministically.
#[test]
fn coordinator_beats_static_by_1_15x_under_drifting_zipf() {
    let cfg = online_cfg(1.2, false);
    let cluster = cluster();
    let stat = run_online(&cfg, &cluster, OnlineStrategy::Static);
    let coord = run_online(&cfg, &cluster, OnlineStrategy::Coordinator);

    assert!(coord.replans >= 1, "rotating hot expert must replan");
    assert!(coord.swaps >= 1, "staged plans must swap in");
    let speedup = stat.total_ms / coord.total_ms;
    assert!(
        speedup >= 1.15,
        "coordinator speedup {speedup:.3} (static {:.3} ms, coordinator {:.3} ms, {} replans)",
        stat.total_ms,
        coord.total_ms,
        coord.replans
    );

    // determinism: bit-for-bit reproducible
    let again = run_online(&cfg, &cluster, OnlineStrategy::Coordinator);
    assert_eq!(coord.per_window_ms, again.per_window_ms);
    assert_eq!(coord.replans, again.replans);
}

/// Acceptance 2: stationary uniform routing never replans — the hysteresis
/// gates hold and the coordinator's serving is bit-for-bit the static plan.
#[test]
fn stationary_uniform_never_replans() {
    let cfg = online_cfg(0.0, false);
    let cluster = cluster();
    let stat = run_online(&cfg, &cluster, OnlineStrategy::Static);
    let coord = run_online(&cfg, &cluster, OnlineStrategy::Coordinator);
    assert_eq!(coord.replans, 0, "uniform routing must never replan");
    assert_eq!(coord.swaps, 0);
    assert_eq!(coord.migration_ms, 0.0);
    assert_eq!(coord.per_window_ms, stat.per_window_ms);
}

/// The coordinator's gates also beat naive replan-every-window once live
/// batches fluctuate: the naive strategy chases sampling noise and pays a
/// weight migration for nearly every window.
#[test]
fn coordinator_beats_naive_replan_every_window_under_noise() {
    let cfg = online_cfg(1.2, true);
    let cluster = cluster();
    let naive = run_online(&cfg, &cluster, OnlineStrategy::EveryWindow);
    let coord = run_online(&cfg, &cluster, OnlineStrategy::Coordinator);
    assert!(
        coord.total_ms < naive.total_ms,
        "coordinator {:.3} ms vs naive {:.3} ms (naive {} replans, coordinator {})",
        coord.total_ms,
        naive.total_ms,
        naive.replans,
        coord.replans
    );
    assert!(
        coord.replans <= naive.replans,
        "the gates must suppress churn: coordinator {} vs naive {}",
        coord.replans,
        naive.replans
    );
}

/// Acceptance 3: migration flows conserve expert weights — after applying
/// the flows (and frees), every `(model, expert)` is hosted exactly per the
/// target deployment — and the weight schedule is contention-free, exact,
/// and optimal.
#[test]
fn migration_flows_conserve_and_schedules_validate() {
    let cluster = cluster();
    let planner = Planner::default();
    let rep_cfg = ReplicationConfig::default();

    let cur_trace = phase_trace(1.2, 0);
    let tgt_trace = phase_trace(1.2, 2);
    let (cur, _) = planner.plan_replicated(&[&cur_trace], &cluster, &rep_cfg).unwrap();
    let (tgt, _) = planner.plan_replicated(&[&tgt_trace], &cluster, &rep_cfg).unwrap();
    assert_ne!(cur, tgt, "rotated hot expert must change the plan");

    let plan = plan_migration(&cur, &tgt, 4096);
    assert!(!plan.is_empty(), "different plans need weight movement");
    assert!(
        migration_preserves_target(&cur, &tgt, &plan),
        "flows + frees must reproduce the target hosting exactly"
    );
    for f in &plan.flows {
        assert!(
            cur.replicas[f.model][f.expert].contains(&f.src),
            "flow source must hold a current copy: {f:?}"
        );
        assert!(
            tgt.replicas[f.model][f.expert].contains(&f.dst),
            "flow destination must host per the target: {f:?}"
        );
        assert_eq!(f.tokens, 4096);
        assert_ne!(f.src, f.dst);
    }
    // the aggregated weight traffic is exactly the flows
    assert_eq!(
        plan.traffic.total(),
        4096 * plan.flows.len() as u64,
        "all weight tokens are off-diagonal wire traffic"
    );
    // slot-scheduled over the same links, machine-checked
    validate_slot_schedule(&plan.traffic, &plan.schedule).unwrap();
    assert_eq!(plan.makespan_tokens(), plan.traffic.b_max_tokens());
    assert!(plan.migration_ms(&cluster) > 0.0);

    // self-diff is empty
    assert!(plan_migration(&cur, &cur, 4096).is_empty());
}

/// The oracle (free, clairvoyant replanning) floors the static plan on the
/// exact drifting workload, and tracks every rotation.
#[test]
fn oracle_floors_the_static_plan() {
    let cfg = online_cfg(1.2, false);
    let cluster = cluster();
    let stat = run_online(&cfg, &cluster, OnlineStrategy::Static);
    let oracle = run_online(&cfg, &cluster, OnlineStrategy::Oracle);
    assert!(
        oracle.total_ms <= stat.total_ms + 1e-9,
        "oracle {:.3} vs static {:.3}",
        oracle.total_ms,
        stat.total_ms
    );
    // one plan change per rotation (phases 1..3), none inside a phase
    assert_eq!(oracle.replans, 3, "exact workload: adapt exactly per phase");
}

/// The `online` eval figure runs end to end with the expected rows.
#[test]
fn online_figure_runs() {
    use aurora::config::EvalConfig;
    use aurora::eval::run_figure;
    let cfg = EvalConfig {
        n_experts: 4,
        batch_images: 128,
        ..EvalConfig::default()
    };
    let reports = run_figure("online", &cfg).unwrap();
    assert_eq!(reports.len(), 1);
    let r = &reports[0];
    assert_eq!(r.rows.len(), 4);
    let vs_static = r.column("vs static").unwrap();
    assert!((vs_static[0] - 1.0).abs() < 1e-9, "{vs_static:?}");
    // the coordinator row must not lose to the static plan
    assert!(vs_static[2] >= 1.0, "{vs_static:?}");
}

/// Acceptance 4 (fault tolerance): a mid-trace GPU failure is survived by
/// promoting the dead GPU's replicas in the *same window* the failure lands
/// (verdict `repair_promoted`), and a full repair replan commits right
/// behind it under the cooldown rules (verdict `repair_replanned`). The
/// serving simulator asserts internally that no window ever routes a token
/// through the dead GPU, so completing the run *is* the routing check.
#[test]
fn gpu_failure_promotes_in_window_and_repairs_under_cooldown() {
    let mut cfg = online_cfg(1.2, false);
    cfg.rotate_every = cfg.windows; // stationary: the failure is the only disturbance
    cfg.events = vec![(5, ClusterEvent::GpuFailed(2))];
    // default cooldown (2 windows) stays armed: the last replan is the
    // initial plan, so the repair is eligible in the failure window itself

    let tr = Tracer::sim();
    let out = run_online_traced(
        &cfg,
        &cluster(),
        OnlineStrategy::Coordinator,
        &tr,
        &MetricsRegistry::disabled(),
    );
    assert!(out.replans >= 1, "the repair must commit");
    assert!(out.per_window_ms.iter().all(|ms| ms.is_finite()));

    let decisions = tr.decisions();
    let verdict = |d: &aurora::obs::DecisionRecord| {
        d.get("verdict").and_then(|v| v.as_str().map(String::from))
    };
    let promoted = decisions
        .iter()
        .position(|d| verdict(d).as_deref() == Some("repair_promoted"))
        .expect("the failure must emit repair_promoted");
    let replanned = decisions
        .iter()
        .position(|d| verdict(d).as_deref() == Some("repair_replanned"))
        .expect("the repair must emit repair_replanned");
    assert!(
        promoted < replanned,
        "promotion (stopgap) precedes the repair replan"
    );
    // promotion happens at injection, before the failure window is observed:
    // its window stamp is exactly the count of fully observed windows
    let promoted_w = decisions[promoted].get("window").unwrap().as_f64().unwrap();
    assert_eq!(promoted_w, 5.0, "promotion lands in the failure window");
    // cooldown rules: the last replan was windows ago, so the repair is not
    // deferred — it commits in the failure window's own observation
    let replanned_w = decisions[replanned].get("window").unwrap().as_f64().unwrap();
    assert_eq!(replanned_w, 6.0, "repair commits at the failure window's observe");

    // deterministic
    let tr2 = Tracer::sim();
    let again = run_online_traced(
        &cfg,
        &cluster(),
        OnlineStrategy::Coordinator,
        &tr2,
        &MetricsRegistry::disabled(),
    );
    assert_eq!(out.per_window_ms, again.per_window_ms);
}

/// Acceptance 5 (recovery win condition): after the failure, the
/// coordinator's serving latency recovers to within 1.15× of a fresh-plan
/// oracle (replans on the masked cluster every window at zero cost) within
/// 5 windows of the failure.
#[test]
fn failure_recovery_lands_within_1_15x_of_the_masked_oracle() {
    let mut cfg = online_cfg(1.2, false);
    cfg.rotate_every = cfg.windows;
    cfg.events = vec![(5, ClusterEvent::GpuFailed(2))];
    cfg.coordinator.cooldown_windows = 0;
    let cluster = cluster();

    let coord = run_online(&cfg, &cluster, OnlineStrategy::Coordinator);
    let oracle = run_online(&cfg, &cluster, OnlineStrategy::Oracle);
    let recovery = (5..10)
        .map(|w| coord.per_window_ms[w] / oracle.per_window_ms[w])
        .fold(f64::INFINITY, f64::min);
    assert!(
        recovery <= 1.15,
        "recovery ratio {recovery:.3} (coordinator {:?}, oracle {:?})",
        &coord.per_window_ms[5..10],
        &oracle.per_window_ms[5..10]
    );
    // and the recovered steady state holds to the end of the run
    let last = cfg.windows - 1;
    let steady = coord.per_window_ms[last] / oracle.per_window_ms[last];
    assert!(steady <= 1.15, "steady-state ratio {steady:.3}");
}

/// A drain vacates the GPU over the migration path while it stays alive,
/// and a later rejoin rebalances back: every strategy completes, and the
/// coordinator ends the round trip with all GPUs placeable.
#[test]
fn drain_then_rejoin_round_trip_completes_for_every_strategy() {
    let mut cfg = online_cfg(1.2, false);
    cfg.events = vec![
        (4, ClusterEvent::GpuDrained(1)),
        (20, ClusterEvent::GpuJoined(1)),
    ];
    cfg.coordinator.cooldown_windows = 0;
    let cluster = cluster();
    for strategy in [
        OnlineStrategy::Static,
        OnlineStrategy::EveryWindow,
        OnlineStrategy::Coordinator,
        OnlineStrategy::Oracle,
    ] {
        let out = run_online(&cfg, &cluster, strategy);
        assert!(
            out.per_window_ms.iter().all(|ms| ms.is_finite() && *ms > 0.0),
            "{strategy:?} must serve every window"
        );
    }
}

/// The `resilience` eval figure runs end to end and pins the win condition
/// from the figure side: static/coordinator/oracle rows, coordinator
/// recovery ≤ 1.15× of the oracle.
#[test]
fn resilience_figure_runs() {
    use aurora::config::EvalConfig;
    use aurora::eval::run_figure;
    let cfg = EvalConfig {
        n_experts: 4,
        batch_images: 128,
        ..EvalConfig::default()
    };
    let reports = run_figure("resilience", &cfg).unwrap();
    assert_eq!(reports.len(), 1);
    let r = &reports[0];
    assert_eq!(r.rows.len(), 3);
    let recovery = r.column("recovery vs oracle").unwrap();
    assert!(recovery[1] <= 1.15, "{recovery:?}");
    let replans = r.column("replans").unwrap();
    assert!(replans[1] >= 1.0, "{replans:?}");
}
