//! The Aurora planner: scenario detection → colocation → assignment →
//! schedule, producing a [`DeploymentPlan`] (the paper's Fig. 2 decision
//! tree).
//!
//! Planning is offline and statistics-driven (§2.4): the planner consumes
//! [`ModelTrace`]s (historical per-layer traffic + compute times) and a
//! [`Cluster`], and emits expert→GPU assignments for one or two models plus
//! the communication policy. The serving layer and the simulator both
//! consume the same plan.

use crate::assignment::sorted_assignment;
use crate::cluster::Cluster;
use crate::colocation::hetero::decoupled_solution;
use crate::colocation::{case2_pairing, send_recv_volumes};
use crate::schedule::SchedulePolicy;
use crate::sim::MoeLayerStats;
use crate::trace::ModelTrace;
use crate::util::Json;

/// The four GPU-cluster settings of Fig. 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// One model, identical GPUs (§4). Optimal.
    ExclusiveHomogeneous,
    /// One model, mixed GPUs (§5). Optimal.
    ExclusiveHeterogeneous,
    /// Two models share GPUs, identical GPUs (§6). Optimal.
    ColocatedHomogeneous,
    /// Two models share GPUs, mixed GPUs (§7). NP-hard; 1.07× heuristic.
    ColocatedHeterogeneous,
}

impl Scenario {
    /// Scenario for a model count and cluster.
    pub fn detect(n_models: usize, cluster: &Cluster) -> Scenario {
        match (n_models, cluster.is_homogeneous()) {
            (1, true) => Scenario::ExclusiveHomogeneous,
            (1, false) => Scenario::ExclusiveHeterogeneous,
            (2, true) => Scenario::ColocatedHomogeneous,
            (2, false) => Scenario::ColocatedHeterogeneous,
            (n, _) => panic!("Aurora colocates at most two models per GPU (§2.4), got {n}"),
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::ExclusiveHomogeneous => "exclusive+homogeneous",
            Scenario::ExclusiveHeterogeneous => "exclusive+heterogeneous",
            Scenario::ColocatedHomogeneous => "colocating+homogeneous",
            Scenario::ColocatedHeterogeneous => "colocating+heterogeneous",
        }
    }
}

/// A complete deployment decision: who goes where, and in what order tokens
/// move.
#[derive(Debug, Clone, PartialEq)]
pub struct DeploymentPlan {
    /// Which of the four scenarios this plan was made for.
    pub scenario: Scenario,
    /// `assignment_a[e]` = GPU hosting Model a's expert `e`.
    pub assignment_a: Vec<usize>,
    /// Model b's assignment when colocating (same GPU ↔ colocated pair).
    pub assignment_b: Option<Vec<usize>>,
    /// Communication scheduling policy.
    pub policy: SchedulePolicy,
}

impl DeploymentPlan {
    /// Model a's layer stats relabelled onto GPUs.
    pub fn place_a(&self, trace: &ModelTrace) -> Vec<MoeLayerStats> {
        trace
            .layers
            .iter()
            .map(|l| l.placed(&self.assignment_a))
            .collect()
    }

    /// Model b's layer stats relabelled onto GPUs. Panics on exclusive plans.
    pub fn place_b(&self, trace: &ModelTrace) -> Vec<MoeLayerStats> {
        let b = self
            .assignment_b
            .as_ref()
            .expect("plan has no second model");
        trace.layers.iter().map(|l| l.placed(b)).collect()
    }

    /// The colocation pairing implied by the two assignments:
    /// `pairing[i]` = b-expert sharing a GPU with a-expert `i`.
    pub fn pairing(&self) -> Option<Vec<usize>> {
        let b = self.assignment_b.as_ref()?;
        let n = self.assignment_a.len();
        let mut gpu_to_b = vec![usize::MAX; n];
        for (e, &g) in b.iter().enumerate() {
            gpu_to_b[g] = e;
        }
        Some(
            self.assignment_a
                .iter()
                .map(|&g| gpu_to_b[g])
                .collect(),
        )
    }

    /// JSON rendering (for the CLI and for plan files consumed by serving).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("scenario", Json::from(self.scenario.name())),
            ("policy", Json::from(self.policy.name())),
            (
                "assignment_a",
                Json::Arr(self.assignment_a.iter().map(|&g| Json::from(g)).collect()),
            ),
        ];
        if let Some(b) = &self.assignment_b {
            fields.push((
                "assignment_b",
                Json::Arr(b.iter().map(|&g| Json::from(g)).collect()),
            ));
        }
        Json::obj(fields)
    }
}

/// Aurora's planner. `planning_layer` selects which layer's statistics drive
/// colocation (the paper plans on layer 1 and studies robustness to the
/// other layers in Fig. 14).
#[derive(Debug, Clone)]
pub struct Planner {
    /// Communication policy to embed in plans (Aurora by default; baselines
    /// use Sjf/Rcs for comparison figures).
    pub policy: SchedulePolicy,
    /// Index of the layer whose traffic drives colocation decisions.
    pub planning_layer: usize,
}

impl Default for Planner {
    fn default() -> Self {
        Self {
            policy: SchedulePolicy::Aurora,
            planning_layer: 0,
        }
    }
}

impl Planner {
    /// Plan one model running exclusively on `cluster`.
    ///
    /// Homogeneous: the identity assignment (observation 1: no placement
    /// decision matters). Heterogeneous: Theorem 5.1's sorted assignment on
    /// the trace's aggregate expert loads.
    pub fn plan_exclusive(&self, trace: &ModelTrace, cluster: &Cluster) -> DeploymentPlan {
        let scenario = Scenario::detect(1, cluster);
        let assignment_a = match scenario {
            Scenario::ExclusiveHomogeneous => (0..trace.n_experts()).collect(),
            _ => sorted_assignment(&trace.total_expert_loads(), cluster),
        };
        DeploymentPlan {
            scenario,
            assignment_a,
            assignment_b: None,
            policy: self.policy,
        }
    }

    /// Like [`Planner::plan_exclusive`], but optimized for a single layer's
    /// statistics (used when per-layer deployment is being studied, e.g. the
    /// precise-input figures of §8.2).
    pub fn plan_exclusive_layer(
        &self,
        trace: &ModelTrace,
        layer: usize,
        cluster: &Cluster,
    ) -> DeploymentPlan {
        let scenario = Scenario::detect(1, cluster);
        let assignment_a = match scenario {
            Scenario::ExclusiveHomogeneous => (0..trace.n_experts()).collect(),
            _ => sorted_assignment(&trace.layers[layer].expert_loads(), cluster),
        };
        DeploymentPlan {
            scenario,
            assignment_a,
            assignment_b: None,
            policy: self.policy,
        }
    }

    /// Plan two models colocated on `cluster`.
    ///
    /// Homogeneous (§6): Case II bottleneck matching on the planning layer's
    /// traffic; pairs stay on Model a's GPU indices.
    /// Heterogeneous (§7.2): decoupled two-stage matching with a per-GPU
    /// completion-estimate cost.
    pub fn plan_colocated(
        &self,
        a: &ModelTrace,
        b: &ModelTrace,
        cluster: &Cluster,
    ) -> DeploymentPlan {
        let scenario = Scenario::detect(2, cluster);
        let n = a.n_experts();
        assert_eq!(n, b.n_experts(), "colocated models need equal expert counts (§6 fn3)");
        assert_eq!(n, cluster.len(), "one expert pair per GPU");
        let la = &a.layers[self.planning_layer.min(a.layers.len() - 1)];
        let lb = &b.layers[self.planning_layer.min(b.layers.len() - 1)];

        match scenario {
            Scenario::ColocatedHomogeneous => {
                let (_, pairing) = case2_pairing(&la.traffic, &lb.traffic);
                // a-expert i on GPU i; b-expert pairing[i] joins it.
                let mut assignment_b = vec![0usize; n];
                for (i, &j) in pairing.iter().enumerate() {
                    assignment_b[j] = i;
                }
                DeploymentPlan {
                    scenario,
                    assignment_a: (0..n).collect(),
                    assignment_b: Some(assignment_b),
                    policy: self.policy,
                }
            }
            Scenario::ColocatedHeterogeneous => {
                let cost = pair_gpu_cost(la, lb, cluster);
                let sol = decoupled_solution(&la.traffic, &lb.traffic, n, cost);
                let mut assignment_b = vec![0usize; n];
                for (i, &j) in sol.pairing.iter().enumerate() {
                    assignment_b[j] = sol.assignment[i];
                }
                DeploymentPlan {
                    scenario,
                    assignment_a: sol.assignment,
                    assignment_b: Some(assignment_b),
                    policy: self.policy,
                }
            }
            _ => unreachable!("detect(2, _) returns colocated scenarios"),
        }
    }
}

/// Per-GPU completion estimate for colocating a-expert `i` and b-expert `j`
/// on GPU `g` — the edge weight of the stage-2 matching (§7.2): serialized
/// compute of both experts plus the pair's worst-direction wire time.
pub fn pair_gpu_cost<'s>(
    la: &'s MoeLayerStats,
    lb: &'s MoeLayerStats,
    cluster: &'s Cluster,
) -> impl Fn(usize, usize, usize) -> f64 + 's {
    let loads_a = la.expert_loads();
    let loads_b = lb.expert_loads();
    let (a_send, a_recv) = send_recv_volumes(&la.traffic);
    let (b_send, b_recv) = send_recv_volumes(&lb.traffic);
    move |i: usize, j: usize, g: usize| {
        let gpu = cluster.gpu(g);
        let compute = (la.gate_ms
            + lb.gate_ms
            + la.agg_ms
            + lb.agg_ms
            + loads_a[i] as f64 * la.ffn_ms_per_token
            + loads_b[j] as f64 * lb.ffn_ms_per_token)
            / gpu.flops_scale;
        let wire = (a_send[i] + b_send[j]).max(a_recv[i] + b_recv[j]) as f64 / gpu.bandwidth;
        compute + wire
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate_colocated, simulate_exclusive};
    use crate::trace::{limoe_trace, Dataset, LimoeVariant};
    use crate::util::Rng;

    fn traces() -> (ModelTrace, ModelTrace) {
        (
            limoe_trace(LimoeVariant::B16, Dataset::Coco, 8, 4, 32, 1),
            limoe_trace(LimoeVariant::B32, Dataset::Imagenet, 8, 4, 128, 2),
        )
    }

    #[test]
    fn scenario_detection() {
        let homo = Cluster::homogeneous(8, 1.0);
        let het = Cluster::paper_heterogeneous(8, 1.0);
        assert_eq!(Scenario::detect(1, &homo), Scenario::ExclusiveHomogeneous);
        assert_eq!(Scenario::detect(1, &het), Scenario::ExclusiveHeterogeneous);
        assert_eq!(Scenario::detect(2, &homo), Scenario::ColocatedHomogeneous);
        assert_eq!(Scenario::detect(2, &het), Scenario::ColocatedHeterogeneous);
    }

    #[test]
    #[should_panic]
    fn three_models_rejected() {
        Scenario::detect(3, &Cluster::homogeneous(8, 1.0));
    }

    #[test]
    fn exclusive_homo_plan_is_identity() {
        let (a, _) = traces();
        let plan = Planner::default().plan_exclusive(&a, &Cluster::homogeneous(8, 1.0));
        assert_eq!(plan.assignment_a, (0..8).collect::<Vec<_>>());
        assert!(plan.assignment_b.is_none());
    }

    #[test]
    fn exclusive_hetero_puts_heavy_experts_on_fast_gpus() {
        let (a, _) = traces();
        let cluster = Cluster::paper_heterogeneous(8, 1.0);
        let plan = Planner::default().plan_exclusive(&a, &cluster);
        let loads = a.total_expert_loads();
        let heaviest = (0..8).max_by_key(|&e| loads[e]).unwrap();
        let lightest = (0..8).min_by_key(|&e| loads[e]).unwrap();
        let bw = cluster.bandwidths();
        assert!(bw[plan.assignment_a[heaviest]] >= bw[plan.assignment_a[lightest]]);
    }

    #[test]
    fn colocated_plan_pairs_each_gpu_once() {
        let (a, b) = traces();
        for cluster in [
            Cluster::homogeneous(8, 1.0),
            Cluster::paper_heterogeneous(8, 1.0),
        ] {
            let plan = Planner::default().plan_colocated(&a, &b, &cluster);
            let pb = plan.assignment_b.clone().unwrap();
            let mut seen_a = vec![false; 8];
            let mut seen_b = vec![false; 8];
            for e in 0..8 {
                assert!(!seen_a[plan.assignment_a[e]]);
                seen_a[plan.assignment_a[e]] = true;
                assert!(!seen_b[pb[e]]);
                seen_b[pb[e]] = true;
            }
            let pairing = plan.pairing().unwrap();
            let mut seen_p = vec![false; 8];
            for &j in &pairing {
                assert!(!seen_p[j]);
                seen_p[j] = true;
            }
        }
    }

    #[test]
    fn plan_end_to_end_beats_random_plans_colocated_homo() {
        let (a, b) = traces();
        let cluster = Cluster::homogeneous(8, 10.0);
        let plan = Planner::default().plan_colocated(&a, &b, &cluster);
        let t_plan: f64 = plan
            .place_a(&a)
            .iter()
            .zip(plan.place_b(&b))
            .map(|(sa, sb)| {
                simulate_colocated(sa, &sb, &cluster, plan.policy)
                    .0
                    .inference_ms
            })
            .sum();
        let mut rng = Rng::new(0xF00D);
        for _ in 0..10 {
            let p = rng.permutation(8);
            let t_rand: f64 = a
                .layers
                .iter()
                .zip(&b.layers)
                .map(|(sa, sb)| {
                    simulate_colocated(sa, &sb.placed(&p), &cluster, SchedulePolicy::Aurora)
                        .0
                        .inference_ms
                })
                .sum();
            // planned on layer 0 only while layers 1-3 route differently, so
            // allow slack across the 4-layer sum; layer-0 optimality itself
            // is asserted exactly in eval::fig11 tests
            assert!(
                t_plan <= t_rand * 1.15,
                "planned {t_plan} vs random {t_rand}"
            );
        }
    }

    #[test]
    fn exclusive_hetero_plan_beats_random_end_to_end() {
        let (a, _) = traces();
        let cluster = Cluster::paper_heterogeneous(8, 10.0);
        let plan = Planner::default().plan_exclusive(&a, &cluster);
        let t_plan: f64 = plan
            .place_a(&a)
            .iter()
            .map(|l| simulate_exclusive(l, &cluster, plan.policy).0.inference_ms)
            .sum();
        let mut rng = Rng::new(0xBEE);
        for _ in 0..20 {
            let p = rng.permutation(8);
            let t_rand: f64 = a
                .layers
                .iter()
                .map(|l| {
                    simulate_exclusive(&l.placed(&p), &cluster, SchedulePolicy::Aurora)
                        .0
                        .inference_ms
                })
                .sum();
            assert!(t_plan <= t_rand + 1e-9);
        }
    }

    #[test]
    fn plan_json_renders() {
        let (a, b) = traces();
        let plan = Planner::default().plan_colocated(&a, &b, &Cluster::homogeneous(8, 1.0));
        let j = plan.to_json();
        assert_eq!(
            j.get("scenario").unwrap().as_str(),
            Some("colocating+homogeneous")
        );
        assert_eq!(j.get("assignment_b").unwrap().as_arr().unwrap().len(), 8);
    }
}
