//! The Aurora planner: scenario detection → colocation → assignment →
//! schedule, producing a generalized [`Deployment`] (and, for the paper's
//! one/two-model shapes, the [`DeploymentPlan`] view of it).
//!
//! Planning is offline and statistics-driven (§2.4): the planner consumes
//! [`ModelTrace`]s (historical per-layer traffic + compute times) and a
//! [`Cluster`], and emits expert→GPU assignments plus the communication
//! policy. The serving layer and the simulator both consume the same plan.
//!
//! [`Planner::plan_multi`] is the general entry point. It routes through the
//! extended Fig. 2 decision tree ([`Scenario::detect`]):
//!
//! * M = 1 or M = 2 with one expert per GPU → the paper's exact/near-exact
//!   paths ([`Planner::plan_exclusive`], [`Planner::plan_colocated`]), so the
//!   optimality theorems keep holding;
//! * anything else (M ≥ 3, multiple experts per GPU, expert count ≠ cluster
//!   size) → iterative pairwise bottleneck matching (stacking §6.2's Case II
//!   against the running aggregate) or a greedy load-balanced generalization
//!   of Theorem 5.1, followed by swap/move local search on the per-GPU
//!   completion estimate of §7.2.

use crate::assignment::sorted_assignment;
use crate::cluster::{Cluster, Topology};
use crate::colocation::hetero::decoupled_solution;
use crate::colocation::{case2_pairing, send_recv_volumes};
use crate::obs::Tracer;
use crate::placement::{DeltaEstimator, Deployment};
use crate::replication::{
    estimate_objective_on, optimize_splits, refine_replicated, ReplicaDeltaEstimator,
    ReplicatedDeployment, SplitPlan,
};
use crate::schedule::SchedulePolicy;
use crate::sim::MoeLayerStats;
use crate::trace::{aggregate_totals, ModelTrace};
use crate::util::par::par_map;
use crate::util::Json;
use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, HashMap};

pub use crate::placement::{PlacementError, Scenario};

/// The paper's one/two-model deployment decision — now a thin view over the
/// generalized [`Deployment`], kept because the figure-reproduction harness
/// and the Fig. 2 parity tests speak in `assignment_a`/`assignment_b` terms.
#[derive(Debug, Clone, PartialEq)]
pub struct DeploymentPlan {
    /// Which of the decision-tree scenarios this plan was made for.
    pub scenario: Scenario,
    /// `assignment_a[e]` = GPU hosting Model a's expert `e`.
    pub assignment_a: Vec<usize>,
    /// Model b's assignment when colocating (same GPU ↔ colocated pair).
    pub assignment_b: Option<Vec<usize>>,
    /// Communication scheduling policy.
    pub policy: SchedulePolicy,
}

impl DeploymentPlan {
    /// The generalized placement this plan denotes.
    pub fn to_deployment(&self) -> Deployment {
        let mut assignments = vec![self.assignment_a.clone()];
        if let Some(b) = &self.assignment_b {
            assignments.push(b.clone());
        }
        Deployment::new(self.assignment_a.len(), assignments, self.policy, self.scenario)
            .expect("a DeploymentPlan is a valid one/two-model deployment")
    }

    /// Model a's layer stats relabelled onto GPUs (projected through the
    /// generalized deployment; identical to a permutation here because plans
    /// place exactly one expert per GPU).
    pub fn place_a(&self, trace: &ModelTrace) -> Vec<MoeLayerStats> {
        let dep = self.to_deployment();
        trace
            .layers
            .iter()
            .map(|l| dep.project_layer(0, l))
            .collect()
    }

    /// Model b's layer stats relabelled onto GPUs. Panics on exclusive plans.
    pub fn place_b(&self, trace: &ModelTrace) -> Vec<MoeLayerStats> {
        assert!(self.assignment_b.is_some(), "plan has no second model");
        let dep = self.to_deployment();
        trace
            .layers
            .iter()
            .map(|l| dep.project_layer(1, l))
            .collect()
    }

    /// The colocation pairing implied by the two assignments:
    /// `pairing[i]` = b-expert sharing a GPU with a-expert `i`.
    pub fn pairing(&self) -> Option<Vec<usize>> {
        let b = self.assignment_b.as_ref()?;
        let n = self.assignment_a.len();
        let mut gpu_to_b = vec![usize::MAX; n];
        for (e, &g) in b.iter().enumerate() {
            gpu_to_b[g] = e;
        }
        Some(
            self.assignment_a
                .iter()
                .map(|&g| gpu_to_b[g])
                .collect(),
        )
    }

    /// JSON rendering (for the CLI and for plan files consumed by serving).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("scenario", Json::from(self.scenario.name())),
            ("policy", Json::from(self.policy.name())),
            (
                "assignment_a",
                Json::Arr(self.assignment_a.iter().map(|&g| Json::from(g)).collect()),
            ),
        ];
        if let Some(b) = &self.assignment_b {
            fields.push((
                "assignment_b",
                Json::Arr(b.iter().map(|&g| Json::from(g)).collect()),
            ));
        }
        Json::obj(fields)
    }
}

/// Aurora's planner. `planning_layer` selects which layer's statistics drive
/// colocation (the paper plans on layer 1 and studies robustness to the
/// other layers in Fig. 14).
#[derive(Debug, Clone)]
pub struct Planner {
    /// Communication policy to embed in plans (Aurora by default; baselines
    /// use Sjf/Rcs for comparison figures).
    pub policy: SchedulePolicy,
    /// Index of the layer whose traffic drives colocation decisions.
    pub planning_layer: usize,
}

impl Default for Planner {
    fn default() -> Self {
        Self {
            policy: SchedulePolicy::Aurora,
            planning_layer: 0,
        }
    }
}

impl Planner {
    /// Plan one model running exclusively on `cluster`.
    ///
    /// Homogeneous: the identity assignment (observation 1: no placement
    /// decision matters). Heterogeneous: Theorem 5.1's sorted assignment on
    /// the trace's aggregate expert loads.
    pub fn plan_exclusive(&self, trace: &ModelTrace, cluster: &Cluster) -> DeploymentPlan {
        let scenario = Scenario::detect(1, cluster).expect("one model always detects");
        let assignment_a = match scenario {
            Scenario::ExclusiveHomogeneous => (0..trace.n_experts()).collect(),
            _ => sorted_assignment(&trace.total_expert_loads(), cluster),
        };
        DeploymentPlan {
            scenario,
            assignment_a,
            assignment_b: None,
            policy: self.policy,
        }
    }

    /// Like [`Planner::plan_exclusive`], but optimized for a single layer's
    /// statistics (used when per-layer deployment is being studied, e.g. the
    /// precise-input figures of §8.2).
    pub fn plan_exclusive_layer(
        &self,
        trace: &ModelTrace,
        layer: usize,
        cluster: &Cluster,
    ) -> DeploymentPlan {
        let scenario = Scenario::detect(1, cluster).expect("one model always detects");
        let assignment_a = match scenario {
            Scenario::ExclusiveHomogeneous => (0..trace.n_experts()).collect(),
            _ => sorted_assignment(&trace.layers[layer].expert_loads(), cluster),
        };
        DeploymentPlan {
            scenario,
            assignment_a,
            assignment_b: None,
            policy: self.policy,
        }
    }

    /// Plan two models colocated on `cluster`.
    ///
    /// Homogeneous (§6): Case II bottleneck matching on the planning layer's
    /// traffic; pairs stay on Model a's GPU indices.
    /// Heterogeneous (§7.2): decoupled two-stage matching with a per-GPU
    /// completion-estimate cost.
    pub fn plan_colocated(
        &self,
        a: &ModelTrace,
        b: &ModelTrace,
        cluster: &Cluster,
    ) -> DeploymentPlan {
        let scenario = Scenario::detect(2, cluster).expect("two models always detect");
        let n = a.n_experts();
        assert_eq!(n, b.n_experts(), "colocated models need equal expert counts (§6 fn3)");
        assert_eq!(n, cluster.len(), "one expert pair per GPU");
        let la = &a.layers[self.planning_layer.min(a.layers.len() - 1)];
        let lb = &b.layers[self.planning_layer.min(b.layers.len() - 1)];

        match scenario {
            Scenario::ColocatedHomogeneous => {
                let (_, pairing) = case2_pairing(&la.traffic, &lb.traffic);
                // a-expert i on GPU i; b-expert pairing[i] joins it.
                let mut assignment_b = vec![0usize; n];
                for (i, &j) in pairing.iter().enumerate() {
                    assignment_b[j] = i;
                }
                DeploymentPlan {
                    scenario,
                    assignment_a: (0..n).collect(),
                    assignment_b: Some(assignment_b),
                    policy: self.policy,
                }
            }
            Scenario::ColocatedHeterogeneous => {
                let cost = pair_gpu_cost(la, lb, cluster);
                let sol = decoupled_solution(&la.traffic, &lb.traffic, n, cost);
                let mut assignment_b = vec![0usize; n];
                for (i, &j) in sol.pairing.iter().enumerate() {
                    assignment_b[j] = sol.assignment[i];
                }
                DeploymentPlan {
                    scenario,
                    assignment_a: sol.assignment,
                    assignment_b: Some(assignment_b),
                    policy: self.policy,
                }
            }
            _ => unreachable!("detect(2, _) returns colocated scenarios"),
        }
    }

    /// Plan any number of models onto `cluster`, with no shape restrictions:
    /// M ≥ 2 models, several experts per GPU, and per-model expert counts
    /// independent of the cluster size are all allowed.
    ///
    /// Shapes the paper analyzes exactly (M ≤ 2, one expert per GPU) fall
    /// back to [`Planner::plan_exclusive`] / [`Planner::plan_colocated`], so
    /// the optimality guarantees of Theorems 5.1/6.2 and the §7.2 heuristic
    /// are preserved bit-for-bit. Everything else uses the generalized
    /// heuristic:
    ///
    /// 1. **Initial placement** — if every model has one expert per GPU
    ///    slot's worth of experts (`n_experts == cluster.len()`), stack
    ///    §6.2's Case II bottleneck matching iteratively: model 0 anchors
    ///    (identity on homogeneous clusters, Theorem 5.1 sorted assignment on
    ///    heterogeneous ones); each further model is matched against the
    ///    *aggregate* traffic of everything placed so far. Otherwise place
    ///    single experts greedily, heaviest first, onto the GPU minimizing
    ///    its post-assignment completion (Theorem 5.1's sort, generalized to
    ///    load accumulation).
    /// 2. **Refinement** — swap/move local search minimizing the max per-GPU
    ///    completion estimate ([`crate::placement::estimate_bottleneck`],
    ///    the §7.2 edge weight generalized to whole expert groups).
    pub fn plan_multi(
        &self,
        traces: &[&ModelTrace],
        cluster: &Cluster,
    ) -> Result<Deployment, PlacementError> {
        self.plan_multi_traced(traces, cluster, &Tracer::disabled())
    }

    /// [`Planner::plan_multi`] with span tracing and per-phase decision
    /// records emitted through `tr`. Tracing is purely observational: with
    /// `tr` disabled this *is* `plan_multi`, and with it enabled the result
    /// is bit-for-bit identical (pinned by the tracing-on/off property
    /// test).
    pub fn plan_multi_traced(
        &self,
        traces: &[&ModelTrace],
        cluster: &Cluster,
        tr: &Tracer,
    ) -> Result<Deployment, PlacementError> {
        let sp = tr.span("planner.plan_multi");
        tr.counter(sp.id(), "models", traces.len() as i64);
        tr.counter(sp.id(), "gpus", cluster.len() as i64);
        let m = traces.len();
        let scenario = Scenario::detect(m, cluster)?;
        let n_gpus = cluster.len();

        // Exact paper paths for the paper's shapes.
        if m == 1 && traces[0].n_experts() == n_gpus {
            tr.label(sp.id(), "path", "exclusive");
            return Ok(self.plan_exclusive(traces[0], cluster).to_deployment());
        }
        if m == 2 && traces[0].n_experts() == n_gpus && traces[1].n_experts() == n_gpus {
            tr.label(sp.id(), "path", "colocated");
            return Ok(self
                .plan_colocated(traces[0], traces[1], cluster)
                .to_deployment());
        }

        // The general path plans on aggregate statistics across layers — the
        // multi-layer analogue of plan_exclusive's total_expert_loads. (The
        // M ≤ 2 paths above keep the paper's planning-layer semantics.)
        let totals = aggregate_totals(traces);
        let layers: Vec<&MoeLayerStats> = totals.iter().collect();

        let assignments = if traces.iter().all(|t| t.n_experts() == n_gpus) {
            tr.label(sp.id(), "path", "stacked_pairing");
            stacked_pairing_assignments(&layers, cluster, tr)
        } else {
            tr.label(sp.id(), "path", "greedy_lpt");
            greedy_lpt_assignments(traces, cluster, tr)
        };

        let mut dep = Deployment::new(n_gpus, assignments, self.policy, scenario)?;
        refine_deployment(&mut dep, &layers, cluster, &Topology::BigSwitch, tr);
        Ok(dep)
    }

    /// Topology-aware placement: [`Planner::plan_multi`] followed by a
    /// **group-local refinement pass** that swaps/moves experts to minimize
    /// the projected cross-uplink token drain, then hands off to the
    /// existing swap/move refinement with an uplink guard (a port-balancing
    /// move is rejected if it would push traffic back across a saturated
    /// uplink).
    ///
    /// **Fallback guarantee:** on [`Topology::BigSwitch`] this *is*
    /// [`Planner::plan_multi`], bit for bit, and the [`Topology::TwoTier`]
    /// path is the historical exhaustive one — the tier-local pass below
    /// engages only for [`Topology::Tiered`].
    ///
    /// On a recursive [`Topology::Tiered`] fabric the localization pass is
    /// **tier-local** ([`refine_uplink_tiered`]): levels are refined from
    /// the outermost tier inward, and each candidate relocation targets one
    /// representative GPU per sibling group instead of every GPU in the
    /// cluster — O(units · Σ sibling groups) candidates per round instead of
    /// the exhaustive O(units² ) move/swap sweep, which is what keeps
    /// thousand-GPU planning inside the bench gate's budget.
    pub fn plan_topology(
        &self,
        traces: &[&ModelTrace],
        cluster: &Cluster,
        topo: &Topology,
    ) -> Result<Deployment, PlacementError> {
        self.plan_topology_traced(traces, cluster, topo, &Tracer::disabled())
    }

    /// [`Planner::plan_topology`] with tracing through `tr` (observational
    /// only — results are bit-for-bit those of `plan_topology`).
    pub fn plan_topology_traced(
        &self,
        traces: &[&ModelTrace],
        cluster: &Cluster,
        topo: &Topology,
        tr: &Tracer,
    ) -> Result<Deployment, PlacementError> {
        let sp = tr.span("planner.plan_topology");
        let topo_name = match topo {
            Topology::BigSwitch => "big_switch",
            Topology::TwoTier { .. } => "two_tier",
            Topology::Tiered { .. } => "tiered",
        };
        tr.label(sp.id(), "topology", topo_name);
        // Typed validation up front: a grouping that does not cover this
        // cluster is a caller error surfaced here, not a panic several
        // frames deep in the refinement or the scheduler.
        let _ = topo
            .owners(cluster.len())
            .map_err(|e| PlacementError::InvalidTopology {
                message: e.to_string(),
            })?;
        let mut dep = self.plan_multi_traced(traces, cluster, tr)?;
        if matches!(topo, Topology::BigSwitch) {
            return Ok(dep);
        }
        let totals = aggregate_totals(traces);
        let layers: Vec<&MoeLayerStats> = totals.iter().collect();
        if matches!(topo, Topology::Tiered { .. }) {
            refine_uplink_tiered(&mut dep, &layers, cluster, topo, tr);
        } else {
            refine_uplink(&mut dep, &layers, cluster, topo, tr);
        }
        refine_deployment(&mut dep, &layers, cluster, topo, tr);
        Ok(dep)
    }

    /// Plan with **expert replication**: run [`Planner::plan_multi`], then
    /// greedily replicate the experts of the bottleneck GPU while each copy
    /// buys at least `cfg.min_gain` relative reduction of the split-aware
    /// per-GPU completion estimate, then re-run the swap/move refinement
    /// with the split-aware evaluator
    /// ([`crate::replication::refine_replicated`]).
    ///
    /// Returns the deployment together with the [`SplitPlan`] it was
    /// optimized with (recomputing it via
    /// [`ReplicatedDeployment::plan_splits`] on the same traces yields the
    /// identical plan).
    ///
    /// **Fallback guarantee:** when no replica clears the threshold (e.g.
    /// uniform routing, where splitting a balanced load cannot shrink the
    /// max), the result is exactly
    /// `ReplicatedDeployment::from_deployment(plan_multi(..))` with the
    /// trivial split plan — the refinement pass is only entered once a
    /// replica has been accepted, so the un-replicated plan is preserved
    /// bit-for-bit.
    pub fn plan_replicated(
        &self,
        traces: &[&ModelTrace],
        cluster: &Cluster,
        cfg: &ReplicationConfig,
    ) -> Result<(ReplicatedDeployment, SplitPlan), PlacementError> {
        self.plan_replicated_on(traces, cluster, &Topology::BigSwitch, cfg, &Tracer::disabled())
    }

    /// [`Planner::plan_replicated`] with tracing through `tr` (observational
    /// only — results are bit-for-bit those of `plan_replicated`).
    pub fn plan_replicated_traced(
        &self,
        traces: &[&ModelTrace],
        cluster: &Cluster,
        cfg: &ReplicationConfig,
        tr: &Tracer,
    ) -> Result<(ReplicatedDeployment, SplitPlan), PlacementError> {
        self.plan_replicated_on(traces, cluster, &Topology::BigSwitch, cfg, tr)
    }

    /// Topology-aware [`Planner::plan_replicated`]: the base placement comes
    /// from [`Planner::plan_topology`], and every replication decision is
    /// judged on the split-aware completion estimate **joined with the
    /// cross-uplink drain** of the split-projected aggregate traffic —
    /// replicating a hot expert into the groups that route to it is how a
    /// two-tier fabric escapes its down-link bound. On
    /// [`Topology::BigSwitch`] this is [`Planner::plan_replicated`], bit for
    /// bit.
    pub fn plan_replicated_topology(
        &self,
        traces: &[&ModelTrace],
        cluster: &Cluster,
        topo: &Topology,
        cfg: &ReplicationConfig,
    ) -> Result<(ReplicatedDeployment, SplitPlan), PlacementError> {
        self.plan_replicated_on(traces, cluster, topo, cfg, &Tracer::disabled())
    }

    /// [`Planner::plan_replicated_topology`] with tracing through `tr`
    /// (observational only — results are bit-for-bit identical).
    pub fn plan_replicated_topology_traced(
        &self,
        traces: &[&ModelTrace],
        cluster: &Cluster,
        topo: &Topology,
        cfg: &ReplicationConfig,
        tr: &Tracer,
    ) -> Result<(ReplicatedDeployment, SplitPlan), PlacementError> {
        self.plan_replicated_on(traces, cluster, topo, cfg, tr)
    }

    /// The shared replication pipeline behind [`Planner::plan_replicated`] /
    /// [`Planner::plan_replicated_topology`].
    ///
    /// Candidate pricing is incremental
    /// ([`ReplicaDeltaEstimator::eval_add`]): the water-filling split plan
    /// is re-solved with cached expert loads, and only the experts whose
    /// splits actually changed re-place their traffic onto cloned integer
    /// counters. At small scale (expert units × GPUs ≤ 1024) every
    /// candidate is re-priced each iteration — bit-for-bit the historical
    /// selections, just cheaper. Above that the greedy goes **lazy-greedy
    /// (CELF-style)**: every candidate for the current bottleneck GPU is
    /// priced exactly once into a priority queue (the exact first sweep —
    /// parallel under the `rayon` feature, with an index-ordered reduction
    /// so results are bit-for-bit the serial ones), and after each commit
    /// only popped entries are re-priced until the cheapest bound is fresh.
    /// Re-pricing on pop keeps accepted values exact; the lazy part assumes
    /// diminishing returns (a commit elsewhere rarely makes a worse-bounded
    /// candidate better), the standard CELF invariant — see "Performance &
    /// incremental planning" in `docs/architecture.md`.
    fn plan_replicated_on(
        &self,
        traces: &[&ModelTrace],
        cluster: &Cluster,
        topo: &Topology,
        cfg: &ReplicationConfig,
        tr: &Tracer,
    ) -> Result<(ReplicatedDeployment, SplitPlan), PlacementError> {
        let base = self.plan_topology_traced(traces, cluster, topo, tr)?;
        let sp = tr.span("planner.replicate");
        let mut rep = ReplicatedDeployment::from_deployment(base);
        if cfg.max_replicas <= 1 {
            let splits = SplitPlan::trivial(&rep);
            return Ok((rep, splits));
        }

        let totals = aggregate_totals(traces);
        let layers: Vec<&MoeLayerStats> = totals.iter().collect();
        let n = cluster.len();

        let mut est = ReplicaDeltaEstimator::new(&rep, &layers, cluster, topo);
        let mut best = est.objective();

        // Below this (expert units × GPUs) size the greedy re-prices every
        // candidate each iteration — still fast, since pricing is
        // incremental, and **bit-for-bit the historical selections**. Above
        // it the lazy (CELF) queue takes over: re-pricing the whole
        // candidate set per iteration is what stops scaling first.
        let units_total: usize = (0..rep.n_models()).map(|m| rep.base.n_experts(m)).sum();
        let lazy = units_total * n > 1024;
        tr.label(sp.id(), "mode", if lazy { "lazy_greedy" } else { "exhaustive" });

        // Lazy-greedy state: cached candidate bounds (objective after the
        // addition) in a min-heap, stamped with the commit version they
        // were priced against.
        let mut heap: BinaryHeap<Reverse<Cand>> = BinaryHeap::new();
        let mut cache: HashMap<(usize, usize, usize), (f64, u64)> = HashMap::new();
        let mut version: u64 = 0;
        let mut last_hot: Option<usize> = None;

        // Hard cap on added replicas keeps the greedy loop polynomial even
        // with an unlimited slot budget.
        let cap = if cfg.slots_per_gpu > 0 { n * cfg.slots_per_gpu } else { n * 4 };
        while rep.added_replicas() < cap {
            // Bottleneck GPU and the experts contributing load to it.
            let hot_gpu = (0..n)
                .max_by(|&a, &b| est.costs()[a].partial_cmp(&est.costs()[b]).unwrap())
                .expect("cluster is non-empty");
            let slots = rep.slots_per_gpu();
            let mut chosen: Option<Cand> = None;
            if !lazy {
                // Exhaustive sweep (the historical loop, incrementally
                // priced): first strict minimum wins ties.
                for m in 0..rep.n_models() {
                    for e in 0..rep.base.n_experts(m) {
                        if !rep.replicas[m][e].contains(&hot_gpu)
                            || rep.replica_count(m, e) >= cfg.max_replicas
                        {
                            continue;
                        }
                        for g in 0..n {
                            if rep.replicas[m][e].contains(&g) {
                                continue;
                            }
                            if cfg.slots_per_gpu > 0 && slots[g] >= cfg.slots_per_gpu {
                                continue;
                            }
                            let mx = est.eval_add(m, e, g);
                            let better = match &chosen {
                                None => true,
                                Some(c) => mx < c.mx,
                            };
                            if better {
                                chosen = Some(Cand { mx, m, e, g, stamp: version });
                            }
                        }
                    }
                }
            } else {
                if last_hot != Some(hot_gpu) {
                    // The bottleneck moved: rebuild the queue for its
                    // candidate set (in the historical iteration order, so
                    // heap ties break to the same candidate the exhaustive
                    // loop chooses). Known candidates re-enter with their
                    // cached bounds; unseen ones get the exact sweep.
                    heap.clear();
                    let mut cands: Vec<(usize, usize, usize)> = Vec::new();
                    for m in 0..rep.n_models() {
                        for e in 0..rep.base.n_experts(m) {
                            if !rep.replicas[m][e].contains(&hot_gpu)
                                || rep.replica_count(m, e) >= cfg.max_replicas
                            {
                                continue;
                            }
                            for g in 0..n {
                                if rep.replicas[m][e].contains(&g) {
                                    continue;
                                }
                                if cfg.slots_per_gpu > 0 && slots[g] >= cfg.slots_per_gpu {
                                    continue;
                                }
                                cands.push((m, e, g));
                            }
                        }
                    }
                    let unseen: Vec<(usize, usize, usize)> = cands
                        .iter()
                        .copied()
                        .filter(|c| !cache.contains_key(c))
                        .collect();
                    let swept = par_map(&unseen, |&(m, e, g)| est.eval_add(m, e, g));
                    for (&c, &mx) in unseen.iter().zip(&swept) {
                        cache.insert(c, (mx, version));
                    }
                    for &(m, e, g) in &cands {
                        let &(mx, stamp) = cache.get(&(m, e, g)).expect("swept above");
                        heap.push(Reverse(Cand { mx, m, e, g, stamp }));
                    }
                    tr.decision(
                        "planner.queue_rebuild",
                        vec![
                            ("hot_gpu", Json::from(hot_gpu)),
                            ("candidates", Json::from(cands.len())),
                            ("swept", Json::from(unseen.len())),
                        ],
                    );
                    tr.counter(sp.id(), "queue_rebuilds", 1);
                    last_hot = Some(hot_gpu);
                }

                // CELF pop loop: re-price stale entries until the cheapest
                // bound is fresh for the current committed state.
                while let Some(Reverse(cand)) = heap.pop() {
                    tr.counter(sp.id(), "queue_pops", 1);
                    let Cand { m, e, g, stamp, .. } = cand;
                    if rep.replicas[m][e].contains(&g)
                        || rep.replica_count(m, e) >= cfg.max_replicas
                        || (cfg.slots_per_gpu > 0 && slots[g] >= cfg.slots_per_gpu)
                    {
                        continue; // invalidated by an earlier commit
                    }
                    if stamp == version {
                        chosen = Some(cand);
                        break;
                    }
                    let mx = est.eval_add(m, e, g);
                    cache.insert((m, e, g), (mx, version));
                    heap.push(Reverse(Cand { mx, m, e, g, stamp: version }));
                }
            }
            match chosen {
                Some(c) if c.mx < best * (1.0 - cfg.min_gain) => {
                    est.commit_add(c.m, c.e, c.g);
                    rep.replicas[c.m][c.e].push(c.g);
                    tr.decision(
                        "planner.replica_commit",
                        vec![
                            ("model", Json::from(c.m)),
                            ("expert", Json::from(c.e)),
                            ("gpu", Json::from(c.g)),
                            ("objective_before", Json::from(best)),
                            ("objective_after", Json::from(c.mx)),
                        ],
                    );
                    tr.counter(sp.id(), "commits", 1);
                    best = est.objective();
                    version += 1;
                }
                _ => break,
            }
        }

        if rep.is_replicated() {
            match topo {
                Topology::BigSwitch => {
                    refine_replicated(&mut rep, &layers, cluster, cfg.slots_per_gpu)
                }
                Topology::TwoTier { .. } | Topology::Tiered { .. } => {
                    // The split-aware refinement optimizes the port estimate
                    // only; on an oversubscribed fabric keep its result just
                    // when it does not worsen the combined (port ∨ uplink)
                    // objective — uplink_bound joins every aggregation level.
                    let eval = |rep: &ReplicatedDeployment| -> f64 {
                        let plan = optimize_splits(rep, &layers, cluster);
                        estimate_objective_on(rep, &layers, cluster, topo, &plan)
                    };
                    let before = rep.clone();
                    let mx_before = eval(&rep);
                    refine_replicated(&mut rep, &layers, cluster, cfg.slots_per_gpu);
                    let mx_after = eval(&rep);
                    if mx_after > mx_before + 1e-12 {
                        rep = before;
                    }
                }
            }
        }
        let splits = optimize_splits(&rep, &layers, cluster);
        Ok((rep, splits))
    }
}

/// Lazy-greedy queue entry: a candidate replica addition and its cached
/// bound on the post-addition objective. Ordered by `(mx, m, e, g)` so heap
/// ties resolve to the first candidate in the historical sweep order;
/// `stamp` records the commit version the bound was priced against and does
/// not participate in the ordering.
#[derive(Debug, Clone, Copy)]
struct Cand {
    mx: f64,
    m: usize,
    e: usize,
    g: usize,
    stamp: u64,
}

impl PartialEq for Cand {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Cand {}

impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Cand {
    fn cmp(&self, other: &Self) -> Ordering {
        self.mx
            .total_cmp(&other.mx)
            .then(self.m.cmp(&other.m))
            .then(self.e.cmp(&other.e))
            .then(self.g.cmp(&other.g))
    }
}

/// Budget and acceptance knobs of [`Planner::plan_replicated`].
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicationConfig {
    /// Maximum copies of one expert (1 disables replication).
    pub max_replicas: usize,
    /// Maximum `(model, expert)` copies per GPU — the memory/slot budget.
    /// `0` means unlimited.
    pub slots_per_gpu: usize,
    /// Minimum *relative* bottleneck reduction a new replica must buy to be
    /// accepted. Keeps uniform workloads replica-free (and therefore
    /// bit-for-bit on the un-replicated plan).
    pub min_gain: f64,
}

impl Default for ReplicationConfig {
    fn default() -> Self {
        Self {
            max_replicas: 4,
            slots_per_gpu: 0,
            min_gain: 0.01,
        }
    }
}

/// Iterative pairwise bottleneck matching (generalizing §6.2 to M models):
/// every model spans the cluster bijectively; model k ≥ 1 is matched against
/// the aggregated GPU-level traffic of models 0..k.
fn stacked_pairing_assignments(
    layers: &[&MoeLayerStats],
    cluster: &Cluster,
    tr: &Tracer,
) -> Vec<Vec<usize>> {
    let sp = tr.span("planner.stacked_pairing");
    tr.counter(sp.id(), "models", layers.len() as i64);
    tr.decision(
        "planner.phase",
        vec![
            ("phase", Json::from("stacked_pairing")),
            ("models", Json::from(layers.len())),
        ],
    );
    let n = cluster.len();
    let a0: Vec<usize> = if cluster.is_homogeneous() {
        (0..n).collect()
    } else {
        sorted_assignment(&layers[0].expert_loads(), cluster)
    };
    let mut agg = layers[0].traffic.project(&a0, n);
    let mut assignments = vec![a0];
    for layer in layers.iter().skip(1) {
        // Case II bottleneck matching of this model's experts against the
        // aggregate placed so far; `pi[g]` = expert joining GPU g.
        let (_, pi) = case2_pairing(&agg, &layer.traffic);
        let mut a = vec![0usize; n];
        for (g, &e) in pi.iter().enumerate() {
            a[e] = g;
        }
        agg = agg.sum(&layer.traffic.project(&a, n));
        assignments.push(a);
    }
    assignments
}

/// Greedy load-balanced placement (generalizing Theorem 5.1): all
/// `(model, expert)` units sorted heaviest-first, each placed on the GPU
/// whose completion estimate after accepting it is smallest (faster GPUs
/// absorb more load; ties prefer higher bandwidth, then lower GPU id).
fn greedy_lpt_assignments(
    traces: &[&ModelTrace],
    cluster: &Cluster,
    tr: &Tracer,
) -> Vec<Vec<usize>> {
    let sp = tr.span("planner.greedy_lpt");
    let n = cluster.len();
    let mut units: Vec<(usize, usize, u64)> = traces
        .iter()
        .enumerate()
        .flat_map(|(m, t)| {
            t.total_expert_loads()
                .into_iter()
                .enumerate()
                .map(move |(e, l)| (m, e, l))
        })
        .collect();
    units.sort_by_key(|&(m, e, l)| (std::cmp::Reverse(l), m, e));
    tr.counter(sp.id(), "units", units.len() as i64);
    tr.decision(
        "planner.phase",
        vec![
            ("phase", Json::from("greedy_lpt")),
            ("units", Json::from(units.len())),
        ],
    );

    let mut acc = vec![0.0f64; n];
    let mut assignments: Vec<Vec<usize>> = traces
        .iter()
        .map(|t| vec![0usize; t.n_experts()])
        .collect();
    for (m, e, l) in units {
        let best = (0..n)
            .min_by(|&x, &y| {
                let cx = (acc[x] + l as f64) / cluster.gpu(x).flops_scale;
                let cy = (acc[y] + l as f64) / cluster.gpu(y).flops_scale;
                cx.partial_cmp(&cy)
                    .unwrap()
                    .then(
                        cluster
                            .gpu(y)
                            .bandwidth
                            .partial_cmp(&cluster.gpu(x).bandwidth)
                            .unwrap(),
                    )
                    .then(x.cmp(&y))
            })
            .expect("cluster is non-empty");
        acc[best] += l as f64;
        assignments[m][e] = best;
    }
    assignments
}

/// The group-local pass of [`Planner::plan_topology`]: single-expert moves
/// and pairwise swaps accepted when they shrink the **combined** objective
/// `max(per-GPU completion estimate, cross-uplink drain)` — the fluid form
/// of the hierarchical schedule's pipelined makespan — with a strictly
/// smaller drain as the tiebreak at an unchanged combined value (localizing
/// below the port ceiling still shortens the uplink phase). Minimizing the
/// drain alone would happily collapse every expert into one group (zero
/// uplink traffic, hopeless ports); the combined form cannot. Bounded
/// rounds keep it polynomial.
///
/// Candidates are priced through a [`DeltaEstimator`]: per-GPU estimates
/// and per-uplink token counters advance in O(expert degree) per trial move
/// instead of the historical full `uplink_drain_ms` rescan (O(models ·
/// experts²)) per cross-group candidate. The counters are exact integers,
/// so the accept/reject decisions are bit-for-bit the rescanning ones.
fn refine_uplink(
    dep: &mut Deployment,
    layers: &[&MoeLayerStats],
    cluster: &Cluster,
    topo: &Topology,
    tr: &Tracer,
) {
    if matches!(topo, Topology::BigSwitch) {
        return;
    }
    let sp = tr.span("planner.refine_uplink");
    let n = dep.n_gpus;
    let units: Vec<(usize, usize)> = (0..dep.n_models())
        .flat_map(|m| (0..dep.n_experts(m)).map(move |e| (m, e)))
        .collect();

    let mut est = DeltaEstimator::new(dep, layers, cluster, topo);
    let mut best_port = est.bottleneck();
    let mut best_drain = est.uplink_drain_ms();
    let accepts = |mx: f64, nd: f64, best_port: f64, best_drain: f64| -> bool {
        let cand = mx.max(nd);
        let best = best_port.max(best_drain);
        cand + 1e-12 < best || (cand <= best + 1e-9 && nd + 1e-9 < best_drain)
    };

    for round in 0..8usize {
        let mut improved = false;
        for &(m, e) in &units {
            let cur = dep.assignments[m][e];
            for g in 0..n {
                if g == cur {
                    continue;
                }
                est.apply_move(m, e, g);
                let mx = est.bottleneck();
                let nd = est.uplink_drain_ms();
                if accepts(mx, nd, best_port, best_drain) {
                    dep.assignments[m][e] = g;
                    best_port = mx;
                    best_drain = nd;
                    improved = true;
                    break; // unit committed; on to the next one
                }
                est.apply_move(m, e, cur);
            }
        }
        for i in 0..units.len() {
            for j in (i + 1)..units.len() {
                let (m1, e1) = units[i];
                let (m2, e2) = units[j];
                let g1 = dep.assignments[m1][e1];
                let g2 = dep.assignments[m2][e2];
                if g1 == g2 || est.group_of_gpu(g1) == est.group_of_gpu(g2) {
                    // a same-group swap never changes what crosses an uplink
                    continue;
                }
                est.apply_swap(m1, e1, m2, e2);
                let mx = est.bottleneck();
                let nd = est.uplink_drain_ms();
                if accepts(mx, nd, best_port, best_drain) {
                    dep.assignments[m1][e1] = g2;
                    dep.assignments[m2][e2] = g1;
                    best_port = mx;
                    best_drain = nd;
                    improved = true;
                } else {
                    est.apply_swap(m1, e1, m2, e2);
                }
            }
        }
        tr.counter(sp.id(), "rounds", 1);
        tr.decision(
            "planner.uplink_round",
            vec![
                ("round", Json::from(round)),
                ("port_ms", Json::from(best_port)),
                ("drain_ms", Json::from(best_drain)),
                ("improved", Json::from(improved)),
            ],
        );
        if !improved {
            break;
        }
    }
}

/// The tier-local localization pass of [`Planner::plan_topology`] for
/// recursive [`Topology::Tiered`] fabrics — the thousand-GPU replacement for
/// [`refine_uplink`]'s exhaustive sweep.
///
/// Levels are refined **outermost first** (pods before racks): localizing a
/// flow below the pod tier also removes it from every tier above, so coarse
/// decisions constrain fine ones and not vice versa. At level `t` a unit's
/// candidate destinations are the **sibling groups** sharing its level-`t+1`
/// parent (every level-`t` group at the top), and each candidate group is
/// entered at its currently cheapest GPU — one representative target per
/// group instead of every member. That bounds a full round to
/// O(units · Σ_t siblings(t)) priced candidates (each O(expert degree)
/// through the [`DeltaEstimator`]), against the exhaustive pass's
/// O(units · GPUs + units²) — the difference between milliseconds and
/// minutes at 1024 GPUs.
///
/// The acceptance rule is [`refine_uplink`]'s combined objective
/// `max(per-GPU completion estimate, cross-uplink drain)` with the
/// strictly-smaller-drain tiebreak, where the drain now joins **every**
/// aggregation level. Port imbalance a representative target introduces is
/// repaired by the [`refine_deployment`] pass that always follows.
fn refine_uplink_tiered(
    dep: &mut Deployment,
    layers: &[&MoeLayerStats],
    cluster: &Cluster,
    topo: &Topology,
    tr: &Tracer,
) {
    let n = dep.n_gpus;
    let l = topo.n_levels();
    if l == 0 {
        return;
    }
    let sp = tr.span("planner.refine_uplink_tiered");
    tr.counter(sp.id(), "levels", l as i64);
    let owners: Vec<Vec<usize>> = (0..l)
        .map(|t| topo.owners_at(n, t).expect("validated by plan_topology"))
        .collect();
    // members[t][h] = GPUs inside level-t group h
    let members: Vec<Vec<Vec<usize>>> = owners
        .iter()
        .map(|ow| {
            let n_groups = ow.iter().map(|&o| o + 1).max().unwrap_or(0);
            let mut ms = vec![Vec::new(); n_groups];
            for (g, &o) in ow.iter().enumerate() {
                ms[o].push(g);
            }
            ms
        })
        .collect();
    // parent of each level-t group one level up (a single shared id at the
    // top level, making every top-level group a sibling of every other)
    let parents: Vec<Vec<usize>> = (0..l)
        .map(|t| {
            members[t]
                .iter()
                .map(|ms| if t + 1 < l { owners[t + 1][ms[0]] } else { 0 })
                .collect()
        })
        .collect();

    let units: Vec<(usize, usize)> = (0..dep.n_models())
        .flat_map(|m| (0..dep.n_experts(m)).map(move |e| (m, e)))
        .collect();
    let mut est = DeltaEstimator::new(dep, layers, cluster, topo);
    let mut best_port = est.bottleneck();
    let mut best_drain = est.uplink_drain_ms();
    let accepts = |mx: f64, nd: f64, best_port: f64, best_drain: f64| -> bool {
        let cand = mx.max(nd);
        let best = best_port.max(best_drain);
        cand + 1e-12 < best || (cand <= best + 1e-9 && nd + 1e-9 < best_drain)
    };

    for round in 0..8usize {
        let mut improved = false;
        for t in (0..l).rev() {
            for &(m, e) in &units {
                let cur = dep.assignments[m][e];
                let hc = owners[t][cur];
                for h in 0..members[t].len() {
                    if h == hc || parents[t][h] != parents[t][hc] {
                        continue;
                    }
                    let g = members[t][h]
                        .iter()
                        .copied()
                        .min_by(|&x, &y| {
                            est.cost(x)
                                .partial_cmp(&est.cost(y))
                                .unwrap()
                                .then(x.cmp(&y))
                        })
                        .expect("groups are non-empty");
                    est.apply_move(m, e, g);
                    let mx = est.bottleneck();
                    let nd = est.uplink_drain_ms();
                    if accepts(mx, nd, best_port, best_drain) {
                        dep.assignments[m][e] = g;
                        best_port = mx;
                        best_drain = nd;
                        improved = true;
                        break; // unit committed at this level; next unit
                    }
                    est.apply_move(m, e, cur);
                }
            }
        }
        tr.counter(sp.id(), "rounds", 1);
        tr.decision(
            "planner.uplink_round",
            vec![
                ("round", Json::from(round)),
                ("tiered", Json::from(true)),
                ("port_ms", Json::from(best_port)),
                ("drain_ms", Json::from(best_drain)),
                ("improved", Json::from(improved)),
            ],
        );
        if !improved {
            break;
        }
    }
}

/// Local-search refinement: single-expert moves and cross-GPU pairwise swaps
/// accepted whenever they shrink the max per-GPU completion estimate.
/// Bounded rounds keep planning polynomial (§7.2 spirit: decouple, then
/// polish).
///
/// Two structural facts keep this cheap. A move or swap only changes the
/// costs of its (at most two) endpoint GPUs, so (a) candidates not touching
/// a **current bottleneck GPU** can never shrink the global max and are
/// skipped, and (b) candidates are priced through a [`DeltaEstimator`]
/// whose integer counters advance in O(expert degree) per trial move — no
/// per-candidate rescans of any kind, and drain values read off the
/// counters are always the *actual* current ones (the historical code
/// tracked a cached drain scalar that `cur_drain.min(nd)` could leave
/// stale-low after a tolerance-window accept).
///
/// On a [`Topology::TwoTier`] fabric the search additionally **guards the
/// uplinks**: a port-balancing candidate that would increase the projected
/// cross-uplink drain is rejected, so this pass never undoes
/// [`refine_uplink`]'s localization. With [`Topology::BigSwitch`] the guard
/// is inert and the behavior is the historical one, bit for bit.
fn refine_deployment(
    dep: &mut Deployment,
    layers: &[&MoeLayerStats],
    cluster: &Cluster,
    topo: &Topology,
    tr: &Tracer,
) {
    let sp = tr.span("planner.refine");
    let n = dep.n_gpus;
    let units: Vec<(usize, usize)> = (0..dep.n_models())
        .flat_map(|m| (0..dep.n_experts(m)).map(move |e| (m, e)))
        .collect();

    let mut est = DeltaEstimator::new(dep, layers, cluster, topo);
    let mut best = est.bottleneck();
    let mut cur_drain = est.uplink_drain_ms();

    let is_hot = |est: &DeltaEstimator, best: f64, g: usize| est.cost(g) >= best - 1e-9;

    for round in 0..8usize {
        let mut improved = false;
        for &(m, e) in &units {
            let cur = dep.assignments[m][e];
            for g in 0..n {
                if g == cur || !(is_hot(&est, best, cur) || is_hot(&est, best, g)) {
                    continue;
                }
                est.apply_move(m, e, g);
                let mx = est.bottleneck();
                let nd = est.uplink_drain_ms();
                if mx + 1e-12 < best && nd <= cur_drain + 1e-9 {
                    dep.assignments[m][e] = g;
                    best = mx;
                    // Track the actual recomputed drain. The historical
                    // `cur_drain.min(nd)` kept the stale smaller value when
                    // `nd` landed inside the 1e-9 tolerance, letting later
                    // accepts compound a drain regression the guard never
                    // saw.
                    cur_drain = nd;
                    improved = true;
                    break; // unit committed; on to the next one
                }
                est.apply_move(m, e, cur);
            }
        }
        for i in 0..units.len() {
            for j in (i + 1)..units.len() {
                let (m1, e1) = units[i];
                let (m2, e2) = units[j];
                let g1 = dep.assignments[m1][e1];
                let g2 = dep.assignments[m2][e2];
                if g1 == g2 || !(is_hot(&est, best, g1) || is_hot(&est, best, g2)) {
                    continue;
                }
                est.apply_swap(m1, e1, m2, e2);
                let mx = est.bottleneck();
                let nd = est.uplink_drain_ms();
                if mx + 1e-12 < best && nd <= cur_drain + 1e-9 {
                    dep.assignments[m1][e1] = g2;
                    dep.assignments[m2][e2] = g1;
                    best = mx;
                    cur_drain = nd;
                    improved = true;
                } else {
                    est.apply_swap(m1, e1, m2, e2);
                }
            }
        }
        tr.counter(sp.id(), "rounds", 1);
        tr.decision(
            "planner.refine_round",
            vec![
                ("round", Json::from(round)),
                ("bottleneck_ms", Json::from(best)),
                ("drain_ms", Json::from(cur_drain)),
                ("improved", Json::from(improved)),
            ],
        );
        if !improved {
            break;
        }
    }
}

/// Per-GPU completion estimate for colocating a-expert `i` and b-expert `j`
/// on GPU `g` — the edge weight of the stage-2 matching (§7.2): serialized
/// compute of both experts plus the pair's worst-direction wire time.
pub fn pair_gpu_cost<'s>(
    la: &'s MoeLayerStats,
    lb: &'s MoeLayerStats,
    cluster: &'s Cluster,
) -> impl Fn(usize, usize, usize) -> f64 + 's {
    let loads_a = la.expert_loads();
    let loads_b = lb.expert_loads();
    let (a_send, a_recv) = send_recv_volumes(&la.traffic);
    let (b_send, b_recv) = send_recv_volumes(&lb.traffic);
    move |i: usize, j: usize, g: usize| {
        let gpu = cluster.gpu(g);
        let compute = (la.gate_ms
            + lb.gate_ms
            + la.agg_ms
            + lb.agg_ms
            + loads_a[i] as f64 * la.ffn_ms_per_token
            + loads_b[j] as f64 * lb.ffn_ms_per_token)
            / gpu.flops_scale;
        let wire = (a_send[i] + b_send[j]).max(a_recv[i] + b_recv[j]) as f64 / gpu.bandwidth;
        compute + wire
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::uplink_bound;
    use crate::replication::estimate_per_gpu_replicated;
    use crate::sim::{simulate_colocated, simulate_exclusive};
    use crate::trace::{limoe_trace, Dataset, LimoeVariant};
    use crate::util::Rng;

    fn traces() -> (ModelTrace, ModelTrace) {
        (
            limoe_trace(LimoeVariant::B16, Dataset::Coco, 8, 4, 32, 1),
            limoe_trace(LimoeVariant::B32, Dataset::Imagenet, 8, 4, 128, 2),
        )
    }

    // Scenario::detect's leaf coverage (including MultiColocated and the
    // NoModels error) is tested where the type lives:
    // placement::tests::detect_covers_all_leaves.

    #[test]
    fn three_models_are_a_planned_path_not_a_crash() {
        // The seed asserted "at most two models per GPU" with a panic; N > 2
        // now detects to the generalized leaf and plans successfully.
        let cluster = Cluster::homogeneous(8, 1.0);
        assert_eq!(Scenario::detect(3, &cluster), Ok(Scenario::MultiColocated));
        assert_eq!(
            Scenario::detect(0, &cluster),
            Err(PlacementError::NoModels)
        );
        let (a, b) = traces();
        let c = limoe_trace(LimoeVariant::B16, Dataset::Imagenet, 8, 4, 64, 3);
        let dep = Planner::default().plan_multi(&[&a, &b, &c], &cluster).unwrap();
        assert_eq!(dep.n_models(), 3);
        assert_eq!(dep.scenario, Scenario::MultiColocated);
        // all 24 experts are placed somewhere on the 8 GPUs
        assert_eq!(dep.experts_per_gpu().iter().sum::<usize>(), 24);
    }

    #[test]
    fn plan_multi_falls_back_to_exact_paths() {
        let (a, b) = traces();
        for cluster in [
            Cluster::homogeneous(8, 1.0),
            Cluster::paper_heterogeneous(8, 1.0),
        ] {
            let planner = Planner::default();
            let d1 = planner.plan_multi(&[&a], &cluster).unwrap();
            assert_eq!(d1, planner.plan_exclusive(&a, &cluster).to_deployment());
            let d2 = planner.plan_multi(&[&a, &b], &cluster).unwrap();
            assert_eq!(
                d2,
                planner.plan_colocated(&a, &b, &cluster).to_deployment()
            );
        }
    }

    #[test]
    fn plan_multi_handles_more_experts_than_gpus() {
        // 16 experts per model on 8 GPUs: two experts of each model per GPU.
        let a = limoe_trace(LimoeVariant::B16, Dataset::Coco, 16, 2, 32, 7);
        let b = limoe_trace(LimoeVariant::B32, Dataset::Imagenet, 16, 2, 32, 8);
        let cluster = Cluster::homogeneous(8, 800.0);
        let dep = Planner::default().plan_multi(&[&a, &b], &cluster).unwrap();
        assert_eq!(dep.n_gpus, 8);
        assert_eq!(dep.n_experts(0), 16);
        assert_eq!(dep.n_experts(1), 16);
        // all 32 experts are placed; the heaviest GPU group stays bounded
        assert_eq!(dep.experts_per_gpu().iter().sum::<usize>(), 32);
        assert!(dep.max_group_size() >= 4); // 32 experts on 8 GPUs
        let sims = dep.simulate(&[&a, &b], &cluster);
        assert_eq!(sims.len(), 2);
        assert!(sims.iter().all(|r| r.inference_ms > 0.0));
    }

    #[test]
    fn plan_multi_balances_load_on_heterogeneous_clusters() {
        // Greedy generalized Theorem 5.1: the slowest GPU must not carry
        // more token load than the fastest.
        let a = limoe_trace(LimoeVariant::B16, Dataset::Coco, 16, 2, 64, 17);
        let cluster = Cluster::paper_heterogeneous(8, 800.0);
        let dep = Planner::default().plan_multi(&[&a], &cluster).unwrap();
        let proj = dep.project_layer(0, &a.layers[0]);
        let loads = proj.expert_loads();
        let bw = cluster.bandwidths();
        let fastest = (0..8).max_by(|&x, &y| bw[x].partial_cmp(&bw[y]).unwrap()).unwrap();
        let slowest = (0..8).min_by(|&x, &y| bw[x].partial_cmp(&bw[y]).unwrap()).unwrap();
        assert!(
            loads[fastest] >= loads[slowest],
            "fast GPU load {} < slow GPU load {}",
            loads[fastest],
            loads[slowest]
        );
    }

    #[test]
    fn exclusive_homo_plan_is_identity() {
        let (a, _) = traces();
        let plan = Planner::default().plan_exclusive(&a, &Cluster::homogeneous(8, 1.0));
        assert_eq!(plan.assignment_a, (0..8).collect::<Vec<_>>());
        assert!(plan.assignment_b.is_none());
    }

    #[test]
    fn exclusive_hetero_puts_heavy_experts_on_fast_gpus() {
        let (a, _) = traces();
        let cluster = Cluster::paper_heterogeneous(8, 1.0);
        let plan = Planner::default().plan_exclusive(&a, &cluster);
        let loads = a.total_expert_loads();
        let heaviest = (0..8).max_by_key(|&e| loads[e]).unwrap();
        let lightest = (0..8).min_by_key(|&e| loads[e]).unwrap();
        let bw = cluster.bandwidths();
        assert!(bw[plan.assignment_a[heaviest]] >= bw[plan.assignment_a[lightest]]);
    }

    #[test]
    fn colocated_plan_pairs_each_gpu_once() {
        let (a, b) = traces();
        for cluster in [
            Cluster::homogeneous(8, 1.0),
            Cluster::paper_heterogeneous(8, 1.0),
        ] {
            let plan = Planner::default().plan_colocated(&a, &b, &cluster);
            let pb = plan.assignment_b.clone().unwrap();
            let mut seen_a = vec![false; 8];
            let mut seen_b = vec![false; 8];
            for e in 0..8 {
                assert!(!seen_a[plan.assignment_a[e]]);
                seen_a[plan.assignment_a[e]] = true;
                assert!(!seen_b[pb[e]]);
                seen_b[pb[e]] = true;
            }
            let pairing = plan.pairing().unwrap();
            let mut seen_p = vec![false; 8];
            for &j in &pairing {
                assert!(!seen_p[j]);
                seen_p[j] = true;
            }
        }
    }

    #[test]
    fn plan_end_to_end_beats_random_plans_colocated_homo() {
        let (a, b) = traces();
        let cluster = Cluster::homogeneous(8, 10.0);
        let plan = Planner::default().plan_colocated(&a, &b, &cluster);
        let t_plan: f64 = plan
            .place_a(&a)
            .iter()
            .zip(plan.place_b(&b))
            .map(|(sa, sb)| {
                simulate_colocated(sa, &sb, &cluster, plan.policy)
                    .0
                    .inference_ms
            })
            .sum();
        let mut rng = Rng::new(0xF00D);
        for _ in 0..10 {
            let p = rng.permutation(8);
            let t_rand: f64 = a
                .layers
                .iter()
                .zip(&b.layers)
                .map(|(sa, sb)| {
                    simulate_colocated(sa, &sb.placed(&p), &cluster, SchedulePolicy::Aurora)
                        .0
                        .inference_ms
                })
                .sum();
            // planned on layer 0 only while layers 1-3 route differently, so
            // allow slack across the 4-layer sum; layer-0 optimality itself
            // is asserted exactly in eval::fig11 tests
            assert!(
                t_plan <= t_rand * 1.15,
                "planned {t_plan} vs random {t_rand}"
            );
        }
    }

    #[test]
    fn exclusive_hetero_plan_beats_random_end_to_end() {
        let (a, _) = traces();
        let cluster = Cluster::paper_heterogeneous(8, 10.0);
        let plan = Planner::default().plan_exclusive(&a, &cluster);
        let t_plan: f64 = plan
            .place_a(&a)
            .iter()
            .map(|l| simulate_exclusive(l, &cluster, plan.policy).0.inference_ms)
            .sum();
        let mut rng = Rng::new(0xBEE);
        for _ in 0..20 {
            let p = rng.permutation(8);
            let t_rand: f64 = a
                .layers
                .iter()
                .map(|l| {
                    simulate_exclusive(&l.placed(&p), &cluster, SchedulePolicy::Aurora)
                        .0
                        .inference_ms
                })
                .sum();
            assert!(t_plan <= t_rand + 1e-9);
        }
    }

    fn zipf_trace(n: usize, n_layers: usize, alpha: f64, seed: u64) -> ModelTrace {
        ModelTrace {
            name: format!("zipf-a{alpha}"),
            // one seed for all layers: the hot expert persists across depth,
            // the regime replication targets
            layers: (0..n_layers)
                .map(|_| MoeLayerStats {
                    traffic: crate::traffic::zipf_traffic(n, 512, alpha, seed),
                    gate_ms: 0.02,
                    ffn_ms_per_token: 0.001,
                    agg_ms: 0.015,
                })
                .collect(),
        }
    }

    #[test]
    fn replicated_plan_falls_back_bitwise_on_uniform_traffic() {
        let t = zipf_trace(16, 2, 0.0, 41);
        let cluster = Cluster::homogeneous(8, 800.0);
        let planner = Planner::default();
        let (rep, splits) = planner
            .plan_replicated(&[&t], &cluster, &ReplicationConfig::default())
            .unwrap();
        assert!(!rep.is_replicated(), "uniform routing must not replicate");
        assert_eq!(splits, SplitPlan::trivial(&rep));
        let plain = planner.plan_multi(&[&t], &cluster).unwrap();
        assert_eq!(rep.base, plain, "fallback must be bit-for-bit");
        assert_eq!(rep, ReplicatedDeployment::from_deployment(plain));
    }

    #[test]
    fn replicated_plan_spreads_the_hot_expert_under_skew() {
        let t = zipf_trace(16, 2, 1.2, 41);
        let cluster = Cluster::homogeneous(8, 800.0);
        let planner = Planner::default();
        let (rep, plan) = planner
            .plan_replicated(&[&t], &cluster, &ReplicationConfig::default())
            .unwrap();
        assert!(rep.is_replicated(), "skewed routing should replicate");
        // the hottest expert got the copies
        let totals = aggregate_totals(&[&t]);
        let loads = totals[0].expert_loads();
        let hot = (0..16).max_by_key(|&e| loads[e]).unwrap();
        assert!(
            rep.replica_count(0, hot) > 1,
            "hot expert {hot} not replicated: {:?}",
            rep.replicas[0]
        );
        // and the split-aware bottleneck estimate improved over the plain plan
        let layers: Vec<&MoeLayerStats> = totals.iter().collect();
        assert_eq!(plan, optimize_splits(&rep, &layers, &cluster));
        let replicated = estimate_per_gpu_replicated(&rep, &layers, &cluster, &plan)
            .into_iter()
            .fold(0.0, f64::max);
        let plain = planner.plan_multi(&[&t], &cluster).unwrap();
        let unreplicated = crate::placement::estimate_bottleneck(&plain, &layers, &cluster);
        assert!(
            replicated < unreplicated,
            "replicated {replicated} vs plain {unreplicated}"
        );
    }

    #[test]
    fn replication_respects_budgets() {
        let t = zipf_trace(16, 2, 1.2, 41);
        let cluster = Cluster::homogeneous(8, 800.0);
        let planner = Planner::default();
        // max_replicas = 1 disables the pass entirely
        let (off, _) = planner
            .plan_replicated(
                &[&t],
                &cluster,
                &ReplicationConfig {
                    max_replicas: 1,
                    ..ReplicationConfig::default()
                },
            )
            .unwrap();
        assert!(!off.is_replicated());
        // a slot budget bounds per-GPU occupancy: replicas and refinement
        // moves never push a GPU past the budget (a GPU the *base* plan
        // already filled beyond it just receives no copies)
        let cfg = ReplicationConfig {
            max_replicas: 8,
            slots_per_gpu: 3,
            ..ReplicationConfig::default()
        };
        let (rep, _) = planner.plan_replicated(&[&t], &cluster, &cfg).unwrap();
        let base_slots = planner.plan_multi(&[&t], &cluster).unwrap().experts_per_gpu();
        for (g, &s) in rep.slots_per_gpu().iter().enumerate() {
            assert!(
                s <= base_slots[g].max(3),
                "GPU {g}: {s} slots exceeds budget (base {})",
                base_slots[g]
            );
        }
        // per-expert cap holds too
        for e in 0..16 {
            assert!(rep.replica_count(0, e) <= 8);
        }
    }

    #[test]
    fn plan_topology_big_switch_is_bit_for_bit() {
        let (a, b) = traces();
        let c = limoe_trace(LimoeVariant::B16, Dataset::Imagenet, 16, 4, 64, 9);
        for cluster in [
            Cluster::homogeneous(8, 10.0),
            Cluster::paper_heterogeneous(8, 10.0),
        ] {
            let planner = Planner::default();
            let flat = planner.plan_multi(&[&a, &b], &cluster).unwrap();
            let topo = planner
                .plan_topology(&[&a, &b], &cluster, &Topology::BigSwitch)
                .unwrap();
            assert_eq!(flat, topo, "BigSwitch fallback must be bit-for-bit");
            // generalized shape too (16 experts on 8 GPUs)
            let flat = planner.plan_multi(&[&c], &cluster).unwrap();
            let topo = planner
                .plan_topology(&[&c], &cluster, &Topology::BigSwitch)
                .unwrap();
            assert_eq!(flat, topo);
        }
    }

    #[test]
    fn plan_replicated_topology_big_switch_is_bit_for_bit() {
        let t = zipf_trace(16, 2, 1.2, 41);
        let cluster = Cluster::homogeneous(8, 800.0);
        let planner = Planner::default();
        let cfg = ReplicationConfig::default();
        let (rep_a, splits_a) = planner.plan_replicated(&[&t], &cluster, &cfg).unwrap();
        let (rep_b, splits_b) = planner
            .plan_replicated_topology(&[&t], &cluster, &Topology::BigSwitch, &cfg)
            .unwrap();
        assert_eq!(rep_a, rep_b);
        assert_eq!(splits_a, splits_b);
    }

    #[test]
    fn plan_topology_localizes_chatty_pairs() {
        // Heavy 0↔2 and 1↔3 flows; identity placement on contiguous groups
        // {0,1} / {2,3} sends all of it across the uplinks. The group-local
        // pass must colocate each chatty pair inside one group.
        let mut d = crate::traffic::TrafficMatrix::zeros(4);
        for (i, j) in [(0, 2), (2, 0), (1, 3), (3, 1)] {
            d.set(i, j, 100);
        }
        for (i, j) in [(0, 1), (1, 0), (2, 3), (3, 2), (0, 3), (3, 0), (1, 2), (2, 1)] {
            d.add(i, j, 1);
        }
        let trace = ModelTrace {
            name: "chatty-pairs".to_string(),
            layers: vec![MoeLayerStats {
                traffic: d,
                gate_ms: 0.1,
                ffn_ms_per_token: 0.01,
                agg_ms: 0.05,
            }],
        };
        let cluster = Cluster::homogeneous(4, 10.0);
        let topo = Topology::even_two_tier(4, 2, 4.0).unwrap();
        let planner = Planner::default();
        let flat = planner.plan_multi(&[&trace], &cluster).unwrap();
        let placed = planner.plan_topology(&[&trace], &cluster, &topo).unwrap();
        let layer = &trace.layers[0];
        let drain_flat =
            uplink_bound(&flat.aggregated_traffic(&[layer]), &cluster, &topo);
        let drain_placed =
            uplink_bound(&placed.aggregated_traffic(&[layer]), &cluster, &topo);
        assert!(
            drain_placed < drain_flat,
            "placed drain {drain_placed} vs flat {drain_flat}"
        );
        // the chatty pairs ended up group-local
        let owner = topo.group_of(4).unwrap();
        assert_eq!(
            owner[placed.gpu_of(0, 0)],
            owner[placed.gpu_of(0, 2)],
            "experts 0 and 2 should share a group: {:?}",
            placed.assignments
        );
        assert_eq!(owner[placed.gpu_of(0, 1)], owner[placed.gpu_of(0, 3)]);
    }

    #[test]
    fn plan_topology_tiered_localizes_chatty_pairs() {
        // 8 GPUs in 4 racks of 2, 2 pods of 2 racks. Chatty expert pairs
        // placed across pods by the identity plan must end up sharing a
        // rack (or at least a pod) after the tier-local pass — and the
        // combined objective must not regress versus the flat plan.
        let mut d = crate::traffic::TrafficMatrix::zeros(8);
        for (i, j) in [(0, 4), (4, 0), (1, 5), (5, 1), (2, 6), (6, 2), (3, 7), (7, 3)] {
            d.set(i, j, 100);
        }
        for i in 0..8usize {
            d.add(i, (i + 1) % 8, 1);
        }
        let trace = ModelTrace {
            name: "tiered-chatty".to_string(),
            layers: vec![MoeLayerStats {
                traffic: d,
                gate_ms: 0.1,
                ffn_ms_per_token: 0.01,
                agg_ms: 0.05,
            }],
        };
        let cluster = Cluster::homogeneous(8, 10.0);
        let topo = Topology::even_tiered(8, &[4, 2], &[2.0, 4.0]).unwrap();
        let planner = Planner::default();
        let flat = planner.plan_multi(&[&trace], &cluster).unwrap();
        let placed = planner.plan_topology(&[&trace], &cluster, &topo).unwrap();
        let layer = &trace.layers[0];
        let drain_flat = uplink_bound(&flat.aggregated_traffic(&[layer]), &cluster, &topo);
        let drain_placed =
            uplink_bound(&placed.aggregated_traffic(&[layer]), &cluster, &topo);
        assert!(
            drain_placed < drain_flat,
            "placed drain {drain_placed} vs flat {drain_flat}"
        );
        let combined = |dep: &Deployment| -> f64 {
            crate::placement::estimate_bottleneck(dep, &[layer], &cluster)
                .max(uplink_bound(&dep.aggregated_traffic(&[layer]), &cluster, &topo))
        };
        assert!(
            combined(&placed) <= combined(&flat) + 1e-6,
            "placed {} vs flat {}",
            combined(&placed),
            combined(&flat)
        );
        // every formerly cross-pod chatty pair now shares a pod
        let pod = topo.owners_at(8, 1).unwrap();
        for (a, b) in [(0usize, 4usize), (1, 5), (2, 6), (3, 7)] {
            assert_eq!(
                pod[placed.gpu_of(0, a)],
                pod[placed.gpu_of(0, b)],
                "experts {a} and {b} should share a pod: {:?}",
                placed.assignments
            );
        }
    }

    #[test]
    fn plan_replicated_topology_tiered_never_worsens_the_objective() {
        let t = zipf_trace(16, 2, 1.2, 23);
        let cluster = Cluster::homogeneous(8, 800.0);
        let topo = Topology::even_tiered(8, &[4, 2], &[2.0, 4.0]).unwrap();
        let planner = Planner::default();
        let (rep, splits) = planner
            .plan_replicated_topology(&[&t], &cluster, &topo, &ReplicationConfig::default())
            .unwrap();
        let totals = aggregate_totals(&[&t]);
        let layers: Vec<&MoeLayerStats> = totals.iter().collect();
        let replicated = estimate_objective_on(&rep, &layers, &cluster, &topo, &splits);
        let base = planner.plan_topology(&[&t], &cluster, &topo).unwrap();
        let base_obj = crate::placement::estimate_bottleneck(&base, &layers, &cluster)
            .max(uplink_bound(&base.aggregated_traffic(&layers), &cluster, &topo));
        assert!(
            replicated <= base_obj + 1e-9,
            "replicated {replicated} vs base {base_obj}"
        );
        assert_eq!(splits, optimize_splits(&rep, &layers, &cluster));
    }

    #[test]
    fn plan_topology_rejects_mismatched_topologies_without_panicking() {
        let (a, _) = traces();
        let cluster = Cluster::homogeneous(8, 10.0);
        // valid 16-GPU topology, 8-GPU cluster: a typed error, not a panic
        let topo = Topology::even_two_tier(16, 4, 2.0).unwrap();
        let err = Planner::default()
            .plan_topology(&[&a], &cluster, &topo)
            .unwrap_err();
        assert!(
            matches!(err, PlacementError::InvalidTopology { .. }),
            "{err:?}"
        );
        assert!(err.to_string().contains("topology"), "{err}");
        // the replication surface routes through the same validation
        let err = Planner::default()
            .plan_replicated_topology(&[&a], &cluster, &topo, &ReplicationConfig::default())
            .unwrap_err();
        assert!(matches!(err, PlacementError::InvalidTopology { .. }));
    }

    #[test]
    fn plan_topology_never_worsens_the_combined_objective() {
        use crate::placement::estimate_bottleneck;
        let (a, b) = traces();
        let cluster = Cluster::homogeneous(8, 10.0);
        let topo = Topology::even_two_tier(8, 4, 4.0).unwrap();
        let planner = Planner::default();
        let flat = planner.plan_multi(&[&a, &b], &cluster).unwrap();
        let placed = planner.plan_topology(&[&a, &b], &cluster, &topo).unwrap();
        let totals = aggregate_totals(&[&a, &b]);
        let layers: Vec<&MoeLayerStats> = totals.iter().collect();
        let combined = |dep: &Deployment| -> f64 {
            estimate_bottleneck(dep, &layers, &cluster)
                .max(uplink_bound(&dep.aggregated_traffic(&layers), &cluster, &topo))
        };
        let c_flat = combined(&flat);
        let c_placed = combined(&placed);
        assert!(
            c_placed <= c_flat + 1e-6,
            "placed {c_placed} vs flat {c_flat}"
        );
    }

    #[test]
    fn refinement_never_regresses_the_uplink_drain() {
        // Regression test for the drain-tracking fix: `refine_deployment`
        // once tracked `cur_drain.min(nd)`, so an accept whose recomputed
        // drain landed inside the 1e-9 tolerance left the tracked value
        // stale-low and later accepts could compound a real regression the
        // guard never saw. The DeltaEstimator reads the actual counters, so
        // across a whole refinement the drain can drift only by the
        // per-accept tolerance — and the port objective never worsens.
        for seed in 0..12u64 {
            let mut rng = Rng::new(0xD00D + seed);
            let n_gpus = 8;
            let mut d = crate::traffic::TrafficMatrix::zeros(16);
            for i in 0..16 {
                for j in 0..16 {
                    if i != j {
                        d.set(i, j, rng.gen_range(40));
                    }
                }
            }
            let trace = ModelTrace {
                name: format!("drain-{seed}"),
                layers: vec![MoeLayerStats {
                    traffic: d,
                    gate_ms: 0.02,
                    ffn_ms_per_token: 0.001,
                    agg_ms: 0.015,
                }],
            };
            let cluster = Cluster::homogeneous(n_gpus, 50.0);
            let topo = Topology::even_two_tier(n_gpus, 4, 4.0).unwrap();
            let assignment: Vec<usize> = (0..16)
                .map(|_| rng.gen_range(n_gpus as u64) as usize)
                .collect();
            let mut dep = Deployment::new(
                n_gpus,
                vec![assignment],
                SchedulePolicy::Aurora,
                Scenario::ExclusiveHomogeneous,
            )
            .unwrap();
            let totals = aggregate_totals(&[&trace]);
            let layers: Vec<&MoeLayerStats> = totals.iter().collect();
            let drain_before = uplink_bound(&dep.aggregated_traffic(&layers), &cluster, &topo);
            let port_before = crate::placement::estimate_bottleneck(&dep, &layers, &cluster);
            refine_deployment(&mut dep, &layers, &cluster, &topo, &Tracer::disabled());
            let drain_after = uplink_bound(&dep.aggregated_traffic(&layers), &cluster, &topo);
            let port_after = crate::placement::estimate_bottleneck(&dep, &layers, &cluster);
            assert!(
                port_after <= port_before + 1e-9,
                "seed {seed}: port {port_before} -> {port_after}"
            );
            assert!(
                drain_after <= drain_before + 1e-6,
                "seed {seed}: drain {drain_before} -> {drain_after}"
            );
        }
    }

    #[test]
    fn lazy_greedy_never_worsens_the_objective() {
        // 64 experts on 32 GPUs crosses the lazy (CELF) threshold. The lazy
        // loop only commits candidates whose exactly re-priced objective
        // clears the min_gain threshold, so whatever the queue order, the
        // final plan's objective must not exceed the base (un-replicated)
        // plan's.
        for seed in [7u64, 41, 99] {
            let t = zipf_trace(64, 2, 1.2, seed);
            let cluster = Cluster::homogeneous(32, 800.0);
            let planner = Planner::default();
            let (rep, splits) = planner
                .plan_replicated(&[&t], &cluster, &ReplicationConfig::default())
                .unwrap();
            let totals = aggregate_totals(&[&t]);
            let layers: Vec<&MoeLayerStats> = totals.iter().collect();
            let replicated =
                estimate_objective_on(&rep, &layers, &cluster, &Topology::BigSwitch, &splits);
            let plain = planner.plan_multi(&[&t], &cluster).unwrap();
            let base = crate::placement::estimate_bottleneck(&plain, &layers, &cluster);
            assert!(
                replicated <= base + 1e-9,
                "seed {seed}: replicated {replicated} vs base {base}"
            );
        }
    }

    #[test]
    fn plan_json_renders() {
        let (a, b) = traces();
        let plan = Planner::default().plan_colocated(&a, &b, &Cluster::homogeneous(8, 1.0));
        let j = plan.to_json();
        assert_eq!(
            j.get("scenario").unwrap().as_str(),
            Some("colocating+homogeneous")
        );
        assert_eq!(j.get("assignment_b").unwrap().as_arr().unwrap().len(), 8);
    }
}
