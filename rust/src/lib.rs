//! # Aurora — MoE inference optimization via model deployment and communication scheduling
//!
//! Reproduction of *"Optimizing Mixture-of-Experts Inference Time Combining Model
//! Deployment and Communication Scheduling"* (Li et al., 2024).
//!
//! Aurora minimizes MoE inference time by jointly deciding:
//!
//! 1. **Communication scheduling** ([`schedule`]) — the order in which tokens are
//!    transmitted during the two all-to-all collectives of an MoE layer. Aurora's
//!    schedule (Alg. 1 / Theorem 4.2) is contention-free at the receivers and
//!    achieves the lower bound `b_max = max(row sums, col sums) / B`.
//! 2. **GPU assignment** ([`assignment`]) — on heterogeneous clusters, which expert
//!    goes on which GPU type (Theorem 5.1: sort experts by load, GPUs by
//!    performance, match in order).
//! 3. **Expert colocation** ([`colocation`]) — which experts of *two different* MoE
//!    models share a GPU, so that one model computes while the other communicates
//!    (Theorem 6.2 / bottleneck matching; NP-hard decoupled heuristic in the
//!    heterogeneous case, §7.2).
//!
//! The crate also ships the substrates the paper's evaluation depends on: a
//! big-switch cluster simulator ([`sim`], [`cluster`]), LIMoE-like trace generation
//! ([`trace`]), a deployment planner ([`planner`]), a serving runtime with a PJRT
//! executor that runs the AOT-compiled JAX/Pallas MoE layer ([`serve`],
//! [`runtime`]), and an evaluation harness regenerating every figure of the paper
//! ([`eval`]).

pub mod assignment;
pub mod cluster;
pub mod colocation;
pub mod config;
pub mod eval;
pub mod matching;
pub mod planner;
pub mod runtime;
pub mod schedule;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod traffic;
pub mod util;

pub use cluster::{Cluster, GpuSpec};
pub use planner::{DeploymentPlan, Planner, Scenario};
pub use traffic::TrafficMatrix;
