//! # Aurora — MoE inference optimization via model deployment and communication scheduling
//!
//! Reproduction and extension of *"Optimizing Mixture-of-Experts Inference
//! Time Combining Model Deployment and Communication Scheduling"* (Li et
//! al., 2024), grown toward a production-shaped serving stack.
//!
//! Aurora minimizes MoE inference time by jointly deciding **where experts
//! live** and **in what order tokens move**:
//!
//! 1. **Communication scheduling** ([`schedule`]) — the order in which
//!    tokens are transmitted during the two all-to-all collectives of an MoE
//!    layer. Aurora's slot schedule (Alg. 1 / Theorem 4.2) is
//!    contention-free at every receiver and achieves the lower bound
//!    `b_max = max(row sums, col sums) / B`; a validator
//!    ([`schedule::validate_slot_schedule`]) machine-checks every schedule.
//! 2. **Placement** ([`placement`]) — the generalized core. A
//!    [`placement::Deployment`] maps `(model, expert)` → GPU with **no shape
//!    restrictions**: any number of colocated models, several experts per
//!    GPU, and per-model expert counts independent of the cluster size. The
//!    paper's one/two-model shapes are the special cases the theorems cover;
//!    [`placement::Scenario`] is the (extended) Fig. 2 decision tree that
//!    picks the right path.
//! 3. **Assignment** ([`assignment`]) — on heterogeneous clusters, which
//!    expert goes on which GPU type (Theorem 5.1: sort experts by load, GPUs
//!    by performance, match in order).
//! 4. **Colocation** ([`colocation`]) — which experts of different models
//!    share a GPU so one model computes while another communicates
//!    (Theorem 6.2 / bottleneck matching; NP-hard decoupled heuristic in the
//!    heterogeneous case, §7.2). [`planner::Planner::plan_multi`] stacks
//!    these pairwise matchings iteratively to place M ≥ 3 models.
//! 5. **Replication** ([`replication`]) — beyond the paper: under skewed
//!    routing a single hot expert pins one GPU's compute and receive port,
//!    which no transmission ordering can fix.
//!    [`planner::Planner::plan_replicated`] copies hot experts onto several
//!    GPUs and a water-filling token-split plan
//!    ([`replication::optimize_splits`]) apportions each sender's load
//!    across the copies; with no replicas the path is bit-for-bit the plain
//!    placement pipeline.
//! 6. **Hierarchical scheduling** ([`schedule::hierarchical_schedule`]) —
//!    beyond the paper's big switch: on a two-tier leaf/spine fabric
//!    ([`cluster::Topology::TwoTier`]) with oversubscribed uplinks, the flat
//!    order loses contention-freedom at the uplinks. The two-phase schedule
//!    runs Aurora within each group at port rate, slot-schedules the
//!    residual cross-group traffic via a group-level BvN decomposition with
//!    designated gateway senders, and stitches the phases with a pipelined
//!    makespan estimate ([`schedule::comm_time_on`]).
//!    [`planner::Planner::plan_topology`] places experts to keep token flow
//!    inside the fast domain first (falling back bit-for-bit to the flat
//!    planner on [`cluster::Topology::BigSwitch`]).
//! 7. **Online coordination** ([`coordinator`]) — the paper plans for one
//!    traffic matrix; production routing drifts. The [`coordinator::Coordinator`]
//!    tracks the live distribution (EWMA + total-variation drift scoring),
//!    replans on the live estimate only when the predicted inference-time
//!    gain exceeds the cost of migrating expert weights (scheduled over the
//!    same per-GPU links with the slot scheduler), and swaps plans hitlessly
//!    (stage → atomic swap → drain). Under stationary routing it never
//!    touches the plan.
//! 8. **Fault tolerance & elasticity** ([`coordinator::ClusterEvent`]) —
//!    membership is dynamic: on a GPU failure the coordinator promotes the
//!    dead GPU's surviving replicas **in the failure window** (split
//!    weights re-solved, no planner call — zero downtime, no token ever
//!    routed to a dead GPU) and stages a full repair replan behind it with
//!    dead GPUs banned as migration sources; drains vacate a GPU over the
//!    migration path while it keeps serving, and joins rebalance back.
//!    With [`CoordinatorConfig::elastic`] the replica budget grows under
//!    sustained SLO burn and the fleet consolidates onto fewer GPUs when
//!    utilization stays low. The `eval resilience` figure pins recovery to
//!    within 1.15× of a fresh-plan oracle within 5 windows of a failure.
//! 9. **Gray-failure robustness** ([`obs::degrade`]) — stragglers and
//!    degraded links don't trip membership events; they only stretch
//!    barriers. The [`obs::degrade::DegradationDetector`] infers per-GPU
//!    effective compute/bandwidth scales by ratioing each served window's
//!    recorded timeline against a nominal-rate re-simulation of the same
//!    traffic (EWMA-smoothed, 0.9/0.97 hysteresis bands, multi-window
//!    confirmation — the coordinator is never told the injected truth), and
//!    [`Coordinator::observe_degradation`] replans on the effective cluster
//!    with migrations priced at the degraded link rates; scales below the
//!    severity floor escalate into the promote-then-repair failure path.
//!    The `eval straggler` figure pins detector-driven recovery to within
//!    1.25× of an oracle-informed plan within 6 windows of a 0.4× compute
//!    straggler, and a noise-only trace provably never replans.
//!
//! The crate also ships the substrates the evaluation depends on: a
//! big-switch cluster simulator ([`sim`], [`cluster`]) whose generalized
//! entry point [`sim::simulate_group`] serializes compute across all
//! colocated experts of a GPU and aggregates per-GPU traffic before
//! scheduling; LIMoE-like trace generation ([`trace`]); the deployment
//! planner ([`planner`]); a serving runtime with a PJRT executor
//! ([`serve`], [`runtime`]); and an evaluation harness regenerating every
//! figure of the paper plus the multi-model extension ([`eval`]).
//!
//! Planning scales to hundred-GPU clusters through the **incremental
//! planning engine**: [`placement::DeltaEstimator`] and
//! [`replication::ReplicaDeltaEstimator`] maintain the planner's objectives
//! as exact integer token counters under moves, swaps, and replica
//! additions, and [`planner::Planner::plan_replicated`] runs a lazy-greedy
//! (CELF-style) candidate queue on top — with a `rayon` cargo feature for
//! the parallel (deterministically reduced) exact first sweep
//! ([`util::par::par_map`]).
//!
//! And to **thousand-GPU pods** through three further mechanisms, each
//! preserving small-scale results bit-for-bit: [`traffic::TrafficMatrix`]
//! stores sparse (CSR-style) or dense by density behind one API, so hot
//! paths walk nonzeros ([`traffic::TrafficMatrix::row_iter`]) instead of
//! `n²` cells; the BvN decomposition parallelizes its matching repair with
//! a deterministic index-ordered reduction (and
//! [`schedule::aurora_schedule_approx`] offers an explicit ε-approximate
//! early-out); and [`cluster::Topology::Tiered`] generalizes the fabric to
//! recursive rack/pod/core levels, scheduled per tier
//! ([`schedule::hierarchical_schedule`]) and planned tier-locally
//! ([`planner::Planner::plan_topology`]). The 1024-GPU plan + schedule is
//! gated under one second by the committed bench baseline.
//!
//! Everything above is observable from the inside: the [`obs`] subsystem
//! provides span tracing ([`obs::Tracer`], wall-clock for the planner,
//! sim-time for the discrete-event simulators, exported as Chrome
//! trace-event JSON or JSONL), a metrics registry
//! ([`obs::MetricsRegistry`]: counters, gauges, log-bucketed histograms),
//! and structured decision logs explaining every planner phase and every
//! coordinator replan verdict. Instrumentation is permanently wired through
//! the `*_traced` planner/scheduler entry points; the `disabled()` handles
//! are total no-ops and tracing never changes results (pinned bit-for-bit
//! by an integration property test). The CLI `profile` subcommand
//! ([`obs::run_profile`]) renders the per-phase time breakdown of a full
//! plan + schedule run.
//!
//! The same discipline extends to the simulators: every one has a
//! `*_recorded` twin threading an [`obs::timeline::TimelineRecorder`] that
//! attributes **every GPU-millisecond** of a simulated layer to a typed
//! segment — compute, comm-send/-recv, sync-wait on a collective barrier,
//! swap-drain of staged migration weights, trailing idle — per GPU engine
//! and per access link ([`obs::timeline::Timelines`]: utilization,
//! per-kind breakdown, Chrome-trace export). On top of it sit the
//! `eval utilization` figure (exclusive vs colocated vs colocated+Aurora
//! with the idle time itemized, §7) and the coordinator's **SLO watchdog**
//! ([`obs::SloMonitor`]): rolling p50/p95/p99 over window latencies whose
//! p99 violations override the drift/gain/cost gates and force a replan
//! (verdicts `slo_triggered` / `slo_suppressed_cooldown` in the decision
//! log).
//!
//! See `docs/architecture.md` for the layer map, the Scenario decision tree,
//! the "Hierarchical scheduling" section (two-tier topologies, the two-phase
//! decomposition, and the uplink bounds), the "Performance & incremental
//! planning" section (complexity table, lazy-greedy invariants, rebuild
//! points), the "Scaling to 1024 GPUs" section (sparse storage contract,
//! parallel-BvN determinism, recursive tiers, the tier-local planner), the
//! "Utilization accounting & SLO watchdog" section (segment taxonomy,
//! recorder contract, SLO-vs-drift trigger semantics), the "Fault tolerance
//! & elasticity" section (event model, the promote-then-repair two-phase
//! contract, elasticity triggers), the "Gray failures & stragglers" section
//! (truth model, detection math, effective-rate replanning, escalation
//! floor), and which code paths are exact versus heuristic.

pub mod assignment;
pub mod cluster;
pub mod colocation;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod matching;
pub mod obs;
pub mod placement;
pub mod planner;
pub mod replication;
pub mod runtime;
pub mod schedule;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod traffic;
pub mod util;

pub use cluster::{Cluster, GpuSpec, Topology, TopologyError};
pub use coordinator::{Coordinator, CoordinatorConfig};
pub use obs::{MetricsRegistry, Tracer};
pub use placement::{Deployment, PlacementError};
pub use planner::{DeploymentPlan, Planner, ReplicationConfig, Scenario};
pub use replication::{ReplicatedDeployment, SplitPlan};
pub use traffic::TrafficMatrix;
