//! Discrete-event cross-validation of the closed-form timelines.
//!
//! The exclusive layer model (Eqn. 3) and the colocated Table 2 recurrences
//! are *analytic*; this module executes the same layer as an explicit
//! discrete-event simulation — tasks with dependencies competing for per-GPU
//! compute engines and a shared barrier-synchronized network — and the test
//! suite asserts the two agree. It also exposes the per-GPU busy intervals
//! that back the utilization metric.
//!
//! Execution semantics (paper §2.2, §6.1):
//! * each GPU has **one compute engine**; compute tasks of colocated models
//!   serialize on it in dependency order;
//! * each all-to-all is a **synchronous collective**: it starts when all of
//!   its producer tasks finished and occupies the switch for its makespan
//!   (from [`crate::schedule::comm_time`]); collectives of *different*
//!   models may overlap, but a model's own collectives are ordered;
//! * a phase's consumers start only when the collective completes (the
//!   non-overlap constraint within a model).

use crate::cluster::Cluster;
use crate::obs::timeline::TimelineRecorder;
use crate::schedule::{aurora_schedule, comm_time, SchedulePolicy};
use crate::sim::MoeLayerStats;

/// One simulated task's execution record.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskTrace {
    /// Task label (e.g. `"F^a@3"` or `"N^b"`).
    pub label: String,
    /// Start time (ms).
    pub start: f64,
    /// End time (ms).
    pub end: f64,
}

/// Result of an event-driven layer execution.
#[derive(Debug, Clone, PartialEq)]
pub struct EventSimResult {
    /// Layer completion time (ms).
    pub makespan: f64,
    /// Per-GPU total compute-busy time (ms) — drives utilization.
    pub compute_busy: Vec<f64>,
    /// Every executed task, in completion order.
    pub tasks: Vec<TaskTrace>,
}

/// Per-GPU compute engine availability.
struct Engines {
    free_at: Vec<f64>,
    busy: Vec<f64>,
}

impl Engines {
    fn new(n: usize) -> Self {
        Self {
            free_at: vec![0.0; n],
            busy: vec![0.0; n],
        }
    }

    /// Run a compute task of `dur` on GPU `g`, ready at `ready`. Returns the
    /// task's end time.
    fn run(&mut self, g: usize, ready: f64, dur: f64) -> f64 {
        let start = self.free_at[g].max(ready);
        let end = start + dur;
        self.free_at[g] = end;
        self.busy[g] += dur;
        end
    }

    /// [`Engines::run`] mirrored into the timeline recorder.
    fn run_rec(
        &mut self,
        rec: &mut TimelineRecorder,
        model: usize,
        g: usize,
        ready: f64,
        dur: f64,
    ) -> f64 {
        let start = self.free_at[g].max(ready);
        let end = self.run(g, ready, dur);
        rec.record_compute(g, model, start, end);
        end
    }
}

/// Event-driven execution of one **exclusive** MoE layer (stats GPU-indexed).
pub fn event_sim_exclusive(
    stats: &MoeLayerStats,
    cluster: &Cluster,
    policy: SchedulePolicy,
) -> EventSimResult {
    event_sim_exclusive_recorded(stats, cluster, policy, &mut TimelineRecorder::disabled())
}

/// [`event_sim_exclusive`] with timeline recording through `rec`
/// (observational only).
pub fn event_sim_exclusive_recorded(
    stats: &MoeLayerStats,
    cluster: &Cluster,
    policy: SchedulePolicy,
    rec: &mut TimelineRecorder,
) -> EventSimResult {
    let n = stats.n_experts();
    assert_eq!(n, cluster.len());
    let bw = cluster.bandwidths();
    let mut engines = Engines::new(n);
    let mut tasks = Vec::new();

    let loads = stats.expert_loads();
    let gate_end: Vec<f64> = (0..n)
        .map(|g| {
            let end = engines.run_rec(rec, 0, g, 0.0, stats.gate_ms / cluster.gpu(g).flops_scale);
            tasks.push(TaskTrace {
                label: format!("G@{g}"),
                start: end - stats.gate_ms / cluster.gpu(g).flops_scale,
                end,
            });
            end
        })
        .collect();

    // First all-to-all: synchronous collective after every gate finishes.
    let n_ready = gate_end.iter().cloned().fold(0.0, f64::max);
    let comm1 = comm_time(&stats.traffic, &bw, policy);
    let n_end = n_ready + comm1.makespan;
    tasks.push(TaskTrace {
        label: "N".into(),
        start: n_ready,
        end: n_end,
    });

    // FFN per GPU after the collective completes.
    let ffn_end: Vec<f64> = (0..n)
        .map(|g| {
            let dur = loads[g] as f64 * stats.ffn_ms_per_token / cluster.gpu(g).flops_scale;
            let end = engines.run_rec(rec, 0, g, n_end, dur);
            tasks.push(TaskTrace {
                label: format!("F@{g}"),
                start: end - dur,
                end,
            });
            end
        })
        .collect();

    // Second all-to-all (reversed), then aggregation.
    let c_ready = ffn_end.iter().cloned().fold(0.0, f64::max);
    let comm2 = comm_time(&stats.traffic.transpose(), &bw, policy);
    let c_end = c_ready + comm2.makespan;
    tasks.push(TaskTrace {
        label: "C".into(),
        start: c_ready,
        end: c_end,
    });

    let agg_end: Vec<f64> = (0..n)
        .map(|g| {
            let dur = stats.agg_ms / cluster.gpu(g).flops_scale;
            let end = engines.run_rec(rec, 0, g, c_end, dur);
            tasks.push(TaskTrace {
                label: format!("A@{g}"),
                start: end - dur,
                end,
            });
            end
        })
        .collect();

    let makespan = agg_end.iter().cloned().fold(0.0, f64::max);
    if rec.is_enabled() {
        let reversed = stats.traffic.transpose();
        rec.record_comm(0, n_ready, n_end, &stats.traffic, &bw);
        rec.record_comm(0, c_ready, c_end, &reversed, &bw);
        if matches!(policy, SchedulePolicy::Aurora) {
            rec.record_rounds("N", &aurora_schedule(&stats.traffic));
            rec.record_rounds("C", &aurora_schedule(&reversed));
        }
        rec.set_makespan(makespan);
    }
    EventSimResult {
        makespan,
        compute_busy: engines.busy,
        tasks,
    }
}

/// Event-driven execution of one **colocated** layer pair (both GPU-indexed),
/// following the Fig. 7 interleaving: `G^b ∥ N^a`, `F^a ∥ N^b`, `F^b ∥ C^a`,
/// `A^a ∥ C^b`, `A^b`, closing with `G^a`.
pub fn event_sim_colocated(
    a: &MoeLayerStats,
    b: &MoeLayerStats,
    cluster: &Cluster,
    policy: SchedulePolicy,
) -> EventSimResult {
    event_sim_colocated_recorded(a, b, cluster, policy, &mut TimelineRecorder::disabled())
}

/// [`event_sim_colocated`] with timeline recording through `rec`
/// (observational only; model 0 = `a`, model 1 = `b`).
pub fn event_sim_colocated_recorded(
    a: &MoeLayerStats,
    b: &MoeLayerStats,
    cluster: &Cluster,
    policy: SchedulePolicy,
    rec: &mut TimelineRecorder,
) -> EventSimResult {
    let n = a.n_experts();
    assert_eq!(n, b.n_experts());
    assert_eq!(n, cluster.len());
    let bw = cluster.bandwidths();
    let mut engines = Engines::new(n);
    let mut tasks = Vec::new();
    let max = |v: &[f64]| v.iter().copied().fold(0.0, f64::max);

    let loads_a = a.expert_loads();
    let loads_b = b.expert_loads();
    let scale = |t: f64, g: usize| t / cluster.gpu(g).flops_scale;

    // G^b on every GPU at t=0; N^a occupies the switch from t=0.
    let gate_b_end: Vec<f64> = (0..n)
        .map(|g| engines.run_rec(rec, 1, g, 0.0, scale(b.gate_ms, g)))
        .collect();
    let e_gate_b = max(&gate_b_end);
    tasks.push(TaskTrace {
        label: "G^b".into(),
        start: 0.0,
        end: e_gate_b,
    });

    let n_a = comm_time(&a.traffic, &bw, policy).makespan;
    let e_n_a = n_a;
    tasks.push(TaskTrace {
        label: "N^a".into(),
        start: 0.0,
        end: e_n_a,
    });

    // F^a: needs N^a done and the GPU free (G^b holds it).
    let f_a_end: Vec<f64> = (0..n)
        .map(|g| {
            engines.run_rec(
                rec,
                0,
                g,
                e_n_a,
                scale(loads_a[g] as f64 * a.ffn_ms_per_token, g),
            )
        })
        .collect();
    let e_f_a = max(&f_a_end);
    tasks.push(TaskTrace {
        label: "F^a".into(),
        start: e_n_a,
        end: e_f_a,
    });

    // N^b: gate^b produced it; shares the switch with N^a — the pair drains
    // at the aggregated makespan (footnote 4 start constraint included).
    let n_b = comm_time(&b.traffic, &bw, policy).makespan;
    let agg_n = comm_time(&a.traffic.sum(&b.traffic), &bw, policy).makespan;
    let e_n_b = agg_n.max(e_gate_b + n_b);
    tasks.push(TaskTrace {
        label: "N^b".into(),
        start: e_gate_b,
        end: e_n_b,
    });

    // F^b: data at E_{N^b}; engine busy with F^a.
    let f_b_end: Vec<f64> = (0..n)
        .map(|g| {
            engines.run_rec(
                rec,
                1,
                g,
                e_n_b,
                scale(loads_b[g] as f64 * b.ffn_ms_per_token, g),
            )
        })
        .collect();
    let e_f_b = max(&f_b_end);
    tasks.push(TaskTrace {
        label: "F^b".into(),
        start: e_n_b,
        end: e_f_b,
    });

    // C^a: F^a outputs, after the N phase drains the switch.
    let c_a = comm_time(&a.traffic.transpose(), &bw, policy).makespan;
    let e_c_a = e_f_a.max(e_n_b) + c_a;
    tasks.push(TaskTrace {
        label: "C^a".into(),
        start: e_f_a.max(e_n_b),
        end: e_c_a,
    });

    // A^a after C^a, competing with F^b for the engine.
    let a_a_end: Vec<f64> = (0..n)
        .map(|g| engines.run_rec(rec, 0, g, e_c_a, scale(a.agg_ms, g)))
        .collect();
    let e_a_a = max(&a_a_end);
    tasks.push(TaskTrace {
        label: "A^a".into(),
        start: e_c_a,
        end: e_a_a,
    });

    // C^b: F^b outputs; the C phase in aggregate needs agg_c after the N
    // phase drained.
    let c_b = comm_time(&b.traffic.transpose(), &bw, policy).makespan;
    let agg_c = comm_time(
        &a.traffic.transpose().sum(&b.traffic.transpose()),
        &bw,
        policy,
    )
    .makespan;
    let e_c_b = (e_f_b + c_b).max(e_f_a.max(e_n_b) + agg_c);
    tasks.push(TaskTrace {
        label: "C^b".into(),
        start: e_f_b,
        end: e_c_b,
    });

    // A^b after C^b and A^a.
    let a_b_end: Vec<f64> = (0..n)
        .map(|g| engines.run_rec(rec, 1, g, e_c_b, scale(b.agg_ms, g)))
        .collect();
    let e_a_b = max(&a_b_end);
    tasks.push(TaskTrace {
        label: "A^b".into(),
        start: e_c_b,
        end: e_a_b,
    });

    // Next layer's G^a closes the round (Eqn. 4).
    let g_a_end: Vec<f64> = (0..n)
        .map(|g| engines.run_rec(rec, 0, g, e_a_b, scale(a.gate_ms, g)))
        .collect();
    let makespan = max(&g_a_end);
    tasks.push(TaskTrace {
        label: "G^a".into(),
        start: e_a_b,
        end: makespan,
    });

    if rec.is_enabled() {
        // Comm windows in chronological start order (N^a, N^b, C^a, C^b —
        // the C^a floor max(E_{F^a}, E_{N^b}) never exceeds E_{F^b}).
        let rev_a = a.traffic.transpose();
        let rev_b = b.traffic.transpose();
        rec.record_comm(0, 0.0, e_n_a, &a.traffic, &bw);
        rec.record_comm(1, e_gate_b, e_n_b, &b.traffic, &bw);
        rec.record_comm(0, e_f_a.max(e_n_b), e_c_a, &rev_a, &bw);
        rec.record_comm(1, e_f_b, e_c_b, &rev_b, &bw);
        if matches!(policy, SchedulePolicy::Aurora) {
            rec.record_rounds("N", &aurora_schedule(&a.traffic.sum(&b.traffic)));
            rec.record_rounds("C", &aurora_schedule(&rev_a.sum(&rev_b)));
        }
        rec.set_makespan(makespan);
    }

    EventSimResult {
        makespan,
        compute_busy: engines.busy,
        tasks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate_colocated, simulate_exclusive};
    use crate::traffic::TrafficMatrix;
    use crate::util::Rng;

    fn toy(n: usize, seed: u64) -> MoeLayerStats {
        let mut rng = Rng::new(seed);
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    d.set(i, j, rng.gen_range(25) + 1);
                }
            }
        }
        MoeLayerStats {
            traffic: d,
            gate_ms: 0.2,
            ffn_ms_per_token: 0.05,
            agg_ms: 0.1,
        }
    }

    #[test]
    fn exclusive_event_sim_matches_closed_form() {
        for seed in 0..20 {
            let s = toy(6, seed);
            for cluster in [
                Cluster::homogeneous(6, 1.5),
                {
                    // a hand-built heterogeneous 6-GPU cluster
                    let mut gpus = Cluster::homogeneous(6, 1.0).gpus().to_vec();
                    for (k, g) in gpus.iter_mut().enumerate() {
                        g.flops_scale = 1.0 - 0.1 * k as f64;
                        g.bandwidth = 1.0 - 0.1 * k as f64;
                    }
                    Cluster::new(gpus)
                },
            ] {
                let (closed, _) = simulate_exclusive(&s, &cluster, SchedulePolicy::Aurora);
                let event = event_sim_exclusive(&s, &cluster, SchedulePolicy::Aurora);
                assert!(
                    (closed.inference_ms - event.makespan).abs() < 1e-9,
                    "seed {seed}: closed {} vs event {}",
                    closed.inference_ms,
                    event.makespan
                );
            }
        }
    }

    #[test]
    fn colocated_event_sim_matches_table2_recurrences() {
        for seed in 0..20 {
            let a = toy(5, seed * 2 + 1);
            let b = toy(5, seed * 2 + 2);
            let cluster = Cluster::homogeneous(5, 2.0);
            let (closed, _) = simulate_colocated(&a, &b, &cluster, SchedulePolicy::Aurora);
            let event = event_sim_colocated(&a, &b, &cluster, SchedulePolicy::Aurora);
            assert!(
                (closed.inference_ms - event.makespan).abs() < 1e-6,
                "seed {seed}: closed {} vs event {}",
                closed.inference_ms,
                event.makespan
            );
        }
    }

    #[test]
    fn event_sim_busy_time_matches_utilization_accounting() {
        let s = toy(4, 3);
        let cluster = Cluster::homogeneous(4, 1.0);
        let (closed, breakdown) = simulate_exclusive(&s, &cluster, SchedulePolicy::Aurora);
        let event = event_sim_exclusive(&s, &cluster, SchedulePolicy::Aurora);
        for g in 0..4 {
            assert!(
                (event.compute_busy[g] - breakdown.per_gpu_compute_ms[g]).abs() < 1e-9,
                "gpu {g}"
            );
        }
        let util = event.compute_busy.iter().sum::<f64>() / 4.0 / event.makespan;
        assert!((util - closed.utilization).abs() < 1e-9);
    }

    #[test]
    fn task_traces_are_causally_ordered() {
        let a = toy(4, 7);
        let b = toy(4, 8);
        let cluster = Cluster::homogeneous(4, 1.0);
        let event = event_sim_colocated(&a, &b, &cluster, SchedulePolicy::Aurora);
        for t in &event.tasks {
            assert!(t.end >= t.start, "{}", t.label);
            assert!(t.end <= event.makespan + 1e-9, "{}", t.label);
        }
        // the phase structure: N^a starts at 0, G^a ends last
        assert_eq!(event.tasks.first().map(|t| t.label.as_str()), Some("G^b"));
        assert_eq!(event.tasks.last().map(|t| t.label.as_str()), Some("G^a"));
    }
}
