//! Per-layer model statistics — Aurora's optimization inputs (Table 1).

use crate::traffic::TrafficMatrix;

/// Historical statistics of one MoE layer of one model (paper Table 1):
/// the first all-to-all traffic matrix `D_N` (the second is its transpose,
/// §2.2) and the component compute times on the reference GPU.
///
/// The matrix is **expert-indexed**: entry `(i, j)` counts tokens that
/// originate at expert `i`'s GPU and are routed to expert `j`. Placing the
/// model onto GPUs relabels both dimensions
/// ([`TrafficMatrix::permute`]).
#[derive(Debug, Clone, PartialEq)]
pub struct MoeLayerStats {
    /// First all-to-all traffic matrix (tokens), expert-indexed.
    pub traffic: TrafficMatrix,
    /// Gate time on the reference GPU (ms) — identical across GPUs
    /// (observation 2, §4.1).
    pub gate_ms: f64,
    /// FFN time per token on the reference GPU (ms/token) — FFN time scales
    /// with the expert's token load (observation 3).
    pub ffn_ms_per_token: f64,
    /// Aggregation time on the reference GPU (ms).
    pub agg_ms: f64,
}

impl MoeLayerStats {
    /// Number of experts (== GPUs the model spans).
    pub fn n_experts(&self) -> usize {
        self.traffic.n()
    }

    /// Per-expert token loads (FFN input volume, diagonal included).
    pub fn expert_loads(&self) -> Vec<u64> {
        self.traffic.expert_loads()
    }

    /// The layer statistics with experts relabelled onto GPUs via `perm`
    /// (`perm[e]` = GPU of expert `e`).
    pub fn placed(&self, perm: &[usize]) -> MoeLayerStats {
        MoeLayerStats {
            traffic: self.traffic.permute(perm),
            ..*self
        }
    }

    /// Total FFN compute (reference-GPU ms) across all experts — used for
    /// utilization accounting.
    pub fn total_ffn_ms(&self) -> f64 {
        self.expert_loads().iter().sum::<u64>() as f64 * self.ffn_ms_per_token
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> MoeLayerStats {
        MoeLayerStats {
            traffic: TrafficMatrix::from_nested(&[vec![1, 2], vec![3, 4]]).unwrap(),
            gate_ms: 0.5,
            ffn_ms_per_token: 0.1,
            agg_ms: 0.2,
        }
    }

    #[test]
    fn expert_loads_from_traffic() {
        assert_eq!(stats().expert_loads(), vec![4, 6]);
    }

    #[test]
    fn placed_permutes_traffic_only() {
        let s = stats();
        let p = s.placed(&[1, 0]);
        assert_eq!(p.gate_ms, s.gate_ms);
        assert_eq!(p.traffic.get(1, 0), s.traffic.get(0, 1));
    }

    #[test]
    fn total_ffn_time() {
        assert!((stats().total_ffn_ms() - 1.0).abs() < 1e-12);
    }
}
