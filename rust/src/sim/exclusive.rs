//! Exclusive scenarios (paper §4, §5): one model, one expert per GPU.

use super::stats::MoeLayerStats;
use super::SimResult;
use crate::cluster::Cluster;
use crate::obs::timeline::{mean_busy_fraction, TimelineRecorder};
use crate::schedule::{aurora_schedule, comm_time, SchedulePolicy};

/// Per-phase breakdown of one exclusive MoE layer (Eqn. 3 terms).
#[derive(Debug, Clone, PartialEq)]
pub struct ExclusiveBreakdown {
    /// `max_i |G_i|` (ms).
    pub gate_ms: f64,
    /// First all-to-all `|N|` makespan (ms).
    pub comm1_ms: f64,
    /// `max_i |F_i|` (ms).
    pub ffn_ms: f64,
    /// Second all-to-all `|C|` makespan (ms).
    pub comm2_ms: f64,
    /// `max_i |A_i|` (ms).
    pub agg_ms: f64,
    /// Per-GPU compute totals (gate + ffn + agg, ms) for utilization.
    pub per_gpu_compute_ms: Vec<f64>,
}

impl ExclusiveBreakdown {
    /// Total layer time: the phases are separated by synchronization
    /// barriers (§2.2: non-overlapping communication and computation), so
    /// the layer time is their sum (Eqn. 3).
    pub fn total_ms(&self) -> f64 {
        self.gate_ms + self.comm1_ms + self.ffn_ms + self.comm2_ms + self.agg_ms
    }
}

/// Simulate one MoE layer running exclusively on `cluster` with experts
/// already placed (the stats' traffic matrix is GPU-indexed; use
/// [`MoeLayerStats::placed`] to apply an assignment first).
///
/// Implements Eqn. 1/3 of the paper: the two all-to-alls are synchronous
/// barriers, so each phase contributes its per-GPU maximum.
pub fn simulate_exclusive(
    stats: &MoeLayerStats,
    cluster: &Cluster,
    policy: SchedulePolicy,
) -> (SimResult, ExclusiveBreakdown) {
    simulate_exclusive_recorded(stats, cluster, policy, &mut TimelineRecorder::disabled())
}

/// [`simulate_exclusive`] with timeline recording through `rec`
/// (observational only — the result is bit-for-bit that of
/// [`simulate_exclusive`]).
pub fn simulate_exclusive_recorded(
    stats: &MoeLayerStats,
    cluster: &Cluster,
    policy: SchedulePolicy,
    rec: &mut TimelineRecorder,
) -> (SimResult, ExclusiveBreakdown) {
    let n = stats.n_experts();
    assert_eq!(
        n,
        cluster.len(),
        "exclusive scenario places one expert per GPU"
    );
    let bw = cluster.bandwidths();

    let gate: Vec<f64> = (0..n)
        .map(|g| stats.gate_ms / cluster.gpu(g).flops_scale)
        .collect();
    let loads = stats.expert_loads();
    let ffn: Vec<f64> = (0..n)
        .map(|g| loads[g] as f64 * stats.ffn_ms_per_token / cluster.gpu(g).flops_scale)
        .collect();
    let agg: Vec<f64> = (0..n)
        .map(|g| stats.agg_ms / cluster.gpu(g).flops_scale)
        .collect();

    let comm1 = comm_time(&stats.traffic, &bw, policy);
    let comm2 = comm_time(&stats.traffic.transpose(), &bw, policy);

    let max = |v: &[f64]| v.iter().copied().fold(0.0, f64::max);
    let breakdown = ExclusiveBreakdown {
        gate_ms: max(&gate),
        comm1_ms: comm1.makespan,
        ffn_ms: max(&ffn),
        comm2_ms: comm2.makespan,
        agg_ms: max(&agg),
        per_gpu_compute_ms: (0..n).map(|g| gate[g] + ffn[g] + agg[g]).collect(),
    };

    let t = breakdown.total_ms();
    let utilization = mean_busy_fraction(&breakdown.per_gpu_compute_ms, t);

    if rec.is_enabled() {
        // Phase windows per Eqn. 3 (barrier-separated): gate [0, max G],
        // comm1, FFN from a common start, comm2, aggregation.
        let ffn_start = breakdown.gate_ms + breakdown.comm1_ms;
        let agg_start = ffn_start + breakdown.ffn_ms + breakdown.comm2_ms;
        for g in 0..n {
            rec.record_compute(g, 0, 0.0, gate[g]);
            rec.record_compute(g, 0, ffn_start, ffn_start + ffn[g]);
            rec.record_compute(g, 0, agg_start, agg_start + agg[g]);
        }
        let reversed = stats.traffic.transpose();
        rec.record_comm(0, breakdown.gate_ms, ffn_start, &stats.traffic, &bw);
        rec.record_comm(0, ffn_start + breakdown.ffn_ms, agg_start, &reversed, &bw);
        if matches!(policy, SchedulePolicy::Aurora) {
            rec.record_rounds("N", &aurora_schedule(&stats.traffic));
            rec.record_rounds("C", &aurora_schedule(&reversed));
        }
        rec.set_makespan(t);
    }

    (
        SimResult {
            inference_ms: t,
            utilization,
            comm_ms: comm1.makespan + comm2.makespan,
        },
        breakdown,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::TrafficMatrix;
    use crate::util::Rng;

    fn toy_stats(n: usize, seed: u64) -> MoeLayerStats {
        let mut rng = Rng::new(seed);
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    d.set(i, j, rng.gen_range(20));
                }
            }
        }
        MoeLayerStats {
            traffic: d,
            gate_ms: 0.3,
            ffn_ms_per_token: 0.05,
            agg_ms: 0.2,
        }
    }

    #[test]
    fn matches_eqn3_closed_form_homogeneous() {
        let s = toy_stats(6, 1);
        let c = Cluster::homogeneous(6, 2.0);
        let (res, b) = simulate_exclusive(&s, &c, SchedulePolicy::Aurora);
        // Eqn. 3: |G| + b_max/B + max|F| + b_max/B + |A|
        let bmax = s.traffic.b_max_tokens() as f64 / 2.0;
        let maxf =
            s.expert_loads().iter().max().copied().unwrap() as f64 * s.ffn_ms_per_token;
        let expect = 0.3 + bmax + maxf + bmax + 0.2;
        assert!((res.inference_ms - expect).abs() < 1e-9);
        assert!((b.comm1_ms - bmax).abs() < 1e-12);
        assert!((b.comm2_ms - bmax).abs() < 1e-12);
    }

    #[test]
    fn aurora_no_slower_than_baselines_end_to_end() {
        for seed in 0..10 {
            let s = toy_stats(8, seed);
            let c = Cluster::homogeneous(8, 1.0);
            let (a, _) = simulate_exclusive(&s, &c, SchedulePolicy::Aurora);
            let (sjf, _) = simulate_exclusive(&s, &c, SchedulePolicy::Sjf);
            let (rcs, _) = simulate_exclusive(&s, &c, SchedulePolicy::Rcs { seed });
            assert!(a.inference_ms <= sjf.inference_ms + 1e-9);
            assert!(a.inference_ms <= rcs.inference_ms + 1e-9);
        }
    }

    #[test]
    fn utilization_in_unit_interval_and_sensible() {
        let s = toy_stats(8, 3);
        let c = Cluster::homogeneous(8, 1.0);
        let (res, _) = simulate_exclusive(&s, &c, SchedulePolicy::Aurora);
        assert!(res.utilization > 0.0 && res.utilization < 1.0);
    }

    #[test]
    fn slower_gpus_stretch_compute() {
        let s = toy_stats(4, 9);
        let fast = Cluster::homogeneous(4, 1.0);
        let mut slow_gpus = fast.gpus().to_vec();
        for g in &mut slow_gpus {
            g.flops_scale = 0.5;
        }
        let slow = Cluster::new(slow_gpus);
        let (rf, bf) = simulate_exclusive(&s, &fast, SchedulePolicy::Aurora);
        let (rs, bs) = simulate_exclusive(&s, &slow, SchedulePolicy::Aurora);
        assert!(rs.inference_ms > rf.inference_ms);
        assert!((bs.ffn_ms - 2.0 * bf.ffn_ms).abs() < 1e-9);
        assert_eq!(bs.comm1_ms, bf.comm1_ms); // bandwidth unchanged
    }

    /// MoE-shaped traffic: every GPU originates an equal shard of the batch
    /// (uniform row sums), while expert popularity skews the columns. This is
    /// the regime in which Theorem 5.1's monotonicity argument holds.
    fn moe_shaped_stats(n: usize, seed: u64) -> MoeLayerStats {
        let mut rng = Rng::new(seed);
        let per_source = 60u64;
        let popularity: Vec<f64> = (0..n).map(|_| rng.gen_f64() + 0.05).collect();
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for _ in 0..per_source {
                let mut j = rng.weighted_index(&popularity);
                if j == i {
                    j = (j + 1) % n; // keep the diagonal empty
                }
                d.add(i, j, 1);
            }
        }
        MoeLayerStats {
            traffic: d,
            gate_ms: 0.3,
            ffn_ms_per_token: 0.05,
            agg_ms: 0.2,
        }
    }

    #[test]
    fn theorem_5_1_sorted_assignment_beats_random_on_hetero() {
        use crate::assignment::{random_assignment, sorted_assignment};
        let mut rng = Rng::new(0x55);
        for seed in 0..5 {
            let s = moe_shaped_stats(8, 100 + seed);
            let c = Cluster::paper_heterogeneous(8, 1.0);
            let sorted = sorted_assignment(&s.expert_loads(), &c);
            let (best, _) = simulate_exclusive(&s.placed(&sorted), &c, SchedulePolicy::Aurora);
            for _ in 0..30 {
                let rand_p = random_assignment(8, &mut rng);
                let (r, _) = simulate_exclusive(&s.placed(&rand_p), &c, SchedulePolicy::Aurora);
                assert!(
                    best.inference_ms <= r.inference_ms + 1e-9,
                    "sorted {} > random {}",
                    best.inference_ms,
                    r.inference_ms
                );
            }
        }
    }

    #[test]
    fn zero_traffic_layer_is_compute_only() {
        let s = MoeLayerStats {
            traffic: TrafficMatrix::zeros(4),
            gate_ms: 1.0,
            ffn_ms_per_token: 0.1,
            agg_ms: 1.0,
        };
        let c = Cluster::homogeneous(4, 1.0);
        let (res, b) = simulate_exclusive(&s, &c, SchedulePolicy::Aurora);
        assert_eq!(b.comm1_ms, 0.0);
        assert_eq!(res.inference_ms, 2.0);
    }
}
