//! Colocated scenarios (paper §6, §7): two models interleaving per GPU.
//!
//! Implements the Table 2 start/end recurrences. Both models' stats must
//! already be GPU-indexed (pairing + assignment applied via
//! [`MoeLayerStats::placed`]); GPU `i` hosts one expert of each model.
//!
//! The execution semantics (paper §6.1):
//! * **Computation competition** — the two models' compute components
//!   serialize on each GPU (one compute engine per GPU);
//! * **Communication overlap** — the two models' collectives may share the
//!   switch, so the completion of the second model's dispatch is the
//!   *aggregated* communication time `|N̄ᵃ⁺ᵇ|` of the summed traffic matrix,
//!   not the sum of individual times.
//!
//! The steady-state layer pipeline (Fig. 7) interleaves: `G^b ∥ N^a`, then
//! `F^a ∥ N^b`, then `F^b ∥ C^a`, then `A^a ∥ C^b`, then `A^b`, then `G^a`.

use super::stats::MoeLayerStats;
use super::SimResult;
use crate::cluster::Cluster;
use crate::obs::timeline::{mean_busy_fraction, TimelineRecorder};
use crate::schedule::{aurora_schedule, comm_time, SchedulePolicy};

/// The Table 2 component end times (ms), all measured from the layer start.
#[derive(Debug, Clone, PartialEq)]
pub struct ColocatedBreakdown {
    /// End of Model b's gate (`E_{G^b}`).
    pub e_gate_b: f64,
    /// End of Model a's first all-to-all alone (`E_{N^a} = |N̄^a|`).
    pub e_n_a: f64,
    /// End of Model a's FFN (`E_{F^a}`).
    pub e_f_a: f64,
    /// End of Model b's first all-to-all (`E_{N^b} = |N̄^{a+b}|`).
    pub e_n_b: f64,
    /// End of Model b's FFN (`E_{F^b}`).
    pub e_f_b: f64,
    /// End of Model a's second all-to-all (`E_{C^a}`).
    pub e_c_a: f64,
    /// End of Model a's aggregation (`E_{A^a}`).
    pub e_a_a: f64,
    /// End of Model b's second all-to-all (`E_{C^b}`).
    pub e_c_b: f64,
    /// End of Model b's aggregation (`E_{A^b}`).
    pub e_a_b: f64,
    /// Layer end (`E_{A^b} + |G^a|`, Eqn. 4).
    pub end: f64,
    /// Aggregated first-all-to-all makespan of the summed traffic.
    pub agg_comm1_ms: f64,
    /// Aggregated second-all-to-all makespan.
    pub agg_comm2_ms: f64,
}

/// Simulate one layer of two colocated MoE models (both GPU-indexed) on
/// `cluster` under `policy`, following the Table 2 recurrences.
pub fn simulate_colocated(
    a: &MoeLayerStats,
    b: &MoeLayerStats,
    cluster: &Cluster,
    policy: SchedulePolicy,
) -> (SimResult, ColocatedBreakdown) {
    simulate_colocated_recorded(a, b, cluster, policy, &mut TimelineRecorder::disabled())
}

/// [`simulate_colocated`] with timeline recording through `rec`
/// (observational only — the result is bit-for-bit that of
/// [`simulate_colocated`]). Model `a` records as model 0, `b` as model 1.
pub fn simulate_colocated_recorded(
    a: &MoeLayerStats,
    b: &MoeLayerStats,
    cluster: &Cluster,
    policy: SchedulePolicy,
    rec: &mut TimelineRecorder,
) -> (SimResult, ColocatedBreakdown) {
    let n = a.n_experts();
    assert_eq!(n, b.n_experts(), "colocated models span the same GPUs");
    assert_eq!(n, cluster.len());
    let bw = cluster.bandwidths();

    let scale = |base: f64, g: usize| base / cluster.gpu(g).flops_scale;
    let max = |v: &[f64]| v.iter().copied().fold(0.0, f64::max);

    let gate_a: Vec<f64> = (0..n).map(|g| scale(a.gate_ms, g)).collect();
    let gate_b: Vec<f64> = (0..n).map(|g| scale(b.gate_ms, g)).collect();
    let loads_a = a.expert_loads();
    let loads_b = b.expert_loads();
    let ffn_a: Vec<f64> = (0..n)
        .map(|g| scale(loads_a[g] as f64 * a.ffn_ms_per_token, g))
        .collect();
    let ffn_b: Vec<f64> = (0..n)
        .map(|g| scale(loads_b[g] as f64 * b.ffn_ms_per_token, g))
        .collect();
    let agg_a: Vec<f64> = (0..n).map(|g| scale(a.agg_ms, g)).collect();
    let agg_b: Vec<f64> = (0..n).map(|g| scale(b.agg_ms, g)).collect();

    // Communication makespans under the chosen policy.
    let n_a = comm_time(&a.traffic, &bw, policy).makespan;
    let n_b = comm_time(&b.traffic, &bw, policy).makespan;
    let c_a = comm_time(&a.traffic.transpose(), &bw, policy).makespan;
    let c_b = comm_time(&b.traffic.transpose(), &bw, policy).makespan;
    let agg_n = comm_time(&a.traffic.sum(&b.traffic), &bw, policy).makespan;
    let agg_c = comm_time(
        &a.traffic.transpose().sum(&b.traffic.transpose()),
        &bw,
        policy,
    )
    .makespan;

    // Table 2 recurrences.
    let e_gate_b = max(&gate_b);
    let e_n_a = n_a;
    // F^a needs: its own dispatch done (N^a) and the GPU free (G^b done).
    let e_f_a = e_gate_b.max(e_n_a) + max(&ffn_a);
    // N^b: starts after G^b; shares the switch with N^a — the pair completes
    // at the aggregated makespan (footnote 4 adds the G^b start constraint).
    let e_n_b = agg_n.max(e_gate_b + n_b);
    // F^b: GPU busy with F^a until e_f_a; data ready at e_n_b.
    let e_f_b = e_f_a.max(e_n_b) + max(&ffn_b);
    // C^a: starts once F^a is done and the switch has drained the N phase
    // (§6.2: N^a and C^a are separated by F^a, so |N̄+C^a| = |N̄| + |C̄^a|).
    let e_c_a = e_f_a.max(e_n_b) + c_a;
    // A^a: GPU busy with F^b; data ready at E_{C^a}.
    let e_a_a = e_f_b.max(e_c_a) + max(&agg_a);
    // C^b: needs F^b done; the C phase in aggregate cannot beat the
    // aggregated makespan of both reversed collectives.
    let e_c_b = (e_f_b + c_b).max(e_f_a.max(e_n_b) + agg_c);
    // A^b: GPU busy with A^a; data ready at E_{C^b}.
    let e_a_b = e_a_a.max(e_c_b) + max(&agg_b);
    // Next layer's G^a closes the pipeline round (Eqn. 4).
    let end = e_a_b + max(&gate_a);

    let per_gpu_compute: Vec<f64> = (0..n)
        .map(|g| gate_a[g] + ffn_a[g] + agg_a[g] + gate_b[g] + ffn_b[g] + agg_b[g])
        .collect();
    let utilization = mean_busy_fraction(&per_gpu_compute, end);

    if rec.is_enabled() {
        // Engine timeline: replay the Fig. 7 interleaving per GPU with the
        // event-sim start rule (engine free AND phase data ready), which the
        // Table 2 phase-end maxima bound from above.
        fn run(
            free_at: &mut [f64],
            rec: &mut TimelineRecorder,
            model: usize,
            g: usize,
            ready: f64,
            dur: f64,
        ) {
            let start = free_at[g].max(ready);
            rec.record_compute(g, model, start, start + dur);
            free_at[g] = start + dur;
        }
        let mut free_at = vec![0.0f64; n];
        for g in 0..n {
            run(&mut free_at, rec, 1, g, 0.0, gate_b[g]);
        }
        for g in 0..n {
            run(&mut free_at, rec, 0, g, e_n_a, ffn_a[g]);
        }
        for g in 0..n {
            run(&mut free_at, rec, 1, g, e_n_b, ffn_b[g]);
        }
        for g in 0..n {
            run(&mut free_at, rec, 0, g, e_c_a, agg_a[g]);
        }
        for g in 0..n {
            run(&mut free_at, rec, 1, g, e_c_b, agg_b[g]);
        }
        for g in 0..n {
            run(&mut free_at, rec, 0, g, e_a_b, gate_a[g]);
        }
        // Link timeline: the four collectives in chronological window order.
        let rev_a = a.traffic.transpose();
        let rev_b = b.traffic.transpose();
        rec.record_comm(0, 0.0, e_n_a, &a.traffic, &bw);
        rec.record_comm(1, e_gate_b, e_n_b, &b.traffic, &bw);
        rec.record_comm(0, e_f_a.max(e_n_b), e_c_a, &rev_a, &bw);
        rec.record_comm(1, e_f_b, e_c_b, &rev_b, &bw);
        if matches!(policy, SchedulePolicy::Aurora) {
            rec.record_rounds("N", &aurora_schedule(&a.traffic.sum(&b.traffic)));
            rec.record_rounds("C", &aurora_schedule(&rev_a.sum(&rev_b)));
        }
        rec.set_makespan(end);
    }

    let breakdown = ColocatedBreakdown {
        e_gate_b,
        e_n_a,
        e_f_a,
        e_n_b,
        e_f_b,
        e_c_a,
        e_a_a,
        e_c_b,
        e_a_b,
        end,
        agg_comm1_ms: agg_n,
        agg_comm2_ms: agg_c,
    };
    (
        SimResult {
            inference_ms: end,
            utilization,
            comm_ms: agg_n + agg_c,
        },
        breakdown,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::simulate_exclusive;
    use crate::traffic::TrafficMatrix;
    use crate::util::Rng;

    fn toy(n: usize, seed: u64, ffn_ms: f64) -> MoeLayerStats {
        let mut rng = Rng::new(seed);
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    d.set(i, j, rng.gen_range(15) + 1);
                }
            }
        }
        MoeLayerStats {
            traffic: d,
            gate_ms: 0.2,
            ffn_ms_per_token: ffn_ms,
            agg_ms: 0.1,
        }
    }

    #[test]
    fn timeline_is_monotone() {
        let a = toy(6, 1, 0.05);
        let b = toy(6, 2, 0.05);
        let c = Cluster::homogeneous(6, 1.0);
        let (_, t) = simulate_colocated(&a, &b, &c, SchedulePolicy::Aurora);
        assert!(t.e_f_a >= t.e_n_a);
        assert!(t.e_f_a >= t.e_gate_b);
        assert!(t.e_n_b >= t.e_n_a); // aggregated comm >= model a's alone
        assert!(t.e_f_b >= t.e_f_a);
        assert!(t.e_c_a >= t.e_f_a);
        assert!(t.e_a_a >= t.e_f_b && t.e_a_a >= t.e_c_a);
        assert!(t.e_c_b >= t.e_f_b);
        assert!(t.e_a_b >= t.e_a_a && t.e_a_b >= t.e_c_b);
        assert!(t.end >= t.e_a_b);
    }

    #[test]
    fn colocated_slower_than_exclusive_but_faster_than_serial() {
        for seed in 0..10 {
            let a = toy(8, seed * 3 + 1, 0.04);
            let b = toy(8, seed * 3 + 2, 0.04);
            let c = Cluster::homogeneous(8, 1.0);
            let (ra, _) = simulate_exclusive(&a, &c, SchedulePolicy::Aurora);
            let (rb, _) = simulate_exclusive(&b, &c, SchedulePolicy::Aurora);
            let (rc, _) = simulate_colocated(&a, &b, &c, SchedulePolicy::Aurora);
            // sharing cannot beat a dedicated cluster for either model
            assert!(rc.inference_ms >= ra.inference_ms.max(rb.inference_ms) - 1e-9);
            // but interleaving beats running the two layers back-to-back
            assert!(
                rc.inference_ms <= ra.inference_ms + rb.inference_ms + 1e-9,
                "seed={seed}: colocated {} vs serial {}",
                rc.inference_ms,
                ra.inference_ms + rb.inference_ms
            );
        }
    }

    #[test]
    fn colocation_roughly_doubles_utilization() {
        // paper regime: compute and communication are comparable (§2.3 puts
        // all-to-all at ~60% of inference time)
        let a = toy(8, 11, 1.0);
        let b = toy(8, 12, 1.0);
        let c = Cluster::homogeneous(8, 1.0);
        let (re, _) = simulate_exclusive(&a, &c, SchedulePolicy::Aurora);
        let (rc, _) = simulate_colocated(&a, &b, &c, SchedulePolicy::Aurora);
        assert!(
            rc.utilization > re.utilization * 1.2,
            "colocated {} vs exclusive {}",
            rc.utilization,
            re.utilization
        );
    }

    #[test]
    fn aurora_pairing_no_worse_than_random_on_aggregated_comm() {
        use crate::colocation::{aggregate_traffic, case2_pairing, random_pairing};
        let mut rng = Rng::new(0xAB);
        for seed in 0..5u64 {
            let a = toy(8, 50 + seed, 0.02);
            let b = toy(8, 60 + seed, 0.02);
            let c = Cluster::homogeneous(8, 1.0);
            let (_, pi) = case2_pairing(&a.traffic, &b.traffic);
            // place model b's experts next to their partners
            let mut inv = vec![0usize; 8];
            for (i, &j) in pi.iter().enumerate() {
                inv[j] = i;
            }
            let b_placed = b.placed(&inv);
            let (r_aurora, t_aurora) =
                simulate_colocated(&a, &b_placed, &c, SchedulePolicy::Aurora);
            // sanity: aggregated matrix matches the helper
            assert_eq!(
                aggregate_traffic(&a.traffic, &b.traffic, &pi).b_max_tokens() as f64,
                t_aurora.agg_comm1_ms
            );
            for _ in 0..20 {
                let p = random_pairing(8, &mut rng);
                let mut pinv = vec![0usize; 8];
                for (i, &j) in p.iter().enumerate() {
                    pinv[j] = i;
                }
                let (r_rand, _) =
                    simulate_colocated(&a, &b.placed(&pinv), &c, SchedulePolicy::Aurora);
                assert!(r_aurora.inference_ms <= r_rand.inference_ms + 1e-9);
            }
        }
    }

    #[test]
    fn zero_traffic_still_serializes_compute() {
        let mk = || MoeLayerStats {
            traffic: TrafficMatrix::zeros(4),
            gate_ms: 1.0,
            ffn_ms_per_token: 0.0,
            agg_ms: 1.0,
        };
        let c = Cluster::homogeneous(4, 1.0);
        let (r, t) = simulate_colocated(&mk(), &mk(), &c, SchedulePolicy::Aurora);
        // G^b(1) -> F(0) -> A^a after F^b ... both agg 1ms each, final gate 1ms
        assert!(t.end > 0.0);
        assert_eq!(r.comm_ms, 0.0);
    }
}
