//! Inference-time simulator for MoE layers on big-switch clusters.
//!
//! The paper's evaluation (§8) is analytic simulation driven by model
//! statistics; this module is that testbed. It computes per-layer inference
//! time and GPU utilization for all four scenarios of Fig. 2:
//!
//! * [`simulate_exclusive`] — one model per set of GPUs (Eqn. 1/3): the
//!   layer is `max(G) + |N| + max(F) + |C| + max(A)` with comm times from
//!   [`crate::schedule::comm_time`].
//! * [`simulate_colocated`] — two models interleaving on shared GPUs,
//!   following the Table 2 start/end recurrences (computation competition on
//!   the GPU, communication overlap on the switch).
//! * [`simulate_window`] — one serving window with optional zero-compute
//!   *background* traffic (staged expert weights of a live migration,
//!   [`crate::coordinator`]) sharing the links.
//! * [`simulate_group`] — the generalized entry point: any number of
//!   GPU-indexed models, dispatching to the exact paths above for M ≤ 2
//!   and to a staggered M-way pipeline otherwise. The placement layer
//!   ([`crate::placement::Deployment`]) projects expert-level statistics to
//!   GPU level (aggregating multi-expert groups) before calling it —
//!   replicated deployments
//!   ([`crate::replication::ReplicatedDeployment`]) do the same through
//!   their split projection, so replica-split traffic needs no special
//!   simulator path.
//!
//! Components scale with GPU performance: a component that takes `t` ms on
//! the reference GPU takes `t / flops_scale` on GPU `g`; the FFN time is
//! proportional to the expert's token load (observation 3, §4.1).
//!
//! Every simulator (including the discrete-event cross-checks in [`event`])
//! has a `*_recorded` twin taking a
//! [`TimelineRecorder`](crate::obs::timeline::TimelineRecorder) that
//! attributes each GPU-millisecond to a typed segment — compute, comm,
//! sync-wait, swap-drain, idle — per GPU engine and per access link.
//! Recording is observational only: the plain entry points delegate to their
//! twins with a disabled recorder and results are bit-for-bit identical
//! either way (pinned by property tests).

mod colocated;
pub mod event;
mod exclusive;
mod group;
mod online;
mod stats;

pub use colocated::{simulate_colocated, simulate_colocated_recorded, ColocatedBreakdown};
pub use event::{
    event_sim_colocated, event_sim_colocated_recorded, event_sim_exclusive,
    event_sim_exclusive_recorded, EventSimResult,
};
pub use exclusive::{simulate_exclusive, simulate_exclusive_recorded, ExclusiveBreakdown};
pub use group::{
    simulate_group, simulate_group_recorded, simulate_group_topology,
    simulate_group_topology_recorded, GroupBreakdown,
};
pub use online::{
    dead_gpu_tokens, simulate_window, simulate_window_recorded, simulate_window_topology,
    simulate_window_topology_recorded,
};
pub use stats::MoeLayerStats;

/// Result of simulating one MoE layer (one model or a colocated pair).
#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    /// End-to-end inference time of the layer (ms).
    pub inference_ms: f64,
    /// Mean GPU utilization: computation time ÷ inference time, averaged
    /// over GPUs (§8.1 Metrics).
    pub utilization: f64,
    /// Total communication time visible in the critical path (ms).
    pub comm_ms: f64,
}
