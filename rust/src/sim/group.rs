//! Generalized colocation simulator: M models per GPU group (M ≥ 1).
//!
//! [`simulate_group`] is the single entry point the placement layer drives.
//! Every model's statistics must already be **GPU-indexed** (projected via
//! [`crate::placement::Deployment::project_layer`], which also aggregates
//! multiple experts of one model sharing a GPU). Dispatch:
//!
//! * `M == 1` → the exact Eqn. 3 closed form ([`super::simulate_exclusive`]);
//! * `M == 2` → the exact Table 2 recurrences ([`super::simulate_colocated`]);
//! * `M ≥ 3` → the staggered pipeline below. Its communication floors are
//!   the Table 2 rows generalized cumulatively; its compute phases use
//!   per-GPU engine serialization (the event simulator's semantics), which
//!   coincides with Table 2's global-max recurrences on homogeneous
//!   clusters and can be slightly tighter on heterogeneous ones — M ≤ 2
//!   never takes this path, so the paper's numbers are untouched.
//!
//! Execution semantics of the generalized pipeline (paper §6.1, extended):
//!
//! * **Computation competition** — every GPU has one compute engine; the
//!   compute components of all colocated experts serialize on it in model
//!   order (gates of models 1..M−1 first, then FFNs in model order, then
//!   aggregations, closing with model 0's next-round gate, Eqn. 4).
//! * **Communication overlap** — models share the switch. Model 0's dispatch
//!   starts the round; model k's dispatch starts when its gate finishes. The
//!   first `k+1` dispatches jointly cannot drain before the makespan of
//!   their **aggregated** traffic matrix (Theorem 6.1 generalized), so
//!   `E_{N^k} = max(|N̄^{0..k}|, E_{G^k} + |N̄^k|, E_{N^{k-1}})`.
//! * The combine phase mirrors it with reversed matrices and the C-phase
//!   start floor `max(E_{F^0}, E_{N^{M-1}})`, exactly as Table 2's
//!   `E_{C^a}`/`E_{C^b}` rows do for M = 2.

use super::stats::MoeLayerStats;
use super::SimResult;
use crate::cluster::{Cluster, Topology};
use crate::obs::timeline::{mean_busy_fraction, TimelineRecorder};
use crate::schedule::{aurora_schedule, comm_time, comm_time_on, SchedulePolicy};
use crate::traffic::TrafficMatrix;

/// Per-model phase end times (ms from layer start) of a group simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupBreakdown {
    /// End of each model's first all-to-all (`E_{N^m}`).
    pub e_n: Vec<f64>,
    /// End of each model's FFN (`E_{F^m}`).
    pub e_f: Vec<f64>,
    /// End of each model's second all-to-all (`E_{C^m}`).
    pub e_c: Vec<f64>,
    /// End of each model's aggregation (`E_{A^m}`).
    pub e_a: Vec<f64>,
    /// Layer end (closing gate included, Eqn. 4).
    pub end: f64,
    /// Aggregated first-all-to-all makespan of all models' summed traffic.
    pub agg_comm1_ms: f64,
    /// Aggregated second-all-to-all makespan.
    pub agg_comm2_ms: f64,
}

/// Simulate one layer of `models.len()` colocated MoE models (all
/// GPU-indexed, all spanning `cluster`) under `policy`.
pub fn simulate_group(
    models: &[&MoeLayerStats],
    cluster: &Cluster,
    policy: SchedulePolicy,
) -> (SimResult, GroupBreakdown) {
    simulate_group_recorded(models, cluster, policy, &mut TimelineRecorder::disabled())
}

/// [`simulate_group`] with timeline recording through `rec` (observational
/// only — results are bit-for-bit those of [`simulate_group`]).
pub fn simulate_group_recorded(
    models: &[&MoeLayerStats],
    cluster: &Cluster,
    policy: SchedulePolicy,
    rec: &mut TimelineRecorder,
) -> (SimResult, GroupBreakdown) {
    assert!(!models.is_empty(), "group needs at least one model");
    let n = cluster.len();
    for s in models {
        assert_eq!(
            s.n_experts(),
            n,
            "group stats must be GPU-indexed (project the deployment first)"
        );
    }

    match models.len() {
        1 => {
            let (res, b) = super::simulate_exclusive_recorded(models[0], cluster, policy, rec);
            let e_n = b.gate_ms + b.comm1_ms;
            let e_f = e_n + b.ffn_ms;
            let e_c = e_f + b.comm2_ms;
            let e_a = e_c + b.agg_ms;
            let breakdown = GroupBreakdown {
                e_n: vec![e_n],
                e_f: vec![e_f],
                e_c: vec![e_c],
                e_a: vec![e_a],
                end: res.inference_ms,
                agg_comm1_ms: b.comm1_ms,
                agg_comm2_ms: b.comm2_ms,
            };
            (res, breakdown)
        }
        2 => {
            let (res, b) =
                super::simulate_colocated_recorded(models[0], models[1], cluster, policy, rec);
            let breakdown = GroupBreakdown {
                e_n: vec![b.e_n_a, b.e_n_b],
                e_f: vec![b.e_f_a, b.e_f_b],
                e_c: vec![b.e_c_a, b.e_c_b],
                e_a: vec![b.e_a_a, b.e_a_b],
                end: b.end,
                agg_comm1_ms: b.agg_comm1_ms,
                agg_comm2_ms: b.agg_comm2_ms,
            };
            (res, breakdown)
        }
        _ => simulate_many(models, cluster, policy, rec),
    }
}

/// Topology-aware group simulation: like [`simulate_group`], but collectives
/// are priced on `topo` via [`crate::schedule::comm_time_on`] — Aurora takes
/// the hierarchical two-phase estimate, ordered baselines the fluid
/// `max(flat, uplink bound)` combination.
///
/// On [`Topology::BigSwitch`] this **is** [`simulate_group`], bit for bit
/// (including the exact M ≤ 2 paper paths). On a two-tier or recursive
/// tiered topology every model count goes through the staggered pipeline
/// with the topology-aware communication times; the M ≤ 2 closed forms
/// assume a non-blocking switch and do not apply there.
pub fn simulate_group_topology(
    models: &[&MoeLayerStats],
    cluster: &Cluster,
    topo: &Topology,
    policy: SchedulePolicy,
) -> (SimResult, GroupBreakdown) {
    simulate_group_topology_recorded(
        models,
        cluster,
        topo,
        policy,
        &mut TimelineRecorder::disabled(),
    )
}

/// [`simulate_group_topology`] with timeline recording through `rec`
/// (observational only). On non-big-switch topologies the per-link comm
/// segments price each GPU's access link only (the documented lower bound);
/// per-round occupancy is recorded on the big-switch path only, where the
/// flat slot schedule is the one actually executed.
pub fn simulate_group_topology_recorded(
    models: &[&MoeLayerStats],
    cluster: &Cluster,
    topo: &Topology,
    policy: SchedulePolicy,
    rec: &mut TimelineRecorder,
) -> (SimResult, GroupBreakdown) {
    match topo {
        Topology::BigSwitch => simulate_group_recorded(models, cluster, policy, rec),
        _ => {
            assert!(!models.is_empty(), "group needs at least one model");
            let n = cluster.len();
            for s in models {
                assert_eq!(
                    s.n_experts(),
                    n,
                    "group stats must be GPU-indexed (project the deployment first)"
                );
            }
            simulate_many_with(
                models,
                cluster,
                &|d: &TrafficMatrix| comm_time_on(d, cluster, topo, policy).makespan,
                rec,
            )
        }
    }
}

/// The M ≥ 3 staggered pipeline on the big switch.
fn simulate_many(
    models: &[&MoeLayerStats],
    cluster: &Cluster,
    policy: SchedulePolicy,
    rec: &mut TimelineRecorder,
) -> (SimResult, GroupBreakdown) {
    let bw = cluster.bandwidths();
    let out = simulate_many_with(
        models,
        cluster,
        &|d: &TrafficMatrix| comm_time(d, &bw, policy).makespan,
        rec,
    );
    if rec.is_enabled() && matches!(policy, SchedulePolicy::Aurora) {
        // Per-round occupancy of the executed slot schedules on the
        // aggregated matrices (Theorem 6.1: the shared switch drains the
        // models' summed traffic).
        let mut agg = models[0].traffic.clone();
        for s in &models[1..] {
            agg = agg.sum(&s.traffic);
        }
        rec.record_rounds("N", &aurora_schedule(&agg));
        rec.record_rounds("C", &aurora_schedule(&agg.transpose()));
    }
    out
}

/// The staggered pipeline over an arbitrary collective cost model `comm`
/// (flat big-switch or topology-aware).
fn simulate_many_with(
    models: &[&MoeLayerStats],
    cluster: &Cluster,
    comm: &dyn Fn(&TrafficMatrix) -> f64,
    rec: &mut TimelineRecorder,
) -> (SimResult, GroupBreakdown) {
    let m = models.len();
    let n = cluster.len();
    let scale = |t: f64, g: usize| t / cluster.gpu(g).flops_scale;
    let max = |v: &[f64]| v.iter().copied().fold(0.0, f64::max);

    // Per-GPU compute engine (serialization in call order). Each completed
    // task is mirrored into the timeline recorder (no-op when disabled).
    let mut free_at = vec![0.0f64; n];
    let mut busy = vec![0.0f64; n];
    fn run(
        free_at: &mut [f64],
        busy: &mut [f64],
        rec: &mut TimelineRecorder,
        model: usize,
        g: usize,
        ready: f64,
        dur: f64,
    ) -> f64 {
        let start = free_at[g].max(ready);
        let end = start + dur;
        free_at[g] = end;
        busy[g] += dur;
        rec.record_compute(g, model, start, end);
        end
    }

    // Gates of models 1..M at t = 0, serialized per GPU in model order
    // (model 0 gated at the close of the previous round, Eqn. 4).
    let mut e_gate = vec![0.0f64; m];
    for k in 1..m {
        let ends: Vec<f64> = (0..n)
            .map(|g| {
                run(
                    &mut free_at,
                    &mut busy,
                    rec,
                    k,
                    g,
                    0.0,
                    scale(models[k].gate_ms, g),
                )
            })
            .collect();
        e_gate[k] = max(&ends);
    }

    // N phase: staggered dispatches over the shared switch with cumulative
    // aggregated-makespan floors.
    let n_single: Vec<f64> = models.iter().map(|s| comm(&s.traffic)).collect();
    let mut e_n = vec![0.0f64; m];
    e_n[0] = n_single[0];
    let mut agg = models[0].traffic.clone();
    let mut agg_n = e_n[0];
    for k in 1..m {
        agg = agg.sum(&models[k].traffic);
        agg_n = comm(&agg);
        e_n[k] = agg_n.max(e_gate[k] + n_single[k]).max(e_n[k - 1]);
    }

    // F phase: each model's FFN when its dispatch lands, engine permitting.
    let mut e_f = vec![0.0f64; m];
    for k in 0..m {
        let loads = models[k].expert_loads();
        let ends: Vec<f64> = (0..n)
            .map(|g| {
                run(
                    &mut free_at,
                    &mut busy,
                    rec,
                    k,
                    g,
                    e_n[k],
                    scale(loads[g] as f64 * models[k].ffn_ms_per_token, g),
                )
            })
            .collect();
        e_f[k] = max(&ends);
    }

    // C phase: reversed collectives after the N phase drains, with the same
    // cumulative aggregation floors (Table 2 rows E_{C^a}/E_{C^b} generalized).
    let c_single: Vec<f64> = models
        .iter()
        .map(|s| comm(&s.traffic.transpose()))
        .collect();
    let c_start = e_f[0].max(e_n[m - 1]);
    let mut e_c = vec![0.0f64; m];
    e_c[0] = c_start + c_single[0];
    let mut agg_rev = models[0].traffic.transpose();
    let mut agg_c = c_single[0];
    for k in 1..m {
        agg_rev = agg_rev.sum(&models[k].traffic.transpose());
        agg_c = comm(&agg_rev);
        e_c[k] = (e_f[k] + c_single[k])
            .max(c_start + agg_c)
            .max(e_c[k - 1]);
    }

    // A phase, in model order on the engines.
    let mut e_a = vec![0.0f64; m];
    for k in 0..m {
        let ends: Vec<f64> = (0..n)
            .map(|g| {
                run(
                    &mut free_at,
                    &mut busy,
                    rec,
                    k,
                    g,
                    e_c[k],
                    scale(models[k].agg_ms, g),
                )
            })
            .collect();
        e_a[k] = max(&ends);
    }

    // Model 0's next-round gate closes the pipeline (Eqn. 4).
    let ends: Vec<f64> = (0..n)
        .map(|g| {
            run(
                &mut free_at,
                &mut busy,
                rec,
                0,
                g,
                e_a[m - 1],
                scale(models[0].gate_ms, g),
            )
        })
        .collect();
    let end = max(&ends);

    let utilization = mean_busy_fraction(&busy, end);

    if rec.is_enabled() {
        // Per-link comm attribution: each model's dispatch occupies its
        // window [gate end, E_{N^k}] (combine mirrors it with reversed
        // matrices from the C-phase start floor). Windows are visited in
        // model order, which is chronological per phase (gates serialize,
        // the E_{N^k}/E_{C^k} floors are monotone).
        let bw = cluster.bandwidths();
        for k in 0..m {
            let start = if k == 0 { 0.0 } else { e_gate[k] };
            rec.record_comm(k, start, e_n[k], &models[k].traffic, &bw);
        }
        for k in 0..m {
            let start = if k == 0 { c_start } else { e_f[k].max(c_start) };
            rec.record_comm(k, start, e_c[k], &models[k].traffic.transpose(), &bw);
        }
        rec.set_makespan(end);
    }
    let breakdown = GroupBreakdown {
        e_n,
        e_f,
        e_c,
        e_a,
        end,
        agg_comm1_ms: agg_n,
        agg_comm2_ms: agg_c,
    };
    (
        SimResult {
            inference_ms: end,
            utilization,
            comm_ms: agg_n + agg_c,
        },
        breakdown,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate_colocated, simulate_exclusive};
    use crate::traffic::TrafficMatrix;
    use crate::util::Rng;

    fn toy(n: usize, seed: u64, ffn_ms: f64) -> MoeLayerStats {
        let mut rng = Rng::new(seed);
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    d.set(i, j, rng.gen_range(18) + 1);
                }
            }
        }
        MoeLayerStats {
            traffic: d,
            gate_ms: 0.2,
            ffn_ms_per_token: ffn_ms,
            agg_ms: 0.1,
        }
    }

    #[test]
    fn one_model_matches_exclusive_exactly() {
        let s = toy(6, 3, 0.04);
        for cluster in [
            Cluster::homogeneous(6, 1.0),
            {
                let mut gpus = Cluster::homogeneous(6, 1.0).gpus().to_vec();
                for (k, g) in gpus.iter_mut().enumerate() {
                    g.flops_scale = 1.0 - 0.1 * k as f64;
                    g.bandwidth = 1.0 - 0.1 * k as f64;
                }
                Cluster::new(gpus)
            },
        ] {
            let (a, _) = simulate_group(&[&s], &cluster, SchedulePolicy::Aurora);
            let (b, _) = simulate_exclusive(&s, &cluster, SchedulePolicy::Aurora);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn two_models_match_colocated_exactly() {
        for seed in 0..8 {
            let a = toy(5, seed * 2 + 1, 0.05);
            let b = toy(5, seed * 2 + 2, 0.05);
            let cluster = Cluster::homogeneous(5, 2.0);
            let (g, gb) = simulate_group(&[&a, &b], &cluster, SchedulePolicy::Aurora);
            let (c, cb) = simulate_colocated(&a, &b, &cluster, SchedulePolicy::Aurora);
            assert_eq!(g, c);
            assert_eq!(gb.end, cb.end);
            assert_eq!(gb.e_c, vec![cb.e_c_a, cb.e_c_b]);
        }
    }

    #[test]
    fn three_model_timeline_is_monotone() {
        let a = toy(6, 11, 0.03);
        let b = toy(6, 12, 0.03);
        let c = toy(6, 13, 0.03);
        let cluster = Cluster::homogeneous(6, 1.0);
        let (res, t) = simulate_group(&[&a, &b, &c], &cluster, SchedulePolicy::Aurora);
        for k in 1..3 {
            assert!(t.e_n[k] >= t.e_n[k - 1]);
            assert!(t.e_c[k] >= t.e_c[k - 1]);
            assert!(t.e_a[k] >= t.e_a[k - 1]);
        }
        for k in 0..3 {
            assert!(t.e_f[k] >= t.e_n[k]);
            assert!(t.e_c[k] >= t.e_f[k]);
            assert!(t.e_a[k] >= t.e_c[k]);
        }
        assert!(t.end >= t.e_a[2]);
        assert!(res.utilization > 0.0 && res.utilization <= 1.0);
        assert_eq!(res.inference_ms, t.end);
    }

    #[test]
    fn group_bounded_by_exclusive_and_serial() {
        for seed in 0..6u64 {
            let cluster = Cluster::homogeneous(6, 1.0);
            let a = toy(6, seed * 3 + 21, 0.04);
            let b = toy(6, seed * 3 + 22, 0.04);
            let c = toy(6, seed * 3 + 23, 0.04);
            let singles: Vec<f64> = [&a, &b, &c]
                .iter()
                .map(|&s| {
                    simulate_exclusive(s, &cluster, SchedulePolicy::Aurora)
                        .0
                        .inference_ms
                })
                .collect();
            let (r3, _) = simulate_group(&[&a, &b, &c], &cluster, SchedulePolicy::Aurora);
            // sharing cannot beat a dedicated cluster for any member...
            let slowest = singles.iter().cloned().fold(0.0, f64::max);
            assert!(
                r3.inference_ms >= slowest - 1e-9,
                "seed {seed}: 3-way {} vs slowest exclusive {slowest}",
                r3.inference_ms
            );
            // ...but interleaving beats running the three layers back-to-back
            let serial: f64 = singles.iter().sum();
            assert!(
                r3.inference_ms <= serial + 1e-9,
                "seed {seed}: 3-way {} vs serial {serial}",
                r3.inference_ms
            );
        }
    }

    #[test]
    fn three_way_colocation_raises_utilization() {
        // comparable compute and comm (the paper's colocation regime)
        let a = toy(8, 31, 1.0);
        let b = toy(8, 32, 1.0);
        let c = toy(8, 33, 1.0);
        let cluster = Cluster::homogeneous(8, 1.0);
        let (r1, _) = simulate_group(&[&a], &cluster, SchedulePolicy::Aurora);
        let (r3, _) = simulate_group(&[&a, &b, &c], &cluster, SchedulePolicy::Aurora);
        assert!(
            r3.utilization > r1.utilization * 1.3,
            "3-way {} vs exclusive {}",
            r3.utilization,
            r1.utilization
        );
    }

    /// Replicated experts reach the simulator as split-projected GPU-level
    /// stats; splitting a hot expert must shorten the simulated layer.
    #[test]
    fn replica_split_projection_shortens_the_layer() {
        use crate::placement::{Deployment, Scenario};
        use crate::replication::{optimize_splits, ReplicatedDeployment};
        use crate::traffic::zipf_traffic;

        let stats = MoeLayerStats {
            traffic: zipf_traffic(8, 512, 1.2, 3),
            gate_ms: 0.02,
            ffn_ms_per_token: 0.001,
            agg_ms: 0.015,
        };
        let cluster = Cluster::homogeneous(4, 100.0);
        let base = Deployment::new(
            4,
            vec![(0..8).map(|e| e % 4).collect()],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        let hot = (0..8).max_by_key(|&e| stats.expert_loads()[e]).unwrap();
        let mut rep = ReplicatedDeployment::from_deployment(base.clone());
        for g in 0..4 {
            if g != base.gpu_of(0, hot) {
                rep.add_replica(0, hot, g).unwrap();
            }
        }
        let plan = optimize_splits(&rep, &[&stats], &cluster);

        let plain = base.project_layer(0, &stats);
        let split = rep.project_layer_split(0, &stats, &plan);
        let (t_plain, _) = simulate_group(&[&plain], &cluster, SchedulePolicy::Aurora);
        let (t_split, _) = simulate_group(&[&split], &cluster, SchedulePolicy::Aurora);
        assert!(
            t_split.inference_ms < t_plain.inference_ms,
            "split {} vs plain {}",
            t_split.inference_ms,
            t_plain.inference_ms
        );
    }

    #[test]
    fn big_switch_topology_is_bit_for_bit_simulate_group() {
        let a = toy(6, 41, 0.04);
        let b = toy(6, 42, 0.04);
        let c = toy(6, 43, 0.04);
        let cluster = Cluster::homogeneous(6, 1.0);
        for models in [vec![&a], vec![&a, &b], vec![&a, &b, &c]] {
            let flat = simulate_group(&models, &cluster, SchedulePolicy::Aurora);
            let topo = simulate_group_topology(
                &models,
                &cluster,
                &Topology::BigSwitch,
                SchedulePolicy::Aurora,
            );
            assert_eq!(flat.0, topo.0);
            assert_eq!(flat.1, topo.1);
        }
    }

    #[test]
    fn oversubscription_slows_the_simulated_layer() {
        let a = toy(8, 51, 0.01);
        let b = toy(8, 52, 0.01);
        let cluster = Cluster::homogeneous(8, 1.0);
        let mut last = 0.0f64;
        for os in [1.0, 2.0, 4.0] {
            let topo = Topology::even_two_tier(8, 2, os).unwrap();
            let (r, _) =
                simulate_group_topology(&[&a, &b], &cluster, &topo, SchedulePolicy::Aurora);
            assert!(r.inference_ms >= last - 1e-9, "os={os}");
            last = r.inference_ms;
        }
    }

    #[test]
    fn zero_traffic_group_still_serializes_compute() {
        let mk = || MoeLayerStats {
            traffic: TrafficMatrix::zeros(4),
            gate_ms: 1.0,
            ffn_ms_per_token: 0.0,
            agg_ms: 1.0,
        };
        let (a, b, c) = (mk(), mk(), mk());
        let cluster = Cluster::homogeneous(4, 1.0);
        let (r, t) = simulate_group(&[&a, &b, &c], &cluster, SchedulePolicy::Aurora);
        assert_eq!(r.comm_ms, 0.0);
        // gates of models 1 and 2 serialize: e_gate = 2.0, then aggs 3 × 1 ms,
        // then the closing gate — all compute, no comm.
        assert!(t.end >= 2.0 + 3.0 + 1.0 - 1e-9);
    }
}
