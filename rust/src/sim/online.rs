//! Serving-window simulation with background (weight-migration) traffic.
//!
//! The online coordinator ([`crate::coordinator`]) stages expert weights
//! over the **same per-GPU ports** tokens use, so a window served during
//! staging must pay link contention. [`simulate_window`] models that by
//! treating the staged weight matrix as one more colocated "model" with
//! zero compute — [`simulate_group`] then charges it in both collectives'
//! aggregated makespans (a deliberate upper bound: weights are assumed on
//! the wire during the whole window, which can only *overstate* the
//! migration cost the coordinator pays, never hide it). With no background
//! traffic the result is bit-for-bit [`simulate_group`].
//!
//! **Gray failures**: every window entry point takes optional per-GPU
//! effective-rate scales ([`GpuScales`]) — the truth of any injected
//! degradation ([`crate::coordinator::ClusterEvent::GpuDegraded`]). With
//! scales present the window is simulated on the *effective* cluster
//! ([`GpuScales::scaled`]), so a throttled GPU's compute segments stretch
//! and a flaky link's transfers slow down in the recorded timeline — which
//! is exactly what an observing detector must see. `None` (or all-nominal
//! scales) is bit-for-bit the nominal path.

use super::{simulate_group_topology_recorded, MoeLayerStats, SimResult};
use crate::cluster::{Cluster, GpuScales, Topology};
use crate::obs::timeline::TimelineRecorder;
use crate::schedule::SchedulePolicy;
use crate::traffic::TrafficMatrix;

/// Simulate one serving window: `models` are GPU-indexed layer stats (one
/// per served model, already projected through the deployment), `background`
/// an optional GPU-indexed traffic matrix sharing the links (e.g. staged
/// expert weights), `scales` optional per-GPU effective-rate degradation.
pub fn simulate_window(
    models: &[&MoeLayerStats],
    background: Option<&TrafficMatrix>,
    cluster: &Cluster,
    scales: Option<&GpuScales>,
    policy: SchedulePolicy,
) -> SimResult {
    simulate_window_topology(models, background, cluster, scales, &Topology::BigSwitch, policy)
}

/// [`simulate_window`] with timeline recording through `rec` (observational
/// only). Background staging traffic shows up as `SwapDrain` link segments.
pub fn simulate_window_recorded(
    models: &[&MoeLayerStats],
    background: Option<&TrafficMatrix>,
    cluster: &Cluster,
    scales: Option<&GpuScales>,
    policy: SchedulePolicy,
    rec: &mut TimelineRecorder,
) -> SimResult {
    simulate_window_topology_recorded(
        models,
        background,
        cluster,
        scales,
        &Topology::BigSwitch,
        policy,
        rec,
    )
}

/// [`simulate_window`] on a network topology: serving *and* staged-weight
/// traffic are priced by [`crate::schedule::comm_time_on`], so on a two-tier
/// fabric a migration crossing an oversubscribed uplink congests the windows
/// it stages under. Big switch ⇒ identical to [`simulate_window`]. Panics
/// when a two-tier grouping does not fit `cluster`.
pub fn simulate_window_topology(
    models: &[&MoeLayerStats],
    background: Option<&TrafficMatrix>,
    cluster: &Cluster,
    scales: Option<&GpuScales>,
    topo: &Topology,
    policy: SchedulePolicy,
) -> SimResult {
    simulate_window_topology_recorded(
        models,
        background,
        cluster,
        scales,
        topo,
        policy,
        &mut TimelineRecorder::disabled(),
    )
}

/// [`simulate_window_topology`] with timeline recording through `rec`
/// (observational only). The zero-compute background "model" is marked so
/// its link traffic is attributed to `SwapDrain` instead of comm.
pub fn simulate_window_topology_recorded(
    models: &[&MoeLayerStats],
    background: Option<&TrafficMatrix>,
    cluster: &Cluster,
    scales: Option<&GpuScales>,
    topo: &Topology,
    policy: SchedulePolicy,
    rec: &mut TimelineRecorder,
) -> SimResult {
    // Degradation rescales the cluster the whole window prices on: compute
    // divides by the effective flops_scale, serving *and* background traffic
    // drain at the effective port rates.
    let effective;
    let cluster = match scales {
        Some(s) if !s.is_nominal() => {
            effective = s.scaled(cluster);
            &effective
        }
        _ => cluster,
    };
    match background {
        None => simulate_group_topology_recorded(models, cluster, topo, policy, rec).0,
        Some(bg) if bg.total() == 0 => {
            simulate_group_topology_recorded(models, cluster, topo, policy, rec).0
        }
        Some(bg) => {
            assert_eq!(bg.n(), cluster.len(), "background traffic must be GPU-indexed");
            let bg_layer = MoeLayerStats {
                traffic: bg.clone(),
                gate_ms: 0.0,
                ffn_ms_per_token: 0.0,
                agg_ms: 0.0,
            };
            let mut all: Vec<&MoeLayerStats> = models.to_vec();
            all.push(&bg_layer);
            rec.set_swap_drain_model(models.len());
            simulate_group_topology_recorded(&all, cluster, topo, policy, rec).0
        }
    }
}

/// Tokens a GPU-indexed traffic matrix routes through non-alive GPUs: the
/// sum of every dead GPU's row (sends) and column (receives). The fault
/// path's safety assertion — after a [`crate::coordinator::ClusterEvent`]
/// failure is promoted, the projected serving traffic of every subsequent
/// window must score **zero** here (a dead GPU neither sends nor receives).
/// Diagonal (local) tokens of a dead GPU are counted twice; irrelevant for
/// the `== 0` check this backs.
pub fn dead_gpu_tokens(traffic: &TrafficMatrix, alive: &[bool]) -> u64 {
    assert_eq!(traffic.n(), alive.len(), "liveness mask must be GPU-indexed");
    (0..traffic.n())
        .filter(|&g| !alive[g])
        .map(|g| traffic.row_sum(g) + traffic.col_sum(g))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::simulate_group;
    use crate::traffic::zipf_traffic;

    fn stats(seed: u64) -> MoeLayerStats {
        MoeLayerStats {
            traffic: zipf_traffic(4, 256, 0.8, seed),
            gate_ms: 0.02,
            ffn_ms_per_token: 0.001,
            agg_ms: 0.015,
        }
    }

    #[test]
    fn no_background_is_bit_for_bit_simulate_group() {
        let s = stats(5);
        let cluster = Cluster::homogeneous(4, 100.0);
        let a = simulate_window(&[&s], None, &cluster, None, SchedulePolicy::Aurora);
        let b = simulate_group(&[&s], &cluster, SchedulePolicy::Aurora).0;
        assert_eq!(a, b);
        // an all-zero background takes the same path
        let z = TrafficMatrix::zeros(4);
        let c = simulate_window(&[&s], Some(&z), &cluster, None, SchedulePolicy::Aurora);
        assert_eq!(a, c);
    }

    #[test]
    fn dead_gpu_tokens_counts_rows_and_columns() {
        let mut t = TrafficMatrix::zeros(3);
        t.set(0, 1, 10);
        t.set(1, 2, 7);
        t.set(2, 0, 5);
        assert_eq!(dead_gpu_tokens(&t, &[true, true, true]), 0);
        // GPU 2 dead: receives 7, sends 5
        assert_eq!(dead_gpu_tokens(&t, &[true, true, false]), 12);
        assert_eq!(dead_gpu_tokens(&t, &[false, true, true]), 15);
    }

    #[test]
    fn degraded_scales_slow_compute_and_links_in_the_recorded_timeline() {
        let s = stats(11);
        let cluster = Cluster::homogeneous(4, 100.0);
        let mut rec = TimelineRecorder::new(4);
        let clean = simulate_window_recorded(&[&s], None, &cluster, None, SchedulePolicy::Aurora, &mut rec);
        let clean_tl = rec.take().unwrap();

        // nominal scales are bit-for-bit the no-scales path
        let nominal = GpuScales::nominal(4);
        let same = simulate_window(&[&s], None, &cluster, Some(&nominal), SchedulePolicy::Aurora);
        assert_eq!(clean, same);

        // throttle GPU 1's compute to 0.4× and its port to 0.5×
        let mut scales = GpuScales::nominal(4);
        scales.set(1, 0.4, 0.5);
        let mut rec = TimelineRecorder::new(4);
        let slow = simulate_window_recorded(
            &[&s],
            None,
            &cluster,
            Some(&scales),
            SchedulePolicy::Aurora,
            &mut rec,
        );
        let slow_tl = rec.take().unwrap();
        assert!(slow.inference_ms > clean.inference_ms);

        // the straggler's compute segments stretch by exactly 1/0.4
        let clean_c = clean_tl.per_gpu_compute_ms();
        let slow_c = slow_tl.per_gpu_compute_ms();
        assert!((slow_c[1] - clean_c[1] / 0.4).abs() < 1e-9, "{} vs {}", slow_c[1], clean_c[1] / 0.4);
        // unaffected GPUs' compute totals are untouched (waits differ, busy doesn't)
        for g in [0, 2, 3] {
            assert!((slow_c[g] - clean_c[g]).abs() < 1e-9);
        }
        // the straggler's link busy time stretches by exactly 1/0.5
        let clean_l = clean_tl.uplinks[1].busy_ms() + clean_tl.downlinks[1].busy_ms();
        let slow_l = slow_tl.uplinks[1].busy_ms() + slow_tl.downlinks[1].busy_ms();
        assert!(clean_l > 0.0);
        assert!((slow_l - clean_l / 0.5).abs() < 1e-9, "{} vs {}", slow_l, clean_l / 0.5);
    }

    #[test]
    fn background_traffic_never_shortens_the_window() {
        let s = stats(9);
        let cluster = Cluster::homogeneous(4, 100.0);
        let clean = simulate_window(&[&s], None, &cluster, None, SchedulePolicy::Aurora);
        let mut bg = TrafficMatrix::zeros(4);
        bg.set(0, 1, 500);
        bg.set(2, 3, 500);
        let loaded = simulate_window(&[&s], Some(&bg), &cluster, None, SchedulePolicy::Aurora);
        assert!(
            loaded.inference_ms >= clean.inference_ms,
            "background {} vs clean {}",
            loaded.inference_ms,
            clean.inference_ms
        );
        // a big enough transfer dominates the window
        let mut heavy = TrafficMatrix::zeros(4);
        heavy.set(0, 1, 50_000);
        let slow = simulate_window(&[&s], Some(&heavy), &cluster, None, SchedulePolicy::Aurora);
        assert!(slow.inference_ms > clean.inference_ms * 2.0);
    }
}
