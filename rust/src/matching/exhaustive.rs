//! Exhaustive permutation search — the optimality oracle.
//!
//! Used by tests (to certify [`super::bottleneck_matching`]) and by the
//! Fig. 13 brute-force optimum in the Colocating + Heterogeneous scenario.

/// Call `f` with every permutation of `0..n` (Heap's algorithm).
///
/// `f` receives the permutation slice; `n = 0` yields a single empty call.
pub fn for_each_permutation(n: usize, mut f: impl FnMut(&[usize])) {
    let mut perm: Vec<usize> = (0..n).collect();
    let mut c = vec![0usize; n];
    f(&perm);
    let mut i = 0;
    while i < n {
        if c[i] < i {
            if i % 2 == 0 {
                perm.swap(0, i);
            } else {
                perm.swap(c[i], i);
            }
            f(&perm);
            c[i] += 1;
            i = 0;
        } else {
            c[i] = 0;
            i += 1;
        }
    }
}

/// Brute-force bottleneck matching by enumerating all `n!` permutations.
/// Only sensible for small `n` (tests use `n ≤ 8`).
pub fn exhaustive_bottleneck(n: usize, weight: impl Fn(usize, usize) -> f64) -> (f64, Vec<usize>) {
    assert!(n > 0);
    let mut best = f64::INFINITY;
    let mut best_perm = (0..n).collect::<Vec<_>>();
    for_each_permutation(n, |perm| {
        let m = (0..n)
            .map(|i| weight(i, perm[i]))
            .fold(f64::NEG_INFINITY, f64::max);
        if m < best {
            best = m;
            best_perm = perm.to_vec();
        }
    });
    (best, best_perm)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permutation_count_is_factorial() {
        for (n, fact) in [(0usize, 1usize), (1, 1), (2, 2), (3, 6), (4, 24), (5, 120)] {
            let mut count = 0;
            for_each_permutation(n, |_| count += 1);
            assert_eq!(count, fact, "n={n}");
        }
    }

    #[test]
    fn permutations_are_all_distinct() {
        let mut seen = std::collections::HashSet::new();
        for_each_permutation(4, |p| {
            assert!(seen.insert(p.to_vec()));
        });
        assert_eq!(seen.len(), 24);
    }

    #[test]
    fn exhaustive_finds_known_optimum() {
        // weight(i,j) = |i - j|: identity gives bottleneck 0
        let (b, p) = exhaustive_bottleneck(5, |i, j| (i as f64 - j as f64).abs());
        assert_eq!(b, 0.0);
        assert_eq!(p, vec![0, 1, 2, 3, 4]);
    }
}
