//! Bipartite matching algorithms.
//!
//! Aurora's colocation and assignment decisions reduce to matching problems:
//!
//! * Case II expert colocation (§6.2) and the decoupled heterogeneous stages
//!   (§7.2) are **bottleneck matching** problems — find a perfect matching
//!   minimizing the maximum edge weight — solved by binary search over sorted
//!   edge weights with **Hopcroft–Karp** feasibility checks
//!   (`O(n² √n log n)`, exactly the paper's stated complexity).
//! * The Birkhoff–von-Neumann slot decomposition in [`crate::schedule`]
//!   extracts perfect matchings from the support of the balanced traffic
//!   matrix, again via Hopcroft–Karp.
//! * [`exhaustive_bottleneck`] enumerates all permutations for small `n` —
//!   the optimality oracle used by tests and the Fig. 13 brute-force
//!   comparison.
//! * [`hungarian_min_sum`] (min-*sum* assignment) backs an ablation: the
//!   paper argues the bottleneck objective, not the sum objective, is the
//!   right one.

mod bottleneck;
mod exhaustive;
mod hopcroft_karp;
mod hungarian;

pub use bottleneck::bottleneck_matching;
pub use exhaustive::{exhaustive_bottleneck, for_each_permutation};
pub use hopcroft_karp::{max_bipartite_matching, perfect_matching_on};
pub use hungarian::hungarian_min_sum;
