//! Hungarian algorithm (Jonker–Volgenant style shortest-augmenting-path),
//! `O(n³)` min-**sum** perfect matching.
//!
//! Aurora's objective is min-*max* (bottleneck), not min-sum; this
//! implementation backs the ablation bench that quantifies how much worse a
//! min-sum colocation is on the paper's inference-time objective.

/// Min-sum perfect matching on an `n × n` cost matrix.
///
/// Returns `(total_cost, perm)` with `perm[i]` = column assigned to row `i`.
pub fn hungarian_min_sum(cost: &[Vec<f64>]) -> (f64, Vec<usize>) {
    let n = cost.len();
    assert!(n > 0 && cost.iter().all(|r| r.len() == n), "square matrix required");
    const INF: f64 = f64::INFINITY;

    // 1-indexed potentials/links per the classic formulation.
    let mut u = vec![0.0; n + 1];
    let mut v = vec![0.0; n + 1];
    let mut p = vec![0usize; n + 1]; // p[j] = row matched to column j (1-indexed)
    let mut way = vec![0usize; n + 1];

    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0;
            for j in 1..=n {
                if !used[j] {
                    let cur = cost[i0 - 1][j - 1] - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }

    let mut perm = vec![0usize; n];
    for j in 1..=n {
        if p[j] > 0 {
            perm[p[j] - 1] = j - 1;
        }
    }
    let total = (0..n).map(|i| cost[i][perm[i]]).sum();
    (total, perm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matching::for_each_permutation;
    use crate::util::Rng;

    fn exhaustive_min_sum(cost: &[Vec<f64>]) -> f64 {
        let n = cost.len();
        let mut best = f64::INFINITY;
        for_each_permutation(n, |perm| {
            let s: f64 = (0..n).map(|i| cost[i][perm[i]]).sum();
            if s < best {
                best = s;
            }
        });
        best
    }

    #[test]
    fn solves_known_instance() {
        let cost = vec![
            vec![4.0, 1.0, 3.0],
            vec![2.0, 0.0, 5.0],
            vec![3.0, 2.0, 2.0],
        ];
        let (total, perm) = hungarian_min_sum(&cost);
        assert_eq!(total, 5.0); // 1 + 2 + 2
        let mut seen = vec![false; 3];
        for &j in &perm {
            assert!(!seen[j]);
            seen[j] = true;
        }
    }

    #[test]
    fn matches_exhaustive_on_random_instances() {
        let mut rng = Rng::new(31);
        for n in 1..=6 {
            for _ in 0..10 {
                let cost: Vec<Vec<f64>> = (0..n)
                    .map(|_| (0..n).map(|_| rng.gen_range(100) as f64).collect())
                    .collect();
                let (total, _) = hungarian_min_sum(&cost);
                let best = exhaustive_min_sum(&cost);
                assert!((total - best).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn identity_optimal_when_diagonal_cheapest() {
        let n = 5;
        let cost: Vec<Vec<f64>> = (0..n)
            .map(|i| (0..n).map(|j| if i == j { 0.0 } else { 1.0 }).collect())
            .collect();
        let (total, perm) = hungarian_min_sum(&cost);
        assert_eq!(total, 0.0);
        assert_eq!(perm, vec![0, 1, 2, 3, 4]);
    }
}
