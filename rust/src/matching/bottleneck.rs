//! Bottleneck matching: perfect matching minimizing the maximum edge weight.
//!
//! Paper §6.2: binary search on the sorted edge-weight array; at each
//! candidate weight `w`, test with Hopcroft–Karp whether the subgraph of
//! edges `≤ w` admits a perfect matching. Overall `O(n² √n log n)`.

use super::hopcroft_karp::perfect_matching_on;

/// Solve the bottleneck matching problem on a complete bipartite graph.
///
/// `weight(i, j)` is the cost of pairing left `i` with right `j`. Returns
/// `(bottleneck, perm)` where `perm[i]` is the right partner of left `i` and
/// `bottleneck = max_i weight(i, perm[i])` is minimal over all perfect
/// matchings. Panics if `n == 0`.
pub fn bottleneck_matching(n: usize, weight: impl Fn(usize, usize) -> f64) -> (f64, Vec<usize>) {
    assert!(n > 0, "bottleneck matching needs n >= 1");

    // Collect and sort the distinct edge weights.
    let mut weights: Vec<f64> = Vec::with_capacity(n * n);
    for i in 0..n {
        for j in 0..n {
            weights.push(weight(i, j));
        }
    }
    weights.sort_by(|a, b| a.partial_cmp(b).unwrap());
    weights.dedup();

    // Binary search the smallest threshold admitting a perfect matching.
    // The full graph always has one, so `hi` is always feasible.
    let (mut lo, mut hi) = (0usize, weights.len() - 1);
    while lo < hi {
        let mid = (lo + hi) / 2;
        let w = weights[mid];
        if perfect_matching_on(n, |i, j| weight(i, j) <= w).is_some() {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    let w_min = weights[lo];
    let perm = perfect_matching_on(n, |i, j| weight(i, j) <= w_min)
        .expect("threshold was verified feasible");
    let bottleneck = (0..n)
        .map(|i| weight(i, perm[i]))
        .fold(f64::NEG_INFINITY, f64::max);
    (bottleneck, perm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matching::exhaustive_bottleneck;
    use crate::util::Rng;

    #[test]
    fn trivial_n1() {
        let (w, p) = bottleneck_matching(1, |_, _| 3.5);
        assert_eq!(w, 3.5);
        assert_eq!(p, vec![0]);
    }

    #[test]
    fn picks_off_diagonal_when_diagonal_expensive() {
        // identity pairing costs 10, everything else 1 -> bottleneck 1
        let (w, p) = bottleneck_matching(3, |i, j| if i == j { 10.0 } else { 1.0 });
        assert_eq!(w, 1.0);
        for (i, &j) in p.iter().enumerate() {
            assert_ne!(i, j);
        }
    }

    #[test]
    fn perm_is_valid_permutation() {
        let mut rng = Rng::new(42);
        let n = 12;
        let w: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..n).map(|_| rng.gen_f64() * 100.0).collect())
            .collect();
        let (_, p) = bottleneck_matching(n, |i, j| w[i][j]);
        let mut seen = vec![false; n];
        for &j in &p {
            assert!(!seen[j]);
            seen[j] = true;
        }
    }

    #[test]
    fn matches_exhaustive_optimum_small_n() {
        let mut rng = Rng::new(7);
        for n in 1..=6 {
            for _ in 0..10 {
                let w: Vec<Vec<f64>> = (0..n)
                    .map(|_| (0..n).map(|_| (rng.gen_range(50)) as f64).collect())
                    .collect();
                let (b, _) = bottleneck_matching(n, |i, j| w[i][j]);
                let (b_opt, _) = exhaustive_bottleneck(n, |i, j| w[i][j]);
                assert_eq!(b, b_opt, "n={n} w={w:?}");
            }
        }
    }

    #[test]
    fn bottleneck_never_above_any_sampled_matching() {
        let mut rng = Rng::new(99);
        let n = 10;
        let w: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..n).map(|_| rng.gen_f64()).collect())
            .collect();
        let (b, _) = bottleneck_matching(n, |i, j| w[i][j]);
        for _ in 0..200 {
            let perm = rng.permutation(n);
            let m = (0..n).map(|i| w[i][perm[i]]).fold(0.0, f64::max);
            assert!(b <= m + 1e-12);
        }
    }
}
