//! Hopcroft–Karp maximum bipartite matching, `O(E √V)`.

/// Maximum matching in a bipartite graph with `n` left and `n` right nodes.
///
/// `adj[u]` lists the right-side neighbours of left node `u`. Returns
/// `(size, pair_left)` where `pair_left[u] = Some(v)` iff `u` is matched to
/// right node `v`.
pub fn max_bipartite_matching(n: usize, adj: &[Vec<usize>]) -> (usize, Vec<Option<usize>>) {
    assert_eq!(adj.len(), n);
    const INF: u32 = u32::MAX;
    let mut pair_u: Vec<Option<usize>> = vec![None; n];
    let mut pair_v: Vec<Option<usize>> = vec![None; n];
    let mut dist = vec![INF; n];
    let mut queue = std::collections::VecDeque::new();

    // BFS phase: layer the graph from free left vertices.
    let bfs = |pair_u: &[Option<usize>],
               pair_v: &[Option<usize>],
               dist: &mut [u32],
               queue: &mut std::collections::VecDeque<usize>|
     -> bool {
        queue.clear();
        for u in 0..n {
            if pair_u[u].is_none() {
                dist[u] = 0;
                queue.push_back(u);
            } else {
                dist[u] = INF;
            }
        }
        let mut found = false;
        while let Some(u) = queue.pop_front() {
            for &v in &adj[u] {
                match pair_v[v] {
                    None => found = true,
                    Some(u2) => {
                        if dist[u2] == INF {
                            dist[u2] = dist[u] + 1;
                            queue.push_back(u2);
                        }
                    }
                }
            }
        }
        found
    };

    // DFS phase: find augmenting paths along the layering.
    fn dfs(
        u: usize,
        adj: &[Vec<usize>],
        pair_u: &mut [Option<usize>],
        pair_v: &mut [Option<usize>],
        dist: &mut [u32],
    ) -> bool {
        for idx in 0..adj[u].len() {
            let v = adj[u][idx];
            let ok = match pair_v[v] {
                None => true,
                Some(u2) => {
                    dist[u2] == dist[u].wrapping_add(1) && dfs(u2, adj, pair_u, pair_v, dist)
                }
            };
            if ok {
                pair_u[u] = Some(v);
                pair_v[v] = Some(u);
                return true;
            }
        }
        dist[u] = u32::MAX;
        false
    }

    let mut matching = 0;
    while bfs(&pair_u, &pair_v, &mut dist, &mut queue) {
        for u in 0..n {
            if pair_u[u].is_none() && dfs(u, adj, &mut pair_u, &mut pair_v, &mut dist) {
                matching += 1;
            }
        }
    }
    (matching, pair_u)
}

/// Perfect matching restricted to edges where `allowed(u, v)` holds.
///
/// Returns the left→right permutation if a perfect matching exists.
pub fn perfect_matching_on(n: usize, allowed: impl Fn(usize, usize) -> bool) -> Option<Vec<usize>> {
    let adj: Vec<Vec<usize>> = (0..n)
        .map(|u| (0..n).filter(|&v| allowed(u, v)).collect())
        .collect();
    let (size, pairs) = max_bipartite_matching(n, &adj);
    if size == n {
        Some(pairs.into_iter().map(|p| p.unwrap()).collect())
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn full_graph_has_perfect_matching() {
        let m = perfect_matching_on(5, |_, _| true).unwrap();
        let mut seen = vec![false; 5];
        for &v in &m {
            assert!(!seen[v]);
            seen[v] = true;
        }
    }

    #[test]
    fn identity_only_graph() {
        let m = perfect_matching_on(4, |u, v| u == v).unwrap();
        assert_eq!(m, vec![0, 1, 2, 3]);
    }

    #[test]
    fn no_perfect_matching_when_vertex_isolated() {
        assert!(perfect_matching_on(3, |u, _| u != 1).is_none());
    }

    #[test]
    fn hall_violation_detected() {
        // left {0,1} both only connect to right {0} -> no perfect matching
        assert!(perfect_matching_on(2, |_, v| v == 0).is_none());
    }

    #[test]
    fn max_matching_size_on_partial_graph() {
        // 0-0, 1-0, 1-1, 2-1 => max matching 2 on n=3 (vertex 2 of right unused
        // ... right vertex 2 isolated)
        let adj = vec![vec![0], vec![0, 1], vec![1]];
        let (size, _) = max_bipartite_matching(3, &adj);
        assert_eq!(size, 2);
    }

    #[test]
    fn random_permutation_graphs_match_perfectly() {
        let mut rng = Rng::new(77);
        for n in 1..=20 {
            let perm = rng.permutation(n);
            let m = perfect_matching_on(n, |u, v| perm[u] == v).unwrap();
            assert_eq!(m, perm);
        }
    }

    #[test]
    fn matching_is_consistent_pairing() {
        let mut rng = Rng::new(123);
        for _ in 0..20 {
            let n = 8;
            // random graph with density ~0.5
            let edges: Vec<Vec<bool>> = (0..n)
                .map(|_| (0..n).map(|_| rng.gen_f64() < 0.5).collect())
                .collect();
            let adj: Vec<Vec<usize>> = (0..n)
                .map(|u| (0..n).filter(|&v| edges[u][v]).collect())
                .collect();
            let (size, pairs) = max_bipartite_matching(n, &adj);
            let mut used = vec![false; n];
            let mut count = 0;
            for (u, p) in pairs.iter().enumerate() {
                if let Some(v) = p {
                    assert!(edges[u][*v], "matched edge must exist");
                    assert!(!used[*v], "right vertex reused");
                    used[*v] = true;
                    count += 1;
                }
            }
            assert_eq!(count, size);
        }
    }
}
