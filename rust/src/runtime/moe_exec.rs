//! The MoE model executor: gate + per-expert FFN artifacts, sparse dispatch
//! done in rust (the L3 analogue of the paper's all-to-all: token groups are
//! formed per expert and issued in the plan's transmission order).

use super::pjrt::{loaded_executable_forward, PjrtRuntime};
use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Parsed `artifacts/meta.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct MoeModelMeta {
    /// Number of experts.
    pub n_experts: usize,
    /// Embedding width.
    pub d_model: usize,
    /// FFN hidden width.
    pub d_ff: usize,
    /// Compiled token capacity of the gate / fused layer.
    pub capacity: usize,
    /// Ascending expert-FFN capacity buckets; each expert group runs on the
    /// smallest bucket that fits (§Perf: avoids full-capacity padding).
    pub ffn_capacities: Vec<usize>,
}

impl MoeModelMeta {
    /// Read and validate `meta.json` from the artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let get = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(|x| x.as_u64())
                .map(|x| x as usize)
                .with_context(|| format!("meta.json missing {k}"))
        };
        let capacity = get("capacity")?;
        let mut ffn_capacities: Vec<usize> = match v.get("ffn_capacities").and_then(|x| x.as_arr())
        {
            Some(arr) => arr
                .iter()
                .map(|c| c.as_u64().map(|c| c as usize).context("bad ffn_capacities"))
                .collect::<Result<_>>()?,
            None => vec![capacity], // legacy single-capacity artifact sets
        };
        ffn_capacities.sort_unstable();
        anyhow::ensure!(
            ffn_capacities.last() == Some(&capacity),
            "largest FFN bucket must equal the gate capacity"
        );
        Ok(Self {
            n_experts: get("n_experts")?,
            d_model: get("d_model")?,
            d_ff: get("d_ff")?,
            capacity,
            ffn_capacities,
        })
    }
}

/// A loaded MoE model: gate + per-expert FFN executables.
pub struct MoeModel {
    /// Model metadata (dims, capacity).
    pub meta: MoeModelMeta,
    gate: xla::PjRtLoadedExecutable,
    /// `experts[e][k]` = expert `e` compiled at `meta.ffn_capacities[k]`.
    experts: Vec<Vec<xla::PjRtLoadedExecutable>>,
    fused: Option<xla::PjRtLoadedExecutable>,
}

impl MoeModel {
    /// Load all artifacts from `dir` on the given runtime.
    pub fn load(rt: &PjrtRuntime, dir: &Path) -> Result<Self> {
        let meta = MoeModelMeta::load(dir)?;
        let gate = rt.load_hlo_text(&dir.join("gate.hlo.txt"))?;
        let mut experts = Vec::with_capacity(meta.n_experts);
        for e in 0..meta.n_experts {
            let mut buckets = Vec::with_capacity(meta.ffn_capacities.len());
            for &cap in &meta.ffn_capacities {
                // legacy layout (single capacity) uses the unsuffixed name
                let suffixed = dir.join(format!("expert_ffn_{e}_c{cap}.hlo.txt"));
                let path = if suffixed.exists() {
                    suffixed
                } else {
                    dir.join(format!("expert_ffn_{e}.hlo.txt"))
                };
                buckets.push(rt.load_hlo_text(&path)?);
            }
            experts.push(buckets);
        }
        let fused_path = dir.join("moe_layer.hlo.txt");
        let fused = if fused_path.exists() {
            Some(rt.load_hlo_text(&fused_path)?)
        } else {
            None
        };
        Ok(Self {
            meta,
            gate,
            experts,
            fused,
        })
    }

    /// Run the gate on a padded `[capacity, d_model]` buffer. Returns
    /// `(expert_idx, gate_weight)` for the first `n_tokens` rows.
    pub fn run_gate(&self, tokens: &[f32], n_tokens: usize) -> Result<(Vec<i32>, Vec<f32>)> {
        let out = loaded_executable_forward(
            &self.gate,
            tokens,
            self.meta.capacity,
            self.meta.d_model,
        )?;
        if out.len() != 2 {
            bail!("gate artifact must return (idx, weight), got {} outputs", out.len());
        }
        let idx: Vec<i32> = out[0].to_vec::<i32>()?;
        let weight: Vec<f32> = out[1].to_vec::<f32>()?;
        Ok((idx[..n_tokens].to_vec(), weight[..n_tokens].to_vec()))
    }

    /// Smallest compiled FFN capacity that holds `n_tokens`.
    ///
    /// Setting `AURORA_FFN_BUCKETS=off` forces the largest capacity — the
    /// pre-optimization behaviour, kept for the §Perf before/after benches.
    pub fn ffn_bucket(&self, n_tokens: usize) -> (usize, usize) {
        let last = self.meta.ffn_capacities.len() - 1;
        if std::env::var_os("AURORA_FFN_BUCKETS").is_some_and(|v| v == "off") {
            return (last, self.meta.ffn_capacities[last]);
        }
        for (k, &cap) in self.meta.ffn_capacities.iter().enumerate() {
            if cap >= n_tokens {
                return (k, cap);
            }
        }
        (last, self.meta.ffn_capacities[last])
    }

    /// Run expert `e`'s FFN on a padded `[cap, d_model]` buffer, where `cap`
    /// is the bucket returned by [`MoeModel::ffn_bucket`] for the group size.
    pub fn run_expert(&self, e: usize, tokens: &[f32], cap: usize) -> Result<Vec<f32>> {
        let k = self
            .meta
            .ffn_capacities
            .iter()
            .position(|&c| c == cap)
            .context("cap must be a compiled bucket")?;
        let out = loaded_executable_forward(&self.experts[e][k], tokens, cap, self.meta.d_model)?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Full MoE layer with **rust-side sparse dispatch**: gate, group tokens
    /// per expert (visiting experts in `expert_order` — the plan's
    /// transmission order), run each non-empty expert, combine weighted
    /// outputs. `tokens` is `[n_tokens, d_model]` flattened, `n_tokens ≤
    /// capacity`.
    pub fn forward_layer(
        &self,
        tokens: &[f32],
        n_tokens: usize,
        expert_order: &[usize],
    ) -> Result<Vec<f32>> {
        let d = self.meta.d_model;
        let cap = self.meta.capacity;
        assert!(n_tokens <= cap, "batch exceeds compiled capacity");
        assert_eq!(tokens.len(), n_tokens * d);

        let mut padded = vec![0f32; cap * d];
        padded[..tokens.len()].copy_from_slice(tokens);
        let (idx, weight) = self.run_gate(&padded, n_tokens)?;
        self.forward_with_gate(tokens, n_tokens, expert_order, &idx, &weight)
    }

    /// [`MoeModel::forward_layer`] with a pre-computed gate decision — the
    /// serving engine runs the gate once for statistics *and* dispatch
    /// (§Perf: the original path gated every batch twice).
    pub fn forward_with_gate(
        &self,
        tokens: &[f32],
        n_tokens: usize,
        expert_order: &[usize],
        idx: &[i32],
        weight: &[f32],
    ) -> Result<Vec<f32>> {
        let d = self.meta.d_model;
        assert_eq!(idx.len(), n_tokens);
        assert_eq!(weight.len(), n_tokens);

        let mut out = vec![0f32; n_tokens * d];
        for &e in expert_order {
            let rows: Vec<usize> = (0..n_tokens).filter(|&t| idx[t] as usize == e).collect();
            if rows.is_empty() {
                continue;
            }
            // pad only to the smallest compiled bucket that fits the group
            let (_, bucket_cap) = self.ffn_bucket(rows.len());
            let mut group = vec![0f32; bucket_cap * d];
            for (slot, &t) in rows.iter().enumerate() {
                group[slot * d..(slot + 1) * d].copy_from_slice(&tokens[t * d..(t + 1) * d]);
            }
            let y = self.run_expert(e, &group, bucket_cap)?;
            for (slot, &t) in rows.iter().enumerate() {
                let w = weight[t];
                for c in 0..d {
                    out[t * d + c] = y[slot * d + c] * w;
                }
            }
        }
        Ok(out)
    }

    /// The fused single-executable layer (used to cross-check the split
    /// dispatch path and by latency benchmarks).
    pub fn forward_fused(&self, tokens: &[f32], n_tokens: usize) -> Result<Vec<f32>> {
        let fused = self
            .fused
            .as_ref()
            .context("moe_layer.hlo.txt not present in artifacts")?;
        let d = self.meta.d_model;
        let cap = self.meta.capacity;
        let mut padded = vec![0f32; cap * d];
        padded[..n_tokens * d].copy_from_slice(&tokens[..n_tokens * d]);
        let out = loaded_executable_forward(fused, &padded, cap, d)?;
        let y: Vec<f32> = out[0].to_vec::<f32>()?;
        Ok(y[..n_tokens * d].to_vec())
    }

    /// Per-expert token counts for a gated batch — the serving engine's
    /// statistics hook feeding the planner (§2.4 historical statistics).
    pub fn expert_histogram(&self, idx: &[i32]) -> Vec<u64> {
        let mut h = vec![0u64; self.meta.n_experts];
        for &e in idx {
            h[e as usize] += 1;
        }
        h
    }
}
