//! Thin wrapper over the `xla` crate's PJRT CPU client.

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT client plus helpers to load HLO-text artifacts.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
        })
    }

    /// Backend platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it into an executable.
    pub fn load_hlo_text(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }
}

/// Execute a single-input executable on an `[rows, cols]` f32 buffer and
/// return the tuple elements as flat `Vec<f32>` / raw literals.
///
/// All artifacts are lowered with `return_tuple=True`, so the output is
/// always a tuple; callers pick the elements they need.
pub fn loaded_executable_forward(
    exe: &xla::PjRtLoadedExecutable,
    input: &[f32],
    rows: usize,
    cols: usize,
) -> Result<Vec<xla::Literal>> {
    assert_eq!(input.len(), rows * cols, "input buffer shape mismatch");
    let lit = xla::Literal::vec1(input).reshape(&[rows as i64, cols as i64])?;
    let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
    Ok(result.to_tuple()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need `make artifacts` to have run; they are exercised via
    /// the integration test `rust/tests/integration_runtime.rs` which skips
    /// gracefully when artifacts are missing.
    #[test]
    fn cpu_client_comes_up() {
        let rt = PjrtRuntime::cpu().unwrap();
        assert!(!rt.platform().is_empty());
    }
}
