//! PJRT runtime: load AOT HLO-text artifacts and execute them from rust.
//!
//! Python runs once (`make artifacts`); after that the rust binary is
//! self-contained. HLO **text** is the interchange format (see
//! DESIGN.md and /opt/xla-example/README.md: xla_extension 0.5.1 rejects
//! jax ≥ 0.5's serialized protos, while the text parser reassigns ids).

mod moe_exec;
mod pjrt;

pub use moe_exec::{MoeModel, MoeModelMeta};
pub use pjrt::{PjrtRuntime, loaded_executable_forward};
