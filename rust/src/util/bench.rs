//! Micro-benchmark harness (the offline build has no `criterion`).
//!
//! `cargo bench` targets use [`Bench`] to time closures with warmup,
//! multiple samples, and median/mean/min reporting. Deliberately simple:
//! wall-clock `Instant` timing around a closure that returns a value (kept
//! alive via `std::hint::black_box` to defeat dead-code elimination).

use std::time::{Duration, Instant};

/// Timing statistics of one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Benchmark label.
    pub name: String,
    /// Number of timed iterations.
    pub iters: u64,
    /// Median per-iteration time.
    pub median: Duration,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Fastest per-iteration time.
    pub min: Duration,
}

impl Sample {
    /// Render as a bench-style line.
    pub fn report(&self) -> String {
        format!(
            "{:<48} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            fmt_duration(self.median),
            fmt_duration(self.mean),
            fmt_duration(self.min),
            self.iters
        )
    }
}

fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Micro-benchmark runner.
pub struct Bench {
    /// Target total measurement time per case.
    pub budget: Duration,
    /// Warmup time per case.
    pub warmup: Duration,
    samples: Vec<Sample>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    /// Runner with default budget (0.6 s measure, 0.2 s warmup per case).
    pub fn new() -> Self {
        Self {
            budget: Duration::from_millis(600),
            warmup: Duration::from_millis(200),
            samples: Vec::new(),
        }
    }

    /// Print the header row.
    pub fn header() {
        println!(
            "{:<48} {:>12} {:>12} {:>12}",
            "benchmark", "median", "mean", "min"
        );
        println!("{}", "-".repeat(96));
    }

    /// Time `f`, printing and recording the result.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Sample {
        // Warmup + estimate per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters == 0 {
            std::hint::black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed() / warm_iters.max(1) as u32;

        // Sample in batches: aim for ~30 samples within the budget.
        let target_samples = 30u64;
        let batch = ((self.budget.as_nanos() as u64
            / target_samples.max(1)
            / per_iter.as_nanos().max(1) as u64)
            .max(1))
        .min(1_000_000);
        let mut times: Vec<Duration> = Vec::new();
        let start = Instant::now();
        let mut iters = 0u64;
        while start.elapsed() < self.budget || times.is_empty() {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            times.push(t0.elapsed() / batch as u32);
            iters += batch;
            if times.len() >= 200 {
                break;
            }
        }
        times.sort();
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<Duration>() / times.len() as u32;
        let min = times[0];
        let sample = Sample {
            name: name.to_string(),
            iters,
            median,
            mean,
            min,
        };
        println!("{}", sample.report());
        self.samples.push(sample);
        self.samples.last().unwrap()
    }

    /// All recorded samples.
    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        let mut b = Bench {
            budget: Duration::from_millis(20),
            warmup: Duration::from_millis(5),
            samples: Vec::new(),
        };
        let s = b.run("noop-ish", || 1 + 1).clone();
        assert!(s.iters > 0);
        assert!(s.min <= s.median);
        assert_eq!(b.samples().len(), 1);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_duration(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with("s"));
    }
}
