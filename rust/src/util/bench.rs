//! Micro-benchmark harness (the offline build has no `criterion`).
//!
//! `cargo bench` targets use [`Bench`] to time closures with warmup,
//! multiple samples, and median/mean/min reporting. Deliberately simple:
//! wall-clock `Instant` timing around a closure that returns a value (kept
//! alive via `std::hint::black_box` to defeat dead-code elimination).

use super::Json;
use crate::obs::Histogram;
use std::time::{Duration, Instant};

/// Timing statistics of one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Benchmark label.
    pub name: String,
    /// Number of timed iterations.
    pub iters: u64,
    /// Median per-iteration time.
    pub median: Duration,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Fastest per-iteration time.
    pub min: Duration,
    /// Log-bucketed distribution of the per-iteration times (ns) across the
    /// sample batches — the full shape, not just the median, so a perf
    /// snapshot can show tail behavior (and a bimodal case is visible).
    pub hist: Histogram,
}

impl Sample {
    /// Render as a bench-style line.
    pub fn report(&self) -> String {
        format!(
            "{:<48} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            fmt_duration(self.median),
            fmt_duration(self.mean),
            fmt_duration(self.min),
            self.iters
        )
    }

    /// Approximate 90th-percentile per-iteration time (ns).
    pub fn p90_ns(&self) -> f64 {
        self.hist.quantile(0.90).unwrap_or(0.0)
    }

    /// Approximate 99th-percentile per-iteration time (ns).
    pub fn p99_ns(&self) -> f64 {
        self.hist.quantile(0.99).unwrap_or(0.0)
    }
}

fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Micro-benchmark runner.
pub struct Bench {
    /// Target total measurement time per case.
    pub budget: Duration,
    /// Warmup time per case.
    pub warmup: Duration,
    samples: Vec<Sample>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    /// Runner with default budget (0.6 s measure, 0.2 s warmup per case).
    pub fn new() -> Self {
        Self {
            budget: Duration::from_millis(600),
            warmup: Duration::from_millis(200),
            samples: Vec::new(),
        }
    }

    /// Print the header row.
    pub fn header() {
        println!(
            "{:<48} {:>12} {:>12} {:>12}",
            "benchmark", "median", "mean", "min"
        );
        println!("{}", "-".repeat(96));
    }

    /// Time `f`, printing and recording the result.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Sample {
        // Warmup + estimate per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters == 0 {
            std::hint::black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed() / warm_iters.max(1) as u32;

        // Sample in batches: aim for ~30 samples within the budget.
        let target_samples = 30u64;
        let batch = ((self.budget.as_nanos() as u64
            / target_samples.max(1)
            / per_iter.as_nanos().max(1) as u64)
            .max(1))
        .min(1_000_000);
        let mut times: Vec<Duration> = Vec::new();
        let mut hist = Histogram::new();
        let start = Instant::now();
        let mut iters = 0u64;
        while start.elapsed() < self.budget || times.is_empty() {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            let per_iter = t0.elapsed() / batch as u32;
            hist.record(per_iter.as_nanos() as f64);
            times.push(per_iter);
            iters += batch;
            if times.len() >= 200 {
                break;
            }
        }
        times.sort();
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<Duration>() / times.len() as u32;
        let min = times[0];
        let sample = Sample {
            name: name.to_string(),
            iters,
            median,
            mean,
            min,
            hist,
        };
        println!("{}", sample.report());
        self.samples.push(sample);
        self.samples.last().unwrap()
    }

    /// All recorded samples.
    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }
}

/// One hot-path timing regression found by [`compare_entries`].
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRegression {
    /// Benchmark name.
    pub name: String,
    /// Baseline median (ns).
    pub baseline_ns: f64,
    /// Current median (ns).
    pub current_ns: f64,
    /// `current / baseline`.
    pub ratio: f64,
}

impl BenchRegression {
    /// Render as a gate-failure line.
    pub fn report(&self) -> String {
        format!(
            "{}: median {:.0} ns -> {:.0} ns ({:.2}x slower)",
            self.name, self.baseline_ns, self.current_ns, self.ratio
        )
    }
}

/// Diff two bench-history entries (objects carrying a `benchmarks` array of
/// `{name, median_ns, ...}` cases): returns every case present in **both**
/// whose median regressed by a factor above `max_regress` (1.25 = fail past
/// +25%), worst first. Cases unique to either side are ignored, so a commit
/// introducing a new benchmark cannot fail its own gate, and a removed case
/// stops gating. This is what `aurora bench --check` runs against the last
/// committed snapshot.
pub fn compare_entries(
    baseline: &Json,
    current: &Json,
    max_regress: f64,
) -> Vec<BenchRegression> {
    assert!(max_regress >= 1.0, "max_regress is a slowdown ratio >= 1");
    let cases = |v: &Json| -> Vec<(String, f64)> {
        v.get("benchmarks")
            .and_then(|b| b.as_arr())
            .map(|arr| {
                arr.iter()
                    .filter_map(|c| {
                        let name = c.get("name")?.as_str()?.to_string();
                        let median = c.get("median_ns")?.as_f64()?;
                        Some((name, median))
                    })
                    .collect()
            })
            .unwrap_or_default()
    };
    let base = cases(baseline);
    let mut out = Vec::new();
    for (name, current_ns) in cases(current) {
        let Some(&(_, baseline_ns)) = base.iter().find(|(n, _)| *n == name) else {
            continue;
        };
        if baseline_ns > 0.0 {
            let ratio = current_ns / baseline_ns;
            if ratio > max_regress {
                out.push(BenchRegression {
                    name,
                    baseline_ns,
                    current_ns,
                    ratio,
                });
            }
        }
    }
    out.sort_by(|a, b| b.ratio.partial_cmp(&a.ratio).unwrap());
    out
}

/// Short git SHA of the working tree's HEAD, if `git` is available and the
/// process runs inside a repository — stamps perf snapshots so the bench
/// history maps back to commits.
pub fn git_sha() -> Option<String> {
    let out = std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()?;
    if !out.status.success() {
        return None;
    }
    let sha = String::from_utf8(out.stdout).ok()?.trim().to_string();
    if sha.is_empty() {
        None
    } else {
        Some(sha)
    }
}

/// ISO-8601 UTC timestamp (`YYYY-MM-DDThh:mm:ssZ`) for `secs` seconds since
/// the Unix epoch. The offline build has no `chrono`, so the civil-from-days
/// conversion (Howard Hinnant's algorithm) is inlined here.
pub fn iso_utc(secs: u64) -> String {
    let days = (secs / 86_400) as i64;
    let rem = secs % 86_400;
    let (h, mi, s) = (rem / 3600, (rem % 3600) / 60, rem % 60);
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = yoe + era * 400 + i64::from(m <= 2);
    format!("{y:04}-{m:02}-{d:02}T{h:02}:{mi:02}:{s:02}Z")
}

/// [`iso_utc`] of the current system time.
pub fn iso_utc_now() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    iso_utc(secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        let mut b = Bench {
            budget: Duration::from_millis(20),
            warmup: Duration::from_millis(5),
            samples: Vec::new(),
        };
        let s = b.run("noop-ish", || 1 + 1).clone();
        assert!(s.iters > 0);
        assert!(s.min <= s.median);
        assert_eq!(b.samples().len(), 1);
        // the per-batch distribution rides along with the point stats
        assert!(s.hist.count() > 0);
        assert!(s.p90_ns() <= s.p99_ns());
        assert!(s.p99_ns() >= s.min.as_nanos() as f64);
    }

    #[test]
    fn iso_utc_known_instants() {
        assert_eq!(iso_utc(0), "1970-01-01T00:00:00Z");
        // leap day
        assert_eq!(iso_utc(951_782_400), "2000-02-29T00:00:00Z");
        // a well-known round number: 2023-11-14 22:13:20 UTC
        assert_eq!(iso_utc(1_700_000_000), "2023-11-14T22:13:20Z");
        // year boundary
        assert_eq!(iso_utc(1_704_067_199), "2023-12-31T23:59:59Z");
        assert_eq!(iso_utc(1_704_067_200), "2024-01-01T00:00:00Z");
    }

    #[test]
    fn iso_utc_now_has_the_right_shape() {
        let s = iso_utc_now();
        assert_eq!(s.len(), 20, "{s}");
        assert!(s.ends_with('Z'));
        assert_eq!(&s[4..5], "-");
        assert_eq!(&s[10..11], "T");
    }

    fn entry(cases: &[(&str, f64)]) -> Json {
        Json::obj(vec![(
            "benchmarks",
            Json::Arr(
                cases
                    .iter()
                    .map(|(n, m)| {
                        Json::obj(vec![("name", Json::from(*n)), ("median_ns", Json::Num(*m))])
                    })
                    .collect(),
            ),
        )])
    }

    #[test]
    fn compare_entries_flags_only_real_regressions() {
        let base = entry(&[("a", 100.0), ("b", 200.0), ("gone", 50.0)]);
        let cur = entry(&[("a", 110.0), ("b", 300.0), ("new", 9999.0)]);
        // a: 1.10x (inside the 1.25 band); b: 1.50x (regressed);
        // "gone"/"new" appear on one side only and never gate.
        let regs = compare_entries(&base, &cur, 1.25);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].name, "b");
        assert!((regs[0].ratio - 1.5).abs() < 1e-12);
        assert!(regs[0].report().contains("1.50x"));
        // a speedup never trips the gate
        let faster = entry(&[("a", 10.0), ("b", 20.0)]);
        assert!(compare_entries(&base, &faster, 1.25).is_empty());
    }

    #[test]
    fn compare_entries_sorts_worst_first_and_survives_junk() {
        let base = entry(&[("a", 100.0), ("b", 100.0)]);
        let cur = entry(&[("a", 200.0), ("b", 400.0)]);
        let regs = compare_entries(&base, &cur, 1.25);
        assert_eq!(regs.len(), 2);
        assert_eq!(regs[0].name, "b");
        assert!(regs[0].ratio > regs[1].ratio);
        // malformed entries compare as empty, not as a crash
        assert!(compare_entries(&Json::Null, &cur, 1.25).is_empty());
        assert!(compare_entries(&base, &Json::obj(vec![]), 1.25).is_empty());
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_duration(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with("s"));
    }
}
