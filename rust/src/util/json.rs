//! Minimal JSON support (the offline build has no `serde`).
//!
//! Implements the full JSON grammar minus some escape exotica (`\u` parses
//! BMP code points only). Used for config files ([`crate::config`]), trace
//! (de)serialization ([`crate::trace`]), and eval-harness report output.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document. Trailing whitespace is allowed; trailing
    /// non-whitespace is an error.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object constructor from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Field lookup on an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer accessor (floors the float; rejects non-numbers).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Bool accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse failure with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset of the error.
    pub pos: usize,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.pos;
                    let rest = &self.bytes[start..];
                    let len = utf8_len(rest[0]);
                    if rest.len() < len {
                        return Err(self.err("bad UTF-8"));
                    }
                    let chunk = std::str::from_utf8(&rest[..len])
                        .map_err(|_| self.err("bad UTF-8"))?;
                    s.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrips() {
        let cases = [
            r#"{"a":1,"b":[true,false,null],"c":"s\"t\n"}"#,
            "[1,2.5,-3]",
            "\"\"",
            "{}",
            "[]",
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let s = v.to_string_compact();
            assert_eq!(Json::parse(&s).unwrap(), v, "case {c}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "tru", "{\"a\"}", "1 2", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
        // control chars are escaped on output
        let s = Json::Str("a\u{1}b".into()).to_string_compact();
        assert_eq!(s, "\"a\\u0001b\"");
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string_compact(), "5");
        assert_eq!(Json::Num(5.25).to_string_compact(), "5.25");
    }

    #[test]
    fn accessors() {
        let v = Json::obj(vec![("n", 3u64.into()), ("s", "x".into()), ("b", true.into())]);
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("missing"), None);
        assert_eq!(Json::Null.as_f64(), None);
    }

    #[test]
    fn from_impls() {
        assert_eq!(Json::from(vec![1u64, 2]), Json::parse("[1,2]").unwrap());
        assert_eq!(Json::from("s"), Json::Str("s".into()));
    }
}
