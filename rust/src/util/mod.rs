//! Small shared utilities: deterministic RNG, formatting helpers.

pub mod bench;
pub mod json;
pub mod par;
pub mod rng;

pub use json::Json;
pub use rng::Rng;

/// Round a float to `digits` decimal places (used for stable report output).
pub fn round_to(x: f64, digits: u32) -> f64 {
    let p = 10f64.powi(digits as i32);
    (x * p).round() / p
}

/// Geometric mean of a slice (ignores non-positive entries).
pub fn geomean(xs: &[f64]) -> f64 {
    let vals: Vec<f64> = xs.iter().copied().filter(|v| *v > 0.0).collect();
    if vals.is_empty() {
        return 0.0;
    }
    (vals.iter().map(|v| v.ln()).sum::<f64>() / vals.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_to_works() {
        assert_eq!(round_to(1.23456, 2), 1.23);
        assert_eq!(round_to(1.235, 2), 1.24);
    }

    #[test]
    fn geomean_basic() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_ignores_nonpositive() {
        let g = geomean(&[0.0, -1.0, 2.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mean_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }
}
