//! Feature-gated data parallelism for the planner's candidate sweeps.
//!
//! The offline build vendors no external crates, so the `rayon` cargo
//! feature does not pull in the rayon crate itself; it enables an equivalent
//! scoped-thread fan-out ([`par_map`]) with rayon's semantics for this use
//! case (pure per-item closures, results in input order). Swapping the body
//! for `items.par_iter().map(f).collect()` is a one-line change once a real
//! dependency is allowed.
//!
//! Determinism contract: results are returned **in input order** regardless
//! of thread interleaving (an index-ordered reduction), so a parallel sweep
//! is bit-for-bit identical to the serial one — the planner's tie-breaking
//! (first candidate wins) never depends on scheduling.

/// Map `f` over `items`, returning results in input order.
///
/// With the `rayon` feature enabled the items are chunked across
/// `available_parallelism` scoped threads; without it this is a plain serial
/// map. `f` must be pure for the two modes to agree (every caller in this
/// crate passes a read-only evaluator closure).
#[cfg(feature = "rayon")]
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(items.len().max(1));
    if threads <= 1 || items.len() < 2 {
        return items.iter().map(&f).collect();
    }
    let chunk = items.len().div_ceil(threads);
    std::thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|part| {
                let f = &f;
                s.spawn(move || part.iter().map(f).collect::<Vec<R>>())
            })
            .collect();
        // Index-ordered reduction: chunks were cut in input order and are
        // joined in spawn order, so the concatenation is the serial result.
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("par_map worker panicked"))
            .collect()
    })
}

/// Serial fallback when the `rayon` feature is off: same signature, same
/// output, one thread.
#[cfg(not(feature = "rayon"))]
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    items.iter().map(f).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let xs: Vec<usize> = (0..97).collect();
        let ys = par_map(&xs, |&x| x * 3);
        assert_eq!(ys, (0..97).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn handles_tiny_inputs() {
        let none: Vec<u32> = vec![];
        assert!(par_map(&none, |&x| x).is_empty());
        assert_eq!(par_map(&[7u32], |&x| x + 1), vec![8]);
    }
}
