//! Deterministic xorshift64* RNG.
//!
//! All randomized components (trace generation, RCS / REC / RGA baselines,
//! property-test workload sampling in benches) go through this seeded generator
//! so every figure in EXPERIMENTS.md is exactly reproducible without pulling in
//! the `rand` crate.

/// A small, fast, seedable PRNG (xorshift64* — Vigna 2016).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. A zero seed is remapped to a fixed
    /// non-zero constant (xorshift has a zero fixed point).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`. Returns 0 when `n == 0`.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        // Lemire-style rejection-free mapping is fine here: modulo bias is
        // negligible for the small `n` used by the simulator workloads.
        self.next_u64() % n
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Falls back to uniform if all weights are zero.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.gen_range(weights.len() as u64) as usize;
        }
        let mut r = self.gen_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut a = Rng::new(0);
        assert_ne!(a.next_u64(), 0);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.gen_range(13);
            assert!(v < 13);
        }
        assert_eq!(r.gen_range(0), 0);
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Rng::new(3);
        let p = r.permutation(16);
        let mut seen = vec![false; 16];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn weighted_index_respects_zero_weights() {
        let mut r = Rng::new(5);
        for _ in 0..200 {
            let i = r.weighted_index(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn weighted_index_roughly_proportional() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 2];
        for _ in 0..10_000 {
            counts[r.weighted_index(&[1.0, 3.0])] += 1;
        }
        let frac = counts[1] as f64 / 10_000.0;
        assert!((frac - 0.75).abs() < 0.03, "frac={frac}");
    }
}
