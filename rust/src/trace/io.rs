//! Trace (de)serialization to JSON — lets users bring real production
//! statistics instead of the synthetic generator.

use super::ModelTrace;
use crate::sim::MoeLayerStats;
use crate::traffic::TrafficMatrix;
use crate::util::Json;

/// Serialize a trace to a JSON value.
pub fn trace_to_json(t: &ModelTrace) -> Json {
    let layers: Vec<Json> = t
        .layers
        .iter()
        .map(|l| {
            let n = l.traffic.n();
            let rows: Vec<Json> = (0..n)
                .map(|i| Json::Arr((0..n).map(|j| Json::from(l.traffic.get(i, j))).collect()))
                .collect();
            Json::obj(vec![
                ("traffic", Json::Arr(rows)),
                ("gate_ms", l.gate_ms.into()),
                ("ffn_ms_per_token", l.ffn_ms_per_token.into()),
                ("agg_ms", l.agg_ms.into()),
            ])
        })
        .collect();
    Json::obj(vec![
        ("name", t.name.as_str().into()),
        ("layers", Json::Arr(layers)),
    ])
}

/// Deserialize a trace from a JSON value. Returns a message on malformed
/// input.
pub fn trace_from_json(v: &Json) -> Result<ModelTrace, String> {
    let name = v
        .get("name")
        .and_then(|n| n.as_str())
        .ok_or("missing name")?
        .to_string();
    let layers_json = v
        .get("layers")
        .and_then(|l| l.as_arr())
        .ok_or("missing layers")?;
    if layers_json.is_empty() {
        return Err("trace needs at least one layer".into());
    }
    let mut layers = Vec::with_capacity(layers_json.len());
    for (k, lj) in layers_json.iter().enumerate() {
        let rows = lj
            .get("traffic")
            .and_then(|t| t.as_arr())
            .ok_or(format!("layer {k}: missing traffic"))?;
        let mut nested: Vec<Vec<u64>> = Vec::with_capacity(rows.len());
        for (i, row) in rows.iter().enumerate() {
            let cells = row.as_arr().ok_or(format!("layer {k}: bad row {i}"))?;
            let mut parsed = Vec::with_capacity(cells.len());
            for (j, c) in cells.iter().enumerate() {
                parsed.push(c.as_u64().ok_or(format!("layer {k}: bad cell ({i},{j})"))?);
            }
            nested.push(parsed);
        }
        // Shape checking is the matrix constructor's typed error
        // ([`crate::traffic::TrafficError`]), surfaced with layer context.
        let traffic = TrafficMatrix::from_nested(&nested).map_err(|e| format!("layer {k}: {e}"))?;
        let num = |key: &str| -> Result<f64, String> {
            lj.get(key)
                .and_then(|x| x.as_f64())
                .ok_or(format!("layer {k}: missing {key}"))
        };
        layers.push(MoeLayerStats {
            traffic,
            gate_ms: num("gate_ms")?,
            ffn_ms_per_token: num("ffn_ms_per_token")?,
            agg_ms: num("agg_ms")?,
        });
    }
    Ok(ModelTrace { name, layers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{limoe_trace, Dataset, LimoeVariant};

    #[test]
    fn roundtrip_preserves_trace() {
        let t = limoe_trace(LimoeVariant::B16, Dataset::Coco, 8, 4, 32, 5);
        let j = trace_to_json(&t);
        let text = j.to_string_compact();
        let back = trace_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            r#"{}"#,
            r#"{"name":"x"}"#,
            r#"{"name":"x","layers":[]}"#,
            r#"{"name":"x","layers":[{"traffic":[[0,1]],"gate_ms":1}]}"#,
            r#"{"name":"x","layers":[{"traffic":[[0,1],[1]],"gate_ms":1,"ffn_ms_per_token":1,"agg_ms":1}]}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(trace_from_json(&v).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn negative_cells_rejected() {
        let v = Json::parse(
            r#"{"name":"x","layers":[{"traffic":[[0,-1],[1,0]],"gate_ms":1,"ffn_ms_per_token":1,"agg_ms":1}]}"#,
        )
        .unwrap();
        assert!(trace_from_json(&v).is_err());
    }
}
