//! Synthetic LIMoE-like trace generation (§8.1 "MoE models").

use super::ModelTrace;
use crate::sim::MoeLayerStats;
use crate::traffic::TrafficMatrix;
use crate::util::Rng;

/// LIMoE model variant: the ViT patch size determines tokens per image.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LimoeVariant {
    /// ViT-B/16 — 196 tokens per image.
    B16,
    /// ViT-B/32 — 49 tokens per image.
    B32,
}

impl LimoeVariant {
    /// Tokens one image contributes to each MoE layer.
    pub fn tokens_per_image(&self) -> u64 {
        match self {
            LimoeVariant::B16 => 196,
            LimoeVariant::B32 => 49,
        }
    }

    /// Display slug.
    pub fn slug(&self) -> &'static str {
        match self {
            LimoeVariant::B16 => "b16",
            LimoeVariant::B32 => "b32",
        }
    }
}

/// Evaluation dataset. The paper derives inputs from COCO and ImageNet; the
/// two differ in how skewed expert routing is (multimodal COCO batches route
/// less evenly than ImageNet's single-domain images in LIMoE's published
/// routing statistics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    /// COCO captions — stronger expert specialization (higher skew).
    Coco,
    /// ImageNet — milder skew.
    Imagenet,
}

impl Dataset {
    /// Zipf-like skew exponent for expert popularity.
    fn skew(&self) -> f64 {
        match self {
            Dataset::Coco => 1.1,
            Dataset::Imagenet => 0.7,
        }
    }

    /// Display slug.
    pub fn slug(&self) -> &'static str {
        match self {
            Dataset::Coco => "coco",
            Dataset::Imagenet => "imagenet",
        }
    }
}

/// ViT-B FFN compute profile on the reference GPU, derived from the layer
/// shape (d_model 768, d_ff 3072): one token's expert FFN is
/// 2 · 2 · 768 · 3072 ≈ 9.4 MFLOPs. At a 10-TFLOP/s effective reference rate
/// that is ≈ 0.001 ms/token. Gate and aggregation are thin elementwise /
/// small-matmul ops; the paper's only requirement is that they are uniform
/// across GPUs (observation 2).
const FFN_MS_PER_TOKEN: f64 = 0.001;
const GATE_MS: f64 = 0.02;
const AGG_MS: f64 = 0.015;

/// Generate a LIMoE-like trace: `n_layers` MoE layers of an `n_experts`
/// model routing `batch_images` images.
///
/// Per layer, each of the `n_experts` source GPUs originates an equal shard
/// of the batch's tokens; destinations follow a layer-specific Zipf-like
/// expert popularity (rotated per layer so different layers favour different
/// experts, matching the LIMoE observation that routing varies by depth).
pub fn limoe_trace(
    variant: LimoeVariant,
    dataset: Dataset,
    n_experts: usize,
    n_layers: usize,
    batch_images: u64,
    seed: u64,
) -> ModelTrace {
    limoe_trace_topk(variant, dataset, n_experts, n_layers, batch_images, seed, 1)
}

/// [`limoe_trace`] with top-k routing (`k ∈ {1, 2}` — paper §2.1: "each token
/// will be sent to one or two experts"). Top-2 doubles the dispatched token
/// volume: every token contributes to two experts' loads and wire traffic.
pub fn limoe_trace_topk(
    variant: LimoeVariant,
    dataset: Dataset,
    n_experts: usize,
    n_layers: usize,
    batch_images: u64,
    seed: u64,
    top_k: usize,
) -> ModelTrace {
    assert!((1..=2).contains(&top_k), "MoE routing uses one or two experts (§2.1)");
    assert!(n_experts >= 2);
    let total_tokens = variant.tokens_per_image() * batch_images;
    let per_source = total_tokens / n_experts as u64;
    let mut rng = Rng::new(seed ^ 0x11_D0E5_C0DE);

    // Fraction of each source's tokens that follow a source-specific expert
    // preference rather than the global popularity. LIMoE's published
    // routing shows strong input locality (tokens of the same image/modality
    // cluster on the same experts); this is also what makes transmission
    // *ordering* matter — with purely global popularity every sender has the
    // same fan-out and head-of-line convoys are rare.
    const SOURCE_AFFINITY: f64 = 0.5;

    let mut layers = Vec::with_capacity(n_layers);
    for layer in 0..n_layers {
        // Zipf-like global popularity, rotated by layer and jittered per seed.
        let mut popularity: Vec<f64> = (0..n_experts)
            .map(|e| {
                let rank = ((e + layer * 3) % n_experts) as f64 + 1.0;
                (1.0 / rank.powf(dataset.skew())) * (0.85 + 0.3 * rng.gen_f64())
            })
            .collect();
        let total_pop: f64 = popularity.iter().sum();
        for p in &mut popularity {
            *p /= total_pop;
        }

        // Deterministic expected-value rounding beats per-token sampling
        // here: traces are reproducible and exactly row-uniform. Top-2 runs
        // the routing pass twice: the runner-up expert follows the same
        // popularity mix, doubling every source's dispatched volume.
        let mut d = TrafficMatrix::zeros(n_experts);
        for _route in 0..top_k {
        for i in 0..n_experts {
            // Source-specific preference: the same Zipf curve anchored at a
            // source-dependent expert.
            let mix: Vec<f64> = (0..n_experts)
                .map(|e| {
                    let pref = popularity[(e + i * 3) % n_experts];
                    (1.0 - SOURCE_AFFINITY) * popularity[e] + SOURCE_AFFINITY * pref
                })
                .collect();
            let mix_total: f64 = mix.iter().sum();
            let mut assigned = 0u64;
            for j in 0..n_experts {
                let share = (per_source as f64 * mix[j] / mix_total).floor() as u64;
                d.add(i, j, share);
                assigned += share;
            }
            // Distribute the rounding remainder by the mixed distribution.
            let mut rest = per_source - assigned;
            while rest > 0 {
                let j = rng.weighted_index(&mix);
                d.add(i, j, 1);
                rest -= 1;
            }
        }
        }
        layers.push(MoeLayerStats {
            traffic: d,
            gate_ms: GATE_MS,
            ffn_ms_per_token: FFN_MS_PER_TOKEN,
            agg_ms: AGG_MS,
        });
    }

    ModelTrace {
        name: if top_k == 1 {
            format!("limoe-{}-{}", variant.slug(), dataset.slug())
        } else {
            format!("limoe-{}-{}-top{}", variant.slug(), dataset.slug(), top_k)
        },
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shape_matches_paper_setup() {
        let t = limoe_trace(LimoeVariant::B16, Dataset::Coco, 8, 4, 64, 7);
        assert_eq!(t.layers.len(), 4);
        assert_eq!(t.n_experts(), 8);
        assert_eq!(t.name, "limoe-b16-coco");
    }

    #[test]
    fn row_sums_are_uniform() {
        let t = limoe_trace(LimoeVariant::B32, Dataset::Imagenet, 8, 4, 128, 3);
        for l in &t.layers {
            let expected = 49 * 128 / 8;
            for i in 0..8 {
                let total: u64 = (0..8).map(|j| l.traffic.get(i, j)).sum();
                assert_eq!(total, expected, "row {i}");
            }
        }
    }

    #[test]
    fn b16_carries_4x_b32_traffic() {
        let t16 = limoe_trace(LimoeVariant::B16, Dataset::Coco, 8, 1, 64, 7);
        let t32 = limoe_trace(LimoeVariant::B32, Dataset::Coco, 8, 1, 64, 7);
        let v16: u64 = t16.layers[0].expert_loads().iter().sum();
        let v32: u64 = t32.layers[0].expert_loads().iter().sum();
        assert_eq!(v16, 4 * v32);
    }

    #[test]
    fn coco_is_more_skewed_than_imagenet() {
        let skew = |t: &ModelTrace| {
            let loads = t.layers[0].expert_loads();
            let max = *loads.iter().max().unwrap() as f64;
            let min = *loads.iter().min().unwrap().max(&1) as f64;
            max / min
        };
        let coco = limoe_trace(LimoeVariant::B16, Dataset::Coco, 8, 1, 256, 1);
        let imnet = limoe_trace(LimoeVariant::B16, Dataset::Imagenet, 8, 1, 256, 1);
        assert!(skew(&coco) > skew(&imnet));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = limoe_trace(LimoeVariant::B16, Dataset::Coco, 8, 4, 64, 42);
        let b = limoe_trace(LimoeVariant::B16, Dataset::Coco, 8, 4, 64, 42);
        assert_eq!(a, b);
        let c = limoe_trace(LimoeVariant::B16, Dataset::Coco, 8, 4, 64, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn top2_doubles_dispatched_volume() {
        let t1 = limoe_trace_topk(LimoeVariant::B32, Dataset::Coco, 8, 1, 64, 5, 1);
        let t2 = limoe_trace_topk(LimoeVariant::B32, Dataset::Coco, 8, 1, 64, 5, 2);
        let v1: u64 = t1.layers[0].expert_loads().iter().sum();
        let v2: u64 = t2.layers[0].expert_loads().iter().sum();
        assert_eq!(v2, 2 * v1);
        assert!(t2.name.ends_with("top2"));
        // rows stay uniform at 2x
        for i in 0..8 {
            let row: u64 = (0..8).map(|j| t2.layers[0].traffic.get(i, j)).sum();
            assert_eq!(row, 2 * 49 * 64 / 8);
        }
    }

    #[test]
    #[should_panic]
    fn top3_rejected() {
        limoe_trace_topk(LimoeVariant::B32, Dataset::Coco, 8, 1, 64, 5, 3);
    }

    #[test]
    fn layers_differ_in_routing() {
        let t = limoe_trace(LimoeVariant::B16, Dataset::Coco, 8, 4, 64, 9);
        assert_ne!(t.layers[0].traffic, t.layers[1].traffic);
    }
}
