//! MoE inference traces — the workload substrate of the evaluation (§8.1).
//!
//! The paper drives its simulation with production statistics of Google's
//! LIMoE models (B/16 and B/32, 8 experts, 4 MoE layers) on the COCO and
//! ImageNet datasets [21]. Those statistics are not public, so this module
//! generates synthetic traces with the same *distributional* structure
//! (documented in DESIGN.md §Hardware-Adaptation):
//!
//! * every GPU originates an equal shard of the batch (uniform row sums);
//! * expert popularity is skewed (Zipf-like), dataset- and layer-dependent —
//!   the uneven token distribution of §2.3;
//! * B/16 sees 196 tokens per image, B/32 sees 49 (ViT patch counts), so
//!   B/16 layers carry ≈4× the traffic at the same batch size;
//! * compute times follow the ViT-B FFN shape (d_model 768, d_ff 3072)
//!   scaled to a reference-GPU token rate.
//!
//! [`noisy_traffic`] mixes in other layers' matrices to emulate the
//! unpredictable-request imprecision sweep of Q4 (Fig. 14).

mod io;
mod limoe;

pub use io::{trace_from_json, trace_to_json};
pub use limoe::{limoe_trace, limoe_trace_topk, Dataset, LimoeVariant};

use crate::sim::MoeLayerStats;
use crate::traffic::TrafficMatrix;

/// A generated inference trace: per-layer statistics of one MoE model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelTrace {
    /// Human-readable name, e.g. `limoe-b16-coco`.
    pub name: String,
    /// Per-MoE-layer statistics (the paper uses 4 layers).
    pub layers: Vec<MoeLayerStats>,
}

impl ModelTrace {
    /// Number of experts (uniform across layers).
    pub fn n_experts(&self) -> usize {
        self.layers[0].traffic.n()
    }

    /// Aggregate expert loads across layers (used for assignment decisions
    /// that must hold for the whole model).
    pub fn total_expert_loads(&self) -> Vec<u64> {
        let n = self.n_experts();
        let mut loads = vec![0u64; n];
        for l in &self.layers {
            for (e, v) in l.expert_loads().into_iter().enumerate() {
                loads[e] += v;
            }
        }
        loads
    }
}

/// Aggregate every model's per-layer traffic into one planning stat per
/// model — the multi-layer totals that [`crate::planner::Planner::plan_multi`]'s
/// general path and the replication split optimizer both plan on (the
/// multi-layer analogue of [`ModelTrace::total_expert_loads`]).
pub fn aggregate_totals(traces: &[&ModelTrace]) -> Vec<MoeLayerStats> {
    traces
        .iter()
        .map(|t| {
            let mut traffic = t.layers[0].traffic.clone();
            for l in &t.layers[1..] {
                traffic = traffic.sum(&l.traffic);
            }
            MoeLayerStats {
                traffic,
                ..t.layers[0]
            }
        })
        .collect()
}

/// Blend the planning-time matrix with traffic from other layers to model
/// imprecise inputs (Q4, Fig. 14): `noise_frac ∈ [0, 1]` is the fraction of
/// total tokens that come from the noise matrices instead of the planned one.
///
/// The result preserves the planned matrix's total volume so comparisons stay
/// load-neutral: `result = (1-f)·planned + f·mean(noise)`, rounded.
pub fn noisy_traffic(
    planned: &TrafficMatrix,
    noise_layers: &[&TrafficMatrix],
    noise_frac: f64,
) -> TrafficMatrix {
    assert!((0.0..=1.0).contains(&noise_frac));
    if noise_layers.is_empty() || noise_frac == 0.0 {
        return planned.clone();
    }
    let n = planned.n();
    // Scale each noise layer to the planned layer's total volume first, so
    // f only shifts *shape*, not load.
    let planned_total = planned.total().max(1) as f64;
    let mut out = TrafficMatrix::zeros(n);
    for i in 0..n {
        for j in 0..n {
            if i == j {
                out.set(i, j, planned.get(i, j));
                continue;
            }
            let mut noise_mean = 0.0;
            for nl in noise_layers {
                assert_eq!(nl.n(), n);
                let scale = planned_total / nl.total().max(1) as f64;
                noise_mean += nl.get(i, j) as f64 * scale;
            }
            noise_mean /= noise_layers.len() as f64;
            let v = (1.0 - noise_frac) * planned.get(i, j) as f64 + noise_frac * noise_mean;
            out.set(i, j, v.round() as u64);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize, fill: u64) -> TrafficMatrix {
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    d.set(i, j, fill + (i * n + j) as u64);
                }
            }
        }
        d
    }

    #[test]
    fn zero_noise_is_identity() {
        let p = mk(4, 3);
        let nz = mk(4, 9);
        assert_eq!(noisy_traffic(&p, &[&nz], 0.0), p);
        assert_eq!(noisy_traffic(&p, &[], 0.5), p);
    }

    #[test]
    fn full_noise_replaces_shape() {
        let p = mk(4, 2);
        let nz = mk(4, 50);
        let out = noisy_traffic(&p, &[&nz], 1.0);
        // totals stay close to planned (rounding aside)
        let ratio = out.total() as f64 / p.total() as f64;
        assert!((0.95..1.05).contains(&ratio), "ratio={ratio}");
        assert_ne!(out, p);
    }

    #[test]
    fn noise_interpolates_volume_neutrally() {
        let p = mk(5, 10);
        let nz1 = mk(5, 1);
        let nz2 = mk(5, 30);
        for f in [0.25, 0.5, 0.75] {
            let out = noisy_traffic(&p, &[&nz1, &nz2], f);
            let ratio = out.total() as f64 / p.total() as f64;
            assert!((0.9..1.1).contains(&ratio), "f={f} ratio={ratio}");
        }
    }

    #[test]
    fn model_trace_aggregates_loads() {
        let t = ModelTrace {
            name: "t".into(),
            layers: vec![
                MoeLayerStats {
                    traffic: mk(3, 1),
                    gate_ms: 0.1,
                    ffn_ms_per_token: 0.01,
                    agg_ms: 0.1,
                },
                MoeLayerStats {
                    traffic: mk(3, 2),
                    gate_ms: 0.1,
                    ffn_ms_per_token: 0.01,
                    agg_ms: 0.1,
                },
            ],
        };
        assert_eq!(t.n_experts(), 3);
        let loads = t.total_expert_loads();
        assert_eq!(loads.len(), 3);
        assert_eq!(
            loads.iter().sum::<u64>(),
            t.layers[0].expert_loads().iter().sum::<u64>()
                + t.layers[1].expert_loads().iter().sum::<u64>()
        );
    }
}
