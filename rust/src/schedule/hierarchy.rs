//! Two-phase hierarchical all-to-all scheduling for two-tier topologies.
//!
//! On a [`Topology::TwoTier`] fabric the flat Aurora order is no longer
//! contention-free: its rounds pair arbitrary GPUs, so a single round can
//! push several concurrent transfers through one oversubscribed uplink and
//! the round stretches by the uplink's congestion factor. The hierarchical
//! schedule ([`hierarchical_schedule`]) decomposes the traffic instead:
//!
//! 1. **Intra phase** — the traffic between members of one group never
//!    touches an uplink. Each group's submatrix gets its own Aurora slot
//!    schedule ([`super::aurora_schedule`]) running at full port rate:
//!    contention-free, makespan exactly the group's `b_max`.
//! 2. **Inter phase** — the residual cross-group traffic collapses to a
//!    group-level matrix `G[a][b] = Σ tokens a→b`. A **group-level BvN
//!    decomposition** (the same Alg. 1 machinery one level up) yields
//!    rounds in which every group sends to at most one group and receives
//!    from at most one — so each uplink carries exactly one group-flow per
//!    round and drains at its full rate. Within a round the group-flow is
//!    realized by **designated gateway senders**: the member flows of the
//!    (src group, dst group) pair, budget-balanced across senders so no
//!    single port serializes the whole round.
//! 3. **Stitch** — gateways use GPU ports the intra phase also wants, but
//!    the two phases occupy *different switches* otherwise. The pipelined
//!    makespan estimate interleaves them in the fluid limit:
//!    `max(intra, inter, per-GPU port drain)`; the sequential estimate
//!    (`intra + inter`) is the no-overlap upper bound. Both are reported.
//!
//! The inter phase's round budgets sum to exactly `b_max(G)` (Theorem 4.2
//! applied to the group graph), so with homogeneous uplinks the uplink
//! phase meets the uplink drain bound of
//! [`crate::cluster::topology::uplink_bound`] — the hierarchical schedule
//! achieves `max(port bound, uplink bound)` in the fluid limit, while flat
//! Aurora pays the per-round congestion [`flat_schedule_on_topology`]
//! makes visible.

use super::bvn::aurora_schedule;
use super::slot::{SlotRound, SlotSchedule};
use super::{comm_time, CommResult, SchedulePolicy};
use crate::cluster::topology::{comm_time_topology, uplink_bound, Topology, TopologyError};
use crate::cluster::Cluster;
use crate::traffic::TrafficMatrix;

/// One inter-group round: a partial permutation of *group* pairs, realized
/// by concrete gateway transfers.
#[derive(Debug, Clone, PartialEq)]
pub struct InterRound {
    /// Group-level round budget in tokens (per-uplink budget of the round).
    pub budget: u64,
    /// Active `(src_group, dst_group, tokens)` pairs — each group appears at
    /// most once as sender and once as receiver.
    pub pairs: Vec<(usize, usize, u64)>,
    /// Realized gateway flows `(src_gpu, dst_gpu, tokens)`. Unlike a
    /// [`SlotRound`], one GPU may carry several flows (the group's uplink is
    /// faster than one port precisely when oversubscription < group size).
    pub transfers: Vec<(usize, usize, u64)>,
}

/// The stitched two-phase schedule for one all-to-all on a two-tier fabric.
#[derive(Debug, Clone, PartialEq)]
pub struct HierarchicalSchedule {
    /// Number of GPUs.
    pub n: usize,
    /// Per-group intra-group Aurora schedules (global GPU ids).
    pub intra: Vec<SlotSchedule>,
    /// Group-level inter rounds with gateway realizations.
    pub inter: Vec<InterRound>,
    /// Intra-phase duration (ms): the slowest group's local `b_max` drain.
    pub intra_ms: f64,
    /// Inter-phase duration (ms): summed group-round times on the uplinks
    /// (gateway port occupancy included).
    pub inter_ms: f64,
    /// Fluid pipelined makespan estimate (ms):
    /// `max(intra, inter, per-GPU port drain)` — phases interleave on ports.
    pub pipelined_ms: f64,
    /// No-overlap upper bound (ms): `intra_ms + inter_ms`.
    pub sequential_ms: f64,
    /// Per-GPU finish estimate (ms): each GPU's own port drain joined with
    /// its group's intra and uplink phases.
    pub per_gpu_ms: Vec<f64>,
}

impl HierarchicalSchedule {
    /// Total real tokens moved per `(src, dst)` pair across both phases —
    /// for conservation checks against the original matrix.
    pub fn delivered(&self) -> TrafficMatrix {
        let mut m = TrafficMatrix::zeros(self.n);
        for s in &self.intra {
            for round in &s.rounds {
                for &(src, dst, real) in &round.transfers {
                    m.add(src, dst, real);
                }
            }
        }
        for round in &self.inter {
            for &(src, dst, tokens) in &round.transfers {
                m.add(src, dst, tokens);
            }
        }
        m
    }

    /// Sum of group-level round budgets (tokens). Equals `b_max` of the
    /// group-level matrix — the Theorem 4.2 bound one level up.
    pub fn inter_budget_tokens(&self) -> u64 {
        self.inter.iter().map(|r| r.budget).sum()
    }
}

/// Build the two-phase hierarchical schedule for `d` on `cluster` under a
/// two-tier `topo`. Errors on a big-switch topology (use
/// [`super::aurora_schedule`] there) or an invalid grouping.
pub fn hierarchical_schedule(
    d: &TrafficMatrix,
    cluster: &Cluster,
    topo: &Topology,
) -> Result<HierarchicalSchedule, TopologyError> {
    hierarchical_core(d, cluster, topo, true)
}

/// The shared construction. With `build_intra` the per-group Aurora slot
/// schedules are materialized (the executable schedule); without it `intra`
/// stays empty and only the timing estimate is computed — every duration
/// field is **identical** either way, because the intra phase is priced by
/// each group's `b_max` (which the group schedule achieves by Theorem 4.2),
/// never by walking its rounds. The estimate-only path is what the
/// simulator's hot loop takes ([`comm_time_on`] is called once per
/// collective), skipping one BvN decomposition per group per call.
fn hierarchical_core(
    d: &TrafficMatrix,
    cluster: &Cluster,
    topo: &Topology,
    build_intra: bool,
) -> Result<HierarchicalSchedule, TopologyError> {
    let n = d.n();
    assert_eq!(cluster.len(), n, "cluster and matrix sizes must match");
    // BigSwitch: no hierarchy to schedule.
    let owner = topo.owners(n)?.ok_or(TopologyError::NoGroups)?;
    let Topology::TwoTier { groups, .. } = topo else {
        unreachable!("owners returned Some for a non-two-tier topology")
    };
    let uplinks = topo.uplink_rates(cluster);
    let bw = cluster.bandwidths();
    let n_groups = groups.len();

    // ---- Phase 1: per-group Aurora on the intra submatrices. ----
    let mut intra = Vec::new();
    let mut intra_time = Vec::with_capacity(n_groups);
    let mut intra_ms = 0.0f64;
    for members in groups.iter() {
        let k = members.len();
        let mut local = TrafficMatrix::zeros(k);
        for (li, &i) in members.iter().enumerate() {
            for (lj, &j) in members.iter().enumerate() {
                if li != lj {
                    local.set(li, lj, d.get(i, j));
                }
            }
        }
        let member_bw: Vec<f64> = members.iter().map(|&i| bw[i]).collect();
        let group_ms = local.b_max_hetero(&member_bw);
        intra_time.push(group_ms);
        intra_ms = intra_ms.max(group_ms);
        if !build_intra {
            continue;
        }
        // Remap the local schedule to global GPU ids.
        let local_sched = aurora_schedule(&local);
        let rounds = local_sched
            .rounds
            .into_iter()
            .map(|r| SlotRound {
                duration: r.duration,
                transfers: r
                    .transfers
                    .into_iter()
                    .map(|(li, lj, t)| (members[li], members[lj], t))
                    .collect(),
            })
            .collect();
        intra.push(SlotSchedule { n, rounds });
    }

    // ---- Phase 2: group-level BvN over the cross traffic. ----
    let mut group_matrix = TrafficMatrix::zeros(n_groups);
    // Remaining cross flows per (src group, dst group), deterministic order.
    let mut cross: Vec<Vec<Vec<(usize, usize, u64)>>> = vec![vec![Vec::new(); n_groups]; n_groups];
    for i in 0..n {
        for j in 0..n {
            let t = d.get(i, j);
            if t == 0 || i == j || owner[i] == owner[j] {
                continue;
            }
            group_matrix.add(owner[i], owner[j], t);
            cross[owner[i]][owner[j]].push((i, j, t));
        }
    }

    let group_sched = aurora_schedule(&group_matrix);
    let mut inter = Vec::with_capacity(group_sched.rounds.len());
    let mut inter_ms = 0.0f64;
    for ground in &group_sched.rounds {
        let mut pairs = Vec::new();
        let mut transfers = Vec::new();
        let mut round_ms = 0.0f64;
        let mut tx = vec![0u64; n];
        let mut rx = vec![0u64; n];
        for &(ga, gb, tokens) in &ground.transfers {
            pairs.push((ga, gb, tokens));
            // Designated gateways: balance the round's budget across the
            // pair's member flows so no single sender port serializes it.
            let flows = &mut cross[ga][gb];
            let mut left = tokens;
            while left > 0 {
                let live = flows.iter().filter(|&&(_, _, rem)| rem > 0).count() as u64;
                debug_assert!(live > 0, "group matrix tracks remaining cross tokens");
                let fair = left.div_ceil(live);
                for (src, dst, rem) in flows.iter_mut() {
                    if *rem == 0 || left == 0 {
                        continue;
                    }
                    let take = fair.min(*rem).min(left);
                    if take == 0 {
                        continue;
                    }
                    *rem -= take;
                    left -= take;
                    tx[*src] += take;
                    rx[*dst] += take;
                    transfers.push((*src, *dst, take));
                }
            }
            // Pair drain: the slower of the two uplinks caps the flow.
            round_ms = round_ms.max(tokens as f64 / uplinks[ga].min(uplinks[gb]));
        }
        // Gateway port occupancy can exceed the uplink term when one sender
        // carries most of the pair budget; charge it honestly.
        for i in 0..n {
            if tx[i] > 0 || rx[i] > 0 {
                round_ms = round_ms.max(tx[i].max(rx[i]) as f64 / bw[i]);
            }
        }
        inter_ms += round_ms;
        inter.push(InterRound {
            budget: ground.duration,
            pairs,
            transfers,
        });
    }

    // ---- Stitch. ----
    let port_ms = (0..n)
        .map(|i| d.row_sum(i).max(d.col_sum(i)) as f64 / bw[i])
        .fold(0.0, f64::max);
    let pipelined_ms = intra_ms.max(inter_ms).max(port_ms);
    let sequential_ms = intra_ms + inter_ms;
    // Per-GPU finish: own port drain ∨ own group's intra phase ∨ own
    // group's uplink drain. Each term is ≤ the corresponding component of
    // `pipelined_ms`, so `max(per_gpu_ms) ≤ makespan` holds by
    // construction (on any cluster, heterogeneous included).
    let per_gpu_ms: Vec<f64> = (0..n)
        .map(|i| {
            let g = owner[i];
            let up: u64 = (0..n_groups).map(|h| group_matrix.get(g, h)).sum();
            let down: u64 = (0..n_groups).map(|h| group_matrix.get(h, g)).sum();
            (d.row_sum(i).max(d.col_sum(i)) as f64 / bw[i])
                .max(intra_time[g])
                .max(up.max(down) as f64 / uplinks[g])
        })
        .collect();

    Ok(HierarchicalSchedule {
        n,
        intra,
        inter,
        intra_ms,
        inter_ms,
        pipelined_ms,
        sequential_ms,
        per_gpu_ms,
    })
}

/// Price an arbitrary flat slot schedule on a two-tier topology: each round
/// lasts as long as its slowest transfer *or* its most congested uplink.
/// This is what a topology-oblivious Aurora order actually costs — its
/// partial permutations coordinate ports, not uplinks, so a round may push
/// several concurrent transfers through one oversubscribed uplink.
/// On the big switch this reduces to the flat per-round port model.
pub fn flat_schedule_on_topology(sched: &SlotSchedule, cluster: &Cluster, topo: &Topology) -> f64 {
    let n = sched.n;
    assert_eq!(cluster.len(), n, "cluster and schedule sizes must match");
    let bw = cluster.bandwidths();
    let owner = topo.group_of(n);
    let uplinks = topo.uplink_rates(cluster);
    let n_groups = uplinks.len();
    let mut total = 0.0f64;
    for round in &sched.rounds {
        let mut round_ms = 0.0f64;
        let mut up = vec![0u64; n_groups];
        let mut down = vec![0u64; n_groups];
        for &(src, dst, real) in &round.transfers {
            if real == 0 {
                continue;
            }
            round_ms = round_ms.max(real as f64 / bw[src].min(bw[dst]));
            if let Some(owner) = &owner {
                if owner[src] != owner[dst] {
                    up[owner[src]] += real;
                    down[owner[dst]] += real;
                }
            }
        }
        for g in 0..n_groups {
            if up[g] > 0 || down[g] > 0 {
                round_ms = round_ms.max(up[g].max(down[g]) as f64 / uplinks[g]);
            }
        }
        total += round_ms;
    }
    total
}

/// Communication time of one all-to-all under `topo` and `policy` — the
/// topology-aware counterpart of [`super::comm_time`]:
///
/// * big switch → [`super::comm_time`] unchanged, bit for bit;
/// * two-tier + Aurora → the hierarchical two-phase schedule's pipelined
///   makespan estimate ([`hierarchical_schedule`]);
/// * two-tier + ordered baselines → the fluid combination
///   `max(flat simulated makespan, uplink bound)`
///   ([`comm_time_topology`]) — a baseline's order is fixed, so the
///   saturated uplink simply serializes it.
///
/// Panics when a two-tier grouping does not match the cluster size; the
/// planner surface ([`crate::planner::Planner::plan_topology`]) validates
/// that combination up front and returns a typed error instead.
pub fn comm_time_on(
    d: &TrafficMatrix,
    cluster: &Cluster,
    topo: &Topology,
    policy: SchedulePolicy,
) -> CommResult {
    match (topo, policy) {
        (Topology::BigSwitch, _) => comm_time(d, &cluster.bandwidths(), policy),
        (Topology::TwoTier { .. }, SchedulePolicy::Aurora) => {
            // Estimate-only build: identical durations, no materialized
            // per-group slot schedules (this runs once per collective in
            // the simulator's hot loop).
            let h = hierarchical_core(d, cluster, topo, false)
                .expect("two-tier topology was validated by the caller");
            CommResult {
                makespan: h.pipelined_ms,
                per_gpu_finish: h.per_gpu_ms,
            }
        }
        (Topology::TwoTier { .. }, _) => comm_time_topology(d, cluster, topo, policy),
    }
}

/// Makespan (ms) of the **flat** Aurora order priced on `topo` — the
/// "schedule ignores the topology" baseline the hierarchical schedule is
/// measured against: same optimal big-switch rounds, each stretched by its
/// uplink congestion.
pub fn flat_aurora_on_topology(d: &TrafficMatrix, cluster: &Cluster, topo: &Topology) -> f64 {
    let sched = aurora_schedule(d);
    // A slot round's budget may exceed its real tokens (Appendix A filler);
    // price real transfers only, which favors the flat baseline.
    flat_schedule_on_topology(&sched, cluster, topo).max(uplink_bound(d, cluster, topo))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate_slot_schedule;
    use crate::util::Rng;

    fn rand_matrix(n: usize, seed: u64, max: u64) -> TrafficMatrix {
        let mut rng = Rng::new(seed);
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    d.set(i, j, rng.gen_range(max));
                }
            }
        }
        d
    }

    #[test]
    fn conserves_every_pair_and_splits_phases_cleanly() {
        let d = rand_matrix(8, 11, 40);
        let c = Cluster::homogeneous(8, 1.0);
        let topo = Topology::even_two_tier(8, 2, 4.0).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        let delivered = h.delivered();
        let owner = topo.group_of(8).unwrap();
        for i in 0..8 {
            for j in 0..8 {
                if i != j {
                    assert_eq!(delivered.get(i, j), d.get(i, j), "({i},{j})");
                }
            }
        }
        // intra schedules carry only in-group flows; inter only cross flows
        for s in &h.intra {
            for r in &s.rounds {
                for &(src, dst, _) in &r.transfers {
                    assert_eq!(owner[src], owner[dst]);
                }
            }
        }
        for r in &h.inter {
            for &(src, dst, _) in &r.transfers {
                assert_ne!(owner[src], owner[dst]);
            }
        }
    }

    #[test]
    fn intra_schedules_are_valid_aurora_schedules() {
        let d = rand_matrix(8, 5, 30);
        let c = Cluster::homogeneous(8, 1.0);
        let topo = Topology::even_two_tier(8, 2, 2.0).unwrap();
        let owner = topo.group_of(8).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        for (g, s) in h.intra.iter().enumerate() {
            // the group's intra submatrix (global indices)
            let mut local = TrafficMatrix::zeros(8);
            for i in 0..8 {
                for j in 0..8 {
                    if i != j && owner[i] == g && owner[j] == g {
                        local.set(i, j, d.get(i, j));
                    }
                }
            }
            validate_slot_schedule(&local, s).unwrap();
        }
    }

    #[test]
    fn inter_rounds_are_group_level_partial_permutations() {
        let d = rand_matrix(12, 7, 25);
        let c = Cluster::homogeneous(12, 1.0);
        let topo = Topology::even_two_tier(12, 3, 4.0).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        for round in &h.inter {
            let mut send = vec![false; 3];
            let mut recv = vec![false; 3];
            let mut pair_tokens = vec![vec![0u64; 3]; 3];
            for &(ga, gb, t) in &round.pairs {
                assert!(!send[ga], "group {ga} sends twice in one round");
                assert!(!recv[gb], "group {gb} receives twice in one round");
                send[ga] = true;
                recv[gb] = true;
                assert!(t <= round.budget);
                pair_tokens[ga][gb] = t;
            }
            // realized gateway flows match the pair budgets exactly
            let owner = topo.group_of(12).unwrap();
            let mut realized = vec![vec![0u64; 3]; 3];
            for &(src, dst, t) in &round.transfers {
                realized[owner[src]][owner[dst]] += t;
            }
            assert_eq!(realized, pair_tokens);
        }
    }

    #[test]
    fn inter_budget_is_the_group_level_b_max() {
        let d = rand_matrix(8, 21, 50);
        let topo = Topology::even_two_tier(8, 4, 4.0).unwrap();
        let owner = topo.group_of(8).unwrap();
        let mut g = TrafficMatrix::zeros(4);
        for i in 0..8 {
            for j in 0..8 {
                if i != j && owner[i] != owner[j] {
                    g.add(owner[i], owner[j], d.get(i, j));
                }
            }
        }
        let c = Cluster::homogeneous(8, 1.0);
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        assert_eq!(h.inter_budget_tokens(), g.b_max_tokens());
    }

    #[test]
    fn purely_local_traffic_needs_no_inter_phase() {
        let mut d = TrafficMatrix::zeros(8);
        d.set(0, 1, 100);
        d.set(5, 6, 80);
        let c = Cluster::homogeneous(8, 1.0);
        let topo = Topology::even_two_tier(8, 2, 4.0).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        assert!(h.inter.is_empty());
        assert_eq!(h.inter_ms, 0.0);
        // pipelined estimate = the heavier group's local drain
        assert_eq!(h.pipelined_ms, 100.0);
    }

    #[test]
    fn hierarchical_beats_flat_aurora_under_oversubscription() {
        let d = rand_matrix(16, 3, 60);
        let c = Cluster::homogeneous(16, 1.0);
        let topo = Topology::even_two_tier(16, 4, 4.0).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        let flat = flat_aurora_on_topology(&d, &c, &topo);
        assert!(
            h.pipelined_ms < flat,
            "hierarchical {} vs flat {}",
            h.pipelined_ms,
            flat
        );
        // and it never reports better than the fluid lower bounds
        let lb = uplink_bound(&d, &c, &topo)
            .max(comm_time(&d, &c.bandwidths(), SchedulePolicy::Aurora).makespan);
        assert!(h.pipelined_ms >= lb - 1e-9);
        assert!(h.sequential_ms >= h.pipelined_ms);
    }

    #[test]
    fn no_oversubscription_keeps_flat_aurora_unstretched() {
        // at 1:1, a round's uplink load can never exceed its port budget for
        // even groups, so the flat schedule's price matches the big switch
        let d = rand_matrix(8, 9, 30);
        let c = Cluster::homogeneous(8, 1.0);
        let topo = Topology::even_two_tier(8, 2, 1.0).unwrap();
        let sched = aurora_schedule(&d);
        let topo_ms = flat_schedule_on_topology(&sched, &c, &topo);
        let flat_ms = flat_schedule_on_topology(&sched, &c, &Topology::BigSwitch);
        assert!((topo_ms - flat_ms).abs() < 1e-9, "{topo_ms} vs {flat_ms}");
    }

    #[test]
    fn comm_time_on_dispatches_per_topology_and_policy() {
        let d = rand_matrix(8, 13, 30);
        let c = Cluster::homogeneous(8, 1.0);
        // big switch: bit-for-bit the flat result for every policy
        for policy in [
            SchedulePolicy::Aurora,
            SchedulePolicy::Sjf,
            SchedulePolicy::Rcs { seed: 4 },
        ] {
            let a = comm_time(&d, &c.bandwidths(), policy);
            let b = comm_time_on(&d, &c, &Topology::BigSwitch, policy);
            assert_eq!(a.makespan, b.makespan);
            assert_eq!(a.per_gpu_finish, b.per_gpu_finish);
        }
        // two-tier Aurora: the hierarchical estimate
        let topo = Topology::even_two_tier(8, 2, 4.0).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        let r = comm_time_on(&d, &c, &topo, SchedulePolicy::Aurora);
        assert_eq!(r.makespan, h.pipelined_ms);
        // two-tier baseline: flat sim joined with the uplink bound
        let s = comm_time_on(&d, &c, &topo, SchedulePolicy::Sjf);
        assert!(s.makespan >= uplink_bound(&d, &c, &topo));
    }

    #[test]
    fn big_switch_topology_is_rejected() {
        let d = TrafficMatrix::zeros(4);
        let c = Cluster::homogeneous(4, 1.0);
        assert!(hierarchical_schedule(&d, &c, &Topology::BigSwitch).is_err());
    }

    #[test]
    fn heterogeneous_ports_slow_their_group() {
        let mut gpus = Cluster::homogeneous(8, 2.0).gpus().to_vec();
        for g in gpus.iter_mut().take(4) {
            g.bandwidth = 1.0; // group 0 has slow ports
        }
        let c = Cluster::new(gpus);
        let d = rand_matrix(8, 17, 30);
        let topo = Topology::even_two_tier(8, 2, 2.0).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        // group 0's local drain is priced at its own (slower) ports
        let owner = topo.group_of(8).unwrap();
        let mut local = TrafficMatrix::zeros(4);
        for i in 0..4 {
            for j in 0..4 {
                if i != j && owner[i] == 0 && owner[j] == 0 {
                    local.set(i, j, d.get(i, j));
                }
            }
        }
        assert!(h.intra_ms >= local.b_max_hetero(&[1.0, 1.0, 1.0, 1.0]) - 1e-9);
    }

    #[test]
    fn per_gpu_finish_never_exceeds_the_makespan() {
        // Mixed-bandwidth group where only the fast members talk: the slow
        // member's port must not be charged for traffic it never carries.
        let mut gpus = Cluster::homogeneous(6, 10.0).gpus().to_vec();
        gpus[0].bandwidth = 1.0; // slow GPU inside group 0
        let c = Cluster::new(gpus);
        let mut d = TrafficMatrix::zeros(6);
        d.set(1, 2, 100); // fast members of group 0 exchange tokens
        d.set(4, 5, 100); // group 1 keeps busy too
        d.set(1, 4, 10); // a little cross traffic
        let topo = Topology::even_two_tier(6, 2, 2.0).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        for (i, &t) in h.per_gpu_ms.iter().enumerate() {
            assert!(
                t <= h.pipelined_ms + 1e-9,
                "GPU {i}: finish {t} exceeds makespan {}",
                h.pipelined_ms
            );
        }
        // and the same through the CommResult surface
        let r = comm_time_on(&d, &c, &topo, SchedulePolicy::Aurora);
        for &t in &r.per_gpu_finish {
            assert!(t <= r.makespan + 1e-9);
        }
        // random hetero shapes too
        for seed in 0..10u64 {
            let d = rand_matrix(6, seed, 30);
            let h = hierarchical_schedule(&d, &c, &topo).unwrap();
            for &t in &h.per_gpu_ms {
                assert!(t <= h.pipelined_ms + 1e-9, "seed {seed}");
            }
        }
    }
}
