//! Two-phase hierarchical all-to-all scheduling for two-tier topologies.
//!
//! On a [`Topology::TwoTier`] fabric the flat Aurora order is no longer
//! contention-free: its rounds pair arbitrary GPUs, so a single round can
//! push several concurrent transfers through one oversubscribed uplink and
//! the round stretches by the uplink's congestion factor. The hierarchical
//! schedule ([`hierarchical_schedule`]) decomposes the traffic instead:
//!
//! 1. **Intra phase** — the traffic between members of one group never
//!    touches an uplink. Each group's submatrix gets its own Aurora slot
//!    schedule ([`super::aurora_schedule`]) running at full port rate:
//!    contention-free, makespan exactly the group's `b_max`.
//! 2. **Inter phase** — the residual cross-group traffic collapses to a
//!    group-level matrix `G[a][b] = Σ tokens a→b`. A **group-level BvN
//!    decomposition** (the same Alg. 1 machinery one level up) yields
//!    rounds in which every group sends to at most one group and receives
//!    from at most one — so each uplink carries exactly one group-flow per
//!    round and drains at its full rate. Within a round the group-flow is
//!    realized by **designated gateway senders**: the member flows of the
//!    (src group, dst group) pair, budget-balanced across senders so no
//!    single port serializes the whole round.
//! 3. **Stitch** — gateways use GPU ports the intra phase also wants, but
//!    the two phases occupy *different switches* otherwise. The pipelined
//!    makespan estimate interleaves them in the fluid limit:
//!    `max(intra, inter, per-GPU port drain)`; the sequential estimate
//!    (`intra + inter`) is the no-overlap upper bound. Both are reported.
//!
//! The inter phase's round budgets sum to exactly `b_max(G)` (Theorem 4.2
//! applied to the group graph), so with homogeneous uplinks the uplink
//! phase meets the uplink drain bound of
//! [`crate::cluster::topology::uplink_bound`] — the hierarchical schedule
//! achieves `max(port bound, uplink bound)` in the fluid limit, while flat
//! Aurora pays the per-round congestion [`flat_schedule_on_topology`]
//! makes visible.
//!
//! # Recursive tiers ([`Topology::Tiered`])
//!
//! Deeper fabrics (GPU / rack / pod) decompose recursively. Every flow has a
//! *span*: the smallest aggregation level whose groups contain both
//! endpoints. Span-0 flows are the intra phase; span-`p` flows form phase
//! `p`, a BvN decomposition **over the level-`p-1` units** — block-diagonal
//! per enclosing level-`p` domain, so independent pods schedule their
//! cross-rack traffic concurrently. Each phase's round budgets sum to the
//! `b_max` of its own span matrix (Theorem 4.2 per tier), its rounds charge
//! the level-`p-1` uplinks of the active pair, the gateway GPU ports, *and*
//! every intermediate uplink level the flows descend through. The pipelined
//! estimate is the fluid max of the intra drain, every phase, the port
//! drain, and the all-level [`uplink_bound`]; the sequential estimate sums
//! the phases.

use super::bvn::{aurora_schedule, aurora_schedule_traced};
use super::slot::{SlotRound, SlotSchedule};
use super::{comm_time, CommResult, SchedulePolicy};
use crate::cluster::topology::{comm_time_topology, uplink_bound, Topology, TopologyError};
use crate::cluster::Cluster;
use crate::obs::Tracer;
use crate::traffic::TrafficMatrix;
use crate::util::Json;

/// One inter-group round: a partial permutation of *group* pairs, realized
/// by concrete gateway transfers.
#[derive(Debug, Clone, PartialEq)]
pub struct InterRound {
    /// Group-level round budget in tokens (per-uplink budget of the round).
    pub budget: u64,
    /// Active `(src_group, dst_group, tokens)` pairs — each group appears at
    /// most once as sender and once as receiver.
    pub pairs: Vec<(usize, usize, u64)>,
    /// Realized gateway flows `(src_gpu, dst_gpu, tokens)`. Unlike a
    /// [`SlotRound`], one GPU may carry several flows (the group's uplink is
    /// faster than one port precisely when oversubscription < group size).
    pub transfers: Vec<(usize, usize, u64)>,
}

/// The stitched two-phase schedule for one all-to-all on a two-tier fabric.
#[derive(Debug, Clone, PartialEq)]
pub struct HierarchicalSchedule {
    /// Number of GPUs.
    pub n: usize,
    /// Per-group intra-group Aurora schedules (global GPU ids).
    pub intra: Vec<SlotSchedule>,
    /// Group-level inter rounds with gateway realizations. For tiered
    /// fabrics this is the concatenation of every aggregation tier's rounds
    /// (innermost tier first), so conservation checks see all cross traffic.
    pub inter: Vec<InterRound>,
    /// Per-aggregation-tier inter rounds for [`Topology::Tiered`] fabrics:
    /// `tiers[p-1]` holds phase `p`'s rounds, whose `pairs` index the
    /// level-`p-1` units. Empty for two-tier topologies (use `inter`).
    pub tiers: Vec<Vec<InterRound>>,
    /// Intra-phase duration (ms): the slowest group's local `b_max` drain.
    pub intra_ms: f64,
    /// Inter-phase duration (ms): summed group-round times on the uplinks
    /// (gateway port occupancy included).
    pub inter_ms: f64,
    /// Fluid pipelined makespan estimate (ms):
    /// `max(intra, inter, per-GPU port drain)` — phases interleave on ports.
    pub pipelined_ms: f64,
    /// No-overlap upper bound (ms): `intra_ms + inter_ms`.
    pub sequential_ms: f64,
    /// Per-GPU finish estimate (ms): each GPU's own port drain joined with
    /// its group's intra and uplink phases.
    pub per_gpu_ms: Vec<f64>,
}

impl HierarchicalSchedule {
    /// Total real tokens moved per `(src, dst)` pair across both phases —
    /// for conservation checks against the original matrix.
    pub fn delivered(&self) -> TrafficMatrix {
        let mut m = TrafficMatrix::zeros(self.n);
        for s in &self.intra {
            for round in &s.rounds {
                for &(src, dst, real) in &round.transfers {
                    m.add(src, dst, real);
                }
            }
        }
        for round in &self.inter {
            for &(src, dst, tokens) in &round.transfers {
                m.add(src, dst, tokens);
            }
        }
        m
    }

    /// Sum of group-level round budgets (tokens). Equals `b_max` of the
    /// group-level matrix — the Theorem 4.2 bound one level up.
    pub fn inter_budget_tokens(&self) -> u64 {
        self.inter.iter().map(|r| r.budget).sum()
    }
}

/// Build the hierarchical schedule for `d` on `cluster` under a two-tier or
/// tiered `topo` (two phases, or one phase per aggregation tier). Errors on
/// a big-switch topology (use [`super::aurora_schedule`] there) or an
/// invalid grouping.
pub fn hierarchical_schedule(
    d: &TrafficMatrix,
    cluster: &Cluster,
    topo: &Topology,
) -> Result<HierarchicalSchedule, TopologyError> {
    hierarchical_core(d, cluster, topo, true, &Tracer::disabled())
}

/// [`hierarchical_schedule`] with span tracing and per-phase decision
/// records through `tr` (observational only — the schedule is bit-for-bit
/// that of `hierarchical_schedule`).
pub fn hierarchical_schedule_traced(
    d: &TrafficMatrix,
    cluster: &Cluster,
    topo: &Topology,
    tr: &Tracer,
) -> Result<HierarchicalSchedule, TopologyError> {
    hierarchical_core(d, cluster, topo, true, tr)
}

/// The shared construction. With `build_intra` the per-group Aurora slot
/// schedules are materialized (the executable schedule); without it `intra`
/// stays empty and only the timing estimate is computed — every duration
/// field is **identical** either way, because the intra phase is priced by
/// each group's `b_max` (which the group schedule achieves by Theorem 4.2),
/// never by walking its rounds. The estimate-only path is what the
/// simulator's hot loop takes ([`comm_time_on`] is called once per
/// collective), skipping one BvN decomposition per group per call.
fn hierarchical_core(
    d: &TrafficMatrix,
    cluster: &Cluster,
    topo: &Topology,
    build_intra: bool,
    tr: &Tracer,
) -> Result<HierarchicalSchedule, TopologyError> {
    if matches!(topo, Topology::Tiered { .. }) {
        return tiered_core(d, cluster, topo, build_intra, tr);
    }
    let n = d.n();
    assert_eq!(cluster.len(), n, "cluster and matrix sizes must match");
    // BigSwitch: no hierarchy to schedule.
    let owner = topo.owners(n)?.ok_or(TopologyError::NoGroups)?;
    let Topology::TwoTier { groups, .. } = topo else {
        unreachable!("owners returned Some for a non-two-tier topology")
    };
    let uplinks = topo.uplink_rates(cluster);
    let bw = cluster.bandwidths();
    let n_groups = groups.len();

    // ---- Phase 1: per-group Aurora on the intra submatrices. ----
    let sp_intra = tr.begin("schedule.intra");
    tr.counter(sp_intra, "groups", n_groups as i64);
    let mut intra = Vec::new();
    let mut intra_time = Vec::with_capacity(n_groups);
    let mut intra_ms = 0.0f64;
    for members in groups.iter() {
        let k = members.len();
        let mut local = TrafficMatrix::zeros(k);
        for (li, &i) in members.iter().enumerate() {
            for (lj, &j) in members.iter().enumerate() {
                if li != lj {
                    local.set(li, lj, d.get(i, j));
                }
            }
        }
        let member_bw: Vec<f64> = members.iter().map(|&i| bw[i]).collect();
        let group_ms = local.b_max_hetero(&member_bw);
        intra_time.push(group_ms);
        intra_ms = intra_ms.max(group_ms);
        if !build_intra {
            continue;
        }
        // Remap the local schedule to global GPU ids.
        let local_sched = aurora_schedule(&local);
        let rounds = local_sched
            .rounds
            .into_iter()
            .map(|r| SlotRound {
                duration: r.duration,
                transfers: r
                    .transfers
                    .into_iter()
                    .map(|(li, lj, t)| (members[li], members[lj], t))
                    .collect(),
            })
            .collect();
        intra.push(SlotSchedule { n, rounds });
    }
    tr.end(sp_intra);
    tr.decision(
        "schedule.phase",
        vec![
            ("phase", Json::from("intra")),
            ("groups", Json::from(n_groups)),
            ("ms", Json::from(intra_ms)),
        ],
    );

    // ---- Phase 2: group-level BvN over the cross traffic. ----
    let sp_inter = tr.begin("schedule.inter");
    let mut group_matrix = TrafficMatrix::zeros(n_groups);
    // Remaining cross flows per (src group, dst group), deterministic order.
    let mut cross: Vec<Vec<Vec<(usize, usize, u64)>>> = vec![vec![Vec::new(); n_groups]; n_groups];
    for i in 0..n {
        for j in 0..n {
            let t = d.get(i, j);
            if t == 0 || i == j || owner[i] == owner[j] {
                continue;
            }
            group_matrix.add(owner[i], owner[j], t);
            cross[owner[i]][owner[j]].push((i, j, t));
        }
    }

    let group_sched = aurora_schedule_traced(&group_matrix, tr);
    let mut inter = Vec::with_capacity(group_sched.rounds.len());
    let mut inter_ms = 0.0f64;
    for ground in &group_sched.rounds {
        let mut pairs = Vec::new();
        let mut transfers = Vec::new();
        let mut round_ms = 0.0f64;
        let mut tx = vec![0u64; n];
        let mut rx = vec![0u64; n];
        for &(ga, gb, tokens) in &ground.transfers {
            pairs.push((ga, gb, tokens));
            // Designated gateways: balance the round's budget across the
            // pair's member flows so no single sender port serializes it.
            let flows = &mut cross[ga][gb];
            let mut left = tokens;
            while left > 0 {
                let live = flows.iter().filter(|&&(_, _, rem)| rem > 0).count() as u64;
                debug_assert!(live > 0, "group matrix tracks remaining cross tokens");
                let fair = left.div_ceil(live);
                for (src, dst, rem) in flows.iter_mut() {
                    if *rem == 0 || left == 0 {
                        continue;
                    }
                    let take = fair.min(*rem).min(left);
                    if take == 0 {
                        continue;
                    }
                    *rem -= take;
                    left -= take;
                    tx[*src] += take;
                    rx[*dst] += take;
                    transfers.push((*src, *dst, take));
                }
            }
            // Pair drain: the slower of the two uplinks caps the flow.
            round_ms = round_ms.max(tokens as f64 / uplinks[ga].min(uplinks[gb]));
        }
        // Gateway port occupancy can exceed the uplink term when one sender
        // carries most of the pair budget; charge it honestly.
        for i in 0..n {
            if tx[i] > 0 || rx[i] > 0 {
                round_ms = round_ms.max(tx[i].max(rx[i]) as f64 / bw[i]);
            }
        }
        inter_ms += round_ms;
        inter.push(InterRound {
            budget: ground.duration,
            pairs,
            transfers,
        });
    }
    tr.counter(sp_inter, "rounds", inter.len() as i64);
    tr.end(sp_inter);
    tr.decision(
        "schedule.phase",
        vec![
            ("phase", Json::from("inter")),
            ("rounds", Json::from(inter.len())),
            ("ms", Json::from(inter_ms)),
        ],
    );

    // ---- Stitch. ----
    let port_ms = (0..n)
        .map(|i| d.row_sum(i).max(d.col_sum(i)) as f64 / bw[i])
        .fold(0.0, f64::max);
    let pipelined_ms = intra_ms.max(inter_ms).max(port_ms);
    let sequential_ms = intra_ms + inter_ms;
    // Per-GPU finish: own port drain ∨ own group's intra phase ∨ own
    // group's uplink drain. Each term is ≤ the corresponding component of
    // `pipelined_ms`, so `max(per_gpu_ms) ≤ makespan` holds by
    // construction (on any cluster, heterogeneous included).
    let per_gpu_ms: Vec<f64> = (0..n)
        .map(|i| {
            let g = owner[i];
            let up: u64 = (0..n_groups).map(|h| group_matrix.get(g, h)).sum();
            let down: u64 = (0..n_groups).map(|h| group_matrix.get(h, g)).sum();
            (d.row_sum(i).max(d.col_sum(i)) as f64 / bw[i])
                .max(intra_time[g])
                .max(up.max(down) as f64 / uplinks[g])
        })
        .collect();

    Ok(HierarchicalSchedule {
        n,
        intra,
        inter,
        tiers: Vec::new(),
        intra_ms,
        inter_ms,
        pipelined_ms,
        sequential_ms,
        per_gpu_ms,
    })
}

/// Recursive decomposition for [`Topology::Tiered`]: per-leaf-group Aurora
/// for the span-0 traffic, then one BvN phase per aggregation tier over the
/// span-`p` flows (see the module docs). Walks `d`'s nonzero structure only,
/// so a sparse thousand-GPU matrix pays for its traffic, not `n²`.
fn tiered_core(
    d: &TrafficMatrix,
    cluster: &Cluster,
    topo: &Topology,
    build_intra: bool,
    tr: &Tracer,
) -> Result<HierarchicalSchedule, TopologyError> {
    let Topology::Tiered { levels } = topo else {
        unreachable!("tiered_core is only dispatched for tiered topologies")
    };
    let n = d.n();
    assert_eq!(cluster.len(), n, "cluster and matrix sizes must match");
    let l = levels.len();
    let owners: Vec<Vec<usize>> = (0..l)
        .map(|t| topo.owners_at(n, t))
        .collect::<Result<_, _>>()?;
    let rates: Vec<Vec<f64>> = (0..l).map(|t| topo.uplink_rates_at(cluster, t)).collect();
    let bw = cluster.bandwidths();

    // ---- Intra: per-leaf-group Aurora, exactly as in the two-tier path. ----
    let leaf_groups = &levels[0].groups;
    let sp_intra = tr.begin("schedule.intra");
    tr.counter(sp_intra, "groups", leaf_groups.len() as i64);
    let mut intra = Vec::new();
    let mut intra_time = Vec::with_capacity(leaf_groups.len());
    let mut intra_ms = 0.0f64;
    for members in leaf_groups.iter() {
        let k = members.len();
        let local_of: std::collections::HashMap<usize, usize> =
            members.iter().enumerate().map(|(li, &i)| (i, li)).collect();
        let mut local = TrafficMatrix::zeros(k);
        for (li, &i) in members.iter().enumerate() {
            for (j, t) in d.row_iter(i) {
                if j == i {
                    continue;
                }
                if let Some(&lj) = local_of.get(&j) {
                    local.set(li, lj, t);
                }
            }
        }
        let member_bw: Vec<f64> = members.iter().map(|&i| bw[i]).collect();
        let group_ms = local.b_max_hetero(&member_bw);
        intra_time.push(group_ms);
        intra_ms = intra_ms.max(group_ms);
        if !build_intra {
            continue;
        }
        let local_sched = aurora_schedule(&local);
        let rounds = local_sched
            .rounds
            .into_iter()
            .map(|r| SlotRound {
                duration: r.duration,
                transfers: r
                    .transfers
                    .into_iter()
                    .map(|(li, lj, t)| (members[li], members[lj], t))
                    .collect(),
            })
            .collect();
        intra.push(SlotSchedule { n, rounds });
    }
    tr.end(sp_intra);
    tr.decision(
        "schedule.phase",
        vec![
            ("phase", Json::from("intra")),
            ("groups", Json::from(leaf_groups.len())),
            ("ms", Json::from(intra_ms)),
        ],
    );

    // ---- One BvN phase per aggregation tier over its span's flows. ----
    let mut tiers: Vec<Vec<InterRound>> = Vec::with_capacity(l);
    let mut inter: Vec<InterRound> = Vec::new();
    let mut tier_ms: Vec<f64> = Vec::with_capacity(l);
    for p in 1..=l {
        let sp_tier = tr.begin("schedule.tier");
        tr.counter(sp_tier, "tier", p as i64);
        let q = p - 1; // the tier's units live at this level
        let o_q = &owners[q];
        let n_units = levels[q].groups.len();
        let mut group_matrix = TrafficMatrix::zeros(n_units);
        let mut cross: Vec<Vec<Vec<(usize, usize, u64)>>> =
            vec![vec![Vec::new(); n_units]; n_units];
        for i in 0..n {
            for (j, t) in d.row_iter(i) {
                if i == j || o_q[i] == o_q[j] {
                    continue;
                }
                // span p: crosses level-q groups but not level-p groups
                if p < l && owners[p][i] != owners[p][j] {
                    continue;
                }
                group_matrix.add(o_q[i], o_q[j], t);
                cross[o_q[i]][o_q[j]].push((i, j, t));
            }
        }
        let group_sched = aurora_schedule_traced(&group_matrix, tr);
        let mut rounds = Vec::with_capacity(group_sched.rounds.len());
        let mut phase_ms = 0.0f64;
        for ground in &group_sched.rounds {
            let mut pairs = Vec::new();
            let mut transfers = Vec::new();
            let mut round_ms = 0.0f64;
            let mut tx = vec![0u64; n];
            let mut rx = vec![0u64; n];
            for &(ua, ub, tokens) in &ground.transfers {
                pairs.push((ua, ub, tokens));
                // Designated gateways, budget-balanced across the pair's
                // member flows (same fair share as the two-tier path).
                let flows = &mut cross[ua][ub];
                let mut left = tokens;
                while left > 0 {
                    let live = flows.iter().filter(|&&(_, _, rem)| rem > 0).count() as u64;
                    debug_assert!(live > 0, "group matrix tracks remaining cross tokens");
                    let fair = left.div_ceil(live);
                    for (src, dst, rem) in flows.iter_mut() {
                        if *rem == 0 || left == 0 {
                            continue;
                        }
                        let take = fair.min(*rem).min(left);
                        if take == 0 {
                            continue;
                        }
                        *rem -= take;
                        left -= take;
                        tx[*src] += take;
                        rx[*dst] += take;
                        transfers.push((*src, *dst, take));
                    }
                }
                round_ms = round_ms.max(tokens as f64 / rates[q][ua].min(rates[q][ub]));
            }
            // Gateway port occupancy, as in the two-tier path.
            for i in 0..n {
                if tx[i] > 0 || rx[i] > 0 {
                    round_ms = round_ms.max(tx[i].max(rx[i]) as f64 / bw[i]);
                }
            }
            // Intermediate uplinks the flows descend through (levels below
            // the tier's own): charge each group's up/down occupancy.
            for lvl in 0..q {
                let o = &owners[lvl];
                let mut up = vec![0u64; rates[lvl].len()];
                let mut down = vec![0u64; rates[lvl].len()];
                for &(src, dst, t) in &transfers {
                    up[o[src]] += t;
                    down[o[dst]] += t;
                }
                for g in 0..up.len() {
                    if up[g] > 0 || down[g] > 0 {
                        round_ms = round_ms.max(up[g].max(down[g]) as f64 / rates[lvl][g]);
                    }
                }
            }
            phase_ms += round_ms;
            rounds.push(InterRound {
                budget: ground.duration,
                pairs,
                transfers,
            });
        }
        tier_ms.push(phase_ms);
        tr.counter(sp_tier, "units", n_units as i64);
        tr.counter(sp_tier, "rounds", rounds.len() as i64);
        tr.end(sp_tier);
        tr.decision(
            "schedule.tier",
            vec![
                ("tier", Json::from(p)),
                ("units", Json::from(n_units)),
                ("rounds", Json::from(rounds.len())),
                ("ms", Json::from(phase_ms)),
            ],
        );
        inter.extend(rounds.iter().cloned());
        tiers.push(rounds);
    }
    let inter_ms: f64 = tier_ms.iter().sum();

    // ---- Stitch: fluid max over every resource's drain time. ----
    // Per-level up/down drain totals double as the uplink bound and the
    // per-GPU finish terms.
    let mut level_up: Vec<Vec<u64>> = rates.iter().map(|r| vec![0u64; r.len()]).collect();
    let mut level_down: Vec<Vec<u64>> = rates.iter().map(|r| vec![0u64; r.len()]).collect();
    for i in 0..n {
        for (j, t) in d.row_iter(i) {
            if i == j {
                continue;
            }
            for lvl in 0..l {
                if owners[lvl][i] != owners[lvl][j] {
                    level_up[lvl][owners[lvl][i]] += t;
                    level_down[lvl][owners[lvl][j]] += t;
                }
            }
        }
    }
    let mut ub = 0.0f64;
    for lvl in 0..l {
        for g in 0..rates[lvl].len() {
            ub = ub
                .max(level_up[lvl][g] as f64 / rates[lvl][g])
                .max(level_down[lvl][g] as f64 / rates[lvl][g]);
        }
    }
    let port_ms = (0..n)
        .map(|i| d.row_sum(i).max(d.col_sum(i)) as f64 / bw[i])
        .fold(0.0, f64::max);
    let busiest_tier = tier_ms.iter().fold(0.0, |a: f64, &b| a.max(b));
    let pipelined_ms = intra_ms.max(busiest_tier).max(port_ms).max(ub);
    let sequential_ms = intra_ms + inter_ms;
    let per_gpu_ms: Vec<f64> = (0..n)
        .map(|i| {
            let mut t = (d.row_sum(i).max(d.col_sum(i)) as f64 / bw[i]).max(intra_time[owners[0][i]]);
            for lvl in 0..l {
                let g = owners[lvl][i];
                t = t.max(level_up[lvl][g].max(level_down[lvl][g]) as f64 / rates[lvl][g]);
            }
            t
        })
        .collect();

    Ok(HierarchicalSchedule {
        n,
        intra,
        inter,
        tiers,
        intra_ms,
        inter_ms,
        pipelined_ms,
        sequential_ms,
        per_gpu_ms,
    })
}

/// Price an arbitrary flat slot schedule on a two-tier topology: each round
/// lasts as long as its slowest transfer *or* its most congested uplink.
/// This is what a topology-oblivious Aurora order actually costs — its
/// partial permutations coordinate ports, not uplinks, so a round may push
/// several concurrent transfers through one oversubscribed uplink.
/// On the big switch this reduces to the flat per-round port model.
pub fn flat_schedule_on_topology(sched: &SlotSchedule, cluster: &Cluster, topo: &Topology) -> f64 {
    let n = sched.n;
    assert_eq!(cluster.len(), n, "cluster and schedule sizes must match");
    let bw = cluster.bandwidths();
    // One owner map + rate vector per aggregation level: none for the big
    // switch, the single leaf level for two-tier (identical arithmetic to
    // the one-level special case), every level for tiered fabrics.
    let n_levels = topo.n_levels();
    let owners: Vec<Vec<usize>> = (0..n_levels)
        .map(|t| topo.owners_at(n, t).expect("invalid topology"))
        .collect();
    let rates: Vec<Vec<f64>> = (0..n_levels)
        .map(|t| topo.uplink_rates_at(cluster, t))
        .collect();
    let mut total = 0.0f64;
    for round in &sched.rounds {
        let mut round_ms = 0.0f64;
        let mut up: Vec<Vec<u64>> = rates.iter().map(|r| vec![0u64; r.len()]).collect();
        let mut down: Vec<Vec<u64>> = rates.iter().map(|r| vec![0u64; r.len()]).collect();
        for &(src, dst, real) in &round.transfers {
            if real == 0 {
                continue;
            }
            round_ms = round_ms.max(real as f64 / bw[src].min(bw[dst]));
            for t in 0..n_levels {
                if owners[t][src] != owners[t][dst] {
                    up[t][owners[t][src]] += real;
                    down[t][owners[t][dst]] += real;
                }
            }
        }
        for t in 0..n_levels {
            for g in 0..rates[t].len() {
                if up[t][g] > 0 || down[t][g] > 0 {
                    round_ms = round_ms.max(up[t][g].max(down[t][g]) as f64 / rates[t][g]);
                }
            }
        }
        total += round_ms;
    }
    total
}

/// Communication time of one all-to-all under `topo` and `policy` — the
/// topology-aware counterpart of [`super::comm_time`]:
///
/// * big switch → [`super::comm_time`] unchanged, bit for bit;
/// * two-tier + Aurora → the hierarchical two-phase schedule's pipelined
///   makespan estimate ([`hierarchical_schedule`]);
/// * two-tier + ordered baselines → the fluid combination
///   `max(flat simulated makespan, uplink bound)`
///   ([`comm_time_topology`]) — a baseline's order is fixed, so the
///   saturated uplink simply serializes it;
/// * tiered fabrics → the same split, with Aurora priced through the
///   recursive per-tier decomposition and baselines through the all-level
///   uplink bound.
///
/// Panics when a two-tier grouping does not match the cluster size; the
/// planner surface ([`crate::planner::Planner::plan_topology`]) validates
/// that combination up front and returns a typed error instead.
pub fn comm_time_on(
    d: &TrafficMatrix,
    cluster: &Cluster,
    topo: &Topology,
    policy: SchedulePolicy,
) -> CommResult {
    match (topo, policy) {
        (Topology::BigSwitch, _) => comm_time(d, &cluster.bandwidths(), policy),
        (Topology::TwoTier { .. }, SchedulePolicy::Aurora) => {
            // Estimate-only build: identical durations, no materialized
            // per-group slot schedules (this runs once per collective in
            // the simulator's hot loop).
            let h = hierarchical_core(d, cluster, topo, false, &Tracer::disabled())
                .expect("two-tier topology was validated by the caller");
            CommResult {
                makespan: h.pipelined_ms,
                per_gpu_finish: h.per_gpu_ms,
            }
        }
        (Topology::TwoTier { .. }, _) => comm_time_topology(d, cluster, topo, policy),
        (Topology::Tiered { .. }, SchedulePolicy::Aurora) => {
            // Same estimate-only build, through the recursive per-tier
            // decomposition.
            let h = hierarchical_core(d, cluster, topo, false, &Tracer::disabled())
                .expect("tiered topology was validated by the caller");
            CommResult {
                makespan: h.pipelined_ms,
                per_gpu_finish: h.per_gpu_ms,
            }
        }
        (Topology::Tiered { .. }, _) => comm_time_topology(d, cluster, topo, policy),
    }
}

/// Makespan (ms) of the **flat** Aurora order priced on `topo` — the
/// "schedule ignores the topology" baseline the hierarchical schedule is
/// measured against: same optimal big-switch rounds, each stretched by its
/// uplink congestion.
pub fn flat_aurora_on_topology(d: &TrafficMatrix, cluster: &Cluster, topo: &Topology) -> f64 {
    let sched = aurora_schedule(d);
    // A slot round's budget may exceed its real tokens (Appendix A filler);
    // price real transfers only, which favors the flat baseline.
    flat_schedule_on_topology(&sched, cluster, topo).max(uplink_bound(d, cluster, topo))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate_slot_schedule;
    use crate::util::Rng;

    fn rand_matrix(n: usize, seed: u64, max: u64) -> TrafficMatrix {
        let mut rng = Rng::new(seed);
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    d.set(i, j, rng.gen_range(max));
                }
            }
        }
        d
    }

    #[test]
    fn conserves_every_pair_and_splits_phases_cleanly() {
        let d = rand_matrix(8, 11, 40);
        let c = Cluster::homogeneous(8, 1.0);
        let topo = Topology::even_two_tier(8, 2, 4.0).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        let delivered = h.delivered();
        let owner = topo.group_of(8).unwrap();
        for i in 0..8 {
            for j in 0..8 {
                if i != j {
                    assert_eq!(delivered.get(i, j), d.get(i, j), "({i},{j})");
                }
            }
        }
        // intra schedules carry only in-group flows; inter only cross flows
        for s in &h.intra {
            for r in &s.rounds {
                for &(src, dst, _) in &r.transfers {
                    assert_eq!(owner[src], owner[dst]);
                }
            }
        }
        for r in &h.inter {
            for &(src, dst, _) in &r.transfers {
                assert_ne!(owner[src], owner[dst]);
            }
        }
    }

    #[test]
    fn intra_schedules_are_valid_aurora_schedules() {
        let d = rand_matrix(8, 5, 30);
        let c = Cluster::homogeneous(8, 1.0);
        let topo = Topology::even_two_tier(8, 2, 2.0).unwrap();
        let owner = topo.group_of(8).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        for (g, s) in h.intra.iter().enumerate() {
            // the group's intra submatrix (global indices)
            let mut local = TrafficMatrix::zeros(8);
            for i in 0..8 {
                for j in 0..8 {
                    if i != j && owner[i] == g && owner[j] == g {
                        local.set(i, j, d.get(i, j));
                    }
                }
            }
            validate_slot_schedule(&local, s).unwrap();
        }
    }

    #[test]
    fn inter_rounds_are_group_level_partial_permutations() {
        let d = rand_matrix(12, 7, 25);
        let c = Cluster::homogeneous(12, 1.0);
        let topo = Topology::even_two_tier(12, 3, 4.0).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        for round in &h.inter {
            let mut send = vec![false; 3];
            let mut recv = vec![false; 3];
            let mut pair_tokens = vec![vec![0u64; 3]; 3];
            for &(ga, gb, t) in &round.pairs {
                assert!(!send[ga], "group {ga} sends twice in one round");
                assert!(!recv[gb], "group {gb} receives twice in one round");
                send[ga] = true;
                recv[gb] = true;
                assert!(t <= round.budget);
                pair_tokens[ga][gb] = t;
            }
            // realized gateway flows match the pair budgets exactly
            let owner = topo.group_of(12).unwrap();
            let mut realized = vec![vec![0u64; 3]; 3];
            for &(src, dst, t) in &round.transfers {
                realized[owner[src]][owner[dst]] += t;
            }
            assert_eq!(realized, pair_tokens);
        }
    }

    #[test]
    fn inter_budget_is_the_group_level_b_max() {
        let d = rand_matrix(8, 21, 50);
        let topo = Topology::even_two_tier(8, 4, 4.0).unwrap();
        let owner = topo.group_of(8).unwrap();
        let mut g = TrafficMatrix::zeros(4);
        for i in 0..8 {
            for j in 0..8 {
                if i != j && owner[i] != owner[j] {
                    g.add(owner[i], owner[j], d.get(i, j));
                }
            }
        }
        let c = Cluster::homogeneous(8, 1.0);
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        assert_eq!(h.inter_budget_tokens(), g.b_max_tokens());
    }

    #[test]
    fn purely_local_traffic_needs_no_inter_phase() {
        let mut d = TrafficMatrix::zeros(8);
        d.set(0, 1, 100);
        d.set(5, 6, 80);
        let c = Cluster::homogeneous(8, 1.0);
        let topo = Topology::even_two_tier(8, 2, 4.0).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        assert!(h.inter.is_empty());
        assert_eq!(h.inter_ms, 0.0);
        // pipelined estimate = the heavier group's local drain
        assert_eq!(h.pipelined_ms, 100.0);
    }

    #[test]
    fn hierarchical_beats_flat_aurora_under_oversubscription() {
        let d = rand_matrix(16, 3, 60);
        let c = Cluster::homogeneous(16, 1.0);
        let topo = Topology::even_two_tier(16, 4, 4.0).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        let flat = flat_aurora_on_topology(&d, &c, &topo);
        assert!(
            h.pipelined_ms < flat,
            "hierarchical {} vs flat {}",
            h.pipelined_ms,
            flat
        );
        // and it never reports better than the fluid lower bounds
        let lb = uplink_bound(&d, &c, &topo)
            .max(comm_time(&d, &c.bandwidths(), SchedulePolicy::Aurora).makespan);
        assert!(h.pipelined_ms >= lb - 1e-9);
        assert!(h.sequential_ms >= h.pipelined_ms);
    }

    #[test]
    fn no_oversubscription_keeps_flat_aurora_unstretched() {
        // at 1:1, a round's uplink load can never exceed its port budget for
        // even groups, so the flat schedule's price matches the big switch
        let d = rand_matrix(8, 9, 30);
        let c = Cluster::homogeneous(8, 1.0);
        let topo = Topology::even_two_tier(8, 2, 1.0).unwrap();
        let sched = aurora_schedule(&d);
        let topo_ms = flat_schedule_on_topology(&sched, &c, &topo);
        let flat_ms = flat_schedule_on_topology(&sched, &c, &Topology::BigSwitch);
        assert!((topo_ms - flat_ms).abs() < 1e-9, "{topo_ms} vs {flat_ms}");
    }

    #[test]
    fn comm_time_on_dispatches_per_topology_and_policy() {
        let d = rand_matrix(8, 13, 30);
        let c = Cluster::homogeneous(8, 1.0);
        // big switch: bit-for-bit the flat result for every policy
        for policy in [
            SchedulePolicy::Aurora,
            SchedulePolicy::Sjf,
            SchedulePolicy::Rcs { seed: 4 },
        ] {
            let a = comm_time(&d, &c.bandwidths(), policy);
            let b = comm_time_on(&d, &c, &Topology::BigSwitch, policy);
            assert_eq!(a.makespan, b.makespan);
            assert_eq!(a.per_gpu_finish, b.per_gpu_finish);
        }
        // two-tier Aurora: the hierarchical estimate
        let topo = Topology::even_two_tier(8, 2, 4.0).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        let r = comm_time_on(&d, &c, &topo, SchedulePolicy::Aurora);
        assert_eq!(r.makespan, h.pipelined_ms);
        // two-tier baseline: flat sim joined with the uplink bound
        let s = comm_time_on(&d, &c, &topo, SchedulePolicy::Sjf);
        assert!(s.makespan >= uplink_bound(&d, &c, &topo));
    }

    #[test]
    fn single_level_tiered_prices_like_two_tier() {
        // one aggregation level: the recursive path must agree with the
        // two-tier path on every duration field, bit for bit
        let d = rand_matrix(8, 31, 40);
        let c = Cluster::homogeneous(8, 1.0);
        let two = Topology::even_two_tier(8, 2, 4.0).unwrap();
        let one = Topology::even_tiered(8, &[2], &[4.0]).unwrap();
        let ht = hierarchical_schedule(&d, &c, &two).unwrap();
        let h1 = hierarchical_schedule(&d, &c, &one).unwrap();
        assert_eq!(h1.intra_ms, ht.intra_ms);
        assert_eq!(h1.inter_ms, ht.inter_ms);
        assert_eq!(h1.pipelined_ms, ht.pipelined_ms);
        assert_eq!(h1.sequential_ms, ht.sequential_ms);
        assert_eq!(h1.per_gpu_ms, ht.per_gpu_ms);
        assert_eq!(h1.inter, ht.inter);
        assert_eq!(h1.tiers.len(), 1);
        assert_eq!(h1.tiers[0], ht.inter);
    }

    #[test]
    fn tiered_conserves_every_pair() {
        // 16 GPUs: 4 racks of 4, 2 pods of 2 racks
        let d = rand_matrix(16, 41, 30);
        let c = Cluster::homogeneous(16, 1.0);
        let topo = Topology::even_tiered(16, &[4, 2], &[2.0, 4.0]).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        let delivered = h.delivered();
        for i in 0..16 {
            for j in 0..16 {
                if i != j {
                    assert_eq!(delivered.get(i, j), d.get(i, j), "({i},{j})");
                }
            }
        }
        // and sparse input produces the identical schedule
        let hs = hierarchical_schedule(&d.to_sparse(), &c, &topo).unwrap();
        assert_eq!(hs.inter, h.inter);
        assert_eq!(hs.pipelined_ms, h.pipelined_ms);
    }

    #[test]
    fn tiered_phases_separate_flow_spans() {
        let d = rand_matrix(16, 43, 25);
        let c = Cluster::homogeneous(16, 1.0);
        let topo = Topology::even_tiered(16, &[4, 2], &[2.0, 4.0]).unwrap();
        let rack = topo.owners_at(16, 0).unwrap();
        let pod = topo.owners_at(16, 1).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        assert_eq!(h.tiers.len(), 2);
        // phase 1: cross-rack, intra-pod flows only
        for round in &h.tiers[0] {
            for &(src, dst, _) in &round.transfers {
                assert_ne!(rack[src], rack[dst]);
                assert_eq!(pod[src], pod[dst]);
            }
        }
        // phase 2: cross-pod flows only
        for round in &h.tiers[1] {
            for &(src, dst, _) in &round.transfers {
                assert_ne!(pod[src], pod[dst]);
            }
        }
        // intra: same-rack flows only
        for s in &h.intra {
            for r in &s.rounds {
                for &(src, dst, _) in &r.transfers {
                    assert_eq!(rack[src], rack[dst]);
                }
            }
        }
    }

    #[test]
    fn tiered_round_budgets_meet_theorem_4_2_per_tier() {
        // each phase's budgets sum to the b_max of its own span matrix
        let d = rand_matrix(16, 47, 35);
        let topo = Topology::even_tiered(16, &[4, 2], &[2.0, 4.0]).unwrap();
        let rack = topo.owners_at(16, 0).unwrap();
        let pod = topo.owners_at(16, 1).unwrap();
        let c = Cluster::homogeneous(16, 1.0);
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();

        let mut g_rack = TrafficMatrix::zeros(4);
        let mut g_pod = TrafficMatrix::zeros(2);
        for i in 0..16 {
            for j in 0..16 {
                if i == j || rack[i] == rack[j] {
                    continue;
                }
                if pod[i] == pod[j] {
                    g_rack.add(rack[i], rack[j], d.get(i, j));
                } else {
                    g_pod.add(pod[i], pod[j], d.get(i, j));
                }
            }
        }
        let budget = |rounds: &[InterRound]| rounds.iter().map(|r| r.budget).sum::<u64>();
        assert_eq!(budget(&h.tiers[0]), g_rack.b_max_tokens());
        assert_eq!(budget(&h.tiers[1]), g_pod.b_max_tokens());
        assert_eq!(h.inter_budget_tokens(), g_rack.b_max_tokens() + g_pod.b_max_tokens());

        // rounds are partial permutations of their tier's units
        for (rounds, n_units) in [(&h.tiers[0], 4), (&h.tiers[1], 2)] {
            for round in rounds {
                let mut send = vec![false; n_units];
                let mut recv = vec![false; n_units];
                for &(ua, ub, t) in &round.pairs {
                    assert!(!send[ua] && !recv[ub], "unit used twice in a round");
                    send[ua] = true;
                    recv[ub] = true;
                    assert!(t <= round.budget);
                }
            }
        }
    }

    #[test]
    fn tiered_estimates_respect_fluid_bounds() {
        let d = rand_matrix(16, 53, 45);
        let c = Cluster::homogeneous(16, 1.0);
        let topo = Topology::even_tiered(16, &[4, 2], &[2.0, 4.0]).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        let lb = uplink_bound(&d, &c, &topo)
            .max(comm_time(&d, &c.bandwidths(), SchedulePolicy::Aurora).makespan);
        assert!(h.pipelined_ms >= lb - 1e-9, "{} < {lb}", h.pipelined_ms);
        assert!(h.sequential_ms >= h.pipelined_ms - 1e-9);
        for &t in &h.per_gpu_ms {
            assert!(t <= h.pipelined_ms + 1e-9);
        }
        // the comm_time_on surface agrees with the estimate-only build
        let r = comm_time_on(&d, &c, &topo, SchedulePolicy::Aurora);
        assert_eq!(r.makespan, h.pipelined_ms);
        assert_eq!(r.per_gpu_finish, h.per_gpu_ms);
        // baselines never beat their own serialization bound
        let s = comm_time_on(&d, &c, &topo, SchedulePolicy::Sjf);
        assert!(s.makespan >= uplink_bound(&d, &c, &topo) - 1e-9);
    }

    #[test]
    fn big_switch_topology_is_rejected() {
        let d = TrafficMatrix::zeros(4);
        let c = Cluster::homogeneous(4, 1.0);
        assert!(hierarchical_schedule(&d, &c, &Topology::BigSwitch).is_err());
    }

    #[test]
    fn heterogeneous_ports_slow_their_group() {
        let mut gpus = Cluster::homogeneous(8, 2.0).gpus().to_vec();
        for g in gpus.iter_mut().take(4) {
            g.bandwidth = 1.0; // group 0 has slow ports
        }
        let c = Cluster::new(gpus);
        let d = rand_matrix(8, 17, 30);
        let topo = Topology::even_two_tier(8, 2, 2.0).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        // group 0's local drain is priced at its own (slower) ports
        let owner = topo.group_of(8).unwrap();
        let mut local = TrafficMatrix::zeros(4);
        for i in 0..4 {
            for j in 0..4 {
                if i != j && owner[i] == 0 && owner[j] == 0 {
                    local.set(i, j, d.get(i, j));
                }
            }
        }
        assert!(h.intra_ms >= local.b_max_hetero(&[1.0, 1.0, 1.0, 1.0]) - 1e-9);
    }

    #[test]
    fn per_gpu_finish_never_exceeds_the_makespan() {
        // Mixed-bandwidth group where only the fast members talk: the slow
        // member's port must not be charged for traffic it never carries.
        let mut gpus = Cluster::homogeneous(6, 10.0).gpus().to_vec();
        gpus[0].bandwidth = 1.0; // slow GPU inside group 0
        let c = Cluster::new(gpus);
        let mut d = TrafficMatrix::zeros(6);
        d.set(1, 2, 100); // fast members of group 0 exchange tokens
        d.set(4, 5, 100); // group 1 keeps busy too
        d.set(1, 4, 10); // a little cross traffic
        let topo = Topology::even_two_tier(6, 2, 2.0).unwrap();
        let h = hierarchical_schedule(&d, &c, &topo).unwrap();
        for (i, &t) in h.per_gpu_ms.iter().enumerate() {
            assert!(
                t <= h.pipelined_ms + 1e-9,
                "GPU {i}: finish {t} exceeds makespan {}",
                h.pipelined_ms
            );
        }
        // and the same through the CommResult surface
        let r = comm_time_on(&d, &c, &topo, SchedulePolicy::Aurora);
        for &t in &r.per_gpu_finish {
            assert!(t <= r.makespan + 1e-9);
        }
        // random hetero shapes too
        for seed in 0..10u64 {
            let d = rand_matrix(6, seed, 30);
            let h = hierarchical_schedule(&d, &c, &topo).unwrap();
            for &t in &h.per_gpu_ms {
                assert!(t <= h.pipelined_ms + 1e-9, "seed {seed}");
            }
        }
    }
}
