//! Slot-schedule validator — the machine-checkable statement of Theorem 4.2.
//!
//! A valid Aurora schedule must (a) never let a GPU send or receive two
//! transfers in the same round (contention freedom), (b) deliver exactly the
//! off-diagonal traffic of the input matrix (conservation), and (c) finish in
//! exactly `b_max` tokens (optimality). Tests and property checks route every
//! generated schedule through this validator.

use super::slot::SlotSchedule;
use crate::traffic::TrafficMatrix;
use std::fmt;

/// Why a slot schedule is invalid.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ValidationError {
    /// A GPU sends twice in one round.
    SenderConflict { round: usize, gpu: usize },
    /// A GPU receives twice in one round.
    ReceiverConflict { round: usize, gpu: usize },
    /// A transfer carries more tokens than the round's duration.
    OverlongTransfer {
        round: usize,
        src: usize,
        dst: usize,
        tokens: u64,
        duration: u64,
    },
    /// A transfer has src == dst (local tokens must not be scheduled).
    DiagonalTransfer { round: usize, gpu: usize },
    /// Delivered traffic differs from the input matrix.
    ConservationViolated {
        src: usize,
        dst: usize,
        expected: u64,
        delivered: u64,
    },
    /// Makespan differs from the Theorem 4.2 optimum.
    NotOptimal { makespan: u64, b_max: u64 },
}

impl fmt::Display for ValidationError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::SenderConflict { round, gpu } => {
                write!(f, "round {round}: GPU {gpu} sends twice")
            }
            Self::ReceiverConflict { round, gpu } => {
                write!(f, "round {round}: GPU {gpu} receives twice")
            }
            Self::OverlongTransfer {
                round,
                src,
                dst,
                tokens,
                duration,
            } => write!(
                f,
                "round {round}: transfer {src}->{dst} has {tokens} tokens > duration {duration}"
            ),
            Self::DiagonalTransfer { round, gpu } => {
                write!(f, "round {round}: diagonal transfer on GPU {gpu}")
            }
            Self::ConservationViolated {
                src,
                dst,
                expected,
                delivered,
            } => write!(
                f,
                "flow {src}->{dst}: delivered {delivered} tokens, expected {expected}"
            ),
            Self::NotOptimal { makespan, b_max } => {
                write!(f, "makespan {makespan} != b_max {b_max}")
            }
        }
    }
}

impl std::error::Error for ValidationError {}

/// Check contention freedom, conservation, and Theorem 4.2 optimality of an
/// Aurora schedule for traffic matrix `d`.
pub fn validate_slot_schedule(
    d: &TrafficMatrix,
    schedule: &SlotSchedule,
) -> Result<(), ValidationError> {
    let n = d.n();
    assert_eq!(schedule.n, n, "schedule dimension mismatch");

    for (k, round) in schedule.rounds.iter().enumerate() {
        let mut sends = vec![false; n];
        let mut recvs = vec![false; n];
        for &(src, dst, tokens) in &round.transfers {
            if src == dst {
                return Err(ValidationError::DiagonalTransfer { round: k, gpu: src });
            }
            if sends[src] {
                return Err(ValidationError::SenderConflict { round: k, gpu: src });
            }
            if recvs[dst] {
                return Err(ValidationError::ReceiverConflict { round: k, gpu: dst });
            }
            sends[src] = true;
            recvs[dst] = true;
            if tokens > round.duration {
                return Err(ValidationError::OverlongTransfer {
                    round: k,
                    src,
                    dst,
                    tokens,
                    duration: round.duration,
                });
            }
        }
    }

    let delivered = schedule.delivered();
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            if delivered.get(i, j) != d.get(i, j) {
                return Err(ValidationError::ConservationViolated {
                    src: i,
                    dst: j,
                    expected: d.get(i, j),
                    delivered: delivered.get(i, j),
                });
            }
        }
    }

    let makespan = schedule.makespan_tokens();
    let b_max = d.b_max_tokens();
    if makespan != b_max {
        return Err(ValidationError::NotOptimal { makespan, b_max });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::slot::SlotRound;

    fn d2() -> TrafficMatrix {
        let mut d = TrafficMatrix::zeros(2);
        d.set(0, 1, 1);
        d
    }

    #[test]
    fn accepts_minimal_valid_schedule() {
        let s = SlotSchedule {
            n: 2,
            rounds: vec![SlotRound {
                duration: 1,
                transfers: vec![(0, 1, 1)],
            }],
        };
        validate_slot_schedule(&d2(), &s).unwrap();
    }

    #[test]
    fn rejects_sender_conflict() {
        let mut d = TrafficMatrix::zeros(3);
        d.set(0, 1, 1);
        d.set(0, 2, 1);
        let s = SlotSchedule {
            n: 3,
            rounds: vec![SlotRound {
                duration: 2,
                transfers: vec![(0, 1, 1), (0, 2, 1)],
            }],
        };
        assert!(matches!(
            validate_slot_schedule(&d, &s),
            Err(ValidationError::SenderConflict { .. })
        ));
    }

    #[test]
    fn rejects_receiver_conflict() {
        let mut d = TrafficMatrix::zeros(3);
        d.set(0, 2, 1);
        d.set(1, 2, 1);
        let s = SlotSchedule {
            n: 3,
            rounds: vec![SlotRound {
                duration: 2,
                transfers: vec![(0, 2, 1), (1, 2, 1)],
            }],
        };
        assert!(matches!(
            validate_slot_schedule(&d, &s),
            Err(ValidationError::ReceiverConflict { .. })
        ));
    }

    #[test]
    fn rejects_undelivered_traffic() {
        let s = SlotSchedule { n: 2, rounds: vec![] };
        assert!(matches!(
            validate_slot_schedule(&d2(), &s),
            Err(ValidationError::ConservationViolated { .. })
        ));
    }

    #[test]
    fn rejects_suboptimal_makespan() {
        let s = SlotSchedule {
            n: 2,
            rounds: vec![
                SlotRound {
                    duration: 1,
                    transfers: vec![(0, 1, 1)],
                },
                SlotRound {
                    duration: 5,
                    transfers: vec![],
                },
            ],
        };
        assert!(matches!(
            validate_slot_schedule(&d2(), &s),
            Err(ValidationError::NotOptimal { .. })
        ));
    }

    #[test]
    fn rejects_diagonal_transfer() {
        let s = SlotSchedule {
            n: 2,
            rounds: vec![SlotRound {
                duration: 1,
                transfers: vec![(0, 0, 1), (0, 1, 1)],
            }],
        };
        assert!(matches!(
            validate_slot_schedule(&d2(), &s),
            Err(ValidationError::DiagonalTransfer { .. })
        ));
    }

    #[test]
    fn error_messages_render() {
        let e = ValidationError::NotOptimal {
            makespan: 5,
            b_max: 3,
        };
        assert!(e.to_string().contains("b_max"));
    }
}
