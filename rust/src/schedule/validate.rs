//! Slot-schedule validator — the machine-checkable statement of Theorem 4.2.
//!
//! A valid Aurora schedule must (a) never let a GPU send or receive two
//! transfers in the same round (contention freedom), (b) deliver exactly the
//! off-diagonal traffic of the input matrix (conservation), and (c) finish in
//! exactly `b_max` tokens (optimality). Tests and property checks route every
//! generated schedule through this validator.

use super::slot::SlotSchedule;
use crate::traffic::TrafficMatrix;
use std::fmt;

/// Why a slot schedule is invalid.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ValidationError {
    /// A GPU sends twice in one round.
    SenderConflict { round: usize, gpu: usize },
    /// A GPU receives twice in one round.
    ReceiverConflict { round: usize, gpu: usize },
    /// A transfer carries more tokens than the round's duration.
    OverlongTransfer {
        round: usize,
        src: usize,
        dst: usize,
        tokens: u64,
        duration: u64,
    },
    /// A transfer has src == dst (local tokens must not be scheduled).
    DiagonalTransfer { round: usize, gpu: usize },
    /// Delivered traffic differs from the input matrix.
    ConservationViolated {
        src: usize,
        dst: usize,
        expected: u64,
        delivered: u64,
    },
    /// Makespan differs from the Theorem 4.2 optimum.
    NotOptimal { makespan: u64, b_max: u64 },
}

impl fmt::Display for ValidationError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::SenderConflict { round, gpu } => {
                write!(f, "round {round}: GPU {gpu} sends twice")
            }
            Self::ReceiverConflict { round, gpu } => {
                write!(f, "round {round}: GPU {gpu} receives twice")
            }
            Self::OverlongTransfer {
                round,
                src,
                dst,
                tokens,
                duration,
            } => write!(
                f,
                "round {round}: transfer {src}->{dst} has {tokens} tokens > duration {duration}"
            ),
            Self::DiagonalTransfer { round, gpu } => {
                write!(f, "round {round}: diagonal transfer on GPU {gpu}")
            }
            Self::ConservationViolated {
                src,
                dst,
                expected,
                delivered,
            } => write!(
                f,
                "flow {src}->{dst}: delivered {delivered} tokens, expected {expected}"
            ),
            Self::NotOptimal { makespan, b_max } => {
                write!(f, "makespan {makespan} != b_max {b_max}")
            }
        }
    }
}

impl std::error::Error for ValidationError {}

/// Check contention freedom, conservation, and Theorem 4.2 optimality of an
/// Aurora schedule for traffic matrix `d`.
pub fn validate_slot_schedule(
    d: &TrafficMatrix,
    schedule: &SlotSchedule,
) -> Result<(), ValidationError> {
    let n = d.n();
    assert_eq!(schedule.n, n, "schedule dimension mismatch");

    for (k, round) in schedule.rounds.iter().enumerate() {
        let mut sends = vec![false; n];
        let mut recvs = vec![false; n];
        for &(src, dst, tokens) in &round.transfers {
            if src == dst {
                return Err(ValidationError::DiagonalTransfer { round: k, gpu: src });
            }
            if sends[src] {
                return Err(ValidationError::SenderConflict { round: k, gpu: src });
            }
            if recvs[dst] {
                return Err(ValidationError::ReceiverConflict { round: k, gpu: dst });
            }
            sends[src] = true;
            recvs[dst] = true;
            if tokens > round.duration {
                return Err(ValidationError::OverlongTransfer {
                    round: k,
                    src,
                    dst,
                    tokens,
                    duration: round.duration,
                });
            }
        }
    }

    let delivered = schedule.delivered();
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            if delivered.get(i, j) != d.get(i, j) {
                return Err(ValidationError::ConservationViolated {
                    src: i,
                    dst: j,
                    expected: d.get(i, j),
                    delivered: delivered.get(i, j),
                });
            }
        }
    }

    let makespan = schedule.makespan_tokens();
    let b_max = d.b_max_tokens();
    if makespan != b_max {
        return Err(ValidationError::NotOptimal { makespan, b_max });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::slot::SlotRound;

    fn d2() -> TrafficMatrix {
        let mut d = TrafficMatrix::zeros(2);
        d.set(0, 1, 1);
        d
    }

    #[test]
    fn accepts_minimal_valid_schedule() {
        let s = SlotSchedule {
            n: 2,
            rounds: vec![SlotRound {
                duration: 1,
                transfers: vec![(0, 1, 1)],
            }],
        };
        validate_slot_schedule(&d2(), &s).unwrap();
    }

    #[test]
    fn rejects_sender_conflict() {
        let mut d = TrafficMatrix::zeros(3);
        d.set(0, 1, 1);
        d.set(0, 2, 1);
        let s = SlotSchedule {
            n: 3,
            rounds: vec![SlotRound {
                duration: 2,
                transfers: vec![(0, 1, 1), (0, 2, 1)],
            }],
        };
        assert!(matches!(
            validate_slot_schedule(&d, &s),
            Err(ValidationError::SenderConflict { .. })
        ));
    }

    #[test]
    fn rejects_receiver_conflict() {
        let mut d = TrafficMatrix::zeros(3);
        d.set(0, 2, 1);
        d.set(1, 2, 1);
        let s = SlotSchedule {
            n: 3,
            rounds: vec![SlotRound {
                duration: 2,
                transfers: vec![(0, 2, 1), (1, 2, 1)],
            }],
        };
        assert!(matches!(
            validate_slot_schedule(&d, &s),
            Err(ValidationError::ReceiverConflict { .. })
        ));
    }

    #[test]
    fn rejects_undelivered_traffic() {
        let s = SlotSchedule { n: 2, rounds: vec![] };
        assert!(matches!(
            validate_slot_schedule(&d2(), &s),
            Err(ValidationError::ConservationViolated { .. })
        ));
    }

    #[test]
    fn rejects_suboptimal_makespan() {
        let s = SlotSchedule {
            n: 2,
            rounds: vec![
                SlotRound {
                    duration: 1,
                    transfers: vec![(0, 1, 1)],
                },
                SlotRound {
                    duration: 5,
                    transfers: vec![],
                },
            ],
        };
        assert!(matches!(
            validate_slot_schedule(&d2(), &s),
            Err(ValidationError::NotOptimal { .. })
        ));
    }

    #[test]
    fn rejects_diagonal_transfer() {
        let s = SlotSchedule {
            n: 2,
            rounds: vec![SlotRound {
                duration: 1,
                transfers: vec![(0, 0, 1), (0, 1, 1)],
            }],
        };
        assert!(matches!(
            validate_slot_schedule(&d2(), &s),
            Err(ValidationError::DiagonalTransfer { .. })
        ));
    }

    #[test]
    fn error_messages_render() {
        let e = ValidationError::NotOptimal {
            makespan: 5,
            b_max: 3,
        };
        assert!(e.to_string().contains("b_max"));
    }

    fn rand_matrix(seed: u64, n: usize, hi: u64) -> TrafficMatrix {
        use crate::util::Rng;
        let mut rng = Rng::new(seed);
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    d.set(i, j, rng.gen_range(hi));
                }
            }
        }
        d
    }

    /// The slot schedule is bandwidth-free (token-level), so one schedule
    /// serves heterogeneous clusters too (Theorem 5.2): it stays valid and
    /// token-optimal, and the time-domain bound `b_max_hetero` it implies
    /// is never beaten by any head-of-line order actually *simulated* on
    /// the same heterogeneous ports.
    #[test]
    fn schedule_valid_on_heterogeneous_bandwidths() {
        use crate::schedule::{aurora_schedule, comm_time, SchedulePolicy};
        for seed in 0..10u64 {
            let d = rand_matrix(seed + 400, 8, 40);
            let s = aurora_schedule(&d);
            validate_slot_schedule(&d, &s).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            // token-domain optimality is bandwidth-independent
            assert_eq!(s.makespan_tokens(), d.b_max_tokens(), "seed {seed}");
            // paper's four-type cluster: 1.0 / 0.8 / 0.5 / 0.4 token rates.
            // Every simulated head-of-line baseline respects per-port rates,
            // so Theorem 5.2's bound must lower-bound them.
            let bw = [1.0, 1.0, 0.8, 0.8, 0.5, 0.5, 0.4, 0.4];
            let aurora = comm_time(&d, &bw, SchedulePolicy::Aurora).makespan;
            for policy in [
                SchedulePolicy::Sjf,
                SchedulePolicy::Ljf,
                SchedulePolicy::Rcs { seed },
            ] {
                let sim = comm_time(&d, &bw, policy).makespan;
                assert!(
                    aurora <= sim + 1e-9,
                    "seed {seed}: aurora {aurora} vs {} {sim}",
                    policy.name()
                );
            }
        }
    }

    /// Aggregated multi-expert-per-GPU matrices — several expert-level
    /// matrices projected onto fewer GPUs and summed — stay schedulable and
    /// optimal: projection may create diagonal (local) tokens, which a valid
    /// schedule must *not* transmit.
    #[test]
    fn schedule_valid_on_aggregated_projected_traffic() {
        use crate::schedule::aurora_schedule;
        for seed in 0..10u64 {
            // two 8-expert models, experts e -> GPU e / 2 (4 GPUs), plus a
            // 12-expert model packed 3-per-GPU
            let da = rand_matrix(seed + 500, 8, 30);
            let db = rand_matrix(seed + 600, 8, 30);
            let dc = rand_matrix(seed + 700, 12, 20);
            let owner8: Vec<usize> = (0..8).map(|e| e / 2).collect();
            let owner12: Vec<usize> = (0..12).map(|e| e / 3).collect();
            let agg = da
                .project(&owner8, 4)
                .sum(&db.project(&owner8, 4))
                .sum(&dc.project(&owner12, 4));
            // aggregation keeps local tokens on the diagonal
            assert!((0..4).any(|g| agg.get(g, g) > 0), "seed {seed}");
            let s = aurora_schedule(&agg);
            validate_slot_schedule(&agg, &s).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            assert_eq!(s.makespan_tokens(), agg.b_max_tokens(), "seed {seed}");
        }
    }

    /// Replica-split matrices — expert-level traffic fanned out across
    /// replica GPUs by fractional weights, integerized per flow — must stay
    /// schedulable, conservation-exact, and Theorem 4.2-optimal: the split
    /// projection only redistributes integer tokens, so the validator's
    /// contract is unchanged.
    #[test]
    fn schedule_valid_on_replica_split_matrices() {
        use crate::schedule::aurora_schedule;
        use crate::traffic::zipf_traffic;
        for seed in 0..8u64 {
            // 8 experts packed two-per-GPU; the two hottest experts each get
            // replicas on two extra GPUs with a lopsided 60/25/15 split.
            let d = zipf_traffic(8, 300 + seed * 17, 1.2, seed);
            let owner: Vec<usize> = (0..8).map(|e| e / 2).collect();
            let mut replicas: Vec<Vec<usize>> = owner.iter().map(|&g| vec![g]).collect();
            let mut weights: Vec<Vec<f64>> = owner.iter().map(|_| vec![1.0]).collect();
            let mut by_load: Vec<usize> = (0..8).collect();
            let loads = d.expert_loads();
            by_load.sort_by_key(|&e| std::cmp::Reverse(loads[e]));
            for &hot in by_load.iter().take(2) {
                let g = owner[hot];
                replicas[hot] = vec![g, (g + 1) % 4, (g + 2) % 4];
                weights[hot] = vec![0.6, 0.25, 0.15];
            }
            let split = d.project_split(&owner, &replicas, &weights, 4);
            // conservation of total token load through the split
            assert_eq!(
                split.expert_loads().iter().sum::<u64>(),
                d.expert_loads().iter().sum::<u64>(),
                "seed {seed}"
            );
            let s = aurora_schedule(&split);
            validate_slot_schedule(&split, &s).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            assert_eq!(s.makespan_tokens(), split.b_max_tokens(), "seed {seed}");
        }
    }

    /// Contention injection: corrupt a genuinely optimal schedule by
    /// redirecting one transfer onto another transfer's receiver; the
    /// validator must flag the exact conflicting GPU.
    #[test]
    fn injected_receiver_contention_is_caught() {
        use crate::schedule::aurora_schedule;
        // two disjoint flows share one round: 0 -> 1 and 2 -> 3
        let mut d = TrafficMatrix::zeros(4);
        d.set(0, 1, 5);
        d.set(2, 3, 5);
        let mut s = aurora_schedule(&d);
        validate_slot_schedule(&d, &s).unwrap();
        let round = s
            .rounds
            .iter_mut()
            .find(|r| r.transfers.len() >= 2)
            .expect("disjoint flows share a round");
        let victim_dst = round.transfers[0].1;
        round.transfers[1].1 = victim_dst;
        match validate_slot_schedule(&d, &s) {
            Err(ValidationError::ReceiverConflict { gpu, .. }) => assert_eq!(gpu, victim_dst),
            other => panic!("expected receiver conflict, got {other:?}"),
        }
    }

    /// Contention injection, sender side: duplicating a source in one round
    /// trips the sender check even when conservation would also fail.
    #[test]
    fn injected_sender_contention_is_caught() {
        use crate::schedule::aurora_schedule;
        let mut d = TrafficMatrix::zeros(4);
        d.set(0, 1, 3);
        d.set(2, 3, 3);
        let mut s = aurora_schedule(&d);
        let round = s
            .rounds
            .iter_mut()
            .find(|r| r.transfers.len() >= 2)
            .expect("disjoint flows share a round");
        let victim_src = round.transfers[0].0;
        round.transfers[1].0 = victim_src;
        match validate_slot_schedule(&d, &s) {
            Err(ValidationError::SenderConflict { gpu, .. }) => assert_eq!(gpu, victim_src),
            other => panic!("expected sender conflict, got {other:?}"),
        }
    }

    /// Padding a round beyond `b_max` breaks Theorem 4.2 optimality even
    /// though contention freedom and conservation still hold.
    #[test]
    fn inflated_duration_fails_optimality() {
        use crate::schedule::aurora_schedule;
        let d = rand_matrix(0xD0, 5, 25);
        let mut s = aurora_schedule(&d);
        validate_slot_schedule(&d, &s).unwrap();
        if let Some(r) = s.rounds.last_mut() {
            r.duration += 7;
        }
        assert!(matches!(
            validate_slot_schedule(&d, &s),
            Err(ValidationError::NotOptimal { .. })
        ));
    }
}
