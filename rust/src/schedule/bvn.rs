//! Aurora's optimal transmission order (Alg. 1 / Theorem 4.2) via
//! Birkhoff–von-Neumann decomposition.
//!
//! Algorithm 1 in the paper orders tokens so the bottleneck GPU transmits
//! continuously and no receiver ever has two simultaneous senders. We realize
//! it constructively, mirroring the Appendix A proof:
//!
//! 1. augment `D` to the doubly-balanced `D' = D + X`
//!    ([`crate::traffic::augment_to_balanced`]) — every row/col sums to
//!    `b_max`;
//! 2. repeatedly extract a perfect matching from the support of `D'`
//!    (Hopcroft–Karp); Hall's condition always holds for a doubly-balanced
//!    non-negative matrix, so a matching always exists;
//! 3. each matching becomes one [`SlotRound`] of duration
//!    `w = min entry along the matching`; subtract and repeat until `D'` is
//!    exhausted.
//!
//! The rounds partition `b_max` tokens of per-port budget, every GPU sends
//! and receives at most once per round, and the bottleneck GPU carries real
//! traffic in every round — so dropping artificial filler keeps the makespan
//! at exactly `b_max`.

use super::slot::{SlotRound, SlotSchedule};
use crate::traffic::{augment_to_balanced, TrafficMatrix};

/// Build Aurora's contention-free slot schedule for traffic matrix `d`
/// (homogeneous port speeds; durations are in tokens).
///
/// The result satisfies (validated by [`super::validate_slot_schedule`]):
/// * per round, each GPU appears at most once as sender and once as receiver;
/// * total real tokens delivered equal `d`'s off-diagonal entries;
/// * `makespan_tokens() == d.b_max_tokens()`.
pub fn aurora_schedule(d: &TrafficMatrix) -> SlotSchedule {
    let n = d.n();
    let b_max = d.b_max_tokens();
    if b_max == 0 {
        return SlotSchedule { n, rounds: vec![] };
    }

    // Step 1: balance. Work on flat arrays from here on — this loop is the
    // planner's hottest path (§Perf: 64x64 BvN went 74 ms → ~4 ms by
    // replacing the per-round from-scratch Hopcroft–Karp with incremental
    // matching repair and dropping the per-round adjacency rebuild).
    let (dp_m, _x) = augment_to_balanced(d);
    let mut dp: Vec<u64> = dp_m.data().to_vec();

    // Track how much *real* traffic remains per pair, so each round reports
    // the real share of its transfers (the artificial remainder is idle time).
    let mut real: Vec<u64> = vec![0; n * n];
    for i in 0..n {
        for j in 0..n {
            if i != j {
                real[i * n + j] = d.get(i, j);
            }
        }
    }

    // Incremental matching state: after subtracting a round's duration, only
    // the edges that hit zero leave the support, so the previous round's
    // matching is repaired with one augmenting-path search per broken pair
    // instead of a full from-scratch matching.
    let mut pair_u: Vec<usize> = vec![usize::MAX; n]; // left i -> right j
    let mut pair_v: Vec<usize> = vec![usize::MAX; n]; // right j -> left i
    let mut visited: Vec<u32> = vec![0; n];
    let mut stamp: u32 = 0;

    /// Kuhn's augmenting DFS on the support of `dp`.
    fn augment(
        u: usize,
        n: usize,
        dp: &[u64],
        pair_u: &mut [usize],
        pair_v: &mut [usize],
        visited: &mut [u32],
        stamp: u32,
    ) -> bool {
        for v in 0..n {
            if dp[u * n + v] > 0 && visited[v] != stamp {
                visited[v] = stamp;
                if pair_v[v] == usize::MAX
                    || augment(pair_v[v], n, dp, pair_u, pair_v, visited, stamp)
                {
                    pair_u[u] = v;
                    pair_v[v] = u;
                    return true;
                }
            }
        }
        false
    }

    let mut rounds = Vec::new();
    let mut remaining = b_max;
    while remaining > 0 {
        // Step 2: repair the matching for every unmatched left vertex.
        for u in 0..n {
            if pair_u[u] == usize::MAX {
                stamp += 1;
                let ok = augment(u, n, &dp, &mut pair_u, &mut pair_v, &mut visited, stamp);
                debug_assert!(
                    ok,
                    "doubly-balanced matrix always has a perfect matching on its support"
                );
            }
        }

        // Step 3: round duration = min entry along the matching.
        let w = (0..n).map(|i| dp[i * n + pair_u[i]]).min().unwrap();
        debug_assert!(w > 0);

        let mut transfers = Vec::new();
        for i in 0..n {
            let j = pair_u[i];
            let cell = i * n + j;
            dp[cell] -= w;
            if i != j {
                let r = real[cell].min(w);
                if r > 0 {
                    real[cell] -= r;
                    transfers.push((i, j, r));
                }
            }
            // Edges that hit zero leave the support; break those pairs so the
            // next round's repair re-augments them.
            if dp[cell] == 0 {
                pair_u[i] = usize::MAX;
                pair_v[j] = usize::MAX;
            }
        }
        rounds.push(SlotRound {
            duration: w,
            transfers,
        });
        remaining -= w;
    }
    debug_assert!(real.iter().all(|&r| r == 0), "all real traffic scheduled");

    SlotSchedule { n, rounds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate_slot_schedule;
    use crate::util::Rng;

    #[test]
    fn empty_matrix_yields_empty_schedule() {
        let s = aurora_schedule(&TrafficMatrix::zeros(4));
        assert!(s.rounds.is_empty());
        assert_eq!(s.makespan_tokens(), 0);
    }

    #[test]
    fn fig4_matrix_schedules_in_two_slots() {
        let d = TrafficMatrix::from_nested(&[vec![0, 1, 1], vec![1, 0, 1], vec![0, 0, 0]]);
        let s = aurora_schedule(&d);
        assert_eq!(s.makespan_tokens(), 2);
        validate_slot_schedule(&d, &s).unwrap();
    }

    #[test]
    fn schedule_hits_b_max_on_random_matrices() {
        let mut rng = Rng::new(0xBEEF);
        for n in 2..=12 {
            for _ in 0..5 {
                let mut d = TrafficMatrix::zeros(n);
                for i in 0..n {
                    for j in 0..n {
                        if i != j {
                            d.set(i, j, rng.gen_range(40));
                        }
                    }
                }
                let s = aurora_schedule(&d);
                assert_eq!(s.makespan_tokens(), d.b_max_tokens(), "n={n}");
                validate_slot_schedule(&d, &s).unwrap();
            }
        }
    }

    #[test]
    fn skewed_single_receiver() {
        // Everyone sends to GPU 0: b_max = col sum of 0.
        let mut d = TrafficMatrix::zeros(5);
        for i in 1..5 {
            d.set(i, 0, 10);
        }
        let s = aurora_schedule(&d);
        assert_eq!(s.makespan_tokens(), 40);
        validate_slot_schedule(&d, &s).unwrap();
    }

    #[test]
    fn diagonal_traffic_is_ignored() {
        let mut d = TrafficMatrix::zeros(3);
        d.set(0, 0, 1000); // local tokens: no wire time
        d.set(0, 1, 2);
        let s = aurora_schedule(&d);
        assert_eq!(s.makespan_tokens(), 2);
        validate_slot_schedule(&d, &s).unwrap();
    }

    #[test]
    fn bottleneck_gpu_transmits_continuously() {
        // Alg. 1's defining property: the bottleneck GPU has real traffic in
        // every round.
        let mut rng = Rng::new(0x51A7);
        for _ in 0..10 {
            let n = 6;
            let mut d = TrafficMatrix::zeros(n);
            for i in 0..n {
                for j in 0..n {
                    if i != j {
                        d.set(i, j, rng.gen_range(20) + 1);
                    }
                }
            }
            let bottleneck = (0..n)
                .max_by_key(|&i| d.row_sum(i).max(d.col_sum(i)))
                .unwrap();
            let s = aurora_schedule(&d);
            let tx_heavy = d.row_sum(bottleneck) >= d.col_sum(bottleneck);
            for (k, round) in s.rounds.iter().enumerate() {
                let active = round.transfers.iter().any(|&(src, dst, real)| {
                    real > 0 && (if tx_heavy { src } else { dst }) == bottleneck
                });
                // The bottleneck's dominant direction must be busy every
                // round, otherwise makespan would exceed b_max.
                let busy_tokens: u64 = round
                    .transfers
                    .iter()
                    .filter(|&&(src, dst, _)| (if tx_heavy { src } else { dst }) == bottleneck)
                    .map(|&(_, _, r)| r)
                    .sum();
                assert!(
                    active && busy_tokens == round.duration,
                    "bottleneck idle in round {k}"
                );
            }
        }
    }
}
