//! Aurora's optimal transmission order (Alg. 1 / Theorem 4.2) via
//! Birkhoff–von-Neumann decomposition.
//!
//! Algorithm 1 in the paper orders tokens so the bottleneck GPU transmits
//! continuously and no receiver ever has two simultaneous senders. We realize
//! it constructively, mirroring the Appendix A proof:
//!
//! 1. augment `D` to the doubly-balanced `D' = D + X`
//!    ([`crate::traffic::augment_to_balanced`]) — every row/col sums to
//!    `b_max`;
//! 2. repeatedly extract a perfect matching from the support of `D'`
//!    (Kuhn with incremental repair); Hall's condition always holds for a
//!    doubly-balanced non-negative matrix, so a matching always exists;
//! 3. each matching becomes one [`SlotRound`] of duration
//!    `w = min entry along the matching`; subtract and repeat until `D'` is
//!    exhausted.
//!
//! The rounds partition `b_max` tokens of per-port budget, every GPU sends
//! and receives at most once per round, and the bottleneck GPU carries real
//! traffic in every round — so dropping artificial filler keeps the makespan
//! at exactly `b_max`.
//!
//! # Scale (1024×1024)
//!
//! Three changes make thousand-port matrices practical without altering the
//! emitted rounds:
//!
//! * **Support lists.** The augmenting DFS walks per-row sorted adjacency
//!   lists of `D'`'s nonzero columns instead of scanning all `n` columns, so
//!   sparse matrices (the common case after
//!   [`TrafficMatrix::to_sparse`][crate::traffic::TrafficMatrix]) skip empty
//!   rows and columns entirely. The lists enumerate the same columns in the
//!   same ascending order as the dense scan, so the matching — and therefore
//!   every round — is unchanged.
//! * **Speculative parallel repair** ([`crate::util::par::par_map`], `rayon`
//!   feature). When a round breaks many pairs, each broken left vertex
//!   speculatively runs its augmenting search against a snapshot of the
//!   matching; speculations are then applied in index order, and any whose
//!   DFS touched a right vertex re-matched by an earlier application is
//!   recomputed serially. A speculation is applied only when re-running it
//!   at apply time would retrace the same search, so the result is
//!   bit-for-bit the serial matching in both feature modes.
//! * **ε-approximate tail** ([`aurora_schedule_approx`]). Most rounds of a
//!   large decomposition move a long tail of tiny residual flows. Once the
//!   remaining *real* traffic drops below `ε · b_max`, the exact loop stops
//!   and the residue is flushed as greedy contention-free partial
//!   permutations, bounding the makespan by `(1 + ε) · b_max` while keeping
//!   conservation exact. [`aurora_schedule`] is the `ε = 0` exact path and
//!   is untouched by this mode.

use super::slot::{SlotRound, SlotSchedule};
use crate::obs::Tracer;
use crate::traffic::{augment_to_balanced, TrafficMatrix};
use crate::util::par::par_map;

/// Below this many broken pairs the speculative parallel repair is pure
/// overhead (scoped-thread spawn per round); repair them serially. Either
/// path yields the identical matching, so the cutoff never changes results.
const PAR_REPAIR_MIN: usize = 32;

/// Build Aurora's contention-free slot schedule for traffic matrix `d`
/// (homogeneous port speeds; durations are in tokens).
///
/// The result satisfies (validated by [`super::validate_slot_schedule`]):
/// * per round, each GPU appears at most once as sender and once as receiver;
/// * total real tokens delivered equal `d`'s off-diagonal entries;
/// * `makespan_tokens() == d.b_max_tokens()`.
pub fn aurora_schedule(d: &TrafficMatrix) -> SlotSchedule {
    schedule_inner(d, 0.0, &Tracer::disabled())
}

/// [`aurora_schedule`] with span tracing through `tr` (observational only —
/// the schedule is bit-for-bit that of `aurora_schedule`).
pub fn aurora_schedule_traced(d: &TrafficMatrix, tr: &Tracer) -> SlotSchedule {
    schedule_inner(d, 0.0, tr)
}

/// [`aurora_schedule`] with early termination: once the remaining real
/// traffic is at most `epsilon * b_max` tokens, the exact BvN loop stops and
/// the residue is flushed as greedy contention-free partial permutations.
///
/// The result still delivers every off-diagonal token exactly once and keeps
/// the per-round sender/receiver exclusivity invariants, but its makespan is
/// only bounded — `makespan_tokens() <= (1 + epsilon) * b_max` — rather than
/// pinned to `b_max`, so it fails [`super::validate_slot_schedule`]'s
/// `NotOptimal` check by design. `epsilon = 0` is exactly
/// [`aurora_schedule`].
pub fn aurora_schedule_approx(d: &TrafficMatrix, epsilon: f64) -> SlotSchedule {
    aurora_schedule_approx_traced(d, epsilon, &Tracer::disabled())
}

/// [`aurora_schedule_approx`] with span tracing through `tr` (observational
/// only — the schedule is bit-for-bit that of `aurora_schedule_approx`).
pub fn aurora_schedule_approx_traced(
    d: &TrafficMatrix,
    epsilon: f64,
    tr: &Tracer,
) -> SlotSchedule {
    assert!(
        epsilon >= 0.0 && epsilon.is_finite(),
        "epsilon must be a finite non-negative fraction of b_max"
    );
    schedule_inner(d, epsilon, tr)
}

fn schedule_inner(d: &TrafficMatrix, epsilon: f64, tr: &Tracer) -> SlotSchedule {
    let n = d.n();
    let b_max = d.b_max_tokens();
    if b_max == 0 {
        return SlotSchedule { n, rounds: vec![] };
    }
    let sp = tr.span("schedule.bvn");
    tr.counter(sp.id(), "n", n as i64);
    tr.counter(sp.id(), "b_max_tokens", b_max as i64);
    tr.label(sp.id(), "mode", if epsilon > 0.0 { "approx" } else { "exact" });

    // Step 1: balance. Work on flat arrays from here on — this loop is the
    // planner's hottest path (§Perf: 64x64 BvN went 74 ms → ~4 ms by
    // replacing the per-round from-scratch Hopcroft–Karp with incremental
    // matching repair and dropping the per-round adjacency rebuild).
    let (dp_m, _x) = augment_to_balanced(d);
    let mut dp: Vec<u64> = dp_m.dense_vec();

    // Support lists: per-row ascending nonzero columns of D'. The DFS below
    // walks these instead of scanning 0..n, which is what lets an (almost)
    // empty row or column cost nothing. Built with an index-ordered parallel
    // map; the row split is by input order, so the result is the serial one.
    let row_ids: Vec<usize> = (0..n).collect();
    let mut adj: Vec<Vec<usize>> = par_map(&row_ids, |&i| {
        (0..n).filter(|&j| dp[i * n + j] > 0).collect::<Vec<usize>>()
    });

    // Track how much *real* traffic remains per pair, so each round reports
    // the real share of its transfers (the artificial remainder is idle
    // time). Walks the nonzero structure only, so sparse inputs skip empty
    // rows outright.
    let mut real: Vec<u64> = vec![0; n * n];
    let mut real_left: u64 = 0;
    for i in 0..n {
        for (j, v) in d.row_iter(i) {
            if i != j {
                real[i * n + j] = v;
                real_left += v;
            }
        }
    }

    let tail_threshold = epsilon * b_max as f64;

    // Incremental matching state: after subtracting a round's duration, only
    // the edges that hit zero leave the support, so the previous round's
    // matching is repaired with one augmenting-path search per broken pair
    // instead of a full from-scratch matching.
    let mut pair_u: Vec<usize> = vec![usize::MAX; n]; // left i -> right j
    let mut pair_v: Vec<usize> = vec![usize::MAX; n]; // right j -> left i

    let mut rounds = Vec::new();
    let mut remaining = b_max;
    while remaining > 0 {
        // ε mode: the residual real traffic fits in the approximation budget;
        // flush it greedily instead of finishing the decomposition.
        if epsilon > 0.0 && (real_left as f64) <= tail_threshold {
            flush_tail(n, &mut real, real_left, &mut rounds);
            real_left = 0;
            break;
        }

        // Step 2: repair the matching for every unmatched left vertex.
        repair_matching(&adj, &mut pair_u, &mut pair_v);

        // Step 3: round duration = min entry along the matching.
        let w = (0..n).map(|i| dp[i * n + pair_u[i]]).min().unwrap();
        debug_assert!(w > 0);

        let mut transfers = Vec::new();
        for i in 0..n {
            let j = pair_u[i];
            let cell = i * n + j;
            dp[cell] -= w;
            if i != j {
                let r = real[cell].min(w);
                if r > 0 {
                    real[cell] -= r;
                    real_left -= r;
                    transfers.push((i, j, r));
                }
            }
            // Edges that hit zero leave the support; break those pairs so the
            // next round's repair re-augments them.
            if dp[cell] == 0 {
                if let Ok(p) = adj[i].binary_search(&j) {
                    adj[i].remove(p);
                }
                pair_u[i] = usize::MAX;
                pair_v[j] = usize::MAX;
            }
        }
        rounds.push(SlotRound {
            duration: w,
            transfers,
        });
        remaining -= w;
    }
    debug_assert!(
        real_left == 0 && real.iter().all(|&r| r == 0),
        "all real traffic scheduled"
    );

    tr.counter(sp.id(), "rounds", rounds.len() as i64);
    SlotSchedule { n, rounds }
}

/// Kuhn's augmenting DFS on the support lists. `adj[u]` holds exactly the
/// columns with `dp[u][v] > 0` in ascending order — the same visit order as
/// the dense `for v in 0..n` scan, so repair order (and every round) is
/// unchanged by the sparse walk.
fn augment(
    u: usize,
    adj: &[Vec<usize>],
    pair_u: &mut [usize],
    pair_v: &mut [usize],
    visited: &mut [bool],
) -> bool {
    for &v in &adj[u] {
        if !visited[v] {
            visited[v] = true;
            if pair_v[v] == usize::MAX || augment(pair_v[v], adj, pair_u, pair_v, visited) {
                pair_u[u] = v;
                pair_v[v] = u;
                return true;
            }
        }
    }
    false
}

/// Re-match every unmatched left vertex. Equivalent to running [`augment`]
/// serially for unmatched `u` in ascending order; when many pairs broke at
/// once, the searches run speculatively in parallel against a snapshot and
/// are applied in index order, falling back to a serial re-run whenever an
/// earlier application re-matched a right vertex the speculation's DFS
/// visited. The DFS reads only the (static) support and `pair_v` at visited
/// rights, so an untouched speculation retraces identically — the final
/// matching is bit-for-bit the serial one with or without the `rayon`
/// feature.
fn repair_matching(adj: &[Vec<usize>], pair_u: &mut [usize], pair_v: &mut [usize]) {
    let n = pair_u.len();
    let unmatched: Vec<usize> = (0..n).filter(|&u| pair_u[u] == usize::MAX).collect();
    if unmatched.is_empty() {
        return;
    }

    if unmatched.len() < PAR_REPAIR_MIN {
        for &u in &unmatched {
            let mut visited = vec![false; n];
            let ok = augment(u, adj, pair_u, pair_v, &mut visited);
            debug_assert!(
                ok,
                "doubly-balanced matrix always has a perfect matching on its support"
            );
        }
        return;
    }

    // Speculate in parallel against a snapshot of the matching. Each
    // speculation records the rights its DFS visited and the pair
    // reassignments it would make (augmenting paths only re-match vertices,
    // never un-match them, so `(left, new right)` diffs capture the change).
    struct Spec {
        ok: bool,
        visited: Vec<usize>,
        diffs: Vec<(usize, usize)>,
    }
    let snap_u: Vec<usize> = pair_u.to_vec();
    let snap_v: Vec<usize> = pair_v.to_vec();
    let specs: Vec<Spec> = par_map(&unmatched, |&u| {
        let mut pu = snap_u.clone();
        let mut pv = snap_v.clone();
        let mut vis = vec![false; n];
        let ok = augment(u, adj, &mut pu, &mut pv, &mut vis);
        Spec {
            ok,
            visited: (0..n).filter(|&v| vis[v]).collect(),
            diffs: (0..n)
                .filter(|&i| pu[i] != snap_u[i])
                .map(|i| (i, pu[i]))
                .collect(),
        }
    });

    // Apply in index order. `modified[v]` marks rights re-matched by an
    // earlier application this phase; a speculation that never visited a
    // modified right would retrace its DFS identically if re-run now, so
    // applying its snapshot diffs equals the serial execution.
    let mut modified = vec![false; n];
    for (spec, &u) in specs.iter().zip(&unmatched) {
        if spec.visited.iter().all(|&v| !modified[v]) {
            debug_assert!(
                spec.ok,
                "doubly-balanced matrix always has a perfect matching on its support"
            );
            for &(i, j) in &spec.diffs {
                pair_u[i] = j;
                pair_v[j] = i;
                modified[j] = true;
            }
        } else {
            // Stale speculation: re-run against the live state (this is
            // exactly what the serial loop would have done at this point).
            let before: Vec<usize> = pair_u.to_vec();
            let mut visited = vec![false; n];
            let ok = augment(u, adj, pair_u, pair_v, &mut visited);
            debug_assert!(
                ok,
                "doubly-balanced matrix always has a perfect matching on its support"
            );
            for i in 0..n {
                if pair_u[i] != before[i] {
                    modified[pair_u[i]] = true;
                }
            }
        }
    }
}

/// Flush the residual real flows as greedy contention-free partial
/// permutations: each round gives every pending sender at most one flow and
/// every receiver at most one sender, ships each chosen flow in full, and
/// lasts as long as its largest transfer. Flows are disjoint across rounds,
/// so the total tail duration is at most `real_left` tokens — which the
/// caller guarantees is within the `ε · b_max` approximation budget.
fn flush_tail(n: usize, real: &mut [u64], mut real_left: u64, rounds: &mut Vec<SlotRound>) {
    while real_left > 0 {
        let mut recv_busy = vec![false; n];
        let mut transfers: Vec<(usize, usize, u64)> = Vec::new();
        let mut w = 0u64;
        for i in 0..n {
            for j in 0..n {
                let cell = i * n + j;
                if real[cell] > 0 && !recv_busy[j] {
                    let r = real[cell];
                    real[cell] = 0;
                    recv_busy[j] = true;
                    real_left -= r;
                    w = w.max(r);
                    transfers.push((i, j, r));
                    break;
                }
            }
        }
        debug_assert!(!transfers.is_empty(), "tail flush must make progress");
        rounds.push(SlotRound {
            duration: w,
            transfers,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate_slot_schedule;
    use crate::util::Rng;

    #[test]
    fn empty_matrix_yields_empty_schedule() {
        let s = aurora_schedule(&TrafficMatrix::zeros(4));
        assert!(s.rounds.is_empty());
        assert_eq!(s.makespan_tokens(), 0);
    }

    #[test]
    fn fig4_matrix_schedules_in_two_slots() {
        let d =
            TrafficMatrix::from_nested(&[vec![0, 1, 1], vec![1, 0, 1], vec![0, 0, 0]]).unwrap();
        let s = aurora_schedule(&d);
        assert_eq!(s.makespan_tokens(), 2);
        validate_slot_schedule(&d, &s).unwrap();
    }

    #[test]
    fn schedule_hits_b_max_on_random_matrices() {
        let mut rng = Rng::new(0xBEEF);
        for n in 2..=12 {
            for _ in 0..5 {
                let mut d = TrafficMatrix::zeros(n);
                for i in 0..n {
                    for j in 0..n {
                        if i != j {
                            d.set(i, j, rng.gen_range(40));
                        }
                    }
                }
                let s = aurora_schedule(&d);
                assert_eq!(s.makespan_tokens(), d.b_max_tokens(), "n={n}");
                validate_slot_schedule(&d, &s).unwrap();
            }
        }
    }

    #[test]
    fn skewed_single_receiver() {
        // Everyone sends to GPU 0: b_max = col sum of 0.
        let mut d = TrafficMatrix::zeros(5);
        for i in 1..5 {
            d.set(i, 0, 10);
        }
        let s = aurora_schedule(&d);
        assert_eq!(s.makespan_tokens(), 40);
        validate_slot_schedule(&d, &s).unwrap();
    }

    #[test]
    fn diagonal_traffic_is_ignored() {
        let mut d = TrafficMatrix::zeros(3);
        d.set(0, 0, 1000); // local tokens: no wire time
        d.set(0, 1, 2);
        let s = aurora_schedule(&d);
        assert_eq!(s.makespan_tokens(), 2);
        validate_slot_schedule(&d, &s).unwrap();
    }

    #[test]
    fn bottleneck_gpu_transmits_continuously() {
        // Alg. 1's defining property: the bottleneck GPU has real traffic in
        // every round.
        let mut rng = Rng::new(0x51A7);
        for _ in 0..10 {
            let n = 6;
            let mut d = TrafficMatrix::zeros(n);
            for i in 0..n {
                for j in 0..n {
                    if i != j {
                        d.set(i, j, rng.gen_range(20) + 1);
                    }
                }
            }
            let bottleneck = (0..n)
                .max_by_key(|&i| d.row_sum(i).max(d.col_sum(i)))
                .unwrap();
            let s = aurora_schedule(&d);
            let tx_heavy = d.row_sum(bottleneck) >= d.col_sum(bottleneck);
            for (k, round) in s.rounds.iter().enumerate() {
                let active = round.transfers.iter().any(|&(src, dst, real)| {
                    real > 0 && (if tx_heavy { src } else { dst }) == bottleneck
                });
                // The bottleneck's dominant direction must be busy every
                // round, otherwise makespan would exceed b_max.
                let busy_tokens: u64 = round
                    .transfers
                    .iter()
                    .filter(|&&(src, dst, _)| (if tx_heavy { src } else { dst }) == bottleneck)
                    .map(|&(_, _, r)| r)
                    .sum();
                assert!(
                    active && busy_tokens == round.duration,
                    "bottleneck idle in round {k}"
                );
            }
        }
    }

    #[test]
    fn sparse_input_schedules_identically() {
        let mut rng = Rng::new(0x5AB5);
        for n in [4, 8, 16] {
            let mut d = TrafficMatrix::zeros(n);
            for i in 0..n {
                for j in 0..n {
                    if i != j && rng.gen_range(4) == 0 {
                        d.set(i, j, rng.gen_range(50) + 1);
                    }
                }
            }
            let sparse = d.to_sparse();
            assert_eq!(aurora_schedule(&d), aurora_schedule(&sparse), "n={n}");
            assert_eq!(
                aurora_schedule_approx(&d, 0.25),
                aurora_schedule_approx(&sparse, 0.25),
                "n={n} approx"
            );
        }
    }

    #[test]
    fn approx_with_zero_epsilon_is_exact() {
        let mut rng = Rng::new(0xA117);
        for n in [3, 6, 9] {
            let mut d = TrafficMatrix::zeros(n);
            for i in 0..n {
                for j in 0..n {
                    if i != j {
                        d.set(i, j, rng.gen_range(30));
                    }
                }
            }
            assert_eq!(aurora_schedule_approx(&d, 0.0), aurora_schedule(&d));
        }
    }

    #[test]
    fn approx_conserves_traffic_within_epsilon_bound() {
        let mut rng = Rng::new(0xE915);
        for n in [4, 8, 12] {
            for eps in [0.05, 0.25, 1.0] {
                let mut d = TrafficMatrix::zeros(n);
                for i in 0..n {
                    for j in 0..n {
                        if i != j {
                            d.set(i, j, rng.gen_range(60));
                        }
                    }
                }
                let b_max = d.b_max_tokens();
                let s = aurora_schedule_approx(&d, eps);
                // conservation: every off-diagonal token delivered exactly once
                let mut got = TrafficMatrix::zeros(n);
                for round in &s.rounds {
                    let mut senders = vec![false; n];
                    let mut receivers = vec![false; n];
                    for &(src, dst, tok) in &round.transfers {
                        assert!(src != dst && tok > 0 && tok <= round.duration);
                        assert!(!senders[src] && !receivers[dst], "contention");
                        senders[src] = true;
                        receivers[dst] = true;
                        got.add(src, dst, tok);
                    }
                }
                for i in 0..n {
                    for j in 0..n {
                        let want = if i == j { 0 } else { d.get(i, j) };
                        assert_eq!(got.get(i, j), want, "n={n} eps={eps} cell ({i},{j})");
                    }
                }
                let bound = b_max + (eps * b_max as f64).ceil() as u64;
                assert!(
                    s.makespan_tokens() <= bound,
                    "n={n} eps={eps}: makespan {} > (1+eps)*b_max {bound}",
                    s.makespan_tokens()
                );
            }
        }
    }

    #[test]
    fn approx_terminates_early_on_skewed_traffic() {
        // One dominant flow plus a dust tail: the ε-mode should flush the
        // dust instead of grinding out the full decomposition, and a generous
        // ε must never yield a worse makespan bound than exact + ε slack.
        let n = 16;
        let mut d = TrafficMatrix::zeros(n);
        d.set(0, 1, 10_000);
        for i in 2..n {
            d.set(i, (i + 1) % n, 3);
        }
        let exact = aurora_schedule(&d);
        let approx = aurora_schedule_approx(&d, 0.01);
        assert_eq!(exact.makespan_tokens(), d.b_max_tokens());
        let bound = d.b_max_tokens() + (0.01 * d.b_max_tokens() as f64).ceil() as u64;
        assert!(approx.makespan_tokens() <= bound);
        assert!(
            approx.rounds.len() <= exact.rounds.len(),
            "tail flush should not inflate the round count on dust traffic"
        );
    }
}
