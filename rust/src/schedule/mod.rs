//! All-to-all communication scheduling (paper §4.2, §5.2).
//!
//! Three policies decide the order in which tokens leave each GPU:
//!
//! * **Aurora** ([`aurora_schedule`]) — Alg. 1 / Theorem 4.2: a slot-level
//!   schedule built from a Birkhoff–von-Neumann decomposition of the
//!   augmented (doubly-balanced) traffic matrix. Contention-free at every
//!   receiver, makespan exactly `b_max`.
//! * **SJF** ([`SchedulePolicy::Sjf`]) — shortest-flow-first, the classic
//!   flow-scheduling baseline the paper compares against.
//! * **RCS** ([`SchedulePolicy::Rcs`]) — random order, the vanilla baseline.
//!
//! Baselines execute on the big-switch port model with *head-of-line*
//! semantics ([`simulate_priority_order`]): each sender issues its flows in
//! order (as NCCL send calls would be issued) and blocks while its current
//! destination's receive port is busy — exactly the behaviour of Fig. 4(b),
//! where a poor order costs 3 time units instead of the optimal 2.
//!
//! Heterogeneous clusters (Theorem 5.2): the same Aurora order stays optimal;
//! the makespan becomes `max_i max(tx_i, rx_i) / B_i` and baseline flows
//! transfer at `min(B_src, B_dst)`.
//!
//! Two-tier topologies ([`crate::cluster::Topology::TwoTier`]): the flat
//! order is no longer contention-free at the oversubscribed group uplinks.
//! [`hierarchical_schedule`] decomposes the all-to-all into per-group Aurora
//! phases plus a group-level BvN uplink phase with designated gateway
//! senders; [`comm_time_on`] is the topology-aware entry point dispatching
//! between the flat and hierarchical paths.

mod bvn;
mod greedy;
mod hierarchy;
mod slot;
mod validate;

pub use bvn::{
    aurora_schedule, aurora_schedule_approx, aurora_schedule_approx_traced, aurora_schedule_traced,
};
pub use greedy::{simulate_priority_order, CommResult};
pub use hierarchy::{
    comm_time_on, flat_aurora_on_topology, flat_schedule_on_topology, hierarchical_schedule,
    hierarchical_schedule_traced, HierarchicalSchedule, InterRound,
};
pub use slot::{SlotRound, SlotSchedule};
pub use validate::{validate_slot_schedule, ValidationError};

use crate::traffic::TrafficMatrix;
use crate::util::Rng;

/// Which communication scheduling policy orders token transmission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Aurora's contention-free slot schedule (Theorem 4.2 / Alg. 1).
    Aurora,
    /// Shortest-job-first flow ordering.
    Sjf,
    /// Longest-job-first flow ordering (ablation: prioritizing the bottleneck
    /// flows without Aurora's receiver-contention analysis).
    Ljf,
    /// FasterMoE-style pairwise exchange: `n-1` structured rounds, round `k`
    /// pairing GPU `i` with GPU `(i+k) mod n` — traffic-oblivious but
    /// contention-free by construction [He et al., PPoPP'22].
    Pairwise,
    /// Random communication scheduling with the given seed.
    Rcs { seed: u64 },
}

impl SchedulePolicy {
    /// Short display name used by the eval harness.
    pub fn name(&self) -> &'static str {
        match self {
            SchedulePolicy::Aurora => "aurora",
            SchedulePolicy::Sjf => "sjf",
            SchedulePolicy::Ljf => "ljf",
            SchedulePolicy::Pairwise => "pairwise",
            SchedulePolicy::Rcs { .. } => "rcs",
        }
    }
}

/// Communication time of one all-to-all under `policy` on a cluster with
/// per-GPU `bandwidths` (tokens/ms).
///
/// For Aurora the makespan is the Theorem 4.2 / 5.2 bound, which the explicit
/// slot schedule achieves (validated in tests for the homogeneous case and by
/// the fluid argument of Appendix B for the heterogeneous case). Baselines
/// are simulated on the head-of-line port model.
pub fn comm_time(d: &TrafficMatrix, bandwidths: &[f64], policy: SchedulePolicy) -> CommResult {
    assert_eq!(d.n(), bandwidths.len());
    match policy {
        SchedulePolicy::Aurora => {
            let makespan = d.b_max_hetero(bandwidths);
            let per_gpu_finish = (0..d.n())
                .map(|i| (d.row_sum(i).max(d.col_sum(i)) as f64) / bandwidths[i])
                .collect();
            CommResult {
                makespan,
                per_gpu_finish,
            }
        }
        SchedulePolicy::Sjf => {
            let mut flows = d.flows();
            // shortest first; deterministic tiebreak on (src, dst)
            flows.sort_by_key(|&(i, j, t)| (t, i, j));
            let order: Vec<(usize, usize)> = flows.iter().map(|&(i, j, _)| (i, j)).collect();
            simulate_priority_order(d, &order, bandwidths)
        }
        SchedulePolicy::Ljf => {
            let mut flows = d.flows();
            flows.sort_by_key(|&(i, j, t)| (std::cmp::Reverse(t), i, j));
            let order: Vec<(usize, usize)> = flows.iter().map(|&(i, j, _)| (i, j)).collect();
            simulate_priority_order(d, &order, bandwidths)
        }
        SchedulePolicy::Pairwise => {
            // n-1 lockstep rounds: round k pairs i -> (i+k) mod n. Each round
            // lasts as long as its slowest pair; contention-free but blind to
            // skew, so light rounds still wait for their heaviest flow.
            let n = d.n();
            let mut makespan = 0.0f64;
            for k in 1..n {
                let round: f64 = (0..n)
                    .map(|i| {
                        let j = (i + k) % n;
                        let t = d.get(i, j);
                        if t == 0 {
                            0.0
                        } else {
                            t as f64 / bandwidths[i].min(bandwidths[j])
                        }
                    })
                    .fold(0.0, f64::max);
                makespan += round;
            }
            CommResult {
                makespan,
                per_gpu_finish: vec![makespan; n],
            }
        }
        SchedulePolicy::Rcs { seed } => {
            let mut flows = d.flows();
            let mut rng = Rng::new(seed);
            rng.shuffle(&mut flows);
            let order: Vec<(usize, usize)> = flows.iter().map(|&(i, j, _)| (i, j)).collect();
            simulate_priority_order(d, &order, bandwidths)
        }
    }
}

/// Convenience: Aurora's minimum communication time on a homogeneous cluster
/// with bandwidth `b` tokens/ms (Theorem 4.2: `b_max / B`).
pub fn aurora_comm_time_homogeneous(d: &TrafficMatrix, b: f64) -> f64 {
    d.b_max_tokens() as f64 / b
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig. 4 of the paper: GPU 0 sends one token each to GPUs 1 and 2;
    /// GPU 1 sends one token each to GPUs 0 and 2.
    fn fig4_matrix() -> TrafficMatrix {
        TrafficMatrix::from_nested(&[vec![0, 1, 1], vec![1, 0, 1], vec![0, 0, 0]]).unwrap()
    }

    #[test]
    fn fig4_aurora_achieves_two_units() {
        let d = fig4_matrix();
        let r = comm_time(&d, &[1.0; 3], SchedulePolicy::Aurora);
        assert_eq!(r.makespan, 2.0);
    }

    #[test]
    fn fig4_bad_order_costs_three_units() {
        // GPU0 queue: [→1, →2]; GPU1 queue: [→0, →2]. GPU1's send to GPU2
        // head-of-line-blocks behind GPU0's (Fig. 4b): 3 units.
        let d = fig4_matrix();
        let order = vec![(0, 1), (1, 0), (0, 2), (1, 2)];
        let r = simulate_priority_order(&d, &order, &[1.0; 3]);
        assert_eq!(r.makespan, 3.0);
    }

    #[test]
    fn fig4_good_order_costs_two_units() {
        // GPU0 queue: [→1, →2]; GPU1 queue: [→2, →0] — Fig. 4c's optimum.
        let d = fig4_matrix();
        let order = vec![(0, 1), (1, 2), (0, 2), (1, 0)];
        let r = simulate_priority_order(&d, &order, &[1.0; 3]);
        assert_eq!(r.makespan, 2.0);
    }

    #[test]
    fn aurora_never_beaten_by_baselines() {
        let mut rng = Rng::new(2024);
        for n in 2..=10 {
            for trial in 0..5 {
                let mut d = TrafficMatrix::zeros(n);
                for i in 0..n {
                    for j in 0..n {
                        if i != j {
                            d.set(i, j, rng.gen_range(30));
                        }
                    }
                }
                let bw = vec![1.0; n];
                let a = comm_time(&d, &bw, SchedulePolicy::Aurora).makespan;
                let s = comm_time(&d, &bw, SchedulePolicy::Sjf).makespan;
                let r = comm_time(&d, &bw, SchedulePolicy::Rcs { seed: trial }).makespan;
                assert!(a <= s + 1e-9, "n={n} aurora={a} sjf={s}");
                assert!(a <= r + 1e-9, "n={n} aurora={a} rcs={r}");
            }
        }
    }

    #[test]
    fn zero_matrix_zero_time() {
        let d = TrafficMatrix::zeros(4);
        for p in [
            SchedulePolicy::Aurora,
            SchedulePolicy::Sjf,
            SchedulePolicy::Rcs { seed: 1 },
        ] {
            assert_eq!(comm_time(&d, &[1.0; 4], p).makespan, 0.0);
        }
    }

    #[test]
    fn reversed_all_to_all_same_aurora_time() {
        let d =
            TrafficMatrix::from_nested(&[vec![0, 9, 1], vec![2, 0, 4], vec![7, 3, 0]]).unwrap();
        let bw = [1.0; 3];
        let fwd = comm_time(&d, &bw, SchedulePolicy::Aurora).makespan;
        let rev = comm_time(&d.transpose(), &bw, SchedulePolicy::Aurora).makespan;
        assert_eq!(fwd, rev);
    }

    #[test]
    fn hetero_bandwidth_scales_makespan() {
        let d = fig4_matrix();
        let r = comm_time(&d, &[2.0, 1.0, 1.0], SchedulePolicy::Aurora);
        // tx: GPU0 2/2=1, GPU1 2/1=2; rx: GPU2 2/1=2 -> 2.0
        assert_eq!(r.makespan, 2.0);
    }

    #[test]
    fn policy_names() {
        assert_eq!(SchedulePolicy::Aurora.name(), "aurora");
        assert_eq!(SchedulePolicy::Sjf.name(), "sjf");
        assert_eq!(SchedulePolicy::Rcs { seed: 3 }.name(), "rcs");
    }
}
