//! Event-driven big-switch simulation of an ordered flow schedule.
//!
//! Models how a communication library executes a given send order: each GPU
//! issues its flows in the supplied priority order and **head-of-line
//! blocks** — the sender's port idles while its current destination's receive
//! port is busy with another sender. This reproduces the paper's Fig. 4(b)
//! pathology (3 time units for a schedule Aurora finishes in 2) and is the
//! execution model for the SJF and RCS baselines.

use crate::traffic::TrafficMatrix;

/// Result of one all-to-all under some schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct CommResult {
    /// Completion time of the whole collective (ms, i.e. tokens ÷ tokens/ms).
    pub makespan: f64,
    /// Per-GPU time at which the GPU finished all its sends and receives.
    pub per_gpu_finish: Vec<f64>,
}

impl CommResult {
    /// An all-zero result for an empty collective.
    pub fn empty(n: usize) -> Self {
        Self {
            makespan: 0.0,
            per_gpu_finish: vec![0.0; n],
        }
    }
}

/// Simulate an all-to-all whose flows start in `order` (global priority;
/// per-sender queues preserve this order). A flow `src → dst` transfers
/// `d[src][dst]` tokens at rate `min(B_src, B_dst)` once both ports are free,
/// and each sender only issues its queue head (head-of-line semantics).
///
/// Flows present in `d` but missing from `order` are appended in row-major
/// order so traffic is never silently dropped.
pub fn simulate_priority_order(
    d: &TrafficMatrix,
    order: &[(usize, usize)],
    bandwidths: &[f64],
) -> CommResult {
    let n = d.n();
    assert_eq!(bandwidths.len(), n);

    // Per-sender FIFO queues in global priority order.
    let mut queues: Vec<Vec<(usize, u64)>> = vec![Vec::new(); n];
    let mut queued = vec![false; n * n];
    for &(src, dst) in order {
        let t = d.get(src, dst);
        if src != dst && t > 0 && !queued[src * n + dst] {
            queued[src * n + dst] = true;
            queues[src].push((dst, t));
        }
    }
    for (src, dst, t) in d.flows() {
        if !queued[src * n + dst] {
            queues[src].push((dst, t));
        }
    }
    // Queue heads pop from the front.
    let mut head = vec![0usize; n];

    let mut tx_busy = vec![false; n];
    let mut rx_busy = vec![false; n];
    // Active flows: (finish_time, src, dst).
    let mut active: Vec<(f64, usize, usize)> = Vec::new();
    let mut finish = vec![0.0f64; n];
    let mut now = 0.0f64;

    loop {
        // Start every queue head whose ports are both free. Keep sweeping
        // until a fixed point: starting one flow can never unblock another
        // (it only occupies ports), so one pass per sender suffices, but a
        // receiver freed *this* instant may serve the next sender in order.
        let mut progressed = true;
        while progressed {
            progressed = false;
            for src in 0..n {
                if tx_busy[src] || head[src] >= queues[src].len() {
                    continue;
                }
                let (dst, tokens) = queues[src][head[src]];
                if rx_busy[dst] {
                    continue; // head-of-line blocked
                }
                let rate = bandwidths[src].min(bandwidths[dst]);
                assert!(rate > 0.0, "zero-bandwidth GPU cannot communicate");
                let t_end = now + tokens as f64 / rate;
                tx_busy[src] = true;
                rx_busy[dst] = true;
                head[src] += 1;
                active.push((t_end, src, dst));
                progressed = true;
            }
        }

        if active.is_empty() {
            debug_assert!((0..n).all(|s| head[s] >= queues[s].len()));
            break;
        }

        // Advance to the earliest finish; release those ports.
        let t_next = active
            .iter()
            .map(|&(t, _, _)| t)
            .fold(f64::INFINITY, f64::min);
        now = t_next;
        let mut i = 0;
        while i < active.len() {
            if active[i].0 <= now + 1e-12 {
                let (t, src, dst) = active.swap_remove(i);
                tx_busy[src] = false;
                rx_busy[dst] = false;
                finish[src] = finish[src].max(t);
                finish[dst] = finish[dst].max(t);
            } else {
                i += 1;
            }
        }
    }

    CommResult {
        makespan: now,
        per_gpu_finish: finish,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn single_flow_duration() {
        let mut d = TrafficMatrix::zeros(2);
        d.set(0, 1, 10);
        let r = simulate_priority_order(&d, &[(0, 1)], &[2.0, 2.0]);
        assert_eq!(r.makespan, 5.0);
        assert_eq!(r.per_gpu_finish, vec![5.0, 5.0]);
    }

    #[test]
    fn hetero_rate_is_min_of_ports() {
        let mut d = TrafficMatrix::zeros(2);
        d.set(0, 1, 10);
        let r = simulate_priority_order(&d, &[(0, 1)], &[5.0, 1.0]);
        assert_eq!(r.makespan, 10.0);
    }

    #[test]
    fn missing_flows_are_appended() {
        let mut d = TrafficMatrix::zeros(3);
        d.set(0, 1, 1);
        d.set(2, 1, 1);
        // order only mentions one flow; the other must still be delivered
        let r = simulate_priority_order(&d, &[(0, 1)], &[1.0; 3]);
        assert_eq!(r.makespan, 2.0); // both serialize on GPU1's rx port
    }

    #[test]
    fn parallel_disjoint_flows_overlap() {
        let mut d = TrafficMatrix::zeros(4);
        d.set(0, 1, 7);
        d.set(2, 3, 7);
        let r = simulate_priority_order(&d, &[(0, 1), (2, 3)], &[1.0; 4]);
        assert_eq!(r.makespan, 7.0);
    }

    #[test]
    fn makespan_never_below_lower_bound() {
        // Any schedule's makespan is >= the Theorem 4.2 bound.
        let mut rng = Rng::new(404);
        for n in 2..=8 {
            for trial in 0..10u64 {
                let mut d = TrafficMatrix::zeros(n);
                for i in 0..n {
                    for j in 0..n {
                        if i != j {
                            d.set(i, j, rng.gen_range(25));
                        }
                    }
                }
                let mut flows = d.flows();
                let mut r2 = Rng::new(trial + 1);
                r2.shuffle(&mut flows);
                let order: Vec<(usize, usize)> = flows.iter().map(|&(i, j, _)| (i, j)).collect();
                let res = simulate_priority_order(&d, &order, &vec![1.0; n]);
                let bound = d.b_max_tokens() as f64;
                assert!(
                    res.makespan >= bound - 1e-9,
                    "makespan {} below bound {bound}",
                    res.makespan
                );
            }
        }
    }

    #[test]
    fn aurora_priority_order_achieves_b_max_via_simulator() {
        // Running Aurora's flattened order through the head-of-line simulator
        // must reach the optimal makespan on permutation-structured traffic
        // (every round is a full permutation, so head-of-line never blocks).
        let mut d = TrafficMatrix::zeros(4);
        // circulant: i -> i+1 (5 tokens), i -> i+2 (3 tokens)
        for i in 0..4 {
            d.set(i, (i + 1) % 4, 5);
            d.set(i, (i + 2) % 4, 3);
        }
        let sched = crate::schedule::aurora_schedule(&d);
        let order = sched.priority_order();
        let res = simulate_priority_order(&d, &order, &[1.0; 4]);
        assert_eq!(res.makespan, d.b_max_tokens() as f64);
    }

    #[test]
    fn conservation_every_flow_runs_exactly_once() {
        let mut d = TrafficMatrix::zeros(3);
        d.set(0, 1, 2);
        d.set(1, 0, 3);
        d.set(2, 0, 4);
        let r = simulate_priority_order(&d, &[(2, 0), (1, 0), (0, 1)], &[1.0; 3]);
        // rx port of 0 serializes 3+4; flow 0->1 overlaps
        assert_eq!(r.makespan, 7.0);
    }
}
