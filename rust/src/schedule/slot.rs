//! Slot-level schedule representation.
//!
//! Aurora's optimal order (Alg. 1) is naturally expressed as a sequence of
//! *rounds*: within one round every GPU sends to at most one destination and
//! receives from at most one source (a partial permutation), so there is no
//! port contention by construction. Rounds have integer token durations; the
//! whole schedule's makespan is the sum of round durations.

use crate::traffic::TrafficMatrix;

/// One contention-free round: a partial permutation of transfers, each moving
/// at most `duration` real tokens from `src` to `dst`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlotRound {
    /// Round length in tokens (per-port budget of this round).
    pub duration: u64,
    /// `(src, dst, real_tokens)` — `real_tokens ≤ duration`. Transfers whose
    /// tokens were purely artificial (the 𝕏 filler of Appendix A) are
    /// omitted; the port simply idles for the round's remainder.
    pub transfers: Vec<(usize, usize, u64)>,
}

/// An ordered list of rounds realizing one all-to-all.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlotSchedule {
    /// Number of GPUs.
    pub n: usize,
    /// Rounds in transmission order.
    pub rounds: Vec<SlotRound>,
}

impl SlotSchedule {
    /// Total schedule length in tokens (at bandwidth `B` tokens/ms, divide by
    /// `B` for milliseconds). For Aurora this equals `b_max` (Theorem 4.2).
    pub fn makespan_tokens(&self) -> u64 {
        self.rounds.iter().map(|r| r.duration).sum()
    }

    /// Per-GPU finish time in tokens: the end of the last round in which the
    /// GPU sends or receives *real* traffic.
    pub fn per_gpu_finish_tokens(&self) -> Vec<u64> {
        let mut finish = vec![0u64; self.n];
        let mut t = 0u64;
        for round in &self.rounds {
            t += round.duration;
            for &(src, dst, real) in &round.transfers {
                if real > 0 {
                    finish[src] = t;
                    finish[dst] = t;
                }
            }
        }
        finish
    }

    /// Total real tokens moved per (src, dst) pair — for conservation checks.
    pub fn delivered(&self) -> TrafficMatrix {
        let mut m = TrafficMatrix::zeros(self.n);
        for round in &self.rounds {
            for &(src, dst, real) in &round.transfers {
                m.add(src, dst, real);
            }
        }
        m
    }

    /// Flatten to a global priority order of flows (first occurrence of each
    /// (src, dst) pair, in round order). This is the order handed to the
    /// communication library (e.g. the sequence of NCCL send calls per GPU).
    pub fn priority_order(&self) -> Vec<(usize, usize)> {
        let mut seen = vec![false; self.n * self.n];
        let mut order = Vec::new();
        for round in &self.rounds {
            for &(src, dst, real) in &round.transfers {
                if real > 0 && !seen[src * self.n + dst] {
                    seen[src * self.n + dst] = true;
                    order.push((src, dst));
                }
            }
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_round_schedule() -> SlotSchedule {
        SlotSchedule {
            n: 3,
            rounds: vec![
                SlotRound {
                    duration: 2,
                    transfers: vec![(0, 1, 2), (1, 2, 1)],
                },
                SlotRound {
                    duration: 1,
                    transfers: vec![(0, 2, 1), (1, 0, 1)],
                },
            ],
        }
    }

    #[test]
    fn makespan_sums_durations() {
        assert_eq!(two_round_schedule().makespan_tokens(), 3);
    }

    #[test]
    fn per_gpu_finish_tracks_last_real_round() {
        let s = two_round_schedule();
        let f = s.per_gpu_finish_tokens();
        assert_eq!(f, vec![3, 3, 3]); // all GPUs active in round 2 (0 recv in r2)
    }

    #[test]
    fn delivered_accumulates() {
        let d = two_round_schedule().delivered();
        assert_eq!(d.get(0, 1), 2);
        assert_eq!(d.get(1, 2), 1);
        assert_eq!(d.get(0, 2), 1);
        assert_eq!(d.get(1, 0), 1);
        assert_eq!(d.total(), 5);
    }

    #[test]
    fn priority_order_deduplicates() {
        let s = SlotSchedule {
            n: 2,
            rounds: vec![
                SlotRound {
                    duration: 1,
                    transfers: vec![(0, 1, 1)],
                },
                SlotRound {
                    duration: 1,
                    transfers: vec![(0, 1, 1)],
                },
            ],
        };
        assert_eq!(s.priority_order(), vec![(0, 1)]);
    }
}
