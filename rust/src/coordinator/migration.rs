//! Live expert migration: diff two deployments into weight-transfer flows
//! and schedule them over the same per-GPU links tokens use.
//!
//! A replan is only worth committing if moving the expert weights costs less
//! than the stale plan's decay. [`plan_migration`] computes that cost
//! honestly: it diffs the current and target
//! [`ReplicatedDeployment`]s into per-`(model, expert)` copy transfers
//! (every GPU that must gain a copy receives it from the least-loaded
//! current holder), aggregates the transfers into an ordinary
//! [`TrafficMatrix`] — weights ride the same full-duplex ports as tokens —
//! and runs [`crate::schedule::aurora_schedule`] over it, so the staging
//! makespan is the Theorem 4.2 bound of the weight traffic and the schedule
//! is machine-checkable with
//! [`crate::schedule::validate_slot_schedule`]. Copies the target drops need
//! no transfer (freeing memory is local) and are listed separately.
//!
//! Pricing is cluster-relative: [`MigrationPlan::migration_ms`] /
//! [`MigrationPlan::migration_ms_on`] read port rates from whatever
//! [`Cluster`] they are handed, so the coordinator's gray-failure path needs
//! no special casing here — passing the *effective* cluster
//! ([`crate::cluster::GpuScales::scaled`]) automatically charges a repair
//! migration at a straggler's degraded link rates.

use crate::cluster::{uplink_bound, Cluster, Topology};
use crate::replication::ReplicatedDeployment;
use crate::schedule::{aurora_schedule, SlotSchedule};
use crate::traffic::TrafficMatrix;

/// One expert-weight transfer: GPU `src` streams a copy of model `model`'s
/// expert `expert` to GPU `dst`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MigrationFlow {
    /// Model index.
    pub model: usize,
    /// Expert index within the model.
    pub expert: usize,
    /// GPU holding the copy being read (always a current holder).
    pub src: usize,
    /// GPU gaining the copy (never a current holder).
    pub dst: usize,
    /// Transfer size in wire tokens (the expert's weight volume).
    pub tokens: u64,
}

/// The full weight-movement plan between two deployments.
#[derive(Debug, Clone)]
pub struct MigrationPlan {
    /// Every copy transfer, in `(model, expert)` order.
    pub flows: Vec<MigrationFlow>,
    /// `(model, expert, gpu)` copies the target no longer hosts — freed
    /// locally after the swap, no wire traffic.
    pub dropped: Vec<(usize, usize, usize)>,
    /// The flows aggregated per (src GPU, dst GPU) — schedulable exactly
    /// like token traffic.
    pub traffic: TrafficMatrix,
    /// Aurora slot schedule of `traffic` (contention-free, optimal).
    pub schedule: SlotSchedule,
}

impl MigrationPlan {
    /// True when the two deployments host identical copies.
    pub fn is_empty(&self) -> bool {
        self.flows.is_empty() && self.dropped.is_empty()
    }

    /// Staging makespan in tokens (`b_max` of the weight traffic).
    pub fn makespan_tokens(&self) -> u64 {
        self.schedule.makespan_tokens()
    }

    /// Staging makespan in milliseconds on `cluster` (Theorem 5.2: the slot
    /// schedule is bandwidth-free; time is the worst per-port volume over
    /// that port's rate). This is the cost the replan policy weighs against
    /// the predicted serving-time gain.
    pub fn migration_ms(&self, cluster: &Cluster) -> f64 {
        assert_eq!(cluster.len(), self.traffic.n());
        self.traffic.b_max_hetero(&cluster.bandwidths())
    }

    /// [`MigrationPlan::migration_ms`] on a network topology: weight
    /// transfers crossing a group boundary ride the same oversubscribed
    /// uplinks tokens do, so the staging makespan is the port bound joined
    /// with the uplink drain bound of the weight traffic. On
    /// [`Topology::BigSwitch`] this is exactly [`MigrationPlan::migration_ms`].
    pub fn migration_ms_on(&self, cluster: &Cluster, topo: &Topology) -> f64 {
        self.migration_ms(cluster)
            .max(uplink_bound(&self.traffic, cluster, topo))
    }
}

/// Diff `cur` into `target`: one flow per copy the target adds, sourced from
/// the current holder with the least outgoing weight volume so far (ties to
/// the lower GPU id — deterministic), `expert_weight_tokens` wire tokens per
/// copy. Both deployments must have identical model/expert/cluster shapes.
pub fn plan_migration(
    cur: &ReplicatedDeployment,
    target: &ReplicatedDeployment,
    expert_weight_tokens: u64,
) -> MigrationPlan {
    plan_migration_avoiding(cur, target, expert_weight_tokens, &[])
}

/// [`plan_migration`] with a per-GPU source ban: flows never read from a GPU
/// whose `banned_src` flag is true — the repair path after a hard failure,
/// where the dead GPU's copies are unreadable
/// ([`crate::coordinator::ClusterHealth::banned_sources`]). An empty (or
/// all-false) mask is bit-for-bit [`plan_migration`]. Draining GPUs are
/// *not* banned: they still hold their weights and sending them off is
/// exactly what the repair replan wants.
///
/// Panics when every holder of a needed copy is banned — the caller must
/// evacuate failed GPUs ([`ReplicatedDeployment::evacuate_gpu`]) before
/// planning repair, which guarantees a live holder for every expert.
pub fn plan_migration_avoiding(
    cur: &ReplicatedDeployment,
    target: &ReplicatedDeployment,
    expert_weight_tokens: u64,
    banned_src: &[bool],
) -> MigrationPlan {
    assert!(expert_weight_tokens > 0, "expert weights occupy wire tokens");
    assert_eq!(cur.n_models(), target.n_models(), "model count mismatch");
    assert_eq!(cur.n_gpus(), target.n_gpus(), "cluster size mismatch");
    let n = cur.n_gpus();

    let mut flows = Vec::new();
    let mut dropped = Vec::new();
    let mut traffic = TrafficMatrix::zeros(n);
    let mut send_load = vec![0u64; n];

    for m in 0..cur.n_models() {
        assert_eq!(
            cur.base.n_experts(m),
            target.base.n_experts(m),
            "model {m} expert count mismatch"
        );
        for e in 0..cur.base.n_experts(m) {
            let have = &cur.replicas[m][e];
            let want = &target.replicas[m][e];
            for &dst in want {
                if have.contains(&dst) {
                    continue;
                }
                let src = *have
                    .iter()
                    .filter(|&&s| !banned_src.get(s).copied().unwrap_or(false))
                    .min_by_key(|&&s| (send_load[s], s))
                    .expect("no live source holds a copy — evacuate failed GPUs before planning repair");
                flows.push(MigrationFlow {
                    model: m,
                    expert: e,
                    src,
                    dst,
                    tokens: expert_weight_tokens,
                });
                traffic.add(src, dst, expert_weight_tokens);
                send_load[src] += expert_weight_tokens;
            }
            for &g in have {
                if !want.contains(&g) {
                    dropped.push((m, e, g));
                }
            }
        }
    }

    let schedule = aurora_schedule(&traffic);
    MigrationPlan {
        flows,
        dropped,
        traffic,
        schedule,
    }
}

/// Conservation check: applying `plan` to `cur` (add every flow's `dst`
/// copy, free every `dropped` copy) hosts each `(model, expert)` exactly on
/// the target's replica set. `plan_migration` output always satisfies this;
/// tests machine-check it.
pub fn migration_preserves_target(
    cur: &ReplicatedDeployment,
    target: &ReplicatedDeployment,
    plan: &MigrationPlan,
) -> bool {
    if cur.n_models() != target.n_models() || cur.n_gpus() != target.n_gpus() {
        return false;
    }
    for m in 0..cur.n_models() {
        if cur.base.n_experts(m) != target.base.n_experts(m) {
            return false;
        }
        for e in 0..cur.base.n_experts(m) {
            let mut after: Vec<usize> = cur.replicas[m][e].clone();
            for f in &plan.flows {
                if f.model == m && f.expert == e {
                    // a flow must source from a current holder and land on a
                    // GPU that does not already hold a copy
                    if !cur.replicas[m][e].contains(&f.src) || after.contains(&f.dst) {
                        return false;
                    }
                    after.push(f.dst);
                }
            }
            for &(dm, de, dg) in &plan.dropped {
                if dm == m && de == e {
                    match after.iter().position(|&g| g == dg) {
                        Some(i) => {
                            after.remove(i);
                        }
                        None => return false,
                    }
                }
            }
            let mut want = target.replicas[m][e].clone();
            after.sort_unstable();
            want.sort_unstable();
            if after != want {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::{Deployment, Scenario};
    use crate::schedule::{validate_slot_schedule, SchedulePolicy};

    fn rep(n_gpus: usize, assignment: Vec<usize>) -> ReplicatedDeployment {
        let base = Deployment::new(
            n_gpus,
            vec![assignment],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        ReplicatedDeployment::from_deployment(base)
    }

    #[test]
    fn identical_deployments_need_no_migration() {
        let a = rep(4, vec![0, 1, 2, 3]);
        let plan = plan_migration(&a, &a, 100);
        assert!(plan.is_empty());
        assert_eq!(plan.makespan_tokens(), 0);
        assert_eq!(plan.migration_ms(&Cluster::homogeneous(4, 10.0)), 0.0);
        assert!(migration_preserves_target(&a, &a, &plan));
    }

    #[test]
    fn added_replica_becomes_one_flow() {
        let cur = rep(4, vec![0, 1, 2, 3]);
        let mut tgt = rep(4, vec![0, 1, 2, 3]);
        tgt.add_replica(0, 0, 3).unwrap();
        let plan = plan_migration(&cur, &tgt, 64);
        assert_eq!(plan.flows.len(), 1);
        let f = &plan.flows[0];
        assert_eq!((f.model, f.expert, f.src, f.dst, f.tokens), (0, 0, 0, 3, 64));
        assert!(plan.dropped.is_empty());
        assert_eq!(plan.traffic.get(0, 3), 64);
        assert!(migration_preserves_target(&cur, &tgt, &plan));
        validate_slot_schedule(&plan.traffic, &plan.schedule).unwrap();
    }

    #[test]
    fn moved_primary_transfers_and_frees() {
        let cur = rep(4, vec![0, 1, 2, 3]);
        let tgt = rep(4, vec![1, 1, 2, 3]);
        let plan = plan_migration(&cur, &tgt, 50);
        // expert 0 moves 0 -> 1: one transfer plus one freed copy on GPU 0
        assert_eq!(plan.flows.len(), 1);
        assert_eq!(plan.dropped, vec![(0, 0, 0)]);
        assert!(!plan.is_empty());
        assert!(migration_preserves_target(&cur, &tgt, &plan));
    }

    #[test]
    fn sources_balance_across_existing_holders() {
        // expert 0 already has copies on GPUs 0 and 1; the target adds
        // copies on GPUs 2 and 3 — one from each holder, not both from 0.
        let mut cur = rep(4, vec![0, 1, 2, 3]);
        cur.add_replica(0, 0, 1).unwrap();
        let mut tgt = rep(4, vec![0, 1, 2, 3]);
        tgt.add_replica(0, 0, 1).unwrap();
        tgt.add_replica(0, 0, 2).unwrap();
        tgt.add_replica(0, 0, 3).unwrap();
        let plan = plan_migration(&cur, &tgt, 100);
        assert_eq!(plan.flows.len(), 2);
        let srcs: Vec<usize> = plan.flows.iter().map(|f| f.src).collect();
        assert!(srcs.contains(&0) && srcs.contains(&1), "srcs {srcs:?}");
        assert!(migration_preserves_target(&cur, &tgt, &plan));
        validate_slot_schedule(&plan.traffic, &plan.schedule).unwrap();
    }

    #[test]
    fn migration_ms_scales_with_bandwidth() {
        let cur = rep(2, vec![0, 1]);
        let mut tgt = rep(2, vec![0, 1]);
        tgt.add_replica(0, 0, 1).unwrap();
        let plan = plan_migration(&cur, &tgt, 800);
        let fast = plan.migration_ms(&Cluster::homogeneous(2, 800.0));
        let slow = plan.migration_ms(&Cluster::homogeneous(2, 400.0));
        assert!((fast - 1.0).abs() < 1e-12);
        assert!((slow - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cross_group_migration_pays_the_uplink() {
        // GPU 0 streams one copy each to GPUs 2 and 3 — both transfers cross
        // group 0's uplink, so the staging time doubles relative to the port
        // bound once the uplink is 4x oversubscribed.
        let cur = rep(4, vec![0, 1, 2, 3]);
        let mut tgt = rep(4, vec![0, 1, 2, 3]);
        tgt.add_replica(0, 0, 2).unwrap();
        tgt.add_replica(0, 0, 3).unwrap();
        let plan = plan_migration(&cur, &tgt, 400);
        let cluster = Cluster::homogeneous(4, 400.0);
        let flat = plan.migration_ms(&cluster);
        assert!((flat - 2.0).abs() < 1e-12, "port bound: 800 tokens at 400/ms");
        let big = plan.migration_ms_on(&cluster, &Topology::BigSwitch);
        assert_eq!(big, flat);
        // uplink rate = 2 ports * 400 / 4 = 200 tokens/ms; 800 tokens -> 4 ms
        let topo = Topology::even_two_tier(4, 2, 4.0).unwrap();
        let two_tier = plan.migration_ms_on(&cluster, &topo);
        assert!((two_tier - 4.0).abs() < 1e-12, "uplink-bound staging: {two_tier}");
    }

    #[test]
    fn banned_sources_are_never_read() {
        // expert 0 holds copies on GPUs 0 and 1; GPU 0 (the least-loaded,
        // lowest-id pick) is banned, so both new copies stream from GPU 1.
        let mut cur = rep(4, vec![0, 1, 2, 3]);
        cur.add_replica(0, 0, 1).unwrap();
        let mut tgt = rep(4, vec![0, 1, 2, 3]);
        tgt.add_replica(0, 0, 1).unwrap();
        tgt.add_replica(0, 0, 2).unwrap();
        tgt.add_replica(0, 0, 3).unwrap();
        let banned = vec![true, false, false, false];
        let plan = plan_migration_avoiding(&cur, &tgt, 100, &banned);
        assert_eq!(plan.flows.len(), 2);
        assert!(plan.flows.iter().all(|f| f.src == 1), "{:?}", plan.flows);
        assert!(migration_preserves_target(&cur, &tgt, &plan));
        // an all-false mask is bit-for-bit the unbanned plan
        let free = plan_migration_avoiding(&cur, &tgt, 100, &[false; 4]);
        let plain = plan_migration(&cur, &tgt, 100);
        assert_eq!(free.flows, plain.flows);
        assert_eq!(free.dropped, plain.dropped);
    }

    #[test]
    #[should_panic(expected = "no live source")]
    fn fully_banned_holders_panic() {
        let cur = rep(2, vec![0, 1]);
        let mut tgt = rep(2, vec![0, 1]);
        tgt.add_replica(0, 0, 1).unwrap();
        plan_migration_avoiding(&cur, &tgt, 10, &[true, false]);
    }

    #[test]
    fn tampered_plan_fails_conservation() {
        let cur = rep(3, vec![0, 1, 2]);
        let mut tgt = rep(3, vec![0, 1, 2]);
        tgt.add_replica(0, 0, 2).unwrap();
        let mut plan = plan_migration(&cur, &tgt, 10);
        plan.flows.clear(); // lose the transfer
        assert!(!migration_preserves_target(&cur, &tgt, &plan));
    }
}
