//! Hitless plan-swap state machine: stage weights → atomic swap → drain.
//!
//! Swapping a live deployment must never stall serving. [`PlanSwap`] models
//! the three-phase protocol the serving engine follows
//! ([`crate::serve::MoeEngine::swap_replicated`] is the commit point):
//!
//! 1. **Staging** — the old plan keeps serving while expert weights stream
//!    to their new GPUs (the migration traffic of
//!    [`super::MigrationPlan`], sharing the links with tokens);
//! 2. **atomic swap** — once every copy has landed, the active plan flips
//!    between two batches ([`PlanSwap::advance`] returns the new plan
//!    exactly once, at this instant);
//! 3. **Draining** — batches dispatched under the old plan finish on the old
//!    copies; the freed replicas are reclaimed when the drain window closes,
//!    and only then may another swap begin (a structural cooldown).
//!
//! The machine is time-driven (milliseconds of serving progress), so the
//! discrete-event simulation and unit tests advance it deterministically.

use crate::replication::{ReplicatedDeployment, SplitPlan};

/// Which phase of the swap protocol is active.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwapPhase {
    /// No swap in flight.
    Serving,
    /// New weights are streaming in; the old plan still serves.
    Staging,
    /// The new plan serves; old in-flight work finishes on the old copies.
    Draining,
}

/// The hitless swap state machine.
#[derive(Debug, Clone)]
pub struct PlanSwap {
    phase: SwapPhase,
    stage_remaining_ms: f64,
    drain_remaining_ms: f64,
    drain_ms: f64,
    pending: Option<(ReplicatedDeployment, SplitPlan)>,
    swaps: u64,
}

impl PlanSwap {
    /// New idle machine; every swap's drain window lasts `drain_ms`.
    pub fn new(drain_ms: f64) -> PlanSwap {
        assert!(drain_ms >= 0.0, "drain window cannot be negative");
        PlanSwap {
            phase: SwapPhase::Serving,
            stage_remaining_ms: 0.0,
            drain_remaining_ms: 0.0,
            drain_ms,
            pending: None,
            swaps: 0,
        }
    }

    /// Current phase.
    pub fn phase(&self) -> SwapPhase {
        self.phase
    }

    /// True while a swap is staging or draining — no new swap may begin.
    pub fn is_busy(&self) -> bool {
        self.phase != SwapPhase::Serving
    }

    /// Completed (atomic) swaps so far.
    pub fn swaps(&self) -> u64 {
        self.swaps
    }

    /// Milliseconds of staging left (0 outside [`SwapPhase::Staging`]).
    pub fn stage_remaining_ms(&self) -> f64 {
        self.stage_remaining_ms
    }

    /// Start staging a new plan. Returns `false` (and changes nothing) when
    /// a swap is already in flight.
    pub fn begin(
        &mut self,
        rep: ReplicatedDeployment,
        splits: SplitPlan,
        staging_ms: f64,
    ) -> bool {
        assert!(staging_ms >= 0.0, "staging time cannot be negative");
        if self.is_busy() {
            return false;
        }
        self.pending = Some((rep, splits));
        self.stage_remaining_ms = staging_ms;
        self.phase = SwapPhase::Staging;
        true
    }

    /// Abandon an in-flight swap and return to [`SwapPhase::Serving`]
    /// immediately. During [`SwapPhase::Staging`] the pending plan is
    /// dropped un-installed (half-staged weights are discarded); during
    /// [`SwapPhase::Draining`] the atomic swap already happened, so aborting
    /// only cuts the drain window short. The fault path uses this: a GPU
    /// failure invalidates whatever was staging, and the repair replan
    /// supersedes it. Returns `true` when there was a swap to abort.
    pub fn abort(&mut self) -> bool {
        if !self.is_busy() {
            return false;
        }
        self.pending = None;
        self.stage_remaining_ms = 0.0;
        self.drain_remaining_ms = 0.0;
        self.phase = SwapPhase::Serving;
        true
    }

    /// Advance the machine by `dt_ms` of serving time. Returns the newly
    /// active plan **exactly once** — at the staging→draining transition,
    /// the atomic swap point; the caller installs it between batches.
    pub fn advance(&mut self, dt_ms: f64) -> Option<(ReplicatedDeployment, SplitPlan)> {
        assert!(dt_ms >= 0.0, "time flows forward");
        let mut dt = dt_ms;
        let mut swapped = None;
        if self.phase == SwapPhase::Staging {
            if dt >= self.stage_remaining_ms {
                dt -= self.stage_remaining_ms;
                self.stage_remaining_ms = 0.0;
                swapped = self.pending.take();
                debug_assert!(swapped.is_some(), "staging always has a pending plan");
                self.swaps += 1;
                self.phase = SwapPhase::Draining;
                self.drain_remaining_ms = self.drain_ms;
            } else {
                self.stage_remaining_ms -= dt;
                return None;
            }
        }
        if self.phase == SwapPhase::Draining {
            if dt >= self.drain_remaining_ms {
                self.drain_remaining_ms = 0.0;
                self.phase = SwapPhase::Serving;
            } else {
                self.drain_remaining_ms -= dt;
            }
        }
        swapped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::{Deployment, Scenario};
    use crate::schedule::SchedulePolicy;

    fn plan(n: usize) -> (ReplicatedDeployment, SplitPlan) {
        let base = Deployment::new(
            n,
            vec![(0..n).collect()],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        let rep = ReplicatedDeployment::from_deployment(base);
        let splits = SplitPlan::trivial(&rep);
        (rep, splits)
    }

    #[test]
    fn full_lifecycle_swaps_exactly_once() {
        let mut s = PlanSwap::new(1.0);
        assert_eq!(s.phase(), SwapPhase::Serving);
        let (rep, splits) = plan(4);
        assert!(s.begin(rep.clone(), splits, 5.0));
        assert_eq!(s.phase(), SwapPhase::Staging);
        assert!(s.is_busy());
        // partial staging: nothing swaps
        assert!(s.advance(3.0).is_none());
        assert!((s.stage_remaining_ms() - 2.0).abs() < 1e-12);
        // staging completes, atomic swap fires, drain begins
        let swapped = s.advance(2.0).expect("swap point");
        assert_eq!(swapped.0, rep);
        assert_eq!(s.phase(), SwapPhase::Draining);
        assert_eq!(s.swaps(), 1);
        // drain completes; no second delivery
        assert!(s.advance(1.0).is_none());
        assert_eq!(s.phase(), SwapPhase::Serving);
    }

    #[test]
    fn busy_machine_rejects_a_second_begin() {
        let mut s = PlanSwap::new(0.0);
        let (rep, splits) = plan(2);
        assert!(s.begin(rep.clone(), splits.clone(), 10.0));
        assert!(!s.begin(rep.clone(), splits.clone(), 1.0));
        // still rejects while draining
        let mut d = PlanSwap::new(4.0);
        assert!(d.begin(rep.clone(), splits.clone(), 0.0));
        assert!(d.advance(0.0).is_some());
        assert_eq!(d.phase(), SwapPhase::Draining);
        assert!(!d.begin(rep, splits, 1.0));
    }

    #[test]
    fn zero_staging_swaps_on_first_advance() {
        let mut s = PlanSwap::new(0.0);
        let (rep, splits) = plan(3);
        assert!(s.begin(rep, splits, 0.0));
        assert!(s.advance(0.5).is_some());
        // zero drain: straight back to serving in the same advance
        assert_eq!(s.phase(), SwapPhase::Serving);
        assert!(!s.is_busy());
    }

    #[test]
    fn one_advance_cascades_through_staging_and_drain() {
        let mut s = PlanSwap::new(2.0);
        let (rep, splits) = plan(2);
        assert!(s.begin(rep, splits, 3.0));
        // 10 ms covers staging (3) and drain (2) in one call
        assert!(s.advance(10.0).is_some());
        assert_eq!(s.phase(), SwapPhase::Serving);
        assert_eq!(s.swaps(), 1);
    }

    #[test]
    fn abort_discards_a_staging_plan_and_frees_the_machine() {
        let mut s = PlanSwap::new(1.0);
        let (rep, splits) = plan(3);
        assert!(!s.abort(), "idle machine has nothing to abort");
        assert!(s.begin(rep.clone(), splits.clone(), 5.0));
        assert!(s.advance(2.0).is_none());
        assert!(s.abort());
        assert_eq!(s.phase(), SwapPhase::Serving);
        assert_eq!(s.swaps(), 0, "aborted staging never swapped");
        // the machine is immediately reusable, and the aborted plan is gone
        assert!(s.begin(rep.clone(), splits.clone(), 0.0));
        let swapped = s.advance(0.0).expect("fresh swap fires");
        assert_eq!(swapped.0, rep);
        // aborting mid-drain only cuts the drain short
        assert_eq!(s.phase(), SwapPhase::Draining);
        assert!(s.abort());
        assert_eq!(s.phase(), SwapPhase::Serving);
        assert_eq!(s.swaps(), 1);
    }

    #[test]
    fn drain_is_a_structural_cooldown() {
        let mut s = PlanSwap::new(5.0);
        let (rep, splits) = plan(2);
        assert!(s.begin(rep.clone(), splits.clone(), 1.0));
        assert!(s.advance(1.0).is_some());
        assert_eq!(s.phase(), SwapPhase::Draining);
        assert!(s.advance(2.0).is_none());
        assert!(!s.begin(rep.clone(), splits.clone(), 1.0));
        assert!(s.advance(3.0).is_none());
        assert!(s.begin(rep, splits, 1.0));
    }
}
