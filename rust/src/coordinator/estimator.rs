//! Live traffic estimation and drift scoring.
//!
//! The planner optimizes for one traffic matrix; production routing drifts.
//! [`TrafficEstimator`] folds observed per-window expert-indexed traffic
//! matrices into an exponentially-weighted moving average — smooth enough
//! that single-window sampling noise does not whipsaw the replan policy,
//! responsive enough that a genuine regime change (the hot expert moving,
//! the drifting-Zipf workload of
//! [`crate::traffic::drifting_zipf_traffic`]) shows up within a couple of
//! windows. [`DriftDetector`] scores how far the live estimate has moved
//! from the matrix the current plan was built on, as total-variation
//! distance between normalized expert-load distributions — the same metric
//! [`crate::serve::AdaptiveReplanner`] thresholds, reused here as the cheap
//! first gate of the cost-aware replan pipeline.

use crate::traffic::TrafficMatrix;

/// EWMA estimator over observed expert-indexed traffic matrices.
#[derive(Debug, Clone)]
pub struct TrafficEstimator {
    n: usize,
    /// Weight of the newest window in `(0, 1]` (1.0 = keep only the latest).
    alpha: f64,
    ewma: Vec<f64>,
    windows: u64,
}

impl TrafficEstimator {
    /// New estimator for `n`-expert matrices with EWMA weight `alpha`.
    pub fn new(n: usize, alpha: f64) -> TrafficEstimator {
        assert!(n > 0, "estimator needs at least one expert");
        assert!(alpha > 0.0 && alpha <= 1.0, "EWMA weight must be in (0, 1]");
        TrafficEstimator {
            n,
            alpha,
            ewma: vec![0.0; n * n],
            windows: 0,
        }
    }

    /// Number of windows folded in so far.
    pub fn windows(&self) -> u64 {
        self.windows
    }

    /// Fold one observed window. The first observation seeds the average.
    pub fn observe(&mut self, d: &TrafficMatrix) {
        assert_eq!(d.n(), self.n, "observed matrix dimension mismatch");
        if self.windows == 0 {
            for (w, v) in self.ewma.iter_mut().zip(d.dense_vec()) {
                *w = v as f64;
            }
        } else {
            for (w, v) in self.ewma.iter_mut().zip(d.dense_vec()) {
                *w = (1.0 - self.alpha) * *w + self.alpha * v as f64;
            }
        }
        self.windows += 1;
    }

    /// The current estimate, rounded back to integer tokens. Before any
    /// observation this is the all-zero matrix.
    pub fn estimate(&self) -> TrafficMatrix {
        let data: Vec<u64> = self.ewma.iter().map(|&v| v.round().max(0.0) as u64).collect();
        TrafficMatrix::from_rows(self.n, &data).expect("EWMA buffer is square by construction")
    }
}

/// Scores divergence between the plan-time routing distribution and a live
/// estimate: total-variation distance of the normalized expert-load vectors,
/// in `[0, 1]`. The score is linear in mixture weight — interpolating the
/// live distribution from the baseline toward any target raises the score
/// monotonically — which is what makes a fixed threshold meaningful.
#[derive(Debug, Clone)]
pub struct DriftDetector {
    baseline: Vec<f64>,
}

impl DriftDetector {
    /// Baseline from the traffic matrix the current plan was optimized for.
    pub fn new(plan_traffic: &TrafficMatrix) -> DriftDetector {
        DriftDetector {
            baseline: normalize(&plan_traffic.expert_loads()),
        }
    }

    /// Baseline from raw per-expert loads (unnormalized is fine).
    pub fn from_loads(plan_loads: &[u64]) -> DriftDetector {
        assert!(!plan_loads.is_empty());
        DriftDetector {
            baseline: normalize(plan_loads),
        }
    }

    /// Drift of a live traffic estimate against the baseline.
    pub fn score(&self, live: &TrafficMatrix) -> f64 {
        self.score_loads(&live.expert_loads())
    }

    /// Drift of a live per-expert load histogram against the baseline.
    pub fn score_loads(&self, live_loads: &[u64]) -> f64 {
        assert_eq!(live_loads.len(), self.baseline.len());
        total_variation(&self.baseline, &normalize(live_loads))
    }

    /// Adopt a new baseline after a replan commits.
    pub fn rebase(&mut self, plan_traffic: &TrafficMatrix) {
        let loads = plan_traffic.expert_loads();
        assert_eq!(loads.len(), self.baseline.len());
        self.baseline = normalize(&loads);
    }
}

fn normalize(counts: &[u64]) -> Vec<f64> {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return vec![1.0 / counts.len() as f64; counts.len()];
    }
    counts.iter().map(|&c| c as f64 / total as f64).collect()
}

fn total_variation(p: &[f64], q: &[f64]) -> f64 {
    0.5 * p.iter().zip(q).map(|(a, b)| (a - b).abs()).sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::zipf_traffic;

    fn uniform(n: usize, fill: u64) -> TrafficMatrix {
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                d.set(i, j, fill);
            }
        }
        d
    }

    #[test]
    fn first_observation_seeds_the_average() {
        let mut est = TrafficEstimator::new(4, 0.5);
        assert_eq!(est.windows(), 0);
        let d = uniform(4, 8);
        est.observe(&d);
        assert_eq!(est.estimate(), d);
        assert_eq!(est.windows(), 1);
    }

    #[test]
    fn ewma_converges_to_a_new_regime() {
        let mut est = TrafficEstimator::new(4, 0.5);
        est.observe(&uniform(4, 100));
        let hot = {
            let mut d = TrafficMatrix::zeros(4);
            for i in 0..4 {
                d.set(i, 0, 400);
            }
            d
        };
        for _ in 0..20 {
            est.observe(&hot);
        }
        // after 20 half-life windows the estimate is the new regime
        assert_eq!(est.estimate(), hot);
    }

    #[test]
    fn alpha_one_keeps_only_the_latest_window() {
        let mut est = TrafficEstimator::new(3, 1.0);
        est.observe(&uniform(3, 9));
        let d = uniform(3, 2);
        est.observe(&d);
        assert_eq!(est.estimate(), d);
    }

    #[test]
    #[should_panic]
    fn mismatched_observation_panics() {
        let mut est = TrafficEstimator::new(3, 0.5);
        est.observe(&uniform(4, 1));
    }

    #[test]
    fn zero_drift_on_the_baseline_itself() {
        let d = zipf_traffic(8, 256, 1.2, 7);
        let det = DriftDetector::new(&d);
        assert!(det.score(&d) < 1e-12);
        // scaling the whole matrix does not change the distribution
        let doubled = d.sum(&d);
        assert!(det.score(&doubled) < 1e-12);
    }

    #[test]
    fn drift_score_is_bounded() {
        let det = DriftDetector::from_loads(&[1, 1, 1, 1]);
        let mut hot = TrafficMatrix::zeros(4);
        hot.set(0, 0, 100);
        let s = det.score(&hot);
        assert!((0.0..=1.0).contains(&s));
        // uniform -> single expert: TV = 1 - 1/4
        assert!((s - 0.75).abs() < 1e-12);
    }

    /// Satellite acceptance: interpolating the live distribution from the
    /// baseline toward a fixed target raises the score monotonically (the
    /// property that makes a fixed replan threshold meaningful).
    #[test]
    fn drift_score_is_monotone_in_mixture_weight() {
        let n = 8;
        let det = DriftDetector::from_loads(&[100u64; 8]);
        let mut last = -1.0;
        // expert loads (1000-100k, 100+...) interpolate uniform -> hot in
        // exact integer steps k/10
        for k in 0..=10u64 {
            let mut d = TrafficMatrix::zeros(n);
            for e in 0..n {
                let load = if e == 0 {
                    1000 - 100 * k + 800 * k
                } else {
                    1000 - 100 * k
                };
                d.set(0, e, load);
            }
            let s = det.score(&d);
            assert!(
                s >= last - 1e-12,
                "drift not monotone at step {k}: {s} < {last}"
            );
            last = s;
        }
        assert!(last > 0.5, "full mixture should be far from baseline");
    }

    #[test]
    fn rebase_adopts_the_new_distribution() {
        let base = zipf_traffic(6, 120, 0.0, 1);
        let skew = zipf_traffic(6, 120, 1.5, 1);
        let mut det = DriftDetector::new(&base);
        assert!(det.score(&skew) > 0.1);
        det.rebase(&skew);
        assert!(det.score(&skew) < 1e-12);
    }

    #[test]
    fn zero_live_loads_read_as_uniform() {
        let det = DriftDetector::from_loads(&[1, 1]);
        assert!(det.score(&TrafficMatrix::zeros(2)) < 1e-12);
    }
}
