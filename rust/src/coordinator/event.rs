//! Cluster-membership events and the liveness mask the fault-tolerant
//! coordinator keeps — the vocabulary of GPUs failing, draining, and
//! (re)joining while serving continues.
//!
//! Semantics of the three states a GPU can be in:
//!
//! * **alive + placeable** — the healthy default: serves tokens, hosts
//!   copies, sources and receives migrations.
//! * **draining** (alive, not placeable) — a graceful leave or a
//!   consolidation target: keeps serving its current copies and may *source*
//!   weight migrations, but no new copy is placed on it. The repair replan
//!   moves its copies off over the normal staged-migration path.
//! * **dead** (not alive) — a hard failure: its copies are gone. Survivor
//!   replicas are promoted immediately ([`ClusterEvent::GpuFailed`] →
//!   [`crate::replication::ReplicatedDeployment::evacuate_gpu`]), it is
//!   banned as a migration *source* ([`super::plan_migration_avoiding`]) and
//!   as a placement target, and the serving loop asserts it receives zero
//!   tokens ([`crate::sim::dead_gpu_tokens`]).
//!
//! [`failure_schedule`] generates randomized but always-survivable event
//! sequences for property tests and the `eval resilience` figure.

use crate::util::Rng;

/// One cluster-membership change, applied at the start of a serving window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterEvent {
    /// Hard failure: the GPU and every expert copy on it are gone.
    GpuFailed(usize),
    /// The GPU is (back) in service and placeable.
    GpuJoined(usize),
    /// Graceful leave: stop placing on the GPU and migrate its copies off;
    /// it keeps serving (and may source migrations) until vacated.
    GpuDrained(usize),
}

impl ClusterEvent {
    /// The GPU the event concerns.
    pub fn gpu(&self) -> usize {
        match *self {
            ClusterEvent::GpuFailed(g)
            | ClusterEvent::GpuJoined(g)
            | ClusterEvent::GpuDrained(g) => g,
        }
    }

    /// Event name (decision-log / CLI vocabulary).
    pub fn name(&self) -> &'static str {
        match self {
            ClusterEvent::GpuFailed(_) => "gpu_failed",
            ClusterEvent::GpuJoined(_) => "gpu_joined",
            ClusterEvent::GpuDrained(_) => "gpu_drained",
        }
    }
}

/// Liveness/placeability mask over the cluster's GPU ids, updated by
/// [`ClusterHealth::apply`]. Starts all-healthy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterHealth {
    alive: Vec<bool>,
    draining: Vec<bool>,
}

impl ClusterHealth {
    /// All `n_gpus` GPUs alive and placeable.
    pub fn new(n_gpus: usize) -> ClusterHealth {
        assert!(n_gpus > 0, "a cluster has at least one GPU");
        ClusterHealth {
            alive: vec![true; n_gpus],
            draining: vec![false; n_gpus],
        }
    }

    /// Cluster size the mask covers.
    pub fn n_gpus(&self) -> usize {
        self.alive.len()
    }

    /// True unless GPU `g` has failed.
    pub fn is_alive(&self, g: usize) -> bool {
        self.alive[g]
    }

    /// True when GPU `g` is alive but being vacated.
    pub fn is_draining(&self, g: usize) -> bool {
        self.draining[g]
    }

    /// True when new expert copies may be placed on GPU `g`.
    pub fn is_placeable(&self, g: usize) -> bool {
        self.alive[g] && !self.draining[g]
    }

    /// True when every GPU is placeable (the healthy fast path: planning
    /// needs no sub-cluster compaction).
    pub fn all_placeable(&self) -> bool {
        (0..self.n_gpus()).all(|g| self.is_placeable(g))
    }

    /// Per-GPU liveness, indexable by GPU id.
    pub fn alive(&self) -> &[bool] {
        &self.alive
    }

    /// Per-GPU placeability, indexable by GPU id.
    pub fn placeable(&self) -> Vec<bool> {
        (0..self.n_gpus()).map(|g| self.is_placeable(g)).collect()
    }

    /// Ids of the placeable GPUs, ascending.
    pub fn placeable_gpus(&self) -> Vec<usize> {
        (0..self.n_gpus()).filter(|&g| self.is_placeable(g)).collect()
    }

    /// Number of placeable GPUs.
    pub fn n_placeable(&self) -> usize {
        self.placeable_gpus().len()
    }

    /// Per-GPU mask of GPUs that must never *source* a migration (the dead
    /// ones — a draining GPU still holds its weights and may send them).
    pub fn banned_sources(&self) -> Vec<bool> {
        self.alive.iter().map(|&a| !a).collect()
    }

    /// Apply one membership event. Idempotent: re-failing a dead GPU or
    /// re-joining a placeable one is a no-op.
    pub fn apply(&mut self, ev: &ClusterEvent) {
        let g = ev.gpu();
        assert!(g < self.n_gpus(), "event names GPU {g} of {}", self.n_gpus());
        match ev {
            ClusterEvent::GpuFailed(_) => {
                self.alive[g] = false;
                self.draining[g] = false;
            }
            ClusterEvent::GpuJoined(_) => {
                self.alive[g] = true;
                self.draining[g] = false;
            }
            ClusterEvent::GpuDrained(_) => {
                self.draining[g] = true;
            }
        }
    }
}

/// A randomized, always-survivable membership-event schedule: `n_events`
/// fail/drain/join events at ascending windows in `0..windows`, constrained
/// (against a health mask replayed in order) so at least two GPUs stay
/// placeable at every point and every event is meaningful — only placeable
/// GPUs fail or drain, only non-placeable ones join. Deterministic in
/// `seed`; the property suite drives the coordinator with these.
pub fn failure_schedule(
    n_gpus: usize,
    windows: usize,
    n_events: usize,
    seed: u64,
) -> Vec<(usize, ClusterEvent)> {
    assert!(n_gpus >= 3, "need headroom to fail a GPU and keep two placeable");
    assert!(windows > 0);
    let mut rng = Rng::new(seed ^ 0xFA11_5AFE);
    let mut ws: Vec<usize> = (0..n_events)
        .map(|_| rng.gen_range(windows as u64) as usize)
        .collect();
    ws.sort_unstable();
    let mut health = ClusterHealth::new(n_gpus);
    let mut out = Vec::with_capacity(n_events);
    for w in ws {
        let mut cands: Vec<ClusterEvent> = Vec::new();
        for g in 0..n_gpus {
            if health.is_placeable(g) {
                if health.n_placeable() > 2 {
                    cands.push(ClusterEvent::GpuFailed(g));
                    cands.push(ClusterEvent::GpuDrained(g));
                }
            } else {
                cands.push(ClusterEvent::GpuJoined(g));
            }
        }
        if cands.is_empty() {
            continue;
        }
        let ev = cands[rng.gen_range(cands.len() as u64) as usize];
        health.apply(&ev);
        out.push((w, ev));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn health_state_machine() {
        let mut h = ClusterHealth::new(4);
        assert!(h.all_placeable());
        h.apply(&ClusterEvent::GpuDrained(1));
        assert!(h.is_alive(1) && !h.is_placeable(1));
        assert!(!h.banned_sources()[1], "draining GPUs still source");
        h.apply(&ClusterEvent::GpuFailed(2));
        assert!(!h.is_alive(2) && h.banned_sources()[2]);
        assert_eq!(h.placeable_gpus(), vec![0, 3]);
        h.apply(&ClusterEvent::GpuJoined(1));
        h.apply(&ClusterEvent::GpuJoined(2));
        assert!(h.all_placeable());
        // idempotence
        h.apply(&ClusterEvent::GpuJoined(2));
        assert!(h.all_placeable());
    }

    #[test]
    fn failure_schedule_is_survivable_and_deterministic() {
        for seed in 0..20 {
            let evs = failure_schedule(5, 12, 8, seed);
            assert_eq!(evs, failure_schedule(5, 12, 8, seed));
            let mut h = ClusterHealth::new(5);
            let mut last_w = 0;
            for (w, ev) in &evs {
                assert!(*w >= last_w, "windows ascend");
                last_w = *w;
                match ev {
                    ClusterEvent::GpuFailed(g) | ClusterEvent::GpuDrained(g) => {
                        assert!(h.is_placeable(*g))
                    }
                    ClusterEvent::GpuJoined(g) => assert!(!h.is_placeable(*g)),
                }
                h.apply(ev);
                assert!(h.n_placeable() >= 2, "never below two placeable GPUs");
            }
        }
    }
}
