//! Cluster-membership events and the liveness mask the fault-tolerant
//! coordinator keeps — the vocabulary of GPUs failing, draining, and
//! (re)joining while serving continues.
//!
//! Semantics of the three states a GPU can be in:
//!
//! * **alive + placeable** — the healthy default: serves tokens, hosts
//!   copies, sources and receives migrations.
//! * **draining** (alive, not placeable) — a graceful leave or a
//!   consolidation target: keeps serving its current copies and may *source*
//!   weight migrations, but no new copy is placed on it. The repair replan
//!   moves its copies off over the normal staged-migration path.
//! * **dead** (not alive) — a hard failure: its copies are gone. Survivor
//!   replicas are promoted immediately ([`ClusterEvent::GpuFailed`] →
//!   [`crate::replication::ReplicatedDeployment::evacuate_gpu`]), it is
//!   banned as a migration *source* ([`super::plan_migration_avoiding`]) and
//!   as a placement target, and the serving loop asserts it receives zero
//!   tokens ([`crate::sim::dead_gpu_tokens`]).
//!
//! Beyond binary membership, GPUs also fail *gray*: thermal throttling, ECC
//! retries, and flaky NICs degrade effective compute or bandwidth without
//! killing anything. [`ClusterEvent::GpuDegraded`], [`ClusterEvent::LinkDegraded`],
//! and [`ClusterEvent::GpuRecovered`] carry that truth; [`DegradeState`]
//! replays them into the per-GPU [`GpuScales`] the simulator serves on. The
//! coordinator is **never** handed these scales — it must infer them from
//! observed timelines ([`crate::obs::degrade`]).
//!
//! [`failure_schedule`] and [`degradation_schedule`] generate randomized,
//! deterministic event sequences (always-survivable for membership) for
//! property tests and the `eval resilience` / `eval straggler` figures; both
//! ride the same seeded [`event_stream`] builder.

use crate::cluster::GpuScales;
use crate::util::Rng;

/// One cluster change, applied at the start of a serving window: a binary
/// membership transition or a gray (effective-rate) degradation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClusterEvent {
    /// Hard failure: the GPU and every expert copy on it are gone.
    GpuFailed(usize),
    /// The GPU is (back) in service and placeable.
    GpuJoined(usize),
    /// Graceful leave: stop placing on the GPU and migrate its copies off;
    /// it keeps serving (and may source migrations) until vacated.
    GpuDrained(usize),
    /// Gray failure: the GPU keeps serving but its effective compute and
    /// port bandwidth drop to the given fractions of nominal (set, not
    /// multiplied — the event carries the new truth).
    GpuDegraded {
        /// The degraded GPU.
        gpu: usize,
        /// Effective compute as a fraction of nominal, in `(0, 1]`.
        compute_scale: f64,
        /// Effective port bandwidth as a fraction of nominal, in `(0, 1]`.
        bandwidth_scale: f64,
    },
    /// Gray link failure: the GPU's port degrades directionally; compute is
    /// untouched. [`GpuSpec`](crate::cluster::GpuSpec) models one full-duplex
    /// port rate, so [`DegradeState`] folds this to the *tighter* direction.
    LinkDegraded {
        /// The GPU whose port degrades.
        gpu: usize,
        /// Uplink (tx) rate as a fraction of nominal, in `(0, 1]`.
        up_scale: f64,
        /// Downlink (rx) rate as a fraction of nominal, in `(0, 1]`.
        down_scale: f64,
    },
    /// The gray failure cleared: the GPU is back at nominal rates.
    GpuRecovered(usize),
}

impl ClusterEvent {
    /// The GPU the event concerns.
    pub fn gpu(&self) -> usize {
        match *self {
            ClusterEvent::GpuFailed(g)
            | ClusterEvent::GpuJoined(g)
            | ClusterEvent::GpuDrained(g)
            | ClusterEvent::GpuRecovered(g) => g,
            ClusterEvent::GpuDegraded { gpu, .. } | ClusterEvent::LinkDegraded { gpu, .. } => gpu,
        }
    }

    /// Event name (decision-log / CLI vocabulary).
    pub fn name(&self) -> &'static str {
        match self {
            ClusterEvent::GpuFailed(_) => "gpu_failed",
            ClusterEvent::GpuJoined(_) => "gpu_joined",
            ClusterEvent::GpuDrained(_) => "gpu_drained",
            ClusterEvent::GpuDegraded { .. } => "gpu_degraded",
            ClusterEvent::LinkDegraded { .. } => "link_degraded",
            ClusterEvent::GpuRecovered(_) => "gpu_recovered",
        }
    }

    /// True for the gray-failure vocabulary (degrade/recover): events that
    /// change effective rates but never membership. [`ClusterHealth`] ignores
    /// them; [`DegradeState`] is their state machine.
    pub fn is_degradation(&self) -> bool {
        matches!(
            self,
            ClusterEvent::GpuDegraded { .. }
                | ClusterEvent::LinkDegraded { .. }
                | ClusterEvent::GpuRecovered(_)
        )
    }
}

/// Liveness/placeability mask over the cluster's GPU ids, updated by
/// [`ClusterHealth::apply`]. Starts all-healthy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterHealth {
    alive: Vec<bool>,
    draining: Vec<bool>,
}

impl ClusterHealth {
    /// All `n_gpus` GPUs alive and placeable.
    pub fn new(n_gpus: usize) -> ClusterHealth {
        assert!(n_gpus > 0, "a cluster has at least one GPU");
        ClusterHealth {
            alive: vec![true; n_gpus],
            draining: vec![false; n_gpus],
        }
    }

    /// Cluster size the mask covers.
    pub fn n_gpus(&self) -> usize {
        self.alive.len()
    }

    /// True unless GPU `g` has failed.
    pub fn is_alive(&self, g: usize) -> bool {
        self.alive[g]
    }

    /// True when GPU `g` is alive but being vacated.
    pub fn is_draining(&self, g: usize) -> bool {
        self.draining[g]
    }

    /// True when new expert copies may be placed on GPU `g`.
    pub fn is_placeable(&self, g: usize) -> bool {
        self.alive[g] && !self.draining[g]
    }

    /// True when every GPU is placeable (the healthy fast path: planning
    /// needs no sub-cluster compaction).
    pub fn all_placeable(&self) -> bool {
        (0..self.n_gpus()).all(|g| self.is_placeable(g))
    }

    /// Per-GPU liveness, indexable by GPU id.
    pub fn alive(&self) -> &[bool] {
        &self.alive
    }

    /// Per-GPU placeability, indexable by GPU id.
    pub fn placeable(&self) -> Vec<bool> {
        (0..self.n_gpus()).map(|g| self.is_placeable(g)).collect()
    }

    /// Ids of the placeable GPUs, ascending.
    pub fn placeable_gpus(&self) -> Vec<usize> {
        (0..self.n_gpus()).filter(|&g| self.is_placeable(g)).collect()
    }

    /// Number of placeable GPUs.
    pub fn n_placeable(&self) -> usize {
        self.placeable_gpus().len()
    }

    /// Per-GPU mask of GPUs that must never *source* a migration (the dead
    /// ones — a draining GPU still holds its weights and may send them).
    pub fn banned_sources(&self) -> Vec<bool> {
        self.alive.iter().map(|&a| !a).collect()
    }

    /// Apply one membership event. Idempotent: re-failing a dead GPU or
    /// re-joining a placeable one is a no-op. Gray-failure events
    /// ([`ClusterEvent::is_degradation`]) never change membership and are
    /// no-ops here — [`DegradeState`] tracks those.
    pub fn apply(&mut self, ev: &ClusterEvent) {
        let g = ev.gpu();
        assert!(g < self.n_gpus(), "event names GPU {g} of {}", self.n_gpus());
        match ev {
            ClusterEvent::GpuFailed(_) => {
                self.alive[g] = false;
                self.draining[g] = false;
            }
            ClusterEvent::GpuJoined(_) => {
                self.alive[g] = true;
                self.draining[g] = false;
            }
            ClusterEvent::GpuDrained(_) => {
                self.draining[g] = true;
            }
            ClusterEvent::GpuDegraded { .. }
            | ClusterEvent::LinkDegraded { .. }
            | ClusterEvent::GpuRecovered(_) => {}
        }
    }
}

/// Ground-truth tracker for gray failures: replays [`ClusterEvent`]s into
/// the per-GPU [`GpuScales`] the *simulator* serves windows on. Events carry
/// set semantics — a second [`ClusterEvent::GpuDegraded`] on the same GPU
/// replaces its scales rather than compounding them. Membership transitions
/// ([`ClusterEvent::GpuFailed`] / [`ClusterEvent::GpuJoined`]) reset the GPU
/// to nominal: a replaced GPU comes back clean.
///
/// This struct is the injection harness's truth, **not** the coordinator's
/// input — the coordinator only sees what the
/// [`crate::obs::degrade::DegradationDetector`] infers from timelines.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradeState {
    scales: GpuScales,
}

impl DegradeState {
    /// All `n_gpus` GPUs at nominal rates.
    pub fn new(n_gpus: usize) -> DegradeState {
        DegradeState {
            scales: GpuScales::nominal(n_gpus),
        }
    }

    /// Cluster size the state covers.
    pub fn n_gpus(&self) -> usize {
        self.scales.n_gpus()
    }

    /// The current true effective-rate scales.
    pub fn scales(&self) -> &GpuScales {
        &self.scales
    }

    /// True when every GPU is at nominal rates.
    pub fn is_nominal(&self) -> bool {
        self.scales.is_nominal()
    }

    /// True when GPU `g` is currently degraded (compute or bandwidth below
    /// nominal).
    pub fn is_degraded(&self, g: usize) -> bool {
        self.scales.compute[g] < 1.0 || self.scales.bandwidth[g] < 1.0
    }

    /// Replay one event into the truth.
    pub fn apply(&mut self, ev: &ClusterEvent) {
        match *ev {
            ClusterEvent::GpuDegraded {
                gpu,
                compute_scale,
                bandwidth_scale,
            } => self.scales.set(gpu, compute_scale, bandwidth_scale),
            ClusterEvent::LinkDegraded {
                gpu,
                up_scale,
                down_scale,
            } => {
                // One full-duplex port rate per GPU, so a directional event
                // folds to the tighter direction; compute stays as-is.
                let compute = self.scales.compute[gpu];
                self.scales.set(gpu, compute, up_scale.min(down_scale));
            }
            ClusterEvent::GpuRecovered(g)
            | ClusterEvent::GpuFailed(g)
            | ClusterEvent::GpuJoined(g) => self.scales.clear(g),
            ClusterEvent::GpuDrained(_) => {}
        }
    }
}

/// Shared seeded builder behind [`failure_schedule`] and
/// [`degradation_schedule`]: draw `n_events` event windows, sort them
/// ascending, then at each window ask `candidates` what the replayed `state`
/// allows, pick one uniformly (skipping windows with an empty candidate
/// set), and `apply` the pick before the next window. Exactly one
/// `gen_range` per placed event keeps schedules deterministic in the
/// caller-salted `rng`.
fn event_stream<S>(
    windows: usize,
    n_events: usize,
    rng: &mut Rng,
    state: &mut S,
    mut candidates: impl FnMut(&S, &mut Rng) -> Vec<ClusterEvent>,
    mut apply: impl FnMut(&mut S, &ClusterEvent),
) -> Vec<(usize, ClusterEvent)> {
    assert!(windows > 0);
    let mut ws: Vec<usize> = (0..n_events)
        .map(|_| rng.gen_range(windows as u64) as usize)
        .collect();
    ws.sort_unstable();
    let mut out = Vec::with_capacity(n_events);
    for w in ws {
        let cands = candidates(state, rng);
        if cands.is_empty() {
            continue;
        }
        let ev = cands[rng.gen_range(cands.len() as u64) as usize];
        apply(state, &ev);
        out.push((w, ev));
    }
    out
}

/// The ≥2-placeable-survivors guarantee, shared by every membership
/// schedule: fail/drain candidates only for placeable GPUs and only while
/// **more than two** are placeable (so at least two survive any pick); join
/// candidates only for non-placeable GPUs.
fn survivable_membership_candidates(health: &ClusterHealth) -> Vec<ClusterEvent> {
    let mut cands: Vec<ClusterEvent> = Vec::new();
    for g in 0..health.n_gpus() {
        if health.is_placeable(g) {
            if health.n_placeable() > 2 {
                cands.push(ClusterEvent::GpuFailed(g));
                cands.push(ClusterEvent::GpuDrained(g));
            }
        } else {
            cands.push(ClusterEvent::GpuJoined(g));
        }
    }
    cands
}

/// A randomized, always-survivable membership-event schedule: `n_events`
/// fail/drain/join events at ascending windows in `0..windows`, constrained
/// (against a health mask replayed in order) so at least two GPUs stay
/// placeable at every point and every event is meaningful — only placeable
/// GPUs fail or drain, only non-placeable ones join. Deterministic in
/// `seed`; the property suite drives the coordinator with these.
pub fn failure_schedule(
    n_gpus: usize,
    windows: usize,
    n_events: usize,
    seed: u64,
) -> Vec<(usize, ClusterEvent)> {
    assert!(n_gpus >= 3, "need headroom to fail a GPU and keep two placeable");
    let mut rng = Rng::new(seed ^ 0xFA11_5AFE);
    let mut health = ClusterHealth::new(n_gpus);
    event_stream(
        windows,
        n_events,
        &mut rng,
        &mut health,
        |h, _| survivable_membership_candidates(h),
        |h, ev| h.apply(ev),
    )
}

/// A randomized gray-failure schedule alongside [`failure_schedule`]:
/// `n_events` degrade/recover events at ascending windows in `0..windows`,
/// constrained (against a [`DegradeState`] replayed in order) so only
/// nominal GPUs degrade and only degraded ones recover. Compute stragglers
/// ([`ClusterEvent::GpuDegraded`]) and slow ports
/// ([`ClusterEvent::LinkDegraded`]) are offered equally, with a severity
/// drawn uniformly from `[0.35, 0.9)` per event window. Deterministic in
/// `seed`; never touches membership, so it interleaves safely with
/// [`failure_schedule`] output.
pub fn degradation_schedule(
    n_gpus: usize,
    windows: usize,
    n_events: usize,
    seed: u64,
) -> Vec<(usize, ClusterEvent)> {
    assert!(n_gpus >= 1);
    let mut rng = Rng::new(seed ^ 0xDE64_4ADE);
    let mut state = DegradeState::new(n_gpus);
    event_stream(
        windows,
        n_events,
        &mut rng,
        &mut state,
        |st, rng| {
            let severity = 0.35 + rng.gen_f64() * 0.55;
            let mut cands: Vec<ClusterEvent> = Vec::new();
            for g in 0..st.n_gpus() {
                if st.is_degraded(g) {
                    cands.push(ClusterEvent::GpuRecovered(g));
                } else {
                    cands.push(ClusterEvent::GpuDegraded {
                        gpu: g,
                        compute_scale: severity,
                        bandwidth_scale: 1.0,
                    });
                    cands.push(ClusterEvent::LinkDegraded {
                        gpu: g,
                        up_scale: severity,
                        down_scale: 1.0,
                    });
                }
            }
            cands
        },
        |st, ev| st.apply(ev),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn health_state_machine() {
        let mut h = ClusterHealth::new(4);
        assert!(h.all_placeable());
        h.apply(&ClusterEvent::GpuDrained(1));
        assert!(h.is_alive(1) && !h.is_placeable(1));
        assert!(!h.banned_sources()[1], "draining GPUs still source");
        h.apply(&ClusterEvent::GpuFailed(2));
        assert!(!h.is_alive(2) && h.banned_sources()[2]);
        assert_eq!(h.placeable_gpus(), vec![0, 3]);
        h.apply(&ClusterEvent::GpuJoined(1));
        h.apply(&ClusterEvent::GpuJoined(2));
        assert!(h.all_placeable());
        // idempotence
        h.apply(&ClusterEvent::GpuJoined(2));
        assert!(h.all_placeable());
    }

    #[test]
    fn health_ignores_gray_failures() {
        let mut h = ClusterHealth::new(3);
        h.apply(&ClusterEvent::GpuDegraded {
            gpu: 1,
            compute_scale: 0.4,
            bandwidth_scale: 0.7,
        });
        h.apply(&ClusterEvent::LinkDegraded {
            gpu: 2,
            up_scale: 0.5,
            down_scale: 1.0,
        });
        h.apply(&ClusterEvent::GpuRecovered(1));
        assert!(h.all_placeable(), "degradation never changes membership");
    }

    #[test]
    fn degrade_state_tracks_truth_with_set_semantics() {
        let mut d = DegradeState::new(4);
        assert!(d.is_nominal());
        d.apply(&ClusterEvent::GpuDegraded {
            gpu: 2,
            compute_scale: 0.4,
            bandwidth_scale: 0.8,
        });
        assert!(d.is_degraded(2) && !d.is_degraded(1));
        assert_eq!((d.scales().compute[2], d.scales().bandwidth[2]), (0.4, 0.8));
        // set, not multiply: a second event replaces the truth
        d.apply(&ClusterEvent::GpuDegraded {
            gpu: 2,
            compute_scale: 0.6,
            bandwidth_scale: 1.0,
        });
        assert_eq!((d.scales().compute[2], d.scales().bandwidth[2]), (0.6, 1.0));
        // link degradation folds to the tighter direction, keeps compute
        d.apply(&ClusterEvent::LinkDegraded {
            gpu: 2,
            up_scale: 0.9,
            down_scale: 0.5,
        });
        assert_eq!((d.scales().compute[2], d.scales().bandwidth[2]), (0.6, 0.5));
        // recovery and membership transitions reset to nominal
        d.apply(&ClusterEvent::GpuRecovered(2));
        assert!(d.is_nominal());
        d.apply(&ClusterEvent::LinkDegraded {
            gpu: 0,
            up_scale: 0.3,
            down_scale: 1.0,
        });
        d.apply(&ClusterEvent::GpuFailed(0));
        assert!(d.is_nominal(), "a replaced GPU comes back clean");
    }

    #[test]
    fn degradation_schedule_is_valid_and_deterministic() {
        for seed in 0..20 {
            let evs = degradation_schedule(5, 12, 8, seed);
            assert_eq!(evs, degradation_schedule(5, 12, 8, seed));
            let mut d = DegradeState::new(5);
            let mut last_w = 0;
            for (w, ev) in &evs {
                assert!(*w >= last_w, "windows ascend");
                last_w = *w;
                assert!(ev.is_degradation(), "only gray-failure events");
                match *ev {
                    ClusterEvent::GpuDegraded {
                        gpu,
                        compute_scale,
                        bandwidth_scale,
                    } => {
                        assert!(!d.is_degraded(gpu));
                        assert!(compute_scale > 0.0 && compute_scale <= 1.0);
                        assert!(bandwidth_scale > 0.0 && bandwidth_scale <= 1.0);
                    }
                    ClusterEvent::LinkDegraded {
                        gpu,
                        up_scale,
                        down_scale,
                    } => {
                        assert!(!d.is_degraded(gpu));
                        assert!(up_scale > 0.0 && up_scale <= 1.0);
                        assert!(down_scale > 0.0 && down_scale <= 1.0);
                    }
                    ClusterEvent::GpuRecovered(g) => assert!(d.is_degraded(g)),
                    _ => unreachable!(),
                }
                d.apply(ev);
                for g in 0..5 {
                    assert!(d.scales().compute[g] > 0.0 && d.scales().compute[g] <= 1.0);
                    assert!(d.scales().bandwidth[g] > 0.0 && d.scales().bandwidth[g] <= 1.0);
                }
            }
        }
    }

    #[test]
    fn failure_schedule_is_survivable_and_deterministic() {
        for seed in 0..20 {
            let evs = failure_schedule(5, 12, 8, seed);
            assert_eq!(evs, failure_schedule(5, 12, 8, seed));
            let mut h = ClusterHealth::new(5);
            let mut last_w = 0;
            for (w, ev) in &evs {
                assert!(*w >= last_w, "windows ascend");
                last_w = *w;
                match ev {
                    ClusterEvent::GpuFailed(g) | ClusterEvent::GpuDrained(g) => {
                        assert!(h.is_placeable(*g))
                    }
                    ClusterEvent::GpuJoined(g) => assert!(!h.is_placeable(*g)),
                }
                h.apply(ev);
                assert!(h.n_placeable() >= 2, "never below two placeable GPUs");
            }
        }
    }
}
