//! Drifting-Zipf discrete-event serving simulation: static plan vs periodic
//! replanning vs the cost-aware coordinator vs a zero-cost oracle.
//!
//! The workload is a stream of serving windows whose expert popularity is
//! Zipf(α) with the hot expert **rotating** every `rotate_every` windows
//! ([`crate::traffic::drifting_zipf_traffic`]; optionally multinomial-sampled
//! per window, [`crate::traffic::sampled_zipf_traffic`], so consecutive
//! windows of one regime fluctuate like live batches). Each window is served
//! by [`crate::sim::simulate_window`] under the strategy's active plan, with
//! any staged migration traffic charged to the same links. Four strategies
//! share the identical initial plan (optimized for phase 0):
//!
//! * **static** — never replans; decays as the hot expert moves away from
//!   its replicas;
//! * **periodic** — replans on every window's raw observation, paying the
//!   migration for every plan diff (no smoothing, no hysteresis — the naive
//!   baseline the coordinator's gates exist to beat);
//! * **coordinator** — the full [`super::Coordinator`] pipeline;
//! * **oracle** — replans each window on that window's true traffic at zero
//!   migration cost: the (unrealizable) lower bound.
//!
//! [`OnlineConfig::events`] injects cluster-membership changes
//! ([`ClusterEvent`]) at window starts. Every strategy promotes around
//! failures before serving (no token ever routes to a dead GPU —
//! [`crate::sim::dead_gpu_tokens`] is asserted zero on every served
//! window); the coordinator additionally runs its cost-aware
//! promote-then-repair pipeline, and the masked oracle becomes the
//! fresh-plan-after-failure baseline the `eval resilience` figure measures
//! recovery against.
//!
//! **Gray failures**: degradation events ([`ClusterEvent::GpuDegraded`] /
//! `LinkDegraded` / `GpuRecovered`) update a [`DegradeState`] *truth* the
//! simulator serves every window on — every strategy's windows actually
//! slow down behind the straggler. The truth is **never** handed to the
//! coordinator: with [`OnlineConfig::degrade_detection`] set it must infer
//! the scales through a [`DegradationDetector`] fed observed-vs-predicted
//! window timelines (optionally jittered by [`OnlineConfig::obs_noise`]).
//! The oracle is *oracle-informed* — it replans each window on the true
//! effective cluster — so `eval straggler` can measure the detection lag as
//! detector-driven vs oracle-informed recovery.

use super::{
    plan_candidate_masked, plan_migration_avoiding, ClusterEvent, ClusterHealth, Coordinator,
    CoordinatorConfig, DegradeState, PlanSwap, SwapPhase,
};
use crate::cluster::{Cluster, GpuScales, Topology};
use crate::config::EvalConfig;
use crate::obs::degrade::{DegradationDetector, DegradeConfig, WindowObservation};
use crate::obs::timeline::TimelineRecorder;
use crate::obs::{MetricsRegistry, Tracer};
use crate::planner::Planner;
use crate::replication::{optimize_splits, ReplicatedDeployment, SplitPlan};
use crate::serve::metrics::p50_p95_p99;
use crate::sim::{
    dead_gpu_tokens, simulate_window_topology_recorded, MoeLayerStats, SimResult,
};
use crate::trace::ModelTrace;
use crate::traffic::{
    drifting_zipf_traffic, multiplicative_noise, sampled_zipf_traffic, TrafficMatrix,
};

/// Compute constants of the simulated model (the LIMoE reference-GPU
/// profile, as in `eval::replication`).
const GATE_MS: f64 = 0.02;
const FFN_MS_PER_TOKEN: f64 = 0.001;
const AGG_MS: f64 = 0.015;

/// Which serving strategy drives the plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OnlineStrategy {
    /// Keep the initial plan forever.
    Static,
    /// Replan on every window's observation, paying every migration.
    EveryWindow,
    /// The cost-aware coordinator.
    Coordinator,
    /// Per-window replan with perfect knowledge and free migration.
    Oracle,
}

impl OnlineStrategy {
    /// Display name (CLI/eval row label).
    pub fn name(&self) -> &'static str {
        match self {
            OnlineStrategy::Static => "static",
            OnlineStrategy::EveryWindow => "periodic",
            OnlineStrategy::Coordinator => "coordinator",
            OnlineStrategy::Oracle => "oracle",
        }
    }
}

/// Workload and policy knobs of the online simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct OnlineConfig {
    /// Cluster size (the cluster passed to [`run_online`] must match).
    pub n_gpus: usize,
    /// Experts of the served model.
    pub n_experts: usize,
    /// Tokens each sender originates per window.
    pub tokens_per_sender: u64,
    /// Zipf skew of the rotating popularity (0 = stationary uniform).
    pub alpha: f64,
    /// Number of serving windows.
    pub windows: usize,
    /// Windows between hot-expert rotations.
    pub rotate_every: usize,
    /// Workload seed.
    pub seed: u64,
    /// Sample each window multinomially instead of the exact shape.
    pub sampled: bool,
    /// Cluster-membership events, injected at the **start** of the named
    /// window (before it is served). Every strategy honors them: failures
    /// are promoted immediately (no window ever routes a token to a dead
    /// GPU — [`crate::sim::dead_gpu_tokens`] is asserted zero); the
    /// coordinator additionally runs its promote-then-repair pipeline,
    /// while static only promotes and periodic/oracle fold the mask into
    /// their per-window replans.
    pub events: Vec<(usize, ClusterEvent)>,
    /// Enable the coordinator's elasticity policy
    /// ([`CoordinatorConfig::elastic`]) and feed it per-window utilization.
    pub elastic: bool,
    /// Run the coordinator's gray-failure loop: record each served window's
    /// timeline, ratio it against a nominal re-simulation, and feed the
    /// [`DegradationDetector`] — the coordinator learns about stragglers only
    /// through what it can measure, never from the injected truth.
    pub degrade_detection: bool,
    /// Relative amplitude of deterministic multiplicative jitter applied to
    /// every detector ratio (`0.05` = ±5%), exercising the hysteresis bands.
    /// Zero (the default) feeds the detector exact ratios.
    pub obs_noise: f64,
    /// Detector tuning (smoothing, hysteresis bands, confirmation count).
    pub degrade: DegradeConfig,
    /// Coordinator policy knobs (also supplies the replication budgets and
    /// the expert weight volume every strategy's migrations use).
    pub coordinator: CoordinatorConfig,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        Self {
            n_gpus: 8,
            n_experts: 16,
            // Long enough windows that a replan's one-window staging cost
            // amortizes against the per-window decay it removes.
            tokens_per_sender: 1024,
            alpha: 1.2,
            windows: 32,
            rotate_every: 8,
            seed: 2024,
            sampled: false,
            events: Vec::new(),
            elastic: false,
            degrade_detection: false,
            obs_noise: 0.0,
            degrade: DegradeConfig::default(),
            coordinator: CoordinatorConfig::default(),
        }
    }
}

impl OnlineConfig {
    /// The canonical workload shape for an [`EvalConfig`]: its homogeneous
    /// cluster serving one `2 × n_experts`-expert model at
    /// `batch_images × 16` tokens per sender. The `online` eval figure and
    /// the `serve-sim` CLI both derive their configs here, so the two
    /// surfaces can never drift apart.
    pub fn from_eval(
        cfg: &EvalConfig,
        alpha: f64,
        windows: usize,
        rotate_every: usize,
        sampled: bool,
    ) -> OnlineConfig {
        OnlineConfig {
            n_gpus: cfg.n_experts,
            n_experts: cfg.n_experts * 2,
            tokens_per_sender: cfg.batch_images * 16,
            alpha,
            windows,
            rotate_every,
            seed: cfg.seed,
            sampled,
            events: Vec::new(),
            elastic: false,
            degrade_detection: false,
            obs_noise: 0.0,
            degrade: DegradeConfig::default(),
            coordinator: CoordinatorConfig::default(),
        }
    }
}

/// End-to-end result of one strategy over the window stream.
#[derive(Debug, Clone)]
pub struct OnlineOutcome {
    /// Strategy name.
    pub strategy: &'static str,
    /// Total simulated serving time (ms), migration contention included.
    pub total_ms: f64,
    /// Per-window serving times (ms).
    pub per_window_ms: Vec<f64>,
    /// Replans committed (migrations started; oracle counts plan changes).
    pub replans: u64,
    /// Atomic swaps completed.
    pub swaps: u64,
    /// Total staged-migration makespan (ms).
    pub migration_ms: f64,
    /// Median window serving time (ms).
    pub p50_ms: f64,
    /// 95th-percentile window serving time (ms).
    pub p95_ms: f64,
    /// 99th-percentile window serving time (ms).
    pub p99_ms: f64,
}

fn outcome(
    strategy: OnlineStrategy,
    per_window_ms: Vec<f64>,
    replans: u64,
    swaps: u64,
    migration_ms: f64,
) -> OnlineOutcome {
    let total_ms = per_window_ms.iter().sum();
    let (p50_ms, p95_ms, p99_ms) = p50_p95_p99(&per_window_ms).unwrap_or((0.0, 0.0, 0.0));
    OnlineOutcome {
        strategy: strategy.name(),
        total_ms,
        per_window_ms,
        replans,
        swaps,
        migration_ms,
        p50_ms,
        p95_ms,
        p99_ms,
    }
}

fn window_traffic(cfg: &OnlineConfig, w: usize) -> TrafficMatrix {
    let phase = w / cfg.rotate_every.max(1);
    if cfg.sampled {
        let draw_seed = cfg.seed ^ (w as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        sampled_zipf_traffic(
            cfg.n_experts,
            cfg.tokens_per_sender,
            cfg.alpha,
            cfg.seed,
            phase,
            draw_seed,
        )
    } else {
        drifting_zipf_traffic(cfg.n_experts, cfg.tokens_per_sender, cfg.alpha, cfg.seed, phase)
    }
}

fn layer(traffic: TrafficMatrix) -> MoeLayerStats {
    MoeLayerStats {
        traffic,
        gate_ms: GATE_MS,
        ffn_ms_per_token: FFN_MS_PER_TOKEN,
        agg_ms: AGG_MS,
    }
}

fn trace_of(stats: MoeLayerStats) -> ModelTrace {
    ModelTrace {
        name: "online-window".to_string(),
        layers: vec![stats],
    }
}

/// The events of `cfg` landing at window `w`, in declaration order.
fn events_at<'a>(cfg: &'a OnlineConfig, w: usize) -> impl Iterator<Item = &'a ClusterEvent> {
    cfg.events.iter().filter(move |(ew, _)| *ew == w).map(|(_, ev)| ev)
}

/// Apply one membership event to a non-coordinator strategy's state:
/// failures are promoted immediately (evacuate the dead GPU's copies and
/// re-solve split weights on `split_layer` — the zero-downtime minimum
/// every strategy owes the workload); joins and drains only update the
/// mask, which the strategy's next replan (if any) folds in.
fn apply_event(
    ev: &ClusterEvent,
    health: &mut ClusterHealth,
    active: &mut (ReplicatedDeployment, SplitPlan),
    split_layer: &MoeLayerStats,
    cluster: &Cluster,
) {
    match ev {
        ClusterEvent::GpuFailed(g) => {
            if !health.is_alive(*g) {
                return;
            }
            health.apply(ev);
            let (rep, _, _) = active.0.evacuate_gpu(*g, &health.placeable());
            let splits = optimize_splits(&rep, &[split_layer], cluster);
            *active = (rep, splits);
        }
        ClusterEvent::GpuJoined(_) | ClusterEvent::GpuDrained(_) => health.apply(ev),
        // Gray failures never change membership; the caller tracks them in
        // its truth `DegradeState` and the strategies stay scale-blind.
        ClusterEvent::GpuDegraded { .. }
        | ClusterEvent::LinkDegraded { .. }
        | ClusterEvent::GpuRecovered(_) => {}
    }
}

/// The simulator-facing view of the truth: `None` while the cluster runs at
/// nominal rates (bit-for-bit the pre-degradation fast path).
fn truth_scales(truth: &DegradeState) -> Option<&GpuScales> {
    if truth.is_nominal() {
        None
    } else {
        Some(truth.scales())
    }
}

/// Serve one window under `(rep, splits)` with optional staged weight
/// traffic sharing the links (both priced on `topo`) and the ground-truth
/// degradation `scales` throttling the affected GPUs' engines and ports.
/// Asserts the projected GPU traffic routes **zero** tokens through dead
/// GPUs — the fault path's safety contract. With a live `metrics` registry
/// it records the window's serving time, mean utilization, queue depth
/// (tokens offered to the window), and the per-GPU token-load series; with
/// an enabled `rec` it captures the window's observed timeline for the
/// degradation detector.
#[allow(clippy::too_many_arguments)]
fn serve_window(
    rep: &ReplicatedDeployment,
    splits: &SplitPlan,
    stats: &MoeLayerStats,
    background: Option<&TrafficMatrix>,
    cluster: &Cluster,
    scales: Option<&GpuScales>,
    topo: &Topology,
    health: &ClusterHealth,
    metrics: &MetricsRegistry,
    rec: &mut TimelineRecorder,
) -> SimResult {
    let gpu_stats = rep.project_layer_split(0, stats, splits);
    assert_eq!(
        dead_gpu_tokens(&gpu_stats.traffic, health.alive()),
        0,
        "window routed tokens through a dead GPU"
    );
    let res = simulate_window_topology_recorded(
        &[&gpu_stats],
        background,
        cluster,
        scales,
        topo,
        rep.base.policy,
        rec,
    );
    if metrics.is_enabled() {
        metrics.counter_add("serve.windows", 1);
        metrics.hist_record("serve.window_ms", res.inference_ms);
        metrics.hist_record("serve.window_util_pct", res.utilization * 100.0);
        metrics.hist_record("serve.window_queue_tokens", stats.traffic.total() as f64);
        for i in 0..cluster.len() {
            metrics.hist_record(
                "serve.gpu_window_tokens",
                gpu_stats.traffic.row_sum(i) as f64,
            );
        }
        metrics.gauge_set("serve.last_window_ms", res.inference_ms);
    }
    res
}

/// Run the drifting-Zipf serving simulation for one strategy. Every
/// strategy starts from the identical plan, optimized (with replication)
/// for the exact phase-0 traffic. Deterministic for a fixed config.
pub fn run_online(
    cfg: &OnlineConfig,
    cluster: &Cluster,
    strategy: OnlineStrategy,
) -> OnlineOutcome {
    run_online_traced(
        cfg,
        cluster,
        strategy,
        &Tracer::disabled(),
        &MetricsRegistry::disabled(),
    )
}

/// [`run_online`] under a tracer and a metrics registry.
///
/// The tracer should be a **sim-time** tracer ([`Tracer::sim`]): the
/// simulation advances the tracer clock by each window's simulated serving
/// time, so every span and decision record is stamped in simulated
/// milliseconds — two runs of the same config produce byte-identical
/// exports, making traces diffable across code changes. Each window is one
/// `serve.window` span; under the coordinator strategy the replan gate's
/// `coordinator.replan_gate` decisions and the candidate planner's spans
/// nest within it. Instrumentation is purely observational: outcomes are
/// bit-for-bit identical with tracing on or off.
pub fn run_online_traced(
    cfg: &OnlineConfig,
    cluster: &Cluster,
    strategy: OnlineStrategy,
    tr: &Tracer,
    metrics: &MetricsRegistry,
) -> OnlineOutcome {
    assert_eq!(cluster.len(), cfg.n_gpus, "cluster size mismatch");
    assert!(cfg.windows > 0, "simulate at least one window");
    if let Err(e) = cfg.coordinator.topology.owners(cluster.len()) {
        panic!("OnlineConfig.coordinator.topology does not fit the cluster: {e}");
    }
    for (w, ev) in &cfg.events {
        assert!(*w < cfg.windows, "event at window {w} is beyond the horizon");
        assert!(
            ev.gpu() < cfg.n_gpus,
            "event names GPU {} of {}",
            ev.gpu(),
            cfg.n_gpus
        );
    }

    let planner = Planner::default();
    let plan_layer = layer(drifting_zipf_traffic(
        cfg.n_experts,
        cfg.tokens_per_sender,
        cfg.alpha,
        cfg.seed,
        0,
    ));
    let plan_trace = trace_of(plan_layer.clone());
    let (rep0, splits0) = planner
        .plan_replicated_topology(
            &[&plan_trace],
            cluster,
            &cfg.coordinator.topology,
            &cfg.coordinator.replication,
        )
        .expect("one model always plans");

    // Simulated serving clock: cumulative window serving time, driven into
    // the tracer so spans carry sim-time (deterministic, diffable) stamps.
    let mut elapsed_ms = 0.0f64;

    match strategy {
        OnlineStrategy::Static => {
            let mut health = ClusterHealth::new(cfg.n_gpus);
            let mut truth = DegradeState::new(cfg.n_gpus);
            let mut active = (rep0, splits0);
            let mut per_window = Vec::with_capacity(cfg.windows);
            for w in 0..cfg.windows {
                tr.set_sim_time_us((elapsed_ms * 1e3).round() as u64);
                let sp = tr.begin("serve.window");
                tr.counter(sp, "window", w as i64);
                // "never replans" still owes the workload survival: promote
                // around failures (splits re-solved on the plan-time stats,
                // the only traffic a static strategy knows)
                for ev in events_at(cfg, w) {
                    truth.apply(ev);
                    apply_event(ev, &mut health, &mut active, &plan_layer, cluster);
                }
                let stats = layer(window_traffic(cfg, w));
                let res = serve_window(
                    &active.0,
                    &active.1,
                    &stats,
                    None,
                    cluster,
                    truth_scales(&truth),
                    &cfg.coordinator.topology,
                    &health,
                    metrics,
                    &mut TimelineRecorder::disabled(),
                );
                per_window.push(res.inference_ms);
                elapsed_ms += res.inference_ms;
                tr.set_sim_time_us((elapsed_ms * 1e3).round() as u64);
                tr.end(sp);
            }
            outcome(strategy, per_window, 0, 0, 0.0)
        }
        OnlineStrategy::Coordinator => {
            let mut ccfg = cfg.coordinator.clone();
            if cfg.elastic {
                ccfg.elastic = true;
            }
            let mut coord = Coordinator::new(planner, rep0, splits0, &plan_layer, ccfg);
            coord.set_tracer(tr.clone());
            let mut truth = DegradeState::new(cfg.n_gpus);
            let mut detector = DegradationDetector::new(cfg.n_gpus, cfg.degrade.clone());
            let mut per_window = Vec::with_capacity(cfg.windows);
            for w in 0..cfg.windows {
                tr.set_sim_time_us((elapsed_ms * 1e3).round() as u64);
                let sp = tr.begin("serve.window");
                tr.counter(sp, "window", w as i64);
                // Membership events land before the window serves: a failed
                // GPU is promoted around in this very window (verdict
                // `repair_promoted`), the repair replan queues behind it.
                // Gray failures only move the truth — the coordinator is
                // never told, it has to *infer* them from window timelines.
                for ev in events_at(cfg, w) {
                    truth.apply(ev);
                    if !ev.is_degradation() {
                        coord.inject_event(ev, cluster);
                    }
                }
                let observed = window_traffic(cfg, w);
                let stats = layer(observed.clone());
                let background = coord.staging_traffic().cloned();
                let mut rec = if cfg.degrade_detection {
                    TimelineRecorder::new(cfg.n_gpus)
                } else {
                    TimelineRecorder::disabled()
                };
                let (rep, splits) = coord.active();
                let res = serve_window(
                    rep,
                    splits,
                    &stats,
                    background.as_ref(),
                    cluster,
                    truth_scales(&truth),
                    &cfg.coordinator.topology,
                    coord.health(),
                    metrics,
                    &mut rec,
                );
                // Detection input must be built against the plan that served
                // this window, before `advance` can swap it: re-simulate the
                // identical projected traffic (staging included) at nominal
                // rates and ratio observed vs predicted busy time per GPU.
                let degrade_obs = if cfg.degrade_detection {
                    let observed_tl = rec.take().expect("recorder was enabled");
                    let (rep, splits) = coord.active();
                    let gpu_stats = rep.project_layer_split(0, &stats, splits);
                    let mut pred = TimelineRecorder::new(cfg.n_gpus);
                    simulate_window_topology_recorded(
                        &[&gpu_stats],
                        background.as_ref(),
                        cluster,
                        None,
                        &cfg.coordinator.topology,
                        rep.base.policy,
                        &mut pred,
                    );
                    let predicted_tl = pred.take().expect("recorder was enabled");
                    let mut obs = WindowObservation::from_timelines(
                        &observed_tl,
                        &predicted_tl,
                        cfg.degrade.min_ms,
                    );
                    if cfg.obs_noise > 0.0 {
                        for g in 0..cfg.n_gpus {
                            obs.compute_ratio[g] *=
                                multiplicative_noise(cfg.seed, w, g, cfg.obs_noise);
                            obs.link_ratio[g] *= multiplicative_noise(
                                cfg.seed,
                                w,
                                cfg.n_gpus + g,
                                cfg.obs_noise,
                            );
                        }
                    }
                    Some(obs)
                } else {
                    None
                };
                let ms = res.inference_ms;
                per_window.push(ms);
                elapsed_ms += ms;
                // Advance the tracer clock before the replan gate runs so
                // its decision records are stamped at the window's end.
                tr.set_sim_time_us((elapsed_ms * 1e3).round() as u64);
                coord.advance(ms);
                // Detector transitions land before the gate: a confirmed
                // straggler queues its effective-rate replan (or escalates)
                // in the same `observe_window` call that follows.
                if let Some(obs) = degrade_obs {
                    let events = detector.observe(&obs);
                    coord.observe_degradation(&events, &detector.scales(), cluster);
                }
                // The window's serving latency feeds the SLO watchdog (a
                // no-op unless the config sets a target) before the gate
                // runs, so a p99 break replans on this very window; the
                // utilization feeds the consolidation signal.
                coord.record_window_latency(ms);
                coord.record_window_utilization(res.utilization);
                coord.observe_window(&observed, cluster);
                tr.end(sp);
            }
            if metrics.is_enabled() {
                metrics.counter_add("serve.slo_triggered", coord.stats.slo_triggered);
                metrics.counter_add("serve.slo_suppressed", coord.stats.slo_suppressed);
                metrics.counter_add("serve.failures", coord.stats.failures);
                metrics.counter_add("serve.promotions", coord.stats.promotions);
                metrics.counter_add("serve.repairs", coord.stats.repairs);
                metrics.counter_add("serve.scale_ups", coord.stats.scale_ups);
                metrics.counter_add("serve.consolidations", coord.stats.consolidations);
                metrics.counter_add("serve.degrade_detected", coord.stats.degrade_detected);
                metrics.counter_add("serve.degrade_replans", coord.stats.degrade_replans);
                metrics.counter_add("serve.degrade_recovered", coord.stats.degrade_recovered);
                metrics.counter_add("serve.escalations", coord.stats.escalations);
            }
            outcome(
                strategy,
                per_window,
                coord.stats.replans,
                coord.stats.swaps,
                coord.stats.migration_ms_total,
            )
        }
        OnlineStrategy::EveryWindow => {
            let mut health = ClusterHealth::new(cfg.n_gpus);
            let mut truth = DegradeState::new(cfg.n_gpus);
            let mut active = (rep0, splits0);
            let mut swap = PlanSwap::new(cfg.coordinator.drain_ms);
            let mut staging: Option<TrafficMatrix> = None;
            let mut per_window = Vec::with_capacity(cfg.windows);
            let mut replans = 0u64;
            let mut migration_total = 0.0f64;
            for w in 0..cfg.windows {
                tr.set_sim_time_us((elapsed_ms * 1e3).round() as u64);
                let sp = tr.begin("serve.window");
                tr.counter(sp, "window", w as i64);
                let observed = window_traffic(cfg, w);
                let stats = layer(observed.clone());
                // A failure invalidates whatever was staging (the dead GPU
                // may be in it) and is promoted around immediately, on this
                // window's own observation.
                for ev in events_at(cfg, w) {
                    truth.apply(ev);
                    if matches!(ev, ClusterEvent::GpuFailed(g) if health.is_alive(*g))
                        && swap.abort()
                    {
                        staging = None;
                    }
                    apply_event(ev, &mut health, &mut active, &stats, cluster);
                }
                let background = if swap.phase() == SwapPhase::Staging {
                    staging.clone()
                } else {
                    None
                };
                let res = serve_window(
                    &active.0,
                    &active.1,
                    &stats,
                    background.as_ref(),
                    cluster,
                    truth_scales(&truth),
                    &cfg.coordinator.topology,
                    &health,
                    metrics,
                    &mut TimelineRecorder::disabled(),
                );
                let ms = res.inference_ms;
                per_window.push(ms);
                elapsed_ms += ms;
                tr.set_sim_time_us((elapsed_ms * 1e3).round() as u64);
                if let Some(new_plan) = swap.advance(ms) {
                    active = new_plan;
                    staging = None;
                }
                if !swap.is_busy() {
                    // naive: replan on this window's raw observation, no
                    // smoothing, no gain or cost gate (but health-masked —
                    // the naive baseline does not place on lost GPUs either)
                    let trace = trace_of(stats);
                    let (cand_rep, cand_splits) = plan_candidate_masked(
                        &Planner::default(),
                        &trace,
                        cluster,
                        &cfg.coordinator.topology,
                        &cfg.coordinator.replication,
                        &health,
                        tr,
                    );
                    let migration = plan_migration_avoiding(
                        &active.0,
                        &cand_rep,
                        cfg.coordinator.expert_weight_tokens,
                        &health.banned_sources(),
                    );
                    if migration.is_empty() {
                        // in-place plan change: no weights move, but it is
                        // still a replan (same accounting as the coordinator)
                        active = (cand_rep, cand_splits);
                        replans += 1;
                    } else {
                        let mig_ms =
                            migration.migration_ms_on(cluster, &cfg.coordinator.topology);
                        let began = swap.begin(cand_rep, cand_splits, mig_ms);
                        debug_assert!(began, "swap was checked idle above");
                        staging = Some(migration.traffic.clone());
                        migration_total += mig_ms;
                        replans += 1;
                    }
                }
                tr.end(sp);
            }
            let swaps = swap.swaps();
            outcome(strategy, per_window, replans, swaps, migration_total)
        }
        OnlineStrategy::Oracle => {
            let mut health = ClusterHealth::new(cfg.n_gpus);
            let mut truth = DegradeState::new(cfg.n_gpus);
            let mut active = (rep0, splits0);
            let mut per_window = Vec::with_capacity(cfg.windows);
            let mut replans = 0u64;
            for w in 0..cfg.windows {
                tr.set_sim_time_us((elapsed_ms * 1e3).round() as u64);
                let sp = tr.begin("serve.window");
                tr.counter(sp, "window", w as i64);
                // The oracle replans fresh below, so events only move the
                // mask (and, for gray failures, the truth it is privileged
                // to read): the oracle-informed plan is the baseline the
                // detector-driven recovery win condition measures against.
                for ev in events_at(cfg, w) {
                    truth.apply(ev);
                    health.apply(ev);
                }
                let observed = window_traffic(cfg, w);
                let stats = layer(observed.clone());
                // perfect knowledge, free migration: adopt the best plan for
                // this exact window, membership, *and* true effective rates
                // (the one privilege the detector-driven coordinator lacks)
                // before serving it
                let eff_storage;
                let plan_cluster: &Cluster = if truth.is_nominal() {
                    cluster
                } else {
                    eff_storage = truth.scales().scaled(cluster);
                    &eff_storage
                };
                let trace = trace_of(stats.clone());
                let (cand_rep, cand_splits) = plan_candidate_masked(
                    &Planner::default(),
                    &trace,
                    plan_cluster,
                    &cfg.coordinator.topology,
                    &cfg.coordinator.replication,
                    &health,
                    tr,
                );
                if cand_rep != active.0 {
                    replans += 1;
                }
                active = (cand_rep, cand_splits);
                let res = serve_window(
                    &active.0,
                    &active.1,
                    &stats,
                    None,
                    cluster,
                    truth_scales(&truth),
                    &cfg.coordinator.topology,
                    &health,
                    metrics,
                    &mut TimelineRecorder::disabled(),
                );
                per_window.push(res.inference_ms);
                elapsed_ms += res.inference_ms;
                tr.set_sim_time_us((elapsed_ms * 1e3).round() as u64);
                tr.end(sp);
            }
            // the oracle's plan changes are free and instantaneous — it
            // never stages, so it never swaps
            outcome(strategy, per_window, replans, 0, 0.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(alpha: f64, sampled: bool) -> OnlineConfig {
        OnlineConfig {
            n_gpus: 4,
            n_experts: 8,
            tokens_per_sender: 2048,
            alpha,
            windows: 16,
            rotate_every: 8,
            seed: 7,
            sampled,
            ..OnlineConfig::default()
        }
    }

    #[test]
    fn stationary_uniform_coordinator_matches_static_exactly() {
        let cfg = small(0.0, false);
        let cluster = Cluster::homogeneous(4, 814.0);
        let stat = run_online(&cfg, &cluster, OnlineStrategy::Static);
        let coord = run_online(&cfg, &cluster, OnlineStrategy::Coordinator);
        assert_eq!(coord.replans, 0, "uniform traffic must not replan");
        assert_eq!(coord.swaps, 0);
        assert_eq!(coord.per_window_ms, stat.per_window_ms);
        assert!((coord.total_ms - stat.total_ms).abs() < 1e-12);
    }

    #[test]
    fn drifting_skew_makes_the_coordinator_adapt() {
        let cfg = small(1.2, false);
        let cluster = Cluster::homogeneous(4, 814.0);
        let stat = run_online(&cfg, &cluster, OnlineStrategy::Static);
        let coord = run_online(&cfg, &cluster, OnlineStrategy::Coordinator);
        assert!(coord.replans >= 1, "rotating hot expert must replan");
        assert!(
            coord.total_ms <= stat.total_ms,
            "coordinator {} vs static {}",
            coord.total_ms,
            stat.total_ms
        );
        // determinism
        let again = run_online(&cfg, &cluster, OnlineStrategy::Coordinator);
        assert_eq!(coord.per_window_ms, again.per_window_ms);
    }

    #[test]
    fn outcome_percentiles_are_ordered() {
        let cfg = small(1.2, true);
        let cluster = Cluster::homogeneous(4, 814.0);
        for strategy in [
            OnlineStrategy::Static,
            OnlineStrategy::EveryWindow,
            OnlineStrategy::Coordinator,
            OnlineStrategy::Oracle,
        ] {
            let out = run_online(&cfg, &cluster, strategy);
            assert_eq!(out.per_window_ms.len(), cfg.windows);
            assert!(out.total_ms > 0.0);
            assert!(
                out.p50_ms <= out.p95_ms && out.p95_ms <= out.p99_ms,
                "{}: p50 {} p95 {} p99 {}",
                out.strategy,
                out.p50_ms,
                out.p95_ms,
                out.p99_ms
            );
        }
    }

    #[test]
    fn oracle_is_a_floor_for_the_static_plan() {
        let cfg = small(1.2, false);
        let cluster = Cluster::homogeneous(4, 814.0);
        let stat = run_online(&cfg, &cluster, OnlineStrategy::Static);
        let oracle = run_online(&cfg, &cluster, OnlineStrategy::Oracle);
        assert!(
            oracle.total_ms <= stat.total_ms + 1e-9,
            "oracle {} vs static {}",
            oracle.total_ms,
            stat.total_ms
        );
    }

    #[test]
    fn slo_watchdog_forces_replans_under_uniform_traffic() {
        // Uniform traffic keeps drift at ~0, so without the watchdog the
        // coordinator never replans (pinned above); an absurdly low p99
        // target makes every window a violation and forces emergency
        // replans through the drift gate.
        let mut cfg = small(0.0, false);
        cfg.coordinator.slo_p99_ms = Some(0.001);
        cfg.coordinator.cooldown_windows = 0;
        let cluster = Cluster::homogeneous(4, 814.0);
        let tr = Tracer::sim();
        let out = run_online_traced(
            &cfg,
            &cluster,
            OnlineStrategy::Coordinator,
            &tr,
            &MetricsRegistry::disabled(),
        );
        assert!(out.replans >= 1, "SLO violations must force a replan");
        assert!(tr.decisions().iter().any(|r| {
            r.get("verdict").and_then(crate::util::Json::as_str) == Some("slo_triggered")
        }));
    }

    #[test]
    #[should_panic]
    fn mismatched_cluster_size_panics() {
        let cfg = small(0.5, false);
        run_online(&cfg, &Cluster::homogeneous(8, 814.0), OnlineStrategy::Static);
    }

    #[test]
    fn mid_run_failure_is_survived_by_every_strategy() {
        // the dead-GPU-tokens assertion inside serve_window is the real
        // check here: completing the run proves no post-failure window ever
        // routed a token through GPU 2
        let mut cfg = small(1.2, false);
        cfg.events = vec![(5, ClusterEvent::GpuFailed(2))];
        cfg.coordinator.cooldown_windows = 0;
        let cluster = Cluster::homogeneous(4, 814.0);
        for strategy in [
            OnlineStrategy::Static,
            OnlineStrategy::EveryWindow,
            OnlineStrategy::Coordinator,
            OnlineStrategy::Oracle,
        ] {
            let out = run_online(&cfg, &cluster, strategy);
            assert_eq!(out.per_window_ms.len(), cfg.windows);
            assert!(out.per_window_ms.iter().all(|ms| ms.is_finite() && *ms > 0.0));
            // determinism holds with events injected
            let again = run_online(&cfg, &cluster, strategy);
            assert_eq!(out.per_window_ms, again.per_window_ms);
        }
        let tr = Tracer::sim();
        let out = run_online_traced(
            &cfg,
            &cluster,
            OnlineStrategy::Coordinator,
            &tr,
            &MetricsRegistry::disabled(),
        );
        assert!(out.replans >= 1, "the repair replan commits");
        let verdicts: Vec<String> = tr
            .decisions()
            .iter()
            .filter_map(|r| {
                r.get("verdict")
                    .and_then(crate::util::Json::as_str)
                    .map(str::to_string)
            })
            .collect();
        let p = verdicts.iter().position(|v| v == "repair_promoted");
        let r = verdicts.iter().position(|v| v == "repair_replanned");
        assert!(p.is_some(), "promotion decision recorded");
        assert!(r.is_some(), "repair decision recorded");
        assert!(p < r, "promotion precedes the repair");
    }

    #[test]
    fn drain_and_rejoin_round_trip() {
        let mut cfg = small(1.2, false);
        cfg.events = vec![
            (3, ClusterEvent::GpuDrained(1)),
            (9, ClusterEvent::GpuJoined(1)),
        ];
        cfg.coordinator.cooldown_windows = 0;
        let cluster = Cluster::homogeneous(4, 814.0);
        for strategy in [
            OnlineStrategy::Static,
            OnlineStrategy::EveryWindow,
            OnlineStrategy::Coordinator,
            OnlineStrategy::Oracle,
        ] {
            let out = run_online(&cfg, &cluster, strategy);
            assert_eq!(out.per_window_ms.len(), cfg.windows);
            assert!(out.total_ms.is_finite());
        }
    }

    #[test]
    fn stationary_failure_recovers_to_the_masked_oracle() {
        // Stationary workload (one phase): after the failure the estimator's
        // EWMA equals the observed traffic exactly, so the committed repair
        // plan is the masked planner's — the same plan the oracle serves.
        // Recovery is therefore exact within promotion + staging windows.
        let mut cfg = small(1.2, false);
        cfg.rotate_every = cfg.windows; // never rotates: failure is the only disturbance
        cfg.events = vec![(5, ClusterEvent::GpuFailed(2))];
        cfg.coordinator.cooldown_windows = 0;
        let cluster = Cluster::homogeneous(4, 814.0);
        let coord = run_online(&cfg, &cluster, OnlineStrategy::Coordinator);
        let oracle = run_online(&cfg, &cluster, OnlineStrategy::Oracle);
        let last = cfg.windows - 1;
        let ratio = coord.per_window_ms[last] / oracle.per_window_ms[last];
        assert!(
            ratio <= 1.15,
            "steady-state after repair {ratio} must sit within 1.15× of the fresh-plan oracle"
        );
    }

    #[test]
    #[should_panic]
    fn event_beyond_the_horizon_panics() {
        let mut cfg = small(0.5, false);
        cfg.events = vec![(100, ClusterEvent::GpuFailed(0))];
        run_online(&cfg, &Cluster::homogeneous(4, 814.0), OnlineStrategy::Static);
    }

    fn verdicts_of(tr: &Tracer) -> Vec<String> {
        tr.decisions()
            .iter()
            .filter_map(|r| {
                r.get("verdict")
                    .and_then(crate::util::Json::as_str)
                    .map(str::to_string)
            })
            .collect()
    }

    #[test]
    fn a_straggler_slows_the_blind_static_plan() {
        // The injected truth must actually bite: pre-onset windows are
        // bit-for-bit the clean run, the onset window is strictly slower.
        let clean_cfg = small(1.2, false);
        let mut cfg = small(1.2, false);
        cfg.events = vec![(
            8,
            ClusterEvent::GpuDegraded { gpu: 2, compute_scale: 0.4, bandwidth_scale: 1.0 },
        )];
        let cluster = Cluster::homogeneous(4, 814.0);
        let clean = run_online(&clean_cfg, &cluster, OnlineStrategy::Static);
        let slow = run_online(&cfg, &cluster, OnlineStrategy::Static);
        assert_eq!(clean.per_window_ms[..8], slow.per_window_ms[..8]);
        assert!(
            slow.per_window_ms[8] > clean.per_window_ms[8] + 1e-9,
            "a 0.4× compute straggler must slow the blind static plan"
        );
        // determinism holds with degradation injected
        let again = run_online(&cfg, &cluster, OnlineStrategy::Static);
        assert_eq!(slow.per_window_ms, again.per_window_ms);
    }

    #[test]
    fn detector_driven_recovery_tracks_the_informed_oracle() {
        // The issue's acceptance pin: a 0.4× compute straggler lands at
        // window 8 of the drifting-Zipf trace; the coordinator — told
        // nothing, inferring through the detector — must come within 1.25×
        // of the oracle-informed per-window time inside 6 windows of onset.
        let mut cfg = small(1.2, false);
        cfg.events = vec![(
            8,
            ClusterEvent::GpuDegraded { gpu: 2, compute_scale: 0.4, bandwidth_scale: 1.0 },
        )];
        cfg.degrade_detection = true;
        cfg.coordinator.cooldown_windows = 0;
        cfg.coordinator.degrade_cooldown_windows = 0;
        let cluster = Cluster::homogeneous(4, 814.0);
        let coord = run_online(&cfg, &cluster, OnlineStrategy::Coordinator);
        let oracle = run_online(&cfg, &cluster, OnlineStrategy::Oracle);
        let best = (8..14)
            .map(|w| coord.per_window_ms[w] / oracle.per_window_ms[w])
            .fold(f64::INFINITY, f64::min);
        assert!(
            best <= 1.25,
            "detector-driven recovery (best ratio {best}) must reach within \
             1.25× of the oracle-informed plan within 6 windows of onset"
        );
        // determinism of the full detection loop
        let again = run_online(&cfg, &cluster, OnlineStrategy::Coordinator);
        assert_eq!(coord.per_window_ms, again.per_window_ms);
    }

    #[test]
    fn degrade_verdicts_are_ordered_detect_then_replan() {
        let mut cfg = small(1.2, false);
        cfg.events = vec![(
            8,
            ClusterEvent::GpuDegraded { gpu: 2, compute_scale: 0.4, bandwidth_scale: 1.0 },
        )];
        cfg.degrade_detection = true;
        cfg.coordinator.cooldown_windows = 0;
        cfg.coordinator.degrade_cooldown_windows = 0;
        let cluster = Cluster::homogeneous(4, 814.0);
        let tr = Tracer::sim();
        let out = run_online_traced(
            &cfg,
            &cluster,
            OnlineStrategy::Coordinator,
            &tr,
            &MetricsRegistry::disabled(),
        );
        assert!(out.replans >= 1);
        let verdicts = verdicts_of(&tr);
        let d = verdicts.iter().position(|v| v == "degrade_detected");
        let r = verdicts.iter().position(|v| v == "degrade_replanned");
        assert!(d.is_some(), "detection decision recorded");
        assert!(r.is_some(), "degrade replan decision recorded");
        assert!(d < r, "detection strictly precedes the replan");
    }

    #[test]
    fn noise_only_never_triggers_a_degrade_replan() {
        // ±5% observation jitter sits entirely above the 0.9 detect band:
        // the hysteresis must eat it — zero detections, zero degrade replans.
        let mut cfg = small(1.2, true);
        cfg.degrade_detection = true;
        cfg.obs_noise = 0.05;
        cfg.coordinator.cooldown_windows = 0;
        cfg.coordinator.degrade_cooldown_windows = 0;
        let cluster = Cluster::homogeneous(4, 814.0);
        let tr = Tracer::sim();
        let out = run_online_traced(
            &cfg,
            &cluster,
            OnlineStrategy::Coordinator,
            &tr,
            &MetricsRegistry::disabled(),
        );
        assert!(out.total_ms.is_finite());
        let verdicts = verdicts_of(&tr);
        assert!(
            !verdicts.iter().any(|v| v == "degrade_detected" || v == "degrade_replanned"),
            "noise alone must never flap the detector: {verdicts:?}"
        );
    }

    #[test]
    fn detection_is_purely_observational_without_degradation() {
        // With nothing to detect, running the whole detection loop (record,
        // re-simulate, ratio, detector) changes no serving outcome.
        let off = small(1.2, false);
        let mut on = small(1.2, false);
        on.degrade_detection = true;
        on.obs_noise = 0.02;
        let cluster = Cluster::homogeneous(4, 814.0);
        let a = run_online(&off, &cluster, OnlineStrategy::Coordinator);
        let b = run_online(&on, &cluster, OnlineStrategy::Coordinator);
        assert_eq!(a.per_window_ms, b.per_window_ms);
        assert_eq!(a.replans, b.replans);
        assert_eq!(a.swaps, b.swaps);
    }

    #[test]
    fn degrade_and_recover_round_trip_emits_all_three_verdicts() {
        let mut cfg = small(1.2, false);
        cfg.events = vec![
            (
                3,
                ClusterEvent::GpuDegraded { gpu: 1, compute_scale: 0.5, bandwidth_scale: 0.6 },
            ),
            (8, ClusterEvent::GpuRecovered(1)),
        ];
        cfg.degrade_detection = true;
        cfg.coordinator.cooldown_windows = 0;
        cfg.coordinator.degrade_cooldown_windows = 0;
        let cluster = Cluster::homogeneous(4, 814.0);
        // every strategy survives the round trip (blind ones just slow down)
        for strategy in [
            OnlineStrategy::Static,
            OnlineStrategy::EveryWindow,
            OnlineStrategy::Coordinator,
            OnlineStrategy::Oracle,
        ] {
            let out = run_online(&cfg, &cluster, strategy);
            assert_eq!(out.per_window_ms.len(), cfg.windows);
            assert!(out.per_window_ms.iter().all(|ms| ms.is_finite() && *ms > 0.0));
        }
        let tr = Tracer::sim();
        run_online_traced(
            &cfg,
            &cluster,
            OnlineStrategy::Coordinator,
            &tr,
            &MetricsRegistry::disabled(),
        );
        let verdicts = verdicts_of(&tr);
        let d = verdicts.iter().position(|v| v == "degrade_detected");
        let r = verdicts.iter().position(|v| v == "degrade_replanned");
        let rec = verdicts.iter().position(|v| v == "degrade_recovered");
        assert!(d.is_some() && r.is_some() && rec.is_some(), "verdicts: {verdicts:?}");
        assert!(d < r && r < rec, "detect → replan → recover in order: {verdicts:?}");
    }

    #[test]
    fn severe_degradation_escalates_to_promote_then_repair() {
        // 0.1× is below the 0.25 escalation floor: the coordinator treats
        // the GPU as failed — completing the run proves no post-escalation
        // window routed a token through it (serve_window's dead-GPU assert).
        let mut cfg = small(1.2, false);
        cfg.events = vec![(
            5,
            ClusterEvent::GpuDegraded { gpu: 2, compute_scale: 0.1, bandwidth_scale: 1.0 },
        )];
        cfg.degrade_detection = true;
        cfg.coordinator.cooldown_windows = 0;
        cfg.coordinator.degrade_cooldown_windows = 0;
        let cluster = Cluster::homogeneous(4, 814.0);
        let tr = Tracer::sim();
        let out = run_online_traced(
            &cfg,
            &cluster,
            OnlineStrategy::Coordinator,
            &tr,
            &MetricsRegistry::disabled(),
        );
        assert_eq!(out.per_window_ms.len(), cfg.windows);
        let verdicts = verdicts_of(&tr);
        assert!(verdicts.iter().any(|v| v == "degrade_detected"));
        assert!(
            verdicts.iter().any(|v| v == "repair_promoted"),
            "escalation reuses promote-then-repair: {verdicts:?}"
        );
    }

    #[test]
    fn degradation_interleaved_with_failure_is_survived() {
        let mut cfg = small(1.2, false);
        cfg.events = vec![
            (
                3,
                ClusterEvent::GpuDegraded { gpu: 1, compute_scale: 0.6, bandwidth_scale: 0.8 },
            ),
            (6, ClusterEvent::GpuFailed(2)),
            (10, ClusterEvent::GpuRecovered(1)),
        ];
        cfg.degrade_detection = true;
        cfg.coordinator.cooldown_windows = 0;
        cfg.coordinator.degrade_cooldown_windows = 0;
        let cluster = Cluster::homogeneous(4, 814.0);
        for strategy in [
            OnlineStrategy::Static,
            OnlineStrategy::EveryWindow,
            OnlineStrategy::Coordinator,
            OnlineStrategy::Oracle,
        ] {
            let out = run_online(&cfg, &cluster, strategy);
            assert_eq!(out.per_window_ms.len(), cfg.windows);
            let again = run_online(&cfg, &cluster, strategy);
            assert_eq!(out.per_window_ms, again.per_window_ms);
        }
    }
}
