//! Online coordination: traffic-drift detection, cost-aware replanning, and
//! live expert migration — the L3 layer above the offline planner.
//!
//! [`crate::planner::Planner`] optimizes for **one** traffic matrix, but
//! production MoE routing drifts: hot experts move, skew sharpens and
//! relaxes, and a static plan silently decays toward the random baseline.
//! The serving layer's [`crate::serve::AdaptiveReplanner`] can *detect* that
//! decay; this module closes the loop — it decides **whether a replan pays
//! for itself** and executes the switch without stalling serving:
//!
//! ```text
//! observed windows ─▶ TrafficEstimator (EWMA) ─▶ DriftDetector (TV vs plan)
//!                                              │ drift > θ, cooldown clear
//!                                              ▼
//!                    Planner::plan_replicated on the live estimate
//!                                              │ candidate plan
//!                                              ▼
//!        cost gate: (cur − new) × horizon  >  2 × migration makespan ?
//!                                              │ yes
//!                                              ▼
//!   plan_migration  (diff replica sets → weight flows → aurora_schedule)
//!                                              ▼
//!   PlanSwap: stage (links shared with tokens) → atomic swap → drain
//! ```
//!
//! Every stage reuses the offline machinery: candidate plans come from
//! [`crate::planner::Planner::plan_replicated`], serving times from the
//! split-aware completion estimator
//! ([`crate::replication::estimate_bottleneck_replicated`]), and migration
//! makespans from the same slot scheduler that orders tokens — weight
//! transfers are just one more traffic matrix on the same per-GPU ports.
//! The two hysteresis gates (drift threshold, predicted-gain-vs-cost) keep a
//! stationary workload replan-free: under uniform routing the coordinator
//! never touches the plan, bit for bit.
//!
//! **SLO watchdog** ([`CoordinatorConfig::slo_p99_ms`]): alongside the
//! drift trigger, an optional [`SloMonitor`] watches per-window serving
//! latencies ([`Coordinator::record_window_latency`]). The drift trigger is
//! *proactive* — it fires on distribution movement before latency decays —
//! and is fully gated; the SLO trigger is *reactive* — the promise to the
//! user is already broken, so a rolling-p99 violation **bypasses** the
//! drift, gain, and cost gates and commits the freshest candidate plan
//! (decision verdict `slo_triggered`, the monitor's window resetting at the
//! commit so the new plan is judged on its own samples). Only an in-flight
//! swap or the cooldown suppresses it (`slo_suppressed_cooldown`) — an
//! atomic swap cannot be preempted mid-stage, and the cooldown keeps a
//! latency storm from thrashing migrations. With no SLO configured every
//! decision is bit-for-bit the historical gate sequence.
//!
//! **Fault tolerance & elasticity** ([`ClusterEvent`] /
//! [`Coordinator::inject_event`]): cluster membership is dynamic — GPUs
//! fail, drain, and (re)join mid-serving. A failure runs a **two-phase
//! promote-then-repair contract**. Phase 1 is synchronous and planner-free:
//! any in-flight swap is aborted, the dead GPU's copies are evacuated onto
//! surviving replicas (sole copies cold-restored,
//! [`crate::replication::ReplicatedDeployment::evacuate_gpu`]) and split
//! weights re-solved on the live estimate — the very next window serves with
//! zero tokens routed to the dead GPU (verdict `repair_promoted`). Phase 2
//! is the cost-aware repair: a queued replan that plans on the placeable
//! sub-cluster ([`plan_candidate_masked`]), bans dead GPUs as migration
//! sources ([`plan_migration_avoiding`]), bypasses the drift, gain, and
//! amortized cost gates (redundancy is not an optional optimization) but
//! still honors swap-busy and the cooldown, and always commits (verdict
//! `repair_replanned`) — the masked candidate is the best deployment for
//! the new membership. Drains queue the same repair while the GPU keeps
//! serving; joins queue a rebalance that commits only if spreading back out
//! helps. With
//! [`CoordinatorConfig::elastic`] set, sustained SLO burn grows the replica
//! budget or reclaims a coordinator-drained GPU (`scaled_up`) and sustained
//! low utilization ([`Coordinator::record_window_utilization`]) drains the
//! least-loaded GPU behind a bounded-slowdown gate (`consolidated`).
//!
//! **Gray failures** ([`Coordinator::observe_degradation`]): a GPU that
//! *slows* instead of dying — thermal throttling, ECC retries, a flaky NIC —
//! re-serializes every synchronous all-to-all behind the straggler. The
//! coordinator is never told the truth ([`DegradeState`] lives in the
//! injection harness); it only sees what the
//! [`crate::obs::degrade::DegradationDetector`] confirms from observed
//! timelines. Confirmed scales become the coordinator's *effective* cluster:
//! candidate plans, serving estimates, and migration prices are all computed
//! on [`GpuScales::scaled`] clones, so the existing heterogeneous planner
//! shifts load off the straggler and migrations are charged at degraded
//! link rates. A confirmed transition emits `degrade_detected` /
//! `degrade_recovered` and queues an always-commit replan (verdict
//! `degrade_replanned`) behind its own flap-damping cooldown
//! ([`CoordinatorConfig::degrade_cooldown_windows`]); degradation below
//! [`CoordinatorConfig::degrade_floor`] escalates to the
//! promote-then-repair path as if the GPU had failed.
//!
//! [`online`] ships the drifting-Zipf discrete-event serving simulation that
//! pins the coordinator against a static plan, naive replan-every-window,
//! and a zero-cost oracle (the `online` eval figure and the `serve-sim` CLI
//! subcommand drive it), plus failure/join/leave and degradation injection
//! ([`OnlineConfig`]`::events`) for the `resilience` and `straggler`
//! figures.

mod estimator;
mod event;
mod migration;
pub mod online;
mod swap;

pub use estimator::{DriftDetector, TrafficEstimator};
pub use event::{
    degradation_schedule, failure_schedule, ClusterEvent, ClusterHealth, DegradeState,
};
pub use migration::{
    migration_preserves_target, plan_migration, plan_migration_avoiding, MigrationFlow,
    MigrationPlan,
};
pub use online::{run_online, run_online_traced, OnlineConfig, OnlineOutcome, OnlineStrategy};
pub use swap::{PlanSwap, SwapPhase};

use std::borrow::Cow;

use crate::cluster::{Cluster, GpuScales, Topology};
use crate::obs::degrade::DetectorEvent;
use crate::obs::{SloMonitor, Tracer};
use crate::placement::Deployment;
use crate::planner::{Planner, ReplicationConfig};
use crate::replication::{
    estimate_objective_on, optimize_splits, ReplicatedDeployment, SplitPlan,
};
use crate::sim::MoeLayerStats;
use crate::trace::ModelTrace;
use crate::traffic::TrafficMatrix;
use crate::util::Json;

/// Knobs of the cost-aware replan policy.
#[derive(Debug, Clone, PartialEq)]
pub struct CoordinatorConfig {
    /// Total-variation drift (plan-time vs live expert distribution) below
    /// which the planner is never even consulted.
    pub drift_threshold: f64,
    /// Minimum *relative* improvement of the candidate's completion estimate
    /// over the current plan's (both on the live estimate) — the hysteresis
    /// band that stops near-tie plan churn.
    pub min_gain: f64,
    /// Windows over which a migration amortizes: a replan commits only when
    /// `(cur − new) × horizon` exceeds the staging cost (twice the
    /// migration makespan — weights ride both collectives of the staging
    /// window).
    pub horizon_windows: f64,
    /// Windows that must pass after a plan activates before the next replan
    /// may be considered.
    pub cooldown_windows: u64,
    /// Wire tokens one expert's weights occupy during migration.
    pub expert_weight_tokens: u64,
    /// EWMA weight of the newest window in the traffic estimator.
    pub ewma_alpha: f64,
    /// Drain window after each atomic swap (ms of serving time).
    pub drain_ms: f64,
    /// Budgets for the candidate plans ([`Planner::plan_replicated`]).
    pub replication: ReplicationConfig,
    /// Network topology the cost model charges migrations on: weight
    /// transfers crossing a group boundary ride the same oversubscribed
    /// uplinks tokens do ([`MigrationPlan::migration_ms_on`]), and candidate
    /// plans come from the topology-aware planner entry point. The default
    /// [`Topology::BigSwitch`] reproduces the historical behavior exactly.
    pub topology: Topology,
    /// Latency SLO: when set, an [`SloMonitor`] watches per-window serving
    /// latencies (fed via [`Coordinator::record_window_latency`]) and a
    /// rolling-p99 violation becomes an **emergency** replan trigger that
    /// bypasses the drift, gain, and cost gates — only an in-flight swap or
    /// the cooldown can suppress it (verdict `slo_suppressed_cooldown`).
    /// `None` (the default) disables the watchdog; every decision is then
    /// bit-for-bit the historical gate sequence.
    pub slo_p99_ms: Option<f64>,
    /// Rolling window (in serving windows) the SLO quantiles are computed
    /// over. Ignored unless [`CoordinatorConfig::slo_p99_ms`] is set.
    pub slo_window: usize,
    /// Enable the elasticity policy: sustained SLO burn grows the replica
    /// budget (or reclaims a coordinator-drained GPU), sustained low
    /// utilization consolidates the deployment onto fewer GPUs. Off by
    /// default — every decision is then bit-for-bit the historical gate
    /// sequence. Scale-up needs [`CoordinatorConfig::slo_p99_ms`] set (the
    /// burn signal) and consolidation needs
    /// [`Coordinator::record_window_utilization`] fed.
    pub elastic: bool,
    /// Consecutive windows a burn/idle signal must persist before an
    /// elastic action triggers (hysteresis against one-window noise).
    pub elastic_patience: u64,
    /// SLO burn rate (rolling p99 ÷ target) at or above which a window
    /// counts toward scale-up.
    pub scale_up_burn: f64,
    /// EWMA utilization below which a window counts toward consolidation.
    pub consolidate_util: f64,
    /// Slack a consolidation may cost: the shrunk plan commits only while
    /// its estimate stays within `(1 + consolidate_slack) ×` the current
    /// plan's.
    pub consolidate_slack: f64,
    /// Consolidation never shrinks the placeable set below this many GPUs.
    pub min_gpus: usize,
    /// Gray-failure escalation floor: a confirmed degradation whose inferred
    /// compute *or* bandwidth scale drops below this fraction of nominal is
    /// treated as a failure (promote-then-repair) instead of a replan — a
    /// GPU that slow drags every synchronous barrier more than it serves.
    pub degrade_floor: f64,
    /// Flap damping for degradation replans: windows that must pass after a
    /// `degrade_replanned` commit before the next degradation transition may
    /// trigger another (transitions observed inside the cooldown stay queued
    /// and run once it clears).
    pub degrade_cooldown_windows: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            drift_threshold: 0.1,
            min_gain: 0.05,
            horizon_windows: 8.0,
            cooldown_windows: 2,
            expert_weight_tokens: 4096,
            ewma_alpha: 0.5,
            drain_ms: 0.0,
            replication: ReplicationConfig::default(),
            topology: Topology::BigSwitch,
            slo_p99_ms: None,
            slo_window: 8,
            elastic: false,
            elastic_patience: 3,
            scale_up_burn: 1.0,
            consolidate_util: 0.35,
            consolidate_slack: 0.10,
            min_gpus: 2,
            degrade_floor: 0.25,
            degrade_cooldown_windows: 4,
        }
    }
}

/// Counters the coordinator keeps (reported by the serving simulation and
/// the `serve-sim` CLI).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CoordinatorStats {
    /// Windows observed.
    pub windows: u64,
    /// Replans committed (migrations started).
    pub replans: u64,
    /// Atomic swaps completed.
    pub swaps: u64,
    /// Replans skipped because a swap was in flight or the cooldown held.
    pub skipped_cooldown: u64,
    /// Replans skipped because the candidate's estimated gain was inside the
    /// hysteresis band.
    pub skipped_gain: u64,
    /// Replans skipped because the migration cost exceeded the amortized
    /// gain.
    pub skipped_cost: u64,
    /// Times the detector settled (rebased) after repeated rejections.
    pub settles: u64,
    /// Total staged-migration makespan (ms).
    pub migration_ms_total: f64,
    /// Emergency replans committed because the rolling p99 broke the SLO
    /// (gain/cost gates bypassed).
    pub slo_triggered: u64,
    /// SLO violations that could not replan because a swap was in flight or
    /// the cooldown held.
    pub slo_suppressed: u64,
    /// Hard GPU failures injected ([`ClusterEvent::GpuFailed`]).
    pub failures: u64,
    /// Survivor replicas promoted to primary during evacuations.
    pub promotions: u64,
    /// Sole-copy experts cold-restored during evacuations.
    pub restores: u64,
    /// Membership-driven replans committed (verdict `repair_replanned`).
    pub repairs: u64,
    /// Elastic scale-ups committed (verdict `scaled_up`).
    pub scale_ups: u64,
    /// Elastic consolidations committed (verdict `consolidated`).
    pub consolidations: u64,
    /// In-flight swaps abandoned because a failure invalidated them.
    pub swaps_aborted: u64,
    /// Confirmed degradation detections adopted (verdict `degrade_detected`).
    pub degrade_detected: u64,
    /// Degradation-driven replans committed (verdict `degrade_replanned`).
    pub degrade_replans: u64,
    /// Confirmed recoveries adopted (verdict `degrade_recovered`).
    pub degrade_recovered: u64,
    /// Degradations below [`CoordinatorConfig::degrade_floor`] escalated to
    /// the promote-then-repair failure path.
    pub escalations: u64,
}

/// What a committed replan looked like.
#[derive(Debug, Clone)]
pub struct ReplanOutcome {
    /// Drift score that triggered the evaluation.
    pub drift: f64,
    /// Predicted serving-time gain over the amortization horizon (ms).
    pub predicted_gain_ms: f64,
    /// Staged migration makespan (ms; 0 for an in-place adoption).
    pub migration_ms: f64,
    /// The weight-transfer plan now staging. **Empty** means the candidate
    /// needed no new copies (only split weights / primary labels changed):
    /// the plan was adopted in place, no swap will fire later, and a caller
    /// driving a real engine should commit it immediately
    /// ([`crate::serve::MoeEngine::swap_replicated`]).
    pub migration: MigrationPlan,
}

/// Decision returned by [`Coordinator::observe_window`].
#[derive(Debug, Clone)]
pub enum CoordinatorDecision {
    /// Keep the active plan (drift low, swap busy, or gates not cleared).
    Keep {
        /// Drift score of the live estimate vs the active plan.
        drift: f64,
    },
    /// A replan committed; its migration is staging.
    Replan(Box<ReplanOutcome>),
}

/// The online coordinator for one served model: estimator → detector → cost
/// model → migration → swap, one `observe_window` call per serving window.
///
/// Scope: the coordinator watches a single model (its deployment may still
/// replicate experts arbitrarily). Multi-model coordination is a mechanical
/// extension — one estimator per model, candidate plans from the same
/// multi-trace planner entry point.
#[derive(Debug)]
pub struct Coordinator {
    cfg: CoordinatorConfig,
    planner: Planner,
    /// Compute constants of the served model (the traffic part of the live
    /// statistics comes from the estimator).
    gate_ms: f64,
    ffn_ms_per_token: f64,
    agg_ms: f64,
    estimator: TrafficEstimator,
    detector: DriftDetector,
    active: (ReplicatedDeployment, SplitPlan),
    swap: PlanSwap,
    staging_traffic: Option<TrafficMatrix>,
    /// SLO watchdog, present iff [`CoordinatorConfig::slo_p99_ms`] is set.
    slo: Option<SloMonitor>,
    windows_since_replan: u64,
    /// Consecutive gate-rejected candidates since the last commit/settle.
    rejections: u64,
    /// Liveness/placeability of every GPU ([`Coordinator::inject_event`]).
    health: ClusterHealth,
    /// A membership- or elasticity-driven replan waiting to run (it bypasses
    /// the drift gate; only swap-busy/cooldown defers it).
    pending: Option<ReplanReason>,
    /// The detector-inferred effective-rate scales the coordinator prices
    /// on ([`Coordinator::observe_degradation`]); nominal = the historical
    /// bit-for-bit path.
    eff_scales: GpuScales,
    /// A confirmed degradation transition awaits a replan (set while the
    /// degrade cooldown holds it back).
    degrade_dirty: bool,
    /// Windows since the last `degrade_replanned` commit (flap damping).
    windows_since_degrade_replan: u64,
    /// GPUs the *coordinator* drained for consolidation — the only ones a
    /// scale-up may silently reclaim (operator drains are not ours to undo).
    drained_by_coordinator: Vec<bool>,
    /// EWMA of observed window utilization
    /// ([`Coordinator::record_window_utilization`]).
    util_ewma: Option<f64>,
    /// Consecutive windows at or above the scale-up burn rate.
    burn_streak: u64,
    /// Consecutive windows below the consolidation utilization floor.
    idle_streak: u64,
    /// Observability sink: one `coordinator.replan_gate` decision record per
    /// observed window, plus the candidate planner's spans. Disabled (a
    /// no-op) unless [`Coordinator::set_tracer`] installs a live tracer.
    tracer: Tracer,
    /// Counters (public for reporting).
    pub stats: CoordinatorStats,
}

/// Serving-time estimate of a plan on live statistics, on the configured
/// topology: the split-aware completion bottleneck joined with the
/// cross-uplink drain of the split-projected traffic — both sides of the
/// replan gate must see the fabric, or a candidate that relieves a
/// saturated uplink (the dominant term under oversubscription) looks like
/// no gain at all. Big switch ⇒ exactly
/// [`crate::replication::estimate_bottleneck_replicated`]. Computed through
/// the planner's single-pass evaluator ([`estimate_objective_on`]) so the
/// replan gate projects each model once instead of twice — same values, and
/// the candidate plan itself now comes out of the incremental
/// ([`crate::replication::ReplicaDeltaEstimator`]-driven) planner, which is
/// what makes consulting it every drifted window affordable.
fn serving_estimate_ms(
    rep: &ReplicatedDeployment,
    splits: &SplitPlan,
    layers: &[&MoeLayerStats],
    cluster: &Cluster,
    topo: &Topology,
) -> f64 {
    estimate_objective_on(rep, layers, cluster, topo, splits)
}

/// After this many consecutive gate-rejected candidates the detector
/// rebases onto the live estimate ("settle"): the standing decision is that
/// for this distribution the current plan stays, so the expensive planner is
/// not consulted again until the distribution moves materially *further*.
const MAX_CONSECUTIVE_REJECTIONS: u64 = 3;

/// Why a membership/elasticity replan is pending (drift gate bypassed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReplanReason {
    /// A failure or drain left the deployment degraded: restore redundancy
    /// and vacate un-placeable GPUs.
    Repair,
    /// A GPU (re)joined: spread the deployment back over the grown cluster
    /// if that actually helps.
    Rebalance,
    /// Sustained SLO burn: replan with a grown replica budget / reclaimed
    /// GPU.
    ScaleUp,
    /// Sustained low utilization: try to vacate `gpu` and serve on fewer
    /// GPUs within the configured slack.
    Consolidate {
        /// The GPU the coordinator drained for this consolidation.
        gpu: usize,
    },
    /// The degradation detector confirmed a transition (a straggler appeared
    /// or recovered): re-price the deployment on the effective cluster.
    Degrade,
}

impl ReplanReason {
    fn name(&self) -> &'static str {
        match self {
            ReplanReason::Repair => "repair",
            ReplanReason::Rebalance => "rebalance",
            ReplanReason::ScaleUp => "scale_up",
            ReplanReason::Consolidate { .. } => "consolidate",
            ReplanReason::Degrade => "degrade",
        }
    }
}

/// The candidate planner under a health mask. All GPUs placeable ⇒ the
/// ordinary topology-aware entry point, bit for bit. Otherwise the placeable
/// GPUs are compacted into a sub-cluster, planned flat
/// ([`Topology::BigSwitch`] — a partial cluster has no well-defined fabric
/// mapping; migration *pricing* stays fabric-aware on the full cluster), and
/// the result is remapped back to full-cluster GPU ids. Split weights carry
/// over verbatim: the remap preserves every replica vector's order.
pub fn plan_candidate_masked(
    planner: &Planner,
    trace: &ModelTrace,
    cluster: &Cluster,
    topo: &Topology,
    rcfg: &ReplicationConfig,
    health: &ClusterHealth,
    tracer: &Tracer,
) -> (ReplicatedDeployment, SplitPlan) {
    let refs = [trace];
    if health.all_placeable() {
        return planner
            .plan_replicated_topology_traced(&refs, cluster, topo, rcfg, tracer)
            .expect("one model always plans");
    }
    let map = health.placeable_gpus();
    assert!(!map.is_empty(), "degraded planning needs a placeable GPU");
    let sub = Cluster::new(map.iter().map(|&g| cluster.gpu(g)).collect());
    let (sub_rep, sub_splits) = planner
        .plan_replicated_topology_traced(&refs, &sub, &Topology::BigSwitch, rcfg, tracer)
        .expect("one model always plans");
    (remap_deployment(&sub_rep, &map, cluster.len()), sub_splits)
}

/// Re-index a sub-cluster deployment onto the full cluster: GPU `i` of the
/// sub-cluster is `map[i]`.
fn remap_deployment(
    sub: &ReplicatedDeployment,
    map: &[usize],
    n_gpus: usize,
) -> ReplicatedDeployment {
    let assignments = sub
        .base
        .assignments
        .iter()
        .map(|a| a.iter().map(|&g| map[g]).collect())
        .collect();
    let base = Deployment::new(n_gpus, assignments, sub.base.policy, sub.base.scenario)
        .expect("remapped assignments stay in range");
    let replicas = sub
        .replicas
        .iter()
        .map(|model| {
            model
                .iter()
                .map(|set| set.iter().map(|&g| map[g]).collect())
                .collect()
        })
        .collect();
    ReplicatedDeployment::new(base, replicas).expect("remap preserves replica-set validity")
}

impl Coordinator {
    /// Start coordinating: `rep`/`splits` is the deployed plan, `plan_layer`
    /// the statistics it was optimized for (traffic seeds the estimator and
    /// the drift baseline; compute constants carry into live estimates).
    pub fn new(
        planner: Planner,
        rep: ReplicatedDeployment,
        splits: SplitPlan,
        plan_layer: &MoeLayerStats,
        cfg: CoordinatorConfig,
    ) -> Coordinator {
        assert_eq!(rep.n_models(), 1, "the coordinator watches one model");
        assert_eq!(
            plan_layer.n_experts(),
            rep.base.n_experts(0),
            "plan statistics must cover the deployed model's experts"
        );
        assert!((0.0..=1.0).contains(&cfg.drift_threshold));
        assert!(cfg.min_gain >= 0.0 && cfg.horizon_windows > 0.0);
        let mut estimator = TrafficEstimator::new(plan_layer.n_experts(), cfg.ewma_alpha);
        estimator.observe(&plan_layer.traffic);
        let detector = DriftDetector::new(&plan_layer.traffic);
        let swap = PlanSwap::new(cfg.drain_ms);
        let slo = cfg
            .slo_p99_ms
            .map(|target| SloMonitor::new(target, cfg.slo_window.max(1)));
        let n_gpus = rep.n_gpus();
        Coordinator {
            planner,
            gate_ms: plan_layer.gate_ms,
            ffn_ms_per_token: plan_layer.ffn_ms_per_token,
            agg_ms: plan_layer.agg_ms,
            estimator,
            detector,
            active: (rep, splits),
            swap,
            staging_traffic: None,
            slo,
            windows_since_replan: 0,
            rejections: 0,
            health: ClusterHealth::new(n_gpus),
            pending: None,
            eff_scales: GpuScales::nominal(n_gpus),
            degrade_dirty: false,
            windows_since_degrade_replan: u64::MAX / 2,
            drained_by_coordinator: vec![false; n_gpus],
            util_ewma: None,
            burn_streak: 0,
            idle_streak: 0,
            tracer: Tracer::disabled(),
            stats: CoordinatorStats::default(),
            cfg,
        }
    }

    /// Install a tracer: every subsequent [`Coordinator::observe_window`]
    /// records a span and emits one structured `coordinator.replan_gate`
    /// decision (drift, candidate gain, migration cost, and the verdict with
    /// its reason), and candidate planning runs traced. Tracing is purely
    /// observational — decisions are identical with it on or off.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// The tracer decisions are recorded through (disabled by default).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Emit the per-window replan-gate decision record.
    fn gate_decision(&self, verdict: &str, drift: f64, extra: Vec<(&str, Json)>) {
        if !self.tracer.is_enabled() {
            return;
        }
        let mut fields = vec![
            ("window", Json::from(self.stats.windows)),
            ("verdict", Json::from(verdict)),
            ("drift", Json::Num(drift)),
        ];
        fields.extend(extra);
        self.tracer.decision("coordinator.replan_gate", fields);
    }

    /// A candidate was rejected by the gain/cost gates. After
    /// [`MAX_CONSECUTIVE_REJECTIONS`] in a row, settle: rebase the drift
    /// baseline onto the live estimate so the planner stops being consulted
    /// every window for a distribution we have already decided to keep
    /// serving with the current plan.
    fn note_rejection(&mut self, est: &TrafficMatrix) {
        self.rejections += 1;
        if self.rejections >= MAX_CONSECUTIVE_REJECTIONS {
            self.detector.rebase(est);
            self.rejections = 0;
            self.stats.settles += 1;
        }
    }

    /// Feed one serving window's observed latency into the SLO watchdog
    /// (no-op unless [`CoordinatorConfig::slo_p99_ms`] is set). Call it
    /// *before* [`Coordinator::observe_window`] so the window's decision
    /// sees the freshest rolling quantiles.
    pub fn record_window_latency(&mut self, latency_ms: f64) {
        if let Some(m) = self.slo.as_mut() {
            m.observe(latency_ms);
        }
    }

    /// The SLO watchdog, if one is configured.
    pub fn slo(&self) -> Option<&SloMonitor> {
        self.slo.as_ref()
    }

    /// Liveness/placeability of every GPU, as updated by
    /// [`Coordinator::inject_event`] and the elasticity policy.
    pub fn health(&self) -> &ClusterHealth {
        &self.health
    }

    /// The detector-inferred effective-rate scales the coordinator currently
    /// prices candidates on (nominal unless
    /// [`Coordinator::observe_degradation`] adopted a confirmed detection).
    pub fn effective_scales(&self) -> &GpuScales {
        &self.eff_scales
    }

    /// The cluster the replan pipeline prices on: the nominal `cluster`
    /// while the inferred scales are nominal (bit-for-bit the historical
    /// path), else a [`GpuScales::scaled`] clone — candidate plans shift
    /// load off stragglers via ordinary heterogeneous planning, and
    /// migrations are charged at degraded link rates.
    fn effective<'a>(&self, cluster: &'a Cluster) -> Cow<'a, Cluster> {
        if self.eff_scales.is_nominal() {
            Cow::Borrowed(cluster)
        } else {
            Cow::Owned(self.eff_scales.scaled(cluster))
        }
    }

    /// Adopt the degradation detector's verdicts for this window: `scales`
    /// is [`crate::obs::degrade::DegradationDetector::scales`] (the inferred
    /// truth, 1.0 on unconfirmed GPUs) and `events` its confirmed
    /// transitions. The scales become the coordinator's effective pricing
    /// cluster immediately; each transition emits a decision record
    /// (`degrade_detected` / `degrade_recovered`) and queues an
    /// always-commit replan behind the degrade cooldown. A detection whose
    /// compute or bandwidth scale sits below
    /// [`CoordinatorConfig::degrade_floor`] instead escalates through
    /// [`Coordinator::inject_event`] as a [`ClusterEvent::GpuFailed`] —
    /// promote-then-repair, as if the GPU had died.
    ///
    /// Call it after serving each window, alongside
    /// [`Coordinator::observe_window`]. Never hand it the injection truth:
    /// the contract of the gray-failure path is that the coordinator only
    /// acts on what the detector inferred from observed timelines.
    pub fn observe_degradation(
        &mut self,
        events: &[DetectorEvent],
        scales: &GpuScales,
        cluster: &Cluster,
    ) {
        assert_eq!(scales.n_gpus(), self.health.n_gpus(), "scales must cover the cluster");
        self.eff_scales = scales.clone();
        // Dead GPUs are priced out by the health mask, not by scales.
        for g in 0..self.health.n_gpus() {
            if !self.health.is_alive(g) {
                self.eff_scales.clear(g);
            }
        }
        for ev in events {
            match *ev {
                DetectorEvent::Degraded {
                    gpu,
                    compute_scale,
                    bandwidth_scale,
                } => {
                    if !self.health.is_alive(gpu) {
                        continue;
                    }
                    self.stats.degrade_detected += 1;
                    let escalate = compute_scale < self.cfg.degrade_floor
                        || bandwidth_scale < self.cfg.degrade_floor;
                    self.gate_decision(
                        "degrade_detected",
                        self.current_drift(),
                        vec![
                            ("gpu", Json::from(gpu)),
                            ("compute_scale", Json::Num(compute_scale)),
                            ("bandwidth_scale", Json::Num(bandwidth_scale)),
                            ("escalated", Json::from(escalate)),
                        ],
                    );
                    if escalate {
                        // Too slow to keep: below the floor the straggler
                        // drags every barrier more than it serves.
                        self.stats.escalations += 1;
                        self.eff_scales.clear(gpu);
                        self.inject_event(&ClusterEvent::GpuFailed(gpu), cluster);
                    } else {
                        self.degrade_dirty = true;
                    }
                }
                DetectorEvent::Recovered { gpu } => {
                    self.stats.degrade_recovered += 1;
                    self.gate_decision(
                        "degrade_recovered",
                        self.current_drift(),
                        vec![("gpu", Json::from(gpu))],
                    );
                    self.degrade_dirty = true;
                }
            }
        }
    }

    /// Feed one serving window's mean GPU utilization (0..1) into the
    /// consolidation signal's EWMA (same α as the traffic estimator). Only
    /// consulted when [`CoordinatorConfig::elastic`] is set.
    pub fn record_window_utilization(&mut self, utilization: f64) {
        let a = self.cfg.ewma_alpha;
        self.util_ewma = Some(match self.util_ewma {
            None => utilization,
            Some(prev) => a * utilization + (1.0 - a) * prev,
        });
    }

    /// Apply one cluster-membership event, *before* serving the window it
    /// lands on.
    ///
    /// [`ClusterEvent::GpuFailed`] runs the zero-downtime half of the
    /// promote-then-repair contract synchronously: any in-flight swap is
    /// aborted (its staged plan may involve the dead GPU), the dead GPU's
    /// copies are evacuated onto surviving replicas — sole copies
    /// cold-restored — via [`ReplicatedDeployment::evacuate_gpu`], split
    /// weights are re-solved on the live estimate ([`optimize_splits`] — no
    /// planner call), and the result serves immediately, so no token is
    /// ever routed to the dead GPU. A cost-aware repair replan is queued
    /// for the next [`Coordinator::observe_window`] (verdict
    /// `repair_replanned` when it commits, with dead GPUs banned as
    /// migration sources).
    ///
    /// [`ClusterEvent::GpuDrained`] queues the same repair (the GPU keeps
    /// serving and may source migrations until vacated);
    /// [`ClusterEvent::GpuJoined`] queues a rebalance that commits only if
    /// spreading back out actually helps. Each effective event emits one
    /// `coordinator.replan_gate` decision (verdicts `repair_promoted`,
    /// `gpu_drained`, `gpu_joined`); events that change nothing (re-failing
    /// a dead GPU) are no-ops.
    ///
    /// Panics when the failure leaves no placeable GPU to evacuate onto.
    pub fn inject_event(&mut self, ev: &ClusterEvent, cluster: &Cluster) {
        assert_eq!(cluster.len(), self.health.n_gpus(), "cluster size mismatch");
        let g = ev.gpu();
        match ev {
            ClusterEvent::GpuFailed(_) => {
                if !self.health.is_alive(g) {
                    return;
                }
                self.health.apply(ev);
                self.drained_by_coordinator[g] = false;
                // A dead GPU's gray-failure scales are moot (its replacement
                // comes back clean); the health mask prices it out instead.
                self.eff_scales.clear(g);
                self.stats.failures += 1;
                if self.swap.abort() {
                    self.staging_traffic = None;
                    self.stats.swaps_aborted += 1;
                }
                let est = self.estimator.estimate();
                let drift = self.detector.score(&est);
                let placeable = self.health.placeable();
                let (rep, promoted, restored) = self.active.0.evacuate_gpu(g, &placeable);
                let live_layer = MoeLayerStats {
                    traffic: est,
                    gate_ms: self.gate_ms,
                    ffn_ms_per_token: self.ffn_ms_per_token,
                    agg_ms: self.agg_ms,
                };
                let splits = optimize_splits(&rep, &[&live_layer], self.effective(cluster).as_ref());
                self.active = (rep, splits);
                self.stats.promotions += promoted.len() as u64;
                self.stats.restores += restored.len() as u64;
                self.pending = Some(ReplanReason::Repair);
                self.gate_decision(
                    "repair_promoted",
                    drift,
                    vec![
                        ("gpu", Json::from(g)),
                        ("promoted", Json::from(promoted.len())),
                        ("restored", Json::from(restored.len())),
                    ],
                );
            }
            ClusterEvent::GpuJoined(_) => {
                if self.health.is_placeable(g) {
                    return;
                }
                self.health.apply(ev);
                self.drained_by_coordinator[g] = false;
                if self.pending.is_none() {
                    self.pending = Some(ReplanReason::Rebalance);
                }
                self.gate_decision("gpu_joined", self.current_drift(), vec![("gpu", Json::from(g))]);
            }
            ClusterEvent::GpuDrained(_) => {
                if !self.health.is_alive(g) || self.health.is_draining(g) {
                    return;
                }
                self.health.apply(ev);
                // an operator's drain, not ours to reclaim on scale-up
                self.drained_by_coordinator[g] = false;
                self.pending = Some(ReplanReason::Repair);
                self.gate_decision(
                    "gpu_drained",
                    self.current_drift(),
                    vec![("gpu", Json::from(g))],
                );
            }
        }
    }

    /// The live-estimate model trace candidate plans are computed on.
    fn live_trace(&self, est: TrafficMatrix) -> ModelTrace {
        ModelTrace {
            name: "live-estimate".to_string(),
            layers: vec![MoeLayerStats {
                traffic: est,
                gate_ms: self.gate_ms,
                ffn_ms_per_token: self.ffn_ms_per_token,
                agg_ms: self.agg_ms,
            }],
        }
    }

    /// Candidate plan under the current health mask
    /// ([`plan_candidate_masked`]); all GPUs placeable ⇒ the historical
    /// planner call, bit for bit.
    fn plan_candidate(
        &self,
        trace: &ModelTrace,
        cluster: &Cluster,
    ) -> (ReplicatedDeployment, SplitPlan) {
        plan_candidate_masked(
            &self.planner,
            trace,
            cluster,
            &self.cfg.topology,
            &self.cfg.replication,
            &self.health,
            &self.tracer,
        )
    }

    /// One window of elasticity bookkeeping: track the burn and idle
    /// streaks and, at the configured patience, queue a scale-up or a
    /// consolidation replan.
    fn elastic_tick(&mut self, burn_rate: Option<f64>) {
        if let Some(burn) = burn_rate {
            if burn >= self.cfg.scale_up_burn {
                self.burn_streak += 1;
            } else {
                self.burn_streak = 0;
            }
        }
        match self.util_ewma {
            Some(u) if u < self.cfg.consolidate_util => self.idle_streak += 1,
            Some(_) => self.idle_streak = 0,
            None => {}
        }
        if self.pending.is_some() {
            return;
        }
        if self.burn_streak >= self.cfg.elastic_patience {
            self.burn_streak = 0;
            self.idle_streak = 0;
            // Grow capacity: reclaim a coordinator-drained GPU if one
            // exists, otherwise raise the replica budget (bounded by the
            // placeable GPU count — replicas live on distinct GPUs).
            if let Some(g) = (0..self.health.n_gpus()).find(|&g| self.drained_by_coordinator[g]) {
                self.health.apply(&ClusterEvent::GpuJoined(g));
                self.drained_by_coordinator[g] = false;
            } else {
                let cap = self.health.n_placeable().max(1);
                self.cfg.replication.max_replicas =
                    (self.cfg.replication.max_replicas + 1).min(cap);
            }
            self.pending = Some(ReplanReason::ScaleUp);
        } else if self.idle_streak >= self.cfg.elastic_patience {
            self.idle_streak = 0;
            if self.health.n_placeable() <= self.cfg.min_gpus.max(1) {
                return;
            }
            // Drain the placeable GPU carrying the least projected load.
            let loads = self.active.0.gpu_loads_split(
                0,
                &self.estimator.estimate().expert_loads(),
                &self.active.1,
            );
            let g = self
                .health
                .placeable_gpus()
                .into_iter()
                .min_by_key(|&g| (loads[g], g))
                .expect("placeable set checked non-empty above");
            self.health.apply(&ClusterEvent::GpuDrained(g));
            self.drained_by_coordinator[g] = true;
            self.pending = Some(ReplanReason::Consolidate { gpu: g });
        }
    }

    /// Run a pending membership/elasticity replan: plan a candidate under
    /// the health mask, gate it by reason, and commit over the normal
    /// migration/swap path with dead GPUs banned as sources. The drift gate
    /// is bypassed; swap-busy and the cooldown still defer (verdict
    /// `skipped_cooldown` with the pending reason attached — the replan
    /// retries next window).
    fn pending_replan(
        &mut self,
        reason: ReplanReason,
        est: &TrafficMatrix,
        drift: f64,
        cluster: &Cluster,
    ) -> CoordinatorDecision {
        if self.swap.is_busy() || self.windows_since_replan <= self.cfg.cooldown_windows {
            self.stats.skipped_cooldown += 1;
            self.gate_decision(
                "skipped_cooldown",
                drift,
                vec![
                    ("swap_busy", Json::from(self.swap.is_busy())),
                    ("pending", Json::from(reason.name())),
                ],
            );
            return CoordinatorDecision::Keep { drift };
        }
        // Every price in this path is computed on the *effective* cluster:
        // with a confirmed straggler the candidate planner sees a weaker
        // GPU (ordinary heterogeneous planning shifts load off it) and the
        // migration is charged at degraded link rates. Nominal scales ⇒
        // borrowed nominal cluster, bit for bit.
        let eff = self.effective(cluster);
        let cluster = eff.as_ref();
        let live_trace = self.live_trace(est.clone());
        let (cand_rep, cand_splits) = self.plan_candidate(&live_trace, cluster);
        let layers = [&live_trace.layers[0]];
        let cur_ms = serving_estimate_ms(
            &self.active.0,
            &self.active.1,
            &layers,
            cluster,
            &self.cfg.topology,
        );
        let new_ms =
            serving_estimate_ms(&cand_rep, &cand_splits, &layers, cluster, &self.cfg.topology);
        let accept = match reason {
            // Repairs always commit: the current plan references (or is a
            // promoted stopgap around) a lost GPU, and the masked candidate
            // is the best deployment for the new membership — a gain gate
            // here would leave drains never vacated and failures
            // under-replicated. Degradation replans commit for the same
            // reason: the active plan was priced for rates that no longer
            // exist, and the effective-cluster candidate is the best
            // deployment for the rates that do.
            ReplanReason::Repair | ReplanReason::Degrade => true,
            // Growth must actually help (same hysteresis as the drift path).
            ReplanReason::Rebalance | ReplanReason::ScaleUp => {
                new_ms < cur_ms * (1.0 - self.cfg.min_gain)
            }
            // Consolidation trades a bounded slowdown for a freed GPU.
            ReplanReason::Consolidate { .. } => {
                new_ms <= cur_ms * (1.0 + self.cfg.consolidate_slack)
            }
        };
        self.pending = None;
        if !accept {
            if let ReplanReason::Consolidate { gpu } = reason {
                // Too expensive to shrink: cancel the drain, keep serving.
                self.health.apply(&ClusterEvent::GpuJoined(gpu));
                self.drained_by_coordinator[gpu] = false;
            }
            self.stats.skipped_gain += 1;
            self.gate_decision(
                "skipped_gain",
                drift,
                vec![
                    ("cur_ms", Json::Num(cur_ms)),
                    ("cand_ms", Json::Num(new_ms)),
                    ("pending", Json::from(reason.name())),
                ],
            );
            return CoordinatorDecision::Keep { drift };
        }
        let migration = plan_migration_avoiding(
            &self.active.0,
            &cand_rep,
            self.cfg.expert_weight_tokens,
            &self.health.banned_sources(),
        );
        let migration_ms = if migration.is_empty() {
            0.0
        } else {
            migration.migration_ms_on(cluster, &self.cfg.topology)
        };
        let predicted_gain_ms = (cur_ms - new_ms) * self.cfg.horizon_windows;
        // No amortized cost gate here: redundancy and capacity changes are
        // not optional optimizations. The migration is still priced and
        // reported — it just does not veto.
        if migration.is_empty() {
            self.active = (cand_rep, cand_splits);
        } else {
            let began = self.swap.begin(cand_rep, cand_splits, migration_ms);
            debug_assert!(began, "swap was checked idle above");
            self.staging_traffic = Some(migration.traffic.clone());
        }
        self.detector.rebase(est);
        self.windows_since_replan = 0;
        self.rejections = 0;
        self.stats.replans += 1;
        self.stats.migration_ms_total += migration_ms;
        let verdict = match reason {
            ReplanReason::Repair | ReplanReason::Rebalance => {
                self.stats.repairs += 1;
                "repair_replanned"
            }
            ReplanReason::ScaleUp => {
                self.stats.scale_ups += 1;
                "scaled_up"
            }
            ReplanReason::Consolidate { .. } => {
                self.stats.consolidations += 1;
                "consolidated"
            }
            ReplanReason::Degrade => {
                self.stats.degrade_replans += 1;
                self.windows_since_degrade_replan = 0;
                "degrade_replanned"
            }
        };
        self.gate_decision(
            verdict,
            drift,
            vec![
                ("reason", Json::from(reason.name())),
                ("cur_ms", Json::Num(cur_ms)),
                ("cand_ms", Json::Num(new_ms)),
                ("predicted_gain_ms", Json::Num(predicted_gain_ms)),
                ("migration_ms", Json::Num(migration_ms)),
                ("in_place", Json::from(migration.is_empty())),
            ],
        );
        CoordinatorDecision::Replan(Box::new(ReplanOutcome {
            drift,
            predicted_gain_ms,
            migration_ms,
            migration,
        }))
    }

    /// The plan currently serving.
    pub fn active(&self) -> (&ReplicatedDeployment, &SplitPlan) {
        (&self.active.0, &self.active.1)
    }

    /// Weight traffic currently staging over the links (charge it to the
    /// serving simulation as background contention), if any.
    pub fn staging_traffic(&self) -> Option<&TrafficMatrix> {
        if self.swap.phase() == SwapPhase::Staging {
            self.staging_traffic.as_ref()
        } else {
            None
        }
    }

    /// Current swap phase.
    pub fn swap_phase(&self) -> SwapPhase {
        self.swap.phase()
    }

    /// Drift of the current live estimate vs the active plan's baseline.
    pub fn current_drift(&self) -> f64 {
        self.detector.score(&self.estimator.estimate())
    }

    /// Advance serving time by `dt_ms`: drives the staging/drain clock and
    /// installs a staged plan at its atomic swap point.
    pub fn advance(&mut self, dt_ms: f64) {
        if let Some((rep, splits)) = self.swap.advance(dt_ms) {
            self.active = (rep, splits);
            self.stats.swaps += 1;
            self.windows_since_replan = 0;
        }
        if self.swap.phase() != SwapPhase::Staging {
            self.staging_traffic = None;
        }
    }

    /// Feed one serving window's observed expert-indexed traffic and run the
    /// replan pipeline: estimate → drift gate → candidate plan → hysteresis
    /// and cost gates → stage the migration.
    ///
    /// Panics when [`CoordinatorConfig::topology`] does not fit `cluster` —
    /// a deployment configuration error, reported as such instead of
    /// surfacing as a planner failure mid-replan.
    pub fn observe_window(
        &mut self,
        observed: &TrafficMatrix,
        cluster: &Cluster,
    ) -> CoordinatorDecision {
        if let Err(e) = self.cfg.topology.owners(cluster.len()) {
            panic!("CoordinatorConfig.topology does not fit the cluster: {e}");
        }
        self.stats.windows += 1;
        self.windows_since_replan += 1;
        self.windows_since_degrade_replan = self.windows_since_degrade_replan.saturating_add(1);
        let _sp = self.tracer.span("coordinator.observe_window");
        self.estimator.observe(observed);
        let est = self.estimator.estimate();
        let drift = self.detector.score(&est);

        // SLO watchdog: a rolling-p99 violation is an emergency trigger that
        // bypasses the drift, gain, and cost gates — only an in-flight swap
        // or the cooldown can suppress it.
        let slo_status = self.slo.as_ref().map(|m| (m.status(), m.target_p99_ms()));
        let slo_violating = slo_status.map(|(st, _)| st.violating).unwrap_or(false);
        let slo_fields = |extra: &mut Vec<(&str, Json)>| {
            if let Some((st, target)) = slo_status {
                extra.push(("slo_p50_ms", Json::Num(st.p50_ms)));
                extra.push(("slo_p95_ms", Json::Num(st.p95_ms)));
                extra.push(("slo_p99_ms", Json::Num(st.p99_ms)));
                extra.push(("slo_target_ms", Json::Num(target)));
                extra.push(("slo_burn_rate", Json::Num(st.burn_rate)));
            }
        };

        // Elasticity bookkeeping may queue a scale-up or consolidation;
        // membership events ([`Coordinator::inject_event`]) may already have
        // queued a repair or rebalance. Any pending membership replan takes
        // the dedicated path — it bypasses the drift gate entirely.
        if self.cfg.elastic {
            self.elastic_tick(slo_status.map(|(st, _)| st.burn_rate));
        }
        // A confirmed degradation transition queues its replan here, behind
        // the flap-damping cooldown: transitions inside the cooldown stay
        // dirty and run once it clears (membership replans take precedence).
        if self.degrade_dirty
            && self.pending.is_none()
            && self.windows_since_degrade_replan > self.cfg.degrade_cooldown_windows
        {
            self.degrade_dirty = false;
            self.pending = Some(ReplanReason::Degrade);
        }
        if let Some(reason) = self.pending {
            return self.pending_replan(reason, &est, drift, cluster);
        }

        if drift <= self.cfg.drift_threshold && !slo_violating {
            self.gate_decision("keep_low_drift", drift, vec![]);
            return CoordinatorDecision::Keep { drift };
        }
        if self.swap.is_busy() || self.windows_since_replan <= self.cfg.cooldown_windows {
            if slo_violating {
                self.stats.slo_suppressed += 1;
                let mut fields = vec![("swap_busy", Json::from(self.swap.is_busy()))];
                slo_fields(&mut fields);
                self.gate_decision("slo_suppressed_cooldown", drift, fields);
            } else {
                self.stats.skipped_cooldown += 1;
                self.gate_decision(
                    "skipped_cooldown",
                    drift,
                    vec![("swap_busy", Json::from(self.swap.is_busy()))],
                );
            }
            return CoordinatorDecision::Keep { drift };
        }

        // Candidate plan on the live estimate, under the health mask (after
        // a drain whose repair was rejected, drift/SLO replans must still
        // avoid placing on non-placeable GPUs) and on the effective cluster
        // (a drift replan while a straggler is confirmed must not hand the
        // hot experts back to the slow GPU).
        let eff = self.effective(cluster);
        let cluster = eff.as_ref();
        let live_trace = self.live_trace(est.clone());
        let (cand_rep, cand_splits) = self.plan_candidate(&live_trace, cluster);

        // Completion estimates of both plans on the *live* statistics,
        // topology-aware on both the gain and the cost side of the gate.
        let layers = [&live_trace.layers[0]];
        let cur_ms = serving_estimate_ms(
            &self.active.0,
            &self.active.1,
            &layers,
            cluster,
            &self.cfg.topology,
        );
        let new_ms =
            serving_estimate_ms(&cand_rep, &cand_splits, &layers, cluster, &self.cfg.topology);
        if !slo_violating && new_ms >= cur_ms * (1.0 - self.cfg.min_gain) {
            self.stats.skipped_gain += 1;
            self.note_rejection(&est);
            self.gate_decision(
                "skipped_gain",
                drift,
                vec![("cur_ms", Json::Num(cur_ms)), ("cand_ms", Json::Num(new_ms))],
            );
            return CoordinatorDecision::Keep { drift };
        }

        let migration = plan_migration_avoiding(
            &self.active.0,
            &cand_rep,
            self.cfg.expert_weight_tokens,
            &self.health.banned_sources(),
        );
        let migration_ms = if migration.is_empty() {
            0.0
        } else {
            migration.migration_ms_on(cluster, &self.cfg.topology)
        };
        // The staging window carries the weight volume on both collectives
        // of the serving model ([`crate::sim::simulate_window`]'s
        // conservative charge), so the cost side of the gate is twice the
        // one-way makespan.
        let staging_cost_ms = 2.0 * migration_ms;
        let predicted_gain_ms = (cur_ms - new_ms) * self.cfg.horizon_windows;
        if !slo_violating && predicted_gain_ms <= staging_cost_ms {
            self.stats.skipped_cost += 1;
            self.note_rejection(&est);
            self.gate_decision(
                "skipped_cost",
                drift,
                vec![
                    ("cur_ms", Json::Num(cur_ms)),
                    ("cand_ms", Json::Num(new_ms)),
                    ("predicted_gain_ms", Json::Num(predicted_gain_ms)),
                    ("staging_cost_ms", Json::Num(staging_cost_ms)),
                ],
            );
            return CoordinatorDecision::Keep { drift };
        }

        // Commit.
        if migration.is_empty() {
            // Every copy the candidate needs is already hosted — only split
            // weights (or primary labels) changed. No weights move, so the
            // swap is trivially atomic: install the candidate in place.
            self.active = (cand_rep, cand_splits);
        } else {
            // Stage the weights; the swap activates at the staging end.
            let began = self.swap.begin(cand_rep, cand_splits, migration_ms);
            debug_assert!(began, "swap was checked idle above");
            self.staging_traffic = Some(migration.traffic.clone());
        }
        self.detector.rebase(&est);
        self.windows_since_replan = 0;
        self.rejections = 0;
        self.stats.replans += 1;
        self.stats.migration_ms_total += migration_ms;
        let verdict = if slo_violating {
            // The replan answers a latency emergency: count it, and forget
            // the violating window so the fresh plan gets a clean reading
            // instead of re-triggering on stale samples.
            self.stats.slo_triggered += 1;
            if let Some(m) = self.slo.as_mut() {
                m.reset_window();
            }
            "slo_triggered"
        } else {
            "commit"
        };
        let mut fields = vec![
            ("cur_ms", Json::Num(cur_ms)),
            ("cand_ms", Json::Num(new_ms)),
            ("predicted_gain_ms", Json::Num(predicted_gain_ms)),
            ("migration_ms", Json::Num(migration_ms)),
            ("in_place", Json::from(migration.is_empty())),
        ];
        if slo_violating {
            slo_fields(&mut fields);
        }
        self.gate_decision(verdict, drift, fields);
        CoordinatorDecision::Replan(Box::new(ReplanOutcome {
            drift,
            predicted_gain_ms,
            migration_ms,
            migration,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::{drifting_zipf_traffic, zipf_traffic};

    const GATE_MS: f64 = 0.02;
    const FFN_MS_PER_TOKEN: f64 = 0.001;
    const AGG_MS: f64 = 0.015;

    fn layer(traffic: TrafficMatrix) -> MoeLayerStats {
        MoeLayerStats {
            traffic,
            gate_ms: GATE_MS,
            ffn_ms_per_token: FFN_MS_PER_TOKEN,
            agg_ms: AGG_MS,
        }
    }

    fn coordinator_for(traffic: TrafficMatrix, cluster: &Cluster) -> Coordinator {
        let stats = layer(traffic);
        let trace = ModelTrace {
            name: "plan".to_string(),
            layers: vec![stats.clone()],
        };
        let planner = Planner::default();
        let (rep, splits) = planner
            .plan_replicated(&[&trace], cluster, &ReplicationConfig::default())
            .unwrap();
        Coordinator::new(planner, rep, splits, &stats, CoordinatorConfig::default())
    }

    #[test]
    fn stationary_uniform_never_consults_the_planner() {
        let cluster = Cluster::homogeneous(8, 814.0);
        let uniform = zipf_traffic(16, 512, 0.0, 3);
        let mut coord = coordinator_for(uniform.clone(), &cluster);
        let before = coord.active().0.clone();
        for _ in 0..12 {
            let d = coord.observe_window(&uniform, &cluster);
            assert!(matches!(d, CoordinatorDecision::Keep { drift } if drift < 1e-9));
            coord.advance(1.0);
        }
        assert_eq!(coord.stats.replans, 0);
        assert_eq!(coord.stats.swaps, 0);
        assert_eq!(coord.active().0, &before);
    }

    #[test]
    fn rotated_hot_expert_triggers_a_cost_cleared_replan() {
        let cluster = Cluster::homogeneous(8, 814.0);
        let phase0 = drifting_zipf_traffic(16, 512, 1.2, 3, 0);
        let mut coord = coordinator_for(phase0, &cluster);
        // the hot expert rotates: feed the new regime until the EWMA and the
        // cooldown both clear
        let phase2 = drifting_zipf_traffic(16, 512, 1.2, 3, 2);
        let mut replanned = false;
        for w in 0..8 {
            let decision = coord.observe_window(&phase2, &cluster);
            coord.advance(5.0);
            if let CoordinatorDecision::Replan(outcome) = decision {
                assert!(outcome.drift > 0.1, "window {w}: drift {}", outcome.drift);
                assert!(outcome.migration_ms > 0.0);
                assert!(outcome.predicted_gain_ms > outcome.migration_ms);
                assert!(!outcome.migration.is_empty());
                replanned = true;
                break;
            }
        }
        assert!(replanned, "drifted hot expert must eventually replan");
        assert_eq!(coord.stats.replans, 1);
        // staging traffic is exposed until the swap point passes
        coord.advance(1e6);
        assert_eq!(coord.stats.swaps, 1);
        assert!(coord.staging_traffic().is_none());
        assert_eq!(coord.swap_phase(), SwapPhase::Serving);
        // after adopting the new regime the drift reads low again
        for _ in 0..6 {
            coord.observe_window(&phase2, &cluster);
            coord.advance(1.0);
        }
        assert!(coord.current_drift() < 0.1);
        assert_eq!(coord.stats.replans, 1, "no churn once adapted");
    }

    #[test]
    fn slo_violation_triggers_emergency_replan_even_at_zero_drift() {
        let cluster = Cluster::homogeneous(8, 814.0);
        let uniform = zipf_traffic(16, 512, 0.0, 3);
        let stats = layer(uniform.clone());
        let trace = ModelTrace {
            name: "plan".to_string(),
            layers: vec![stats.clone()],
        };
        let planner = Planner::default();
        let (rep, splits) = planner
            .plan_replicated(&[&trace], &cluster, &ReplicationConfig::default())
            .unwrap();
        let cfg = CoordinatorConfig {
            slo_p99_ms: Some(0.001),
            slo_window: 4,
            cooldown_windows: 0,
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(planner, rep, splits, &stats, cfg);
        let tracer = Tracer::sim();
        coord.set_tracer(tracer.clone());
        // stationary traffic: drift is ~0, so only the SLO can replan
        coord.record_window_latency(5.0);
        let d = coord.observe_window(&uniform, &cluster);
        assert!(matches!(d, CoordinatorDecision::Replan(_)));
        assert_eq!(coord.stats.slo_triggered, 1);
        let ds = tracer.decisions();
        let triggered = ds
            .iter()
            .find(|r| r.get("verdict").and_then(Json::as_str) == Some("slo_triggered"))
            .expect("slo_triggered decision recorded");
        assert!(triggered.get("slo_p99_ms").is_some());
        // the monitor window reset at the commit
        assert!(!coord.slo().unwrap().is_violating());
    }

    #[test]
    fn slo_violation_suppressed_inside_cooldown() {
        let cluster = Cluster::homogeneous(8, 814.0);
        let uniform = zipf_traffic(16, 512, 0.0, 3);
        let stats = layer(uniform.clone());
        let trace = ModelTrace {
            name: "plan".to_string(),
            layers: vec![stats.clone()],
        };
        let planner = Planner::default();
        let (rep, splits) = planner
            .plan_replicated(&[&trace], &cluster, &ReplicationConfig::default())
            .unwrap();
        let cfg = CoordinatorConfig {
            slo_p99_ms: Some(0.001),
            slo_window: 4,
            cooldown_windows: 100,
            ..CoordinatorConfig::default()
        };
        let mut coord = Coordinator::new(planner, rep, splits, &stats, cfg);
        let tracer = Tracer::sim();
        coord.set_tracer(tracer.clone());
        coord.record_window_latency(5.0);
        let d = coord.observe_window(&uniform, &cluster);
        assert!(matches!(d, CoordinatorDecision::Keep { .. }));
        assert_eq!(coord.stats.slo_suppressed, 1);
        assert_eq!(coord.stats.replans, 0);
        assert!(tracer.decisions().iter().any(|r| {
            r.get("verdict").and_then(Json::as_str) == Some("slo_suppressed_cooldown")
        }));
    }

    #[test]
    fn no_slo_config_means_no_watchdog() {
        let cluster = Cluster::homogeneous(8, 814.0);
        let uniform = zipf_traffic(16, 512, 0.0, 3);
        let mut coord = coordinator_for(uniform.clone(), &cluster);
        assert!(coord.slo().is_none());
        coord.record_window_latency(1e9); // swallowed: no monitor
        let d = coord.observe_window(&uniform, &cluster);
        assert!(matches!(d, CoordinatorDecision::Keep { .. }));
        assert_eq!(coord.stats.slo_triggered, 0);
        assert_eq!(coord.stats.slo_suppressed, 0);
    }

    #[test]
    fn busy_swap_defers_further_replans() {
        let cluster = Cluster::homogeneous(8, 814.0);
        let phase0 = drifting_zipf_traffic(16, 512, 1.2, 3, 0);
        let mut coord = coordinator_for(phase0, &cluster);
        let phase2 = drifting_zipf_traffic(16, 512, 1.2, 3, 2);
        // drive to the replan without advancing time: the swap stays staged
        let mut committed = false;
        for _ in 0..8 {
            let d = coord.observe_window(&phase2, &cluster);
            if matches!(d, CoordinatorDecision::Replan(_)) {
                committed = true;
                break;
            }
            coord.advance(5.0);
        }
        assert!(committed);
        assert_eq!(coord.swap_phase(), SwapPhase::Staging);
        assert!(coord.staging_traffic().is_some());
        // a further drifted regime cannot preempt the in-flight swap
        let phase4 = drifting_zipf_traffic(16, 512, 1.2, 3, 4);
        let skipped_before = coord.stats.skipped_cooldown;
        for _ in 0..3 {
            let d = coord.observe_window(&phase4, &cluster);
            assert!(matches!(d, CoordinatorDecision::Keep { .. }));
        }
        assert!(coord.stats.skipped_cooldown > skipped_before);
        assert_eq!(coord.stats.replans, 1);
    }

    fn coordinator_with(
        traffic: TrafficMatrix,
        cluster: &Cluster,
        cfg: CoordinatorConfig,
    ) -> Coordinator {
        let stats = layer(traffic);
        let trace = ModelTrace {
            name: "plan".to_string(),
            layers: vec![stats.clone()],
        };
        let planner = Planner::default();
        let (rep, splits) = planner
            .plan_replicated(&[&trace], cluster, &ReplicationConfig::default())
            .unwrap();
        Coordinator::new(planner, rep, splits, &stats, cfg)
    }

    #[test]
    fn gpu_failure_promotes_survivors_then_repairs() {
        let cluster = Cluster::homogeneous(8, 814.0);
        let skew = zipf_traffic(16, 512, 1.2, 3);
        let cfg = CoordinatorConfig {
            cooldown_windows: 0,
            ..CoordinatorConfig::default()
        };
        let mut coord = coordinator_with(skew.clone(), &cluster, cfg);
        let tracer = Tracer::sim();
        coord.set_tracer(tracer.clone());

        coord.inject_event(&ClusterEvent::GpuFailed(2), &cluster);
        // phase 1, same call: the active plan no longer references GPU 2
        let (rep, _) = coord.active();
        for (e, set) in rep.replicas[0].iter().enumerate() {
            assert!(!set.contains(&2), "expert {e} still on the dead GPU");
        }
        for &g in &rep.base.assignments[0] {
            assert_ne!(g, 2);
        }
        assert_eq!(coord.stats.failures, 1);
        assert!(!coord.health().is_alive(2));
        // idempotent: re-failing a dead GPU changes nothing
        coord.inject_event(&ClusterEvent::GpuFailed(2), &cluster);
        assert_eq!(coord.stats.failures, 1);

        // phase 2: the queued repair replans on the next window, bypassing
        // the drift gate (traffic is stationary, drift ≈ 0)
        let d = coord.observe_window(&skew, &cluster);
        let CoordinatorDecision::Replan(out) = d else {
            panic!("repair must replan");
        };
        for f in &out.migration.flows {
            assert_ne!(f.src, 2, "dead GPUs never source repairs");
        }
        assert_eq!(coord.stats.repairs, 1);
        let verdicts: Vec<String> = tracer
            .decisions()
            .iter()
            .filter_map(|r| r.get("verdict").and_then(Json::as_str).map(str::to_string))
            .collect();
        let p = verdicts.iter().position(|v| v == "repair_promoted").unwrap();
        let r = verdicts.iter().position(|v| v == "repair_replanned").unwrap();
        assert!(p < r, "promotion precedes the repair replan");
    }

    #[test]
    fn drain_vacates_the_gpu_over_the_migration_path() {
        let cluster = Cluster::homogeneous(8, 814.0);
        let skew = zipf_traffic(16, 512, 1.2, 3);
        let cfg = CoordinatorConfig {
            cooldown_windows: 0,
            ..CoordinatorConfig::default()
        };
        let mut coord = coordinator_with(skew.clone(), &cluster, cfg);
        coord.inject_event(&ClusterEvent::GpuDrained(3), &cluster);
        assert!(coord.health().is_alive(3) && !coord.health().is_placeable(3));
        let d = coord.observe_window(&skew, &cluster);
        assert!(matches!(d, CoordinatorDecision::Replan(_)), "drain repair always commits");
        coord.advance(1e6); // staging completes, the swap lands
        let (rep, _) = coord.active();
        for set in &rep.replicas[0] {
            assert!(!set.contains(&3), "the drained GPU was vacated");
        }
        assert!(coord.health().is_alive(3), "draining is graceful — the GPU never died");
    }

    #[test]
    fn sustained_slo_burn_grows_the_replica_budget() {
        let cluster = Cluster::homogeneous(8, 814.0);
        let uniform = zipf_traffic(16, 512, 0.0, 3);
        let cfg = CoordinatorConfig {
            elastic: true,
            elastic_patience: 2,
            slo_p99_ms: Some(0.001),
            slo_window: 4,
            cooldown_windows: 0,
            ..CoordinatorConfig::default()
        };
        let mut coord = coordinator_with(uniform.clone(), &cluster, cfg);
        let tracer = Tracer::sim();
        coord.set_tracer(tracer.clone());
        let budget0 = coord.cfg.replication.max_replicas;
        for _ in 0..6 {
            coord.record_window_latency(5.0); // hopelessly over the target
            coord.observe_window(&uniform, &cluster);
            coord.advance(1e6);
        }
        assert!(
            coord.cfg.replication.max_replicas > budget0,
            "sustained burn grows the replica budget"
        );
        let considered = tracer.decisions().iter().any(|r| {
            r.get("verdict").and_then(Json::as_str) == Some("scaled_up")
                || r.get("pending").and_then(Json::as_str) == Some("scale_up")
                || r.get("reason").and_then(Json::as_str) == Some("scale_up")
        });
        assert!(considered, "a scale-up replan was at least considered");
    }

    #[test]
    fn sustained_idle_considers_consolidation_and_rolls_back_on_reject() {
        let cluster = Cluster::homogeneous(8, 814.0);
        let uniform = zipf_traffic(16, 512, 0.0, 3);
        let cfg = CoordinatorConfig {
            elastic: true,
            elastic_patience: 2,
            cooldown_windows: 0,
            ..CoordinatorConfig::default()
        };
        let mut coord = coordinator_with(uniform.clone(), &cluster, cfg);
        let tracer = Tracer::sim();
        coord.set_tracer(tracer.clone());
        for _ in 0..4 {
            coord.record_window_utilization(0.05);
            coord.observe_window(&uniform, &cluster);
            coord.advance(1e6);
        }
        let considered = tracer.decisions().iter().any(|r| {
            r.get("verdict").and_then(Json::as_str) == Some("consolidated")
                || r.get("pending").and_then(Json::as_str) == Some("consolidate")
        });
        assert!(considered, "low utilization must at least consider shrinking");
        if coord.stats.consolidations > 0 {
            assert!(coord.health().n_placeable() < 8);
            let (rep, _) = coord.active();
            for set in &rep.replicas[0] {
                for &g in set {
                    assert!(coord.health().is_placeable(g), "copies only on placeable GPUs");
                }
            }
        } else {
            // every attempt was too expensive: the drains rolled back
            assert!(coord.health().all_placeable());
        }
    }

    #[test]
    fn confirmed_degradation_replans_on_the_effective_cluster() {
        let cluster = Cluster::homogeneous(8, 814.0);
        let skew = zipf_traffic(16, 512, 1.2, 3);
        let cfg = CoordinatorConfig {
            cooldown_windows: 0,
            degrade_cooldown_windows: 0,
            ..CoordinatorConfig::default()
        };
        let mut coord = coordinator_with(skew.clone(), &cluster, cfg);
        let tracer = Tracer::sim();
        coord.set_tracer(tracer.clone());

        let mut scales = GpuScales::nominal(8);
        scales.set(2, 0.4, 1.0);
        coord.observe_degradation(
            &[DetectorEvent::Degraded {
                gpu: 2,
                compute_scale: 0.4,
                bandwidth_scale: 1.0,
            }],
            &scales,
            &cluster,
        );
        assert_eq!(coord.stats.degrade_detected, 1);
        assert_eq!(coord.effective_scales().compute[2], 0.4);

        let d = coord.observe_window(&skew, &cluster);
        assert!(matches!(d, CoordinatorDecision::Replan(_)), "degrade replans always commit");
        assert_eq!(coord.stats.degrade_replans, 1);
        let verdicts: Vec<String> = tracer
            .decisions()
            .iter()
            .filter_map(|r| r.get("verdict").and_then(Json::as_str).map(str::to_string))
            .collect();
        let det = verdicts.iter().position(|v| v == "degrade_detected").unwrap();
        let rep = verdicts.iter().position(|v| v == "degrade_replanned").unwrap();
        assert!(det < rep, "detection strictly precedes the replan");
        // the straggler stays alive — degradation is gray, not a failure
        assert!(coord.health().all_placeable());

        // recovery: scales return to nominal, one more always-commit replan
        coord.advance(1e6);
        coord.observe_degradation(&[DetectorEvent::Recovered { gpu: 2 }], &GpuScales::nominal(8), &cluster);
        assert_eq!(coord.stats.degrade_recovered, 1);
        assert!(coord.effective_scales().is_nominal());
        let d = coord.observe_window(&skew, &cluster);
        assert!(matches!(d, CoordinatorDecision::Replan(_)));
        assert_eq!(coord.stats.degrade_replans, 2);
        assert!(tracer
            .decisions()
            .iter()
            .any(|r| r.get("verdict").and_then(Json::as_str) == Some("degrade_recovered")));
    }

    #[test]
    fn degradation_below_the_floor_escalates_to_failure() {
        let cluster = Cluster::homogeneous(8, 814.0);
        let skew = zipf_traffic(16, 512, 1.2, 3);
        let cfg = CoordinatorConfig {
            cooldown_windows: 0,
            ..CoordinatorConfig::default()
        };
        let mut coord = coordinator_with(skew.clone(), &cluster, cfg);
        let tracer = Tracer::sim();
        coord.set_tracer(tracer.clone());
        let mut scales = GpuScales::nominal(8);
        scales.set(5, 0.1, 1.0); // below the 0.25 default floor
        coord.observe_degradation(
            &[DetectorEvent::Degraded {
                gpu: 5,
                compute_scale: 0.1,
                bandwidth_scale: 1.0,
            }],
            &scales,
            &cluster,
        );
        assert_eq!(coord.stats.escalations, 1);
        assert_eq!(coord.stats.failures, 1, "escalation runs the failure path");
        assert!(!coord.health().is_alive(5));
        // the dead GPU's scales are moot — the health mask prices it out
        assert_eq!(coord.effective_scales().compute[5], 1.0);
        let (rep, _) = coord.active();
        for set in &rep.replicas[0] {
            assert!(!set.contains(&5), "escalated GPU already evacuated");
        }
        let verdicts: Vec<String> = tracer
            .decisions()
            .iter()
            .filter_map(|r| r.get("verdict").and_then(Json::as_str).map(str::to_string))
            .collect();
        assert!(verdicts.contains(&"degrade_detected".to_string()));
        assert!(verdicts.contains(&"repair_promoted".to_string()));
        // the queued repair commits as usual
        let d = coord.observe_window(&skew, &cluster);
        assert!(matches!(d, CoordinatorDecision::Replan(_)));
        assert_eq!(coord.stats.repairs, 1);
    }

    #[test]
    fn degrade_cooldown_damps_flapping() {
        let cluster = Cluster::homogeneous(8, 814.0);
        let skew = zipf_traffic(16, 512, 1.2, 3);
        let cfg = CoordinatorConfig {
            cooldown_windows: 0,
            degrade_cooldown_windows: 10,
            ..CoordinatorConfig::default()
        };
        let mut coord = coordinator_with(skew.clone(), &cluster, cfg);
        let mut scales = GpuScales::nominal(8);
        scales.set(1, 0.5, 1.0);
        coord.observe_degradation(
            &[DetectorEvent::Degraded {
                gpu: 1,
                compute_scale: 0.5,
                bandwidth_scale: 1.0,
            }],
            &scales,
            &cluster,
        );
        let d = coord.observe_window(&skew, &cluster);
        assert!(matches!(d, CoordinatorDecision::Replan(_)));
        assert_eq!(coord.stats.degrade_replans, 1);
        coord.advance(1e6);
        // a flapping detector inside the cooldown queues but never commits
        for w in 0..5 {
            let (evs, s) = if w % 2 == 0 {
                (vec![DetectorEvent::Recovered { gpu: 1 }], GpuScales::nominal(8))
            } else {
                (
                    vec![DetectorEvent::Degraded {
                        gpu: 1,
                        compute_scale: 0.5,
                        bandwidth_scale: 1.0,
                    }],
                    scales.clone(),
                )
            };
            coord.observe_degradation(&evs, &s, &cluster);
            coord.observe_window(&skew, &cluster);
            coord.advance(1e6);
        }
        assert_eq!(
            coord.stats.degrade_replans, 1,
            "flapping inside the cooldown must not storm replans"
        );
    }

    #[test]
    fn join_of_a_placeable_gpu_is_a_no_op_and_rejoin_queues_rebalance() {
        let cluster = Cluster::homogeneous(8, 814.0);
        let skew = zipf_traffic(16, 512, 1.2, 3);
        let cfg = CoordinatorConfig {
            cooldown_windows: 0,
            ..CoordinatorConfig::default()
        };
        let mut coord = coordinator_with(skew.clone(), &cluster, cfg);
        coord.inject_event(&ClusterEvent::GpuJoined(1), &cluster);
        assert_eq!(coord.pending, None, "joining a healthy GPU changes nothing");

        coord.inject_event(&ClusterEvent::GpuFailed(5), &cluster);
        assert_eq!(coord.pending, Some(ReplanReason::Repair));
        let d = coord.observe_window(&skew, &cluster);
        assert!(matches!(d, CoordinatorDecision::Replan(_)));
        coord.advance(1e6);

        coord.inject_event(&ClusterEvent::GpuJoined(5), &cluster);
        assert_eq!(coord.pending, Some(ReplanReason::Rebalance));
        assert!(coord.health().all_placeable());
    }
}
