//! Adaptive replanning — the paper's §10 future-work direction, built as a
//! first-class feature: watch the live expert-routing distribution drift away
//! from the statistics the current plan was optimized for, and trigger a
//! replan when the drift exceeds a threshold.
//!
//! Drift is measured as total-variation distance between the normalized
//! expert-load histogram the plan was built on and the histogram observed in
//! the current window. Q4 of the evaluation (Fig. 14) shows Aurora tolerates
//! ≤ 75% imprecision with ≤ 15.8% degradation, so the default threshold
//! (0.25) replans long before the plan decays materially.
//!
//! This watcher is the lightweight in-engine trigger. The full cost-aware
//! loop — EWMA traffic estimation, replan hysteresis, migration costing over
//! the slot scheduler, and the hitless plan swap — lives one layer up in
//! [`crate::coordinator`].

use crate::placement::Deployment;
use crate::replication::{ReplicatedDeployment, SplitPlan};

/// Decision returned by [`AdaptiveReplanner::observe`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplanDecision {
    /// Keep the current plan.
    Keep,
    /// The routing distribution drifted past the threshold — replan.
    Replan,
}

/// Watches expert-routing drift over fixed-size observation windows.
#[derive(Debug, Clone)]
pub struct AdaptiveReplanner {
    /// Normalized expert distribution the current plan assumed.
    baseline: Vec<f64>,
    /// Total-variation threshold in `[0, 1]` that triggers a replan.
    pub threshold: f64,
    /// Tokens per observation window.
    pub window_tokens: u64,
    window: Vec<u64>,
    window_total: u64,
    replans: u64,
}

impl AdaptiveReplanner {
    /// Start from the plan's assumed expert loads (unnormalized is fine).
    pub fn new(plan_loads: &[u64], threshold: f64, window_tokens: u64) -> Self {
        assert!(!plan_loads.is_empty());
        assert!((0.0..=1.0).contains(&threshold));
        assert!(window_tokens > 0);
        Self {
            baseline: normalize(plan_loads),
            threshold,
            window_tokens,
            window: vec![0; plan_loads.len()],
            window_total: 0,
            replans: 0,
        }
    }

    /// Defaults tuned to the Fig. 14 robustness envelope.
    pub fn with_defaults(plan_loads: &[u64]) -> Self {
        Self::new(plan_loads, 0.25, 4096)
    }

    /// Watch a generalized placement: the baseline is the **per-GPU**
    /// aggregated load distribution the deployment was optimized for (what
    /// actually decays when routing drifts is the GPU-group balance, not any
    /// single expert's share). Feed observations through
    /// [`AdaptiveReplanner::observe_deployment`].
    pub fn for_deployment(
        deployment: &Deployment,
        model: usize,
        plan_expert_loads: &[u64],
    ) -> Self {
        Self::with_defaults(&deployment.gpu_loads(model, plan_expert_loads))
    }

    /// [`AdaptiveReplanner::observe`] for deployment-watching replanners:
    /// aggregates a per-expert batch histogram into per-GPU loads first.
    pub fn observe_deployment(
        &mut self,
        deployment: &Deployment,
        model: usize,
        batch_histogram: &[u64],
    ) -> ReplanDecision {
        self.observe(&deployment.gpu_loads(model, batch_histogram))
    }

    /// Watch a **replicated** placement: the baseline is the per-GPU load
    /// distribution the deployment-plus-split-plan was optimized for, so
    /// routing drift *within* a replica set (absorbed by the token splitter)
    /// does not trigger replans — only drift that unbalances the GPUs does.
    pub fn for_replicated(
        rep: &ReplicatedDeployment,
        plan: &SplitPlan,
        model: usize,
        plan_expert_loads: &[u64],
    ) -> Self {
        Self::with_defaults(&rep.gpu_loads_split(model, plan_expert_loads, plan))
    }

    /// [`AdaptiveReplanner::observe`] for replicated deployments: splits the
    /// batch histogram across replicas by the plan weights before comparing
    /// per-GPU loads against the baseline.
    pub fn observe_replicated(
        &mut self,
        rep: &ReplicatedDeployment,
        plan: &SplitPlan,
        model: usize,
        batch_histogram: &[u64],
    ) -> ReplanDecision {
        self.observe(&rep.gpu_loads_split(model, batch_histogram, plan))
    }

    /// Number of replans triggered so far.
    pub fn replans(&self) -> u64 {
        self.replans
    }

    /// Current drift of the (partial) window vs the baseline.
    pub fn current_drift(&self) -> f64 {
        if self.window_total == 0 {
            return 0.0;
        }
        total_variation(&self.baseline, &normalize(&self.window))
    }

    /// Feed one batch's expert histogram. Returns [`ReplanDecision::Replan`]
    /// when a full window has drifted past the threshold; the caller is then
    /// expected to re-run the [`crate::planner::Planner`] on fresh statistics
    /// and call [`AdaptiveReplanner::replanned`].
    pub fn observe(&mut self, batch_histogram: &[u64]) -> ReplanDecision {
        assert_eq!(batch_histogram.len(), self.window.len());
        for (w, &h) in self.window.iter_mut().zip(batch_histogram) {
            *w += h;
        }
        self.window_total += batch_histogram.iter().sum::<u64>();
        if self.window_total < self.window_tokens {
            return ReplanDecision::Keep;
        }
        let drift = total_variation(&self.baseline, &normalize(&self.window));
        let decision = if drift > self.threshold {
            ReplanDecision::Replan
        } else {
            ReplanDecision::Keep
        };
        // roll the window
        self.window.iter_mut().for_each(|w| *w = 0);
        self.window_total = 0;
        decision
    }

    /// Adopt the distribution the new plan was built on.
    pub fn replanned(&mut self, new_plan_loads: &[u64]) {
        assert_eq!(new_plan_loads.len(), self.baseline.len());
        self.baseline = normalize(new_plan_loads);
        self.replans += 1;
    }
}

fn normalize(counts: &[u64]) -> Vec<f64> {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return vec![1.0 / counts.len() as f64; counts.len()];
    }
    counts.iter().map(|&c| c as f64 / total as f64).collect()
}

fn total_variation(p: &[f64], q: &[f64]) -> f64 {
    0.5 * p.iter().zip(q).map(|(a, b)| (a - b).abs()).sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_distribution_never_replans() {
        let mut r = AdaptiveReplanner::new(&[10, 20, 30, 40], 0.2, 100);
        for _ in 0..50 {
            assert_eq!(r.observe(&[1, 2, 3, 4]), ReplanDecision::Keep);
        }
        assert_eq!(r.replans(), 0);
    }

    #[test]
    fn strong_drift_triggers_replan_after_one_window() {
        let mut r = AdaptiveReplanner::new(&[10, 10, 10, 10], 0.2, 40);
        // all traffic suddenly routes to expert 0
        let mut decisions = Vec::new();
        for _ in 0..4 {
            decisions.push(r.observe(&[10, 0, 0, 0]));
        }
        assert!(decisions.contains(&ReplanDecision::Replan));
    }

    #[test]
    fn replanned_adopts_new_baseline() {
        let mut r = AdaptiveReplanner::new(&[10, 10], 0.2, 20);
        assert_eq!(r.observe(&[20, 0]), ReplanDecision::Replan);
        r.replanned(&[20, 0]);
        assert_eq!(r.replans(), 1);
        // the drifted distribution is now the baseline: no more replans
        assert_eq!(r.observe(&[20, 0]), ReplanDecision::Keep);
    }

    #[test]
    fn drift_metric_bounds() {
        let mut r = AdaptiveReplanner::new(&[5, 5], 0.5, 1000);
        assert_eq!(r.current_drift(), 0.0);
        r.observe(&[10, 0]);
        let d = r.current_drift();
        assert!((0.0..=1.0).contains(&d));
        assert!((d - 0.5).abs() < 1e-12); // TV([0.5,0.5],[1,0]) = 0.5
    }

    #[test]
    fn zero_window_distribution_is_uniform() {
        let r = AdaptiveReplanner::with_defaults(&[0, 0, 0]);
        assert_eq!(r.current_drift(), 0.0);
    }

    #[test]
    #[should_panic]
    fn mismatched_histogram_panics() {
        let mut r = AdaptiveReplanner::with_defaults(&[1, 2]);
        r.observe(&[1, 2, 3]);
    }

    #[test]
    fn replicated_watcher_absorbs_intra_replica_drift() {
        use crate::placement::{Deployment, Scenario};
        use crate::replication::{ReplicatedDeployment, SplitPlan};
        use crate::schedule::SchedulePolicy;
        // 2 experts on 2 GPUs; expert 0 is replicated on both with a 50/50
        // split, expert 1 lives on GPU 1 only.
        let base = Deployment::new(
            2,
            vec![vec![0, 1]],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        let mut rep = ReplicatedDeployment::from_deployment(base);
        rep.add_replica(0, 0, 1).unwrap();
        let mut plan = SplitPlan::trivial(&rep);
        plan.weights[0][0] = vec![0.5, 0.5];
        // plan assumed 20/20: per-GPU baseline [10, 30]
        let mut r = AdaptiveReplanner::for_replicated(&rep, &plan, 0, &[20, 20]);
        r.window_tokens = 40;
        r.threshold = 0.2;
        // all of expert 1's traffic flips onto expert 0: the split absorbs
        // half of it onto GPU 1, so per-GPU loads stay [20, 20] vs baseline
        // [10, 30] -> TV = 0.25 > 0.2 -> replan
        assert_eq!(
            r.observe_replicated(&rep, &plan, 0, &[40, 0]),
            ReplanDecision::Replan
        );
        // matching the plan's histogram keeps the baseline
        assert_eq!(
            r.observe_replicated(&rep, &plan, 0, &[20, 20]),
            ReplanDecision::Keep
        );
    }

    #[test]
    fn deployment_watcher_tracks_gpu_groups_not_experts() {
        use crate::placement::{Deployment, Scenario};
        use crate::schedule::SchedulePolicy;
        // 4 experts on 2 GPUs: {0,1} on GPU 0, {2,3} on GPU 1.
        let dep = Deployment::new(
            2,
            vec![vec![0, 0, 1, 1]],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        let mut r = AdaptiveReplanner::for_deployment(&dep, 0, &[10, 10, 10, 10]);
        r.window_tokens = 40;
        r.threshold = 0.2;
        // routing flips between experts *within* each GPU group: per-GPU
        // loads are unchanged, so the placement has not decayed -> keep.
        assert_eq!(
            r.observe_deployment(&dep, 0, &[20, 0, 0, 20]),
            ReplanDecision::Keep
        );
        // all traffic collapses onto GPU 0's experts -> replan.
        assert_eq!(
            r.observe_deployment(&dep, 0, &[20, 20, 0, 0]),
            ReplanDecision::Replan
        );
    }
}
