//! Dynamic batching: group requests up to a token budget or a deadline.
//!
//! Pure logic (no threads) so invariants are directly testable: the engine
//! worker drives it with `push` / `flush_due`. Deadline behavior is
//! clock-injectable ([`Clock`]) — production uses the wall clock
//! ([`SystemClock`]); tests and the coordinator's serving simulation drive a
//! [`ManualClock`] deterministically instead of sleeping.

use super::Request;
use std::cell::Cell;
use std::rc::Rc;
use std::time::{Duration, Instant};

/// Time source for deadline decisions.
pub trait Clock: std::fmt::Debug {
    /// The current instant.
    fn now(&self) -> Instant;
}

/// The real wall clock ([`Instant::now`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct SystemClock;

impl Clock for SystemClock {
    fn now(&self) -> Instant {
        Instant::now()
    }
}

/// A manually-advanced clock. Clones share the same time, so a test keeps
/// one handle and advances the batcher's view of time deterministically.
#[derive(Debug, Clone)]
pub struct ManualClock(Rc<Cell<Instant>>);

impl ManualClock {
    /// New clock frozen at the current instant.
    pub fn new() -> ManualClock {
        ManualClock(Rc::new(Cell::new(Instant::now())))
    }

    /// Move time forward by `d` for every clone of this clock.
    pub fn advance(&self, d: Duration) {
        self.0.set(self.0.get() + d);
    }
}

impl Default for ManualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for ManualClock {
    fn now(&self) -> Instant {
        self.0.get()
    }
}

/// Batching policy knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatcherConfig {
    /// Maximum total tokens per batch (bounded by the compiled capacity).
    pub max_batch_tokens: usize,
    /// Maximum number of requests per batch.
    pub max_batch_requests: usize,
    /// Maximum time the oldest request may wait before the batch is cut.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch_tokens: 64,
            max_batch_requests: 16,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// A cut batch: requests plus the arrival time of its oldest member.
#[derive(Debug)]
pub struct Batch {
    /// The requests in arrival order.
    pub requests: Vec<Request>,
    /// Total token rows across requests.
    pub total_tokens: usize,
    /// Arrival instant of the oldest request (for queueing-latency metrics).
    pub oldest_arrival: Instant,
}

/// Token-budgeted, deadline-bounded batcher.
#[derive(Debug)]
pub struct DynamicBatcher<C: Clock = SystemClock> {
    cfg: BatcherConfig,
    pending: Vec<(Request, Instant)>,
    pending_tokens: usize,
    clock: C,
}

impl DynamicBatcher {
    /// New empty batcher on the wall clock.
    pub fn new(cfg: BatcherConfig) -> DynamicBatcher {
        DynamicBatcher::with_clock(cfg, SystemClock)
    }
}

impl<C: Clock> DynamicBatcher<C> {
    /// New empty batcher with an injected time source.
    pub fn with_clock(cfg: BatcherConfig, clock: C) -> DynamicBatcher<C> {
        assert!(cfg.max_batch_tokens > 0 && cfg.max_batch_requests > 0);
        DynamicBatcher {
            cfg,
            pending: Vec::new(),
            pending_tokens: 0,
            clock,
        }
    }

    /// Number of queued requests.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// [`DynamicBatcher::push`] stamped with the injected clock.
    pub fn push_now(&mut self, req: Request) -> Result<Option<Batch>, Request> {
        let now = self.clock.now();
        self.push(req, now)
    }

    /// [`DynamicBatcher::flush_due`] evaluated at the injected clock's time.
    pub fn flush_due_now(&mut self) -> Option<Batch> {
        let now = self.clock.now();
        self.flush_due(now)
    }

    /// Add a request; returns a cut batch when a budget fills.
    ///
    /// A request larger than the whole token budget is rejected back to the
    /// caller as `Err` (it can never be served by the compiled capacity).
    pub fn push(&mut self, req: Request, now: Instant) -> Result<Option<Batch>, Request> {
        if req.n_tokens > self.cfg.max_batch_tokens {
            return Err(req);
        }
        // Cut *before* adding if this request would overflow the budget.
        let would_overflow = self.pending_tokens + req.n_tokens > self.cfg.max_batch_tokens;
        let mut cut = None;
        if would_overflow && !self.pending.is_empty() {
            cut = Some(self.cut());
        }
        self.pending_tokens += req.n_tokens;
        self.pending.push((req, now));
        if cut.is_none()
            && (self.pending.len() >= self.cfg.max_batch_requests
                || self.pending_tokens == self.cfg.max_batch_tokens)
        {
            cut = Some(self.cut());
        }
        Ok(cut)
    }

    /// Cut the current batch if the oldest request has waited past the
    /// deadline (drives tail latency under light load).
    pub fn flush_due(&mut self, now: Instant) -> Option<Batch> {
        let oldest = self.pending.first().map(|(_, t)| *t)?;
        if now.duration_since(oldest) >= self.cfg.max_wait {
            Some(self.cut())
        } else {
            None
        }
    }

    /// Unconditionally cut whatever is pending (used at shutdown).
    pub fn flush_all(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            None
        } else {
            Some(self.cut())
        }
    }

    fn cut(&mut self) -> Batch {
        let oldest_arrival = self.pending.first().map(|(_, t)| *t).unwrap();
        let requests: Vec<Request> = self.pending.drain(..).map(|(r, _)| r).collect();
        let total_tokens = requests.iter().map(|r| r.n_tokens).sum();
        self.pending_tokens = 0;
        Batch {
            requests,
            total_tokens,
            oldest_arrival,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, n_tokens: usize) -> Request {
        Request::new(id, vec![0.5; n_tokens * 4], 4)
    }

    fn cfg(tokens: usize, reqs: usize, wait_ms: u64) -> BatcherConfig {
        BatcherConfig {
            max_batch_tokens: tokens,
            max_batch_requests: reqs,
            max_wait: Duration::from_millis(wait_ms),
        }
    }

    #[test]
    fn cuts_when_token_budget_fills_exactly() {
        let mut b = DynamicBatcher::new(cfg(8, 100, 1000));
        let now = Instant::now();
        assert!(b.push(req(1, 4), now).unwrap().is_none());
        let batch = b.push(req(2, 4), now).unwrap().unwrap();
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.total_tokens, 8);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn overflow_cuts_previous_batch_and_keeps_new_request() {
        let mut b = DynamicBatcher::new(cfg(8, 100, 1000));
        let now = Instant::now();
        assert!(b.push(req(1, 6), now).unwrap().is_none());
        let batch = b.push(req(2, 6), now).unwrap().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.requests[0].id, 1);
        assert_eq!(b.pending_len(), 1); // request 2 waits for the next cut
    }

    #[test]
    fn cuts_on_request_count() {
        let mut b = DynamicBatcher::new(cfg(100, 3, 1000));
        let now = Instant::now();
        assert!(b.push(req(1, 1), now).unwrap().is_none());
        assert!(b.push(req(2, 1), now).unwrap().is_none());
        let batch = b.push(req(3, 1), now).unwrap().unwrap();
        assert_eq!(batch.requests.len(), 3);
    }

    #[test]
    fn deadline_flush() {
        let mut b = DynamicBatcher::new(cfg(100, 100, 5));
        let t0 = Instant::now();
        assert!(b.push(req(1, 2), t0).unwrap().is_none());
        assert!(b.flush_due(t0).is_none());
        let later = t0 + Duration::from_millis(6);
        let batch = b.flush_due(later).unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert!(b.flush_due(later).is_none()); // empty now
    }

    #[test]
    fn manual_clock_drives_deadlines_deterministically() {
        let clock = ManualClock::new();
        let mut b = DynamicBatcher::with_clock(cfg(100, 100, 5), clock.clone());
        assert!(b.push_now(req(1, 2)).unwrap().is_none());
        // no wall time passes in this test, only the manual clock moves
        assert!(b.flush_due_now().is_none());
        clock.advance(Duration::from_millis(4));
        assert!(b.flush_due_now().is_none());
        clock.advance(Duration::from_millis(1));
        let batch = b.flush_due_now().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert!(b.flush_due_now().is_none());
    }

    #[test]
    fn system_clock_batcher_still_constructs() {
        let mut b = DynamicBatcher::new(cfg(8, 4, 1000));
        assert!(b.push_now(req(1, 4)).unwrap().is_none());
        let batch = b.push_now(req(2, 4)).unwrap().unwrap();
        assert_eq!(batch.total_tokens, 8);
    }

    #[test]
    fn oversized_request_rejected() {
        let mut b = DynamicBatcher::new(cfg(4, 10, 1));
        let r = req(1, 8);
        let back = b.push(r.clone(), Instant::now()).unwrap_err();
        assert_eq!(back, r);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn flush_all_drains() {
        let mut b = DynamicBatcher::new(cfg(100, 100, 1000));
        let now = Instant::now();
        b.push(req(1, 1), now).unwrap();
        b.push(req(2, 1), now).unwrap();
        let batch = b.flush_all().unwrap();
        assert_eq!(batch.requests.len(), 2);
        assert!(b.flush_all().is_none());
    }

    #[test]
    fn conservation_no_request_lost_or_duplicated() {
        use crate::util::Rng;
        let mut b = DynamicBatcher::new(cfg(16, 5, 1000));
        let mut rng = Rng::new(42);
        let now = Instant::now();
        let mut seen = Vec::new();
        for id in 0..200u64 {
            let r = req(id, rng.gen_range(6) as usize + 1);
            match b.push(r, now) {
                Ok(Some(batch)) => seen.extend(batch.requests.iter().map(|r| r.id)),
                Ok(None) => {}
                Err(_) => unreachable!("sizes are within budget"),
            }
        }
        if let Some(batch) = b.flush_all() {
            seen.extend(batch.requests.iter().map(|r| r.id));
        }
        seen.sort();
        assert_eq!(seen, (0..200u64).collect::<Vec<_>>());
    }
}
