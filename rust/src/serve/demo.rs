//! End-to-end serving demo: load the AOT model, serve batched requests
//! through router + batcher + engine on a worker thread, report latency and
//! throughput. This is the `aurora serve` subcommand and the
//! `examples/serve_moe.rs` entry point.
//!
//! PJRT handles are not `Send`, so the engine worker thread owns the whole
//! XLA stack (client, executables); only plain-data requests and responses
//! cross the channel — which is also the honest architecture: one engine
//! thread per device.

use super::adaptive::{AdaptiveReplanner, ReplanDecision};
use super::batcher::{Batch, BatcherConfig, DynamicBatcher};
use super::engine::MoeEngine;
use super::metrics::Metrics;
use super::router::{RoutePolicy, Router};
use super::{Request, Response};
use crate::runtime::{MoeModel, MoeModelMeta, PjrtRuntime};
use crate::schedule::SchedulePolicy;
use crate::util::Rng;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Run the serving demo: `n_requests` random requests, batched up to
/// `batch_tokens`, against the artifacts in `artifacts_dir`.
pub fn run_serving_demo(
    artifacts_dir: &str,
    n_requests: usize,
    batch_tokens: usize,
    policy: SchedulePolicy,
) -> Result<()> {
    // Read only the metadata on the main thread; the XLA stack lives in the
    // worker.
    let meta = MoeModelMeta::load(Path::new(artifacts_dir))?;
    println!(
        "model: {} experts, d_model {}, d_ff {}, capacity {} tokens",
        meta.n_experts, meta.d_model, meta.d_ff, meta.capacity
    );

    let (tx, rx) = mpsc::channel::<(Request, Instant)>();
    let (resp_tx, resp_rx) = mpsc::channel::<(Response, Instant, usize)>();
    let dir = PathBuf::from(artifacts_dir);
    let batch_cfg = BatcherConfig {
        max_batch_tokens: batch_tokens.min(meta.capacity),
        max_batch_requests: 64,
        max_wait: Duration::from_millis(1),
    };

    let worker = std::thread::spawn(move || -> Result<Metrics> {
        engine_worker(&dir, policy, batch_cfg, rx, resp_tx)
    });

    // Producer: random requests of 1-8 tokens each, routed through the
    // (single-worker) router for accounting.
    let mut router = Router::new(1, RoutePolicy::LeastLoaded);
    let mut gen = Rng::new(0xD151);
    for id in 0..n_requests as u64 {
        let n_tokens = gen.gen_range(8) as usize + 1;
        let tokens: Vec<f32> = (0..n_tokens * meta.d_model)
            .map(|_| gen.gen_f64() as f32 - 0.5)
            .collect();
        let req = Request::new(id, tokens, meta.d_model);
        let _worker_id = router.route(&req);
        tx.send((req, Instant::now())).ok();
    }
    drop(tx);

    // Collect responses.
    let mut latencies = Metrics::new();
    let mut received = 0usize;
    while received < n_requests {
        match resp_rx.recv_timeout(Duration::from_secs(60)) {
            Ok((resp, submitted, n_tokens)) => {
                latencies.record_request(submitted.elapsed(), n_tokens);
                router.complete(0, n_tokens);
                anyhow::ensure!(
                    resp.output.iter().all(|v| v.is_finite()),
                    "non-finite output for request {}",
                    resp.id
                );
                received += 1;
            }
            Err(_) => anyhow::bail!("timed out waiting for responses ({received}/{n_requests})"),
        }
    }
    let engine_metrics = worker.join().expect("worker panicked")?;

    let s = latencies.latency_summary().unwrap();
    println!("---- serving report ----");
    println!("requests: {} (all completed, conservation OK)", s.count);
    println!(
        "batches: {} (mean {:.1} reqs/batch)",
        engine_metrics.batches(),
        engine_metrics.mean_batch_size()
    );
    println!(
        "latency: mean {:?}  p50 {:?}  p95 {:?}  p99 {:?}  max {:?}",
        s.mean, s.p50, s.p95, s.p99, s.max
    );
    println!(
        "throughput: {:.0} tokens/s, {:.0} requests/s",
        latencies.token_throughput(),
        latencies.request_throughput()
    );
    Ok(())
}

/// The engine worker: owns PJRT, batches incoming requests, executes, and
/// streams responses back with their submission timestamps.
fn engine_worker(
    artifacts_dir: &Path,
    policy: SchedulePolicy,
    batch_cfg: BatcherConfig,
    rx: mpsc::Receiver<(Request, Instant)>,
    resp_tx: mpsc::Sender<(Response, Instant, usize)>,
) -> Result<Metrics> {
    let rt = PjrtRuntime::cpu().context("PJRT startup")?;
    println!("PJRT platform: {}", rt.platform());
    let model = MoeModel::load(&rt, artifacts_dir)?;
    let d_model = model.meta.d_model;
    let mut engine = MoeEngine::new(model, policy);

    // Cross-check split dispatch vs the fused artifact before serving.
    let mut rng = Rng::new(7);
    let probe: Vec<f32> = (0..8 * d_model)
        .map(|_| rng.gen_f64() as f32 - 0.5)
        .collect();
    let max_diff = engine.validate_against_fused(&probe, 8)?;
    println!("split-vs-fused max |diff| on probe batch: {max_diff:.2e}");
    anyhow::ensure!(
        max_diff < 1e-4,
        "split dispatch diverges from the fused layer"
    );

    let mut batcher = DynamicBatcher::new(batch_cfg);
    let mut metrics = Metrics::new();
    // Adaptive replanning (§10 future work, built in): watch routing drift
    // vs the uniform prior the initial expert order assumed.
    let mut replanner = AdaptiveReplanner::new(
        &vec![1; engine.meta().n_experts],
        0.25,
        256,
    );

    // Arrival timestamps ride alongside requests keyed by id.
    let mut arrivals: std::collections::HashMap<u64, Instant> = std::collections::HashMap::new();

    let execute = |engine: &mut MoeEngine,
                       metrics: &mut Metrics,
                       arrivals: &mut std::collections::HashMap<u64, Instant>,
                       replanner: &mut AdaptiveReplanner,
                       batch: Batch|
     -> Result<()> {
        metrics.record_batch(batch.requests.len());
        let sizes: Vec<(u64, usize)> = batch
            .requests
            .iter()
            .map(|r| (r.id, r.n_tokens))
            .collect();
        let stats_before = engine.expert_stats.clone();
        let responses = engine.run_batch(&batch)?;
        let batch_hist: Vec<u64> = engine
            .expert_stats
            .iter()
            .zip(&stats_before)
            .map(|(a, b)| a - b)
            .collect();
        if replanner.observe(&batch_hist) == ReplanDecision::Replan {
            // re-anchor on the full history (the planner's new statistics)
            replanner.replanned(&engine.expert_stats.clone());
            println!(
                "adaptive replan #{}: routing drifted; new expert order {:?}",
                replanner.replans(),
                engine.expert_order
            );
        }
        for (resp, (id, n_tokens)) in responses.into_iter().zip(sizes) {
            debug_assert_eq!(resp.id, id);
            let submitted = arrivals.remove(&id).unwrap_or_else(Instant::now);
            resp_tx.send((resp, submitted, n_tokens)).ok();
        }
        Ok(())
    };

    loop {
        match rx.recv_timeout(Duration::from_millis(1)) {
            Ok((req, arrived)) => {
                arrivals.insert(req.id, arrived);
                match batcher.push(req, arrived) {
                    Ok(Some(batch)) => execute(&mut engine, &mut metrics, &mut arrivals, &mut replanner, batch)?,
                    Ok(None) => {}
                    Err(oversized) => {
                        arrivals.remove(&oversized.id);
                        eprintln!(
                            "rejecting oversized request {} ({} tokens > capacity)",
                            oversized.id, oversized.n_tokens
                        );
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if let Some(batch) = batcher.flush_due_now() {
                    execute(&mut engine, &mut metrics, &mut arrivals, &mut replanner, batch)?;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                if let Some(batch) = batcher.flush_all() {
                    execute(&mut engine, &mut metrics, &mut arrivals, &mut replanner, batch)?;
                }
                break;
            }
        }
    }
    println!(
        "expert token histogram (historical stats): {:?}",
        engine.expert_stats
    );
    println!(
        "final expert order ({}): {:?}",
        policy.name(),
        engine.expert_order
    );
    Ok(metrics)
}
