//! Replica-aware token routing for the serving layer.
//!
//! The planner's [`SplitPlan`] says what *fraction* of each expert's tokens
//! every replica should absorb; at inference time some component has to turn
//! a concrete batch histogram into per-replica token counts and keep the
//! split honest as batches stream through. [`ReplicaRouter`] is that
//! component: it apportions each batch's tokens per expert with
//! largest-remainder rounding ([`crate::traffic::split_tokens`]), carries
//! the rounding *debt* across batches (so a 70/30 split stays 70/30 in the
//! long run even when batches are tiny), and tracks per-GPU outstanding
//! tokens for observability — the quantity
//! [`super::AdaptiveReplanner::observe`] watches for replica-load drift.

use crate::replication::{ReplicatedDeployment, SplitPlan};
use crate::traffic::split_tokens;

/// Routes each expert's token batches across its replica GPUs according to
/// a [`SplitPlan`], amortizing rounding error across batches.
#[derive(Debug, Clone)]
pub struct ReplicaRouter {
    /// `sets[m][e]` = replica GPUs of model `m`'s expert `e`.
    sets: Vec<Vec<Vec<usize>>>,
    /// `weights[m][e][r]` = target fraction for replica `r`.
    weights: Vec<Vec<Vec<f64>>>,
    /// Cumulative tokens already routed per `(m, e, r)` — the state that
    /// lets tiny batches converge to the target split.
    routed: Vec<Vec<Vec<u64>>>,
    /// Outstanding (in-flight) tokens per GPU.
    outstanding: Vec<u64>,
    n_gpus: usize,
}

impl ReplicaRouter {
    /// Build from a replicated deployment and its split plan.
    pub fn new(rep: &ReplicatedDeployment, plan: &SplitPlan) -> Self {
        let routed = rep
            .replicas
            .iter()
            .map(|model| model.iter().map(|set| vec![0u64; set.len()]).collect())
            .collect();
        Self {
            sets: rep.replicas.clone(),
            weights: plan.weights.clone(),
            routed,
            outstanding: vec![0; rep.n_gpus()],
            n_gpus: rep.n_gpus(),
        }
    }

    /// Number of GPUs routed across.
    pub fn n_gpus(&self) -> usize {
        self.n_gpus
    }

    /// Split `tokens` of model `m`'s expert `e` across its replicas.
    /// Returns `(gpu, tokens)` shares (zero shares omitted). Rounding debt
    /// carries over: the *cumulative* routed counts track the target split,
    /// so a stream of 1-token batches still converges to the plan weights.
    pub fn route_tokens(&mut self, m: usize, e: usize, tokens: u64) -> Vec<(usize, u64)> {
        let set = &self.sets[m][e];
        if set.len() == 1 {
            self.routed[m][e][0] += tokens;
            self.outstanding[set[0]] += tokens;
            return vec![(set[0], tokens)];
        }
        // Target cumulative counts after this batch, minus what's already
        // routed, is this batch's share — rounding debt repays itself.
        let total_after: u64 = self.routed[m][e].iter().sum::<u64>() + tokens;
        let targets = split_tokens(total_after, &self.weights[m][e]);
        let mut shares = Vec::new();
        let mut remaining = tokens;
        for (r, &target) in targets.iter().enumerate() {
            let already = self.routed[m][e][r];
            let give = target.saturating_sub(already).min(remaining);
            if give > 0 {
                shares.push((set[r], give));
                self.routed[m][e][r] += give;
                self.outstanding[set[r]] += give;
                remaining -= give;
            }
        }
        // Numerical corner (targets drifting below already-routed): dump the
        // leftover on the primary so conservation always holds.
        if remaining > 0 {
            self.routed[m][e][0] += remaining;
            self.outstanding[set[0]] += remaining;
            match shares.iter().position(|&(g, _)| g == set[0]) {
                Some(i) => shares[i].1 += remaining,
                None => shares.push((set[0], remaining)),
            }
        }
        shares
    }

    /// Report completion of `tokens` on GPU `gpu`, freeing outstanding load.
    pub fn complete(&mut self, gpu: usize, tokens: u64) {
        assert!(
            self.outstanding[gpu] >= tokens,
            "completing more tokens than outstanding on GPU {gpu}"
        );
        self.outstanding[gpu] -= tokens;
    }

    /// Outstanding tokens per GPU (observability; feed to the adaptive
    /// replanner as a load histogram).
    pub fn outstanding(&self) -> &[u64] {
        &self.outstanding
    }

    /// Cumulative tokens routed to each replica of model `m`'s expert `e`.
    pub fn routed_per_replica(&self, m: usize, e: usize) -> &[u64] {
        &self.routed[m][e]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::{Deployment, Scenario};
    use crate::schedule::SchedulePolicy;

    fn two_gpu_rep() -> (ReplicatedDeployment, SplitPlan) {
        // 4 experts on 2 GPUs; expert 0 replicated onto GPU 1 at 70/30.
        let base = Deployment::new(
            2,
            vec![vec![0, 1, 0, 1]],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        let mut rep = ReplicatedDeployment::from_deployment(base);
        rep.add_replica(0, 0, 1).unwrap();
        let mut plan = SplitPlan::trivial(&rep);
        plan.weights[0][0] = vec![0.7, 0.3];
        (rep, plan)
    }

    #[test]
    fn singleton_experts_route_to_their_primary() {
        let (rep, plan) = two_gpu_rep();
        let mut r = ReplicaRouter::new(&rep, &plan);
        assert_eq!(r.route_tokens(0, 1, 10), vec![(1, 10)]);
        assert_eq!(r.route_tokens(0, 2, 5), vec![(0, 5)]);
        assert_eq!(r.outstanding(), &[5, 10]);
    }

    #[test]
    fn split_follows_the_plan_and_conserves() {
        let (rep, plan) = two_gpu_rep();
        let mut r = ReplicaRouter::new(&rep, &plan);
        let shares = r.route_tokens(0, 0, 100);
        let total: u64 = shares.iter().map(|&(_, t)| t).sum();
        assert_eq!(total, 100);
        assert_eq!(r.routed_per_replica(0, 0), &[70, 30]);
    }

    #[test]
    fn rounding_debt_amortizes_across_tiny_batches() {
        let (rep, plan) = two_gpu_rep();
        let mut r = ReplicaRouter::new(&rep, &plan);
        for _ in 0..100 {
            let shares = r.route_tokens(0, 0, 1);
            assert_eq!(shares.iter().map(|&(_, t)| t).sum::<u64>(), 1);
        }
        // after 100 single-token batches the cumulative split matches the
        // 70/30 plan exactly
        assert_eq!(r.routed_per_replica(0, 0), &[70, 30]);
    }

    #[test]
    fn completion_frees_outstanding_load() {
        let (rep, plan) = two_gpu_rep();
        let mut r = ReplicaRouter::new(&rep, &plan);
        r.route_tokens(0, 0, 10);
        let before: u64 = r.outstanding().iter().sum();
        assert_eq!(before, 10);
        r.complete(0, 7);
        r.complete(1, 3);
        assert_eq!(r.outstanding(), &[0, 0]);
    }

    #[test]
    #[should_panic]
    fn over_completion_panics() {
        let (rep, plan) = two_gpu_rep();
        let mut r = ReplicaRouter::new(&rep, &plan);
        r.complete(0, 1);
    }
}
