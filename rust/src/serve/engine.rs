//! The MoE serving engine: executes batches against the AOT model with
//! plan-ordered expert dispatch, feeding gate statistics back to the planner.

use super::batcher::Batch;
use super::Response;
use crate::placement::Deployment;
use crate::replication::{ReplicatedDeployment, SplitPlan};
use crate::runtime::MoeModel;
use crate::schedule::{aurora_schedule, SchedulePolicy};
use crate::traffic::TrafficMatrix;
use crate::util::Rng;
use anyhow::Result;

/// Derive the expert execution order from observed per-expert token counts.
///
/// This is the serving-side analogue of the paper's transmission ordering:
/// the engine plays the role of every source GPU at once, so the induced
/// traffic matrix has one row per source shard and one column per expert;
/// Aurora's slot schedule then yields the contention-free visit order
/// (heaviest/bottleneck experts first — Alg. 1 starts from the bottleneck).
/// RCS shuffles; SJF visits lightest-first.
pub fn expert_execution_order(
    histogram: &[u64],
    policy: SchedulePolicy,
) -> Vec<usize> {
    let n = histogram.len();
    match policy {
        SchedulePolicy::Aurora => {
            // Build the single-source traffic matrix (row 0 fans out to all
            // experts), schedule it, and read experts in first-transmission
            // order; experts the schedule never visits (zero tokens) go last.
            let mut d = TrafficMatrix::zeros(n);
            for (e, &t) in histogram.iter().enumerate() {
                if e != 0 {
                    d.set(0, e, t);
                }
            }
            // Alg. 1: order the bottleneck (heaviest) first. For a
            // single-source matrix the optimal order is descending size.
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by_key(|&e| std::cmp::Reverse(histogram[e]));
            // sanity: the BvN machinery agrees the matrix is schedulable
            debug_assert_eq!(
                aurora_schedule(&d).makespan_tokens(),
                d.b_max_tokens()
            );
            order
        }
        SchedulePolicy::Sjf => {
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by_key(|&e| histogram[e]);
            order
        }
        SchedulePolicy::Ljf => {
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by_key(|&e| std::cmp::Reverse(histogram[e]));
            order
        }
        SchedulePolicy::Pairwise => (0..n).collect(),
        SchedulePolicy::Rcs { seed } => {
            let mut rng = Rng::new(seed);
            rng.permutation(n)
        }
    }
}

/// Derive the expert execution order under a [`Deployment`]: experts are
/// visited GPU group by GPU group, heaviest-loaded group first (its port is
/// the bottleneck the slot schedule drains first), heaviest expert first
/// within a group. Falls back to [`expert_execution_order`]'s flat ordering
/// for non-Aurora policies, which are group-oblivious by definition.
pub fn grouped_execution_order(
    histogram: &[u64],
    deployment: &Deployment,
    model: usize,
    policy: SchedulePolicy,
) -> Vec<usize> {
    if !matches!(policy, SchedulePolicy::Aurora) {
        return expert_execution_order(histogram, policy);
    }
    let gpu_loads = deployment.gpu_loads(model, histogram);
    let mut gpus: Vec<usize> = (0..deployment.n_gpus).collect();
    gpus.sort_by_key(|&g| (std::cmp::Reverse(gpu_loads[g]), g));
    let mut order = Vec::with_capacity(histogram.len());
    for g in gpus {
        let mut experts: Vec<usize> = deployment
            .experts_on(g)
            .into_iter()
            .filter(|&(m, _)| m == model)
            .map(|(_, e)| e)
            .collect();
        experts.sort_by_key(|&e| (std::cmp::Reverse(histogram[e]), e));
        order.extend(experts);
    }
    order
}

/// Stateful engine wrapping the PJRT model.
pub struct MoeEngine {
    model: MoeModel,
    policy: SchedulePolicy,
    /// The generalized placement this engine executes, plus this model's
    /// index within it. `None` runs the single-host flat ordering.
    deployment: Option<(Deployment, usize)>,
    /// Replica sets and split weights when the placement is replicated; the
    /// engine then reports split-aware per-GPU statistics (execution order
    /// still follows the primary placement above).
    replicated: Option<(ReplicatedDeployment, SplitPlan)>,
    /// Cumulative per-expert token counts (the "historical statistics" the
    /// planner consumes, §2.4).
    pub expert_stats: Vec<u64>,
    /// Current expert visit order (re-derived as stats accumulate).
    pub expert_order: Vec<usize>,
    /// Atomic plan swaps performed ([`MoeEngine::swap_replicated`]).
    pub plan_swaps: u64,
}

impl MoeEngine {
    /// Wrap a loaded model.
    pub fn new(model: MoeModel, policy: SchedulePolicy) -> Self {
        let n = model.meta.n_experts;
        Self {
            model,
            policy,
            deployment: None,
            replicated: None,
            expert_stats: vec![0; n],
            expert_order: (0..n).collect(),
            plan_swaps: 0,
        }
    }

    /// Wrap a loaded model and bind it to its slot in a deployment; the
    /// engine then visits experts in GPU-group order and can report per-GPU
    /// load statistics.
    pub fn with_deployment(
        model: MoeModel,
        deployment: Deployment,
        model_index: usize,
    ) -> Self {
        assert!(model_index < deployment.n_models(), "model index out of range");
        assert_eq!(
            deployment.n_experts(model_index),
            model.meta.n_experts,
            "deployment expert count must match the model"
        );
        let policy = deployment.policy;
        let mut engine = Self::new(model, policy);
        engine.deployment = Some((deployment, model_index));
        engine
    }

    /// Like [`MoeEngine::with_deployment`], but replica-aware: execution
    /// order follows the replicated deployment's primary placement, while
    /// per-GPU statistics ([`MoeEngine::gpu_stats`]) split each expert's
    /// observed tokens across its replicas by the plan weights — the load
    /// the cluster actually sees.
    pub fn with_replicated_deployment(
        model: MoeModel,
        rep: ReplicatedDeployment,
        plan: SplitPlan,
        model_index: usize,
    ) -> Self {
        let mut engine = Self::with_deployment(model, rep.base.clone(), model_index);
        engine.replicated = Some((rep, plan));
        engine
    }

    /// The bound deployment, if any.
    pub fn deployment(&self) -> Option<&Deployment> {
        self.deployment.as_ref().map(|(d, _)| d)
    }

    /// Atomically install a new replicated deployment and split plan — the
    /// serving-side commit point of the coordinator's stage → swap → drain
    /// pipeline ([`crate::coordinator::PlanSwap`] decides *when*; this
    /// method is the swap itself, called between batches). Accumulated gate
    /// statistics carry over (they are routing history, not plan state);
    /// the expert visit order is re-derived under the new placement.
    pub fn swap_replicated(&mut self, rep: ReplicatedDeployment, plan: SplitPlan) {
        let m = self.deployment.as_ref().map(|(_, i)| *i).unwrap_or(0);
        assert!(m < rep.n_models(), "model index out of range in the new deployment");
        assert_eq!(
            rep.base.n_experts(m),
            self.model.meta.n_experts,
            "new deployment expert count must match the model"
        );
        self.policy = rep.base.policy;
        self.expert_order = grouped_execution_order(&self.expert_stats, &rep.base, m, self.policy);
        self.deployment = Some((rep.base.clone(), m));
        self.replicated = Some((rep, plan));
        self.plan_swaps += 1;
    }

    /// The bound replicated deployment, if any.
    pub fn replicated_deployment(&self) -> Option<&ReplicatedDeployment> {
        self.replicated.as_ref().map(|(r, _)| r)
    }

    /// Cumulative observed token load per GPU under the bound deployment.
    /// Replica-bound engines report split-aware loads.
    pub fn gpu_stats(&self) -> Option<Vec<u64>> {
        if let Some((rep, plan)) = &self.replicated {
            let m = self.deployment.as_ref().map(|(_, m)| *m).unwrap_or(0);
            return Some(rep.gpu_loads_split(m, &self.expert_stats, plan));
        }
        self.deployment
            .as_ref()
            .map(|(d, m)| d.gpu_loads(*m, &self.expert_stats))
    }

    /// Model metadata.
    pub fn meta(&self) -> &crate::runtime::MoeModelMeta {
        &self.model.meta
    }

    /// Execute one batch: concatenate request tokens, run the layer with
    /// plan-ordered dispatch, split outputs back per request.
    pub fn run_batch(&mut self, batch: &Batch) -> Result<Vec<Response>> {
        let d = self.model.meta.d_model;
        let mut tokens = Vec::with_capacity(batch.total_tokens * d);
        for r in &batch.requests {
            tokens.extend_from_slice(&r.tokens);
        }
        let n_tokens = batch.total_tokens;

        // One gate run serves both statistics and dispatch (§Perf).
        let mut padded = vec![0f32; self.model.meta.capacity * d];
        padded[..tokens.len()].copy_from_slice(&tokens);
        let (idx, weight) = self.model.run_gate(&padded, n_tokens)?;
        for &e in &idx {
            self.expert_stats[e as usize] += 1;
        }
        self.expert_order = match &self.deployment {
            Some((dep, m)) => grouped_execution_order(&self.expert_stats, dep, *m, self.policy),
            None => expert_execution_order(&self.expert_stats, self.policy),
        };

        let out =
            self.model
                .forward_with_gate(&tokens, n_tokens, &self.expert_order, &idx, &weight)?;

        let mut responses = Vec::with_capacity(batch.requests.len());
        let mut off = 0;
        for r in &batch.requests {
            let len = r.n_tokens * d;
            responses.push(Response {
                id: r.id,
                output: out[off..off + len].to_vec(),
            });
            off += len;
        }
        Ok(responses)
    }

    /// Cross-check the split dispatch path against the fused layer artifact
    /// (returns the max absolute difference).
    pub fn validate_against_fused(&self, tokens: &[f32], n_tokens: usize) -> Result<f32> {
        let split = self
            .model
            .forward_layer(tokens, n_tokens, &self.expert_order)?;
        let fused = self.model.forward_fused(tokens, n_tokens)?;
        Ok(split
            .iter()
            .zip(&fused)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aurora_order_is_heaviest_first() {
        let order = expert_execution_order(&[5, 100, 0, 30], SchedulePolicy::Aurora);
        assert_eq!(order[0], 1);
        assert_eq!(order[1], 3);
        assert_eq!(order.len(), 4);
    }

    #[test]
    fn sjf_order_is_lightest_first() {
        let order = expert_execution_order(&[5, 100, 0, 30], SchedulePolicy::Sjf);
        assert_eq!(order[0], 2);
        assert_eq!(order[3], 1);
    }

    #[test]
    fn rcs_order_is_a_permutation() {
        let order = expert_execution_order(&[1, 2, 3, 4, 5], SchedulePolicy::Rcs { seed: 9 });
        let mut seen = vec![false; 5];
        for &e in &order {
            assert!(!seen[e]);
            seen[e] = true;
        }
    }

    #[test]
    fn grouped_order_visits_heaviest_gpu_group_first() {
        use crate::placement::{Deployment, Scenario};
        // 4 experts on 2 GPUs: experts {0,1} on GPU 0, {2,3} on GPU 1.
        let dep = Deployment::new(
            2,
            vec![vec![0, 0, 1, 1]],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        // GPU 1 carries 90 tokens vs GPU 0's 30 -> its experts go first,
        // heaviest within the group leading.
        let order = grouped_execution_order(&[10, 20, 40, 50], &dep, 0, SchedulePolicy::Aurora);
        assert_eq!(order, vec![3, 2, 1, 0]);
        // non-Aurora policies keep their flat semantics
        let sjf = grouped_execution_order(&[10, 20, 40, 50], &dep, 0, SchedulePolicy::Sjf);
        assert_eq!(sjf, expert_execution_order(&[10, 20, 40, 50], SchedulePolicy::Sjf));
    }

    #[test]
    fn grouped_order_is_a_permutation() {
        use crate::placement::{Deployment, Scenario};
        let dep = Deployment::new(
            3,
            vec![vec![0, 2, 1, 2, 0, 1]],
            SchedulePolicy::Aurora,
            Scenario::ExclusiveHomogeneous,
        )
        .unwrap();
        let order = grouped_execution_order(&[5, 0, 9, 9, 1, 2], &dep, 0, SchedulePolicy::Aurora);
        let mut seen = vec![false; 6];
        for &e in &order {
            assert!(!seen[e]);
            seen[e] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn orders_cover_all_experts_even_with_zeros() {
        for policy in [
            SchedulePolicy::Aurora,
            SchedulePolicy::Sjf,
            SchedulePolicy::Rcs { seed: 1 },
        ] {
            let order = expert_execution_order(&[0, 0, 0], policy);
            assert_eq!(order.len(), 3);
        }
    }
}
