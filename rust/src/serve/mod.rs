//! Serving runtime: request router, dynamic batcher, MoE engine, metrics.
//!
//! The L3 coordinator that a deployment would actually run. Requests flow
//!
//! ```text
//! client → Router → per-worker queue → DynamicBatcher → MoeEngine (PJRT)
//!                                                         └→ Metrics
//! ```
//!
//! The engine executes the AOT-compiled JAX/Pallas artifacts
//! ([`crate::runtime::MoeModel`]) with rust-side sparse dispatch, visiting
//! experts in the deployment plan's transmission order. Gate statistics are
//! recorded per batch and can be folded back into the planner — closing the
//! paper's "historical statistics" loop (§2.4).
//!
//! Concurrency is std::thread + mpsc (the offline build has no tokio); the
//! demo ([`demo`]) wires one engine worker, which is the right shape for the
//! single-CPU-host testbed.

pub mod adaptive;
pub mod batcher;
pub mod demo;
pub mod engine;
pub mod metrics;
pub mod replica;
pub mod router;

pub use adaptive::{AdaptiveReplanner, ReplanDecision};
pub use batcher::{Batch, BatcherConfig, Clock, DynamicBatcher, ManualClock, SystemClock};
pub use engine::{expert_execution_order, grouped_execution_order, MoeEngine};
pub use metrics::{p50_p95_p99, percentile, LatencySummary, Metrics, MetricsError};
pub use replica::ReplicaRouter;
pub use router::Router;

/// A serving request: a few tokens of `d_model` features.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Caller-assigned id (unique per run).
    pub id: u64,
    /// Flattened `[n_tokens, d_model]` activations.
    pub tokens: Vec<f32>,
    /// Number of token rows.
    pub n_tokens: usize,
}

impl Request {
    /// Construct, checking the shape invariant.
    pub fn new(id: u64, tokens: Vec<f32>, d_model: usize) -> Request {
        assert!(
            !tokens.is_empty() && tokens.len() % d_model == 0,
            "request tokens must be a non-empty multiple of d_model"
        );
        let n_tokens = tokens.len() / d_model;
        Request {
            id,
            tokens,
            n_tokens,
        }
    }
}

/// A completed response.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    /// Request id.
    pub id: u64,
    /// Flattened `[n_tokens, d_model]` layer output.
    pub output: Vec<f32>,
}
