//! Request router: spread requests across engine workers.
//!
//! The single-host demo runs one worker, but the router is written (and
//! tested) for `R` replicas with two policies: round-robin and
//! least-outstanding-tokens — the shape a multi-replica deployment of
//! colocated models needs.

use super::Request;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through workers.
    RoundRobin,
    /// Send to the worker with the fewest outstanding tokens.
    LeastLoaded,
}

/// Router over `R` worker queues.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    outstanding_tokens: Vec<usize>,
    next_rr: usize,
    routed: u64,
}

impl Router {
    /// Router over `workers` queues.
    pub fn new(workers: usize, policy: RoutePolicy) -> Self {
        assert!(workers > 0);
        Self {
            policy,
            outstanding_tokens: vec![0; workers],
            next_rr: 0,
            routed: 0,
        }
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.outstanding_tokens.len()
    }

    /// Total requests routed so far.
    pub fn routed(&self) -> u64 {
        self.routed
    }

    /// Pick a worker for `req` and account its load.
    pub fn route(&mut self, req: &Request) -> usize {
        let w = match self.policy {
            RoutePolicy::RoundRobin => {
                let w = self.next_rr;
                self.next_rr = (self.next_rr + 1) % self.workers();
                w
            }
            RoutePolicy::LeastLoaded => self
                .outstanding_tokens
                .iter()
                .enumerate()
                .min_by_key(|(_, &t)| t)
                .map(|(i, _)| i)
                .unwrap(),
        };
        self.outstanding_tokens[w] += req.n_tokens;
        self.routed += 1;
        w
    }

    /// Report a batch completion on worker `w` freeing `tokens`.
    pub fn complete(&mut self, w: usize, tokens: usize) {
        assert!(
            self.outstanding_tokens[w] >= tokens,
            "completing more tokens than outstanding"
        );
        self.outstanding_tokens[w] -= tokens;
    }

    /// Current outstanding token counts (for observability).
    pub fn load(&self) -> &[usize] {
        &self.outstanding_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, n: usize) -> Request {
        Request::new(id, vec![0.0; n * 2], 2)
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(3, RoutePolicy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|i| r.route(&req(i, 1))).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(r.routed(), 6);
    }

    #[test]
    fn least_loaded_balances_tokens() {
        let mut r = Router::new(2, RoutePolicy::LeastLoaded);
        assert_eq!(r.route(&req(0, 10)), 0);
        // next goes to worker 1 (0 tokens < 10)
        assert_eq!(r.route(&req(1, 1)), 1);
        // worker 1 still lighter
        assert_eq!(r.route(&req(2, 1)), 1);
        assert_eq!(r.load(), &[10, 2]);
    }

    /// Unequal request sizes: least-loaded must weigh *tokens*, not request
    /// counts — one giant request should send several small ones elsewhere.
    #[test]
    fn least_loaded_weighs_tokens_not_request_counts() {
        let mut r = Router::new(3, RoutePolicy::LeastLoaded);
        assert_eq!(r.route(&req(0, 100)), 0); // giant request
        // the next five small ones avoid worker 0 entirely
        for i in 1..=5 {
            let w = r.route(&req(i, 4));
            assert_ne!(w, 0, "request {i} landed on the overloaded worker");
        }
        assert_eq!(r.load(), &[100, 12, 8]);
        // only once the others catch up does worker 0 become eligible again
        r.complete(0, 96);
        assert_eq!(r.route(&req(6, 1)), 0);
    }

    /// Ties break on the lowest worker id (min_by_key keeps the first
    /// minimum), which makes routing deterministic.
    #[test]
    fn least_loaded_ties_break_deterministically() {
        let mut r = Router::new(4, RoutePolicy::LeastLoaded);
        assert_eq!(r.route(&req(0, 5)), 0);
        assert_eq!(r.route(&req(1, 5)), 1);
        assert_eq!(r.route(&req(2, 5)), 2);
        assert_eq!(r.route(&req(3, 5)), 3);
        // all equal again -> back to worker 0
        assert_eq!(r.route(&req(4, 5)), 0);
    }

    /// A skewed stream of mixed sizes keeps the per-worker token imbalance
    /// bounded by the largest single request.
    #[test]
    fn least_loaded_bounds_imbalance_under_mixed_sizes() {
        let mut r = Router::new(4, RoutePolicy::LeastLoaded);
        let sizes = [64usize, 1, 1, 1, 32, 2, 2, 2, 16, 4, 4, 4, 8, 8, 8, 8];
        let mut max_size = 0;
        for (i, &s) in sizes.iter().cycle().take(160).enumerate() {
            r.route(&req(i as u64, s));
            max_size = max_size.max(s);
        }
        let min = *r.load().iter().min().unwrap();
        let max = *r.load().iter().max().unwrap();
        assert!(
            max - min <= max_size,
            "imbalance {} exceeds largest request {max_size} (loads {:?})",
            max - min,
            r.load()
        );
    }

    #[test]
    fn completion_frees_load() {
        let mut r = Router::new(2, RoutePolicy::LeastLoaded);
        r.route(&req(0, 8));
        r.complete(0, 8);
        assert_eq!(r.load(), &[0, 0]);
    }

    #[test]
    #[should_panic]
    fn over_completion_panics() {
        let mut r = Router::new(1, RoutePolicy::RoundRobin);
        r.complete(0, 5);
    }

    #[test]
    fn conservation_every_request_routed_once() {
        let mut r = Router::new(4, RoutePolicy::LeastLoaded);
        let mut per_worker = vec![0u64; 4];
        for i in 0..100 {
            per_worker[r.route(&req(i, (i % 7 + 1) as usize))] += 1;
        }
        assert_eq!(per_worker.iter().sum::<u64>(), 100);
        assert_eq!(r.routed(), 100);
        // least-loaded should not starve any worker
        assert!(per_worker.iter().all(|&c| c > 0));
    }
}
