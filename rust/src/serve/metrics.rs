//! Serving metrics: latency percentiles and throughput.
//!
//! [`Metrics`] accumulates wall-clock request latencies in the live serving
//! path; the free functions [`percentile`] / [`p50_p95_p99`] work on plain
//! `f64` samples (simulated milliseconds), so the discrete-event serving
//! simulation ([`crate::coordinator::online`]) reports the same tail
//! statistics the demo prints.

use std::time::{Duration, Instant};

/// Nearest-rank pick from an already-sorted non-empty sample slice — the
/// one rank convention every percentile in this module uses.
fn pick_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p), "percentile must be in [0, 1]");
    sorted[((sorted.len() as f64 - 1.0) * p).round() as usize]
}

fn sorted_copy(samples: &[f64]) -> Vec<f64> {
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).expect("samples must be finite"));
    xs
}

/// Nearest-rank percentile of `samples` (any unit; must be finite), `p` in
/// `[0, 1]`. Sorts a copy; returns `None` on empty input.
pub fn percentile(samples: &[f64], p: f64) -> Option<f64> {
    assert!((0.0..=1.0).contains(&p), "percentile must be in [0, 1]");
    if samples.is_empty() {
        return None;
    }
    Some(pick_sorted(&sorted_copy(samples), p))
}

/// The (p50, p95, p99) summary of `samples` — sorted once, the trio every
/// serving report leads with. `None` on empty input.
pub fn p50_p95_p99(samples: &[f64]) -> Option<(f64, f64, f64)> {
    if samples.is_empty() {
        return None;
    }
    let xs = sorted_copy(samples);
    Some((
        pick_sorted(&xs, 0.50),
        pick_sorted(&xs, 0.95),
        pick_sorted(&xs, 0.99),
    ))
}

/// Percentile summary of recorded latencies.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencySummary {
    /// Sample count.
    pub count: usize,
    /// Mean latency.
    pub mean: Duration,
    /// Median.
    pub p50: Duration,
    /// 95th percentile.
    pub p95: Duration,
    /// 99th percentile.
    pub p99: Duration,
    /// Max.
    pub max: Duration,
}

/// Accumulates per-request latency and token counts.
#[derive(Debug)]
pub struct Metrics {
    start: Instant,
    latencies: Vec<Duration>,
    tokens: u64,
    batches: u64,
    batch_sizes: Vec<usize>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    /// Start the clock.
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
            latencies: Vec::new(),
            tokens: 0,
            batches: 0,
            batch_sizes: Vec::new(),
        }
    }

    /// Record one completed request.
    pub fn record_request(&mut self, latency: Duration, tokens: usize) {
        self.latencies.push(latency);
        self.tokens += tokens as u64;
    }

    /// Record one executed batch.
    pub fn record_batch(&mut self, n_requests: usize) {
        self.batches += 1;
        self.batch_sizes.push(n_requests);
    }

    /// Completed request count.
    pub fn requests(&self) -> usize {
        self.latencies.len()
    }

    /// Executed batch count.
    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Mean requests per batch.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        }
    }

    /// Tokens per second since start.
    pub fn token_throughput(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.tokens as f64 / secs
        } else {
            0.0
        }
    }

    /// Requests per second since start.
    pub fn request_throughput(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.latencies.len() as f64 / secs
        } else {
            0.0
        }
    }

    /// Latency percentile summary. Returns `None` with no samples.
    pub fn latency_summary(&self) -> Option<LatencySummary> {
        if self.latencies.is_empty() {
            return None;
        }
        let mut xs = self.latencies.clone();
        xs.sort();
        let pct = |p: f64| xs[((xs.len() as f64 - 1.0) * p).round() as usize];
        let mean = xs.iter().sum::<Duration>() / xs.len() as u32;
        Some(LatencySummary {
            count: xs.len(),
            mean,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            max: *xs.last().unwrap(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_metrics_have_no_summary() {
        let m = Metrics::new();
        assert!(m.latency_summary().is_none());
        assert_eq!(m.requests(), 0);
    }

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::new();
        for i in 1..=100u64 {
            m.record_request(Duration::from_micros(i * 10), 4);
        }
        let s = m.latency_summary().unwrap();
        assert_eq!(s.count, 100);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.max, Duration::from_micros(1000));
    }

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.batches(), 2);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_helpers_match_by_hand_values() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), Some(1.0));
        assert_eq!(percentile(&xs, 1.0), Some(100.0));
        // nearest-rank on 100 samples: (99 * 0.5).round() = 50 -> 51.0
        assert_eq!(percentile(&xs, 0.5), Some(51.0));
        let (p50, p95, p99) = p50_p95_p99(&xs).unwrap();
        assert_eq!(p50, 51.0);
        assert_eq!(p95, 95.0);
        assert_eq!(p99, 99.0);
        assert!(p50 <= p95 && p95 <= p99);
        // order-independent: helpers sort internally
        let shuffled = [3.0, 1.0, 2.0];
        assert_eq!(percentile(&shuffled, 0.5), Some(2.0));
    }

    #[test]
    fn percentile_helpers_handle_empty_and_singleton() {
        assert_eq!(percentile(&[], 0.5), None);
        assert_eq!(p50_p95_p99(&[]), None);
        assert_eq!(p50_p95_p99(&[7.0]), Some((7.0, 7.0, 7.0)));
    }

    #[test]
    #[should_panic]
    fn percentile_rejects_out_of_range_p() {
        percentile(&[1.0], 1.5);
    }

    #[test]
    fn throughput_positive_after_records() {
        let mut m = Metrics::new();
        m.record_request(Duration::from_micros(5), 16);
        std::thread::sleep(Duration::from_millis(2));
        assert!(m.token_throughput() > 0.0);
        assert!(m.request_throughput() > 0.0);
    }
}
