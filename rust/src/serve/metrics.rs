//! Serving metrics: latency percentiles and throughput.

use std::time::{Duration, Instant};

/// Percentile summary of recorded latencies.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencySummary {
    /// Sample count.
    pub count: usize,
    /// Mean latency.
    pub mean: Duration,
    /// Median.
    pub p50: Duration,
    /// 95th percentile.
    pub p95: Duration,
    /// 99th percentile.
    pub p99: Duration,
    /// Max.
    pub max: Duration,
}

/// Accumulates per-request latency and token counts.
#[derive(Debug)]
pub struct Metrics {
    start: Instant,
    latencies: Vec<Duration>,
    tokens: u64,
    batches: u64,
    batch_sizes: Vec<usize>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    /// Start the clock.
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
            latencies: Vec::new(),
            tokens: 0,
            batches: 0,
            batch_sizes: Vec::new(),
        }
    }

    /// Record one completed request.
    pub fn record_request(&mut self, latency: Duration, tokens: usize) {
        self.latencies.push(latency);
        self.tokens += tokens as u64;
    }

    /// Record one executed batch.
    pub fn record_batch(&mut self, n_requests: usize) {
        self.batches += 1;
        self.batch_sizes.push(n_requests);
    }

    /// Completed request count.
    pub fn requests(&self) -> usize {
        self.latencies.len()
    }

    /// Executed batch count.
    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Mean requests per batch.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        }
    }

    /// Tokens per second since start.
    pub fn token_throughput(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.tokens as f64 / secs
        } else {
            0.0
        }
    }

    /// Requests per second since start.
    pub fn request_throughput(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.latencies.len() as f64 / secs
        } else {
            0.0
        }
    }

    /// Latency percentile summary. Returns `None` with no samples.
    pub fn latency_summary(&self) -> Option<LatencySummary> {
        if self.latencies.is_empty() {
            return None;
        }
        let mut xs = self.latencies.clone();
        xs.sort();
        let pct = |p: f64| xs[((xs.len() as f64 - 1.0) * p).round() as usize];
        let mean = xs.iter().sum::<Duration>() / xs.len() as u32;
        Some(LatencySummary {
            count: xs.len(),
            mean,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            max: *xs.last().unwrap(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_metrics_have_no_summary() {
        let m = Metrics::new();
        assert!(m.latency_summary().is_none());
        assert_eq!(m.requests(), 0);
    }

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::new();
        for i in 1..=100u64 {
            m.record_request(Duration::from_micros(i * 10), 4);
        }
        let s = m.latency_summary().unwrap();
        assert_eq!(s.count, 100);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.max, Duration::from_micros(1000));
    }

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.batches(), 2);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_positive_after_records() {
        let mut m = Metrics::new();
        m.record_request(Duration::from_micros(5), 16);
        std::thread::sleep(Duration::from_millis(2));
        assert!(m.token_throughput() > 0.0);
        assert!(m.request_throughput() > 0.0);
    }
}
