//! Serving metrics: latency percentiles and throughput.
//!
//! [`Metrics`] accumulates wall-clock request latencies in the live serving
//! path; the free functions [`percentile`] / [`p50_p95_p99`] (re-exported
//! from [`crate::obs::metrics`]) work on plain `f64` samples (simulated
//! milliseconds), so the discrete-event serving simulation
//! ([`crate::coordinator::online`]) reports the same tail statistics the
//! demo prints. They return typed [`MetricsError`]s — an out-of-range `p`
//! or an all-non-finite sample set is a recoverable condition in a serving
//! report, never a panic (a single NaN latency must not take down the
//! metrics endpoint).

use std::time::{Duration, Instant};

pub use crate::obs::metrics::{p50_p95_p99, percentile, MetricsError};
// The SLO layer consumes these same tail statistics: a serving loop that
// already tracks latencies here can feed an [`SloMonitor`] directly and get
// the coordinator's emergency-replan trigger for free.
pub use crate::obs::slo::{SloMonitor, SloStatus};

/// Percentile summary of recorded latencies.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencySummary {
    /// Sample count.
    pub count: usize,
    /// Mean latency.
    pub mean: Duration,
    /// Median.
    pub p50: Duration,
    /// 95th percentile.
    pub p95: Duration,
    /// 99th percentile.
    pub p99: Duration,
    /// Max.
    pub max: Duration,
}

/// Accumulates per-request latency and token counts.
#[derive(Debug)]
pub struct Metrics {
    start: Instant,
    latencies: Vec<Duration>,
    tokens: u64,
    batches: u64,
    batch_sizes: Vec<usize>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    /// Start the clock.
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
            latencies: Vec::new(),
            tokens: 0,
            batches: 0,
            batch_sizes: Vec::new(),
        }
    }

    /// Record one completed request.
    pub fn record_request(&mut self, latency: Duration, tokens: usize) {
        self.latencies.push(latency);
        self.tokens += tokens as u64;
    }

    /// Record one executed batch.
    pub fn record_batch(&mut self, n_requests: usize) {
        self.batches += 1;
        self.batch_sizes.push(n_requests);
    }

    /// Completed request count.
    pub fn requests(&self) -> usize {
        self.latencies.len()
    }

    /// Executed batch count.
    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Mean requests per batch.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        }
    }

    /// Tokens per second since start.
    pub fn token_throughput(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.tokens as f64 / secs
        } else {
            0.0
        }
    }

    /// Requests per second since start.
    pub fn request_throughput(&self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.latencies.len() as f64 / secs
        } else {
            0.0
        }
    }

    /// Latency percentile summary. Returns `None` with no samples.
    pub fn latency_summary(&self) -> Option<LatencySummary> {
        if self.latencies.is_empty() {
            return None;
        }
        let mut xs = self.latencies.clone();
        xs.sort();
        let pct = |p: f64| xs[((xs.len() as f64 - 1.0) * p).round() as usize];
        let mean = xs.iter().sum::<Duration>() / xs.len() as u32;
        Some(LatencySummary {
            count: xs.len(),
            mean,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            max: *xs.last().unwrap(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_metrics_have_no_summary() {
        let m = Metrics::new();
        assert!(m.latency_summary().is_none());
        assert_eq!(m.requests(), 0);
    }

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::new();
        for i in 1..=100u64 {
            m.record_request(Duration::from_micros(i * 10), 4);
        }
        let s = m.latency_summary().unwrap();
        assert_eq!(s.count, 100);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.max, Duration::from_micros(1000));
    }

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.batches(), 2);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_helpers_match_by_hand_values() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), Ok(1.0));
        assert_eq!(percentile(&xs, 1.0), Ok(100.0));
        // nearest-rank on 100 samples: (99 * 0.5).round() = 50 -> 51.0
        assert_eq!(percentile(&xs, 0.5), Ok(51.0));
        let (p50, p95, p99) = p50_p95_p99(&xs).unwrap();
        assert_eq!(p50, 51.0);
        assert_eq!(p95, 95.0);
        assert_eq!(p99, 99.0);
        assert!(p50 <= p95 && p95 <= p99);
        // order-independent: helpers sort internally
        let shuffled = [3.0, 1.0, 2.0];
        assert_eq!(percentile(&shuffled, 0.5), Ok(2.0));
    }

    #[test]
    fn percentile_helpers_handle_empty_and_singleton() {
        assert_eq!(
            percentile(&[], 0.5),
            Err(MetricsError::NoFiniteSamples { dropped: 0 })
        );
        assert!(p50_p95_p99(&[]).is_err());
        assert_eq!(p50_p95_p99(&[7.0]), Ok((7.0, 7.0, 7.0)));
    }

    #[test]
    fn percentile_rejects_out_of_range_p_without_panicking() {
        assert_eq!(
            percentile(&[1.0], 1.5),
            Err(MetricsError::InvalidPercentile { p: 1.5 })
        );
        assert_eq!(
            percentile(&[1.0], -0.01),
            Err(MetricsError::InvalidPercentile { p: -0.01 })
        );
    }

    #[test]
    fn nan_and_infinite_latencies_never_panic_the_report() {
        // A poisoned sample set (a NaN latency from a clock glitch, an ∞
        // from a division) used to abort the whole report via the sort
        // comparator; now the non-finite samples are dropped and counted.
        let xs = [5.0, f64::NAN, 1.0, f64::INFINITY, 3.0, f64::NEG_INFINITY];
        assert_eq!(percentile(&xs, 0.5), Ok(3.0));
        assert_eq!(p50_p95_p99(&xs), Ok((3.0, 5.0, 5.0)));
        let all_bad = [f64::NAN, f64::INFINITY];
        assert_eq!(
            percentile(&all_bad, 0.5),
            Err(MetricsError::NoFiniteSamples { dropped: 2 })
        );
    }

    #[test]
    fn throughput_positive_after_records() {
        let mut m = Metrics::new();
        m.record_request(Duration::from_micros(5), 16);
        std::thread::sleep(Duration::from_millis(2));
        assert!(m.token_throughput() > 0.0);
        assert!(m.request_throughput() > 0.0);
    }
}
