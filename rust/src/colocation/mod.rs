//! Expert colocation across two MoE models (paper §6, §7).
//!
//! Aurora pairs each expert of Model *a* with one expert of Model *b* on a
//! shared GPU so that one model computes while the other communicates
//! (Fig. 3b). The pairing is chosen to minimize the *aggregated*
//! communication time — by Theorem 6.1 this also minimizes inference time on
//! homogeneous clusters.
//!
//! * [`case1_pairing`] — Theorem 6.2: when per-GPU send and receive volumes
//!   coincide, sort one vector ascending, the other descending, pair in
//!   order.
//! * [`case2_pairing`] — the general case as a bottleneck matching over edge
//!   weights `max(a_i + b_j, a_{n+i} + b_{n+j})` (§6.2, Fig. 8b).
//! * [`lina_grouping`] — the Lina baseline: packs two experts of the *same*
//!   model per GPU (most popular with least popular).
//! * [`random_pairing`] — REC: random cross-model colocation.
//! * [`hetero`] — the NP-hard Colocating + Heterogeneous scenario (§7):
//!   decoupled two-stage matching plus a brute-force optimum for Fig. 13.

pub mod hetero;

use crate::matching::bottleneck_matching;
use crate::traffic::TrafficMatrix;
use crate::util::Rng;

/// A colocation is a permutation `pi`: expert `i` of Model *a* shares its GPU
/// with expert `pi[i]` of Model *b*.
pub type Colocation = Vec<usize>;

/// Per-GPU send/receive volume vectors of a traffic matrix: the paper's
/// `a = [(a_1, a_{n+1}), ...]` (§6.2). Returns `(send, recv)`.
pub fn send_recv_volumes(d: &TrafficMatrix) -> (Vec<u64>, Vec<u64>) {
    let n = d.n();
    (
        (0..n).map(|i| d.row_sum(i)).collect(),
        (0..n).map(|i| d.col_sum(i)).collect(),
    )
}

/// Theorem 6.2 (Case I): given scalar per-expert volumes (send == receive),
/// pair ascending `a` with descending `b`. Returns `pi` minimizing
/// `max_i (a_i + b_{pi[i]})`.
pub fn case1_pairing(a: &[u64], b: &[u64]) -> Colocation {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut ai: Vec<usize> = (0..n).collect();
    ai.sort_by_key(|&i| (a[i], i)); // ascending
    let mut bi: Vec<usize> = (0..n).collect();
    bi.sort_by_key(|&j| (std::cmp::Reverse(b[j]), j)); // descending
    let mut pi = vec![0usize; n];
    for k in 0..n {
        pi[ai[k]] = bi[k];
    }
    pi
}

/// Case II (§6.2): bottleneck matching on the complete bipartite graph whose
/// edge `(i, j)` weighs `max(a_i + b_j, a_{n+i} + b_{n+j})` — the worst of
/// combined send and combined receive volume if experts `i` (Model a) and
/// `j` (Model b) share a GPU.
///
/// Returns `(bottleneck_volume, pi)`.
pub fn case2_pairing(da: &TrafficMatrix, db: &TrafficMatrix) -> (u64, Colocation) {
    assert_eq!(da.n(), db.n(), "colocated models must have equal expert counts");
    let (a_send, a_recv) = send_recv_volumes(da);
    let (b_send, b_recv) = send_recv_volumes(db);
    let weight = |i: usize, j: usize| -> f64 {
        let s = a_send[i] + b_send[j];
        let r = a_recv[i] + b_recv[j];
        s.max(r) as f64
    };
    let (w, pi) = bottleneck_matching(da.n(), weight);
    (w as u64, pi)
}

/// REC baseline: uniformly random cross-model pairing.
pub fn random_pairing(n: usize, rng: &mut Rng) -> Colocation {
    rng.permutation(n)
}

/// Lina baseline grouping: pack two experts of the *same* model per GPU,
/// pairing the most popular with the least popular (§8.1, footnote 5).
///
/// `loads[e]` is expert `e`'s token load; returns `n/2` groups of two expert
/// ids each. Panics if `n` is odd.
pub fn lina_grouping(loads: &[u64]) -> Vec<Vec<usize>> {
    let n = loads.len();
    assert!(n % 2 == 0, "Lina packs experts in pairs");
    let mut ids: Vec<usize> = (0..n).collect();
    ids.sort_by_key(|&e| (loads[e], e)); // ascending popularity
    (0..n / 2).map(|k| vec![ids[k], ids[n - 1 - k]]).collect()
}

/// The aggregated traffic matrix of two colocated models: Model b's experts
/// are relabelled onto Model a's GPU indices via `pi`, then summed.
pub fn aggregate_traffic(da: &TrafficMatrix, db: &TrafficMatrix, pi: &[usize]) -> TrafficMatrix {
    // pi[i] = b-expert on the GPU of a-expert i  =>  b-expert j lands on GPU
    // inv[j] where inv[pi[i]] = i.
    let n = da.n();
    let mut inv = vec![0usize; n];
    for (i, &j) in pi.iter().enumerate() {
        inv[j] = i;
    }
    da.sum(&db.permute(&inv))
}

/// The aggregated bottleneck volume `max column/row sum` of the colocated
/// pair under `pi` — the quantity Theorem 6.1 says to minimize.
pub fn aggregated_b_max(da: &TrafficMatrix, db: &TrafficMatrix, pi: &[usize]) -> u64 {
    aggregate_traffic(da, db, pi).b_max_tokens()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_matrix(n: usize, seed: u64, hi: u64) -> TrafficMatrix {
        let mut rng = Rng::new(seed);
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    d.set(i, j, rng.gen_range(hi));
                }
            }
        }
        d
    }

    #[test]
    fn case1_pairs_large_with_small() {
        let a = vec![1, 5, 3];
        let b = vec![2, 6, 4];
        let pi = case1_pairing(&a, &b);
        // smallest a (idx 0) pairs with largest b (idx 1)
        assert_eq!(pi[0], 1);
        // largest a (idx 1) pairs with smallest b (idx 0)
        assert_eq!(pi[1], 0);
        assert_eq!(pi[2], 2);
    }

    #[test]
    fn case1_minimizes_max_sum_vs_exhaustive() {
        use crate::matching::for_each_permutation;
        let mut rng = Rng::new(0xC1);
        for n in 1..=6 {
            for _ in 0..10 {
                let a: Vec<u64> = (0..n).map(|_| rng.gen_range(50)).collect();
                let b: Vec<u64> = (0..n).map(|_| rng.gen_range(50)).collect();
                let pi = case1_pairing(&a, &b);
                let ours = (0..n).map(|i| a[i] + b[pi[i]]).max().unwrap();
                let mut best = u64::MAX;
                for_each_permutation(n, |p| {
                    let m = (0..n).map(|i| a[i] + b[p[i]]).max().unwrap();
                    best = best.min(m);
                });
                assert_eq!(ours, best, "a={a:?} b={b:?}");
            }
        }
    }

    #[test]
    fn case2_is_valid_permutation() {
        let da = rand_matrix(8, 1, 20);
        let db = rand_matrix(8, 2, 20);
        let (_, pi) = case2_pairing(&da, &db);
        let mut seen = vec![false; 8];
        for &j in &pi {
            assert!(!seen[j]);
            seen[j] = true;
        }
    }

    #[test]
    fn case2_bottleneck_beats_random_pairings() {
        let da = rand_matrix(8, 3, 30);
        let db = rand_matrix(8, 4, 30);
        let (w, pi) = case2_pairing(&da, &db);
        // the weight function is the max of aggregated send/recv *volumes*;
        // verify optimality against 500 random pairings on the same metric
        let (a_send, a_recv) = send_recv_volumes(&da);
        let (b_send, b_recv) = send_recv_volumes(&db);
        let vol = |p: &[usize]| -> u64 {
            (0..8)
                .map(|i| (a_send[i] + b_send[p[i]]).max(a_recv[i] + b_recv[p[i]]))
                .max()
                .unwrap()
        };
        assert_eq!(w, vol(&pi));
        let mut rng = Rng::new(5);
        for _ in 0..500 {
            let p = rng.permutation(8);
            assert!(w <= vol(&p));
        }
    }

    #[test]
    fn case2_reduces_to_case1_when_symmetric() {
        // build symmetric matrices (send == recv per GPU) and check both
        // approaches achieve the same bottleneck volume
        let mut da = TrafficMatrix::zeros(4);
        let mut db = TrafficMatrix::zeros(4);
        for (i, v) in [(0usize, 3u64), (1, 7), (2, 5), (3, 1)] {
            // ring traffic: i sends v to i+1 and receives v from i-1 — but to
            // make send == recv per GPU, use a symmetric pattern
            da.set(i, (i + 1) % 4, v);
            da.set((i + 1) % 4, i, v);
            db.set(i, (i + 2) % 4, v + 1);
            db.set((i + 2) % 4, i, v + 1);
        }
        let (a_send, a_recv) = send_recv_volumes(&da);
        assert_eq!(a_send, a_recv);
        let (w2, _) = case2_pairing(&da, &db);
        let (b_send, _) = send_recv_volumes(&db);
        let pi1 = case1_pairing(&a_send, &b_send);
        let w1 = (0..4).map(|i| a_send[i] + b_send[pi1[i]]).max().unwrap();
        assert_eq!(w1, w2);
    }

    #[test]
    fn lina_pairs_popular_with_unpopular() {
        let loads = vec![100, 10, 50, 70, 20, 90, 40, 60];
        let groups = lina_grouping(&loads);
        assert_eq!(groups.len(), 4);
        // least popular (1: load 10) pairs with most popular (0: load 100)
        assert_eq!(groups[0], vec![1, 0]);
        // all experts covered exactly once
        let mut seen = vec![false; 8];
        for g in &groups {
            for &e in g {
                assert!(!seen[e]);
                seen[e] = true;
            }
        }
    }

    #[test]
    #[should_panic]
    fn lina_rejects_odd_expert_count() {
        lina_grouping(&[1, 2, 3]);
    }

    #[test]
    fn aggregate_traffic_conserves_totals() {
        let da = rand_matrix(6, 7, 15);
        let db = rand_matrix(6, 8, 15);
        let mut rng = Rng::new(9);
        let pi = random_pairing(6, &mut rng);
        let agg = aggregate_traffic(&da, &db, &pi);
        assert_eq!(agg.total(), da.total() + db.total());
    }

    #[test]
    fn identity_pairing_aggregates_in_place() {
        let da = rand_matrix(4, 11, 10);
        let db = rand_matrix(4, 12, 10);
        let pi: Vec<usize> = (0..4).collect();
        let agg = aggregate_traffic(&da, &db, &pi);
        assert_eq!(agg.get(0, 1), da.get(0, 1) + db.get(0, 1));
    }

    #[test]
    fn case2_aggregated_b_max_not_worse_than_rec_average() {
        // Aurora's pairing should beat the average random pairing on the
        // aggregated b_max objective (the actual optimality is on volume,
        // which equals b_max here because b_max == max send/recv volume).
        let da = rand_matrix(8, 21, 40);
        let db = rand_matrix(8, 22, 40);
        let (_, pi) = case2_pairing(&da, &db);
        let ours = aggregated_b_max(&da, &db, &pi);
        let mut rng = Rng::new(23);
        let mut worse = 0;
        for _ in 0..200 {
            let p = rng.permutation(8);
            if aggregated_b_max(&da, &db, &p) >= ours {
                worse += 1;
            }
        }
        assert!(worse >= 190, "random beat Aurora too often: {}", 200 - worse);
    }
}
