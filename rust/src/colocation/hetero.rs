//! Colocating + Heterogeneous: the NP-hard scenario (paper §7).
//!
//! Picking (a-expert, b-expert, GPU) triples is a 3-dimensional matching
//! problem (Fig. 10a) — NP-hard. Aurora decouples it (§7.2, Fig. 10b):
//!
//! 1. **Pairing stage** — ignore GPUs; solve the Case II bottleneck matching
//!    between the two models' experts ([`super::case2_pairing`]).
//! 2. **Assignment stage** — treat each colocated pair as one unit and solve
//!    a second bottleneck matching of pairs onto GPUs, with edge weights
//!    given by the estimated inference-time contribution of running that
//!    pair on that GPU.
//!
//! The cost of a (pair, GPU) edge is supplied by the caller (the planner
//! wires in the simulator's per-GPU completion estimate), which keeps this
//! module free of simulator dependencies and lets tests use analytic costs.

use super::{case2_pairing, Colocation};
use crate::matching::{bottleneck_matching, for_each_permutation};
use crate::traffic::TrafficMatrix;

/// A complete solution for the Colocating + Heterogeneous scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct HeteroSolution {
    /// `pairing[i]` = b-expert colocated with a-expert `i`.
    pub pairing: Colocation,
    /// `assignment[i]` = GPU hosting the pair led by a-expert `i`.
    pub assignment: Vec<usize>,
    /// The stage-2 bottleneck value (max per-GPU cost under `cost`).
    pub bottleneck: f64,
}

/// Aurora's polynomial-time decoupled solution (§7.2).
///
/// `cost(a_expert, b_expert, gpu)` estimates the per-GPU completion metric of
/// colocating the two experts on `gpu` — larger is worse; the stage-2
/// matching minimizes the maximum.
pub fn decoupled_solution(
    da: &TrafficMatrix,
    db: &TrafficMatrix,
    n_gpus: usize,
    cost: impl Fn(usize, usize, usize) -> f64,
) -> HeteroSolution {
    let n = da.n();
    assert_eq!(n, db.n());
    assert_eq!(n, n_gpus, "one expert pair per GPU");

    // Stage 1: expert colocation without GPUs (bottleneck matching, Case II).
    let (_, pairing) = case2_pairing(da, db);

    // Stage 2: pairs → GPUs (second bottleneck matching).
    let (bottleneck, assignment) =
        bottleneck_matching(n, |i, g| cost(i, pairing[i], g));
    HeteroSolution {
        pairing,
        assignment,
        bottleneck,
    }
}

/// Assignment stage alone, for a *fixed* pairing (used by baselines that
/// randomize the pairing but still assign GPUs sensibly, and by the brute
/// force below).
pub fn assign_pairs_to_gpus(
    pairing: &[usize],
    n_gpus: usize,
    cost: impl Fn(usize, usize, usize) -> f64,
) -> (f64, Vec<usize>) {
    bottleneck_matching(n_gpus, |i, g| cost(i, pairing[i], g))
}

/// Brute force over **all pairings**, each with an exhaustive assignment
/// search against the *true* objective `full_cost(pairing, assignment)`
/// (typically the simulated end-to-end inference time).
///
/// `O(n!²)` — only for small `n`; this is the exact optimum used to certify
/// the 1.07× gap claim at small scale.
pub fn brute_force_exact(
    n: usize,
    mut full_cost: impl FnMut(&[usize], &[usize]) -> f64,
) -> (f64, Colocation, Vec<usize>) {
    let mut best = f64::INFINITY;
    let mut best_pair: Vec<usize> = (0..n).collect();
    let mut best_assign: Vec<usize> = (0..n).collect();
    // Heap's algorithm needs a non-borrowing callback; collect pairings first.
    let mut pairings: Vec<Vec<usize>> = Vec::new();
    for_each_permutation(n, |p| pairings.push(p.to_vec()));
    let mut assignments: Vec<Vec<usize>> = Vec::new();
    for_each_permutation(n, |p| assignments.push(p.to_vec()));
    for pairing in &pairings {
        for assignment in &assignments {
            let c = full_cost(pairing, assignment);
            if c < best {
                best = c;
                best_pair = pairing.clone();
                best_assign = assignment.clone();
            }
        }
    }
    (best, best_pair, best_assign)
}

/// Stronger-than-decoupled search used as the Fig. 13 "optimum" at paper
/// scale (n = 8, where the exact `n!²` search is infeasible): enumerate all
/// pairings, solve the assignment stage exactly per pairing via bottleneck
/// matching, and score with the true objective.
pub fn brute_force_pairings(
    n: usize,
    cost: impl Fn(usize, usize, usize) -> f64,
    mut full_cost: impl FnMut(&[usize], &[usize]) -> f64,
) -> (f64, Colocation, Vec<usize>) {
    let mut pairings: Vec<Vec<usize>> = Vec::new();
    for_each_permutation(n, |p| pairings.push(p.to_vec()));
    let mut best = f64::INFINITY;
    let mut best_pair: Vec<usize> = (0..n).collect();
    let mut best_assign: Vec<usize> = (0..n).collect();
    for pairing in &pairings {
        let (_, assignment) = assign_pairs_to_gpus(pairing, n, &cost);
        let c = full_cost(pairing, &assignment);
        if c < best {
            best = c;
            best_pair = pairing.clone();
            best_assign = assignment;
        }
    }
    (best, best_pair, best_assign)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_matrix(n: usize, seed: u64) -> TrafficMatrix {
        let mut rng = Rng::new(seed);
        let mut d = TrafficMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    d.set(i, j, rng.gen_range(25));
                }
            }
        }
        d
    }

    /// Analytic toy cost: combined volume divided by a GPU speed factor.
    fn toy_cost(
        da: &TrafficMatrix,
        db: &TrafficMatrix,
        speeds: Vec<f64>,
    ) -> impl Fn(usize, usize, usize) -> f64 {
        let (a_s, a_r) = super::super::send_recv_volumes(da);
        let (b_s, b_r) = super::super::send_recv_volumes(db);
        move |i, j, g| ((a_s[i] + b_s[j]).max(a_r[i] + b_r[j])) as f64 / speeds[g]
    }

    #[test]
    fn decoupled_solution_is_bijective() {
        let da = rand_matrix(6, 1);
        let db = rand_matrix(6, 2);
        let speeds = vec![1.0, 1.0, 0.8, 0.8, 0.5, 0.5];
        let sol = decoupled_solution(&da, &db, 6, toy_cost(&da, &db, speeds));
        for perm in [&sol.pairing, &sol.assignment] {
            let mut seen = vec![false; 6];
            for &v in perm.iter() {
                assert!(!seen[v]);
                seen[v] = true;
            }
        }
    }

    #[test]
    fn decoupled_close_to_exact_optimum_small_n() {
        // the paper reports a 1.07x average gap at n=8; at n=4-5 with toy
        // costs the decoupled heuristic should stay within ~1.5x
        for seed in 0..8u64 {
            let n = 4;
            let da = rand_matrix(n, seed * 2 + 1);
            let db = rand_matrix(n, seed * 2 + 2);
            let speeds = vec![1.0, 0.8, 0.5, 0.4];
            let cost = toy_cost(&da, &db, speeds);
            let sol = decoupled_solution(&da, &db, n, &cost);
            let (opt, _, _) = brute_force_exact(n, |pairing, assignment| {
                (0..n)
                    .map(|i| cost(i, pairing[i], assignment[i]))
                    .fold(0.0, f64::max)
            });
            assert!(opt > 0.0);
            let ratio = sol.bottleneck / opt;
            assert!(
                (1.0..1.6).contains(&ratio),
                "seed={seed} ratio={ratio} (sub-optimal heuristic should be >= optimum, close to it)"
            );
        }
    }

    #[test]
    fn brute_force_pairings_at_least_as_good_as_decoupled() {
        let n = 5;
        let da = rand_matrix(n, 31);
        let db = rand_matrix(n, 32);
        let speeds = vec![1.0, 0.9, 0.8, 0.5, 0.4];
        let cost = toy_cost(&da, &db, speeds);
        let objective = |pairing: &[usize], assignment: &[usize]| {
            (0..n)
                .map(|i| cost(i, pairing[i], assignment[i]))
                .fold(0.0, f64::max)
        };
        let sol = decoupled_solution(&da, &db, n, &cost);
        let (bf, _, _) = brute_force_pairings(n, &cost, objective);
        assert!(bf <= sol.bottleneck + 1e-9);
    }

    #[test]
    fn assign_pairs_respects_fixed_pairing() {
        let da = rand_matrix(4, 41);
        let db = rand_matrix(4, 42);
        let speeds = vec![1.0, 1.0, 0.5, 0.5];
        let cost = toy_cost(&da, &db, speeds);
        let pairing = vec![3, 2, 1, 0];
        let (b, assignment) = assign_pairs_to_gpus(&pairing, 4, &cost);
        let m = (0..4)
            .map(|i| cost(i, pairing[i], assignment[i]))
            .fold(0.0, f64::max);
        assert!((b - m).abs() < 1e-12);
    }
}
