//! `aurora` — CLI for the Aurora MoE inference optimizer.
//!
//! Subcommands:
//! * `eval --figure <11a|...|multi|replication|online|topology|all>` —
//!   regenerate a paper figure (or a beyond-paper extension) on synthetic
//!   traces.
//! * `plan --cluster <homo|hetero> --models <N> [--experts-per-gpu <K>]
//!   [--replicas <R>] [--skew <ALPHA>]` — print a deployment plan as JSON.
//!   N ≤ 2 with one expert per GPU uses the paper's exact paths; `--replicas`
//!   ≥ 2 runs the replication pass (optionally on a Zipf(`--skew`) workload).
//! * `simulate --cluster <homo|hetero> --models <N> [--experts-per-gpu <K>]
//!   [--replicas <R>] [--skew <ALPHA>] [--groups <G> --oversub <F>]` —
//!   per-layer inference times and utilization for the planned deployment;
//!   `--groups`/`--oversub` plan and price it on a two-tier topology.
//! * `bench [--out <file>] [--budget-ms <N>] [--check [--max-regress R]]` —
//!   time the planner/schedule/sim hot paths on fixed seeds, append a JSON
//!   perf snapshot, and optionally gate on regressions vs the last snapshot.
//!   `bench --merge-measured <artifact.json>` skips the run and instead
//!   folds a CI-measured snapshot into the history file.
//! * `trace --out <file>` — dump the generated traces to JSON.
//! * `serve` — run the end-to-end serving demo on the AOT-compiled MoE model
//!   (requires `make artifacts`).

use aurora::config::EvalConfig;
use aurora::eval::{multi_workload, run_figure, skewed_workload, Workloads};
use aurora::planner::{Planner, ReplicationConfig};
use aurora::schedule::SchedulePolicy;
use aurora::obs::timeline::TimelineRecorder;
use aurora::sim::{
    simulate_colocated, simulate_colocated_recorded, simulate_exclusive,
    simulate_exclusive_recorded, simulate_group_topology_recorded,
};
use aurora::trace::{trace_to_json, ModelTrace};
use aurora::util::Json;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = args[0].as_str();
    let opts = Opts::parse(&args[1..]);
    let result = match cmd {
        "eval" => cmd_eval(&opts),
        "plan" => cmd_plan(&opts),
        "simulate" => cmd_simulate(&opts),
        "bench" => cmd_bench(&opts),
        "trace" => cmd_trace(&opts),
        "serve" => cmd_serve(&opts),
        "serve-sim" => cmd_serve_sim(&opts),
        "profile" => cmd_profile(&opts),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => Err(format!("unknown command '{other}'")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "aurora — MoE inference optimization (paper reproduction)

USAGE:
  aurora eval     --figure <11a|11b|11c|11d|12|13|14|a1|a2|ablation|multi|replication|online|topology|utilization|resilience|straggler|all> [--config f.json] [--json out.json]
  aurora plan     --cluster <homo|hetero> --models <N> [--experts-per-gpu <K>] [--replicas <R>] [--skew <ALPHA>] [--groups <G> --oversub <F>] [--config f.json]
  aurora simulate --cluster <homo|hetero> --models <N> [--experts-per-gpu <K>] [--replicas <R>] [--skew <ALPHA>] [--groups <G> --oversub <F>] [--policy aurora|sjf|ljf|pairwise|rcs]
  aurora bench    [--out BENCH_planner.json] [--budget-ms N] [--groups <G> --oversub <F>] [--check [--max-regress R]]
  aurora bench    --merge-measured <artifact.json> [--out BENCH_planner.json]
  aurora trace    --out <file.json> [--config f.json]
  aurora serve    [--artifacts DIR] [--requests N] [--batch N] [--policy aurora|rcs]
  aurora serve-sim [--drift ALPHA] [--windows N] [--rotate-every N] [--strategy static|periodic|coordinator|oracle|all] [--noise] [--fail-gpu G@W[,G@W...]] [--drain-gpu G@W] [--join-gpu G@W] [--degrade-gpu G@W:S] [--degrade-link G@W:S] [--recover-gpu G@W] [--obs-noise A] [--elastic] [--groups <G> --oversub <F>] [--config f.json]
  aurora profile  [--gpus N] [--skew ALPHA] [--replicas R] [--seed S] [--trace-out f.json] [--jsonl-out f.jsonl]

  --models N           colocate N models (N >= 3 uses the generalized placement core)
  --experts-per-gpu K  give every model K*n_gpus experts (K >= 2 packs multiple experts per GPU)
  --replicas R         allow up to R copies of each expert (R >= 2 enables replication)
  --skew ALPHA         drive planning with a Zipf(ALPHA)-skewed workload (0 = uniform)
  --groups G           two-tier topology with G even GPU groups (1 = big switch)
  --oversub F          uplink oversubscription factor >= 1 (needs --groups >= 2)
  --pods P             stack a third tier: P pods of G/P leaf groups each (needs --groups >= 2)
  --pod-oversub F      pod-uplink oversubscription (default: same as --oversub)
  --drift ALPHA        serve-sim: Zipf skew of the rotating hot expert (0 = stationary uniform)
  --noise              serve-sim: sample each window multinomially (live-batch fluctuation)
  --check              bench: fail when a hot path regresses past --max-regress (default 1.25x)
                       vs the last snapshot in the history file
  --trace-out F        plan/simulate/serve-sim/profile: write the run's span trace as Chrome
                       trace-event JSON (open in chrome://tracing or Perfetto)
  --metrics-out F      plan/simulate/serve-sim: write a metrics-registry JSON snapshot
  --timeline-out F     simulate: record the first layer's GPU/link timeline, print the
                       per-GPU utilization breakdown, and write it as Chrome trace JSON
  --slo-p99-ms T       serve-sim: arm the coordinator's SLO watchdog — replan when the
                       rolling p99 window latency exceeds T ms (emergency override of
                       the drift/gain/cost gates; cooldown still applies)
  --fail-gpu G@W       serve-sim: fail GPU G at the start of window W (comma-separate
                       for multiple events); survivors are promoted in-window and a
                       repair replan follows
  --drain-gpu G@W      serve-sim: gracefully drain GPU G at window W (migrates away,
                       stays alive)
  --join-gpu G@W       serve-sim: (re)join GPU G to the placeable set at window W
  --degrade-gpu G@W:S  serve-sim: silently throttle GPU G's compute to S x nominal
                       (0 < S < 1) at window W — a gray failure the coordinator must
                       *detect* from window timelines, never a membership change
                       (comma-separate for multiple events; enables detection)
  --degrade-link G@W:S serve-sim: silently throttle GPU G's access link to S x nominal
                       at window W (same detection contract as --degrade-gpu)
  --recover-gpu G@W    serve-sim: restore GPU G to nominal rates at window W
  --obs-noise A        serve-sim: multiply every detector ratio by a deterministic
                       factor in [1-A, 1+A] (measurement jitter; default 0)
  --elastic            serve-sim: let the coordinator grow replica budgets under SLO
                       burn and consolidate onto fewer GPUs when utilization is low
  --merge-measured F   bench: append the snapshot measured in F (a bench history, legacy
                       single-snapshot, or .rejected.json artifact) to --out instead of
                       running benchmarks; prints the measured-vs-committed diff
"
    );
}

/// Tiny flag parser: `--key value` pairs (the offline build has no `clap`).
struct Opts {
    kv: Vec<(String, String)>,
}

impl Opts {
    fn parse(args: &[String]) -> Opts {
        let mut kv = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    i += 1;
                    args[i].clone()
                } else {
                    "true".to_string()
                };
                kv.push((key.to_string(), val));
            } else {
                eprintln!("warning: ignoring stray argument '{a}'");
            }
            i += 1;
        }
        Opts { kv }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn config(&self) -> Result<EvalConfig, String> {
        EvalConfig::load(self.get("config"))
    }

    fn policy(&self) -> Result<SchedulePolicy, String> {
        match self.get("policy").unwrap_or("aurora") {
            "aurora" => Ok(SchedulePolicy::Aurora),
            "sjf" => Ok(SchedulePolicy::Sjf),
            "ljf" => Ok(SchedulePolicy::Ljf),
            "pairwise" => Ok(SchedulePolicy::Pairwise),
            "rcs" => Ok(SchedulePolicy::Rcs { seed: 0 }),
            other => Err(format!("unknown policy '{other}'")),
        }
    }
}

/// Wall-clock tracer when `--trace-out` was given, disabled (no-op)
/// otherwise — so the planning paths below can pass it unconditionally.
fn tracer_for(opts: &Opts) -> aurora::Tracer {
    if opts.get("trace-out").is_some() {
        aurora::Tracer::wall()
    } else {
        aurora::Tracer::disabled()
    }
}

/// Live metrics registry when `--metrics-out` was given, disabled otherwise.
fn metrics_for(opts: &Opts) -> aurora::MetricsRegistry {
    if opts.get("metrics-out").is_some() {
        aurora::MetricsRegistry::new()
    } else {
        aurora::MetricsRegistry::disabled()
    }
}

/// Fold per-span durations into the registry (one histogram per span name),
/// so `--metrics-out` on plan/simulate reports phase timing distributions.
fn span_metrics(tr: &aurora::Tracer, metrics: &aurora::MetricsRegistry) {
    if !metrics.is_enabled() {
        return;
    }
    for s in tr.spans() {
        metrics.hist_record(&format!("phase.{}_us", s.name), s.dur_us as f64);
    }
}

/// Write the `--trace-out` / `--metrics-out` artifacts, if requested.
fn write_obs_outputs(
    opts: &Opts,
    tr: &aurora::Tracer,
    metrics: &aurora::MetricsRegistry,
) -> Result<(), String> {
    if let Some(path) = opts.get("trace-out") {
        std::fs::write(path, tr.to_chrome_string()).map_err(|e| format!("{path}: {e}"))?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = opts.get("jsonl-out") {
        std::fs::write(path, tr.to_jsonl()).map_err(|e| format!("{path}: {e}"))?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = opts.get("metrics-out") {
        std::fs::write(path, metrics.snapshot().to_string_compact())
            .map_err(|e| format!("{path}: {e}"))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// GPU/link timeline recorder when `--timeline-out` was given, disabled
/// (no-op) otherwise. The simulate paths record their *first* layer only:
/// every layer restarts the clock at t = 0, so one layer is one timeline.
fn timeline_recorder_for(opts: &Opts, n_gpus: usize) -> TimelineRecorder {
    if opts.get("timeline-out").is_some() {
        TimelineRecorder::new(n_gpus)
    } else {
        TimelineRecorder::disabled()
    }
}

/// Write the `--timeline-out` artifact and print the utilization breakdown
/// table, if a timeline was recorded.
fn write_timeline(opts: &Opts, rec: &mut TimelineRecorder) -> Result<(), String> {
    let Some(path) = opts.get("timeline-out") else {
        return Ok(());
    };
    let tl = rec
        .take()
        .ok_or("--timeline-out: no timeline was recorded for this scenario")?;
    std::fs::write(path, tl.to_chrome_string()).map_err(|e| format!("{path}: {e}"))?;
    eprintln!("wrote {path}");
    println!();
    println!("{}", tl.render_table());
    Ok(())
}

fn cmd_eval(opts: &Opts) -> Result<(), String> {
    let cfg = opts.config()?;
    let figure = opts.get("figure").unwrap_or("all");
    let reports = run_figure(figure, &cfg)?;
    for r in &reports {
        println!("{}", r.render());
    }
    if let Some(path) = opts.get("json") {
        let arr = Json::Arr(reports.iter().map(|r| r.to_json()).collect());
        std::fs::write(path, arr.to_string_compact()).map_err(|e| format!("{path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cluster_for(opts: &Opts, cfg: &EvalConfig) -> Result<aurora::Cluster, String> {
    match opts.get("cluster").unwrap_or("homo") {
        "homo" | "homogeneous" => Ok(cfg.homogeneous_cluster()),
        "hetero" | "heterogeneous" => Ok(cfg.heterogeneous_cluster()),
        other => Err(format!("unknown cluster '{other}'")),
    }
}

/// Parse and validate `--models` / `--experts-per-gpu`. `experts_per_gpu`
/// is `None` when the flag is absent — `None` with N ≤ 2 is the paper's
/// shape (classic `DeploymentPlan` output); anything else takes the
/// generalized placement path.
fn parse_shape(opts: &Opts) -> Result<(usize, Option<usize>), String> {
    let models: usize = opts
        .get("models")
        .unwrap_or("1")
        .parse()
        .map_err(|_| "bad --models")?;
    if models == 0 {
        return Err("--models must be >= 1".into());
    }
    let per_gpu = match opts.get("experts-per-gpu") {
        None => None,
        Some(s) => {
            let k: usize = s.parse().map_err(|_| "bad --experts-per-gpu")?;
            if k == 0 {
                return Err("--experts-per-gpu must be >= 1".into());
            }
            // An explicit K=1 is the default shape: normalize so it plans
            // the same workload as omitting the flag.
            if k == 1 {
                None
            } else {
                Some(k)
            }
        }
    };
    Ok((models, per_gpu))
}

/// Parse `--groups` / `--oversub` / `--pods` / `--pod-oversub` into a
/// [`aurora::cluster::Topology`]. `--groups 1` (the default) is the big
/// switch; `--groups N ≥ 2` alone builds an even two-tier fabric with
/// `--oversub` (default 1.0) uplink oversubscription; adding `--pods P ≥ 2`
/// stacks a third tier that groups the `N` leaf groups into `P` pods, whose
/// uplinks are oversubscribed by `--pod-oversub` (default: same as
/// `--oversub`).
fn parse_topology(opts: &Opts, n_gpus: usize) -> Result<aurora::cluster::Topology, String> {
    use aurora::cluster::Topology;
    let groups: usize = opts
        .get("groups")
        .unwrap_or("1")
        .parse()
        .map_err(|_| "bad --groups")?;
    let oversub: f64 = opts
        .get("oversub")
        .unwrap_or("1")
        .parse()
        .map_err(|_| "bad --oversub")?;
    let pods: usize = opts
        .get("pods")
        .unwrap_or("1")
        .parse()
        .map_err(|_| "bad --pods")?;
    if groups == 0 {
        return Err("--groups must be >= 1".into());
    }
    if groups == 1 {
        if oversub != 1.0 {
            return Err("--oversub needs --groups >= 2 (one group is a big switch)".into());
        }
        if pods > 1 {
            return Err("--pods needs --groups >= 2 (one group is a big switch)".into());
        }
        return Ok(Topology::BigSwitch);
    }
    if pods <= 1 {
        if opts.get("pod-oversub").is_some() {
            return Err("--pod-oversub needs --pods >= 2".into());
        }
        return Topology::even_two_tier(n_gpus, groups, oversub).map_err(|e| e.to_string());
    }
    let pod_oversub: f64 = match opts.get("pod-oversub") {
        None => oversub,
        Some(s) => s.parse().map_err(|_| "bad --pod-oversub")?,
    };
    Topology::even_tiered(n_gpus, &[groups, pods], &[oversub, pod_oversub])
        .map_err(|e| e.to_string())
}

/// JSON rendering of a topology (`None` for the big switch, which keeps the
/// classic plan output byte-identical when no topology flags are given).
fn topology_json(topo: &aurora::cluster::Topology) -> Option<aurora::util::Json> {
    use aurora::cluster::Topology;
    match topo {
        Topology::BigSwitch => None,
        Topology::TwoTier {
            groups,
            oversubscription,
        } => Some(Json::obj(vec![
            ("groups", Json::from(groups.len())),
            ("oversubscription", Json::Num(*oversubscription)),
        ])),
        Topology::Tiered { levels } => Some(Json::obj(vec![(
            "levels",
            Json::Arr(
                levels
                    .iter()
                    .map(|lv| {
                        Json::obj(vec![
                            ("groups", Json::from(lv.groups.len())),
                            ("oversubscription", Json::Num(lv.oversubscription)),
                        ])
                    })
                    .collect(),
            ),
        )])),
    }
}

/// Parse `--replicas` / `--skew`. Replication engages at R ≥ 2; a positive
/// skew swaps the LIMoE workload for a Zipf(α) one.
fn parse_replication(opts: &Opts) -> Result<(usize, f64), String> {
    let replicas: usize = opts
        .get("replicas")
        .unwrap_or("1")
        .parse()
        .map_err(|_| "bad --replicas")?;
    if replicas == 0 {
        return Err("--replicas must be >= 1".into());
    }
    let skew: f64 = opts
        .get("skew")
        .unwrap_or("0")
        .parse()
        .map_err(|_| "bad --skew")?;
    if skew < 0.0 {
        return Err("--skew must be >= 0".into());
    }
    Ok((replicas, skew))
}

/// Workloads for the generalized paths: Zipf(`skew`) traces when a skew was
/// requested (one hot-expert profile per model), the LIMoE grid otherwise.
fn generalized_workload(
    cfg: &EvalConfig,
    models: usize,
    n_experts: usize,
    skew: f64,
) -> Vec<ModelTrace> {
    if skew > 0.0 {
        (0..models)
            .map(|m| {
                skewed_workload(
                    n_experts,
                    cfg.n_layers,
                    cfg.batch_images * 16,
                    skew,
                    cfg.seed.wrapping_add(m as u64),
                )
            })
            .collect()
    } else {
        multi_workload(cfg, models, n_experts)
    }
}

fn cmd_plan(opts: &Opts) -> Result<(), String> {
    use aurora::cluster::Topology;
    let cfg = opts.config()?;
    let cluster = cluster_for(opts, &cfg)?;
    let planner = Planner::default();
    let (models, per_gpu) = parse_shape(opts)?;
    let (replicas, skew) = parse_replication(opts)?;
    let topo = parse_topology(opts, cluster.len())?;
    let big_switch = matches!(topo, Topology::BigSwitch);
    let tr = tracer_for(opts);
    let metrics = metrics_for(opts);
    // The paper's shapes print the classic two-model plan JSON for parity.
    if per_gpu.is_none() && models <= 2 && replicas == 1 && skew == 0.0 && big_switch {
        let w = Workloads::generate(&cfg);
        let sp = tr.begin("planner.plan_classic");
        let plan = match models {
            1 => planner.plan_exclusive(&w.b16_coco, &cluster),
            _ => planner.plan_colocated(&w.b16_coco, &w.b32_coco, &cluster),
        };
        tr.end(sp);
        span_metrics(&tr, &metrics);
        write_obs_outputs(opts, &tr, &metrics)?;
        println!("{}", plan.to_json().to_string_compact());
        return Ok(());
    }
    let n_experts = per_gpu.unwrap_or(1) * cluster.len();
    let traces = generalized_workload(&cfg, models, n_experts, skew);
    let refs: Vec<&ModelTrace> = traces.iter().collect();
    let plan_json = if replicas >= 2 {
        let rep_cfg = ReplicationConfig {
            max_replicas: replicas,
            ..ReplicationConfig::default()
        };
        let (rep, _) = planner
            .plan_replicated_topology_traced(&refs, &cluster, &topo, &rep_cfg, &tr)
            .map_err(|e| e.to_string())?;
        rep.to_json()
    } else {
        let dep = planner
            .plan_topology_traced(&refs, &cluster, &topo, &tr)
            .map_err(|e| e.to_string())?;
        dep.to_json()
    };
    span_metrics(&tr, &metrics);
    write_obs_outputs(opts, &tr, &metrics)?;
    match topology_json(&topo) {
        // no topology flags: the classic plan JSON, byte for byte
        None => println!("{}", plan_json.to_string_compact()),
        Some(t) => {
            let wrapped = Json::obj(vec![("topology", t), ("plan", plan_json)]);
            println!("{}", wrapped.to_string_compact());
        }
    }
    Ok(())
}

fn cmd_simulate(opts: &Opts) -> Result<(), String> {
    use aurora::cluster::Topology;
    let cfg = opts.config()?;
    let cluster = cluster_for(opts, &cfg)?;
    let policy = opts.policy()?;
    let planner = Planner {
        policy,
        planning_layer: 0,
    };
    let (models, per_gpu) = parse_shape(opts)?;
    let (replicas, skew) = parse_replication(opts)?;
    let topo = parse_topology(opts, cluster.len())?;
    let tr = tracer_for(opts);
    let metrics = metrics_for(opts);
    println!(
        "scenario: {} model(s), {} cluster, policy {}",
        models,
        if cluster.is_homogeneous() {
            "homogeneous"
        } else {
            "heterogeneous"
        },
        policy.name()
    );
    if let Topology::TwoTier {
        groups,
        oversubscription,
    } = &topo
    {
        println!(
            "topology: two-tier, {} groups, {:.1}x oversubscribed uplinks",
            groups.len(),
            oversubscription
        );
    }
    if let Topology::Tiered { levels } = &topo {
        let desc: Vec<String> = levels
            .iter()
            .map(|lv| format!("{} groups x{:.1}", lv.groups.len(), lv.oversubscription))
            .collect();
        println!(
            "topology: {}-level tiered ({})",
            levels.len(),
            desc.join(", ")
        );
    }
    if replicas >= 2 || skew > 0.0 {
        // Replication / skewed-workload path: plan with replicas allowed and
        // simulate with the water-filled token splits applied.
        let k = per_gpu.unwrap_or(1);
        let traces = generalized_workload(&cfg, models, k * cluster.len(), skew);
        let refs: Vec<&ModelTrace> = traces.iter().collect();
        let rep_cfg = ReplicationConfig {
            max_replicas: replicas,
            ..ReplicationConfig::default()
        };
        let (rep, splits) = planner
            .plan_replicated_topology_traced(&refs, &cluster, &topo, &rep_cfg, &tr)
            .map_err(|e| e.to_string())?;
        println!(
            "deployment: {} models x {} experts, skew {:.2}, {} added replica(s), max slots {}",
            rep.n_models(),
            rep.base.n_experts(0),
            skew,
            rep.added_replicas(),
            rep.slots_per_gpu().into_iter().max().unwrap_or(0)
        );
        let sims = rep.simulate_topology(&refs, &cluster, &topo, &splits);
        for (k, res) in sims.iter().enumerate() {
            println!(
                "layer {}: inference {:.3} ms, util {:.1}%, agg comm {:.3} ms",
                k + 1,
                res.inference_ms,
                res.utilization * 100.0,
                res.comm_ms
            );
        }
        // Timeline: re-run the first layer with the recorder on (recording
        // is observational, so this reproduces layer 1's numbers exactly).
        let mut rec = timeline_recorder_for(opts, cluster.len());
        if rec.is_enabled() {
            let projected: Vec<aurora::sim::MoeLayerStats> = refs
                .iter()
                .enumerate()
                .map(|(m, t)| rep.project_layer_split(m, &t.layers[0], &splits))
                .collect();
            let prefs: Vec<&aurora::sim::MoeLayerStats> = projected.iter().collect();
            simulate_group_topology_recorded(&prefs, &cluster, &topo, policy, &mut rec);
        }
        write_timeline(opts, &mut rec)?;
        span_metrics(&tr, &metrics);
        write_obs_outputs(opts, &tr, &metrics)?;
        return Ok(());
    }
    match (models, per_gpu, &topo) {
        (1, None, Topology::BigSwitch) => {
            let w = Workloads::generate(&cfg);
            let sp = tr.begin("planner.plan_classic");
            let plan = planner.plan_exclusive(&w.b16_coco, &cluster);
            tr.end(sp);
            let mut rec = timeline_recorder_for(opts, cluster.len());
            for (k, layer) in plan.place_a(&w.b16_coco).iter().enumerate() {
                let (res, _) = if k == 0 {
                    simulate_exclusive_recorded(layer, &cluster, policy, &mut rec)
                } else {
                    simulate_exclusive(layer, &cluster, policy)
                };
                println!(
                    "layer {}: inference {:.3} ms, util {:.1}%, comm {:.3} ms",
                    k + 1,
                    res.inference_ms,
                    res.utilization * 100.0,
                    res.comm_ms
                );
            }
            write_timeline(opts, &mut rec)?;
        }
        (2, None, Topology::BigSwitch) => {
            let w = Workloads::generate(&cfg);
            let sp = tr.begin("planner.plan_classic");
            let plan = planner.plan_colocated(&w.b16_coco, &w.b32_coco, &cluster);
            tr.end(sp);
            let pa = plan.place_a(&w.b16_coco);
            let pb = plan.place_b(&w.b32_coco);
            let mut rec = timeline_recorder_for(opts, cluster.len());
            for (k, (la, lb)) in pa.iter().zip(&pb).enumerate() {
                let (res, _) = if k == 0 {
                    simulate_colocated_recorded(la, lb, &cluster, policy, &mut rec)
                } else {
                    simulate_colocated(la, lb, &cluster, policy)
                };
                println!(
                    "layer {}: inference {:.3} ms, util {:.1}%, agg comm {:.3} ms",
                    k + 1,
                    res.inference_ms,
                    res.utilization * 100.0,
                    res.comm_ms
                );
            }
            write_timeline(opts, &mut rec)?;
        }
        _ => {
            // Generalized path: N models, K experts per GPU slot, any
            // topology (plan_topology/simulate_topology are bit-for-bit the
            // flat pipeline on the big switch).
            let k = per_gpu.unwrap_or(1);
            let traces = multi_workload(&cfg, models, k * cluster.len());
            let refs: Vec<&ModelTrace> = traces.iter().collect();
            let dep = planner
                .plan_topology_traced(&refs, &cluster, &topo, &tr)
                .map_err(|e| e.to_string())?;
            println!(
                "deployment: {} models x {} experts ({} per GPU slot), max group {}",
                dep.n_models(),
                dep.n_experts(0),
                k,
                dep.max_group_size()
            );
            for (k, res) in dep.simulate_topology(&refs, &cluster, &topo).iter().enumerate() {
                println!(
                    "layer {}: inference {:.3} ms, util {:.1}%, agg comm {:.3} ms",
                    k + 1,
                    res.inference_ms,
                    res.utilization * 100.0,
                    res.comm_ms
                );
            }
            // Timeline: re-run the first layer with the recorder on
            // (observational — reproduces layer 1's numbers exactly).
            let mut rec = timeline_recorder_for(opts, cluster.len());
            if rec.is_enabled() {
                let projected: Vec<aurora::sim::MoeLayerStats> = refs
                    .iter()
                    .enumerate()
                    .map(|(m, t)| dep.project_layer(m, &t.layers[0]))
                    .collect();
                let prefs: Vec<&aurora::sim::MoeLayerStats> = projected.iter().collect();
                simulate_group_topology_recorded(&prefs, &cluster, &topo, policy, &mut rec);
            }
            write_timeline(opts, &mut rec)?;
        }
    }
    span_metrics(&tr, &metrics);
    write_obs_outputs(opts, &tr, &metrics)?;
    Ok(())
}

/// Time the planner / schedule / sim hot paths on fixed seeds and append a
/// JSON perf snapshot to the history file (`BENCH_planner.json` by default)
/// — the artifact CI archives to build a perf trajectory over time. With
/// `--check`, additionally fail when any case's median regressed past
/// `--max-regress` (default 1.25x) vs the last snapshot already in the file
/// — the committed baseline, in CI.
fn cmd_bench(opts: &Opts) -> Result<(), String> {
    use aurora::cluster::Cluster;
    use aurora::schedule::{aurora_schedule, comm_time};
    use aurora::util::bench::Bench;
    use std::time::Duration;

    let out = opts.get("out").unwrap_or("BENCH_planner.json");
    if let Some(artifact) = opts.get("merge-measured") {
        return merge_measured(artifact, out);
    }
    let budget_ms: u64 = opts
        .get("budget-ms")
        .unwrap_or("200")
        .parse()
        .map_err(|_| "bad --budget-ms")?;
    let cfg = opts.config()?;
    let mut b = Bench::new();
    b.budget = Duration::from_millis(budget_ms);
    b.warmup = Duration::from_millis((budget_ms / 4).max(1));
    Bench::header();

    let planner = Planner::default();
    let cluster = Cluster::homogeneous(8, 800.0);

    // Scheduling hot paths.
    let traces = multi_workload(&cfg, 3, 16);
    let refs: Vec<&ModelTrace> = traces.iter().collect();
    let d = &traces[0].layers[0].traffic;
    b.run("schedule: bvn slot schedule 16x16", || {
        aurora_schedule(d).makespan_tokens()
    });
    let bw = vec![800.0f64; 16];
    b.run("schedule: head-of-line sjf 16x16", || {
        comm_time(d, &bw, SchedulePolicy::Sjf).makespan
    });

    // Planner hot paths. Each fallible planning call is validated once
    // up front so a setup error reports one line and exits nonzero instead
    // of panicking inside the timing loop.
    let dep = planner
        .plan_multi(&refs, &cluster)
        .map_err(|e| format!("bench setup: plan_multi 3x16 on 8 GPUs: {e}"))?;
    b.run("planner: plan_multi 3x16 on 8 GPUs", || {
        planner
            .plan_multi(&refs, &cluster)
            .expect("validated above")
            .max_group_size()
    });
    let skewed = skewed_workload(16, cfg.n_layers, cfg.batch_images * 16, 1.2, cfg.seed);
    let skewed_refs = [&skewed];
    let rep_cfg = ReplicationConfig::default();
    planner
        .plan_replicated(&skewed_refs, &cluster, &rep_cfg)
        .map_err(|e| format!("bench setup: plan_replicated 16 on 8 GPUs: {e}"))?;
    b.run("planner: plan_replicated zipf(1.2) 16 on 8 GPUs", || {
        planner
            .plan_replicated(&skewed_refs, &cluster, &rep_cfg)
            .expect("validated above")
            .0
            .added_replicas()
    });

    // Simulator hot path: the 3-way grouped pipeline on planned placements.
    let layers: Vec<&aurora::sim::MoeLayerStats> =
        traces.iter().map(|t| &t.layers[0]).collect();
    b.run("sim: simulate_layer 3-way on 8 GPUs", || {
        dep.simulate_layer(&layers, &cluster).inference_ms
    });

    // Hierarchical scheduling hot paths on a 16-GPU two-tier fabric.
    // `--groups/--oversub` reshape it; non-default shapes get distinct case
    // names, so they never gate against the default history.
    let groups: usize = opts
        .get("groups")
        .unwrap_or("4")
        .parse()
        .map_err(|_| "bad --groups")?;
    let oversub: f64 = opts
        .get("oversub")
        .unwrap_or("4")
        .parse()
        .map_err(|_| "bad --oversub")?;
    let topo = aurora::cluster::Topology::even_two_tier(16, groups, oversub)
        .map_err(|e| e.to_string())?;
    let cluster16 = Cluster::homogeneous(16, 800.0);
    let d16 = &skewed.layers[0].traffic;
    aurora::schedule::hierarchical_schedule(d16, &cluster16, &topo)
        .map_err(|e| format!("bench setup: hierarchical_schedule 16x16: {e}"))?;
    b.run(
        &format!("schedule: hierarchical two-phase 16x16 {groups}g x{oversub}"),
        || {
            aurora::schedule::hierarchical_schedule(d16, &cluster16, &topo)
                .expect("validated above")
                .pipelined_ms
        },
    );
    planner
        .plan_topology(&skewed_refs, &cluster16, &topo)
        .map_err(|e| format!("bench setup: plan_topology 16 on 16 GPUs: {e}"))?;
    b.run(
        &format!("planner: plan_topology zipf(1.2) 16 on 16 GPUs {groups}g x{oversub}"),
        || {
            planner
                .plan_topology(&skewed_refs, &cluster16, &topo)
                .expect("validated above")
                .max_group_size()
        },
    );

    // Large-scale entries: the incremental planning engine's target shapes
    // (one expert per GPU, Zipf(1.2) routing), where the lazy-greedy
    // replication loop and the delta-estimated refinements dominate. At
    // these sizes a single plan may exceed the per-case budget; the harness
    // still takes one warm iteration and at least one sample.
    for &n in &[64usize, 128, 256] {
        let big_cluster = Cluster::homogeneous(n, 800.0);
        let big_trace = skewed_workload(n, 2, 512, 1.2, cfg.seed);
        let big_refs = [&big_trace];
        planner
            .plan_replicated(&big_refs, &big_cluster, &rep_cfg)
            .map_err(|e| format!("bench setup: plan_replicated {n} on {n} GPUs: {e}"))?;
        b.run(
            &format!("planner: plan_replicated zipf(1.2) {n} on {n} GPUs"),
            || {
                planner
                    .plan_replicated(&big_refs, &big_cluster, &rep_cfg)
                    .expect("validated above")
                    .0
                    .added_replicas()
            },
        );
        let big_topo = aurora::cluster::Topology::even_two_tier(n, 8, 4.0)
            .map_err(|e| e.to_string())?;
        planner
            .plan_topology(&big_refs, &big_cluster, &big_topo)
            .map_err(|e| format!("bench setup: plan_topology {n} on {n} GPUs: {e}"))?;
        b.run(
            &format!("planner: plan_topology zipf(1.2) {n} on {n} GPUs 8g x4"),
            || {
                planner
                    .plan_topology(&big_refs, &big_cluster, &big_topo)
                    .expect("validated above")
                    .max_group_size()
            },
        );
    }
    for &n in &[64usize, 128] {
        let big_trace = skewed_workload(n, 1, 512, 1.2, cfg.seed);
        let d_big = &big_trace.layers[0].traffic;
        b.run(&format!("schedule: bvn slot schedule {n}x{n}"), || {
            aurora_schedule(d_big).makespan_tokens()
        });
    }
    {
        // Sparse-era BvN scale point: Zipf rows at 512 GPUs leave most cells
        // empty, so the decomposition's cost tracks the nonzero structure
        // (and, under --features rayon, the parallel matching repair).
        let big_trace = skewed_workload(512, 1, 512, 1.2, cfg.seed);
        let d_big = &big_trace.layers[0].traffic;
        b.run("schedule: bvn slot schedule 512x512", || {
            aurora_schedule(d_big).makespan_tokens()
        });
    }

    // Thousand-GPU tier: recursive three-tier planning (tier-local
    // localization + hot-gated port refinement) followed by the full
    // recursive hierarchical schedule of the planned placement — the
    // end-to-end path the sparse matrices, the parallel BvN, and the
    // tier-local planner exist to keep under a second at 1024 GPUs.
    for &(n, racks, pods) in &[(512usize, 64usize, 8usize), (1024, 128, 16)] {
        let big_cluster = Cluster::homogeneous(n, 800.0);
        let big_trace = skewed_workload(n, 1, 512, 1.2, cfg.seed);
        let big_refs = [&big_trace];
        let topo3 = aurora::cluster::Topology::even_tiered(n, &[racks, pods], &[2.0, 4.0])
            .map_err(|e| e.to_string())?;
        let big_dep = planner
            .plan_topology(&big_refs, &big_cluster, &topo3)
            .map_err(|e| format!("bench setup: plan_topology {n} on {n} GPUs 3-tier: {e}"))?;
        let big_agg = big_dep.aggregated_traffic(&[&big_trace.layers[0]]);
        aurora::schedule::hierarchical_schedule(&big_agg, &big_cluster, &topo3)
            .map_err(|e| format!("bench setup: hierarchical_schedule {n} 3-tier: {e}"))?;
        b.run(
            &format!("planner: plan_topology+schedule zipf(1.2) {n} on {n} GPUs 3-tier"),
            || {
                let dep = planner
                    .plan_topology(&big_refs, &big_cluster, &topo3)
                    .expect("validated above");
                let agg = dep.aggregated_traffic(&[&big_trace.layers[0]]);
                aurora::schedule::hierarchical_schedule(&agg, &big_cluster, &topo3)
                    .expect("validated above")
                    .pipelined_ms
            },
        );
    }

    let benchmarks: Vec<Json> = b
        .samples()
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("name", Json::from(s.name.as_str())),
                ("iters", Json::from(s.iters)),
                ("median_ns", Json::Num(s.median.as_nanos() as f64)),
                ("mean_ns", Json::Num(s.mean.as_nanos() as f64)),
                ("min_ns", Json::Num(s.min.as_nanos() as f64)),
                // full per-iteration distribution (log-bucketed), not just
                // the point stats — the regression gate still reads only
                // median_ns, so these ride along without affecting it
                ("p90_ns", Json::Num(s.p90_ns())),
                ("p99_ns", Json::Num(s.p99_ns())),
                ("hist", s.hist.to_json()),
            ])
        })
        .collect();
    // Each run appends one git-SHA + timestamp-stamped snapshot, so the file
    // accumulates the perf trajectory across commits instead of losing it.
    let sha = aurora::util::bench::git_sha().map_or(Json::Null, Json::Str);
    let entry = Json::obj(vec![
        ("git_sha", sha),
        ("timestamp", Json::Str(aurora::util::bench::iso_utc_now())),
        ("budget_ms", Json::from(budget_ms)),
        ("benchmarks", Json::Arr(benchmarks)),
    ]);
    let mut history: Vec<Json> = read_bench_history(out)?;
    // Gate BEFORE appending: a failed run must not become the next
    // baseline, or re-running the check would silently pass against the
    // regressed numbers it just rejected.
    if opts.get("check").is_some() {
        use aurora::util::bench::compare_entries;
        let max_regress: f64 = opts
            .get("max-regress")
            .unwrap_or("1.25")
            .parse()
            .map_err(|_| "bad --max-regress")?;
        if max_regress < 1.0 {
            return Err("--max-regress must be >= 1".into());
        }
        match history.last() {
            None => println!("bench check: no prior snapshot; nothing to gate against"),
            Some(prev) => {
                let regressions = compare_entries(prev, &entry, max_regress);
                if regressions.is_empty() {
                    println!(
                        "bench check: all hot paths within {max_regress}x of the last snapshot"
                    );
                } else {
                    for r in &regressions {
                        eprintln!("regression: {}", r.report());
                    }
                    // Keep the measured numbers recoverable even though the
                    // baseline is left unchanged — CI uploads this file
                    // alongside the history, so a legitimate slowdown can be
                    // accepted by committing it as the new baseline.
                    let rejected = format!("{out}.rejected.json");
                    let doc = Json::obj(vec![("rejected", entry.clone())]);
                    std::fs::write(&rejected, doc.to_string_compact())
                        .map_err(|e| format!("{rejected}: {e}"))?;
                    return Err(format!(
                        "{} hot-path timing(s) regressed past {max_regress}x vs the last \
                         snapshot in {out}; baseline left unchanged, measured snapshot \
                         written to {rejected}",
                        regressions.len()
                    ));
                }
            }
        }
    }
    history.push(entry);
    let n_snapshots = history.len();
    let doc = Json::obj(vec![("history", Json::Arr(history))]);
    std::fs::write(out, doc.to_string_compact()).map_err(|e| format!("{out}: {e}"))?;
    println!("wrote {out} ({n_snapshots} snapshot(s))");
    Ok(())
}

/// Read a bench history file into its list of snapshots. A missing file is
/// an empty history; an unparseable one is an error — never silently discard
/// an existing trajectory. Accepts the `{"history": [...]}` format and the
/// legacy single-snapshot format (kept as the first entry).
fn read_bench_history(path: &str) -> Result<Vec<Json>, String> {
    match std::fs::read_to_string(path) {
        Err(_) => Ok(Vec::new()),
        Ok(text) => {
            let v = Json::parse(&text).map_err(|e| {
                format!("{path}: existing bench file is not valid JSON ({e}); move it aside to start a new history")
            })?;
            match v.get("history").and_then(|h| h.as_arr()) {
                Some(arr) => Ok(arr.to_vec()),
                None if v.get("benchmarks").is_some() => Ok(vec![v.clone()]),
                None => Err(format!(
                    "{path}: unrecognized bench file format; move it aside to start a new history"
                )),
            }
        }
    }
}

/// `bench --merge-measured`: fold a CI-measured snapshot into the committed
/// history file without running any benchmark. The artifact may be a bench
/// history (its last snapshot is taken), a legacy single snapshot, or the
/// `.rejected.json` file a failed `--check` leaves behind. Prints the
/// measured-vs-committed diff — every case slower than the committed
/// baseline, via [`aurora::util::bench::compare_entries`] at ratio 1.0 —
/// then appends. Prior history entries (including the provenance note on
/// the first, hand-estimated one) are carried over verbatim.
fn merge_measured(artifact: &str, out: &str) -> Result<(), String> {
    use aurora::util::bench::compare_entries;

    let text = std::fs::read_to_string(artifact).map_err(|e| format!("{artifact}: {e}"))?;
    let v = Json::parse(&text).map_err(|e| format!("{artifact}: not valid JSON ({e})"))?;
    let measured = if let Some(arr) = v.get("history").and_then(|h| h.as_arr()) {
        arr.last()
            .cloned()
            .ok_or_else(|| format!("{artifact}: empty history"))?
    } else if let Some(rejected) = v.get("rejected") {
        rejected.clone()
    } else if v.get("benchmarks").is_some() {
        v.clone()
    } else {
        return Err(format!(
            "{artifact}: unrecognized bench artifact (expected a history, a single \
             snapshot, or a rejected-snapshot file)"
        ));
    };
    if measured.get("benchmarks").is_none() {
        return Err(format!("{artifact}: snapshot has no 'benchmarks' array"));
    }
    let mut history = read_bench_history(out)?;
    match history.last() {
        None => println!("merge-measured: no committed snapshot in {out}; nothing to diff"),
        Some(prev) => {
            let slower = compare_entries(prev, &measured, 1.0);
            if slower.is_empty() {
                println!("merge-measured: no case slower than the committed baseline");
            } else {
                println!(
                    "merge-measured: {} case(s) slower than the committed baseline:",
                    slower.len()
                );
                for r in &slower {
                    println!("  {}", r.report());
                }
            }
        }
    }
    history.push(measured);
    let n_snapshots = history.len();
    let doc = Json::obj(vec![("history", Json::Arr(history))]);
    std::fs::write(out, doc.to_string_compact()).map_err(|e| format!("{out}: {e}"))?;
    println!("wrote {out} ({n_snapshots} snapshot(s))");
    Ok(())
}

/// Drifting-Zipf online-serving simulation: static plan vs periodic
/// replanning vs the cost-aware coordinator vs a zero-cost oracle, with
/// per-window p50/p95/p99 serving-time percentiles.
/// Parse one fault-injection flag: comma-separated `GPU@WINDOW` specs,
/// validated against the cluster and the window horizon.
fn parse_events(
    opts: &Opts,
    flag: &str,
    windows: usize,
    n_gpus: usize,
    mk: fn(usize) -> aurora::coordinator::ClusterEvent,
) -> Result<Vec<(usize, aurora::coordinator::ClusterEvent)>, String> {
    let Some(spec) = opts.get(flag) else {
        return Ok(Vec::new());
    };
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let (gpu, window) = part
            .split_once('@')
            .ok_or_else(|| format!("bad --{flag} '{part}': expected GPU@WINDOW"))?;
        let g: usize = gpu
            .trim()
            .parse()
            .map_err(|_| format!("bad --{flag} GPU '{gpu}'"))?;
        let w: usize = window
            .trim()
            .parse()
            .map_err(|_| format!("bad --{flag} window '{window}'"))?;
        if g >= n_gpus {
            return Err(format!("--{flag}: GPU {g} out of range (cluster has {n_gpus} GPUs)"));
        }
        if w >= windows {
            return Err(format!("--{flag}: window {w} out of range (run has {windows} windows)"));
        }
        out.push((w, mk(g)));
    }
    Ok(out)
}

/// Parse one gray-failure flag: comma-separated `GPU@WINDOW:SCALE` specs
/// (`0 < SCALE < 1`), validated like [`parse_events`].
fn parse_scaled_events(
    opts: &Opts,
    flag: &str,
    windows: usize,
    n_gpus: usize,
    mk: fn(usize, f64) -> aurora::coordinator::ClusterEvent,
) -> Result<Vec<(usize, aurora::coordinator::ClusterEvent)>, String> {
    let Some(spec) = opts.get(flag) else {
        return Ok(Vec::new());
    };
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let (gpu, rest) = part
            .split_once('@')
            .ok_or_else(|| format!("bad --{flag} '{part}': expected GPU@WINDOW:SCALE"))?;
        let (window, scale) = rest
            .split_once(':')
            .ok_or_else(|| format!("bad --{flag} '{part}': expected GPU@WINDOW:SCALE"))?;
        let g: usize = gpu
            .trim()
            .parse()
            .map_err(|_| format!("bad --{flag} GPU '{gpu}'"))?;
        let w: usize = window
            .trim()
            .parse()
            .map_err(|_| format!("bad --{flag} window '{window}'"))?;
        let s: f64 = scale
            .trim()
            .parse()
            .map_err(|_| format!("bad --{flag} scale '{scale}'"))?;
        if g >= n_gpus {
            return Err(format!("--{flag}: GPU {g} out of range (cluster has {n_gpus} GPUs)"));
        }
        if w >= windows {
            return Err(format!("--{flag}: window {w} out of range (run has {windows} windows)"));
        }
        if !(s > 0.0 && s < 1.0) {
            return Err(format!(
                "--{flag}: scale {s} out of range (a gray failure runs at 0 < scale < 1)"
            ));
        }
        out.push((w, mk(g, s)));
    }
    Ok(out)
}

fn cmd_serve_sim(opts: &Opts) -> Result<(), String> {
    use aurora::cluster::Cluster;
    use aurora::coordinator::{run_online_traced, ClusterEvent, OnlineConfig, OnlineStrategy};

    let cfg = opts.config()?;
    let alpha: f64 = opts
        .get("drift")
        .unwrap_or("1.2")
        .parse()
        .map_err(|_| "bad --drift")?;
    if alpha < 0.0 {
        return Err("--drift must be >= 0".into());
    }
    let windows: usize = opts
        .get("windows")
        .unwrap_or("24")
        .parse()
        .map_err(|_| "bad --windows")?;
    if windows == 0 {
        return Err("--windows must be >= 1".into());
    }
    let rotate_every: usize = opts
        .get("rotate-every")
        .unwrap_or("8")
        .parse()
        .map_err(|_| "bad --rotate-every")?;
    if rotate_every == 0 {
        return Err("--rotate-every must be >= 1".into());
    }
    let sampled = opts.get("noise").is_some_and(|v| v != "false");
    let cluster: Cluster = cfg.homogeneous_cluster();
    let mut ocfg = OnlineConfig::from_eval(&cfg, alpha, windows, rotate_every, sampled);
    // Two-tier serving: candidate plans localize, and migrations are charged
    // for the uplinks their weight transfers cross.
    ocfg.coordinator.topology = parse_topology(opts, cluster.len())?;
    // SLO watchdog: a rolling-p99 violation overrides the drift/gain/cost
    // gates and forces a replan (cooldown still applies).
    if let Some(s) = opts.get("slo-p99-ms") {
        let target: f64 = s.parse().map_err(|_| "bad --slo-p99-ms")?;
        if !(target > 0.0) || !target.is_finite() {
            return Err("--slo-p99-ms must be a positive number".into());
        }
        ocfg.coordinator.slo_p99_ms = Some(target);
    }
    // Fault injection: comma-separated GPU@WINDOW specs, landing at the
    // start of their window (before it serves).
    let mut events = Vec::new();
    events.extend(parse_events(opts, "fail-gpu", windows, cluster.len(), ClusterEvent::GpuFailed)?);
    events.extend(parse_events(
        opts,
        "drain-gpu",
        windows,
        cluster.len(),
        ClusterEvent::GpuDrained,
    )?);
    events.extend(parse_events(opts, "join-gpu", windows, cluster.len(), ClusterEvent::GpuJoined)?);
    // Gray-failure injection: GPU@WINDOW:SCALE specs. Any degradation flag
    // arms the coordinator's detector — the injected truth only throttles
    // the simulator; the coordinator has to notice on its own.
    let gray = {
        let mut gray = Vec::new();
        gray.extend(parse_scaled_events(
            opts,
            "degrade-gpu",
            windows,
            cluster.len(),
            |gpu, s| ClusterEvent::GpuDegraded { gpu, compute_scale: s, bandwidth_scale: 1.0 },
        )?);
        gray.extend(parse_scaled_events(
            opts,
            "degrade-link",
            windows,
            cluster.len(),
            |gpu, s| ClusterEvent::LinkDegraded { gpu, up_scale: s, down_scale: s },
        )?);
        gray.extend(parse_events(
            opts,
            "recover-gpu",
            windows,
            cluster.len(),
            ClusterEvent::GpuRecovered,
        )?);
        gray
    };
    if !gray.is_empty() {
        ocfg.degrade_detection = true;
        events.extend(gray);
    }
    if let Some(s) = opts.get("obs-noise") {
        let amplitude: f64 = s.parse().map_err(|_| "bad --obs-noise")?;
        if !(0.0..1.0).contains(&amplitude) {
            return Err("--obs-noise must sit in [0, 1)".into());
        }
        ocfg.obs_noise = amplitude;
        ocfg.degrade_detection = true;
    }
    events.sort_by_key(|(w, _)| *w);
    ocfg.events = events;
    ocfg.elastic = opts.get("elastic").is_some_and(|v| v != "false");

    let strategies: Vec<OnlineStrategy> = match opts.get("strategy").unwrap_or("all") {
        "static" => vec![OnlineStrategy::Static],
        "periodic" => vec![OnlineStrategy::EveryWindow],
        "coordinator" => vec![OnlineStrategy::Coordinator],
        "oracle" => vec![OnlineStrategy::Oracle],
        "all" => vec![
            OnlineStrategy::Static,
            OnlineStrategy::EveryWindow,
            OnlineStrategy::Coordinator,
            OnlineStrategy::Oracle,
        ],
        other => return Err(format!("unknown strategy '{other}'")),
    };

    println!(
        "online serving: {} experts on {} GPUs, {windows} windows, Zipf({alpha:.2}) rotating every {rotate_every}{}",
        ocfg.n_experts,
        cluster.len(),
        if sampled { ", sampled windows" } else { "" }
    );
    for (w, ev) in &ocfg.events {
        println!("  event: {} GPU {} at window {w}", ev.name(), ev.gpu());
    }
    if ocfg.elastic {
        println!("  elastic: scale-up on SLO burn, consolidation on low utilization");
    }
    if ocfg.degrade_detection {
        println!(
            "  degradation detection: on (observation jitter +/-{:.0}%)",
            ocfg.obs_noise * 100.0
        );
    }
    // Serve-sim traces use the simulator's clock, not the wall clock: two runs
    // with the same seed produce byte-identical trace files.
    let tr = if opts.get("trace-out").is_some() || opts.get("jsonl-out").is_some() {
        aurora::Tracer::sim()
    } else {
        aurora::Tracer::disabled()
    };
    let metrics = metrics_for(opts);
    for (idx, strategy) in strategies.into_iter().enumerate() {
        tr.set_track(idx as u32); // one Chrome-trace lane per strategy
        let out = run_online_traced(&ocfg, &cluster, strategy, &tr, &metrics);
        println!(
            "{:<12} total {:>9.3} ms | windows p50 {:.3} / p95 {:.3} / p99 {:.3} ms | {} replan(s), {} swap(s), migration {:.3} ms",
            out.strategy,
            out.total_ms,
            out.p50_ms,
            out.p95_ms,
            out.p99_ms,
            out.replans,
            out.swaps,
            out.migration_ms
        );
    }
    write_obs_outputs(opts, &tr, &metrics)?;
    Ok(())
}

fn cmd_profile(opts: &Opts) -> Result<(), String> {
    use aurora::obs::{run_profile, ProfileConfig};

    let mut cfg = ProfileConfig::default();
    if let Some(v) = opts.get("gpus") {
        cfg.gpus = v.parse().map_err(|_| "bad --gpus")?;
    }
    if let Some(v) = opts.get("skew") {
        cfg.skew = v.parse().map_err(|_| "bad --skew")?;
    }
    if let Some(v) = opts.get("replicas") {
        cfg.replicas = v.parse().map_err(|_| "bad --replicas")?;
    }
    if let Some(v) = opts.get("seed") {
        cfg.seed = v.parse().map_err(|_| "bad --seed")?;
    }
    if cfg.gpus == 0 {
        return Err("--gpus must be >= 1".into());
    }
    let report = run_profile(&cfg)?;
    println!(
        "profiled plan+schedule: {} GPUs ({}), Zipf({:.2}), max {} replica(s)",
        cfg.gpus, report.topology, cfg.skew, cfg.replicas
    );
    println!("schedule estimate: {:.3} ms", report.schedule_ms);
    println!();
    println!("{}", report.render_table());
    if let Some(path) = opts.get("trace-out") {
        std::fs::write(path, report.tracer.to_chrome_string())
            .map_err(|e| format!("{path}: {e}"))?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = opts.get("jsonl-out") {
        std::fs::write(path, report.tracer.to_jsonl()).map_err(|e| format!("{path}: {e}"))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_trace(opts: &Opts) -> Result<(), String> {
    let cfg = opts.config()?;
    let w = Workloads::generate(&cfg);
    let out = opts.get("out").ok_or("--out required")?;
    let arr = Json::Arr(
        [&w.b16_coco, &w.b16_imagenet, &w.b32_coco, &w.b32_imagenet]
            .iter()
            .map(|t| trace_to_json(t))
            .collect(),
    );
    std::fs::write(out, arr.to_string_compact()).map_err(|e| format!("{out}: {e}"))?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_serve(opts: &Opts) -> Result<(), String> {
    let artifacts = opts.get("artifacts").unwrap_or("artifacts");
    let requests: usize = opts
        .get("requests")
        .unwrap_or("64")
        .parse()
        .map_err(|_| "bad --requests")?;
    let batch: usize = opts
        .get("batch")
        .unwrap_or("8")
        .parse()
        .map_err(|_| "bad --batch")?;
    let policy = opts.policy()?;
    aurora::serve::demo::run_serving_demo(artifacts, requests, batch, policy)
        .map_err(|e| e.to_string())
}
