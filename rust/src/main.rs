//! `aurora` — CLI for the Aurora MoE inference optimizer.
//!
//! Subcommands:
//! * `eval --figure <11a|11b|11c|11d|12|13|14|a1|all>` — regenerate a paper
//!   figure on synthetic LIMoE-like traces.
//! * `plan --cluster <homo|hetero> --models <1|2>` — print a deployment plan
//!   as JSON.
//! * `simulate --cluster <homo|hetero> --models <1|2>` — per-layer inference
//!   times and utilization for the planned deployment.
//! * `trace --out <file>` — dump the generated traces to JSON.
//! * `serve` — run the end-to-end serving demo on the AOT-compiled MoE model
//!   (requires `make artifacts`).

use aurora::config::EvalConfig;
use aurora::eval::{run_figure, Workloads};
use aurora::planner::Planner;
use aurora::schedule::SchedulePolicy;
use aurora::sim::{simulate_colocated, simulate_exclusive};
use aurora::trace::trace_to_json;
use aurora::util::Json;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = args[0].as_str();
    let opts = Opts::parse(&args[1..]);
    let result = match cmd {
        "eval" => cmd_eval(&opts),
        "plan" => cmd_plan(&opts),
        "simulate" => cmd_simulate(&opts),
        "trace" => cmd_trace(&opts),
        "serve" => cmd_serve(&opts),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => Err(format!("unknown command '{other}'")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "aurora — MoE inference optimization (paper reproduction)

USAGE:
  aurora eval     --figure <11a|11b|11c|11d|12|13|14|a1|all> [--config f.json] [--json out.json]
  aurora plan     --cluster <homo|hetero> --models <1|2> [--config f.json]
  aurora simulate --cluster <homo|hetero> --models <1|2> [--policy aurora|sjf|ljf|pairwise|rcs]
  aurora trace    --out <file.json> [--config f.json]
  aurora serve    [--artifacts DIR] [--requests N] [--batch N] [--policy aurora|rcs]
"
    );
}

/// Tiny flag parser: `--key value` pairs (the offline build has no `clap`).
struct Opts {
    kv: Vec<(String, String)>,
}

impl Opts {
    fn parse(args: &[String]) -> Opts {
        let mut kv = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    i += 1;
                    args[i].clone()
                } else {
                    "true".to_string()
                };
                kv.push((key.to_string(), val));
            } else {
                eprintln!("warning: ignoring stray argument '{a}'");
            }
            i += 1;
        }
        Opts { kv }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn config(&self) -> Result<EvalConfig, String> {
        EvalConfig::load(self.get("config"))
    }

    fn policy(&self) -> Result<SchedulePolicy, String> {
        match self.get("policy").unwrap_or("aurora") {
            "aurora" => Ok(SchedulePolicy::Aurora),
            "sjf" => Ok(SchedulePolicy::Sjf),
            "ljf" => Ok(SchedulePolicy::Ljf),
            "pairwise" => Ok(SchedulePolicy::Pairwise),
            "rcs" => Ok(SchedulePolicy::Rcs { seed: 0 }),
            other => Err(format!("unknown policy '{other}'")),
        }
    }
}

fn cmd_eval(opts: &Opts) -> Result<(), String> {
    let cfg = opts.config()?;
    let figure = opts.get("figure").unwrap_or("all");
    let reports = run_figure(figure, &cfg)?;
    for r in &reports {
        println!("{}", r.render());
    }
    if let Some(path) = opts.get("json") {
        let arr = Json::Arr(reports.iter().map(|r| r.to_json()).collect());
        std::fs::write(path, arr.to_string_compact()).map_err(|e| format!("{path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cluster_for(opts: &Opts, cfg: &EvalConfig) -> Result<aurora::Cluster, String> {
    match opts.get("cluster").unwrap_or("homo") {
        "homo" | "homogeneous" => Ok(cfg.homogeneous_cluster()),
        "hetero" | "heterogeneous" => Ok(cfg.heterogeneous_cluster()),
        other => Err(format!("unknown cluster '{other}'")),
    }
}

fn cmd_plan(opts: &Opts) -> Result<(), String> {
    let cfg = opts.config()?;
    let cluster = cluster_for(opts, &cfg)?;
    let w = Workloads::generate(&cfg);
    let planner = Planner::default();
    let models: usize = opts
        .get("models")
        .unwrap_or("1")
        .parse()
        .map_err(|_| "bad --models")?;
    let plan = match models {
        1 => planner.plan_exclusive(&w.b16_coco, &cluster),
        2 => planner.plan_colocated(&w.b16_coco, &w.b32_coco, &cluster),
        _ => return Err("--models must be 1 or 2 (§2.4: at most two per GPU)".into()),
    };
    println!("{}", plan.to_json().to_string_compact());
    Ok(())
}

fn cmd_simulate(opts: &Opts) -> Result<(), String> {
    let cfg = opts.config()?;
    let cluster = cluster_for(opts, &cfg)?;
    let policy = opts.policy()?;
    let w = Workloads::generate(&cfg);
    let planner = Planner {
        policy,
        planning_layer: 0,
    };
    let models: usize = opts
        .get("models")
        .unwrap_or("1")
        .parse()
        .map_err(|_| "bad --models")?;
    println!(
        "scenario: {} model(s), {} cluster, policy {}",
        models,
        if cluster.is_homogeneous() {
            "homogeneous"
        } else {
            "heterogeneous"
        },
        policy.name()
    );
    match models {
        1 => {
            let plan = planner.plan_exclusive(&w.b16_coco, &cluster);
            for (k, layer) in plan.place_a(&w.b16_coco).iter().enumerate() {
                let (res, _) = simulate_exclusive(layer, &cluster, policy);
                println!(
                    "layer {}: inference {:.3} ms, util {:.1}%, comm {:.3} ms",
                    k + 1,
                    res.inference_ms,
                    res.utilization * 100.0,
                    res.comm_ms
                );
            }
        }
        2 => {
            let plan = planner.plan_colocated(&w.b16_coco, &w.b32_coco, &cluster);
            let pa = plan.place_a(&w.b16_coco);
            let pb = plan.place_b(&w.b32_coco);
            for (k, (la, lb)) in pa.iter().zip(&pb).enumerate() {
                let (res, _) = simulate_colocated(la, lb, &cluster, policy);
                println!(
                    "layer {}: inference {:.3} ms, util {:.1}%, agg comm {:.3} ms",
                    k + 1,
                    res.inference_ms,
                    res.utilization * 100.0,
                    res.comm_ms
                );
            }
        }
        _ => return Err("--models must be 1 or 2".into()),
    }
    Ok(())
}

fn cmd_trace(opts: &Opts) -> Result<(), String> {
    let cfg = opts.config()?;
    let w = Workloads::generate(&cfg);
    let out = opts.get("out").ok_or("--out required")?;
    let arr = Json::Arr(
        [&w.b16_coco, &w.b16_imagenet, &w.b32_coco, &w.b32_imagenet]
            .iter()
            .map(|t| trace_to_json(t))
            .collect(),
    );
    std::fs::write(out, arr.to_string_compact()).map_err(|e| format!("{out}: {e}"))?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_serve(opts: &Opts) -> Result<(), String> {
    let artifacts = opts.get("artifacts").unwrap_or("artifacts");
    let requests: usize = opts
        .get("requests")
        .unwrap_or("64")
        .parse()
        .map_err(|_| "bad --requests")?;
    let batch: usize = opts
        .get("batch")
        .unwrap_or("8")
        .parse()
        .map_err(|_| "bad --batch")?;
    let policy = opts.policy()?;
    aurora::serve::demo::run_serving_demo(artifacts, requests, batch, policy)
        .map_err(|e| e.to_string())
}
